//! Self-check: the shipped tree passes its own lint.
//!
//! This is the enforcement point for the whole rule set — any new
//! finding (a wall-clock call outside the facade, an unlisted Relaxed,
//! a lock-order inversion, a format-arity slip, an `EventKind` /
//! config-surface drift) fails `cargo test` with the full report, so
//! violations cannot land without either a fix or a reviewed manifest
//! entry.

use std::path::Path;

fn repo_root() -> &'static Path {
    // CARGO_MANIFEST_DIR is the directory holding Cargo.toml, which is
    // also where lint/rules/ lives.
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn shipped_tree_is_lint_clean() {
    let report = omprt::lint::run(repo_root()).expect("lint run");
    assert!(
        report.is_clean(),
        "lint findings in the shipped tree:\n{}",
        report.render()
    );
}

#[test]
fn lint_scans_the_whole_tree() {
    // Guard against a silently-degenerate run (wrong root, empty walk):
    // the tree has far more than 50 Rust files and must keep scanning
    // the lint module itself.
    let report = omprt::lint::run(repo_root()).expect("lint run");
    assert!(
        report.files_scanned > 50,
        "only {} files scanned — wrong root?",
        report.files_scanned
    );
    let files = omprt::lint::rust_files(repo_root()).expect("walk");
    assert!(files.iter().any(|f| f == "rust/src/lint/mod.rs"));
    assert!(files.iter().any(|f| f == "rust/tests/lint_clean.rs"));
}

#[test]
fn manifests_parse_and_declare_the_sched_lock_order() {
    let m = omprt::lint::Manifests::load(repo_root()).expect("manifests");
    // The facade file itself must be allowlisted for the wallclock rule.
    assert!(m.wallclock_allow.iter().any(|f| f == "rust/src/util/clock.rs"));
    // The declared sched lock order: inflight_reg < queue < clients.
    let rank = |name: &str| m.lock_ranks[&format!("rust/src/sched/pool.rs:{name}")];
    assert!(rank("inflight_reg") < rank("queue"));
    assert!(rank("queue") < rank("clients"));
    // The seqlock/latch fields stay deny-listed.
    for f in ["settled", "state", "stamp"] {
        assert!(m.atomics_deny.iter().any(|d| d == f), "`{f}` missing from deny list");
    }
    // Config rows cover the full `[pool]` surface (drift in either
    // direction is a lint finding; this just pins the floor).
    assert!(m.consistency.len() >= 19, "only {} consistency rows", m.consistency.len());
}
