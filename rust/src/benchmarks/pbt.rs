//! 570.pbt analog: block-tridiagonal line sweeps.
//!
//! N independent tridiagonal systems (one per mesh line) solved with the
//! Thomas algorithm, one line per thread under **static chunked**
//! scheduling — the line-sweep phase structure of BT.

use super::common::{checksum_f32, compare_f32, unpack_range, BenchResult, Benchmark, Scale};
use crate::coordinator::Coordinator;
use crate::devrt::{irlib, state};
use crate::hostrt::{DataEnv, MapType};
use crate::ir::passes::OptLevel;
use crate::ir::{AddrSpace, CmpPred, FunctionBuilder, Module, Operand, Type, UnOp};
use crate::sim::LaunchConfig;
use crate::util::{Error, SplitMix64};

/// The benchmark.
pub struct Pbt {
    lines: usize,
    len: usize,
    teams: u32,
    block: u32,
    chunk: i32,
}

impl Pbt {
    /// Configure for a scale.
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Small => Pbt { lines: 64, len: 32, teams: 2, block: 32, chunk: 4 },
            Scale::Paper => Pbt { lines: 1024, len: 64, teams: 6, block: 64, chunk: 4 },
        }
    }

    /// Thomas solve per line: diag 4, off-diag −1 (SPD), rhs per line.
    /// Buffers: rhs (lines×len, in), out (lines×len), cw (lines×len
    /// scratch for the modified upper diagonal).
    fn module(&self) -> Module {
        let len = self.len as i32;
        let lines = self.lines as i32;
        let chunk = self.chunk;
        let mut m = Module::new("pbt");
        let mut b = FunctionBuilder::new("sweep", &[Type::I64; 3], None).kernel();
        let (rhs, out, cw) = (b.param(0), b.param(1), b.param(2));
        irlib::emit_spmd_prologue(&mut b);
        // Lines are distributed over the *global* thread space (the
        // `teams distribute parallel for schedule(static, chunk)` shape):
        // the packed first chunk comes from the worksharing runtime; the
        // thread then strides by total_threads·chunk.
        let (gid, total) = super::common::emit_gid_stride(&mut b);
        let packed = b.call(
            "__kmpc_for_static_init_4",
            &[
                gid.into(),
                Operand::i32(state::SCHED_STATIC_CHUNKED as i32),
                Operand::i32(0),
                Operand::i32(lines),
                Operand::i32(chunk),
            ],
            Type::I64,
        );
        let (lb0, ub0) = unpack_range(&mut b, packed);
        let stride = b.mul(total, Operand::i32(chunk));
        // for (start = lb0; start < lines; start += nthreads*chunk)
        let start = b.copy(lb0);
        let end = b.copy(ub0);
        b.loop_(|b| {
            let done = b.cmp(CmpPred::Ge, start, Operand::i32(lines));
            b.if_(done, |b| b.break_());
            b.for_range(start, end, Operand::i32(1), |b, line| {
                let base = b.mul(line, Operand::i32(len));
                // forward sweep
                // c'[0] = -1/4 ; d'[0] = rhs[0]/4
                let b0 = b.index(rhs, base, 4);
                let d0 = b.load(Type::F32, AddrSpace::Global, b0);
                let d0p = b.mul(d0, Operand::f32(0.25));
                let o0 = b.index(out, base, 4);
                b.store(Type::F32, AddrSpace::Global, o0, d0p);
                let c0 = b.index(cw, base, 4);
                b.store(Type::F32, AddrSpace::Global, c0, Operand::f32(-0.25));
                b.for_range(Operand::i32(1), Operand::i32(len), Operand::i32(1), |b, i| {
                    let idx = b.add(base, i);
                    let im1 = b.add(idx, Operand::i32(-1));
                    let cprev_a = b.index(cw, im1, 4);
                    let cprev = b.load(Type::F32, AddrSpace::Global, cprev_a);
                    // denom = 4 - (-1)*c'[i-1] = 4 + c'
                    let denom = b.add(cprev, Operand::f32(4.0));
                    let inv = b.un(UnOp::FRcp, denom);
                    let ca = b.index(cw, idx, 4);
                    let cv = b.mul(inv, Operand::f32(-1.0));
                    b.store(Type::F32, AddrSpace::Global, ca, cv);
                    let ra = b.index(rhs, idx, 4);
                    let rv = b.load(Type::F32, AddrSpace::Global, ra);
                    let dprev_a = b.index(out, im1, 4);
                    let dprev = b.load(Type::F32, AddrSpace::Global, dprev_a);
                    // d' = (rhs + d'[i-1]) / denom   (a = -1)
                    let num = b.add(rv, dprev);
                    let dv = b.mul(num, inv);
                    let oa = b.index(out, idx, 4);
                    b.store(Type::F32, AddrSpace::Global, oa, dv);
                });
                // back substitution: x[i] = d'[i] - c'[i] x[i+1]
                let last = b.add(base, Operand::i32(len - 1));
                let xa = b.index(out, last, 4);
                let xl = b.load(Type::F32, AddrSpace::Global, xa);
                let xnext = b.copy(xl);
                let i = b.copy(Operand::i32(len - 2));
                b.loop_(|b| {
                    let neg = b.cmp(CmpPred::Lt, i, Operand::i32(0));
                    b.if_(neg, |b| b.break_());
                    let idx = b.add(base, i);
                    let ca = b.index(cw, idx, 4);
                    let cv = b.load(Type::F32, AddrSpace::Global, ca);
                    let oa = b.index(out, idx, 4);
                    let dv = b.load(Type::F32, AddrSpace::Global, oa);
                    let cx = b.mul(cv, xnext);
                    let xv = b.sub(dv, cx);
                    b.store(Type::F32, AddrSpace::Global, oa, xv);
                    b.assign(xnext, xv);
                    let im1 = b.add(i, Operand::i32(-1));
                    b.assign(i, im1);
                });
            });
            let ns = b.add(start, stride);
            b.assign(start, ns);
            let ne0 = b.add(end, stride);
            let ne = b.bin(crate::ir::BinOp::SMin, ne0, Operand::i32(lines));
            b.assign(end, ne);
        });
        irlib::emit_spmd_epilogue(&mut b);
        b.ret();
        m.add_func(b.build());
        m
    }

    fn rhs(&self) -> Vec<f32> {
        let mut rng = SplitMix64::new(570);
        let mut v = vec![0f32; self.lines * self.len];
        rng.fill_f32(&mut v, -1.0, 1.0);
        v
    }

    fn host_ref(&self) -> Vec<f32> {
        let len = self.len;
        let rhs = self.rhs();
        let mut out = vec![0f32; self.lines * len];
        let mut cw = vec![0f32; len];
        for line in 0..self.lines {
            let base = line * len;
            cw[0] = -0.25;
            out[base] = rhs[base] * 0.25;
            for i in 1..len {
                let inv = 1.0 / (4.0 + cw[i - 1]);
                cw[i] = -inv;
                out[base + i] = (rhs[base + i] + out[base + i - 1]) * inv;
            }
            for i in (0..len - 1).rev() {
                out[base + i] -= cw[i] * out[base + i + 1];
            }
        }
        out
    }
}

impl Benchmark for Pbt {
    fn name(&self) -> &'static str {
        "570.pbt"
    }

    fn run(&self, c: &Coordinator) -> Result<BenchResult, Error> {
        let image = c.prepare(self.module(), OptLevel::O2)?;
        let mut env = DataEnv::new(&c.device);
        let rhs = self.rhs();
        let mut out = vec![0f32; self.lines * self.len];
        let cw = vec![0f32; self.lines * self.len];
        let args = [
            env.map(&rhs, MapType::To)?,
            env.map(&out, MapType::From)?,
            env.map(&cw, MapType::Alloc)?,
        ];
        let stats = c.run_region(
            &image,
            "sweep",
            "pbt.sweep",
            &args,
            LaunchConfig::new(self.teams, self.block),
        )?;
        env.unmap(&mut out)?;
        let want = self.host_ref();
        let verified = match compare_f32(&out, &want, 1e-3) {
            None => true,
            Some(msg) => {
                log::error!("pbt verify failed: {msg}");
                false
            }
        };
        Ok(BenchResult { kernel_wall: stats.wall, verified, checksum: checksum_f32(&out) })
    }
}
