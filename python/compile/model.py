"""L2: the JAX compute graphs AOT-compiled into PJRT artifacts.

Each function here is a *payload*: the numeric hot spot of one target
region of the benchmark suite, calling the L1 Pallas kernels where the
compute pattern profits from tiling. `aot.py` lowers each payload once to
HLO text; the Rust coordinator loads and executes them — Python is never
on the request path.

Payload shapes are fixed at AOT time (one executable per shape, like one
PTX/GCN kernel per template instantiation in the paper's world) and are
recorded in artifacts/manifest.toml.
"""

import jax.numpy as jnp

from .kernels import ref
from .kernels.stencil import stencil_tile as _pallas_stencil
from .kernels.vgh import vgh_matmul as _pallas_vgh

# ---- shapes (single source of truth; mirrored into manifest.toml) -----

# postencil: 256×256 interior + halo border; 8 teams × 32-row stripes.
STENCIL_ROWS = 32
STENCIL_COLS = 258  # 256 interior + 2 halo columns

# miniQMC evaluate_vgh: P positions × 10 planes, B basis, O orbitals.
VGH_P = 16
VGH_PLANES = 10
VGH_B = 64
VGH_O = 32

# miniQMC evaluateDetRatios: K candidate moves against one inverse row.
DET_K = 16
DET_B = 64


def stencil_payload(slab):
    """One Jacobi step on a (STENCIL_ROWS+2, STENCIL_COLS) slab."""
    return (_pallas_stencil(slab),)


def vgh_payload(basis, coef):
    """(10·P, B) @ (B, O) value/gradient/hessian contraction."""
    return (_pallas_vgh(basis, coef),)


def detratio_payload(u, inv_row):
    """K determinant ratios: u @ inv_row."""
    return (ref.detratio_tile(u, inv_row),)


#: name -> (fn, input shapes, output shape). aot.py iterates this table.
PAYLOADS = {
    "stencil_tile": (
        stencil_payload,
        [(STENCIL_ROWS + 2, STENCIL_COLS)],
        (STENCIL_ROWS, STENCIL_COLS),
    ),
    "vgh_tile": (
        vgh_payload,
        [(VGH_PLANES * VGH_P, VGH_B), (VGH_B, VGH_O)],
        (VGH_PLANES * VGH_P, VGH_O),
    ),
    "detratio_tile": (
        detratio_payload,
        [(DET_K, DET_B), (DET_B,)],
        (DET_K,),
    ),
}
