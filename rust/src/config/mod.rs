//! Mini-TOML configuration system.
//!
//! The offline crate set has no `serde`/`toml`, so this is a small,
//! dependency-free parser for the subset we use: sections, string /
//! integer / float / boolean values, and flat arrays of strings or
//! integers. Used by benchmark run configs, the CLI defaults, the
//! AOT artifact manifest written by `python/compile/aot.py`, and the
//! `[pool]` scheduler table (devices, batching/sharding knobs, the
//! `adaptive` / `fairness` / `client_weights` / `client_slos` keys, and
//! the health layer's `faults` / `watchdog` / `watchdog_min_ms` /
//! `retry_max` keys — see [`crate::sched::PoolConfig::from_config`]).
//!
//! ```text
//! # comment
//! [section]
//! key = "string"
//! n = 42
//! x = 1.5
//! flag = true
//! dims = [32, 32]
//! names = ["a", "b"]
//! ```

use crate::util::Error;
use std::collections::BTreeMap;

/// A configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    IntList(Vec<i64>),
    StrList(Vec<String>),
}

impl Value {
    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Integer view (accepts Int only).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Non-negative integer view (rejects negatives — used by size/count
    /// knobs like the `[pool]` table's `queue_cap`/`cache_budget_bytes`).
    pub fn as_uint(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }
    /// Float view (Int promotes).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// Bool view.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Integer-list view.
    pub fn as_int_list(&self) -> Option<&[i64]> {
        match self {
            Value::IntList(v) => Some(v),
            _ => None,
        }
    }
    /// String-list view.
    pub fn as_str_list(&self) -> Option<&[String]> {
        match self {
            Value::StrList(v) => Some(v),
            _ => None,
        }
    }
}

/// One `[section]` of keys.
pub type Section = BTreeMap<String, Value>;

/// A parsed configuration document.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    /// Keys before any section header.
    pub root: Section,
    /// Sections (BTreeMap: deterministic order).
    pub sections: BTreeMap<String, Section>,
}

impl Config {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<Self, Error> {
        let mut cfg = Config::default();
        let mut current: Option<String> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                let name = name.trim().to_string();
                if name.is_empty() {
                    return Err(err(lineno, "empty section name"));
                }
                cfg.sections.entry(name.clone()).or_default();
                current = Some(name);
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| err(lineno, "expected `key = value`"))?;
            let key = k.trim().to_string();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(v.trim()).map_err(|m| err(lineno, &m))?;
            let section = match &current {
                Some(s) => cfg.sections.get_mut(s).unwrap(),
                None => &mut cfg.root,
            };
            section.insert(key, value);
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Self, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", path.display())))?;
        Self::parse(&text)
    }

    /// Section accessor.
    pub fn section(&self, name: &str) -> Option<&Section> {
        self.sections.get(name)
    }

    /// `section.key` lookup (root when `section` is None).
    pub fn get(&self, section: Option<&str>, key: &str) -> Option<&Value> {
        match section {
            Some(s) => self.sections.get(s).and_then(|sec| sec.get(key)),
            None => self.root.get(key),
        }
    }

    /// Typed helper: integer with default.
    pub fn int_or(&self, section: Option<&str>, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    /// Typed helper: string with default.
    pub fn str_or<'a>(&'a self, section: Option<&str>, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    /// Serialize back to text (used to write the artifact manifest).
    pub fn to_text(&self) -> String {
        fn write_section(out: &mut String, s: &Section) {
            for (k, v) in s {
                out.push_str(&format!("{k} = {}\n", render(v)));
            }
        }
        let mut out = String::new();
        write_section(&mut out, &self.root);
        for (name, s) in &self.sections {
            out.push_str(&format!("\n[{name}]\n"));
            write_section(&mut out, s);
        }
        out
    }
}

fn render(v: &Value) -> String {
    match v {
        Value::Str(s) => format!("\"{s}\""),
        Value::Int(i) => i.to_string(),
        Value::Float(f) => {
            if f.fract() == 0.0 {
                format!("{f:.1}")
            } else {
                f.to_string()
            }
        }
        Value::Bool(b) => b.to_string(),
        Value::IntList(v) => {
            format!("[{}]", v.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(", "))
        }
        Value::StrList(v) => {
            format!("[{}]", v.iter().map(|s| format!("\"{s}\"")).collect::<Vec<_>>().join(", "))
        }
    }
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("line {}: {msg}", lineno + 1))
}

/// Strip a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::IntList(vec![]));
        }
        let items: Vec<&str> = inner.split(',').map(|i| i.trim()).collect();
        if items[0].starts_with('"') {
            let mut out = vec![];
            for it in items {
                match parse_value(it)? {
                    Value::Str(s) => out.push(s),
                    _ => return Err("mixed array".into()),
                }
            }
            return Ok(Value::StrList(out));
        }
        let mut out = vec![];
        for it in items {
            out.push(it.parse::<i64>().map_err(|e| format!("bad int `{it}`: {e}"))?);
        }
        return Ok(Value::IntList(out));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
        # top comment
        title = "omprt"
        reps = 5

        [postencil]
        grid = [512, 512]
        iters = 100
        tol = 1.0e-5
        verify = true
        names = ["a", "b"]  # trailing comment
    "#;

    #[test]
    fn parses_root_and_sections() {
        let c = Config::parse(DOC).unwrap();
        assert_eq!(c.root["title"], Value::Str("omprt".into()));
        assert_eq!(c.root["reps"], Value::Int(5));
        let s = c.section("postencil").unwrap();
        assert_eq!(s["grid"], Value::IntList(vec![512, 512]));
        assert_eq!(s["iters"], Value::Int(100));
        assert_eq!(s["verify"], Value::Bool(true));
        assert_eq!(s["names"], Value::StrList(vec!["a".into(), "b".into()]));
        assert!((s["tol"].as_float().unwrap() - 1e-5).abs() < 1e-12);
    }

    #[test]
    fn comment_inside_string_is_preserved() {
        let c = Config::parse("k = \"a # b\"").unwrap();
        assert_eq!(c.root["k"], Value::Str("a # b".into()));
    }

    #[test]
    fn typed_helpers_have_defaults() {
        let c = Config::parse(DOC).unwrap();
        assert_eq!(c.int_or(Some("postencil"), "iters", 1), 100);
        assert_eq!(c.int_or(Some("postencil"), "missing", 7), 7);
        assert_eq!(c.str_or(None, "title", "x"), "omprt");
    }

    #[test]
    fn as_uint_rejects_negatives_and_non_ints() {
        assert_eq!(Value::Int(5).as_uint(), Some(5));
        assert_eq!(Value::Int(0).as_uint(), Some(0));
        assert_eq!(Value::Int(-1).as_uint(), None);
        assert_eq!(Value::Str("5".into()).as_uint(), None);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Config::parse("\n\nbad line").unwrap_err().to_string();
        assert!(e.contains("line 3"), "{e}");
    }

    #[test]
    fn roundtrips_through_to_text() {
        let c = Config::parse(DOC).unwrap();
        let again = Config::parse(&c.to_text()).unwrap();
        assert_eq!(c, again);
    }

    #[test]
    fn rejects_malformed_values() {
        assert!(Config::parse("k = \"unterminated").is_err());
        assert!(Config::parse("k = [1, 2").is_err());
        assert!(Config::parse("k = nope").is_err());
        assert!(Config::parse("[]").is_err());
    }
}
