//! The conformance suite — our SOLLVE V&V / OvO analog (paper §4.2).
//!
//! A set of named functional tests over the device-runtime API. Each test
//! builds a small kernel, runs it, and reduces the observable output to a
//! canonical string. The runner executes the whole suite against a
//! runtime build; the §4.2 claim is that the reports are **identical**
//! under the legacy and portable runtimes (see `rust/tests/conformance.rs`
//! and `examples/conformance_suite.rs`).

use crate::coordinator::Coordinator;
use crate::devrt::{irlib, state, RuntimeKind};
use crate::hostrt::{DataEnv, MapType};
use crate::ir::passes::OptLevel;
use crate::ir::{
    AddrSpace, BinOp, CastOp, CmpPred, FunctionBuilder, Module, Operand, Type,
};
use crate::sim::{Arch, LaunchConfig};
use crate::util::Error;

/// One conformance test.
pub struct Test {
    /// Suite-unique name.
    pub name: &'static str,
    /// Runs the test; returns a canonical observable string.
    pub run: fn(&Coordinator) -> Result<String, Error>,
}

/// Result row of a suite run.
#[derive(Debug, Clone, PartialEq)]
pub struct Outcome {
    /// Test name.
    pub name: String,
    /// `Ok(observable)` or the error text.
    pub result: Result<String, String>,
}

/// Run the full suite on a coordinator.
pub fn run_suite(c: &Coordinator) -> Vec<Outcome> {
    all_tests()
        .iter()
        .map(|t| Outcome {
            name: t.name.to_string(),
            result: (t.run)(c).map_err(|e| e.to_string()),
        })
        .collect()
}

/// Run the suite under every (runtime, arch) combination and return
/// `(per-config outcomes, identical_across_configs)`.
pub fn run_matrix() -> (Vec<(RuntimeKind, Arch, Vec<Outcome>)>, bool) {
    let mut rows = vec![];
    for kind in RuntimeKind::all() {
        for arch in Arch::all() {
            let c = Coordinator::new(kind, arch);
            rows.push((kind, arch, run_suite(&c)));
        }
    }
    // Identical = same pass/fail and same observables per test name,
    // modulo the arch-dependent observables (tests encode arch-dependent
    // values in an arch-independent canonical form).
    let first = &rows[0].2;
    let identical = rows.iter().all(|(_, _, o)| o == first);
    (rows, identical)
}

/// Helper: run kernel `k` from `module` with one u32 output buffer of
/// `words` words; returns the buffer canonicalized as a string.
fn run_words(
    c: &Coordinator,
    module: Module,
    words: usize,
    grid: u32,
    block: u32,
) -> Result<String, Error> {
    let image = c.prepare(module, OptLevel::O2)?;
    let mut env = DataEnv::new(&c.device);
    let mut out = vec![0u32; words];
    let d = env.map(&out, MapType::Tofrom)?;
    c.device.offload(&image, "k", &[d], LaunchConfig::new(grid, block))?;
    env.unmap(&mut out)?;
    Ok(format!("{out:?}"))
}

fn kernel(body: impl FnOnce(&mut FunctionBuilder, crate::ir::Reg)) -> Module {
    let mut m = Module::new("conf");
    let mut b = FunctionBuilder::new("k", &[Type::I64], None).kernel();
    let out = b.param(0);
    irlib::emit_spmd_prologue(&mut b);
    body(&mut b, out);
    irlib::emit_spmd_epilogue(&mut b);
    b.ret();
    m.add_func(b.build());
    m
}

/// The full test list.
pub fn all_tests() -> &'static [Test] {
    &[
        Test { name: "ids.thread_team", run: t_ids },
        Test { name: "barrier.flush_visibility", run: t_barrier },
        Test { name: "workshare.static_coverage", run: t_static },
        Test { name: "workshare.static_chunked", run: t_chunked },
        Test { name: "workshare.dynamic_once", run: t_dynamic },
        Test { name: "workshare.guided_once", run: t_guided },
        Test { name: "atomic.add_sum", run: t_atomic_add },
        Test { name: "atomic.max_unsigned", run: t_atomic_max },
        Test { name: "atomic.exchange_last", run: t_atomic_exchange },
        Test { name: "atomic.cas_single_winner", run: t_atomic_cas },
        Test { name: "atomic.inc_wraps", run: t_atomic_inc },
        Test { name: "reduce.add_f64", run: t_reduce_f64 },
        Test { name: "reduce.warp_shuffle_u32", run: t_warp_reduce },
        Test { name: "alloc_shared.stack", run: t_alloc_shared },
        Test { name: "parallel.generic_two_regions", run: t_generic_parallel },
        Test { name: "icv.num_threads", run: t_icv },
        Test { name: "variant.wrong_arch_intrinsic_traps", run: t_wrong_arch },
    ]
}

// ---- individual tests --------------------------------------------------

fn t_ids(c: &Coordinator) -> Result<String, Error> {
    // out[0] = Σ team numbers over teams; out[1] = nteams; out[2] = nthreads
    let m = kernel(|b, out| {
        let tid = b.call("omp_get_thread_num", &[], Type::I32);
        let is0 = b.cmp(CmpPred::Eq, tid, Operand::i32(0));
        b.if_(is0, |b| {
            let team = b.call("omp_get_team_num", &[], Type::I32);
            b.call("__kmpc_atomic_add", &[out.into(), team.into()], Type::I32);
            let nteams = b.call("omp_get_num_teams", &[], Type::I32);
            let a1 = b.add(out, Operand::i64(4));
            b.store(Type::I32, AddrSpace::Global, a1, nteams);
            let nth = b.call("omp_get_num_threads", &[], Type::I32);
            let a2 = b.add(out, Operand::i64(8));
            b.store(Type::I32, AddrSpace::Global, a2, nth);
        });
    });
    run_words(c, m, 3, 4, 64)
}

fn t_barrier(c: &Coordinator) -> Result<String, Error> {
    // thread 1 writes, barrier+flush, thread 0 reads.
    let m = kernel(|b, out| {
        let tid = b.call("gpu.tid.x", &[], Type::I32);
        let is1 = b.cmp(CmpPred::Eq, tid, Operand::i32(1));
        b.if_(is1, |b| {
            let a1 = b.add(out, Operand::i64(4));
            b.store(Type::I32, AddrSpace::Global, a1, Operand::i32(77));
            b.call_void("__kmpc_flush", &[]);
        });
        b.call_void("__kmpc_barrier", &[]);
        let is0 = b.cmp(CmpPred::Eq, tid, Operand::i32(0));
        b.if_(is0, |b| {
            let a1 = b.add(out, Operand::i64(4));
            let v = b.load(Type::I32, AddrSpace::Global, a1);
            b.store(Type::I32, AddrSpace::Global, out, v);
        });
    });
    run_words(c, m, 2, 1, 64)
}

fn t_static(c: &Coordinator) -> Result<String, Error> {
    // each thread marks its static range; every element must be 1.
    let m = kernel(|b, out| {
        let (lb, ub) =
            crate::benchmarks::common::emit_static_range(b, Operand::i32(0), Operand::i32(97));
        b.for_range(lb, ub, Operand::i32(1), |b, i| {
            let a = b.index(out, i, 4);
            b.call("__kmpc_atomic_add", &[a.into(), Operand::i32(1)], Type::I32);
        });
    });
    run_words(c, m, 97, 1, 32)
}

fn t_chunked(c: &Coordinator) -> Result<String, Error> {
    let m = kernel(|b, out| {
        let tid = b.call("omp_get_thread_num", &[], Type::I32);
        let packed = b.call(
            "__kmpc_for_static_init_4",
            &[
                tid.into(),
                Operand::i32(state::SCHED_STATIC_CHUNKED as i32),
                Operand::i32(0),
                Operand::i32(64),
                Operand::i32(3),
            ],
            Type::I64,
        );
        let (lb, ub) = crate::benchmarks::common::unpack_range(b, packed);
        let nth = b.call("omp_get_num_threads", &[], Type::I32);
        let stride = b.mul(nth, Operand::i32(3));
        let start = b.copy(lb);
        let end = b.copy(ub);
        b.loop_(|b| {
            let done = b.cmp(CmpPred::Ge, start, Operand::i32(64));
            b.if_(done, |b| b.break_());
            b.for_range(start, end, Operand::i32(1), |b, i| {
                let a = b.index(out, i, 4);
                b.call("__kmpc_atomic_add", &[a.into(), Operand::i32(1)], Type::I32);
            });
            let ns = b.add(start, stride);
            b.assign(start, ns);
            let ne0 = b.add(end, stride);
            let ne = b.bin(BinOp::SMin, ne0, Operand::i32(64));
            b.assign(end, ne);
        });
    });
    run_words(c, m, 64, 1, 16)
}

fn dispatch_test(c: &Coordinator, sched: u32) -> Result<String, Error> {
    let m = kernel(move |b, out| {
        b.call_void(
            "__kmpc_dispatch_init_4",
            &[Operand::i64(0), Operand::i64(50), Operand::i64(3), Operand::i64(sched as i64)],
        );
        b.loop_(|b| {
            let packed = b.call("__kmpc_dispatch_next_4", &[], Type::I64);
            let done = b.cmp(CmpPred::Eq, packed, Operand::i64(state::DISPATCH_DONE as i64));
            b.if_(done, |b| b.break_());
            let (lb, ub) = crate::benchmarks::common::unpack_range(b, packed);
            b.for_range(lb, ub, Operand::i32(1), |b, i| {
                let a = b.index(out, i, 4);
                b.call("__kmpc_atomic_add", &[a.into(), Operand::i32(1)], Type::I32);
            });
        });
        b.call_void("__kmpc_dispatch_fini_4", &[]);
    });
    run_words(c, m, 50, 1, 48)
}

fn t_dynamic(c: &Coordinator) -> Result<String, Error> {
    dispatch_test(c, state::SCHED_DYNAMIC)
}

fn t_guided(c: &Coordinator) -> Result<String, Error> {
    dispatch_test(c, state::SCHED_GUIDED)
}

fn t_atomic_add(c: &Coordinator) -> Result<String, Error> {
    let m = kernel(|b, out| {
        let tid = b.call("gpu.tid.x", &[], Type::I32);
        b.call("__kmpc_atomic_add", &[out.into(), tid.into()], Type::I32);
    });
    run_words(c, m, 1, 2, 64) // 2 teams × Σ(0..63) = 2·2016
}

fn t_atomic_max(c: &Coordinator) -> Result<String, Error> {
    let m = kernel(|b, out| {
        let tid = b.call("gpu.tid.x", &[], Type::I32);
        let v = b.mul(tid, Operand::i32(13));
        let h = b.srem(v, Operand::i32(101));
        b.call("__kmpc_atomic_max", &[out.into(), h.into()], Type::I32);
    });
    run_words(c, m, 1, 1, 64)
}

fn t_atomic_exchange(c: &Coordinator) -> Result<String, Error> {
    // every thread exchanges 42 in; the final value must be 42 and the
    // sum of returned old values must be 42·(N−1) + initial(0).
    let m = kernel(|b, out| {
        let old = b.call("__kmpc_atomic_exchange", &[out.into(), Operand::i32(42)], Type::I32);
        let a1 = b.add(out, Operand::i64(4));
        b.call("__kmpc_atomic_add", &[a1.into(), old.into()], Type::I32);
    });
    run_words(c, m, 2, 1, 32)
}

fn t_atomic_cas(c: &Coordinator) -> Result<String, Error> {
    // out starts 0; everyone CAS(0 → tid+1): exactly one winner; count
    // successes by comparing returned old value with 0.
    let m = kernel(|b, out| {
        let tid = b.call("gpu.tid.x", &[], Type::I32);
        let t1 = b.add(tid, Operand::i32(1));
        let old =
            b.call("__kmpc_atomic_cas", &[out.into(), Operand::i32(0), t1.into()], Type::I32);
        let won = b.cmp(CmpPred::Eq, old, Operand::i32(0));
        b.if_(won, |b| {
            let a1 = b.add(out, Operand::i64(4));
            b.call("__kmpc_atomic_add", &[a1.into(), Operand::i32(1)], Type::I32);
        });
    });
    let s = run_words(c, m, 2, 1, 64)?;
    // winner value is nondeterministic; canonicalize: [nonzero, 1]
    let winner_ok = !s.starts_with("[0,");
    let one_winner = s.ends_with(", 1]");
    Ok(format!("winner_nonzero={winner_ok} single_winner={one_winner}"))
}

fn t_atomic_inc(c: &Coordinator) -> Result<String, Error> {
    let m = kernel(|b, out| {
        b.call("__kmpc_atomic_inc", &[out.into(), Operand::i32(6)], Type::I32);
    });
    // 100 threads wrapping at 6 → 100 mod 7 = 2
    run_words(c, m, 1, 1, 100)
}

fn t_reduce_f64(c: &Coordinator) -> Result<String, Error> {
    let m = kernel(|b, out| {
        let tid = b.call("omp_get_thread_num", &[], Type::I32);
        let tf = b.cast(CastOp::SIToFP, tid, Type::F64);
        let total = b.call("__kmpc_reduce_add_f64", &[tid.into(), tf.into()], Type::F64);
        let ti = b.cast(CastOp::FPToSI, total, Type::I32);
        let is0 = b.cmp(CmpPred::Eq, tid, Operand::i32(0));
        b.if_(is0, |b| {
            b.store(Type::I32, AddrSpace::Global, out, ti);
        });
    });
    run_words(c, m, 1, 1, 96) // Σ(0..95) = 4560
}

fn t_warp_reduce(c: &Coordinator) -> Result<String, Error> {
    // Each warp reduces its lane ids; lane 0 adds the warp sum. The total
    // equals Σ tid — canonical across warp widths.
    let m = kernel(|b, out| {
        let tid = b.call("gpu.tid.x", &[], Type::I32);
        let sum = b.call("__kmpc_warp_reduce_add_u32", &[tid.into()], Type::I32);
        let lane = b.call("gpu.lane.id", &[], Type::I32);
        let is0 = b.cmp(CmpPred::Eq, lane, Operand::i32(0));
        b.if_(is0, |b| {
            b.call("__kmpc_atomic_add", &[out.into(), sum.into()], Type::I32);
        });
    });
    run_words(c, m, 1, 1, 128)
}

fn t_alloc_shared(c: &Coordinator) -> Result<String, Error> {
    // alloc, use, free, alloc again — stack discipline returns the same
    // address; observable: the data written through the second alloc.
    let m = kernel(|b, out| {
        let tid = b.call("gpu.tid.x", &[], Type::I32);
        let is0 = b.cmp(CmpPred::Eq, tid, Operand::i32(0));
        b.if_(is0, |b| {
            let p1 = b.call("__kmpc_alloc_shared", &[Operand::i64(64)], Type::I64);
            b.store(Type::I32, AddrSpace::Shared, p1, Operand::i32(11));
            b.call_void("__kmpc_free_shared", &[Operand::i64(64)]);
            let p2 = b.call("__kmpc_alloc_shared", &[Operand::i64(64)], Type::I64);
            let same = b.cmp(CmpPred::Eq, p1, p2);
            let same32 = b.cast(CastOp::ZExt, same, Type::I32);
            b.store(Type::I32, AddrSpace::Global, out, same32);
            b.call_void("__kmpc_free_shared", &[Operand::i64(64)]);
        });
    });
    run_words(c, m, 1, 1, 32)
}

fn t_generic_parallel(c: &Coordinator) -> Result<String, Error> {
    let mut m = Module::new("conf_generic");
    let mut r = FunctionBuilder::new("region", &[Type::I32, Type::I64], None);
    let tid = r.param(0);
    let arg = r.param(1);
    let a = r.index(arg, tid, 4);
    let cur = r.load(Type::I32, AddrSpace::Global, a);
    let v = r.add(cur, Operand::i32(1));
    r.store(Type::I32, AddrSpace::Global, a, v);
    r.ret();
    m.add_func(r.build());
    let mut b = FunctionBuilder::new("k", &[Type::I64], None).kernel();
    let out = b.param(0);
    irlib::emit_generic_prologue(&mut b);
    let fnid = b.call("gpu.funcref.region", &[], Type::I64);
    b.call_void("__kmpc_parallel_51", &[fnid.into(), out.into(), Operand::i32(8)]);
    b.call_void("__kmpc_parallel_51", &[fnid.into(), out.into(), Operand::i32(4)]);
    irlib::emit_generic_epilogue(&mut b);
    b.ret();
    m.add_func(b.build());
    // width-dependent worker mapping is canonicalized by only using 8
    // participants; block = 2 warps on either arch (128 threads).
    run_words(c, m, 8, 1, 128)
}

fn t_icv(c: &Coordinator) -> Result<String, Error> {
    let m = kernel(|b, out| {
        let tid = b.call("omp_get_thread_num", &[], Type::I32);
        let is0 = b.cmp(CmpPred::Eq, tid, Operand::i32(0));
        b.if_(is0, |b| {
            let n = b.call("omp_get_num_threads", &[], Type::I32);
            b.store(Type::I32, AddrSpace::Global, out, n);
        });
    });
    run_words(c, m, 1, 1, 40)
}

fn t_wrong_arch(c: &Coordinator) -> Result<String, Error> {
    // Calling the *other* vendor's intrinsic must trap — the observable
    // teeth behind variant dispatch. Canonical output is arch-neutral.
    let wrong = match c.device.desc.arch {
        Arch::Nvptx64 => "amdgcn.atomic.inc32",
        Arch::Amdgcn => "nvvm.atom.inc.u32",
    };
    let mut m = Module::new("conf_wrong");
    let mut b = FunctionBuilder::new("k", &[Type::I64], None).kernel();
    let out = b.param(0);
    irlib::emit_spmd_prologue(&mut b);
    b.call(wrong, &[out.into(), Operand::i32(1)], Type::I32);
    irlib::emit_spmd_epilogue(&mut b);
    b.ret();
    m.add_func(b.build());
    match run_words(c, m, 1, 1, 32) {
        Ok(_) => Ok("wrong-arch intrinsic executed (BUG)".into()),
        Err(e) => {
            let msg = e.to_string();
            Ok(format!("trapped={}", msg.contains("intrinsic")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_unique_names() {
        let mut names: Vec<_> = all_tests().iter().map(|t| t.name).collect();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n);
        assert!(n >= 15, "suite should be substantial, got {n}");
    }
}
