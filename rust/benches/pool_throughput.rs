//! BENCH: device-pool offload throughput — 1-device vs 4-device mixed
//! pool, cold vs warm kernel-image cache, in launches/sec.
//!
//! The repeated-kernel workload replays the `scale`/`saxpy` conformance
//! kernels; cold batches pay `prepare` (link + optimize + load) per
//! device, warm batches should be queue-pop + map + launch only, so the
//! warm/cold gap is the cache win and the 4-vs-1 gap is the scaling win.

use omprt::devrt::RuntimeKind;
use omprt::ir::passes::OptLevel;
use omprt::sched::workload::{saxpy_request, scale_request};
use omprt::sched::{bytes_to_f32, Affinity, DevicePool, PoolConfig};
use omprt::sim::Arch;
use std::time::Instant;

const BATCH: usize = 256;
const ELEMS: usize = 256;

/// Submit one mixed batch and wait for every result; returns launches/sec.
fn run_batch(pool: &DevicePool, batch: usize) -> f64 {
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(batch);
    for i in 0..batch {
        let (req, want) = if i % 2 == 0 {
            let data: Vec<f32> = (0..ELEMS).map(|k| (k + i) as f32).collect();
            scale_request(&data, Affinity::any(), OptLevel::O2)
        } else {
            let x: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
            let y: Vec<f32> = (0..ELEMS).map(|k| (k + i) as f32).collect();
            saxpy_request(0.5, &x, &y, Affinity::any(), OptLevel::O2)
        };
        handles.push((pool.submit(req).unwrap(), want));
    }
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        let got = bytes_to_f32(resp.buffers[0].as_ref().unwrap());
        assert_eq!(got, want, "pool result must match the host reference");
    }
    batch as f64 / t0.elapsed().as_secs_f64()
}

fn bench_pool(name: &str, config: &PoolConfig) -> (f64, f64) {
    let pool = DevicePool::new(config).unwrap();
    let cold = run_batch(&pool, BATCH);
    let warm = run_batch(&pool, BATCH);
    let m = pool.metrics();
    let cache = m.cache();
    println!(
        "{name:<22} cold {cold:>8.1} launches/s | warm {warm:>8.1} launches/s | \
         speedup {:.2}x | cache {:.1}% hit ({} hits / {} misses)",
        warm / cold,
        cache.hit_rate() * 100.0,
        cache.hits,
        cache.misses
    );
    (cold, warm)
}

fn main() {
    println!(
        "\n=== pool throughput: {BATCH} requests/batch, {ELEMS} f32 elems, mixed scale/saxpy ===\n"
    );
    let (cold1, warm1) = bench_pool(
        "1 device (portable)",
        &PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64),
    );
    let (cold4, warm4) = bench_pool("4 devices (mixed)", &PoolConfig::mixed4());
    println!(
        "\n4-device vs 1-device: cold {:.2}x, warm {:.2}x",
        cold4 / cold1,
        warm4 / warm1
    );

    // The repeated-kernel workload must be cache-friendly: two modules
    // over the pool's devices.
    let pool = DevicePool::new(&PoolConfig::mixed4()).unwrap();
    run_batch(&pool, BATCH);
    let cache = pool.metrics().cache();
    assert!(
        cache.hit_rate() > 0.9,
        "repeated-kernel batch must exceed 90% hit rate, got {:.1}%",
        cache.hit_rate() * 100.0
    );
    println!(
        "repeated-kernel batch hit rate: {:.1}% (> 90% required)",
        cache.hit_rate() * 100.0
    );
}
