//! `trace` — structured event tracing and metrics export for the device
//! pool.
//!
//! The scheduler's five policy layers (batching, sharding, DRR
//! fairness, EDF/SLO, health) interact in ways aggregate counters can't
//! show. This subsystem records *what the scheduler actually did*, per
//! request, on a timeline:
//!
//! * every accepted request gets a [`RequestId`] at submit; workers, the
//!   queue, the stitchers, the health monitor and the retry path emit
//!   typed [`Event`]s ([`EventKind`] is the taxonomy) carrying that id —
//!   shard jobs carry the parent's id, retries reuse the id with an
//!   incremented attempt;
//! * events land in fixed-capacity [`ring::TraceRing`]s — one per device
//!   worker plus a few shared stripes — as seqno + monotonic-timestamp
//!   POD records, with no allocation or locking on the hot path; the
//!   [`Tracer`] gates emission at runtime (a disabled tracer costs one
//!   branch) and drains rings on demand into a [`TraceSnapshot`];
//! * [`chrome_trace_json`] renders a snapshot as Perfetto-loadable
//!   Chrome trace-event JSON (devices as tracks, request spans as flow
//!   events; `--trace-out` on `omprt pool` / `omprt bench --pool`);
//!   [`capture_text`] renders the compact replay capture (client, image
//!   key, shard spec, deadline, submit time) that [`parse_capture`]
//!   reads back as typed [`CaptureRecord`]s for the `sched` replay
//!   engine (`omprt replay`); [`validate_chrome_trace`] and
//!   [`validate_capture`] are the structural checkers CI runs over
//!   generated traces and captures (`omprt trace-validate` sniffs the
//!   format);
//! * [`Histogram`] (log-bucketed, signed, mergeable) replaces the old
//!   capped-sample latency rings for per-client sojourn / queue-wait /
//!   slack quantiles, and [`MetricsRegistry`] is the named-metrics
//!   export behind `--metrics-json`.

pub mod capture;
pub mod event;
pub mod export;
pub mod metrics;
pub mod ring;
pub mod sink;

pub use capture::{escape_client, parse_capture, unescape_client, Capture, CaptureRecord};
pub use event::{Event, EventKind, RequestId, TraceRecord};
pub use export::{
    capture_text, chrome_trace_json, parse_json, validate_capture, validate_chrome_trace,
    ExportMeta, JsonValue,
};
pub use metrics::{json_escape, Histogram, MetricsRegistry};
pub use sink::{Tracer, TraceSnapshot, TraceStats, DEFAULT_TRACE_CAPACITY};
