//! `omprt lint` — the repo's own static invariant checker.
//!
//! Seven PRs of this tree were authored in containers without a Rust
//! toolchain, each repeating the same manual review ritual: delimiter
//! balance, format-argument arity, event-kind cross-checks, atomics
//! ordering audits. This module codifies that ritual as a real,
//! dependency-free static analysis pass over the repo's own sources: a
//! lexer that makes strings/comments opaque ([`lexer`]), then rule
//! passes over the token stream ([`rules`]).
//!
//! The rule catalog (each rule reads an allowlist manifest from
//! `lint/rules/` at the repo root — shared verbatim with the
//! toolchain-less Python driver `python/lint/run.py`):
//!
//! | rule | invariant | manifest |
//! |------|-----------|----------|
//! | `wallclock` | `Instant::now`/`SystemTime::now`/`thread::sleep` only inside the `util::clock` facade | `wallclock.allow` |
//! | `atomics` | every `Ordering::Relaxed` is an allowlisted counter; latch/CAS/seqlock fields may never relax | `atomics.allow` |
//! | `locks` | the declared sched lock order (`inflight_reg` < `queue` < `clients`) via guard-scope tracking | `locks.order` |
//! | `fmtargs` | format-string placeholder arity matches the supplied arguments | `fmtargs.allow` |
//! | `delims` | `()`/`[]`/`{}` balance per file, outside strings and comments | `delims.allow` |
//! | `consistency` | `EventKind` variants ↔ `from_u8` ↔ `name()` ↔ roundtrip test; `[pool]` config keys ↔ CLI flags ↔ README flag table | `consistency.list` |
//!
//! Policy: fix the violation. An allowlist entry needs a one-line `#`
//! justification in the manifest and review scrutiny; the self-check
//! test (`rust/tests/lint_clean.rs`) keeps the shipped tree at zero
//! findings, so any new finding fails `cargo test` and CI.

pub mod lexer;
pub mod rules;

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// One lint violation: file, 1-based line, rule id, message.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-relative path with `/` separators.
    pub file: String,
    /// 1-based source line (0 for file-level findings).
    pub line: u32,
    /// Rule id (`wallclock`, `atomics`, …).
    pub rule: &'static str,
    /// Human-readable description of the violation.
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Parsed rule manifests from `lint/rules/`.
#[derive(Debug, Default)]
pub struct Manifests {
    /// Files allowed to touch the wall clock (`wallclock.allow`).
    pub wallclock_allow: Vec<String>,
    /// `file:context` pairs allowed to use `Ordering::Relaxed`, plus the
    /// deny-listed field names that may *never* relax (`atomics.allow`).
    pub atomics_allow: Vec<String>,
    /// Field names that must never be accessed with `Ordering::Relaxed`.
    pub atomics_deny: Vec<String>,
    /// Declared lock ranks `file:lockname -> rank` (`locks.order`).
    pub lock_ranks: BTreeMap<String, u32>,
    /// `file:fn:lock` lock-order exceptions (`locks.order` `allow` lines).
    pub lock_allow: Vec<String>,
    /// `file:line` format-arity exceptions (`fmtargs.allow`).
    pub fmtargs_allow: Vec<String>,
    /// Files exempt from delimiter balance (`delims.allow`).
    pub delims_allow: Vec<String>,
    /// `[pool]` key ↔ CLI flag ↔ README token rows (`consistency.list`).
    pub consistency: Vec<rules::consistency::Row>,
}

/// Read one manifest: `#` starts a comment, blank lines ignored, entries
/// whitespace-trimmed. Missing manifests are an error — the rule set and
/// its manifests ship together.
pub fn load_manifest(path: &Path) -> crate::Result<Vec<String>> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        crate::util::Error::Config(format!("lint manifest `{}`: {e}", path.display()))
    })?;
    Ok(text
        .lines()
        .filter_map(|l| {
            let entry = l.split('#').next().unwrap_or("").trim();
            (!entry.is_empty()).then(|| entry.to_string())
        })
        .collect())
}

impl Manifests {
    /// Load every manifest under `<root>/lint/rules/`.
    pub fn load(root: &Path) -> crate::Result<Manifests> {
        let dir = root.join("lint").join("rules");
        let mut m = Manifests {
            wallclock_allow: load_manifest(&dir.join("wallclock.allow"))?,
            fmtargs_allow: load_manifest(&dir.join("fmtargs.allow"))?,
            delims_allow: load_manifest(&dir.join("delims.allow"))?,
            ..Manifests::default()
        };
        for entry in load_manifest(&dir.join("atomics.allow"))? {
            if let Some(rest) = entry.strip_prefix("allow ") {
                m.atomics_allow.push(rest.trim().to_string());
            } else if let Some(rest) = entry.strip_prefix("deny ") {
                m.atomics_deny.push(rest.trim().to_string());
            } else {
                return Err(crate::util::Error::Config(format!(
                    "atomics.allow: entry `{entry}` must start with `allow ` or `deny `"
                )));
            }
        }
        for entry in load_manifest(&dir.join("locks.order"))? {
            if let Some(rest) = entry.strip_prefix("lock ") {
                let mut it = rest.split_whitespace();
                let (name, rank) = (it.next().unwrap_or(""), it.next().unwrap_or(""));
                let rank: u32 = rank.parse().map_err(|_| {
                    crate::util::Error::Config(format!(
                        "locks.order: `lock {rest}` wants `lock file:name RANK`"
                    ))
                })?;
                m.lock_ranks.insert(name.to_string(), rank);
            } else if let Some(rest) = entry.strip_prefix("allow ") {
                m.lock_allow.push(rest.trim().to_string());
            } else {
                return Err(crate::util::Error::Config(format!(
                    "locks.order: entry `{entry}` must start with `lock ` or `allow `"
                )));
            }
        }
        for entry in load_manifest(&dir.join("consistency.list"))? {
            m.consistency.push(rules::consistency::Row::parse(&entry)?);
        }
        Ok(m)
    }
}

/// Directories walked for Rust sources, relative to the repo root. The
/// Python driver walks the same list.
pub const LINT_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Every `.rs` file under [`LINT_DIRS`], as sorted repo-relative paths.
pub fn rust_files(root: &Path) -> crate::Result<Vec<String>> {
    let mut files = Vec::new();
    for d in LINT_DIRS {
        let top = root.join(d);
        if top.is_dir() {
            walk(&top, &mut files).map_err(|e| {
                crate::util::Error::Config(format!("walking `{}`: {e}", top.display()))
            })?;
        }
    }
    let mut rels: Vec<String> = files
        .iter()
        .filter_map(|p| p.strip_prefix(root).ok())
        .map(|p| p.to_string_lossy().replace('\\', "/"))
        .collect();
    rels.sort();
    Ok(rels)
}

/// The lint report: every finding plus the run's coverage stats.
#[derive(Debug)]
pub struct Report {
    /// All findings, sorted by (file, line).
    pub findings: Vec<Finding>,
    /// How many files were scanned.
    pub files_scanned: usize,
}

impl Report {
    /// True when the tree is clean.
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Render the report in the `file:line: [rule] msg` format both
    /// drivers share, with a trailing summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&f.to_string());
            out.push('\n');
        }
        out.push_str(&format!(
            "omprt-lint: {} files, {} finding(s)\n",
            self.files_scanned,
            self.findings.len()
        ));
        out
    }
}

/// Run every rule over the tree rooted at `root` (the directory holding
/// `Cargo.toml` and `lint/rules/`).
pub fn run(root: &Path) -> crate::Result<Report> {
    let manifests = Manifests::load(root)?;
    let files = rust_files(root)?;
    let mut sources: BTreeMap<String, String> = BTreeMap::new();
    for rel in &files {
        let text = std::fs::read_to_string(root.join(rel)).map_err(|e| {
            crate::util::Error::Config(format!("reading `{rel}`: {e}"))
        })?;
        sources.insert(rel.clone(), text);
    }
    let mut findings = Vec::new();
    for (rel, src) in &sources {
        let toks = lexer::lex(src);
        findings.extend(rules::wallclock::check(rel, &toks, &manifests));
        findings.extend(rules::atomics::check(rel, &toks, &manifests));
        findings.extend(rules::locks::check(rel, &toks, &manifests));
        findings.extend(rules::fmtargs::check(rel, &toks, &manifests));
        findings.extend(rules::delims::check(rel, &toks, &manifests));
    }
    findings.extend(rules::consistency::check(root, &sources, &manifests));
    findings.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    Ok(Report { findings, files_scanned: files.len() })
}

/// Locate the repo root by walking up from `start` until a directory
/// holding both `Cargo.toml` and `lint/rules/` is found.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut d = start.to_path_buf();
    loop {
        if d.join("Cargo.toml").is_file() && d.join("lint").join("rules").is_dir() {
            return Some(d);
        }
        if !d.pop() {
            return None;
        }
    }
}
