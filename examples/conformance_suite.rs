//! §4.2 functional testing: run the SOLLVE-analog conformance suite under
//! every (runtime, arch) configuration and check the reports agree.

use omprt::conformance::run_matrix;

fn main() {
    let (rows, identical) = run_matrix();
    for (kind, arch, outcomes) in &rows {
        let pass = outcomes.iter().filter(|o| o.result.is_ok()).count();
        println!("{kind:>8} / {arch:<8}: {pass}/{} passed", outcomes.len());
        for o in outcomes {
            if let Err(e) = &o.result {
                println!("  FAIL {}: {e}", o.name);
            }
        }
    }
    println!("\nreports identical across all configurations: {identical}");
    assert!(identical);
}
