//! Trace replay: re-issue a `# omprt-capture v1` capture against a live
//! [`DevicePool`], turning recorded traffic into the unit of
//! reproducibility for every bench and chaos claim.
//!
//! [`replay_capture`] walks the parsed [`Capture`] in submit order and,
//! per line, reconstructs the request the capture describes:
//!
//! * **pacing** — the driver sleeps on the *pool's* clock until the
//!   recorded `t_us` offset (scaled by [`ReplayOptions::speed`]) from
//!   replay start. On a wall-clock pool that reproduces the original
//!   arrival process in real time; under a
//!   [`crate::util::VirtualClock`] the same offsets elapse on the
//!   virtual timeline, so the replay completes as fast as execution
//!   allows while every submit still lands on its exact recorded
//!   instant — which is what makes two virtual replays of the same
//!   capture produce **byte-identical** re-captures;
//! * **client identity** — the escaped `client` token is already
//!   decoded by the parser; the request re-joins the same fairness
//!   lane / SLO bucket it was recorded under;
//! * **deadline budget** — `deadline_us` (recorded rounded-up, never 0)
//!   becomes the request's [`OffloadRequest::deadline`];
//! * **image key** — the recorded content hash is mapped through a
//!   deterministic factor to a distinct `scale`-by-factor kernel image
//!   ([`super::workload::scale_module_by`]), so equal recorded keys hit
//!   the image cache together and distinct keys stay distinct (the
//!   re-captured keys are the *new* images' hashes — replay preserves
//!   the key partition, not the key values);
//! * **shard fan-out / arch** — a `shards=N` line gets a
//!   [`crate::sched::pool::ShardSpec`] payload sized at exactly
//!   `N × shard_min_trips` elements, which pins the planner's
//!   element-bound to the recorded fan-out, plus an
//!   [`Affinity::on_arch`] hint when the pool has devices of the
//!   recorded architecture (a capture from a differently-shaped pool
//!   replays unpinned instead of being rejected).
//!
//! A capture whose ring overwrote records (`# dropped=N`) is **refused**
//! unless [`ReplayOptions::allow_lossy`] is set: its request lines
//! under-represent the original workload, and silently replaying them
//! would launder a truncated recording into a reproducibility claim.
//!
//! [`synth_capture`] is the workload-shaped emitter behind the
//! `traces/` fixtures: three canonical scenarios (steady multi-tenant,
//! diurnal burst, adversarial hot-key) generated deterministically from
//! fixed seeds, so the committed files are regenerable byte-for-byte.

use std::collections::BTreeSet;
use std::time::Duration;

use super::pool::{bytes_to_f32, Affinity, DevicePool, OffloadRequest};
use super::workload::{scale_request_by, sharded_scale_request_by};
use crate::ir::passes::OptLevel;
use crate::sim::Arch;
use crate::trace::{Capture, CaptureRecord};
use crate::util::{Error, SplitMix64};

/// Replay knobs. Defaults replay at recorded speed, refuse lossy
/// captures, and issue 96-element payloads for unsharded lines.
#[derive(Debug, Clone)]
pub struct ReplayOptions {
    /// Time-scale: recorded inter-arrival gaps are divided by this
    /// (2.0 = twice as fast, 0.5 = half speed). Must be finite and
    /// positive.
    pub speed: f64,
    /// Replay a capture carrying a `# dropped=N` trailer anyway.
    pub allow_lossy: bool,
    /// Payload elements for unsharded lines (sharded lines are sized
    /// from the recorded fan-out instead).
    pub elems: usize,
}

impl Default for ReplayOptions {
    fn default() -> Self {
        ReplayOptions::new()
    }
}

impl ReplayOptions {
    /// Defaults: recorded speed, lossless-only, 96-element payloads.
    pub fn new() -> ReplayOptions {
        ReplayOptions { speed: 1.0, allow_lossy: false, elems: 96 }
    }

    /// Set the time-scale factor.
    pub fn with_speed(mut self, speed: f64) -> ReplayOptions {
        self.speed = speed;
        self
    }

    /// Allow replaying lossy captures.
    pub fn with_allow_lossy(mut self, allow: bool) -> ReplayOptions {
        self.allow_lossy = allow;
        self
    }

    /// Set the unsharded payload size in elements.
    pub fn with_elems(mut self, elems: usize) -> ReplayOptions {
        self.elems = elems.max(1);
        self
    }
}

/// What a replay did: submit-side and completion-side tallies. Queue
/// and deadline behaviour beyond this (miss counts, slack quantiles)
/// comes from the pool's own metrics as usual.
#[derive(Debug, Clone, Default)]
pub struct ReplayReport {
    /// Capture lines re-issued (accepted by the pool).
    pub submitted: u64,
    /// Capture lines the pool refused at submit (e.g. an affinity that
    /// matches nothing on this pool shape).
    pub rejected: u64,
    /// Requests that completed with a response.
    pub completed: u64,
    /// Requests that failed after acceptance.
    pub failed: u64,
    /// Completed requests whose payload bytes did not match the
    /// host-computed expectation (always 0 on a healthy pool).
    pub mismatched: u64,
    /// Distinct client names re-issued.
    pub clients: usize,
    /// Elapsed time on the pool's clock from first pace to last
    /// completion (virtual time under a `VirtualClock`).
    pub elapsed: Duration,
}

/// Re-issue `cap` against `pool`, pacing by recorded `t_us`. Blocks
/// until every re-issued request completed or failed; see the module
/// docs for the per-line reconstruction rules.
///
/// The calling thread is the pacing driver: on a virtual-clock pool it
/// must be registered with the clock (a
/// [`crate::util::clock::Participant`]) like any other driver thread,
/// so its pacing sleeps advance virtual time deterministically.
pub fn replay_capture(
    pool: &DevicePool,
    cap: &Capture,
    opts: &ReplayOptions,
) -> Result<ReplayReport, Error> {
    if cap.dropped > 0 && !opts.allow_lossy {
        return Err(Error::Config(format!(
            "capture is lossy ({} trace records were overwritten at record time), so its \
             request lines under-represent the original workload; pass --allow-lossy to \
             replay it anyway",
            cap.dropped
        )));
    }
    if !(opts.speed.is_finite() && opts.speed > 0.0) {
        return Err(Error::Config(format!(
            "replay speed must be finite and > 0, got {}",
            opts.speed
        )));
    }
    let clock = pool.clock();
    let min_trips = pool.shard_min_trips();
    let pool_archs: Vec<Arch> = pool.specs().iter().map(|s| s.arch).collect();
    let distinct_clients =
        cap.records.iter().map(|r| r.client.as_str()).collect::<BTreeSet<_>>().len();
    let mut report = ReplayReport { clients: distinct_clients, ..ReplayReport::default() };
    let start = clock.now();
    let mut pending = Vec::with_capacity(cap.records.len());
    for r in &cap.records {
        let target = start + scaled_offset(r, opts.speed);
        let now = clock.now();
        if target > now {
            clock.sleep(target.saturating_duration_since(now));
        }
        let (req, want) = synth_request(r, opts, min_trips, &pool_archs);
        match pool.submit(req) {
            Ok(handle) => {
                report.submitted += 1;
                pending.push((handle, want));
            }
            Err(_) => report.rejected += 1,
        }
    }
    for (handle, want) in pending {
        match handle.wait() {
            Ok(resp) => {
                report.completed += 1;
                let ok = resp.buffers.first().and_then(|b| b.as_ref()).is_some_and(|bytes| {
                    bytes_to_f32(bytes) == want
                });
                if !ok {
                    report.mismatched += 1;
                }
            }
            Err(_) => report.failed += 1,
        }
    }
    report.elapsed = clock.now().saturating_duration_since(start);
    Ok(report)
}

/// The recorded submit offset scaled by `speed`, exact to the
/// nanosecond at `speed == 1.0` (the 3-decimal `t_us` rendering is a
/// lossless ns encoding).
fn scaled_offset(r: &CaptureRecord, speed: f64) -> Duration {
    Duration::from_nanos((r.t_us * 1e3 / speed).round() as u64)
}

/// Map a recorded image key to a kernel scale factor: equal keys →
/// equal factors (same image, cache hits preserved); distinct keys →
/// distinct factors for any workload with fewer than 8192 distinct
/// images (beyond that, keys may merge — replay preserves the key
/// *partition*, not the values).
fn key_factor(key: u64) -> f32 {
    1.0 + (key % 8192) as f32 / 16384.0
}

/// Deterministic payload for a capture line: a function of the key and
/// length only, so identical replays issue identical bytes.
fn synth_payload(key: u64, elems: usize) -> Vec<f32> {
    let mut rng = SplitMix64::new(key ^ 0x0FF1_0AD5_EED5);
    (0..elems).map(|_| rng.below(64) as f32).collect()
}

/// Build the request a capture line describes (see the module docs),
/// plus the host-computed expected output for verification.
fn synth_request(
    r: &CaptureRecord,
    opts: &ReplayOptions,
    min_trips: usize,
    pool_archs: &[Arch],
) -> (OffloadRequest, Vec<f32>) {
    let factor = key_factor(r.key);
    let affinity = r
        .arch
        .as_deref()
        .and_then(Arch::parse)
        .filter(|a| pool_archs.contains(a))
        .map_or_else(Affinity::any, Affinity::on_arch);
    let (mut req, want) = if r.shards > 1 {
        // Size the payload so the planner's element bound equals the
        // recorded fan-out: `elems / shard_min_trips == shards`. On a
        // pool with at least `shards` eligible devices this reproduces
        // the recorded split exactly (the element bound dominates the
        // racy idle-device sample); on a smaller pool it degrades to
        // the widest split that pool supports.
        let elems = (r.shards as usize).saturating_mul(min_trips);
        let data = synth_payload(r.key, elems);
        sharded_scale_request_by(factor, &data, affinity, OptLevel::O2)
    } else {
        let data = synth_payload(r.key, opts.elems);
        scale_request_by(factor, &data, affinity, OptLevel::O2)
    };
    req.client = r.client.clone();
    req.deadline = r.deadline();
    (req, want)
}

/// The canonical fixture scenarios under `traces/`, by name.
pub const SCENARIOS: [&str; 3] = ["steady-multi-tenant", "diurnal-burst", "adversarial-hot-key"];

/// Synthesize one of the canonical workload-shaped captures. Fully
/// deterministic (fixed [`SplitMix64`] seeds, integer-µs timestamps),
/// so the committed `traces/` fixtures can be regenerated
/// byte-for-byte; `rust/tests/trace_replay.rs` asserts they match.
pub fn synth_capture(scenario: &str) -> Result<Capture, Error> {
    match scenario {
        "steady-multi-tenant" => Ok(steady_multi_tenant()),
        "diurnal-burst" => Ok(diurnal_burst()),
        "adversarial-hot-key" => Ok(adversarial_hot_key()),
        other => Err(Error::Config(format!(
            "unknown trace scenario `{other}` (expected one of {SCENARIOS:?})"
        ))),
    }
}

fn record(
    req: u64,
    t_us: u64,
    client: &str,
    key: u64,
    deadline_us: Option<u64>,
    sharded: bool,
) -> CaptureRecord {
    CaptureRecord {
        req,
        t_us: t_us as f64,
        client: client.to_string(),
        key,
        deadline_us,
        shards: if sharded { 2 } else { 1 },
        arch: sharded.then(|| "nvptx64".to_string()),
    }
}

/// Four tenants at a steady aggregate rate: two latency-sensitive (with
/// deadline budgets), one best-effort, one bulk; a small per-tenant
/// image working set plus a shared pool of sharded images.
fn steady_multi_tenant() -> Capture {
    const CLIENTS: [&str; 4] = ["tenant-a", "tenant-b", "tenant-c", "bulk"];
    let mut rng = SplitMix64::new(0x51EA_D711);
    let mut t_us: u64 = 0;
    let mut records = Vec::new();
    for i in 0..160u64 {
        t_us += 200 + rng.below(1_200);
        let c = (i % 4) as usize;
        let sharded = i % 20 == 7;
        let key = if sharded {
            0x5000 + rng.below(4)
        } else {
            0x100 * (c as u64 + 1) + rng.below(8)
        };
        let deadline_us = match c {
            0 => Some(5_000),
            1 => Some(2_500),
            _ => None,
        };
        records.push(record(i + 1, t_us, CLIENTS[c], key, deadline_us, sharded));
    }
    Capture { records, dropped: 0 }
}

/// Bursty diurnal traffic: three cycles of a low-rate background
/// shoulder followed by a tight two-client interactive burst with
/// sub-millisecond budgets.
fn diurnal_burst() -> Capture {
    let mut rng = SplitMix64::new(0xD10C_0FFE);
    let mut t_us: u64 = 0;
    let mut records = Vec::new();
    let mut req = 0u64;
    for _cycle in 0..3 {
        for _ in 0..10 {
            t_us += 4_000 + rng.below(2_000);
            req += 1;
            records.push(record(req, t_us, "background", 0x900 + rng.below(3), None, false));
        }
        for j in 0..40u64 {
            t_us += 80 + rng.below(120);
            req += 1;
            let client = if j % 2 == 0 { "peak-a" } else { "peak-b" };
            let sharded = j % 13 == 5;
            let key = if sharded { 0xb00 + rng.below(2) } else { 0xa00 + rng.below(6) };
            let deadline_us = Some(if j % 2 == 0 { 1_000 } else { 800 });
            records.push(record(req, t_us, client, key, deadline_us, sharded));
        }
    }
    Capture { records, dropped: 0 }
}

/// Adversarial traffic: hostile client names that stress the capture
/// escaping (`tenant a`, `a=b`, a literal `-`, `100%`), 70% of requests
/// hammering one hot image key, and `deadline_us=1` lines — the
/// rounded-up form of a sub-microsecond budget.
fn adversarial_hot_key() -> Capture {
    const HOSTILE: [&str; 4] = ["tenant a", "a=b", "-", "100%"];
    let mut rng = SplitMix64::new(0xAD5E_4B1A);
    let mut t_us: u64 = 0;
    let mut records = Vec::new();
    for i in 0..120u64 {
        t_us += 100 + rng.below(400);
        let hot = rng.below(10) < 7;
        let key = if hot { 0xBEEF } else { 0xC000 + rng.below(32) };
        let sharded = i % 30 == 11;
        let deadline_us = match i % 5 {
            0 => Some(1),
            1 => Some(250),
            _ => None,
        };
        records.push(record(i + 1, t_us, HOSTILE[(i % 4) as usize], key, deadline_us, sharded));
    }
    Capture { records, dropped: 0 }
}

#[cfg(test)]
mod tests {
    use super::super::pool::PoolConfig;
    use super::*;
    use crate::devrt::RuntimeKind;
    use crate::trace::parse_capture;

    #[test]
    fn synthesized_scenarios_render_to_valid_captures() {
        for name in SCENARIOS {
            let cap = synth_capture(name).unwrap();
            assert!(!cap.records.is_empty(), "{name}");
            assert_eq!(cap.dropped, 0, "{name}");
            let text = cap.to_text();
            let back = parse_capture(&text).unwrap_or_else(|e| panic!("{name}: {e}\n{text}"));
            assert_eq!(back, cap, "{name} must round-trip through its rendering");
            // Identical inputs regenerate identical bytes.
            assert_eq!(synth_capture(name).unwrap().to_text(), text, "{name}");
        }
        assert!(synth_capture("nope").is_err());
    }

    #[test]
    fn adversarial_scenario_exercises_the_hard_cases() {
        let cap = synth_capture("adversarial-hot-key").unwrap();
        let clients: BTreeSet<&str> = cap.records.iter().map(|r| r.client.as_str()).collect();
        for hostile in ["tenant a", "a=b", "-", "100%"] {
            assert!(clients.contains(hostile), "missing {hostile:?}");
        }
        assert!(cap.records.iter().any(|r| r.deadline_us == Some(1)));
        assert!(cap.records.iter().any(|r| r.shards == 2));
        let hot = cap.records.iter().filter(|r| r.key == 0xBEEF).count();
        assert!(hot * 2 > cap.records.len(), "hot key must dominate: {hot}");
    }

    #[test]
    fn replay_refuses_lossy_captures_without_opt_in() {
        let pool =
            DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, crate::sim::Arch::Nvptx64))
                .unwrap();
        let cap = Capture { records: vec![], dropped: 5 };
        let err = replay_capture(&pool, &cap, &ReplayOptions::new()).unwrap_err();
        assert!(err.to_string().contains("lossy"), "{err}");
        // Opting in replays the (empty) capture fine.
        let report =
            replay_capture(&pool, &cap, &ReplayOptions::new().with_allow_lossy(true)).unwrap();
        assert_eq!(report.submitted, 0);
    }

    #[test]
    fn replay_rejects_nonsense_speeds() {
        let pool =
            DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, crate::sim::Arch::Nvptx64))
                .unwrap();
        for speed in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let err = replay_capture(
                &pool,
                &Capture::default(),
                &ReplayOptions::new().with_speed(speed),
            )
            .unwrap_err();
            assert!(err.to_string().contains("speed"), "{speed}: {err}");
        }
    }

    #[test]
    fn replay_reissues_and_verifies_a_small_capture() {
        let pool =
            DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, crate::sim::Arch::Nvptx64))
                .unwrap();
        let text = "# omprt-capture v1\n\
                    req=1 t_us=0.000 client=tenant%20a key=0xbeef deadline_us=- shards=1 arch=-\n\
                    req=2 t_us=50.000 client=%2D key=0xbeef deadline_us=250000 shards=1 arch=-\n\
                    req=3 t_us=100.000 client=- key=0x7 deadline_us=- shards=1 arch=-\n";
        let cap = parse_capture(text).unwrap();
        let report = replay_capture(&pool, &cap, &ReplayOptions::new()).unwrap();
        assert_eq!(report.submitted, 3, "{report:?}");
        assert_eq!(report.completed, 3, "{report:?}");
        assert_eq!(report.rejected, 0, "{report:?}");
        assert_eq!(report.failed, 0, "{report:?}");
        assert_eq!(report.mismatched, 0, "{report:?}");
        assert_eq!(report.clients, 3, "tenant a, -, and the default client");
        pool.quiesce();
        let m = pool.metrics();
        assert_eq!(m.submitted, 3);
        assert_eq!(m.completed, 3);
    }
}
