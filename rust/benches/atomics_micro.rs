//! BENCH (E5): atomic-operation microbenchmark — OpenMP-5.1-constructed
//! atomics (portable) vs intrinsic atomics (legacy) must have identical
//! throughput (the performance half of the paper's Listing 3/4 claim).

use omprt::coordinator::Coordinator;
use omprt::devrt::{irlib, RuntimeKind};
use omprt::hostrt::{DataEnv, MapType};
use omprt::ir::passes::OptLevel;
use omprt::ir::{FunctionBuilder, Module, Operand, Type};
use omprt::sim::{Arch, LaunchConfig};
use omprt::util::clock;
use omprt::util::stats::rel_diff;

fn kernel(op: &'static str, iters: i32) -> Module {
    let mut m = Module::new("atomics_micro");
    let mut b = FunctionBuilder::new("k", &[Type::I64], None).kernel();
    let out = b.param(0);
    irlib::emit_spmd_prologue(&mut b);
    b.for_range(Operand::i32(0), Operand::i32(iters), Operand::i32(1), |b, _| {
        match op {
            "cas" => {
                b.call("__kmpc_atomic_cas", &[out.into(), Operand::i32(0), Operand::i32(1)], Type::I32);
            }
            "inc" => {
                b.call("__kmpc_atomic_inc", &[out.into(), Operand::i32(1000)], Type::I32);
            }
            _ => {
                b.call(op, &[out.into(), Operand::i32(1)], Type::I32);
            }
        }
    });
    irlib::emit_spmd_epilogue(&mut b);
    b.ret();
    m.add_func(b.build());
    m
}

fn time_op(kind: RuntimeKind, op: &'static str, iters: i32) -> f64 {
    let c = Coordinator::new(kind, Arch::Nvptx64);
    let image = c.prepare(kernel(op, iters), OptLevel::O2).unwrap();
    let mut env = DataEnv::new(&c.device);
    let out = vec![0u32; 1];
    let d = env.map(&out, MapType::Tofrom).unwrap();
    // warmup
    c.device.offload(&image, "k", &[d], LaunchConfig::new(2, 64)).unwrap();
    let mut best = f64::MAX;
    for _ in 0..5 {
        let t0 = clock::now();
        c.device.offload(&image, "k", &[d], LaunchConfig::new(2, 64)).unwrap();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let iters = 2000;
    println!("\n=== atomics microbenchmark (2 teams x 64 thr x {iters} iters, best of 5) ===\n");
    println!("op                  | Original (ms) | New (ms) | rel.diff");
    println!("--------------------+---------------+----------+---------");
    for op in ["__kmpc_atomic_add", "__kmpc_atomic_max", "__kmpc_atomic_exchange", "cas", "inc"] {
        let a = time_op(RuntimeKind::Legacy, op, iters);
        let b = time_op(RuntimeKind::Portable, op, iters);
        println!(
            "{:<20}| {:>13.3} | {:>8.3} | {:>6.2}%",
            op,
            a * 1e3,
            b * 1e3,
            rel_diff(a, b) * 100.0
        );
    }
}
