//! The coordinator: ties the host runtime, PJRT service and profiler into
//! the launch pipeline benchmarks drive, and owns the `nvprof`-analog
//! per-region profiler that regenerates the paper's Table 1 columns.
//! [`PoolCoordinator`] is the multi-device variant over
//! [`crate::sched::DevicePool`].

pub mod pool;
pub mod profiler;

pub use pool::{PoolCoordinator, PoolRegionReport};
pub use profiler::{Profiler, RegionReport};

use crate::devrt::RuntimeKind;
use crate::hostrt::{KernelImage, OffloadDevice};
use crate::ir::passes::OptLevel;
use crate::ir::Module;
use crate::runtime::{install_payloads, ArtifactManifest, PjrtService};
use crate::sim::{Arch, LaunchConfig, LaunchStats};
use crate::util::Error;
use std::sync::Arc;

/// One device + its profiler + (optionally) the PJRT payload service.
///
/// The device is behind an `Arc` so a coordinator can also wrap a device
/// *leased from a pool* ([`Coordinator::on_device`], used by
/// `omprt bench --pool`); artifacts can only be attached while the
/// coordinator is the device's sole owner.
pub struct Coordinator {
    /// The offload device (runtime build + memory).
    pub device: Arc<OffloadDevice>,
    /// Per-region profiler.
    pub profiler: Profiler,
    /// PJRT service handle, if artifacts were attached.
    pub pjrt: Option<PjrtService>,
}

impl Coordinator {
    /// A coordinator without PJRT payloads.
    pub fn new(kind: RuntimeKind, arch: Arch) -> Self {
        Coordinator {
            device: Arc::new(OffloadDevice::new(kind, arch)),
            profiler: Profiler::new(),
            pjrt: None,
        }
    }

    /// A coordinator over an existing (possibly shared) device — e.g. a
    /// pool device lease.
    pub fn on_device(device: Arc<OffloadDevice>) -> Self {
        Coordinator { device, profiler: Profiler::new(), pjrt: None }
    }

    /// Exclusive device access, required to install bindings.
    fn device_mut(&mut self) -> Result<&mut OffloadDevice, Error> {
        Arc::get_mut(&mut self.device).ok_or_else(|| {
            Error::HostRt(
                "cannot attach artifacts: the device is shared (e.g. leased from a pool)".into(),
            )
        })
    }

    /// Attach AOT artifacts: starts (or reuses) a PJRT service, compiles
    /// every artifact, installs `payload.*` bindings.
    pub fn attach_artifacts(&mut self, manifest: &ArtifactManifest) -> Result<(), Error> {
        let svc = match &self.pjrt {
            Some(s) => s.clone(),
            None => {
                let s = PjrtService::start()?;
                self.pjrt = Some(s.clone());
                s
            }
        };
        install_payloads(self.device_mut()?.bindings_mut(), &svc, manifest)?;
        Ok(())
    }

    /// Attach artifacts re-using an existing PJRT service (PJRT startup
    /// is expensive; benchmark harnesses share one service across the
    /// legacy/portable coordinators they compare).
    pub fn attach_artifacts_with(
        &mut self,
        svc: &PjrtService,
        manifest: &ArtifactManifest,
    ) -> Result<(), Error> {
        self.pjrt = Some(svc.clone());
        install_payloads(self.device_mut()?.bindings_mut(), svc, manifest)?;
        Ok(())
    }

    /// Device-code compilation step (Fig. 1).
    pub fn prepare(&self, app: Module, opt: OptLevel) -> Result<KernelImage, Error> {
        self.device.prepare(app, opt)
    }

    /// Launch a target region under the profiler. `region` is the name
    /// `nvprof` would show (e.g. `evaluate_vgh`).
    pub fn run_region(
        &self,
        image: &KernelImage,
        kernel: &str,
        region: &str,
        args: &[u64],
        cfg: LaunchConfig,
    ) -> Result<LaunchStats, Error> {
        let (r, elapsed) =
            crate::util::stats::timed(|| self.device.offload(image, kernel, args, cfg));
        self.profiler.record(region, elapsed);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::FunctionBuilder;

    fn empty_kernel() -> Module {
        let mut m = Module::new("t");
        let mut b = FunctionBuilder::new("k", &[], None).kernel();
        b.ret();
        m.add_func(b.build());
        m
    }

    #[test]
    fn run_region_records_profile() {
        let c = Coordinator::new(RuntimeKind::Portable, Arch::Nvptx64);
        let image = c.prepare(empty_kernel(), OptLevel::O2).unwrap();
        for _ in 0..3 {
            c.run_region(&image, "k", "r1", &[], LaunchConfig::new(1, 32)).unwrap();
        }
        let report = c.profiler.report();
        let r1 = report.iter().find(|r| r.name == "r1").unwrap();
        assert_eq!(r1.summary.count(), 3);
        assert!(r1.summary.avg_us() > 0.0);
    }
}
