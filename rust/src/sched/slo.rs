//! SLO primitives for deadline-aware scheduling: per-image service-time
//! prediction and signed slack accounting.
//!
//! The pool's fairness layer (weighted deficit round robin, see
//! [`crate::sched`]) equalizes *shares*; it says nothing about *when* a
//! given client's request runs. This module supplies the two small
//! mechanisms the deadline layer is built from:
//!
//! * [`ServiceEwma`] — an EWMA of observed per-job service time keyed by
//!   kernel-image content hash, used to predict how long a queued request
//!   will take once a device picks it up. A request whose remaining time
//!   to deadline is within this prediction is *in its panic window*: it
//!   must start now (or sooner) to have any chance of meeting the
//!   deadline, so the queue lets it preempt the DRR rotation.
//! * [`SlackSummary`] — an online summary of **signed** slack (deadline
//!   minus completion time): positive when the deadline was met with room
//!   to spare, negative when it was missed. The unsigned
//!   [`crate::util::Summary`] cannot represent misses, hence this type.
//!
//! Deadlines themselves are stamped at submit time in
//! [`crate::sched::DevicePool::submit`] from either the request's own
//! [`crate::sched::OffloadRequest::deadline`] budget or the client's
//! configured `[pool] client_slos` target, and the preemption policy
//! (EDF within the fairness envelope, bounded by a panic-streak cap)
//! lives in the queue — see the *SLO lifecycle* section of
//! [`crate::sched`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// EWMA smoothing factor for service-time observations: one observation
/// moves the estimate 20% of the way, matching the batching controller's
/// responsiveness (a few launches of a new image are enough to predict
/// it usefully).
const ALPHA: f64 = 0.2;

/// Most distinct image keys tracked before the table is reset. One-off
/// images (the eviction soak mints them on purpose) would otherwise grow
/// the map without bound; predictions rebuild within a few launches, so
/// a rare wholesale reset is cheaper than an LRU here.
const SERVICE_KEY_CAP: usize = 1024;

/// Per-image-key EWMA of observed per-job service time, plus a global
/// EWMA fallback for work with no per-key history (first launch of an
/// image, leased tasks).
///
/// Workers record one observation per executed *non-shard* batch (batch
/// wall time divided by batch size); the queue consults
/// [`ServiceEwma::predict`] to decide whether a deadlined request is
/// inside its panic window. Shard launches and leased tasks are
/// deliberately not recorded: a shard covers a fraction of its image's
/// full request under the same key, and a multi-second leased benchmark
/// would poison the global fallback into declaring every unseen key
/// permanently panicked.
/// All updates are heuristic — a lost race just weights a neighboring
/// observation — so the table takes a plain mutex and the global EWMA a
/// relaxed atomic.
pub struct ServiceEwma {
    /// key = kernel-image content hash → EWMA of per-job seconds.
    per_key: Mutex<HashMap<u64, f64>>,
    /// EWMA across all work, stored as `f64::to_bits`. 0.0 = no
    /// observation yet (predict 0: nothing panics before its deadline
    /// has actually arrived, which is the safe cold-start default).
    global_bits: AtomicU64,
}

impl Default for ServiceEwma {
    fn default() -> Self {
        ServiceEwma::new()
    }
}

impl ServiceEwma {
    /// Empty tracker; predictions start at zero (see `global_bits`).
    pub fn new() -> ServiceEwma {
        ServiceEwma {
            per_key: Mutex::new(HashMap::new()),
            global_bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    /// Fold one per-job service observation into the EWMA for `key`
    /// (`None` updates only the global estimate). Non-finite or negative
    /// observations are discarded.
    pub fn record(&self, key: Option<u64>, secs: f64) {
        if !secs.is_finite() || secs < 0.0 {
            return;
        }
        if let Some(k) = key {
            let mut map = self.per_key.lock().unwrap();
            if map.len() >= SERVICE_KEY_CAP && !map.contains_key(&k) {
                map.clear();
            }
            let e = map.entry(k).or_insert(secs);
            *e += ALPHA * (secs - *e);
        }
        let cur = f64::from_bits(self.global_bits.load(Ordering::Relaxed));
        let next = if cur == 0.0 { secs } else { cur + ALPHA * (secs - cur) };
        self.global_bits.store(next.to_bits(), Ordering::Relaxed);
    }

    /// Predicted per-job service time for `key`: the key's EWMA when one
    /// exists, otherwise the global EWMA (0 before any observation).
    /// Clamped to 60 s so a corrupt observation can never make every
    /// deadline look unreachable.
    pub fn predict(&self, key: Option<u64>) -> Duration {
        let secs = key
            .and_then(|k| self.per_key.lock().unwrap().get(&k).copied())
            .unwrap_or_else(|| f64::from_bits(self.global_bits.load(Ordering::Relaxed)));
        Duration::from_secs_f64(secs.clamp(0.0, 60.0))
    }

    /// Predicted service time for a whole executing batch: the per-job
    /// prediction for `key` scaled by the batch size (saturating). This
    /// is the quantity in-flight age is compared against — by the stall
    /// watchdog ([`crate::sched::health::judge`]), the hedging trigger
    /// ([`crate::sched::health::hedge_after`]) and the report's
    /// in-flight age column — so all three judge with the same yardstick.
    /// Zero when the key (and the global fallback) is still cold.
    pub fn predict_batch(&self, key: Option<u64>, jobs: u64) -> Duration {
        self.predict(key).saturating_mul(jobs.clamp(1, u32::MAX as u64) as u32)
    }

    /// Distinct image keys currently tracked (tests/report only).
    pub fn tracked_keys(&self) -> usize {
        self.per_key.lock().unwrap().len()
    }
}

/// Online summary of **signed** slack samples: deadline minus completion
/// time, in microseconds. Positive = met with room, negative = missed by
/// that much. All statistics are finite for any finite inputs (the
/// deadline-miss accounting tests assert this).
///
/// This is the report-level aggregate (count/mean/min/max). Quantiles of
/// the same samples come from the per-client slack
/// [`crate::trace::Histogram`] in
/// [`crate::sched::ClientMetrics::slack_us`], and each judged sample is
/// also emitted as a `DeadlineJudged` trace event
/// ([`crate::trace::EventKind::DeadlineJudged`]) when tracing is on.
#[derive(Debug, Clone, Default)]
pub struct SlackSummary {
    n: u64,
    total_us: f64,
    min_us: f64,
    max_us: f64,
}

impl SlackSummary {
    /// Empty summary.
    pub fn new() -> SlackSummary {
        SlackSummary::default()
    }

    /// Record one slack sample in seconds (may be negative: a miss).
    /// Non-finite samples are discarded so the aggregates stay finite.
    pub fn record_secs(&mut self, secs: f64) {
        self.record_us(secs * 1e6);
    }

    /// Record one slack sample in microseconds (may be negative: a
    /// miss). Non-finite samples are discarded so the aggregates stay
    /// finite. This is the unit the pool's completion path works in —
    /// the same value feeds [`crate::trace::Histogram::record_us`] for
    /// per-client quantiles.
    pub fn record_us(&mut self, us: f64) {
        if !us.is_finite() {
            return;
        }
        if self.n == 0 {
            self.min_us = us;
            self.max_us = us;
        } else {
            self.min_us = self.min_us.min(us);
            self.max_us = self.max_us.max(us);
        }
        self.n += 1;
        self.total_us += us;
    }

    /// Merge another summary into this one.
    pub fn merge(&mut self, other: &SlackSummary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
        self.n += other.n;
        self.total_us += other.total_us;
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean slack in microseconds (0 when empty).
    pub fn avg_us(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.total_us / self.n as f64
        }
    }

    /// Smallest (most negative) slack in microseconds.
    pub fn min_us(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min_us
        }
    }

    /// Largest slack in microseconds.
    pub fn max_us(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max_us
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_ewma_predicts_zero_before_any_observation() {
        let s = ServiceEwma::new();
        assert_eq!(s.predict(Some(42)), Duration::ZERO);
        assert_eq!(s.predict(None), Duration::ZERO);
    }

    #[test]
    fn service_ewma_tracks_per_key_and_global() {
        let s = ServiceEwma::new();
        for _ in 0..32 {
            s.record(Some(1), 0.010);
        }
        for _ in 0..32 {
            s.record(Some(2), 0.001);
        }
        let p1 = s.predict(Some(1)).as_secs_f64();
        let p2 = s.predict(Some(2)).as_secs_f64();
        assert!((p1 - 0.010).abs() < 0.002, "key 1 must converge near 10ms: {p1}");
        assert!((p2 - 0.001).abs() < 0.001, "key 2 must converge near 1ms: {p2}");
        // Unknown keys fall back to the global EWMA, which sits between.
        let g = s.predict(Some(999)).as_secs_f64();
        assert!(g > 0.0 && g < 0.011, "global fallback in range: {g}");
    }

    #[test]
    fn predict_batch_scales_with_jobs_and_saturates() {
        let s = ServiceEwma::new();
        // Cold: zero regardless of batch size.
        assert_eq!(s.predict_batch(Some(1), 16), Duration::ZERO);
        for _ in 0..32 {
            s.record(Some(1), 0.010);
        }
        let one = s.predict_batch(Some(1), 1).as_secs_f64();
        let four = s.predict_batch(Some(1), 4).as_secs_f64();
        assert!((four / one - 4.0).abs() < 1e-6, "batch prediction scales linearly");
        // A zero-job batch is judged as one job, never as "free".
        assert_eq!(s.predict_batch(Some(1), 0), s.predict_batch(Some(1), 1));
        // Absurd batch sizes saturate instead of overflowing.
        let huge = s.predict_batch(Some(1), u64::MAX);
        assert!(huge >= s.predict_batch(Some(1), 1));
    }

    #[test]
    fn service_ewma_discards_garbage_and_caps_keys() {
        let s = ServiceEwma::new();
        s.record(Some(1), f64::NAN);
        s.record(Some(1), -5.0);
        assert_eq!(s.predict(Some(1)), Duration::ZERO);
        // A corrupt huge observation cannot push predictions past 60s.
        s.record(Some(1), 1e12);
        assert!(s.predict(Some(1)) <= Duration::from_secs(60));
        // One-off keys cannot grow the table without bound.
        for k in 0..3000u64 {
            s.record(Some(k), 0.001);
        }
        assert!(s.tracked_keys() <= SERVICE_KEY_CAP);
    }

    #[test]
    fn slack_summary_handles_signed_samples() {
        let mut s = SlackSummary::new();
        s.record_secs(0.002); // met by 2ms
        s.record_secs(-0.001); // missed by 1ms
        assert_eq!(s.count(), 2);
        assert!((s.avg_us() - 500.0).abs() < 1e-9);
        assert!((s.min_us() - -1000.0).abs() < 1e-9);
        assert!((s.max_us() - 2000.0).abs() < 1e-9);
        // record_us is the same accumulator in µs directly.
        s.record_us(2000.0);
        assert_eq!(s.count(), 3);
        assert!((s.max_us() - 2000.0).abs() < 1e-9);
        // Aggregates stay finite; garbage is discarded.
        s.record_secs(f64::INFINITY);
        s.record_secs(f64::NAN);
        assert_eq!(s.count(), 3);
        assert!(s.avg_us().is_finite() && s.min_us().is_finite() && s.max_us().is_finite());
    }

    #[test]
    fn slack_summary_merges() {
        let mut a = SlackSummary::new();
        a.record_secs(0.001);
        let mut b = SlackSummary::new();
        b.record_secs(-0.003);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.min_us() - -3000.0).abs() < 1e-9);
        assert!((a.max_us() - 1000.0).abs() < 1e-9);
    }
}
