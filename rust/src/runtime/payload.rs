//! `payload.*` bindings: the bridge from device IR call sites to the
//! PJRT-compiled artifacts.
//!
//! Calling convention (fixed, documented in DESIGN.md §6): a kernel calls
//!
//! ```text
//! call @payload.<name>(out_addr, in0_addr, in1_addr, …)
//! ```
//!
//! with warp-uniform global-memory addresses. The binding gathers the f32
//! input tensors from device global memory, executes the artifact on the
//! PJRT service thread, and scatters the f32 result to `out_addr`. This
//! plays the role of the per-target PTX/GCN code the vendor compilers
//! produced in the paper's pipeline — one compiled artifact per target
//! variant, selected at load time.

use super::artifact::ArtifactManifest;
use super::pjrt::PjrtService;
use crate::sim::Bindings;
use crate::util::Error;
use std::sync::Arc;

/// Read an f32 tensor from device global memory.
fn gather_f32(
    gmem: &crate::sim::memory::MemRegion,
    addr: u64,
    elems: usize,
) -> Result<Vec<f32>, Error> {
    let mut bytes = vec![0u8; elems * 4];
    gmem.read_bytes(addr, &mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
}

/// Write an f32 tensor to device global memory.
fn scatter_f32(
    gmem: &crate::sim::memory::MemRegion,
    addr: u64,
    data: &[f32],
) -> Result<(), Error> {
    let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
    gmem.write_bytes(addr, &bytes)
}

/// Compile every artifact in `manifest` and install one binding per
/// payload.
pub fn install_payloads(
    bindings: &mut Bindings,
    svc: &PjrtService,
    manifest: &ArtifactManifest,
) -> Result<(), Error> {
    for spec in &manifest.specs {
        svc.load(spec)?;
        let spec = spec.clone();
        let svc = svc.clone();
        bindings.bind(
            format!("payload.{}", spec.name),
            Arc::new(move |env, args, mask| {
                let first = mask.trailing_zeros() as usize;
                let expected = 1 + spec.inputs.len();
                if args.len() != expected {
                    return Err(Error::Pjrt(format!(
                        "payload.{}: expected {expected} args (out + {} inputs), got {}",
                        spec.name,
                        spec.inputs.len(),
                        args.len()
                    )));
                }
                let out_addr = args[0][first];
                let mut inputs = Vec::with_capacity(spec.inputs.len());
                for (i, _) in spec.inputs.iter().enumerate() {
                    let addr = args[1 + i][first];
                    inputs.push(gather_f32(env.gmem, addr, spec.input_elems(i))?);
                }
                let out = svc.execute(&spec.name, inputs)?;
                scatter_f32(env.gmem, out_addr, &out)?;
                Ok(None)
            }),
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_scatter_roundtrip() {
        let gmem = crate::sim::GlobalMemory::new(1 << 16);
        let addr = gmem.alloc(16, 8).unwrap();
        scatter_f32(&gmem, addr, &[1.0, -2.5, 3.25, 0.0]).unwrap();
        let v = gather_f32(&gmem, addr, 4).unwrap();
        assert_eq!(v, vec![1.0, -2.5, 3.25, 0.0]);
    }
}
