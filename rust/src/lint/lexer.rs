//! A small Rust lexer for the lint rules: just enough token structure to
//! make string literals, char literals, lifetimes and comments *opaque*,
//! which is exactly what the manual review ritual kept getting wrong.
//!
//! The token stream drops comments entirely, collapses every string form
//! (plain, raw `r#"…"#`, byte, C) into a single [`TokKind::Str`] token
//! carrying the body between the quotes, keeps `::` as one token for
//! path matching, and distinguishes lifetimes from char literals. It is
//! *not* a parser: rules pattern-match short token windows.
//!
//! `python/lint/run.py` carries a line-for-line port of this lexer; the
//! fixture tests below are the shared contract — any behavior change
//! here must land in the Python driver too.

/// What a token is. `Str` carries the body between the quotes (escapes
/// unprocessed); `Punct` is a single character except for `::`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Any string literal form; `text` is the body between the quotes.
    Str,
    /// Char literal; `text` is the body between the quotes.
    Char,
    /// Numeric literal.
    Num,
    /// Lifetime (`'a`, `'static`).
    Life,
    /// Punctuation: one character, or the two-character `::`.
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Token class.
    pub kind: TokKind,
    /// Source text (for `Str`/`Char`: the body between the quotes).
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: u32,
}

impl Tok {
    /// True for an identifier with exactly this text.
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// True for punctuation with exactly this text.
    pub fn is_punct(&self, s: &str) -> bool {
        self.kind == TokKind::Punct && self.text == s
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Raw/byte/C string prefix at `b[i]`: (`prefix_len`, `hashes`, `raw`).
/// Matches `r`, `br`, `b`, `c`, `cr` followed (for raw forms) by hashes,
/// then a double quote.
fn string_prefix(b: &[u8], i: usize) -> Option<(usize, usize, bool)> {
    let mut j = i;
    let mut raw = false;
    match b.get(j) {
        Some(b'b') | Some(b'c') => {
            j += 1;
            if b.get(j) == Some(&b'r') {
                j += 1;
                raw = true;
            }
        }
        Some(b'r') => {
            j += 1;
            raw = true;
        }
        _ => return None,
    }
    let mut hashes = 0;
    if raw {
        while b.get(j) == Some(&b'#') {
            j += 1;
            hashes += 1;
        }
    }
    if b.get(j) == Some(&b'"') {
        Some((j - i, hashes, raw))
    } else {
        None
    }
}

/// Tokenize Rust source. Never fails: unterminated constructs consume to
/// end-of-input (the delimiter-balance rule reports the damage).
pub fn lex(src: &str) -> Vec<Tok> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // Comments: line, and nested block.
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            continue;
        }
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // Raw / byte / C strings (checked before plain idents: `r#"`).
        if (c == b'r' || c == b'b' || c == b'c') && string_prefix(b, i).is_some() {
            let (plen, hashes, raw) = string_prefix(b, i).unwrap();
            let start_line = line;
            i += plen + 1; // past the opening quote
            let body_start = i;
            let body_end;
            if raw {
                // Scan for `"` followed by `hashes` hash marks.
                loop {
                    if i >= n {
                        body_end = n;
                        break;
                    }
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    if b[i] == b'"' && b[i + 1..].iter().take(hashes).filter(|&&h| h == b'#').count() == hashes {
                        body_end = i;
                        i += 1 + hashes;
                        break;
                    }
                    i += 1;
                }
            } else {
                while i < n && b[i] != b'"' {
                    if b[i] == b'\\' {
                        i += 1;
                    }
                    if i < n && b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
                body_end = i;
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Str,
                text: String::from_utf8_lossy(&b[body_start..body_end.min(n)]).into_owned(),
                line: start_line,
            });
            continue;
        }
        // Plain strings.
        if c == b'"' {
            let start_line = line;
            i += 1;
            let body_start = i;
            while i < n && b[i] != b'"' {
                if b[i] == b'\\' {
                    i += 1;
                }
                if i < n && b[i] == b'\n' {
                    line += 1;
                }
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Str,
                text: String::from_utf8_lossy(&b[body_start..i.min(n)]).into_owned(),
                line: start_line,
            });
            i += 1;
            continue;
        }
        // Char literal vs lifetime: `'a'` is a char, `'a` a lifetime.
        if c == b'\'' {
            let next = b.get(i + 1).copied().unwrap_or(0) as char;
            if is_ident_start(next) && b.get(i + 2) != Some(&b'\'') {
                let start = i;
                i += 1;
                while i < n && is_ident_cont(b[i] as char) {
                    i += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Life,
                    text: String::from_utf8_lossy(&b[start..i]).into_owned(),
                    line,
                });
                continue;
            }
            let mut j = i + 1;
            while j < n && b[j] != b'\'' {
                if b[j] == b'\\' {
                    j += 1;
                }
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Char,
                text: String::from_utf8_lossy(&b[i + 1..j.min(n)]).into_owned(),
                line,
            });
            i = (j + 1).min(n);
            continue;
        }
        // Numbers; `1..4` must not swallow the dots.
        if c.is_ascii_digit() {
            let start = i;
            while i < n && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            if b.get(i) == Some(&b'.') && b.get(i + 1).is_some_and(|d| d.is_ascii_digit()) {
                i += 1;
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                if (b.get(i.wrapping_sub(1)) == Some(&b'e') || b.get(i.wrapping_sub(1)) == Some(&b'E'))
                    && (b.get(i) == Some(&b'+') || b.get(i) == Some(&b'-'))
                {
                    i += 1;
                    while i < n && b[i].is_ascii_digit() {
                        i += 1;
                    }
                }
            }
            toks.push(Tok {
                kind: TokKind::Num,
                text: String::from_utf8_lossy(&b[start..i]).into_owned(),
                line,
            });
            continue;
        }
        // Identifiers / keywords.
        if is_ident_start(c as char) {
            let start = i;
            while i < n && is_ident_cont(b[i] as char) {
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: String::from_utf8_lossy(&b[start..i]).into_owned(),
                line,
            });
            continue;
        }
        // Punctuation; `::` kept whole for path matching.
        if c == b':' && b.get(i + 1) == Some(&b':') {
            toks.push(Tok { kind: TokKind::Punct, text: "::".into(), line });
            i += 2;
            continue;
        }
        toks.push(Tok {
            kind: TokKind::Punct,
            text: (c as char).to_string(),
            line,
        });
        i += 1;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_vanish_including_nested_blocks() {
        let toks = kinds("a // Instant::now()\n/* x /* nested */ y */ b");
        assert_eq!(
            toks,
            vec![(TokKind::Ident, "a".into()), (TokKind::Ident, "b".into())]
        );
    }

    #[test]
    fn strings_are_opaque_single_tokens() {
        let toks = kinds("f(\"Instant::now() }} {\", x)");
        assert_eq!(toks[2].0, TokKind::Str);
        assert_eq!(toks[2].1, "Instant::now() }} {");
        // The brace inside the string must not unbalance anything.
        assert_eq!(toks.iter().filter(|t| t.0 == TokKind::Punct && t.1 == "{").count(), 0);
    }

    #[test]
    fn raw_strings_with_hashes_and_quotes() {
        let src = "let s = r#\"quote \" and { brace\"#; done";
        let toks = kinds(src);
        let s = toks.iter().find(|t| t.0 == TokKind::Str).unwrap();
        assert_eq!(s.1, "quote \" and { brace");
        assert!(toks.iter().any(|t| t.0 == TokKind::Ident && t.1 == "done"));
    }

    #[test]
    fn escaped_quote_does_not_end_string() {
        let toks = kinds(r#"x("a\"b")"#);
        let s = toks.iter().find(|t| t.0 == TokKind::Str).unwrap();
        assert_eq!(s.1, r#"a\"b"#);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let toks = kinds("fn f<'a>(x: &'a str) { let c = 'a'; let nl = '\\n'; }");
        let lifes: Vec<_> = toks.iter().filter(|t| t.0 == TokKind::Life).collect();
        assert_eq!(lifes.len(), 2);
        let chars: Vec<_> = toks.iter().filter(|t| t.0 == TokKind::Char).collect();
        assert_eq!(chars.len(), 2);
        assert_eq!(chars[0].1, "a");
        assert_eq!(chars[1].1, "\\n");
    }

    #[test]
    fn double_colon_is_one_token_and_ranges_stay_numbers() {
        let toks = lex("a::b 1..4 2.5 0x1f");
        assert!(toks.iter().any(|t| t.is_punct("::")));
        let nums: Vec<_> = toks.iter().filter(|t| t.kind == TokKind::Num).map(|t| t.text.clone()).collect();
        assert_eq!(nums, vec!["1", "4", "2.5", "0x1f"]);
    }

    #[test]
    fn lines_are_tracked_across_strings_and_comments() {
        let src = "a\n\"two\nlines\"\n/* c\nc */ b";
        let toks = lex(src);
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2); // string starts on line 2
        assert_eq!(toks[2].line, 5); // `b` after the two-line comment
    }

    #[test]
    fn unterminated_string_consumes_to_eof_without_panicking() {
        let toks = lex("x \"never closed");
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[1].kind, TokKind::Str);
    }
}
