//! Typed trace events and their packed record form.
//!
//! Every event is a fixed-size POD so the hot path never allocates: a
//! [`TraceRecord`] is eight `u64` words (global sequence number,
//! monotonic timestamp, packed kind + device, request id and three
//! kind-specific payload words). The payload meaning per [`EventKind`]:
//!
//! | kind | `a` | `b` | `c` |
//! |------|-----|-----|-----|
//! | `Submit` | client id | image key | deadline budget (ns, 0 = none) |
//! | `Enqueue` | queue depth after push | 1 = shard job | pinned device + 1 (0 = unpinned) |
//! | `BackpressureWait` | wait (ns) | — | — |
//! | `PopNormal` | jobs popped | — | 1 = pinned claim |
//! | `PopPanic` | jobs popped | — | — |
//! | `BatchFormed` | batch size | image key | — |
//! | `ShardPlanned` | fan-out | arch code | — |
//! | `LaunchStart` | jobs in batch | image key | — |
//! | `LaunchEnd` | jobs in batch | 1 = ok, 0 = faulted | batch wall (ns) |
//! | `Stitch` | shards stitched | 1 = ok | — |
//! | `Retry` | attempt (1-based) | — | — |
//! | `Quarantine` | — | — | — |
//! | `Probe` | 1 = passed | — | — |
//! | `Readmit` | — | — | — |
//! | `DeadlineJudged` | 1 = missed | slack (µs, two's-complement `i64`) | client id |
//! | `Done` | 1 = ok | sojourn (ns) | client id |
//! | `HedgeLaunched` | original device | in-flight age (ns) | predicted service (ns) |
//! | `HedgeWon` | — | — | — |
//! | `HedgeWasted` | 0 = lost race, 1 = dup faulted, 2 = drained | — | — |
//!
//! `Retry`, `Quarantine`, `Probe`, `Readmit`, `LaunchStart`/`LaunchEnd`
//! carry the device in the record's `device` field; queue-side events
//! leave it `None`. Client ids index the [`crate::trace::Tracer`]'s
//! interner table (surfaced by [`crate::trace::TraceSnapshot::clients`]);
//! arch codes index [`crate::trace::ExportMeta::arch_labels`].

/// Identifier assigned to every accepted request at submit time. `0`
/// means "no request" (device-lifecycle events such as `Quarantine`).
/// Shard jobs carry their *parent* request's id; a retried job keeps its
/// id and bumps the `Retry` attempt counter instead.
pub type RequestId = u64;

/// The event taxonomy: everything the scheduler does to a request, plus
/// the device-health lifecycle. Discriminants are stable (they are the
/// packed wire form inside the ring) — append, never renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum EventKind {
    /// A request was accepted by `submit`/`try_submit`/`run_on`.
    Submit = 1,
    /// One job entered the submission queue.
    Enqueue = 2,
    /// A submitter blocked on the bounded queue (`queue_cap`).
    BackpressureWait = 3,
    /// A worker claimed work through the normal DRR rotation.
    PopNormal = 4,
    /// A worker claimed work by EDF panic-window preemption.
    PopPanic = 5,
    /// A lead job coalesced followers into a multi-job batch.
    BatchFormed = 6,
    /// A shardable request was split at submit time.
    ShardPlanned = 7,
    /// A device began executing a batch.
    LaunchStart = 8,
    /// A device finished executing a batch.
    LaunchEnd = 9,
    /// A stitcher recombined shard responses into the client reply.
    Stitch = 10,
    /// A faulted job was requeued for a different device.
    Retry = 11,
    /// The health layer took a device out of service.
    Quarantine = 12,
    /// A quarantined device was probed.
    Probe = 13,
    /// A probe passed and the device was readmitted.
    Readmit = 14,
    /// A deadlined request was judged (exactly once) at completion.
    DeadlineJudged = 15,
    /// Terminal event: the request's reply was resolved (ok or error).
    Done = 16,
    /// The monitor speculatively duplicated an at-risk in-flight job.
    /// `device` is the hedge *target*; `a` is the original device.
    HedgeLaunched = 17,
    /// A hedge duplicate completed first and owns the reply.
    HedgeWon = 18,
    /// A hedge duplicate was suppressed (`a` says why).
    HedgeWasted = 19,
}

impl EventKind {
    /// Decode a packed discriminant; `None` for garbage (a torn ring
    /// slot), which the drain discards.
    pub fn from_u8(v: u8) -> Option<EventKind> {
        Some(match v {
            1 => EventKind::Submit,
            2 => EventKind::Enqueue,
            3 => EventKind::BackpressureWait,
            4 => EventKind::PopNormal,
            5 => EventKind::PopPanic,
            6 => EventKind::BatchFormed,
            7 => EventKind::ShardPlanned,
            8 => EventKind::LaunchStart,
            9 => EventKind::LaunchEnd,
            10 => EventKind::Stitch,
            11 => EventKind::Retry,
            12 => EventKind::Quarantine,
            13 => EventKind::Probe,
            14 => EventKind::Readmit,
            15 => EventKind::DeadlineJudged,
            16 => EventKind::Done,
            17 => EventKind::HedgeLaunched,
            18 => EventKind::HedgeWon,
            19 => EventKind::HedgeWasted,
            _ => return None,
        })
    }

    /// Stable display name (used by the exporters).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Submit => "Submit",
            EventKind::Enqueue => "Enqueue",
            EventKind::BackpressureWait => "BackpressureWait",
            EventKind::PopNormal => "PopNormal",
            EventKind::PopPanic => "PopPanic",
            EventKind::BatchFormed => "BatchFormed",
            EventKind::ShardPlanned => "ShardPlanned",
            EventKind::LaunchStart => "LaunchStart",
            EventKind::LaunchEnd => "LaunchEnd",
            EventKind::Stitch => "Stitch",
            EventKind::Retry => "Retry",
            EventKind::Quarantine => "Quarantine",
            EventKind::Probe => "Probe",
            EventKind::Readmit => "Readmit",
            EventKind::DeadlineJudged => "DeadlineJudged",
            EventKind::Done => "Done",
            EventKind::HedgeLaunched => "HedgeLaunched",
            EventKind::HedgeWon => "HedgeWon",
            EventKind::HedgeWasted => "HedgeWasted",
        }
    }
}

/// An event about to be emitted: kind plus the optional device, request
/// id and payload words. Built with the chained setters so call sites
/// read as `Event::new(LaunchStart).device(2).req(rid).a(n).b(key)`.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// What happened.
    pub kind: EventKind,
    /// Device involved, when the event is device-scoped.
    pub device: Option<usize>,
    /// Request this event belongs to (`0` = none).
    pub req: RequestId,
    /// First payload word (see the [`EventKind`] table).
    pub a: u64,
    /// Second payload word.
    pub b: u64,
    /// Third payload word.
    pub c: u64,
}

impl Event {
    /// A bare event of `kind` with no device, request or payload.
    pub fn new(kind: EventKind) -> Event {
        Event { kind, device: None, req: 0, a: 0, b: 0, c: 0 }
    }

    /// Attach the device id.
    pub fn device(mut self, d: usize) -> Event {
        self.device = Some(d);
        self
    }

    /// Attach the request id.
    pub fn req(mut self, r: RequestId) -> Event {
        self.req = r;
        self
    }

    /// Set payload word `a`.
    pub fn a(mut self, v: u64) -> Event {
        self.a = v;
        self
    }

    /// Set payload word `b`.
    pub fn b(mut self, v: u64) -> Event {
        self.b = v;
        self
    }

    /// Set payload word `c`.
    pub fn c(mut self, v: u64) -> Event {
        self.c = v;
        self
    }
}

/// One drained trace record: an [`Event`] plus its global sequence
/// number and monotonic timestamp (ns since the tracer's epoch, which is
/// pool construction). Snapshots are sorted by `(t_ns, seq)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    /// Global emission order (allocated from one atomic counter; ties in
    /// `t_ns` are broken by `seq`).
    pub seq: u64,
    /// Monotonic timestamp, ns since the tracer epoch.
    pub t_ns: u64,
    /// What happened.
    pub kind: EventKind,
    /// Device involved, when device-scoped.
    pub device: Option<usize>,
    /// Request id (`0` = none).
    pub req: RequestId,
    /// First payload word (see the [`EventKind`] table).
    pub a: u64,
    /// Second payload word.
    pub b: u64,
    /// Third payload word.
    pub c: u64,
}

impl TraceRecord {
    /// The `DeadlineJudged` slack payload, decoded back to signed µs.
    pub fn slack_us(&self) -> i64 {
        self.b as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_through_u8() {
        for k in 1u8..=19 {
            let kind = EventKind::from_u8(k).expect("contiguous discriminants");
            assert_eq!(kind as u8, k);
            assert!(!kind.name().is_empty());
        }
        assert_eq!(EventKind::from_u8(0), None);
        assert_eq!(EventKind::from_u8(20), None);
        assert_eq!(EventKind::from_u8(255), None);
    }

    #[test]
    fn event_builder_sets_fields() {
        let e = Event::new(EventKind::LaunchStart).device(3).req(7).a(4).b(0xdead).c(9);
        assert_eq!(e.kind, EventKind::LaunchStart);
        assert_eq!(e.device, Some(3));
        assert_eq!(e.req, 7);
        assert_eq!((e.a, e.b, e.c), (4, 0xdead, 9));
    }

    #[test]
    fn slack_payload_roundtrips_signed() {
        let mut r = TraceRecord {
            seq: 0,
            t_ns: 0,
            kind: EventKind::DeadlineJudged,
            device: None,
            req: 1,
            a: 1,
            b: (-1500i64) as u64,
            c: 0,
        };
        assert_eq!(r.slack_us(), -1500);
        r.b = 2500u64;
        assert_eq!(r.slack_us(), 2500);
    }
}
