//! 504.polbm analog: D2Q9 lattice-Boltzmann (BGK collision + streaming).
//!
//! Pure device-IR compute (heavy f32 ALU per site) under static
//! worksharing; one launch per time step, ping-pong between two
//! distribution arrays laid out f[q][y][x].

use super::common::{
    checksum_f32, compare_f32, emit_static_range, BenchResult, Benchmark, Scale,
};
use crate::coordinator::Coordinator;
use crate::devrt::irlib;
use crate::hostrt::{DataEnv, MapType};
use crate::ir::passes::OptLevel;
use crate::ir::{AddrSpace, BinOp, FunctionBuilder, Module, Operand, Type};
use crate::sim::LaunchConfig;
use crate::util::{Error, SplitMix64};
use std::time::Duration;

/// D2Q9 discrete velocities and weights.
const CX: [i32; 9] = [0, 1, 0, -1, 0, 1, -1, -1, 1];
const CY: [i32; 9] = [0, 0, 1, 0, -1, 1, 1, -1, -1];
const W: [f32; 9] = [
    4.0 / 9.0,
    1.0 / 9.0,
    1.0 / 9.0,
    1.0 / 9.0,
    1.0 / 9.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
];
const OMEGA: f32 = 1.2;

/// The benchmark.
pub struct Polbm {
    nx: usize,
    ny: usize,
    iters: usize,
    teams: u32,
}

impl Polbm {
    /// Configure for a scale.
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Small => Polbm { nx: 24, ny: 16, iters: 2, teams: 2 },
            Scale::Paper => Polbm { nx: 64, ny: 48, iters: 6, teams: 6 },
        }
    }

    fn sites(&self) -> usize {
        self.nx * self.ny
    }

    /// Collide-and-stream for one site, emitted as IR.
    fn module(&self) -> Module {
        let (nx, ny) = (self.nx as i32, self.ny as i32);
        let sites = self.sites() as i32;
        let mut m = Module::new("polbm");
        let mut b = FunctionBuilder::new("step", &[Type::I64, Type::I64], None).kernel();
        let (fout, fin) = (b.param(0), b.param(1));
        irlib::emit_spmd_prologue(&mut b);
        // `distribute` sites across teams, then static worksharing within
        // the team.
        let team = b.call("gpu.ctaid.x", &[], Type::I32);
        let nteams = b.call("gpu.nctaid.x", &[], Type::I32);
        let nm1 = b.add(nteams, Operand::i32(-1));
        let spad = b.add(nm1, Operand::i32(sites));
        let per = b.sdiv(spad, nteams);
        let lo = b.mul(team, per);
        let hi0 = b.add(lo, per);
        let hi = b.bin(BinOp::SMin, hi0, Operand::i32(sites));
        let (lb, ub) = emit_static_range(&mut b, lo.into(), hi.into());
        b.for_range(lb, ub, Operand::i32(1), |b, site| {
            let x = b.srem(site, Operand::i32(nx));
            let y = b.sdiv(site, Operand::i32(nx));
            // Load the 9 distributions; accumulate rho, ux, uy.
            let mut fq = vec![];
            let rho = b.copy(Operand::f32(0.0));
            let ux = b.copy(Operand::f32(0.0));
            let uy = b.copy(Operand::f32(0.0));
            for q in 0..9 {
                let off = b.add(site, Operand::i32(q * sites));
                let addr = b.index(fin, off, 4);
                let f = b.load(Type::F32, AddrSpace::Global, addr);
                fq.push(f);
                let nr = b.add(rho, f);
                b.assign(rho, nr);
                if CX[q as usize] != 0 {
                    let term = b.mul(f, Operand::f32(CX[q as usize] as f32));
                    let nu = b.add(ux, term);
                    b.assign(ux, nu);
                }
                if CY[q as usize] != 0 {
                    let term = b.mul(f, Operand::f32(CY[q as usize] as f32));
                    let nu = b.add(uy, term);
                    b.assign(uy, nu);
                }
            }
            let inv_rho = b.un(crate::ir::UnOp::FRcp, rho);
            let uxn = b.mul(ux, inv_rho);
            let uyn = b.mul(uy, inv_rho);
            let ux2 = b.mul(uxn, uxn);
            let uy2 = b.mul(uyn, uyn);
            let usq0 = b.add(ux2, uy2);
            let usq = b.mul(usq0, Operand::f32(1.5));
            // Collide + stream each direction (periodic wrap).
            for q in 0..9usize {
                let cu0 = b.mul(uxn, Operand::f32(CX[q] as f32));
                let cu1 = b.mul(uyn, Operand::f32(CY[q] as f32));
                let cu = b.add(cu0, cu1);
                let cu3 = b.mul(cu, Operand::f32(3.0));
                let cu2 = b.mul(cu3, cu3);
                let cu2h = b.mul(cu2, Operand::f32(0.5));
                // feq = w*rho*(1 + 3cu + 4.5cu² − 1.5u²)
                let t0 = b.add(cu3, Operand::f32(1.0));
                let t1 = b.add(t0, cu2h);
                let t2 = b.sub(t1, usq);
                let wrho = b.mul(rho, Operand::f32(W[q]));
                let feq = b.mul(wrho, t2);
                // f' = f + ω(feq − f)
                let diff = b.sub(feq, fq[q]);
                let relax = b.mul(diff, Operand::f32(OMEGA));
                let fnew = b.add(fq[q], relax);
                // stream to (x+cx, y+cy) with periodic wrap
                let xs = b.add(x, Operand::i32(CX[q] + nx));
                let xd = b.srem(xs, Operand::i32(nx));
                let ys = b.add(y, Operand::i32(CY[q] + ny));
                let yd = b.srem(ys, Operand::i32(ny));
                let row = b.mul(yd, Operand::i32(nx));
                let dsite = b.add(row, xd);
                let doff = b.add(dsite, Operand::i32(q as i32 * sites));
                let daddr = b.index(fout, doff, 4);
                b.store(Type::F32, AddrSpace::Global, daddr, fnew);
            }
        });
        irlib::emit_spmd_epilogue(&mut b);
        b.ret();
        m.add_func(b.build());
        m
    }

    fn host_step(&self, fin: &[f32], fout: &mut [f32]) {
        let (nx, ny) = (self.nx, self.ny);
        let sites = self.sites();
        for site in 0..sites {
            let (x, y) = (site % nx, site / nx);
            let mut rho = 0f32;
            let mut ux = 0f32;
            let mut uy = 0f32;
            let mut fq = [0f32; 9];
            for q in 0..9 {
                let f = fin[q * sites + site];
                fq[q] = f;
                rho += f;
                ux += f * CX[q] as f32;
                uy += f * CY[q] as f32;
            }
            let inv = 1.0 / rho;
            let (uxn, uyn) = (ux * inv, uy * inv);
            let usq = 1.5 * (uxn * uxn + uyn * uyn);
            for q in 0..9 {
                let cu3 = 3.0 * (uxn * CX[q] as f32 + uyn * CY[q] as f32);
                let feq = W[q] * rho * (1.0 + cu3 + 0.5 * cu3 * cu3 - usq);
                let fnew = fq[q] + OMEGA * (feq - fq[q]);
                let xd = (x as i32 + CX[q] + nx as i32) as usize % nx;
                let yd = (y as i32 + CY[q] + ny as i32) as usize % ny;
                fout[q * sites + yd * nx + xd] = fnew;
            }
        }
    }

    fn init(&self) -> Vec<f32> {
        let mut rng = SplitMix64::new(504);
        let sites = self.sites();
        let mut f = vec![0f32; 9 * sites];
        for q in 0..9 {
            for s in 0..sites {
                f[q * sites + s] = W[q] * (1.0 + 0.05 * (rng.f32() - 0.5));
            }
        }
        f
    }
}

impl Benchmark for Polbm {
    fn name(&self) -> &'static str {
        "504.polbm"
    }

    fn run(&self, c: &Coordinator) -> Result<BenchResult, Error> {
        let image = c.prepare(self.module(), OptLevel::O2)?;
        let mut env = DataEnv::new(&c.device);
        let mut a = self.init();
        let mut bb = a.clone();
        let d_a = env.map(&a, MapType::Tofrom)?;
        let d_b = env.map(&bb, MapType::Tofrom)?;
        let mut wall = Duration::ZERO;
        let mut bufs = [d_a, d_b];
        for _ in 0..self.iters {
            let stats = c.run_region(
                &image,
                "step",
                "polbm.step",
                &[bufs[1], bufs[0]],
                LaunchConfig::new(self.teams, 64),
            )?;
            wall += stats.wall;
            bufs.swap(0, 1);
        }
        let result: &mut Vec<f32> = if bufs[0] == d_a { &mut a } else { &mut bb };
        env.update_from(result)?;
        let got = result.clone();

        let mut h_in = self.init();
        let mut h_out = h_in.clone();
        for _ in 0..self.iters {
            self.host_step(&h_in, &mut h_out);
            std::mem::swap(&mut h_in, &mut h_out);
        }
        let verified = match compare_f32(&got, &h_in, 1e-3) {
            None => true,
            Some(msg) => {
                log::error!("polbm verify failed: {msg}");
                false
            }
        };
        Ok(BenchResult { kernel_wall: wall, verified, checksum: checksum_f32(&got) })
    }
}
