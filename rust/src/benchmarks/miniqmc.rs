//! miniQMC proxy-app analog (`miniqmc_sync_move -g "2 2 1"`).
//!
//! The paper's Table 1 profiles the two offloaded target regions of the
//! walker loop:
//!
//! * **evaluate_vgh** — B-spline value/gradient/hessian evaluation: the
//!   team fills the 10 basis-derivative planes from the electron
//!   positions (divergent polynomial evaluation in device IR), then
//!   contracts them with the orbital coefficients through the Pallas
//!   `vgh_tile` payload (MXU-shaped matmul).
//! * **evaluateDetRatios** — Slater-determinant ratios of candidate
//!   moves against a row of the inverse matrix (`detratio_tile`).
//!
//! The walker loop calls `evaluate_vgh` ≈ 3.5× as often as
//! `evaluateDetRatios`, matching the call-count ratio in Table 1.

use super::common::{checksum_f32, compare_f32, BenchResult, Benchmark, Scale};
use crate::coordinator::Coordinator;
use crate::devrt::irlib;
use crate::hostrt::{DataEnv, KernelImage, MapType};
use crate::ir::passes::OptLevel;
use crate::ir::{AddrSpace, CmpPred, FunctionBuilder, Module, Operand, Type};
use crate::sim::LaunchConfig;
use crate::util::{Error, SplitMix64, Summary};

/// Positions per vgh call (matches the AOT payload shapes).
const P: usize = 16;
/// Basis functions.
const B: usize = 64;
/// Orbitals.
const O: usize = 32;
/// Derivative planes (value + 3 grad + 6 hess).
const PLANES: usize = 10;
/// Candidate moves per det-ratio call.
const K: usize = 16;

/// The proxy app.
pub struct MiniQmc {
    /// Walker steps; each step issues 7 vgh calls and 2 det calls
    /// (≈3.5:1, the Table 1 ratio).
    steps: usize,
}

impl MiniQmc {
    /// Configure for a scale.
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Small => MiniQmc { steps: 3 },
            Scale::Paper => MiniQmc { steps: 40 },
        }
    }

    /// Module with both target-region kernels.
    fn module(&self) -> Module {
        let mut m = Module::new("miniqmc");

        // evaluate_vgh(out, basis, coef, pos): fill basis then contract.
        let mut b = FunctionBuilder::new("evaluate_vgh", &[Type::I64; 4], None).kernel();
        let (out, basis, coef, pos) = (b.param(0), b.param(1), b.param(2), b.param(3));
        irlib::emit_spmd_prologue(&mut b);
        let (lb, ub) = super::common::emit_static_range(
            &mut b,
            Operand::i32(0),
            Operand::i32((PLANES * P * B) as i32),
        );
        b.for_range(lb, ub, Operand::i32(1), |b, e| {
            // e = ((plane*P)+p)*B + j
            let j = b.srem(e, Operand::i32(B as i32));
            let row = b.sdiv(e, Operand::i32(B as i32));
            let p = b.srem(row, Operand::i32(P as i32));
            let plane = b.sdiv(row, Operand::i32(P as i32));
            // t = pos[p*3 + j%3]
            let j3 = b.srem(j, Operand::i32(3));
            let p3 = b.mul(p, Operand::i32(3));
            let pidx = b.add(p3, j3);
            let pa = b.index(pos, pidx, 4);
            let t = b.load(Type::F32, AddrSpace::Global, pa);
            // s = 0.25·(j+1), q = 0.125·(plane+1); basis = (t·s + q)²·s⁻¹-ish
            let j1 = b.add(j, Operand::i32(1));
            let jf = b.cast(crate::ir::CastOp::SIToFP, j1, Type::F32);
            let s = b.mul(jf, Operand::f32(0.25));
            let pl1 = b.add(plane, Operand::i32(1));
            let plf = b.cast(crate::ir::CastOp::SIToFP, pl1, Type::F32);
            let q = b.mul(plf, Operand::f32(0.125));
            let ts = b.mul(t, s);
            let tsq = b.add(ts, q);
            let val = b.mul(tsq, tsq);
            let ba = b.index(basis, e, 4);
            b.store(Type::F32, AddrSpace::Global, ba, val);
        });
        b.call_void("__kmpc_barrier", &[]);
        let tid = b.call("gpu.tid.x", &[], Type::I32);
        let is0 = b.cmp(CmpPred::Eq, tid, Operand::i32(0));
        b.if_(is0, |b| {
            b.call_void("payload.vgh_tile", &[out.into(), basis.into(), coef.into()]);
        });
        irlib::emit_spmd_epilogue(&mut b);
        b.ret();
        m.add_func(b.build());

        // evaluateDetRatios(ratios, u, invrow, pos): fill u then dot.
        let mut b = FunctionBuilder::new("evaluateDetRatios", &[Type::I64; 4], None).kernel();
        let (ratios, u, invrow, pos) = (b.param(0), b.param(1), b.param(2), b.param(3));
        irlib::emit_spmd_prologue(&mut b);
        let (lb, ub) = super::common::emit_static_range(
            &mut b,
            Operand::i32(0),
            Operand::i32((K * B) as i32),
        );
        b.for_range(lb, ub, Operand::i32(1), |b, e| {
            let j = b.srem(e, Operand::i32(B as i32));
            let k = b.sdiv(e, Operand::i32(B as i32));
            let k3 = b.srem(k, Operand::i32(3));
            let pa = b.index(pos, k3, 4);
            let t = b.load(Type::F32, AddrSpace::Global, pa);
            let j1 = b.add(j, Operand::i32(1));
            let jf = b.cast(crate::ir::CastOp::SIToFP, j1, Type::F32);
            let tj = b.mul(t, jf);
            let uv = b.mul(tj, Operand::f32(0.0625));
            let ua = b.index(u, e, 4);
            b.store(Type::F32, AddrSpace::Global, ua, uv);
        });
        b.call_void("__kmpc_barrier", &[]);
        let tid = b.call("gpu.tid.x", &[], Type::I32);
        let is0 = b.cmp(CmpPred::Eq, tid, Operand::i32(0));
        b.if_(is0, |b| {
            b.call_void("payload.detratio_tile", &[ratios.into(), u.into(), invrow.into()]);
        });
        irlib::emit_spmd_epilogue(&mut b);
        b.ret();
        m.add_func(b.build());
        m
    }

    fn host_basis(pos: &[f32], basis: &mut [f32]) {
        for plane in 0..PLANES {
            for p in 0..P {
                for j in 0..B {
                    let t = pos[p * 3 + j % 3];
                    let s = (j + 1) as f32 * 0.25;
                    let q = (plane + 1) as f32 * 0.125;
                    let v = t * s + q;
                    basis[(plane * P + p) * B + j] = v * v;
                }
            }
        }
    }

    fn host_u(pos: &[f32], u: &mut [f32]) {
        for k in 0..K {
            for j in 0..B {
                u[k * B + j] = pos[k % 3] * (j + 1) as f32 * 0.0625;
            }
        }
    }
}

/// Result of one miniqmc run, including per-region profiles (Table 1).
pub struct MiniQmcProfile {
    /// evaluate_vgh summary.
    pub vgh: Summary,
    /// evaluateDetRatios summary.
    pub det: Summary,
    /// Overall result.
    pub result: BenchResult,
}

impl MiniQmc {
    /// Full run with per-region profiling (the Table 1 harness calls this
    /// directly; [`Benchmark::run`] wraps it).
    pub fn run_profiled(&self, c: &Coordinator) -> Result<MiniQmcProfile, Error> {
        let image: KernelImage = c.prepare(self.module(), OptLevel::O2)?;
        let mut env = DataEnv::new(&c.device);
        let mut rng = SplitMix64::new(2021);

        let mut pos = vec![0f32; P * 3];
        rng.fill_f32(&mut pos, -1.0, 1.0);
        let mut coef = vec![0f32; B * O];
        rng.fill_f32(&mut coef, -0.5, 0.5);
        let mut invrow = vec![0f32; B];
        rng.fill_f32(&mut invrow, -0.5, 0.5);

        let basis = vec![0f32; PLANES * P * B];
        let mut vgh_out = vec![0f32; PLANES * P * O];
        let u = vec![0f32; K * B];
        let mut ratios = vec![0f32; K];

        let d_pos = env.map(&pos, MapType::To)?;
        let d_coef = env.map(&coef, MapType::To)?;
        let d_invrow = env.map(&invrow, MapType::To)?;
        let d_basis = env.map(&basis, MapType::Alloc)?;
        let d_vgh_out = env.map(&vgh_out, MapType::From)?;
        let d_u = env.map(&u, MapType::Alloc)?;
        let d_ratios = env.map(&ratios, MapType::From)?;

        // Warm both regions once outside the profile (nvprof-style: the
        // paper's numbers exclude context/JIT initialization).
        c.run_region(
            &image,
            "evaluate_vgh",
            "warmup",
            &[d_vgh_out, d_basis, d_coef, d_pos],
            LaunchConfig::new(1, 64),
        )?;
        c.run_region(
            &image,
            "evaluateDetRatios",
            "warmup",
            &[d_ratios, d_u, d_invrow, d_pos],
            LaunchConfig::new(1, 64),
        )?;
        c.profiler.reset();
        let mut wall = std::time::Duration::ZERO;
        for _step in 0..self.steps {
            // Walker drift on the host, then sync-move offloads.
            for v in pos.iter_mut() {
                *v = (*v + 0.01).clamp(-1.0, 1.0);
            }
            let bytes: Vec<u8> = pos.iter().flat_map(|f| f.to_le_bytes()).collect();
            c.device.gmem.write_bytes(d_pos, &bytes)?;
            for _ in 0..7 {
                let s = c.run_region(
                    &image,
                    "evaluate_vgh",
                    "evaluate_vgh",
                    &[d_vgh_out, d_basis, d_coef, d_pos],
                    LaunchConfig::new(1, 64),
                )?;
                wall += s.wall;
            }
            for _ in 0..2 {
                let s = c.run_region(
                    &image,
                    "evaluateDetRatios",
                    "evaluateDetRatios",
                    &[d_ratios, d_u, d_invrow, d_pos],
                    LaunchConfig::new(1, 64),
                )?;
                wall += s.wall;
            }
        }
        env.unmap(&mut vgh_out)?;
        env.unmap(&mut ratios)?;

        // Host reference for the final step's outputs.
        let mut h_basis = vec![0f32; PLANES * P * B];
        Self::host_basis(&pos, &mut h_basis);
        let mut h_vgh = vec![0f32; PLANES * P * O];
        for r in 0..PLANES * P {
            for o in 0..O {
                let mut acc = 0f32;
                for j in 0..B {
                    acc += h_basis[r * B + j] * coef[j * O + o];
                }
                h_vgh[r * O + o] = acc;
            }
        }
        let mut h_u = vec![0f32; K * B];
        Self::host_u(&pos, &mut h_u);
        let mut h_ratios = vec![0f32; K];
        for k in 0..K {
            h_ratios[k] = (0..B).map(|j| h_u[k * B + j] * invrow[j]).sum();
        }
        let verified = compare_f32(&vgh_out, &h_vgh, 1e-3).is_none()
            && compare_f32(&ratios, &h_ratios, 1e-3).is_none();
        if !verified {
            log::error!("miniqmc verify failed");
        }

        let report = c.profiler.report();
        let find = |name: &str| {
            report
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.summary.clone())
                .unwrap_or_default()
        };
        let mut all = vgh_out.clone();
        all.extend_from_slice(&ratios);
        Ok(MiniQmcProfile {
            vgh: find("evaluate_vgh"),
            det: find("evaluateDetRatios"),
            result: BenchResult { kernel_wall: wall, verified, checksum: checksum_f32(&all) },
        })
    }
}

impl Benchmark for MiniQmc {
    fn name(&self) -> &'static str {
        "miniqmc"
    }

    fn needs_artifacts(&self) -> bool {
        true
    }

    fn run(&self, c: &Coordinator) -> Result<BenchResult, Error> {
        Ok(self.run_profiled(c)?.result)
    }
}
