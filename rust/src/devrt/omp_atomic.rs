//! OpenMP 5.1 atomic constructs and their lowering (paper §3.1,
//! Listings 3–4).
//!
//! The portable runtime implements `atomic_add`/`max`/`exchange`/`cas`
//! with `#pragma omp atomic [compare] capture seq_cst` statements. This
//! module models those constructs as data ([`Construct`]) and *lowers*
//! them the way Clang lowers them — to target-independent atomic
//! instructions (`gpu.atom.*`, our `atomicrmw`/`cmpxchg` analog). This is
//! the mechanism behind the paper's §4.1 result: the OpenMP-built library
//! produces the *same instructions* as the intrinsic-built one.
//!
//! It also encodes the two standard-level findings of §3.1:
//! * with OpenMP **5.0** flush semantics, a seq-cst capture atomic is
//!   surrounded by flushes; OpenMP **5.1** removed that requirement
//!   (footnote 3) — [`lower`] takes the spec version and emits the
//!   flushes only for 5.0, which is exactly why the authors needed the
//!   5.1 semantics to match CUDA codegen;
//! * CUDA's `atomicInc` is **not expressible** as an OpenMP 5.1
//!   `atomic compare` ([`Construct::expressible_in`] returns false): the
//!   order operation must be `<`/`>`/`==` and the "else" value must be
//!   `x` itself, while `atomicInc` needs `>=` and a zero reset.

use crate::ir::{FunctionBuilder, Operand, Reg, Type};

/// OpenMP spec version controlling flush semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecVersion {
    /// OpenMP 5.0: seq-cst atomics imply surrounding flushes.
    V50,
    /// OpenMP 5.1: flush requirement removed for write/update/capture.
    V51,
}

/// Right-hand sides allowed in a conditional-update statement
/// `{ v = *x; if (*x OP e) { *x = RHS; } }`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rhs {
    /// Keep `*x` (the implicit "else").
    X,
    /// Store the operand `e`.
    E,
    /// Store the second operand `d` (CAS desired value).
    D,
    /// Store zero (what `atomicInc` wants — not OpenMP-expressible).
    Zero,
    /// Store `*x + 1` (the other half of `atomicInc`).
    XPlusOne,
}

/// Comparison in an `atomic compare` condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CondOp {
    /// `*x < e` (→ max update).
    Lt,
    /// `*x > e` (→ min update).
    Gt,
    /// `*x == e` (→ compare-and-swap).
    Eq,
    /// `*x >= e` — what `atomicInc` needs; **not** allowed by 5.1.
    Ge,
}

/// An OpenMP atomic construct over a `uint32_t*`, as in Listing 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Construct {
    /// `{ v = *x; *x += e; }` — `atomic capture`.
    CaptureAdd,
    /// `{ v = *x; *x = e; }` — `atomic capture` (exchange).
    CaptureExchange,
    /// `{ v = *x; if (*x OP rhs-cond) *x = then; }` — `atomic compare capture`.
    CompareCapture { op: CondOp, then: Rhs },
}

impl Construct {
    /// The four portable atomics of Listing 3.
    pub fn add() -> Self {
        Construct::CaptureAdd
    }
    /// Exchange.
    pub fn exchange() -> Self {
        Construct::CaptureExchange
    }
    /// Max via `if (*x < e) *x = e`.
    pub fn max() -> Self {
        Construct::CompareCapture { op: CondOp::Lt, then: Rhs::E }
    }
    /// CAS via `if (*x == e) *x = d`.
    pub fn cas() -> Self {
        Construct::CompareCapture { op: CondOp::Eq, then: Rhs::D }
    }
    /// CUDA `atomicInc` — representable as data here, but rejected by
    /// [`Self::expressible_in`] for OpenMP 5.1 (paper §3.1).
    pub fn inc() -> Self {
        Construct::CompareCapture { op: CondOp::Ge, then: Rhs::Zero }
    }

    /// Can this construct be written in the given OpenMP version?
    ///
    /// 5.1 `atomic compare` requires the order operation to be `<`, `>`
    /// or `==`, and the conditional's alternative to leave `x` unchanged;
    /// additionally the stored expression must be the compared expression
    /// (for `<`/`>`) or a free expression (for `==`).
    pub fn expressible_in(&self, v: SpecVersion) -> bool {
        match self {
            Construct::CaptureAdd | Construct::CaptureExchange => true,
            Construct::CompareCapture { op, then } => {
                if v == SpecVersion::V50 {
                    // 5.0 has no `compare` clause at all.
                    return false;
                }
                match op {
                    CondOp::Lt | CondOp::Gt => *then == Rhs::E,
                    CondOp::Eq => *then == Rhs::D || *then == Rhs::E,
                    CondOp::Ge => false,
                }
            }
        }
    }

    /// Lower the construct into `b`, returning the captured old value
    /// (`v`). `addr` is the `uint32_t*`; `e`/`d` the operands. `shared`
    /// selects the `.shared` address-space form.
    ///
    /// Lowering mirrors Clang: capture-add → `atomicrmw add`; exchange →
    /// `atomicrmw xchg`; `< e ? e : x` → `atomicrmw umax`; `== e ? d : x`
    /// → `cmpxchg`. Under 5.0 semantics, flushes (`gpu.membar`) wrap the
    /// operation — the codegen difference §3.1 footnote 3 is about.
    pub fn lower(
        &self,
        b: &mut FunctionBuilder,
        spec: SpecVersion,
        addr: Operand,
        e: Operand,
        d: Option<Operand>,
        shared: bool,
    ) -> Reg {
        assert!(
            self.expressible_in(spec) || spec == SpecVersion::V50,
            "construct {self:?} is not expressible in {spec:?}"
        );
        let sfx = if shared { ".shared" } else { "" };
        if spec == SpecVersion::V50 {
            b.call_void("gpu.membar", &[]);
        }
        let old = match self {
            Construct::CaptureAdd => b.call(format!("gpu.atom.add.u32{sfx}"), &[addr, e], Type::I32),
            Construct::CaptureExchange => {
                b.call(format!("gpu.atom.exch.u32{sfx}"), &[addr, e], Type::I32)
            }
            Construct::CompareCapture { op: CondOp::Lt, then: Rhs::E } => {
                b.call(format!("gpu.atom.umax.u32{sfx}"), &[addr, e], Type::I32)
            }
            Construct::CompareCapture { op: CondOp::Eq, then: Rhs::D } => {
                let d = d.expect("cas needs a desired value");
                b.call(format!("gpu.atom.cas.u32{sfx}"), &[addr, e, d], Type::I32)
            }
            other => panic!("no 5.1 lowering for {other:?} (paper §3.1: keep it an intrinsic)"),
        };
        if spec == SpecVersion::V50 {
            b.call_void("gpu.membar", &[]);
        }
        old
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::printer::print_function;

    fn lower_to_text(c: Construct, spec: SpecVersion) -> String {
        let mut b = FunctionBuilder::new("t", &[Type::I64, Type::I32, Type::I32], Some(Type::I32));
        let addr = b.param(0);
        let e = b.param(1);
        let d = b.param(2);
        let v = c.lower(&mut b, spec, addr.into(), e.into(), Some(d.into()), false);
        b.ret_val(v);
        print_function(&b.build())
    }

    #[test]
    fn listing3_constructs_are_51_expressible() {
        for c in [Construct::add(), Construct::exchange(), Construct::max(), Construct::cas()] {
            assert!(c.expressible_in(SpecVersion::V51), "{c:?}");
        }
    }

    #[test]
    fn atomic_inc_is_not_expressible_in_51() {
        // The paper's §3.1 conclusion.
        assert!(!Construct::inc().expressible_in(SpecVersion::V51));
    }

    #[test]
    fn compare_clause_requires_51() {
        assert!(!Construct::max().expressible_in(SpecVersion::V50));
        assert!(Construct::add().expressible_in(SpecVersion::V50));
    }

    #[test]
    fn v51_lowering_is_flush_free_and_single_instruction() {
        let text = lower_to_text(Construct::add(), SpecVersion::V51);
        assert!(text.contains("gpu.atom.add.u32"), "{text}");
        assert!(!text.contains("membar"), "5.1 must not emit flushes: {text}");
    }

    #[test]
    fn v50_lowering_emits_flushes() {
        // Why the authors needed the updated 5.1 flush rules to match the
        // CUDA codegen (footnote 3).
        let text = lower_to_text(Construct::add(), SpecVersion::V50);
        assert_eq!(text.matches("gpu.membar").count(), 2, "{text}");
    }

    #[test]
    fn max_lowers_to_umax_and_cas_to_cmpxchg() {
        let max = lower_to_text(Construct::max(), SpecVersion::V51);
        assert!(max.contains("gpu.atom.umax.u32"), "{max}");
        let cas = lower_to_text(Construct::cas(), SpecVersion::V51);
        assert!(cas.contains("gpu.atom.cas.u32"), "{cas}");
    }

    #[test]
    #[should_panic(expected = "not expressible")]
    fn lowering_inc_panics() {
        let _ = lower_to_text(Construct::inc(), SpecVersion::V51);
    }
}
