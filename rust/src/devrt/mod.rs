//! The OpenMP **device runtime** — the paper's contribution.
//!
//! Two interchangeable builds of the same runtime API:
//!
//! * [`legacy`] — the *original* structure (paper §2.1): one
//!   hand-specialized copy per target, generated from shared source via
//!   macros (the `DEVICE`/`SHARED` trick of Listing 1), compiled "as CUDA"
//!   for `nvptx64` and "as HIP" for `amdgcn`.
//! * [`portable`] — the *new* structure (paper §3): a single common part
//!   (written once), with the small target-dependent surface expressed as
//!   `declare variant` functions resolved by the [`variant`] engine
//!   (including the paper's `match_any` extension), and atomics
//!   constructed from OpenMP 5.1 `atomic [compare] capture seq_cst`
//!   statements ([`omp_atomic`], Listings 3–4).
//!
//! Each build yields a [`api::DeviceRuntime`]: a set of Rust *bindings*
//! for the control-heavy entry points (`__kmpc_target_init`, worksharing,
//! …) plus an **IR library** (the `dev.rtl.bc` analog) that the linker
//! merges into application kernels so the optimizer can specialize it —
//! the co-optimization flow of the paper's Fig. 1.

pub mod api;
pub mod bindings_impl;
pub mod irlib;
pub mod legacy;
pub mod omp_atomic;
pub mod portable;
pub mod state;
pub mod variant;

pub use api::{DeviceRuntime, RuntimeKind};

use crate::sim::Arch;

/// Build a runtime of the given kind for an architecture.
pub fn build(kind: RuntimeKind, arch: Arch) -> DeviceRuntime {
    match kind {
        RuntimeKind::Legacy => legacy::build(arch),
        RuntimeKind::Portable => portable::build(arch),
    }
}
