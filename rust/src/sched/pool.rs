//! The device pool: N offload devices fed by one async submission queue.
//!
//! Clients [`DevicePool::submit`] an [`OffloadRequest`] and get an
//! [`OffloadHandle`] back immediately; the launch happens on one of the
//! pool's worker threads. See the module docs of [`crate::sched`] for the
//! placement, batching, sharding and backpressure policies.

use super::cache::{CacheStats, ImageCache};
use crate::config::Config;
use crate::coordinator::profiler::{Profiler, RegionReport};
use crate::devrt::RuntimeKind;
use crate::hostrt::{KernelImage, MapType, OffloadDevice};
use crate::ir::passes::OptLevel;
use crate::ir::Module;
use crate::sim::{Arch, BatchKernelSpec, LaunchConfig, LaunchStats, MemStats};
use crate::util::Error;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Which devices may serve a request. `None` fields match anything.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Affinity {
    /// Restrict to one architecture.
    pub arch: Option<Arch>,
    /// Restrict to one runtime build.
    pub kind: Option<RuntimeKind>,
}

impl Affinity {
    /// Runs anywhere.
    pub fn any() -> Affinity {
        Affinity::default()
    }

    /// Pin to an architecture.
    pub fn on_arch(arch: Arch) -> Affinity {
        Affinity { arch: Some(arch), kind: None }
    }

    /// Pin to a runtime kind.
    pub fn on_kind(kind: RuntimeKind) -> Affinity {
        Affinity { arch: None, kind: Some(kind) }
    }

    /// Does a device with `(arch, kind)` satisfy this constraint?
    pub fn matches(&self, arch: Arch, kind: RuntimeKind) -> bool {
        self.arch.map_or(true, |a| a == arch) && self.kind.map_or(true, |k| k == kind)
    }
}

/// One host buffer mapped for the duration of a pooled offload.
#[derive(Debug, Clone)]
pub struct MapBuf {
    /// Host bytes (copied to the device for `To`/`Tofrom`).
    pub bytes: Vec<u8>,
    /// Mapping semantics.
    pub map_type: MapType,
}

impl MapBuf {
    /// Map an f32 slice.
    pub fn f32(data: &[f32], map_type: MapType) -> MapBuf {
        MapBuf { bytes: f32_to_bytes(data), map_type }
    }
}

/// f32 slice → little-endian bytes.
pub fn f32_to_bytes(data: &[f32]) -> Vec<u8> {
    data.iter().flat_map(|v| v.to_le_bytes()).collect()
}

/// Little-endian bytes → f32 vector.
pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// A kernel argument: the device address of a mapped buffer, or an
/// immediate scalar.
#[derive(Debug, Clone, Copy)]
pub enum KernelArg {
    /// Address of `buffers[i]` after mapping.
    Buf(usize),
    /// Immediate 64-bit value.
    Imm(u64),
}

/// How to split one large request across several devices.
///
/// Sharding needs to know the request's data decomposition: which buffers
/// are *partitioned* by element range (each shard gets its slice) versus
/// broadcast whole, and which immediate argument carries the element
/// count so each shard can be told its own. Grid-strided kernels — every
/// kernel in this repo — are shardable this way by construction: a shard
/// is just the same kernel over a smaller `n`.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    /// Indices into `buffers` that are partitioned by element range; all
    /// other buffers are passed whole to every shard.
    pub partitioned: Vec<usize>,
    /// Bytes per element of the partitioned buffers.
    pub elem_bytes: usize,
    /// Index into `args` of the `Imm` argument holding the element count.
    pub count_arg: usize,
    /// Total element count of the request.
    pub elems: usize,
}

/// What a client submits to the pool.
pub struct OffloadRequest {
    /// The application module (kernels + globals).
    pub module: Module,
    /// Kernel entry point to launch.
    pub kernel: String,
    /// Profiler region name (aggregated in the pool report).
    pub region: String,
    /// Launch geometry.
    pub cfg: LaunchConfig,
    /// Optimization level for `prepare` (part of the cache key).
    pub opt: OptLevel,
    /// Host buffers to map.
    pub buffers: Vec<MapBuf>,
    /// Kernel arguments in order.
    pub args: Vec<KernelArg>,
    /// Placement constraint.
    pub affinity: Affinity,
    /// Optional decomposition for cross-device sharding. `None` (the
    /// default for all small launches) always runs on one device; with a
    /// spec, the pool may split the request across idle devices of one
    /// architecture when it is large enough to amortize the overhead
    /// (see `[pool] shard_min_trips`).
    pub shard: Option<ShardSpec>,
}

/// What the pool hands back when a request completes.
#[derive(Debug)]
pub struct OffloadResponse {
    /// Pool-local id of the device that ran the launch (first shard's
    /// device for a sharded request).
    pub device_id: usize,
    /// Its architecture.
    pub arch: Arch,
    /// Its runtime build.
    pub kind: RuntimeKind,
    /// Launch counters (summed over shards; `wall` is the max).
    pub stats: LaunchStats,
    /// Whether the kernel image came out of the cache (for shards: all of
    /// them).
    pub cache_hit: bool,
    /// Time the request sat in the queue before a worker picked it up
    /// (max over shards).
    pub queue_wait: Duration,
    /// How many device shards executed this request (1 = unsharded).
    pub shards: usize,
    /// Post-launch contents of each `From`/`Tofrom` buffer (`None` for
    /// `To`/`Alloc` buffers). Sharded partitioned outputs are stitched
    /// back into the full-size buffer.
    pub buffers: Vec<Option<Vec<u8>>>,
}

/// Future side of a submission; resolves when a worker finishes the
/// request (or the pool shuts down first).
pub struct OffloadHandle {
    rx: mpsc::Receiver<Result<OffloadResponse, Error>>,
}

impl OffloadHandle {
    /// Block until the request completes.
    pub fn wait(self) -> Result<OffloadResponse, Error> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::Sched("pool dropped before the request completed".into())),
        }
    }

    /// Non-blocking poll; `None` while the request is still in flight.
    pub fn try_wait(&self) -> Option<Result<OffloadResponse, Error>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(Error::Sched("pool dropped before the request completed".into())))
            }
        }
    }
}

/// Why [`DevicePool::try_submit`] did not accept a request.
pub enum TrySubmitError {
    /// The submission queue is at capacity (`[pool] queue_cap`); the
    /// request is handed back untouched so the caller can retry or shed
    /// load — the non-blocking `WouldBlock` counterpart of the blocking
    /// [`DevicePool::submit`].
    Full(OffloadRequest),
    /// The request is malformed or unroutable (same checks as `submit`).
    Rejected(Error),
}

impl std::fmt::Debug for TrySubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrySubmitError::Full(_) => write!(f, "Full(<request>)"),
            TrySubmitError::Rejected(e) => write!(f, "Rejected({e})"),
        }
    }
}

/// Handle for a device task submitted with [`DevicePool::run_on`].
pub struct TaskHandle<R> {
    rx: mpsc::Receiver<R>,
}

impl<R> TaskHandle<R> {
    /// Block until the task ran on a pool device.
    pub fn wait(self) -> Result<R, Error> {
        self.rx
            .recv()
            .map_err(|_| Error::Sched("pool dropped before the task ran".into()))
    }
}

/// What a [`DevicePool::run_on`] closure gets: exclusive use of one pool
/// device (its worker thread is running the closure) plus the device's
/// profiler, so arbitrary multi-launch workloads — e.g. the SPEC-analog
/// benchmarks behind `omprt bench --pool` — can execute through the
/// pool's scheduler without being reshaped into single-launch requests.
pub struct DeviceLease<'a> {
    /// Pool-local device id.
    pub id: usize,
    /// Device spec.
    pub spec: DeviceSpec,
    /// The leased device.
    pub device: &'a Arc<OffloadDevice>,
    /// The device's region profiler (feeds the pool report).
    pub profiler: &'a Profiler,
}

// ---------------------------------------------------------------------------
// Pool configuration
// ---------------------------------------------------------------------------

/// One device of the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceSpec {
    /// Runtime build.
    pub kind: RuntimeKind,
    /// Architecture.
    pub arch: Arch,
}

impl DeviceSpec {
    /// Parse `"<kind>:<arch>"`, e.g. `"portable:nvptx64"`.
    pub fn parse(s: &str) -> Option<DeviceSpec> {
        let (k, a) = s.split_once(':')?;
        Some(DeviceSpec { kind: RuntimeKind::parse(k.trim())?, arch: Arch::parse(a.trim())? })
    }
}

impl std::fmt::Display for DeviceSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.kind, self.arch)
    }
}

/// Pool construction parameters (the `[pool]` config table).
#[derive(Debug, Clone, PartialEq)]
pub struct PoolConfig {
    /// Devices, in pool-id order.
    pub devices: Vec<DeviceSpec>,
    /// Default optimization level for requests (callers still set their
    /// own per-request `opt`; the demo and bench use this).
    pub default_opt: OptLevel,
    /// Most queued same-image requests a worker coalesces into one batch
    /// (1 disables batching).
    pub batch_max: usize,
    /// Submission-queue bound; `submit` blocks (and `try_submit` returns
    /// [`TrySubmitError::Full`]) while the queue is at capacity. 0 =
    /// unbounded.
    pub queue_cap: usize,
    /// Minimum elements each shard must keep; a sharded request that
    /// cannot give at least 2 shards this many elements runs on a single
    /// device instead (shard overhead would dominate).
    pub shard_min_trips: usize,
    /// Per-device kernel-image cache budget in bytes (LRU eviction past
    /// it). 0 = unlimited.
    pub cache_budget_bytes: u64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig::mixed4()
    }
}

impl PoolConfig {
    /// The canonical 4-device mixed pool: both architectures under both
    /// runtime builds.
    pub fn mixed4() -> PoolConfig {
        PoolConfig {
            devices: vec![
                DeviceSpec { kind: RuntimeKind::Portable, arch: Arch::Nvptx64 },
                DeviceSpec { kind: RuntimeKind::Portable, arch: Arch::Amdgcn },
                DeviceSpec { kind: RuntimeKind::Legacy, arch: Arch::Nvptx64 },
                DeviceSpec { kind: RuntimeKind::Legacy, arch: Arch::Amdgcn },
            ],
            default_opt: OptLevel::O2,
            batch_max: 16,
            queue_cap: 1024,
            shard_min_trips: 4096,
            cache_budget_bytes: 0,
        }
    }

    /// A single-device pool (baseline for the throughput bench).
    pub fn single(kind: RuntimeKind, arch: Arch) -> PoolConfig {
        PoolConfig { devices: vec![DeviceSpec { kind, arch }], ..PoolConfig::mixed4() }
    }

    /// `n` identical devices (the sharding bench/test shape).
    pub fn uniform(kind: RuntimeKind, arch: Arch, n: usize) -> PoolConfig {
        PoolConfig {
            devices: vec![DeviceSpec { kind, arch }; n.max(1)],
            ..PoolConfig::mixed4()
        }
    }

    /// Override the batch limit (1 disables batching).
    pub fn with_batch_max(mut self, batch_max: usize) -> PoolConfig {
        self.batch_max = batch_max.max(1);
        self
    }

    /// Override the queue bound (0 = unbounded).
    pub fn with_queue_cap(mut self, queue_cap: usize) -> PoolConfig {
        self.queue_cap = queue_cap;
        self
    }

    /// Override the minimum per-shard element count.
    pub fn with_shard_min_trips(mut self, trips: usize) -> PoolConfig {
        self.shard_min_trips = trips.max(1);
        self
    }

    /// Override the per-device image-cache budget (0 = unlimited).
    pub fn with_cache_budget(mut self, bytes: u64) -> PoolConfig {
        self.cache_budget_bytes = bytes;
        self
    }

    /// Read the `[pool]` section of a config document:
    ///
    /// ```text
    /// [pool]
    /// devices = ["portable:nvptx64", "legacy:amdgcn"]
    /// opt = "O2"
    /// batch_max = 16          # same-image launches coalesced per pop
    /// queue_cap = 1024        # submission-queue bound (0 = unbounded)
    /// shard_min_trips = 4096  # min elements per shard
    /// cache_budget_bytes = 0  # per-device image-cache LRU budget
    /// ```
    ///
    /// Missing section or keys fall back to [`PoolConfig::mixed4`].
    pub fn from_config(cfg: &Config) -> Result<PoolConfig, Error> {
        let mut out = PoolConfig::mixed4();
        let Some(sec) = cfg.section("pool") else {
            return Ok(out);
        };
        if let Some(list) = sec.get("devices").and_then(|v| v.as_str_list()) {
            let mut devices = vec![];
            for s in list {
                let spec = DeviceSpec::parse(s).ok_or_else(|| {
                    Error::Config(format!(
                        "[pool] bad device `{s}` (want \"<legacy|portable>:<nvptx64|amdgcn>\")"
                    ))
                })?;
                devices.push(spec);
            }
            if devices.is_empty() {
                return Err(Error::Config("[pool] devices list is empty".into()));
            }
            out.devices = devices;
        }
        if let Some(s) = sec.get("opt").and_then(|v| v.as_str()) {
            out.default_opt = OptLevel::parse(s)
                .ok_or_else(|| Error::Config(format!("[pool] bad opt `{s}` (want O0|O2)")))?;
        }
        out.batch_max = read_uint(sec, "batch_max", out.batch_max as i64, 1)? as usize;
        out.queue_cap = read_uint(sec, "queue_cap", out.queue_cap as i64, 0)? as usize;
        out.shard_min_trips =
            read_uint(sec, "shard_min_trips", out.shard_min_trips as i64, 1)? as usize;
        out.cache_budget_bytes =
            read_uint(sec, "cache_budget_bytes", out.cache_budget_bytes as i64, 0)? as u64;
        Ok(out)
    }
}

/// Read a non-negative integer `[pool]` key with a minimum-value check.
fn read_uint(
    sec: &crate::config::Section,
    key: &str,
    default: i64,
    min: i64,
) -> Result<i64, Error> {
    match sec.get(key) {
        None => Ok(default),
        Some(v) => match v.as_uint() {
            Some(u) if u as i64 >= min => Ok(u as i64),
            _ => Err(Error::Config(format!("[pool] bad {key} `{v:?}` (want integer >= {min})"))),
        },
    }
}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

/// The batch-compatibility key: two queued requests can be coalesced on a
/// device when their image-cache keys agree (arch/kind are implied by the
/// device doing the popping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct BatchKey {
    content: u64,
    opt: OptLevel,
}

struct OffloadJob {
    req: OffloadRequest,
    key: BatchKey,
    /// Shard jobs are never coalesced: a batch runs on one device, which
    /// would defeat the point of splitting the request.
    no_batch: bool,
    reply: mpsc::Sender<Result<OffloadResponse, Error>>,
    enqueued: Instant,
}

type TaskFn = Box<dyn FnOnce(&DeviceLease<'_>) + Send>;

struct TaskJob {
    affinity: Affinity,
    run: TaskFn,
}

enum Job {
    Offload(OffloadJob),
    Task(TaskJob),
}

impl Job {
    fn affinity(&self) -> Affinity {
        match self {
            Job::Offload(j) => j.req.affinity,
            Job::Task(t) => t.affinity,
        }
    }
}

/// Per-device state shared with the device's worker thread.
struct DeviceSlot {
    id: usize,
    spec: DeviceSpec,
    device: Arc<OffloadDevice>,
    cache: ImageCache,
    profiler: Profiler,
    inflight: AtomicUsize,
    completed: AtomicU64,
    batches: AtomicU64,
    batched_jobs: AtomicU64,
    max_batch: AtomicUsize,
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    /// Workers wait here for jobs.
    cv: Condvar,
    /// Submitters wait here for queue space (when `queue_cap > 0`).
    space: Condvar,
    shutdown: AtomicBool,
    slots: Vec<DeviceSlot>,
    batch_max: usize,
    queue_cap: usize,
    shard_min_trips: usize,
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    sharded_requests: AtomicU64,
    shard_jobs: AtomicU64,
    peak_depth: AtomicUsize,
    started: Instant,
}

/// A pool of offload devices with per-device worker threads.
pub struct DevicePool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl DevicePool {
    /// Build the devices and start one worker thread per device.
    pub fn new(config: &PoolConfig) -> Result<DevicePool, Error> {
        if config.devices.is_empty() {
            return Err(Error::Sched("pool needs at least one device".into()));
        }
        let slots: Vec<DeviceSlot> = config
            .devices
            .iter()
            .enumerate()
            .map(|(id, spec)| DeviceSlot {
                id,
                spec: *spec,
                device: Arc::new(OffloadDevice::new(spec.kind, spec.arch)),
                cache: ImageCache::with_budget(config.cache_budget_bytes),
                profiler: Profiler::new(),
                inflight: AtomicUsize::new(0),
                completed: AtomicU64::new(0),
                batches: AtomicU64::new(0),
                batched_jobs: AtomicU64::new(0),
                max_batch: AtomicUsize::new(0),
            })
            .collect();
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            space: Condvar::new(),
            shutdown: AtomicBool::new(false),
            slots,
            batch_max: config.batch_max.max(1),
            queue_cap: config.queue_cap,
            shard_min_trips: config.shard_min_trips.max(1),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            sharded_requests: AtomicU64::new(0),
            shard_jobs: AtomicU64::new(0),
            peak_depth: AtomicUsize::new(0),
            started: Instant::now(),
        });
        let mut workers = vec![];
        for id in 0..config.devices.len() {
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("pool-dev{id}"))
                .spawn(move || worker_loop(&shared, id))
                .map_err(|e| Error::Sched(format!("cannot spawn pool worker: {e}")))?;
            workers.push(handle);
        }
        Ok(DevicePool { shared, workers })
    }

    /// Number of devices.
    pub fn device_count(&self) -> usize {
        self.shared.slots.len()
    }

    /// Device specs in pool-id order.
    pub fn specs(&self) -> Vec<DeviceSpec> {
        self.shared.slots.iter().map(|s| s.spec).collect()
    }

    /// Fail fast when the request is malformed, its affinity matches no
    /// pool device, or its shard spec is inconsistent.
    fn validate(&self, req: &OffloadRequest) -> Result<(), Error> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Sched("pool is shut down".into()));
        }
        if req.kernel.is_empty() {
            return Err(Error::Sched("request has no kernel name".into()));
        }
        for a in &req.args {
            if let KernelArg::Buf(i) = a {
                if *i >= req.buffers.len() {
                    return Err(Error::Sched(format!(
                        "arg references buffer {i} but only {} buffers are mapped",
                        req.buffers.len()
                    )));
                }
            }
        }
        if !self
            .shared
            .slots
            .iter()
            .any(|s| req.affinity.matches(s.spec.arch, s.spec.kind))
        {
            return Err(Error::Sched(format!(
                "affinity {:?} matches no device in the pool ({:?})",
                req.affinity,
                self.specs().iter().map(|s| s.to_string()).collect::<Vec<_>>()
            )));
        }
        if let Some(spec) = &req.shard {
            if spec.elem_bytes == 0 || spec.elems == 0 {
                return Err(Error::Sched("shard spec with zero elems or elem_bytes".into()));
            }
            match req.args.get(spec.count_arg) {
                Some(KernelArg::Imm(_)) => {}
                _ => {
                    return Err(Error::Sched(format!(
                        "shard count_arg {} must index an Imm argument",
                        spec.count_arg
                    )))
                }
            }
            let want = spec
                .elems
                .checked_mul(spec.elem_bytes)
                .ok_or_else(|| Error::Sched("shard spec size overflow".into()))?;
            for &bi in &spec.partitioned {
                let len = req
                    .buffers
                    .get(bi)
                    .ok_or_else(|| {
                        Error::Sched(format!("shard partitions missing buffer {bi}"))
                    })?
                    .bytes
                    .len();
                if len != want {
                    return Err(Error::Sched(format!(
                        "partitioned buffer {bi} is {len} bytes, expected {want} \
                         (elems * elem_bytes)"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Submit a request; returns a handle resolving to the response.
    ///
    /// Fails fast (without enqueueing) when the request is malformed or
    /// its affinity matches no device in the pool. When the pool has a
    /// `queue_cap`, a full queue makes `submit` **block** until workers
    /// drain space (backpressure); use [`DevicePool::try_submit`] to shed
    /// load instead.
    ///
    /// A request carrying a [`ShardSpec`] that is large enough (see
    /// `[pool] shard_min_trips`) is split into per-device shards across
    /// the matching architecture with the most eligible devices; the
    /// handle resolves to the stitched response.
    pub fn submit(&self, req: OffloadRequest) -> Result<OffloadHandle, Error> {
        self.validate(&req)?;
        if let Some(plan) = self.shard_plan(&req) {
            let (jobs, parts) = self.build_shards(&req, &plan);
            let frx = spawn_stitcher(&req, parts)?;
            let n = jobs.len();
            for job in jobs {
                self.enqueue(Job::Offload(job))?;
            }
            self.shared.sharded_requests.fetch_add(1, Ordering::Relaxed);
            self.shared.shard_jobs.fetch_add(n as u64, Ordering::Relaxed);
            return Ok(OffloadHandle { rx: frx });
        }
        let (reply, rx) = mpsc::channel();
        let job = make_offload_job(req, reply, false);
        self.enqueue(Job::Offload(job))?;
        Ok(OffloadHandle { rx })
    }

    /// Non-blocking [`DevicePool::submit`]: when the queue is at capacity
    /// the request is returned in [`TrySubmitError::Full`] instead of
    /// blocking. A sharded request is accepted only if **all** its shard
    /// jobs fit at once.
    pub fn try_submit(&self, req: OffloadRequest) -> Result<OffloadHandle, TrySubmitError> {
        if let Err(e) = self.validate(&req) {
            return Err(TrySubmitError::Rejected(e));
        }
        if let Some(plan) = self.shard_plan(&req) {
            // Cheap capacity check before materializing shard buffers and
            // spawning the stitcher: under sustained backpressure every
            // rejected retry would otherwise pay O(data) copies. The
            // all-or-nothing bulk enqueue below remains authoritative.
            if self.shared.queue_cap > 0 {
                let depth = self.shared.queue.lock().unwrap().len();
                if depth + plan.ranges.len() > self.shared.queue_cap {
                    return Err(TrySubmitError::Full(req));
                }
            }
            let (jobs, parts) = self.build_shards(&req, &plan);
            let frx = match spawn_stitcher(&req, parts) {
                Ok(rx) => rx,
                Err(e) => return Err(TrySubmitError::Rejected(e)),
            };
            let n = jobs.len();
            if self
                .try_enqueue_bulk(jobs.into_iter().map(Job::Offload).collect())
                .is_err()
            {
                // Dropping the shard jobs disconnects the stitcher, which
                // exits; the untouched original goes back to the caller.
                return Err(TrySubmitError::Full(req));
            }
            self.shared.sharded_requests.fetch_add(1, Ordering::Relaxed);
            self.shared.shard_jobs.fetch_add(n as u64, Ordering::Relaxed);
            return Ok(OffloadHandle { rx: frx });
        }
        let (reply, rx) = mpsc::channel();
        let job = make_offload_job(req, reply, false);
        match self.try_enqueue_bulk(vec![Job::Offload(job)]) {
            Ok(()) => Ok(OffloadHandle { rx }),
            Err(mut jobs) => match jobs.pop() {
                Some(Job::Offload(j)) => Err(TrySubmitError::Full(j.req)),
                _ => unreachable!("bulk enqueue returns the jobs it was given"),
            },
        }
    }

    /// Run an arbitrary closure with exclusive use of one matching pool
    /// device (a *device lease*). The closure runs on the device's worker
    /// thread, scheduled like any queued job — this is how whole
    /// benchmarks route through the pool (`omprt bench --pool`).
    pub fn run_on<R, F>(&self, affinity: Affinity, f: F) -> Result<TaskHandle<R>, Error>
    where
        R: Send + 'static,
        F: FnOnce(&DeviceLease<'_>) -> R + Send + 'static,
    {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Sched("pool is shut down".into()));
        }
        if !self
            .shared
            .slots
            .iter()
            .any(|s| affinity.matches(s.spec.arch, s.spec.kind))
        {
            return Err(Error::Sched(format!(
                "affinity {:?} matches no device in the pool ({:?})",
                affinity,
                self.specs().iter().map(|s| s.to_string()).collect::<Vec<_>>()
            )));
        }
        let (tx, rx) = mpsc::channel();
        let run: TaskFn = Box::new(move |lease: &DeviceLease<'_>| {
            let _ = tx.send(f(lease));
        });
        self.enqueue(Job::Task(TaskJob { affinity, run }))?;
        Ok(TaskHandle { rx })
    }

    /// Blocking enqueue honoring `queue_cap` backpressure.
    fn enqueue(&self, job: Job) -> Result<(), Error> {
        let shared = &self.shared;
        let mut q = shared.queue.lock().unwrap();
        if shared.queue_cap > 0 {
            while q.len() >= shared.queue_cap {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return Err(Error::Sched("pool is shut down".into()));
                }
                q = shared.space.wait(q).unwrap();
            }
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Sched("pool is shut down".into()));
        }
        // Count while holding the queue lock, before the job becomes
        // visible, so `submitted` never lags behind `completed` in a
        // metrics snapshot.
        shared.submitted.fetch_add(1, Ordering::Relaxed);
        q.push_back(job);
        let depth = q.len();
        shared.peak_depth.fetch_max(depth, Ordering::Relaxed);
        drop(q);
        // notify_all: the job may be eligible only for a subset of the
        // sleeping workers, and notify_one could wake the wrong one.
        shared.cv.notify_all();
        Ok(())
    }

    /// All-or-nothing non-blocking enqueue; hands the jobs back when they
    /// do not fit under `queue_cap`.
    fn try_enqueue_bulk(&self, jobs: Vec<Job>) -> Result<(), Vec<Job>> {
        let shared = &self.shared;
        let mut q = shared.queue.lock().unwrap();
        if shared.queue_cap > 0 && q.len() + jobs.len() > shared.queue_cap {
            return Err(jobs);
        }
        for job in jobs {
            shared.submitted.fetch_add(1, Ordering::Relaxed);
            q.push_back(job);
        }
        let depth = q.len();
        shared.peak_depth.fetch_max(depth, Ordering::Relaxed);
        drop(q);
        shared.cv.notify_all();
        Ok(())
    }

    /// Decide whether (and how) to shard `req`: pick the matching
    /// architecture with the most eligible devices, split the element
    /// range evenly, and fall back to single-device execution when any
    /// shard would drop under `shard_min_trips` elements.
    fn shard_plan(&self, req: &OffloadRequest) -> Option<ShardPlan> {
        let spec = req.shard.as_ref()?;
        let mut archs: Vec<(Arch, usize)> = vec![];
        for s in &self.shared.slots {
            if req.affinity.matches(s.spec.arch, s.spec.kind) {
                match archs.iter_mut().find(|(a, _)| *a == s.spec.arch) {
                    Some((_, c)) => *c += 1,
                    None => archs.push((s.spec.arch, 1)),
                }
            }
        }
        // First-seen order breaks ties, so the plan is deterministic.
        let mut best: Option<(Arch, usize)> = None;
        for (a, c) in archs {
            if best.map_or(true, |(_, bc)| c > bc) {
                best = Some((a, c));
            }
        }
        let (arch, ndev) = best?;
        // Clamp to the queue bound so a sharded request can always be
        // enqueued whole — otherwise `try_submit` on a pool with
        // queue_cap < device count would report Full forever, even idle.
        let cap = if self.shared.queue_cap > 0 { self.shared.queue_cap } else { usize::MAX };
        let n = ndev.min(spec.elems / self.shared.shard_min_trips).min(cap);
        if n < 2 {
            return None;
        }
        let base = spec.elems / n;
        let rem = spec.elems % n;
        let mut ranges = Vec::with_capacity(n);
        let mut lo = 0usize;
        for i in 0..n {
            let len = base + usize::from(i < rem);
            ranges.push((lo, lo + len));
            lo += len;
        }
        Some(ShardPlan { arch, ranges })
    }

    /// Materialize the shard jobs for `req` under `plan`. The original
    /// request is only borrowed, so a failed enqueue can hand it back.
    fn build_shards(
        &self,
        req: &OffloadRequest,
        plan: &ShardPlan,
    ) -> (Vec<OffloadJob>, Vec<ShardPart>) {
        let spec = req.shard.as_ref().expect("a plan implies a spec");
        let n = plan.ranges.len();
        let mut jobs = Vec::with_capacity(n);
        let mut parts = Vec::with_capacity(n);
        for &(lo, hi) in &plan.ranges {
            let buffers: Vec<MapBuf> = req
                .buffers
                .iter()
                .enumerate()
                .map(|(bi, b)| {
                    if spec.partitioned.contains(&bi) {
                        MapBuf {
                            bytes: b.bytes[lo * spec.elem_bytes..hi * spec.elem_bytes].to_vec(),
                            map_type: b.map_type,
                        }
                    } else {
                        b.clone()
                    }
                })
                .collect();
            let mut args = req.args.clone();
            args[spec.count_arg] = KernelArg::Imm((hi - lo) as u64);
            let sreq = OffloadRequest {
                module: req.module.clone(),
                kernel: req.kernel.clone(),
                region: req.region.clone(),
                cfg: LaunchConfig::new(
                    req.cfg.grid_dim.div_ceil(n as u32).max(1),
                    req.cfg.block_dim,
                ),
                opt: req.opt,
                buffers,
                args,
                affinity: Affinity { arch: Some(plan.arch), kind: req.affinity.kind },
                shard: None,
            };
            let (tx, rx) = mpsc::channel();
            jobs.push(make_offload_job(sreq, tx, true));
            parts.push(ShardPart { rx, lo, hi });
        }
        (jobs, parts)
    }

    /// Snapshot of queue/throughput/cache/allocator metrics.
    pub fn metrics(&self) -> PoolMetrics {
        let queue_depth = self.shared.queue.lock().unwrap().len();
        let devices: Vec<DeviceMetrics> = self
            .shared
            .slots
            .iter()
            .map(|s| DeviceMetrics {
                id: s.id,
                kind: s.spec.kind,
                arch: s.spec.arch,
                inflight: s.inflight.load(Ordering::Relaxed),
                completed: s.completed.load(Ordering::Relaxed),
                batches: s.batches.load(Ordering::Relaxed),
                batched_jobs: s.batched_jobs.load(Ordering::Relaxed),
                max_batch: s.max_batch.load(Ordering::Relaxed),
                cache: s.cache.stats(),
                cached_images: s.cache.len(),
                cache_bytes: s.cache.bytes(),
                mem: s.device.gmem.stats(),
            })
            .collect();
        PoolMetrics {
            queue_depth,
            peak_queue_depth: self.shared.peak_depth.load(Ordering::Relaxed),
            queue_cap: self.shared.queue_cap,
            submitted: self.shared.submitted.load(Ordering::Relaxed),
            completed: self.shared.completed.load(Ordering::Relaxed),
            failed: self.shared.failed.load(Ordering::Relaxed),
            sharded_requests: self.shared.sharded_requests.load(Ordering::Relaxed),
            shard_jobs: self.shared.shard_jobs.load(Ordering::Relaxed),
            uptime: self.shared.started.elapsed(),
            devices,
        }
    }

    /// Per-device profiler reports, in pool-id order.
    pub fn profiler_reports(&self) -> Vec<(DeviceSpec, Vec<RegionReport>)> {
        self.shared
            .slots
            .iter()
            .map(|s| (s.spec, s.profiler.report()))
            .collect()
    }

    /// Block until every submitted request has completed or failed.
    /// Intended for tests/benches that stop submitting first; new
    /// submissions during the wait extend it.
    pub fn quiesce(&self) {
        loop {
            let m = self.metrics();
            if m.queue_depth == 0 && m.completed + m.failed >= m.submitted {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

struct ShardPlan {
    arch: Arch,
    ranges: Vec<(usize, usize)>,
}

struct ShardPart {
    rx: mpsc::Receiver<Result<OffloadResponse, Error>>,
    lo: usize,
    hi: usize,
}

fn make_offload_job(
    req: OffloadRequest,
    reply: mpsc::Sender<Result<OffloadResponse, Error>>,
    no_batch: bool,
) -> OffloadJob {
    let key = BatchKey { content: req.module.content_hash(), opt: req.opt };
    OffloadJob { req, key, no_batch, reply, enqueued: Instant::now() }
}

/// Spawn the result-stitcher for a sharded request; resolves the returned
/// receiver with the assembled response once every shard reported.
fn spawn_stitcher(
    req: &OffloadRequest,
    parts: Vec<ShardPart>,
) -> Result<mpsc::Receiver<Result<OffloadResponse, Error>>, Error> {
    let spec = req.shard.as_ref().expect("sharded request has a spec");
    let buf_meta: Vec<(MapType, usize)> =
        req.buffers.iter().map(|b| (b.map_type, b.bytes.len())).collect();
    let partitioned = spec.partitioned.clone();
    let elem_bytes = spec.elem_bytes;
    let (ftx, frx) = mpsc::channel();
    std::thread::Builder::new()
        .name("pool-stitch".into())
        .spawn(move || stitch(parts, buf_meta, partitioned, elem_bytes, ftx))
        .map_err(|e| Error::Sched(format!("cannot spawn shard stitcher: {e}")))?;
    Ok(frx)
}

/// Wait for all shard responses and assemble the full-request response:
/// partitioned outputs are copied into their element ranges, broadcast
/// outputs come from the first shard, counters are summed (`wall` and
/// `queue_wait` take the max).
fn stitch(
    parts: Vec<ShardPart>,
    buf_meta: Vec<(MapType, usize)>,
    partitioned: Vec<usize>,
    elem_bytes: usize,
    ftx: mpsc::Sender<Result<OffloadResponse, Error>>,
) {
    let mut got: Vec<(OffloadResponse, usize, usize)> = Vec::with_capacity(parts.len());
    let mut first_err: Option<Error> = None;
    for part in parts {
        match part.rx.recv() {
            Ok(Ok(resp)) => got.push((resp, part.lo, part.hi)),
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err =
                        Some(Error::Sched("shard dropped before the request completed".into()));
                }
            }
        }
    }
    if let Some(e) = first_err {
        let _ = ftx.send(Err(e));
        return;
    }
    let mut buffers: Vec<Option<Vec<u8>>> = Vec::with_capacity(buf_meta.len());
    for (bi, (map_type, full_len)) in buf_meta.iter().enumerate() {
        if !matches!(map_type, MapType::From | MapType::Tofrom) {
            buffers.push(None);
            continue;
        }
        if partitioned.contains(&bi) {
            let mut out = vec![0u8; *full_len];
            for (resp, lo, hi) in &got {
                if let Some(src) = &resp.buffers[bi] {
                    out[lo * elem_bytes..hi * elem_bytes].copy_from_slice(src);
                }
            }
            buffers.push(Some(out));
        } else {
            buffers.push(got[0].0.buffers[bi].clone());
        }
    }
    let mut stats = LaunchStats::default();
    let mut queue_wait = Duration::ZERO;
    let mut cache_hit = true;
    for (resp, _, _) in &got {
        stats.lane_ops += resp.stats.lane_ops;
        stats.warp_steps += resp.stats.warp_steps;
        stats.blocks += resp.stats.blocks;
        if resp.stats.wall > stats.wall {
            stats.wall = resp.stats.wall;
        }
        if resp.queue_wait > queue_wait {
            queue_wait = resp.queue_wait;
        }
        cache_hit &= resp.cache_hit;
    }
    let shards = got.len();
    let first = &got[0].0;
    let _ = ftx.send(Ok(OffloadResponse {
        device_id: first.device_id,
        arch: first.arch,
        kind: first.kind,
        stats,
        cache_hit,
        queue_wait,
        shards,
        buffers,
    }));
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        // Flip the shutdown predicate while holding the queue mutex: a
        // worker that already checked `shutdown` and is between that check
        // and `cv.wait` would otherwise miss this notify forever. Blocked
        // submitters (backpressure) are woken the same way.
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
            self.shared.cv.notify_all();
            self.shared.space.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // Fail any requests still queued so waiting clients unblock with
        // an error instead of a channel disconnect. (Dropped task jobs
        // disconnect their handles, which also unblocks their waiters.)
        let mut q = self.shared.queue.lock().unwrap();
        while let Some(job) = q.pop_front() {
            if let Job::Offload(j) = job {
                let _ = j
                    .reply
                    .send(Err(Error::Sched("pool shut down before the request ran".into())));
            }
        }
    }
}

/// What a worker popped in one queue visit.
enum Work {
    Batch(Vec<OffloadJob>),
    Task(TaskJob),
}

/// Worker body: pop the oldest affinity-compatible job — coalescing up to
/// `batch_max` same-image offload requests behind it — run it, reply.
fn worker_loop(shared: &Shared, id: usize) {
    let slot = &shared.slots[id];
    loop {
        let work = {
            let mut q = shared.queue.lock().unwrap();
            'wait: loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(pos) = q
                    .iter()
                    .position(|j| j.affinity().matches(slot.spec.arch, slot.spec.kind))
                {
                    let first = q.remove(pos).expect("position is in range");
                    match first {
                        Job::Task(t) => break 'wait Work::Task(t),
                        Job::Offload(j) => {
                            let mut batch = vec![j];
                            if shared.batch_max > 1 && !batch[0].no_batch {
                                let key = batch[0].key;
                                // After the removal, the element formerly at
                                // pos+1 sits at pos: continue scanning there.
                                let mut i = pos;
                                while batch.len() < shared.batch_max && i < q.len() {
                                    let compatible = matches!(
                                        &q[i],
                                        Job::Offload(o) if o.key == key
                                            && !o.no_batch
                                            && o.req.affinity.matches(slot.spec.arch, slot.spec.kind)
                                    );
                                    if compatible {
                                        match q.remove(i) {
                                            Some(Job::Offload(o)) => batch.push(o),
                                            _ => unreachable!("index i held an offload job"),
                                        }
                                    } else {
                                        i += 1;
                                    }
                                }
                            }
                            break 'wait Work::Batch(batch);
                        }
                    }
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        // Jobs left the queue: wake submitters blocked on a full queue.
        shared.space.notify_all();
        match work {
            Work::Task(task) => {
                slot.inflight.fetch_add(1, Ordering::Relaxed);
                let lease = DeviceLease {
                    id: slot.id,
                    spec: slot.spec,
                    device: &slot.device,
                    profiler: &slot.profiler,
                };
                // Leased closures are arbitrary user code; a panic must
                // not kill this device's worker thread (every job pinned
                // to the device would starve forever). The panicked
                // task's handle resolves to an error via its dropped
                // sender.
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    (task.run)(&lease)
                }));
                slot.inflight.fetch_sub(1, Ordering::Relaxed);
                match outcome {
                    Ok(()) => {
                        slot.completed.fetch_add(1, Ordering::Relaxed);
                        shared.completed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        shared.failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Work::Batch(batch) => run_offload_batch(shared, slot, batch),
        }
    }
}

/// Execute a popped batch (size ≥ 1) on `slot` and reply to every job.
///
/// The image lookup/prepare is paid once per batch; follower jobs are
/// recorded as cache hits (they share the leader's image by
/// construction). Batches of independent jobs — images without
/// global-space globals, so no cross-launch device state — execute as one
/// fused grid via [`OffloadDevice::offload_batch`]; anything else falls
/// back to per-job sequential launches.
fn run_offload_batch(shared: &Shared, slot: &DeviceSlot, batch: Vec<OffloadJob>) {
    let n = batch.len();
    slot.inflight.fetch_add(n, Ordering::Relaxed);
    slot.batches.fetch_add(1, Ordering::Relaxed);
    if n > 1 {
        slot.batched_jobs.fetch_add(n as u64, Ordering::Relaxed);
    }
    slot.max_batch.fetch_max(n, Ordering::Relaxed);
    let waits: Vec<Duration> = batch.iter().map(|j| j.enqueued.elapsed()).collect();

    let results: Vec<Result<OffloadResponse, Error>> =
        match slot.cache.get_or_prepare(&slot.device, &batch[0].req.module, batch[0].req.opt) {
            Err(e) => {
                let msg = format!("prepare failed: {e}");
                batch.iter().map(|_| Err(Error::Sched(msg.clone()))).collect()
            }
            Ok((image, first_hit)) => {
                if n > 1 {
                    slot.cache.note_batched_hits(n as u64 - 1);
                }
                if n > 1 && image.module.global_addrs.is_empty() {
                    run_fused(slot, &image, &batch, &waits, first_hit)
                } else {
                    batch
                        .iter()
                        .enumerate()
                        .map(|(i, job)| {
                            let hit = if i == 0 { first_hit } else { true };
                            run_one(slot, &image, &job.req, waits[i], hit)
                        })
                        .collect()
                }
            }
        };

    slot.inflight.fetch_sub(n, Ordering::Relaxed);
    for (job, result) in batch.into_iter().zip(results) {
        match &result {
            Ok(_) => {
                slot.completed.fetch_add(1, Ordering::Relaxed);
                shared.completed.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                shared.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        // A dropped handle is fine: the work still ran.
        let _ = job.reply.send(result);
    }
}

/// Map each request buffer into device memory (copying `To`/`Tofrom`
/// data); on failure everything already mapped is freed.
fn map_buffers(device: &OffloadDevice, req: &OffloadRequest) -> Result<Vec<u64>, Error> {
    let mut addrs = Vec::with_capacity(req.buffers.len());
    for b in &req.buffers {
        match device.gmem.alloc((b.bytes.len() as u64).max(1), 8) {
            Ok(addr) => {
                addrs.push(addr);
                if matches!(b.map_type, MapType::To | MapType::Tofrom) {
                    if let Err(e) = device.gmem.write_bytes(addr, &b.bytes) {
                        free_buffers(device, &addrs);
                        return Err(e);
                    }
                }
            }
            Err(e) => {
                free_buffers(device, &addrs);
                return Err(e);
            }
        }
    }
    Ok(addrs)
}

/// Return mapped buffers to the device's free-list allocator.
fn free_buffers(device: &OffloadDevice, addrs: &[u64]) {
    for &addr in addrs {
        let _ = device.gmem.free(addr);
    }
}

/// Resolve `KernelArg`s against the mapped device addresses.
fn resolve_args(req: &OffloadRequest, dev_addrs: &[u64]) -> Vec<u64> {
    req.args
        .iter()
        .map(|a| match a {
            KernelArg::Buf(i) => dev_addrs[*i], // index validated at submit
            KernelArg::Imm(v) => *v,
        })
        .collect()
}

/// Read back `From`/`Tofrom` buffers after a launch.
fn read_back(
    device: &OffloadDevice,
    req: &OffloadRequest,
    dev_addrs: &[u64],
) -> Result<Vec<Option<Vec<u8>>>, Error> {
    let mut out = Vec::with_capacity(req.buffers.len());
    for (b, addr) in req.buffers.iter().zip(dev_addrs) {
        if matches!(b.map_type, MapType::From | MapType::Tofrom) {
            let mut buf = vec![0u8; b.bytes.len()];
            device.gmem.read_bytes(*addr, &mut buf)?;
            out.push(Some(buf));
        } else {
            out.push(None);
        }
    }
    Ok(out)
}

/// Execute one request on `slot`: map, launch, read back, free.
fn run_one(
    slot: &DeviceSlot,
    image: &Arc<KernelImage>,
    req: &OffloadRequest,
    queue_wait: Duration,
    cache_hit: bool,
) -> Result<OffloadResponse, Error> {
    let dev_addrs = map_buffers(&slot.device, req)?;
    let args = resolve_args(req, &dev_addrs);
    let (launch, elapsed) =
        crate::util::stats::timed(|| slot.device.offload(image, &req.kernel, &args, req.cfg));
    slot.profiler.record(&req.region, elapsed);
    let result = (|| {
        let stats = launch?;
        let buffers = read_back(&slot.device, req, &dev_addrs)?;
        Ok(OffloadResponse {
            device_id: slot.id,
            arch: slot.spec.arch,
            kind: slot.spec.kind,
            stats,
            cache_hit,
            queue_wait,
            shards: 1,
            buffers,
        })
    })();
    free_buffers(&slot.device, &dev_addrs);
    result
}

/// Execute a batch of independent jobs as one fused grid. Per-job wall
/// attribution inside a fused grid is not measurable; each job's region
/// is charged an equal share of the batch.
fn run_fused(
    slot: &DeviceSlot,
    image: &Arc<KernelImage>,
    batch: &[OffloadJob],
    waits: &[Duration],
    first_hit: bool,
) -> Vec<Result<OffloadResponse, Error>> {
    let n = batch.len();
    let mut mapped: Vec<Result<Vec<u64>, Error>> =
        batch.iter().map(|j| map_buffers(&slot.device, &j.req)).collect();

    // Fused items cover only the successfully mapped jobs.
    let mut arg_store: Vec<Vec<u64>> = Vec::with_capacity(n);
    let mut item_of_job: Vec<Option<usize>> = Vec::with_capacity(n);
    for (i, job) in batch.iter().enumerate() {
        match &mapped[i] {
            Ok(addrs) => {
                item_of_job.push(Some(arg_store.len()));
                arg_store.push(resolve_args(&job.req, addrs));
            }
            Err(_) => item_of_job.push(None),
        }
    }
    let mut items: Vec<BatchKernelSpec<'_>> = Vec::with_capacity(arg_store.len());
    for (i, job) in batch.iter().enumerate() {
        if let Some(k) = item_of_job[i] {
            items.push(BatchKernelSpec {
                kernel: &job.req.kernel,
                args: &arg_store[k],
                cfg: job.req.cfg,
            });
        }
    }

    let (launch_results, elapsed) =
        crate::util::stats::timed(|| slot.device.offload_batch(image, &items));
    // Equal-share attribution over the jobs that actually launched;
    // map-failed jobs ran nothing and are not charged.
    let share = elapsed / items.len().max(1) as u32;

    let mut launch_iter = launch_results.into_iter();
    let mut results = Vec::with_capacity(n);
    for (i, job) in batch.iter().enumerate() {
        let res = match item_of_job[i] {
            None => {
                let e = std::mem::replace(&mut mapped[i], Ok(Vec::new()));
                Err(e.expect_err("unmapped job carries its map error"))
            }
            Some(_) => {
                slot.profiler.record(&job.req.region, share);
                match launch_iter.next().expect("one result per fused item") {
                    Err(e) => Err(e),
                    Ok(stats) => {
                        let addrs = mapped[i].as_ref().expect("mapped job has addresses");
                        read_back(&slot.device, &job.req, addrs).map(|buffers| OffloadResponse {
                            device_id: slot.id,
                            arch: slot.spec.arch,
                            kind: slot.spec.kind,
                            stats,
                            cache_hit: if i == 0 { first_hit } else { true },
                            queue_wait: waits[i],
                            shards: 1,
                            buffers,
                        })
                    }
                }
            }
        };
        results.push(res);
    }
    for m in &mapped {
        if let Ok(addrs) = m {
            free_buffers(&slot.device, addrs);
        }
    }
    results
}

// ---------------------------------------------------------------------------
// Metrics
// ---------------------------------------------------------------------------

/// Per-device metrics snapshot.
#[derive(Debug, Clone)]
pub struct DeviceMetrics {
    /// Pool-local device id.
    pub id: usize,
    /// Runtime build.
    pub kind: RuntimeKind,
    /// Architecture.
    pub arch: Arch,
    /// Requests currently executing on this device (a whole batch counts
    /// each of its jobs).
    pub inflight: usize,
    /// Requests completed on this device.
    pub completed: u64,
    /// Queue pops (each pop executes a batch of ≥ 1 jobs).
    pub batches: u64,
    /// Jobs that ran inside a multi-job batch.
    pub batched_jobs: u64,
    /// Largest batch popped so far.
    pub max_batch: usize,
    /// Image-cache counters.
    pub cache: CacheStats,
    /// Images currently cached.
    pub cached_images: usize,
    /// Estimated bytes of cached images.
    pub cache_bytes: u64,
    /// Device global-memory allocator counters.
    pub mem: MemStats,
}

/// Pool-wide metrics snapshot.
#[derive(Debug, Clone)]
pub struct PoolMetrics {
    /// Jobs waiting in the submission queue.
    pub queue_depth: usize,
    /// Deepest the queue has ever been.
    pub peak_queue_depth: usize,
    /// Configured queue bound (0 = unbounded).
    pub queue_cap: usize,
    /// Total jobs accepted (shard jobs and device tasks count
    /// individually).
    pub submitted: u64,
    /// Total jobs completed successfully.
    pub completed: u64,
    /// Total jobs that failed.
    pub failed: u64,
    /// Client requests that were split across devices.
    pub sharded_requests: u64,
    /// Shard jobs those requests produced.
    pub shard_jobs: u64,
    /// Time since the pool started.
    pub uptime: Duration,
    /// Per-device breakdown.
    pub devices: Vec<DeviceMetrics>,
}

impl PoolMetrics {
    /// Aggregated image-cache counters.
    pub fn cache(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for d in &self.devices {
            s.hits += d.cache.hits;
            s.misses += d.cache.misses;
            s.evictions += d.cache.evictions;
        }
        s
    }

    /// Jobs coalesced into multi-job batches, pool-wide.
    pub fn batched_jobs(&self) -> u64 {
        self.devices.iter().map(|d| d.batched_jobs).sum()
    }

    /// Bytes live across every device allocator.
    pub fn device_live_bytes(&self) -> u64 {
        self.devices.iter().map(|d| d.mem.live_bytes).sum()
    }

    /// Completed launches per second of pool uptime.
    pub fn throughput_per_sec(&self) -> f64 {
        let secs = self.uptime.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.completed as f64 / secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affinity_matching() {
        let any = Affinity::any();
        assert!(any.matches(Arch::Nvptx64, RuntimeKind::Legacy));
        let a = Affinity::on_arch(Arch::Amdgcn);
        assert!(a.matches(Arch::Amdgcn, RuntimeKind::Portable));
        assert!(!a.matches(Arch::Nvptx64, RuntimeKind::Portable));
        let k = Affinity::on_kind(RuntimeKind::Legacy);
        assert!(k.matches(Arch::Nvptx64, RuntimeKind::Legacy));
        assert!(!k.matches(Arch::Nvptx64, RuntimeKind::Portable));
    }

    #[test]
    fn device_spec_parses() {
        let s = DeviceSpec::parse("portable:nvptx64").unwrap();
        assert_eq!(s.kind, RuntimeKind::Portable);
        assert_eq!(s.arch, Arch::Nvptx64);
        assert_eq!(DeviceSpec::parse("legacy:amdgcn").unwrap().arch, Arch::Amdgcn);
        assert!(DeviceSpec::parse("nvptx64").is_none());
        assert!(DeviceSpec::parse("bad:nvptx64").is_none());
        assert!(DeviceSpec::parse("legacy:gfx9").is_none());
    }

    #[test]
    fn pool_config_from_config_document() {
        let cfg = Config::parse(
            "[pool]\ndevices = [\"portable:nvptx64\", \"legacy:amdgcn\"]\nopt = \"O0\"\n\
             batch_max = 4\nqueue_cap = 32\nshard_min_trips = 100\ncache_budget_bytes = 65536",
        )
        .unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(pc.devices.len(), 2);
        assert_eq!(pc.devices[1], DeviceSpec { kind: RuntimeKind::Legacy, arch: Arch::Amdgcn });
        assert_eq!(pc.default_opt, OptLevel::O0);
        assert_eq!(pc.batch_max, 4);
        assert_eq!(pc.queue_cap, 32);
        assert_eq!(pc.shard_min_trips, 100);
        assert_eq!(pc.cache_budget_bytes, 65536);
        // Missing section → default mixed pool.
        let pc = PoolConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(pc, PoolConfig::mixed4());
        // Bad spec errors.
        let cfg = Config::parse("[pool]\ndevices = [\"warp9:nvptx64\"]").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        // Out-of-range knobs error.
        let cfg = Config::parse("[pool]\nbatch_max = 0").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[pool]\nqueue_cap = -1").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn f32_byte_roundtrip() {
        let v = vec![0.0f32, 1.5, -2.25, f32::MAX];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&v)), v);
    }

    fn base_request(affinity: Affinity) -> OffloadRequest {
        OffloadRequest {
            module: Module::new("m"),
            kernel: "k".into(),
            region: "r".into(),
            cfg: LaunchConfig::new(1, 32),
            opt: OptLevel::O2,
            buffers: vec![],
            args: vec![],
            affinity,
            shard: None,
        }
    }

    #[test]
    fn submit_validates_before_enqueue() {
        let pool = DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64))
            .unwrap();
        // Bad buffer index.
        let mut r = base_request(Affinity::any());
        r.args = vec![KernelArg::Buf(3)];
        assert!(pool.submit(r).is_err());
        // Affinity matching no pool device.
        let r = base_request(Affinity::on_arch(Arch::Amdgcn));
        assert!(pool.submit(r).is_err());
        assert_eq!(pool.metrics().submitted, 0);
    }

    #[test]
    fn submit_validates_shard_specs() {
        let pool = DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64))
            .unwrap();
        // count_arg must point at an Imm argument.
        let mut r = base_request(Affinity::any());
        r.buffers = vec![MapBuf { bytes: vec![0u8; 32], map_type: MapType::Tofrom }];
        r.args = vec![KernelArg::Buf(0)];
        r.shard = Some(ShardSpec { partitioned: vec![0], elem_bytes: 4, count_arg: 0, elems: 8 });
        assert!(pool.submit(r).is_err());
        // Partitioned buffer length must equal elems * elem_bytes.
        let mut r = base_request(Affinity::any());
        r.buffers = vec![MapBuf { bytes: vec![0u8; 30], map_type: MapType::Tofrom }];
        r.args = vec![KernelArg::Buf(0), KernelArg::Imm(8)];
        r.shard = Some(ShardSpec { partitioned: vec![0], elem_bytes: 4, count_arg: 1, elems: 8 });
        assert!(pool.submit(r).is_err());
        assert_eq!(pool.metrics().submitted, 0);
    }
}
