//! Property-based tests over coordinator/runtime invariants, using the
//! in-house `util::prop` harness (offline build has no proptest).

use omprt::coordinator::Coordinator;
use omprt::devrt::{irlib, state, RuntimeKind};
use omprt::hostrt::{DataEnv, MapType};
use omprt::ir::passes::OptLevel;
use omprt::ir::{CmpPred, FunctionBuilder, Module, Operand, Type};
use omprt::sim::{Arch, LaunchConfig};
use omprt::util::prop::{forall, Config};

/// Worksharing invariant: for random (n, threads, sched, chunk) the claimed
/// ranges tile the iteration space exactly once.
#[test]
fn prop_worksharing_tiles_iteration_space() {
    let c = Coordinator::new(RuntimeKind::Portable, Arch::Nvptx64);
    forall(
        Config { cases: 12, seed: 0x51AB },
        |r| {
            let n = 1 + r.below(300) as i32;
            let block = [17u32, 32, 48, 64][r.below(4) as usize];
            let sched = [state::SCHED_DYNAMIC, state::SCHED_GUIDED][r.below(2) as usize];
            let chunk = 1 + r.below(9) as i64;
            (n, block, sched, chunk)
        },
        |&(n, block, sched, chunk)| {
            let mut m = Module::new("p");
            let mut b = FunctionBuilder::new("k", &[Type::I64], None).kernel();
            let out = b.param(0);
            irlib::emit_spmd_prologue(&mut b);
            b.call_void(
                "__kmpc_dispatch_init_4",
                &[
                    Operand::i64(0),
                    Operand::i64(n as i64),
                    Operand::i64(chunk),
                    Operand::i64(sched as i64),
                ],
            );
            b.loop_(|b| {
                let packed = b.call("__kmpc_dispatch_next_4", &[], Type::I64);
                let done =
                    b.cmp(CmpPred::Eq, packed, Operand::i64(state::DISPATCH_DONE as i64));
                b.if_(done, |b| b.break_());
                let (lb, ub) = omprt::benchmarks::common::unpack_range(b, packed);
                b.for_range(lb, ub, Operand::i32(1), |b, i| {
                    let a = b.index(out, i, 4);
                    b.call("__kmpc_atomic_add", &[a.into(), Operand::i32(1)], Type::I32);
                });
            });
            b.call_void("__kmpc_dispatch_fini_4", &[]);
            irlib::emit_spmd_epilogue(&mut b);
            b.ret();
            m.add_func(b.build());

            let image = c.prepare(m, OptLevel::O2).map_err(|e| e.to_string())?;
            let mut env = DataEnv::new(&c.device);
            let mut out = vec![0u32; n as usize];
            let d = env.map(&out, MapType::Tofrom).map_err(|e| e.to_string())?;
            c.device
                .offload(&image, "k", &[d], LaunchConfig::new(1, block))
                .map_err(|e| e.to_string())?;
            env.unmap(&mut out).map_err(|e| e.to_string())?;
            if out.iter().all(|&v| v == 1) {
                Ok(())
            } else {
                Err(format!("coverage broken: {out:?}"))
            }
        },
    );
}

/// Static schedule invariant (pure binding math, fast): ranges are
/// contiguous, ordered, within bounds, and sum to the whole space.
#[test]
fn prop_static_partition_is_exact() {
    let c = Coordinator::new(RuntimeKind::Legacy, Arch::Amdgcn);
    forall(
        Config { cases: 10, seed: 0xBEEF },
        |r| {
            let n = r.below(500) as i32; // may be 0
            let block = 1 + r.below(128) as u32;
            (n, block)
        },
        |&(n, block)| {
            let mut m = Module::new("p");
            let mut b = FunctionBuilder::new("k", &[Type::I64], None).kernel();
            let out = b.param(0);
            irlib::emit_spmd_prologue(&mut b);
            let (lb, ub) =
                omprt::benchmarks::common::emit_static_range(&mut b, Operand::i32(0), Operand::i32(n));
            b.for_range(lb, ub, Operand::i32(1), |b, i| {
                let a = b.index(out, i, 4);
                b.call("__kmpc_atomic_add", &[a.into(), Operand::i32(1)], Type::I32);
            });
            irlib::emit_spmd_epilogue(&mut b);
            b.ret();
            m.add_func(b.build());

            let image = c.prepare(m, OptLevel::O2).map_err(|e| e.to_string())?;
            let mut env = DataEnv::new(&c.device);
            let mut out = vec![0u32; (n as usize).max(1)];
            let d = env.map(&out, MapType::Tofrom).map_err(|e| e.to_string())?;
            c.device
                .offload(&image, "k", &[d], LaunchConfig::new(1, block))
                .map_err(|e| e.to_string())?;
            env.unmap(&mut out).map_err(|e| e.to_string())?;
            if out[..n as usize].iter().all(|&v| v == 1) {
                Ok(())
            } else {
                Err(format!("partition broken for n={n} block={block}: {out:?}"))
            }
        },
    );
}

/// Atomic equivalence: the OpenMP-5.1-constructed atomics and direct
/// device atomics produce identical final states for random op sequences.
#[test]
fn prop_omp_atomics_equal_intrinsic_atomics() {
    // Use one coordinator per runtime; drive identical op sequences.
    let legacy = Coordinator::new(RuntimeKind::Legacy, Arch::Nvptx64);
    let portable = Coordinator::new(RuntimeKind::Portable, Arch::Nvptx64);
    forall(
        Config { cases: 8, seed: 0xA70 },
        |r| {
            // sequence of (op, operand) pairs baked into the kernel
            let ops: Vec<(u8, i32)> = (0..8)
                .map(|_| (r.below(4) as u8, r.below(100) as i32))
                .collect();
            ops
        },
        |ops| {
            let build = |m_name: &str| {
                let mut m = Module::new(m_name.to_string());
                let mut b = FunctionBuilder::new("k", &[Type::I64], None).kernel();
                let out = b.param(0);
                irlib::emit_spmd_prologue(&mut b);
                let tid = b.call("gpu.tid.x", &[], Type::I32);
                let is0 = b.cmp(CmpPred::Eq, tid, Operand::i32(0));
                b.if_(is0, |b| {
                    for &(op, v) in ops {
                        match op {
                            0 => {
                                b.call(
                                    "__kmpc_atomic_add",
                                    &[out.into(), Operand::i32(v)],
                                    Type::I32,
                                );
                            }
                            1 => {
                                b.call(
                                    "__kmpc_atomic_max",
                                    &[out.into(), Operand::i32(v)],
                                    Type::I32,
                                );
                            }
                            2 => {
                                b.call(
                                    "__kmpc_atomic_exchange",
                                    &[out.into(), Operand::i32(v)],
                                    Type::I32,
                                );
                            }
                            _ => {
                                b.call(
                                    "__kmpc_atomic_inc",
                                    &[out.into(), Operand::i32(v.max(1))],
                                    Type::I32,
                                );
                            }
                        }
                    }
                });
                irlib::emit_spmd_epilogue(&mut b);
                b.ret();
                m.add_func(b.build());
                m
            };
            let run = |c: &Coordinator| -> Result<u32, String> {
                let image = c.prepare(build("p"), OptLevel::O2).map_err(|e| e.to_string())?;
                let mut env = DataEnv::new(&c.device);
                let mut out = vec![0u32; 1];
                let d = env.map(&out, MapType::Tofrom).map_err(|e| e.to_string())?;
                c.device
                    .offload(&image, "k", &[d], LaunchConfig::new(1, 32))
                    .map_err(|e| e.to_string())?;
                env.unmap(&mut out).map_err(|e| e.to_string())?;
                Ok(out[0])
            };
            let a = run(&legacy)?;
            let b = run(&portable)?;
            if a == b {
                Ok(())
            } else {
                Err(format!("legacy={a} portable={b}"))
            }
        },
    );
}

/// Scheduling-queue invariants under random op sequences, driven
/// through the `QueueTestHarness` over the pool's internal
/// weighted-DRR/EDF queue:
///
/// * the deficit floor holds: no lane's deficit ever drops below −8
///   (bounded borrowing, whatever mix of coalescing and preemption);
/// * pinned jobs are invisible to DRR/EDF pops (asserted inside the
///   harness on every pop) and claimable only via `pop_pinned` on the
///   right device;
/// * the panic streak never exceeds `PANIC_STREAK_MAX`;
/// * lane compaction never drops jobs: pushes − pops == len, exactly,
///   at every step — even with hundreds of one-off client tags forcing
///   compaction;
/// * hedge duplicates obey the same accounting (a hedge push is one
///   queue entry, pinned, so only `pop_pinned` on its device sees it)
///   and every winner latch settles exactly once however the settle
///   ops interleave.
#[test]
fn prop_sched_queue_invariants_under_random_ops() {
    use omprt::sched::pool::QueueTestHarness;

    forall(
        Config { cases: 24, seed: 0xC4A05 },
        |r| {
            // An op sequence: (op selector, client selector, device/pin
            // selector, deadline flag) tuples.
            let ops: Vec<(u8, u8, u8, bool)> = (0..200)
                .map(|_| {
                    (
                        r.below(12) as u8,
                        r.below(12) as u8,
                        r.below(3) as u8,
                        r.below(4) == 0,
                    )
                })
                .collect();
            let weighted = r.below(2) == 0;
            (ops, weighted)
        },
        |(ops, weighted)| {
            let weights: Vec<(String, f64)> = if *weighted {
                vec![("a".to_string(), 3.0), ("b".to_string(), 0.5)]
            } else {
                vec![]
            };
            let mut q = QueueTestHarness::new(true, &weights);
            let mut pushed = 0usize;
            let mut popped = 0usize;
            let mut oneoff = 0usize;
            let mut hedges: Vec<usize> = vec![];
            let mut settled = 0usize;
            for (i, &(op, client_sel, dev, deadline)) in ops.iter().enumerate() {
                match op {
                    // 0-5: push. Client 0-2 from a small stable set;
                    // selector 3+ mints one-off tags to force lane
                    // compaction. Occasionally pinned, occasionally
                    // already past its deadline (panic-eligible).
                    0..=5 => {
                        let name;
                        let client = match client_sel {
                            0 => "a",
                            1 => "b",
                            2 => "c",
                            _ => {
                                oneoff += 1;
                                name = format!("oneoff{oneoff}-{i}");
                                name.as_str()
                            }
                        };
                        let pin = (op == 5).then_some(dev as usize);
                        q.push(client, pin, deadline);
                        pushed += 1;
                    }
                    // 6-8: a DRR/EDF pop for a random device with a
                    // random batch limit.
                    6..=8 => {
                        if let Some((_, _, batch)) = q.pop(dev as usize, 1 + (op - 6) as usize * 3)
                        {
                            popped += batch;
                        }
                    }
                    // 9: claim a pinned job.
                    9 => {
                        if q.pop_pinned(dev as usize) {
                            popped += 1;
                        }
                    }
                    // 10: enqueue a hedge duplicate pinned to `dev` —
                    // one queue entry like any other push, but invisible
                    // to the DRR/EDF pops above.
                    10 => {
                        hedges.push(q.push_hedge("a", dev as usize));
                        pushed += 1;
                    }
                    // 11: race a settle against whatever already
                    // happened to that latch; `settle` may only win the
                    // first time for any given hedge.
                    _ => {
                        if !hedges.is_empty() {
                            let idx = hedges[client_sel as usize % hedges.len()];
                            if q.settle(idx) {
                                settled += 1;
                            }
                        }
                    }
                }
                // Invariants hold after *every* op.
                if q.len() != pushed - popped {
                    return Err(format!(
                        "op {i}: accounting broke: len {} != pushed {pushed} - popped {popped}",
                        q.len()
                    ));
                }
                if q.min_deficit() < QueueTestHarness::deficit_floor() - 1e-9 {
                    return Err(format!(
                        "op {i}: deficit floor violated: {}",
                        q.min_deficit()
                    ));
                }
                if q.panic_streak() > QueueTestHarness::panic_streak_max() {
                    return Err(format!(
                        "op {i}: panic streak {} exceeds the bound",
                        q.panic_streak()
                    ));
                }
            }
            // Drain completely: every job pushed must come back out —
            // compaction may have dropped empty lanes, never jobs.
            for dev in 0..3usize {
                while q.pop_pinned(dev) {
                    popped += 1;
                }
            }
            loop {
                let mut progress = false;
                for dev in 0..3usize {
                    if let Some((_, _, batch)) = q.pop(dev, 4) {
                        popped += batch;
                        progress = true;
                    }
                }
                if !progress {
                    break;
                }
            }
            if popped != pushed || !q.is_empty() {
                return Err(format!(
                    "drain incomplete: pushed {pushed}, popped {popped}, {} left",
                    q.len()
                ));
            }
            // The one-off tags must not have grown the lane table
            // without bound (compaction reclaims drained lanes).
            if q.lane_count() > 130 {
                return Err(format!("{} lanes survived compaction", q.lane_count()));
            }
            // Exactly-once settling: after force-settling every hedge
            // latch, each must have yielded `true` exactly once across
            // the whole run, however the random settles interleaved.
            if q.latch_count() != hedges.len() {
                return Err(format!(
                    "latch count {} != {} hedge pushes",
                    q.latch_count(),
                    hedges.len()
                ));
            }
            let mut total = settled;
            for &idx in &hedges {
                if q.settle(idx) {
                    total += 1;
                }
            }
            if total != hedges.len() {
                return Err(format!(
                    "settle accounting broke: {total} wins over {} latches",
                    hedges.len()
                ));
            }
            Ok(())
        },
    );
}

/// Data-environment invariant: map/unmap with random refcounts never
/// leaks mappings and roundtrips data.
#[test]
fn prop_data_env_refcounts_balance() {
    let dev = omprt::hostrt::OffloadDevice::new(RuntimeKind::Portable, Arch::Nvptx64);
    forall(
        Config { cases: 30, seed: 0xDA7A },
        |r| (1 + r.below(40) as usize, 1 + r.below(4) as u32),
        |&(len, refs)| {
            let mut env = DataEnv::new(&dev);
            let mut host: Vec<f32> = (0..len).map(|i| i as f32).collect();
            let mut addr = None;
            for _ in 0..refs {
                let a = env.map(&host, MapType::Tofrom).map_err(|e| e.to_string())?;
                if let Some(prev) = addr {
                    if prev != a {
                        return Err("address changed across remap".into());
                    }
                }
                addr = Some(a);
            }
            for i in 0..refs {
                env.unmap(&mut host).map_err(|e| e.to_string())?;
                let expect_live = i + 1 < refs;
                if (env.live_mappings() > 0) != expect_live {
                    return Err(format!("live={} after {} unmaps", env.live_mappings(), i + 1));
                }
            }
            for (i, v) in host.iter().enumerate() {
                if *v != i as f32 {
                    return Err(format!("data corrupted at {i}"));
                }
            }
            Ok(())
        },
    );
}
