//! Constant folding + single-def constant propagation.
//!
//! Registers are mutable cells, so full SCCP is out of scope; instead we
//! (1) fold any instruction whose operands are all constants, and
//! (2) propagate constants from registers that are assigned exactly once
//! in the whole function. Combined with the inliner this is enough to
//! specialize the runtime library's argument-dependent paths — the
//! paper's "specializing a generic runtime" effect.

use crate::ir::inst::{BinOp, CastOp, CmpPred, Inst, Stmt, UnOp};
use crate::ir::module::{Function, Module};
use crate::ir::types::{Const, Operand, Reg, Type};
use std::collections::HashMap;

/// Run over every function; returns instructions folded/propagated.
pub fn run(m: &mut Module) -> usize {
    let mut n = 0;
    for f in m.funcs.values_mut() {
        n += run_function(f);
    }
    n
}

fn run_function(f: &mut Function) -> usize {
    let mut folded = 0;

    // Pass 1: fold all-const instructions into Copy-of-const.
    for s in &mut f.body {
        s.visit_insts_mut(&mut |i| {
            if let Some(c) = eval_inst(i) {
                if !matches!(i, Inst::Copy { src: Operand::Const(_), .. }) {
                    let dst = i.dst().expect("foldable inst has dst");
                    *i = Inst::Copy { dst, src: Operand::Const(c) };
                    folded += 1;
                }
            }
        });
    }

    // Pass 2: single-def constant propagation.
    let mut def_count: HashMap<Reg, usize> = HashMap::new();
    let mut const_def: HashMap<Reg, Const> = HashMap::new();
    for s in &f.body {
        s.visit_insts(&mut |i| {
            if let Some(d) = i.dst() {
                *def_count.entry(d).or_insert(0) += 1;
                if let Inst::Copy { src: Operand::Const(c), .. } = i {
                    const_def.insert(d, *c);
                }
            }
        });
    }
    let prop: HashMap<Reg, Const> = const_def
        .into_iter()
        .filter(|(r, _)| def_count.get(r) == Some(&1))
        .collect();
    if !prop.is_empty() {
        for s in &mut f.body {
            propagate_stmt(s, &prop, &mut folded);
        }
    }

    // Pass 3: If with constant condition → splice the taken arm.
    let body = std::mem::take(&mut f.body);
    f.body = fold_branches(body, &mut folded);

    folded
}

fn propagate_stmt(s: &mut Stmt, prop: &HashMap<Reg, Const>, folded: &mut usize) {
    let subst = |o: &mut Operand, folded: &mut usize| {
        if let Operand::Reg(r) = o {
            if let Some(c) = prop.get(r) {
                *o = Operand::Const(*c);
                *folded += 1;
            }
        }
    };
    match s {
        Stmt::Inst(i) => {
            // Do not rewrite the dst-defining Copy itself into a self-copy.
            i.map_operands(|o| subst(o, folded));
        }
        Stmt::If { cond, then_, else_ } => {
            subst(cond, folded);
            for t in then_ {
                propagate_stmt(t, prop, folded);
            }
            for e in else_ {
                propagate_stmt(e, prop, folded);
            }
        }
        Stmt::Loop { body } => {
            for b in body {
                propagate_stmt(b, prop, folded);
            }
        }
        Stmt::Return(Some(v)) => subst(v, folded),
        _ => {}
    }
}

fn fold_branches(body: Vec<Stmt>, folded: &mut usize) -> Vec<Stmt> {
    let mut out = Vec::with_capacity(body.len());
    for s in body {
        match s {
            Stmt::If { cond: Operand::Const(Const::I1(c)), then_, else_ } => {
                *folded += 1;
                let taken = if c { then_ } else { else_ };
                out.extend(fold_branches(taken, folded));
            }
            Stmt::If { cond, then_, else_ } => {
                let t = fold_branches(then_, folded);
                let e = fold_branches(else_, folded);
                out.push(Stmt::If { cond, then_: t, else_: e });
            }
            Stmt::Loop { body } => {
                let b = fold_branches(body, folded);
                out.push(Stmt::Loop { body: b });
            }
            other => out.push(other),
        }
    }
    out
}

/// Evaluate an instruction whose operands are all constants.
/// Shared with tests that cross-check the SIMT interpreter's scalar ALU.
pub fn eval_inst(i: &Inst) -> Option<Const> {
    match i {
        Inst::Bin { op, a: Operand::Const(a), b: Operand::Const(b), .. } => eval_bin(*op, *a, *b),
        Inst::Un { op, a: Operand::Const(a), .. } => eval_un(*op, *a),
        Inst::Cmp { pred, a: Operand::Const(a), b: Operand::Const(b), .. } => {
            eval_cmp(*pred, *a, *b).map(Const::I1)
        }
        Inst::Select {
            cond: Operand::Const(Const::I1(c)),
            a: Operand::Const(a),
            b: Operand::Const(b),
            ..
        } => Some(if *c { *a } else { *b }),
        Inst::Cast { op, src: Operand::Const(s), dst } => {
            let _ = dst;
            eval_cast(*op, *s, cast_target_ty(i)?)
        }
        Inst::Copy { src: Operand::Const(c), .. } => Some(*c),
        _ => None,
    }
}

/// The cast target type is the dst register's type — but passes don't see
/// the register table here, so casts carry enough info only when the
/// target is deducible. We conservatively only fold casts where the
/// operation implies the target.
fn cast_target_ty(i: &Inst) -> Option<Type> {
    if let Inst::Cast { op, src: Operand::Const(s), .. } = i {
        Some(match (op, s.ty()) {
            (CastOp::SExt, Type::I32) | (CastOp::ZExt, Type::I32) => Type::I64,
            (CastOp::SExt, Type::I1) | (CastOp::ZExt, Type::I1) => Type::I32,
            (CastOp::Trunc, Type::I64) => Type::I32,
            (CastOp::SIToFP, _) => Type::F64, // ambiguous — skip f32 targets
            (CastOp::FPExt, Type::F32) => Type::F64,
            (CastOp::FPTrunc, Type::F64) => Type::F32,
            _ => return None,
        })
    } else {
        None
    }
}

/// Constant binary evaluation.
pub fn eval_bin(op: BinOp, a: Const, b: Const) -> Option<Const> {
    use BinOp::*;
    use Const as C;
    Some(match (a, b) {
        (C::I32(x), C::I32(y)) => C::I32(match op {
            Add => x.wrapping_add(y),
            Sub => x.wrapping_sub(y),
            Mul => x.wrapping_mul(y),
            SDiv => {
                if y == 0 {
                    return None;
                }
                x.wrapping_div(y)
            }
            UDiv => {
                if y == 0 {
                    return None;
                }
                ((x as u32) / (y as u32)) as i32
            }
            SRem => {
                if y == 0 {
                    return None;
                }
                x.wrapping_rem(y)
            }
            URem => {
                if y == 0 {
                    return None;
                }
                ((x as u32) % (y as u32)) as i32
            }
            And => x & y,
            Or => x | y,
            Xor => x ^ y,
            Shl => x.wrapping_shl(y as u32),
            LShr => ((x as u32).wrapping_shr(y as u32)) as i32,
            AShr => x.wrapping_shr(y as u32),
            SMin => x.min(y),
            SMax => x.max(y),
            UMin => ((x as u32).min(y as u32)) as i32,
            UMax => ((x as u32).max(y as u32)) as i32,
            FDiv | FMin | FMax => return None,
        }),
        (C::I64(x), C::I64(y)) => C::I64(match op {
            Add => x.wrapping_add(y),
            Sub => x.wrapping_sub(y),
            Mul => x.wrapping_mul(y),
            SDiv => {
                if y == 0 {
                    return None;
                }
                x.wrapping_div(y)
            }
            UDiv => {
                if y == 0 {
                    return None;
                }
                ((x as u64) / (y as u64)) as i64
            }
            SRem => {
                if y == 0 {
                    return None;
                }
                x.wrapping_rem(y)
            }
            URem => {
                if y == 0 {
                    return None;
                }
                ((x as u64) % (y as u64)) as i64
            }
            And => x & y,
            Or => x | y,
            Xor => x ^ y,
            Shl => x.wrapping_shl(y as u32),
            LShr => ((x as u64).wrapping_shr(y as u32)) as i64,
            AShr => x.wrapping_shr(y as u32),
            SMin => x.min(y),
            SMax => x.max(y),
            UMin => ((x as u64).min(y as u64)) as i64,
            UMax => ((x as u64).max(y as u64)) as i64,
            FDiv | FMin | FMax => return None,
        }),
        (C::F32(x), C::F32(y)) => C::F32(match op {
            Add => x + y,
            Sub => x - y,
            Mul => x * y,
            FDiv => x / y,
            FMin => x.min(y),
            FMax => x.max(y),
            _ => return None,
        }),
        (C::F64(x), C::F64(y)) => C::F64(match op {
            Add => x + y,
            Sub => x - y,
            Mul => x * y,
            FDiv => x / y,
            FMin => x.min(y),
            FMax => x.max(y),
            _ => return None,
        }),
        (C::I1(x), C::I1(y)) => C::I1(match op {
            And => x & y,
            Or => x | y,
            Xor => x ^ y,
            _ => return None,
        }),
        _ => return None,
    })
}

/// Constant unary evaluation.
pub fn eval_un(op: UnOp, a: Const) -> Option<Const> {
    use Const as C;
    use UnOp::*;
    Some(match a {
        C::I32(x) => match op {
            Neg => C::I32(x.wrapping_neg()),
            Not => C::I32(!x),
            _ => return None,
        },
        C::I64(x) => match op {
            Neg => C::I64(x.wrapping_neg()),
            Not => C::I64(!x),
            _ => return None,
        },
        C::F32(x) => C::F32(match op {
            Neg => -x,
            FAbs => x.abs(),
            FSqrt => x.sqrt(),
            FExp => x.exp(),
            FLog => x.ln(),
            FSin => x.sin(),
            FCos => x.cos(),
            FFloor => x.floor(),
            FRcp => 1.0 / x,
            Not => return None,
        }),
        C::F64(x) => C::F64(match op {
            Neg => -x,
            FAbs => x.abs(),
            FSqrt => x.sqrt(),
            FExp => x.exp(),
            FLog => x.ln(),
            FSin => x.sin(),
            FCos => x.cos(),
            FFloor => x.floor(),
            FRcp => 1.0 / x,
            Not => return None,
        }),
        C::I1(x) => match op {
            Not => C::I1(!x),
            _ => return None,
        },
    })
}

/// Constant comparison evaluation.
pub fn eval_cmp(pred: CmpPred, a: Const, b: Const) -> Option<bool> {
    use CmpPred::*;
    use Const as C;
    match (a, b) {
        (C::I32(x), C::I32(y)) => Some(match pred {
            Eq => x == y,
            Ne => x != y,
            Lt => x < y,
            Le => x <= y,
            Gt => x > y,
            Ge => x >= y,
            ULt => (x as u32) < (y as u32),
            ULe => (x as u32) <= (y as u32),
            UGt => (x as u32) > (y as u32),
            UGe => (x as u32) >= (y as u32),
        }),
        (C::I64(x), C::I64(y)) => Some(match pred {
            Eq => x == y,
            Ne => x != y,
            Lt => x < y,
            Le => x <= y,
            Gt => x > y,
            Ge => x >= y,
            ULt => (x as u64) < (y as u64),
            ULe => (x as u64) <= (y as u64),
            UGt => (x as u64) > (y as u64),
            UGe => (x as u64) >= (y as u64),
        }),
        (C::F32(x), C::F32(y)) => Some(match pred {
            Eq => x == y,
            Ne => x != y,
            Lt => x < y,
            Le => x <= y,
            Gt => x > y,
            Ge => x >= y,
            _ => return None,
        }),
        (C::F64(x), C::F64(y)) => Some(match pred {
            Eq => x == y,
            Ne => x != y,
            Lt => x < y,
            Le => x <= y,
            Gt => x > y,
            Ge => x >= y,
            _ => return None,
        }),
        (C::I1(x), C::I1(y)) => Some(match pred {
            Eq => x == y,
            Ne => x != y,
            _ => return None,
        }),
        _ => None,
    }
}

/// Constant cast evaluation.
pub fn eval_cast(op: CastOp, s: Const, to: Type) -> Option<Const> {
    use CastOp::*;
    use Const as C;
    Some(match (op, s, to) {
        (SExt, C::I32(x), Type::I64) => C::I64(x as i64),
        (ZExt, C::I32(x), Type::I64) => C::I64(x as u32 as i64),
        (SExt, C::I1(x), Type::I32) | (ZExt, C::I1(x), Type::I32) => C::I32(x as i32),
        (Trunc, C::I64(x), Type::I32) => C::I32(x as i32),
        (SIToFP, C::I32(x), Type::F64) => C::F64(x as f64),
        (SIToFP, C::I64(x), Type::F64) => C::F64(x as f64),
        (FPExt, C::F32(x), Type::F64) => C::F64(x as f64),
        (FPTrunc, C::F64(x), Type::F32) => C::F32(x as f32),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::FunctionBuilder;
    use crate::ir::printer::print_function;
    use crate::ir::verify::verify_module;

    #[test]
    fn folds_constant_arithmetic() {
        let mut m = Module::new("t");
        let mut f = FunctionBuilder::new("f", &[], Some(Type::I32));
        let a = f.add(Operand::i32(40), Operand::i32(2));
        f.ret_val(a);
        m.add_func(f.build());
        let n = run(&mut m);
        assert!(n >= 1);
        verify_module(&m).unwrap();
        let text = print_function(&m.funcs["f"]);
        assert!(text.contains("42"), "{text}");
    }

    #[test]
    fn division_by_zero_is_not_folded() {
        let mut m = Module::new("t");
        let mut f = FunctionBuilder::new("f", &[], Some(Type::I32));
        let a = f.sdiv(Operand::i32(1), Operand::i32(0));
        f.ret_val(a);
        m.add_func(f.build());
        run(&mut m);
        let text = print_function(&m.funcs["f"]);
        assert!(text.contains("sdiv"), "div-by-zero must stay a runtime trap: {text}");
    }

    #[test]
    fn const_branch_is_spliced() {
        let mut m = Module::new("t");
        let mut f = FunctionBuilder::new("f", &[], Some(Type::I32));
        f.if_else(
            Operand::bool(true),
            |b| b.ret_val(Operand::i32(1)),
            |b| b.ret_val(Operand::i32(2)),
        );
        m.add_func(f.build());
        run(&mut m);
        let text = print_function(&m.funcs["f"]);
        assert!(!text.contains("if"), "{text}");
        assert!(text.contains("return 1"), "{text}");
    }

    #[test]
    fn multiply_assigned_reg_is_not_propagated() {
        let mut m = Module::new("t");
        let mut f = FunctionBuilder::new("f", &[Type::I1], Some(Type::I32));
        let p = f.param(0);
        let v = f.copy(Operand::i32(1));
        f.if_(p, |b| b.assign(v, Operand::i32(2)));
        f.ret_val(v);
        m.add_func(f.build());
        run(&mut m);
        let text = print_function(&m.funcs["f"]);
        // v is assigned twice; the return must still read the register.
        assert!(text.contains("return %r"), "{text}");
    }

    #[test]
    fn eval_bin_wrapping_and_unsigned() {
        assert_eq!(eval_bin(BinOp::Add, Const::I32(i32::MAX), Const::I32(1)), Some(Const::I32(i32::MIN)));
        assert_eq!(eval_bin(BinOp::UDiv, Const::I32(-2), Const::I32(2)), Some(Const::I32(0x7FFF_FFFF)));
        assert_eq!(eval_bin(BinOp::UMax, Const::I32(-1), Const::I32(1)), Some(Const::I32(-1)));
    }

    #[test]
    fn eval_cmp_signed_vs_unsigned() {
        assert_eq!(eval_cmp(CmpPred::Lt, Const::I32(-1), Const::I32(1)), Some(true));
        assert_eq!(eval_cmp(CmpPred::ULt, Const::I32(-1), Const::I32(1)), Some(false));
    }
}
