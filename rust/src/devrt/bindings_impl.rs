//! Implementation of the control-heavy device-runtime entry points.
//!
//! This is the runtime's **common part** (paper §3.1): target-independent
//! logic written once. The portable build binds these functions directly;
//! the legacy build instantiates one macro-generated copy per target
//! (see [`super::legacy`]), mirroring how the original CUDA/HIP runtime
//! compiled the same source once per vendor.
//!
//! All functions have the runtime-binding signature
//! `fn(&CallEnv, &[Vec<u64>], mask) -> Result<Option<Vec<u64>>>` and are
//! invoked once per *warp* reaching the call site.

use super::state::{self, role, MODE_GENERIC, MODE_SPMD};
use crate::sim::interp::{lanes, CallEnv};
use crate::util::Error;

/// First active lane of a mask.
#[inline]
fn first_lane(mask: u64) -> u32 {
    mask.trailing_zeros()
}

/// Uniform (lane-0-of-mask) value of an argument.
#[inline]
fn uarg(args: &[Vec<u64>], i: usize, mask: u64) -> u64 {
    args[i][first_lane(mask) as usize]
}

/// `__kmpc_target_init(mode)` → per-lane role.
///
/// Warp 0 initializes the team state; a block barrier then publishes it.
/// Roles (paper ref. [8], warp specialization): in SPMD mode every thread
/// proceeds; in generic mode thread 0 is the main thread, the rest of its
/// warp exits, and all other warps become workers.
pub fn target_init(env: &CallEnv, args: &[Vec<u64>], mask: u64) -> Result<Option<Vec<u64>>, Error> {
    let mode = uarg(args, 0, mask) as u32;
    let width = env.width();
    if env.warp_id == 0 {
        let s = env.smem;
        s.write_bits(state::EXEC_MODE, 4, mode as u64)?;
        s.write_bits(state::TERMINATE, 4, 0)?;
        s.write_bits(state::PARALLEL_FN, 8, 0)?;
        s.write_bits(state::PARALLEL_ARG, 8, 0)?;
        s.write_bits(state::PARALLEL_LEVEL, 4, 0)?;
        let avail = if mode == MODE_SPMD {
            env.block_dim
        } else {
            // main thread + the full worker warps (warp 0's other lanes idle)
            1 + env.block_dim.saturating_sub(width)
        };
        s.write_bits(state::NUM_THREADS, 4, avail as u64)?;
        s.write_bits(state::AVAIL_THREADS, 4, avail as u64)?;
        // Reduction scratch: 8 B per thread at the arena base; the
        // alloc_shared stack begins after it, 16-aligned.
        let arena = env.module.shared_arena_base;
        let reduce_buf = arena.next_multiple_of(8);
        let stack = (reduce_buf + 8 * env.block_dim as u64).next_multiple_of(16);
        s.write_bits(state::REDUCE_BUF, 8, reduce_buf)?;
        s.write_bits(state::STACK_PTR, 8, stack)?;
        s.write_bits(state::STACK_BASE, 8, stack)?;
    }
    env.barrier.wait()?;
    let mut roles = vec![role::EXIT; width as usize];
    for lane in lanes(mask, width) {
        let tid = env.tid(lane);
        roles[lane as usize] = if mode == MODE_SPMD {
            role::MAIN
        } else if tid == 0 {
            role::MAIN
        } else if env.warp_id == 0 {
            role::EXIT
        } else {
            role::WORKER
        };
    }
    Ok(Some(roles))
}

/// `__kmpc_target_deinit()` — generic mode: the main thread releases the
/// workers from the state machine. SPMD mode: no-op.
pub fn target_deinit(env: &CallEnv, _args: &[Vec<u64>], _mask: u64) -> Result<Option<Vec<u64>>, Error> {
    let mode = env.smem.read_bits(state::EXEC_MODE, 4)? as u32;
    if mode == MODE_GENERIC {
        env.smem.atomic_store_u32(state::TERMINATE, 1)?;
        env.barrier.wait()?;
    }
    Ok(None)
}

/// `__kmpc_parallel_begin(fn_id, arg, num_threads)` — main thread only:
/// publish the outlined region and release the workers (their barrier A).
pub fn parallel_begin(env: &CallEnv, args: &[Vec<u64>], mask: u64) -> Result<Option<Vec<u64>>, Error> {
    let fn_id = uarg(args, 0, mask);
    let arg = uarg(args, 1, mask);
    let req = uarg(args, 2, mask) as u32;
    let s = env.smem;
    let avail = s.read_bits(state::AVAIL_THREADS, 4)? as u32;
    let n = if req == 0 { avail } else { req.min(avail) };
    s.write_bits(state::NUM_THREADS, 4, n as u64)?;
    s.write_bits(state::PARALLEL_ARG, 8, arg)?;
    s.write_bits(state::PARALLEL_LEVEL, 4, 1)?;
    // +1 so that id 0 is distinguishable from "no region".
    s.write_bits(state::PARALLEL_FN, 8, fn_id + 1)?;
    env.barrier.wait()?; // workers' barrier A
    Ok(None)
}

/// `__kmpc_parallel_end()` — main thread only: join the workers
/// (barrier B) and clear the descriptor.
pub fn parallel_end(env: &CallEnv, _args: &[Vec<u64>], _mask: u64) -> Result<Option<Vec<u64>>, Error> {
    env.barrier.wait()?; // workers' barrier B
    let s = env.smem;
    s.write_bits(state::PARALLEL_FN, 8, 0)?;
    let avail = s.read_bits(state::AVAIL_THREADS, 4)?;
    s.write_bits(state::NUM_THREADS, 4, avail)?;
    s.write_bits(state::PARALLEL_LEVEL, 4, 0)?;
    Ok(None)
}

/// `__kmpc_barrier` — block-wide barrier. Requires full-team
/// participation (all live warps), as on hardware.
pub fn barrier(env: &CallEnv, _args: &[Vec<u64>], _mask: u64) -> Result<Option<Vec<u64>>, Error> {
    env.barrier.wait()?;
    Ok(None)
}

/// `__kmpc_for_static_init_4(omp_tid, sched, lower, upper, chunk)` →
/// per-lane packed `[lb, ub)`.
///
/// `sched = SCHED_STATIC`: iterations are split into `nthreads` nearly
/// equal contiguous blocks (remainder spread over the first threads).
/// `sched = SCHED_STATIC_CHUNKED`: thread's **first** chunk is returned;
/// the kernel strides by `nthreads · chunk`.
pub fn for_static_init(env: &CallEnv, args: &[Vec<u64>], mask: u64) -> Result<Option<Vec<u64>>, Error> {
    let width = env.width();
    let sched = uarg(args, 1, mask) as u32;
    let lower = uarg(args, 2, mask) as u32;
    let upper = uarg(args, 3, mask) as u32;
    let chunk = (uarg(args, 4, mask) as u32).max(1);
    let n = (env.smem.read_bits(state::NUM_THREADS, 4)? as u32).max(1);
    let total = upper.saturating_sub(lower);
    let mut out = vec![0u64; width as usize];
    for lane in lanes(mask, width) {
        let tid = args[0][lane as usize] as u32;
        let (lb, ub) = match sched {
            state::SCHED_STATIC_CHUNKED => {
                let lb = lower.saturating_add(tid.saturating_mul(chunk));
                (lb.min(upper), lb.saturating_add(chunk).min(upper))
            }
            _ => {
                // Plain static: block partition.
                let base = total / n;
                let rem = total % n;
                let (start, len) = if tid < rem {
                    (tid * (base + 1), base + 1)
                } else {
                    (rem * (base + 1) + (tid - rem) * base, base)
                };
                let lb = lower + start.min(total);
                (lb, lb + len.min(total - start.min(total)))
            }
        };
        out[lane as usize] = state::pack_range(lb, ub);
    }
    Ok(Some(out))
}

/// `__kmpc_dispatch_init_4(lower, upper, chunk, sched)`.
///
/// Must be called by **all** team threads (it contains a team barrier so
/// the shared descriptor is published before anyone fetches).
pub fn dispatch_init(env: &CallEnv, args: &[Vec<u64>], mask: u64) -> Result<Option<Vec<u64>>, Error> {
    if env.warp_id == 0 {
        let s = env.smem;
        s.write_bits(state::DISPATCH_NEXT, 8, uarg(args, 0, mask))?;
        s.write_bits(state::DISPATCH_END, 8, uarg(args, 1, mask))?;
        s.write_bits(state::DISPATCH_CHUNK, 8, uarg(args, 2, mask).max(1))?;
        s.write_bits(state::DISPATCH_SCHED, 4, uarg(args, 3, mask))?;
    }
    env.barrier.wait()?;
    Ok(None)
}

/// `__kmpc_dispatch_next_4()` → per-lane packed `[start, end)` chunk, or
/// [`state::DISPATCH_DONE`] when the iteration space is exhausted.
pub fn dispatch_next(env: &CallEnv, _args: &[Vec<u64>], mask: u64) -> Result<Option<Vec<u64>>, Error> {
    let width = env.width();
    let s = env.smem;
    let end = s.read_bits(state::DISPATCH_END, 8)?;
    let chunk = s.read_bits(state::DISPATCH_CHUNK, 8)?.max(1);
    let sched = s.read_bits(state::DISPATCH_SCHED, 4)? as u32;
    let n = (s.read_bits(state::NUM_THREADS, 4)? as u64).max(1);
    let mut out = vec![state::DISPATCH_DONE; width as usize];
    for lane in lanes(mask, width) {
        let claimed = match sched {
            state::SCHED_GUIDED => {
                // size = max(remaining / 2n, chunk), claimed via CAS.
                loop {
                    let cur = s.read_bits(state::DISPATCH_NEXT, 8)?;
                    if cur >= end {
                        break None;
                    }
                    let remaining = end - cur;
                    let size = (remaining / (2 * n)).max(chunk).min(remaining);
                    let got = s.atomic_cas_u64(state::DISPATCH_NEXT, cur, cur + size)?;
                    if got == cur {
                        break Some((cur, cur + size));
                    }
                }
            }
            _ => {
                // Dynamic: unconditional fetch-add; overshoot is harmless.
                let start = s.atomic_add_u64(state::DISPATCH_NEXT, chunk)?;
                if start >= end {
                    None
                } else {
                    Some((start, (start + chunk).min(end)))
                }
            }
        };
        out[lane as usize] = match claimed {
            Some((a, b)) => state::pack_range(a as u32, b as u32),
            None => state::DISPATCH_DONE,
        };
    }
    Ok(Some(out))
}

/// `__kmpc_dispatch_fini_4()` — join barrier after a dispatch loop.
pub fn dispatch_fini(env: &CallEnv, _args: &[Vec<u64>], _mask: u64) -> Result<Option<Vec<u64>>, Error> {
    env.barrier.wait()?;
    Ok(None)
}

/// `__kmpc_alloc_shared(bytes)` → team-shared address (uniform).
///
/// A bump allocator over the shared arena; 16-byte aligned like the real
/// runtime's `__kmpc_alloc_shared` stack.
pub fn alloc_shared(env: &CallEnv, args: &[Vec<u64>], mask: u64) -> Result<Option<Vec<u64>>, Error> {
    let bytes = uarg(args, 0, mask).next_multiple_of(16);
    let addr = env.smem.atomic_add_u64(state::STACK_PTR, bytes)?;
    if addr + bytes > env.smem.len() {
        return Err(Error::DevRt(format!(
            "__kmpc_alloc_shared: out of shared memory ({} of {} bytes used)",
            addr + bytes,
            env.smem.len()
        )));
    }
    Ok(Some(vec![addr; env.width() as usize]))
}

/// `__kmpc_free_shared(bytes)` — stack discipline: frees the most recent
/// allocation of that (rounded) size.
pub fn free_shared(env: &CallEnv, args: &[Vec<u64>], mask: u64) -> Result<Option<Vec<u64>>, Error> {
    let bytes = uarg(args, 0, mask).next_multiple_of(16);
    let base = env.smem.read_bits(state::STACK_BASE, 8)?;
    let cur = env.smem.read_bits(state::STACK_PTR, 8)?;
    if cur < base + bytes {
        return Err(Error::DevRt("__kmpc_free_shared underflow (free without alloc?)".into()));
    }
    // fetch_sub via wrapping add of two's complement
    env.smem.atomic_add_u64(state::STACK_PTR, (bytes as i64).wrapping_neg() as u64)?;
    Ok(None)
}

#[cfg(test)]
mod tests {
    use crate::sim::launch::{Bindings, LaunchConfig};
    use crate::sim::{launch_kernel, DeviceDesc, GlobalMemory, LoadedModule};

    // Note: full end-to-end exercises of these bindings live in the
    // portable/legacy runtime tests and the conformance suite; here we
    // unit-test the pure parts.

    #[test]
    fn static_partition_covers_iteration_space_exactly() {
        // Directly test the partition math through a tiny launch.
        // kernel: out[tid*2] = lb, out[tid*2+1] = ub for static_init(0..100)
        use crate::ir::{AddrSpace, FunctionBuilder, Operand, Type};
        let mut m = crate::ir::Module::new("t");
        let mut b = FunctionBuilder::new("k", &[Type::I64], None).kernel();
        let out = b.param(0);
        b.call("__kmpc_target_init", &[Operand::i32(0)], Type::I32);
        let tid = b.call("gpu.tid.x", &[], Type::I32);
        let packed = b.call(
            "__kmpc_for_static_init_4",
            &[tid.into(), Operand::i32(0), Operand::i32(0), Operand::i32(100), Operand::i32(1)],
            Type::I64,
        );
        let lb = b.cast(crate::ir::CastOp::Trunc, packed, Type::I32);
        let hi = b.bin(crate::ir::BinOp::LShr, packed, Operand::i64(32));
        let ub = b.cast(crate::ir::CastOp::Trunc, hi, Type::I32);
        let t2 = b.mul(tid, Operand::i32(2));
        let a0 = b.index(out, t2, 4);
        b.store(Type::I32, AddrSpace::Global, a0, lb);
        let t21 = b.add(t2, Operand::i32(1));
        let a1 = b.index(out, t21, 4);
        b.store(Type::I32, AddrSpace::Global, a1, ub);
        b.ret();
        m.add_func(b.build());

        let gmem = GlobalMemory::new(1 << 20);
        let lm = LoadedModule::load(m, &gmem).unwrap();
        let out_buf = gmem.alloc(7 * 2 * 4, 8).unwrap();
        let mut bindings = Bindings::new();
        super::super::portable::install_bindings(&mut bindings);
        launch_kernel(
            &DeviceDesc::nvptx64(),
            &lm,
            "k",
            &[out_buf],
            &gmem,
            &bindings,
            LaunchConfig::new(1, 7),
        )
        .unwrap();
        let mut bytes = vec![0u8; 7 * 2 * 4];
        gmem.read_bytes(out_buf, &mut bytes).unwrap();
        let vals: Vec<u32> = bytes
            .chunks(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        // ranges must tile [0,100) in tid order with sizes 15/14
        let mut covered = 0u32;
        for t in 0..7 {
            let (lb, ub) = (vals[t * 2], vals[t * 2 + 1]);
            assert_eq!(lb, covered, "thread {t}");
            assert!(ub >= lb);
            let len = ub - lb;
            assert!(len == 14 || len == 15, "thread {t} got {len}");
            covered = ub;
        }
        assert_eq!(covered, 100);
    }
}
