//! Rule `fmtargs`: format-argument arity for the `format!` /
//! `println!` / `write!` macro families.
//!
//! For every call with a *literal* format string, the number of
//! positional placeholders (implicit `{}`, explicit `{0}`, `width$` /
//! `.prec$` / `.*` spec arguments) must equal the number of positional
//! arguments supplied, and every `name = value` argument must be used
//! by some `{name…}` placeholder. Named placeholders without a matching
//! `name =` argument are fine — Rust 2021 captures them from scope, and
//! scope resolution is beyond a lexer. Dynamic format strings are out of
//! scope.

use crate::lint::lexer::{Tok, TokKind};
use crate::lint::{Finding, Manifests};

/// Macro name → index of its format-string argument. The format string
/// is optional for the `assert!`/`panic!` shapes: when the argument at
/// that index is not a string literal the call is skipped.
const FMT_MACROS: &[(&str, usize)] = &[
    ("format", 0),
    ("format_args", 0),
    ("print", 0),
    ("println", 0),
    ("eprint", 0),
    ("eprintln", 0),
    ("panic", 0),
    ("todo", 0),
    ("unimplemented", 0),
    ("unreachable", 0),
    // The vendored `log` shim forwards `format_args!`, so std arity
    // rules apply to the log macros too.
    ("error", 0),
    ("warn", 0),
    ("info", 0),
    ("debug", 0),
    ("trace", 0),
    ("write", 1),
    ("writeln", 1),
    ("assert", 1),
    ("debug_assert", 1),
    ("assert_eq", 2),
    ("assert_ne", 2),
    ("debug_assert_eq", 2),
    ("debug_assert_ne", 2),
];

fn is_open(s: &str) -> bool {
    matches!(s, "(" | "[" | "{")
}

fn matching_close(open: &str) -> &'static str {
    match open {
        "(" => ")",
        "[" => "]",
        _ => "}",
    }
}

/// Split the macro invocation opening at `toks[start]` into top-level
/// argument slices. Turbofish `::<…>` commas are not split points.
fn split_args<'t>(toks: &'t [Tok], start: usize) -> Vec<&'t [Tok]> {
    let close = matching_close(&toks[start].text);
    let (mut paren, mut bracket, mut brace, mut angle) = (0i32, 0i32, 0i32, 0i32);
    let mut args: Vec<&[Tok]> = Vec::new();
    let mut arg_start = start + 1;
    let mut k = start + 1;
    while k < toks.len() {
        let t = &toks[k];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" => paren += 1,
                "[" => bracket += 1,
                "{" => brace += 1,
                ")" | "]" | "}" => {
                    let depth = match t.text.as_str() {
                        ")" => &mut paren,
                        "]" => &mut bracket,
                        _ => &mut brace,
                    };
                    if t.text == close && *depth == 0 {
                        if k > arg_start {
                            args.push(&toks[arg_start..k]);
                        }
                        return args;
                    }
                    *depth -= 1;
                }
                "::" if toks.get(k + 1).is_some_and(|n| n.is_punct("<")) => {
                    angle += 1;
                    k += 2;
                    continue;
                }
                ">" if angle > 0 => angle -= 1,
                "," if paren == 0 && bracket == 0 && brace == 0 && angle == 0 => {
                    args.push(&toks[arg_start..k]);
                    arg_start = k + 1;
                }
                _ => {}
            }
        }
        k += 1;
    }
    args // unterminated: the delims rule reports the real problem
}

fn is_ident_like(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_alphanumeric() || c == '_')
}

/// Placeholder census of a format-string body: number of implicit
/// positionals, highest explicit index (`-1` if none), set of named
/// arguments used.
pub fn parse_placeholders(body: &str) -> (usize, i64, Vec<String>) {
    let b: Vec<char> = body.chars().collect();
    let n = b.len();
    let mut implicit = 0usize;
    let mut max_explicit: i64 = -1;
    let mut named: Vec<String> = Vec::new();
    let mut i = 0usize;
    let note_named = |named: &mut Vec<String>, s: &str| {
        if !named.iter().any(|x| x == s) {
            named.push(s.to_string());
        }
    };
    while i < n {
        match b[i] {
            '{' if b.get(i + 1) == Some(&'{') => i += 2,
            '{' => {
                let Some(jrel) = b[i..].iter().position(|&c| c == '}') else { break };
                let j = i + jrel;
                let spec: String = b[i + 1..j].iter().collect();
                let (arg, fmt) = match spec.split_once(':') {
                    Some((a, f)) => (a, Some(f)),
                    None => (spec.as_str(), None),
                };
                if arg.is_empty() {
                    implicit += 1;
                } else if arg.chars().all(|c| c.is_ascii_digit()) {
                    max_explicit = max_explicit.max(arg.parse::<i64>().unwrap_or(-1));
                } else if is_ident_like(arg) {
                    note_named(&mut named, arg);
                }
                if let Some(fmt) = fmt {
                    // width / precision may name their own argument.
                    let f: Vec<char> = fmt.chars().collect();
                    let m = f.len();
                    let mut k = 0usize;
                    while k < m {
                        if f[k] == '.' && f.get(k + 1) == Some(&'*') {
                            implicit += 1;
                            k += 2;
                            continue;
                        }
                        if f[k].is_alphanumeric() || f[k] == '_' {
                            let mut e = k;
                            while e < m && (f[e].is_alphanumeric() || f[e] == '_') {
                                e += 1;
                            }
                            if f.get(e) == Some(&'$') {
                                let word: String = f[k..e].iter().collect();
                                if word.chars().all(|c| c.is_ascii_digit()) {
                                    max_explicit =
                                        max_explicit.max(word.parse::<i64>().unwrap_or(-1));
                                } else {
                                    note_named(&mut named, &word);
                                }
                                k = e + 1;
                                continue;
                            }
                            k = e;
                            continue;
                        }
                        k += 1;
                    }
                }
                i = j + 1;
            }
            '}' if b.get(i + 1) == Some(&'}') => i += 2,
            _ => i += 1,
        }
    }
    (implicit, max_explicit, named)
}

/// Check format-argument arity over `toks`.
pub fn check(file: &str, toks: &[Tok], m: &Manifests) -> Vec<Finding> {
    let mut out = Vec::new();
    for k in 0..toks.len().saturating_sub(2) {
        let t = &toks[k];
        if t.kind != TokKind::Ident {
            continue;
        }
        let Some(&(_, fmt_idx)) = FMT_MACROS.iter().find(|(name, _)| *name == t.text) else {
            continue;
        };
        if !toks[k + 1].is_punct("!")
            || toks[k + 2].kind != TokKind::Punct
            || !is_open(&toks[k + 2].text)
        {
            continue;
        }
        // Skip definitions and paths (`macro_rules! assert`, `std::print`).
        if k > 0 && (toks[k - 1].is_ident("macro_rules") || toks[k - 1].is_punct("::")) {
            continue;
        }
        let args = split_args(toks, k + 2);
        if args.len() <= fmt_idx {
            continue; // bare `assert!(cond)` / `panic!()` — no format string
        }
        let fmt_arg = args[fmt_idx];
        if fmt_arg.len() != 1 || fmt_arg[0].kind != TokKind::Str {
            continue; // dynamic format string
        }
        let key = format!("{file}:{}", t.line);
        if m.fmtargs_allow.iter().any(|e| *e == key) {
            continue;
        }
        let body = &fmt_arg[0].text;
        let (implicit, max_explicit, named_used) = parse_placeholders(body);
        let required = implicit.max((max_explicit + 1) as usize);
        let mut positional = 0usize;
        let mut named_given: Vec<&str> = Vec::new();
        for a in &args[fmt_idx + 1..] {
            if a.len() >= 2
                && a[0].kind == TokKind::Ident
                && a[1].is_punct("=")
                && a.get(2).map_or(true, |t2| !t2.is_punct("="))
            {
                named_given.push(&a[0].text);
            } else {
                positional += 1;
            }
        }
        if positional != required {
            let head: String = body.chars().take(40).collect();
            out.push(Finding {
                file: file.to_string(),
                line: t.line,
                rule: "fmtargs",
                msg: format!(
                    "`{}!` wants {required} positional argument(s) for \"{head}\", got {positional}",
                    t.text
                ),
            });
        }
        for name in named_given {
            if !named_used.iter().any(|u| u == name) {
                out.push(Finding {
                    file: file.to_string(),
                    line: t.line,
                    rule: "fmtargs",
                    msg: format!(
                        "`{}!` named argument `{name}` never used by the format string",
                        t.text
                    ),
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn run(src: &str) -> Vec<Finding> {
        check("x.rs", &lex(src), &Manifests::default())
    }

    #[test]
    fn correct_arity_passes() {
        let src = r#"fn f() {
            println!("{} and {}", a, b);
            format!("{0} {1} {0}", a, b);
            write!(w, "{x}", x = 3)?;
            println!("{name} captured from scope");
            assert!(ok, "ctx {} {}", a, b);
            assert_eq!(a, b, "mismatch at {}", i);
            println!("{{escaped}} {}", only_one);
            info!("{:>8} {:.3}", wide, precise);
            println!("{:w$}", v, w = 8);
            println!("{:.*} end", prec, v);
        }"#;
        assert!(run(src).is_empty(), "{:?}", run(src));
    }

    #[test]
    fn missing_and_extra_positionals_flagged() {
        let got = run(r#"fn f() { println!("{} {}", a); format!("{}", a, b); }"#);
        assert_eq!(got.len(), 2);
        assert!(got[0].msg.contains("wants 2"));
        assert!(got[1].msg.contains("wants 1"));
    }

    #[test]
    fn explicit_index_beyond_args_flagged() {
        let got = run(r#"fn f() { format!("{0} {2}", a, b); }"#);
        assert_eq!(got.len(), 1);
        assert!(got[0].msg.contains("wants 3"));
    }

    #[test]
    fn unused_named_argument_flagged() {
        let got = run(r#"fn f() { write!(w, "{a}", a = 1, b = 2); }"#);
        assert_eq!(got.len(), 1);
        assert!(got[0].msg.contains("`b` never used"));
    }

    #[test]
    fn width_prec_spec_args_counted() {
        // `{:w$}` names `w`; `{:.*}` consumes one positional before the value.
        let got = run(r#"fn f() { println!("{:.*}", v); }"#);
        assert_eq!(got.len(), 1);
        assert!(got[0].msg.contains("wants 2"));
    }

    #[test]
    fn dynamic_format_and_bare_asserts_skipped() {
        let src = r#"fn f() { let s = fmt_var; println!("{}", x); format!(s); assert!(cond); panic!(); }"#;
        assert!(run(src).is_empty());
    }

    #[test]
    fn nested_calls_and_turbofish_commas_are_one_argument() {
        let src = r#"fn f() {
            println!("{}", v.iter().map(|(a, b)| a + b).collect::<HashMap<u64, u64>>().len());
            format!("{}", g(1, 2));
        }"#;
        assert!(run(src).is_empty(), "{:?}", run(src));
    }

    #[test]
    fn allowlisted_line_is_skipped() {
        let m = Manifests { fmtargs_allow: vec!["x.rs:1".into()], ..Manifests::default() };
        let got = check("x.rs", &lex(r#"fn f() { println!("{}", a, b); }"#), &m);
        assert!(got.is_empty());
    }
}
