//! `omprt` binary entry point.
fn main() {
    std::process::exit(omprt::cli::main_entry());
}
