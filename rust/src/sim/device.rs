//! Device descriptors for the two simulated targets.

use std::fmt;

/// Simulated device architectures. The two the paper targets, §3: Nvidia
/// (`nvptx64`) and AMD (`amdgcn`). Warp width is the semantically visible
/// difference (32 vs 64 — the paper's footnote 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    Nvptx64,
    Amdgcn,
}

impl Arch {
    /// Target-triple-ish name used in module headers and variant matching.
    pub fn name(self) -> &'static str {
        match self {
            Arch::Nvptx64 => "nvptx64",
            Arch::Amdgcn => "amdgcn",
        }
    }

    /// Warp (Nvidia) / wavefront (AMD) width in lanes.
    pub fn warp_width(self) -> u32 {
        match self {
            Arch::Nvptx64 => 32,
            Arch::Amdgcn => 64,
        }
    }

    /// All supported architectures.
    pub fn all() -> [Arch; 2] {
        [Arch::Nvptx64, Arch::Amdgcn]
    }

    /// Parse from a name (accepts the paper's `nvptx` alias too).
    pub fn parse(s: &str) -> Option<Arch> {
        match s {
            "nvptx64" | "nvptx" | "nvptx64-sim" => Some(Arch::Nvptx64),
            "amdgcn" | "amdgcn-sim" => Some(Arch::Amdgcn),
            _ => None,
        }
    }
}

impl fmt::Display for Arch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Static description of a simulated device.
#[derive(Debug, Clone)]
pub struct DeviceDesc {
    /// Architecture (fixes warp width + intrinsic namespace).
    pub arch: Arch,
    /// Number of block slots executing concurrently ("SMs"/"CUs"). The
    /// launcher schedules blocks over this many pool workers.
    pub sm_count: u32,
    /// Shared memory per block, bytes.
    pub shared_mem_per_block: u64,
    /// Global memory size, bytes.
    pub global_mem: u64,
    /// Maximum threads per block.
    pub max_threads_per_block: u32,
}

impl DeviceDesc {
    /// A V100-flavoured `nvptx64-sim` device, scaled for a host CPU.
    pub fn nvptx64() -> Self {
        DeviceDesc {
            arch: Arch::Nvptx64,
            sm_count: host_parallelism(),
            shared_mem_per_block: 96 * 1024,
            global_mem: 512 * 1024 * 1024,
            max_threads_per_block: 1024,
        }
    }

    /// An MI100-flavoured `amdgcn-sim` device.
    pub fn amdgcn() -> Self {
        DeviceDesc {
            arch: Arch::Amdgcn,
            sm_count: host_parallelism(),
            shared_mem_per_block: 64 * 1024,
            global_mem: 512 * 1024 * 1024,
            max_threads_per_block: 1024,
        }
    }

    /// Descriptor for an arch.
    pub fn for_arch(arch: Arch) -> Self {
        match arch {
            Arch::Nvptx64 => Self::nvptx64(),
            Arch::Amdgcn => Self::amdgcn(),
        }
    }

    /// Warps per block for a given block size.
    pub fn warps_for(&self, threads_per_block: u32) -> u32 {
        threads_per_block.div_ceil(self.arch.warp_width())
    }
}

/// Number of worker threads used to execute blocks.
pub fn host_parallelism() -> u32 {
    std::thread::available_parallelism().map(|n| n.get() as u32).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warp_widths_differ_by_arch() {
        assert_eq!(Arch::Nvptx64.warp_width(), 32);
        assert_eq!(Arch::Amdgcn.warp_width(), 64);
    }

    #[test]
    fn parse_accepts_paper_aliases() {
        assert_eq!(Arch::parse("nvptx"), Some(Arch::Nvptx64));
        assert_eq!(Arch::parse("nvptx64"), Some(Arch::Nvptx64));
        assert_eq!(Arch::parse("amdgcn"), Some(Arch::Amdgcn));
        assert_eq!(Arch::parse("gfx908"), None);
    }

    #[test]
    fn warps_for_rounds_up() {
        let d = DeviceDesc::nvptx64();
        assert_eq!(d.warps_for(32), 1);
        assert_eq!(d.warps_for(33), 2);
        assert_eq!(d.warps_for(1024), 32);
        let a = DeviceDesc::amdgcn();
        assert_eq!(a.warps_for(65), 2);
    }
}
