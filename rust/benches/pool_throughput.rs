//! BENCH: device-pool offload throughput.
//!
//! Scenarios:
//! 1. **scaling** — 1-device vs 4-device mixed pool, cold vs warm image
//!    cache (the PR-1 baseline numbers, kept for continuity);
//! 2. **batched small launches** — warm 4-device pool, identical small
//!    `scale` requests: synchronous per-request submission (one round
//!    trip per launch) vs async `batch_max=1` vs async `batch_max=32`;
//!    the batched case must beat the per-request baseline by ≥ 2x;
//! 3. **sharded large launch** — one 256K-element `scale` request on a
//!    single device vs the same request sharded across a 4-device
//!    uniform pool;
//! 4. **adaptive vs static** — 8 concurrent clients on the mixed
//!    4-device pool: occupancy-driven batch sizing must match or beat
//!    the static `batch_max=32` configuration;
//! 5. **fairness** — 8 equal-weight clients with identical fixed
//!    backlogs on the mixed pool, progress sampled when the first
//!    client finishes: no client's completion share may fall below half
//!    its fair share (1/8);
//! 6. **SLO** — 1 latency-sensitive client (25ms target, sparse
//!    sequential requests) + 7 bulk clients (async backlogs), run with
//!    and without `client_slos`: the SLO client's p95 sojourn must
//!    undercut the bulk clients' median, while bulk throughput stays
//!    ≥ 0.8x the fairness-only baseline;
//! 7. **degraded device** — closed-loop sharded requests on a uniform
//!    4-device pool while device 2 is scripted to wedge (150ms hang per
//!    launch) mid-run: without the watchdog every stitch serializes on
//!    the wedged reservation; with quarantine + re-planning, completion
//!    must beat that no-re-plan baseline;
//! 8. **trace overhead** — identical async small-launch workloads with
//!    the event tracer gated off vs recording, interleaved best-of-3:
//!    the gated-off pool (tracing compiled in, one branch per would-be
//!    event) must stay within 2% of the fastest configuration;
//! 9. **hedged** — closed-loop requests on a uniform 4-device pool
//!    whose device 2 wedges 150 ms on its first launch: watchdog-only
//!    re-planning quarantines the device but the in-flight victim still
//!    eats the whole hang, so its p99 carries the stall; with hedging a
//!    duplicate rescues the victim at the ~4 ms hedge floor and the p99
//!    must beat the watchdog-only run. A clean-pool companion (hedge on
//!    vs off, no faults, interleaved best-of-3) gates the idle overhead
//!    of the in-flight registry to within noise.
//! 10. **replayed** — the committed steady multi-tenant trace fixture
//!    replayed twice on fresh virtual-clock pools: the recorded
//!    inter-arrival gaps elapse on the virtual timeline (wall time pays
//!    only execution), the two runs' re-captures must be byte-identical
//!    (the reproducibility contract `omprt replay --virtual` rests on),
//!    and the run reports replay throughput plus the deadline miss
//!    count under the recorded SLO budgets.
//!
//! Results are also written as JSON to `BENCH_pool.json` (override the
//! path with the `BENCH_POOL_JSON` env var) so CI can archive them.
//! Pass `--smoke` for a reduced-iteration CI run.

use omprt::devrt::RuntimeKind;
use omprt::ir::passes::OptLevel;
use omprt::sched::workload::{
    saxpy_request, scale_request, scale_request_by, sharded_scale_request,
};
use omprt::sched::{bytes_to_f32, replay_capture, Affinity, DevicePool, PoolConfig, ReplayOptions};
use omprt::sim::Arch;
use omprt::trace::{parse_capture, Histogram};
use omprt::util::clock;
use omprt::util::clock::Participant;
use omprt::util::VirtualClock;
use std::sync::Arc;

const ELEMS: usize = 256;

/// Submit one mixed batch asynchronously and wait for every result;
/// returns launches/sec.
fn run_batch(pool: &DevicePool, batch: usize) -> f64 {
    let t0 = clock::now();
    let mut handles = Vec::with_capacity(batch);
    for i in 0..batch {
        let (req, want) = if i % 2 == 0 {
            let data: Vec<f32> = (0..ELEMS).map(|k| (k + i) as f32).collect();
            scale_request(&data, Affinity::any(), OptLevel::O2)
        } else {
            let x: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
            let y: Vec<f32> = (0..ELEMS).map(|k| (k + i) as f32).collect();
            saxpy_request(0.5, &x, &y, Affinity::any(), OptLevel::O2)
        };
        handles.push((pool.submit(req).unwrap(), want));
    }
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        let got = bytes_to_f32(resp.buffers[0].as_ref().unwrap());
        assert_eq!(got, want, "pool result must match the host reference");
    }
    batch as f64 / t0.elapsed().as_secs_f64()
}

fn bench_pool(name: &str, config: &PoolConfig, batch: usize) -> (f64, f64) {
    let pool = DevicePool::new(config).unwrap();
    let cold = run_batch(&pool, batch);
    let warm = run_batch(&pool, batch);
    let m = pool.metrics();
    let cache = m.cache();
    println!(
        "{name:<22} cold {cold:>8.1} launches/s | warm {warm:>8.1} launches/s | \
         speedup {:.2}x | cache {:.1}% hit ({} hits / {} misses)",
        warm / cold,
        cache.hit_rate() * 100.0,
        cache.hits,
        cache.misses
    );
    (cold, warm)
}

/// All-identical small `scale` requests, submitted synchronously (wait
/// after each submit — the per-request baseline) or asynchronously.
fn run_small_scales(pool: &DevicePool, count: usize, sync: bool) -> f64 {
    let data: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
    let t0 = clock::now();
    if sync {
        for _ in 0..count {
            let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
            let resp = pool.submit(req).unwrap().wait().unwrap();
            assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
        }
    } else {
        let mut handles = Vec::with_capacity(count);
        for _ in 0..count {
            let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
            handles.push((pool.submit(req).unwrap(), want));
        }
        for (h, want) in handles {
            let resp = h.wait().unwrap();
            assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
        }
    }
    count as f64 / t0.elapsed().as_secs_f64()
}

/// Returns (per_request, async_unbatched, batched32).
fn batched_small_launch_scenario(batch: usize) -> (f64, f64, f64) {
    println!("\n--- batched small launches: {batch} x scale({ELEMS}) on a 4-device pool ---");
    // Per-request baseline: batching off, one request in flight at a time.
    let per_request = {
        let pool = DevicePool::new(&PoolConfig::mixed4().with_batch_max(1)).unwrap();
        run_small_scales(&pool, batch, false); // warm the image caches
        run_small_scales(&pool, batch, true)
    };
    // Async pipeline, still unbatched.
    let async_unbatched = {
        let pool = DevicePool::new(&PoolConfig::mixed4().with_batch_max(1)).unwrap();
        run_small_scales(&pool, batch, false);
        run_small_scales(&pool, batch, false)
    };
    // Async + batching: same-image launches fuse into one grid per pop.
    let (batched, batched_jobs, max_batch) = {
        let pool = DevicePool::new(&PoolConfig::mixed4().with_batch_max(32)).unwrap();
        run_small_scales(&pool, batch, false);
        let rate = run_small_scales(&pool, batch, false);
        let m = pool.metrics();
        let max = m.devices.iter().map(|d| d.max_batch).max().unwrap_or(0);
        (rate, m.batched_jobs(), max)
    };
    println!(
        "per-request (sync)    {per_request:>8.1} launches/s\n\
         async, batch_max=1    {async_unbatched:>8.1} launches/s ({:.2}x)\n\
         async, batch_max=32   {batched:>8.1} launches/s ({:.2}x) | {batched_jobs} jobs coalesced, max batch {max_batch}",
        async_unbatched / per_request,
        batched / per_request,
    );
    assert!(
        batched >= 2.0 * per_request,
        "warm batched throughput must be >= 2x the per-request baseline \
         (got {batched:.1} vs {per_request:.1} launches/s)"
    );
    (per_request, async_unbatched, batched)
}

/// Returns (t_single_ms, t_quad_ms, shards).
fn sharded_large_launch_scenario(n: usize) -> (f64, f64, usize) {
    println!("\n--- sharded large launch: scale({n}) ---");
    let data: Vec<f32> = (0..n).map(|k| (k % 1013) as f32).collect();

    let single = DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64))
        .unwrap();
    // Warm the cache, then time the unsharded request (ShardSpec present,
    // but a 1-device pool always falls back to a single shard).
    let (req, want) = sharded_scale_request(&data, Affinity::any(), OptLevel::O2);
    single.submit(req).unwrap().wait().unwrap();
    let t0 = clock::now();
    let (req, _) = sharded_scale_request(&data, Affinity::any(), OptLevel::O2);
    let resp = single.submit(req).unwrap().wait().unwrap();
    let t_single = t0.elapsed().as_secs_f64();
    assert_eq!(resp.shards, 1);
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);

    let quad =
        DevicePool::new(&PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4)).unwrap();
    let (req, _) = sharded_scale_request(&data, Affinity::any(), OptLevel::O2);
    quad.submit(req).unwrap().wait().unwrap(); // warm all shards' caches
    let t0 = clock::now();
    let (req, _) = sharded_scale_request(&data, Affinity::any(), OptLevel::O2);
    let resp = quad.submit(req).unwrap().wait().unwrap();
    let t_quad = t0.elapsed().as_secs_f64();
    assert!(resp.shards >= 2, "a 4-device uniform pool must shard, got {}", resp.shards);
    assert_eq!(
        bytes_to_f32(resp.buffers[0].as_ref().unwrap()),
        want,
        "stitched sharded result must match the host reference"
    );
    println!(
        "1 device: {:.1} ms | 4 devices, {} shards: {:.1} ms | speedup {:.2}x",
        t_single * 1e3,
        resp.shards,
        t_quad * 1e3,
        t_single / t_quad
    );
    (t_single * 1e3, t_quad * 1e3, resp.shards)
}

/// 8 concurrent client threads, each submitting `per_client` mixed small
/// requests asynchronously; returns aggregate launches/sec.
fn run_multi_client(pool: &DevicePool, per_client: usize) -> f64 {
    let t0 = clock::now();
    std::thread::scope(|scope| {
        for client in 0..8 {
            let pool = &pool;
            scope.spawn(move || {
                let mut handles = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    let (mut req, want) = if i % 2 == 0 {
                        let data: Vec<f32> = (0..ELEMS).map(|k| (k + i) as f32).collect();
                        scale_request(&data, Affinity::any(), OptLevel::O2)
                    } else {
                        let x: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
                        let y: Vec<f32> = (0..ELEMS).map(|k| (k + client) as f32).collect();
                        saxpy_request(0.5, &x, &y, Affinity::any(), OptLevel::O2)
                    };
                    req.client = format!("client{client}");
                    handles.push((pool.submit(req).unwrap(), want));
                }
                for (h, want) in handles {
                    let resp = h.wait().unwrap();
                    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
                }
            });
        }
    });
    (8 * per_client) as f64 / t0.elapsed().as_secs_f64()
}

/// Adaptive occupancy-driven batching vs the static `batch_max=32`
/// configuration under 8-client contention. Returns (static, adaptive)
/// launches/sec.
fn adaptive_vs_static_scenario(per_client: usize) -> (f64, f64) {
    println!("\n--- adaptive vs static: 8 clients x {per_client} requests, mixed 4-device pool ---");
    let static_rate = {
        let pool = DevicePool::new(
            &PoolConfig::mixed4().with_batch_max(32).with_adaptive(false),
        )
        .unwrap();
        run_multi_client(&pool, per_client); // warm
        run_multi_client(&pool, per_client)
    };
    let (adaptive_rate, stats) = {
        let pool = DevicePool::new(&PoolConfig::mixed4().with_batch_max(32)).unwrap();
        run_multi_client(&pool, per_client);
        let rate = run_multi_client(&pool, per_client);
        (rate, pool.metrics().adaptive_stats)
    };
    println!(
        "static batch_max=32   {static_rate:>8.1} launches/s\n\
         adaptive (cap 32)     {adaptive_rate:>8.1} launches/s ({:.2}x) | \
         {} decisions, avg decided {:.1}, fill efficiency {:.2}",
        adaptive_rate / static_rate,
        stats.decisions,
        stats.avg_decided(),
        stats.efficiency
    );
    assert!(
        adaptive_rate >= 0.85 * static_rate,
        "adaptive mode must match or beat static batching within noise \
         (got {adaptive_rate:.1} vs {static_rate:.1} launches/s)"
    );
    (static_rate, adaptive_rate)
}

/// 8 equal-weight clients, each with an identical fixed backlog
/// (distinct kernel images, so no cross-client fusing) submitted upfront
/// from one thread — removing OS thread scheduling from the measurement.
/// Per-client progress is sampled from the pool's own completion
/// counters at the moment the *first* client finishes its backlog: under
/// fair DRR every still-backlogged client has comparable progress at
/// that instant, while a serve-one-lane-to-exhaustion regression would
/// show near-zero shares. Returns each client's share of the sampled
/// completions; no share may fall below half the fair 1/8.
fn fairness_scenario(per_client: usize) -> Vec<f64> {
    println!("\n--- fairness: 8 clients x {per_client} requests, mixed 4-device pool ---");
    let pool = DevicePool::new(&PoolConfig::mixed4()).unwrap();
    let data: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
    // Warm each client's image so the sampled window measures
    // scheduling, not prepare time.
    for client in 0..8 {
        let factor = 1.5 + client as f32;
        let (mut req, want) = scale_request_by(factor, &data, Affinity::any(), OptLevel::O2);
        req.client = format!("client{client}");
        let resp = pool.submit(req).unwrap().wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    pool.quiesce();
    // Submit all backlogs round-robin from this one thread.
    let mut handles: Vec<Vec<_>> = (0..8).map(|_| vec![]).collect();
    for _ in 0..per_client {
        for (client, hs) in handles.iter_mut().enumerate() {
            let factor = 1.5 + client as f32;
            let (mut req, want) = scale_request_by(factor, &data, Affinity::any(), OptLevel::O2);
            req.client = format!("client{client}");
            hs.push((pool.submit(req).unwrap(), want));
        }
    }
    // Wait for client0's backlog, then sample everyone's progress.
    for (h, want) in handles.remove(0) {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    let m = pool.metrics();
    // Subtract the one warm-up request each client already completed.
    let counts: Vec<u64> = (0..8)
        .map(|client| {
            let name = format!("client{client}");
            m.clients
                .iter()
                .find(|c| c.client == name)
                .map_or(0, |c| c.completed)
                .saturating_sub(1)
        })
        .collect();
    // Drain the rest (and verify every result).
    for hs in handles {
        for (h, want) in hs {
            let resp = h.wait().unwrap();
            assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
        }
    }
    let total: u64 = counts.iter().sum();
    let shares: Vec<f64> = counts.iter().map(|&c| c as f64 / total.max(1) as f64).collect();
    let min_share = shares.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "completions at first-finisher: {counts:?} | shares: {} | min {:.3} (fair 0.125)",
        shares.iter().map(|s| format!("{s:.3}")).collect::<Vec<_>>().join(" "),
        min_share
    );
    assert!(
        min_share >= 0.5 / 8.0,
        "no client's share may fall below half its fair share (min {min_share:.3})"
    );
    shares
}

/// One SLO-scenario run: 7 bulk clients submit async backlogs while the
/// "slo" client issues sparse sequential submit→wait requests of its own
/// image. Returns `(slo_p95_us, bulk_median_us, bulk_rate, misses,
/// preemptions)`; latencies come from the pool's own per-client sojourn
/// samples, so both sides are measured identically.
fn slo_run(with_slo: bool, per_client: usize) -> (f64, f64, f64, u64, u64) {
    const BULK: usize = 7;
    const SLO_FACTOR: f32 = 9.5; // distinct image for the SLO client
    let mut cfg = PoolConfig::mixed4();
    if with_slo {
        cfg = cfg.with_client_slo("slo", 25.0);
    }
    let pool = DevicePool::new(&cfg).unwrap();
    let data: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
    // Warm every image across the devices before measuring.
    let mut warm = vec![];
    for i in 0..8 {
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        warm.push((pool.submit(req).unwrap(), want));
        let y: Vec<f32> = (0..ELEMS).map(|k| (k + i) as f32).collect();
        let (req, want) = saxpy_request(0.5, &data, &y, Affinity::any(), OptLevel::O2);
        warm.push((pool.submit(req).unwrap(), want));
        let (req, want) = scale_request_by(SLO_FACTOR, &data, Affinity::any(), OptLevel::O2);
        warm.push((pool.submit(req).unwrap(), want));
    }
    for (h, want) in warm {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    pool.quiesce();
    // Warm-up traffic ran under the default client tag, so the per-client
    // samples below cover only the measured window.
    let t0 = clock::now();
    std::thread::scope(|scope| {
        for b in 0..BULK {
            let pool = &pool;
            let data = &data;
            scope.spawn(move || {
                let mut handles = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    let (mut req, want) = if i % 2 == 0 {
                        scale_request(data, Affinity::any(), OptLevel::O2)
                    } else {
                        let y: Vec<f32> = (0..ELEMS).map(|k| (k + b) as f32).collect();
                        saxpy_request(0.5, data, &y, Affinity::any(), OptLevel::O2)
                    };
                    req.client = format!("bulk{b}");
                    handles.push((pool.submit(req).unwrap(), want));
                }
                for (h, want) in handles {
                    let resp = h.wait().unwrap();
                    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
                }
            });
        }
        let pool = &pool;
        let data = &data;
        scope.spawn(move || {
            // Sparse, closed-loop: one request in flight at a time, as a
            // latency-sensitive interactive client behaves. Never fewer
            // than 16 requests, so the asserted p95 is not just the
            // worst single sample in smoke mode.
            for _ in 0..per_client.max(16) {
                let (mut req, want) =
                    scale_request_by(SLO_FACTOR, data, Affinity::any(), OptLevel::O2);
                req.client = "slo".into();
                let resp = pool.submit(req).unwrap().wait().unwrap();
                assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
            }
        });
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let m = pool.metrics();
    let slo_p95 = m
        .clients
        .iter()
        .find(|c| c.client == "slo")
        .expect("slo client metrics")
        .latency_p95_us();
    let mut bulk_hist = Histogram::new();
    for c in m.clients.iter().filter(|c| c.client.starts_with("bulk")) {
        bulk_hist.merge(&c.latency_us);
    }
    let bulk_median = bulk_hist.percentile_us(0.5);
    let bulk_rate = (BULK * per_client) as f64 / elapsed;
    let (_, misses) = m.deadline_totals();
    (slo_p95, bulk_median, bulk_rate, misses, m.preemptions)
}

/// Deadline-aware scheduling: the SLO client's tail must beat the bulk
/// median without collapsing bulk throughput.
fn slo_scenario(per_client: usize) -> (f64, f64, f64, f64, u64, u64) {
    println!("\n--- SLO: 1 latency client (25ms) + 7 bulk x {per_client}, mixed 4-device pool ---");
    let (_, _, bulk_base, _, _) = slo_run(false, per_client);
    let (slo_p95, bulk_median, bulk_slo, misses, preemptions) = slo_run(true, per_client);
    println!(
        "slo p95 {slo_p95:>9.1} us | bulk median {bulk_median:>9.1} us | \
         bulk {bulk_slo:>7.1} launches/s vs baseline {bulk_base:>7.1} ({:.2}x) | \
         {misses} misses, {preemptions} preemptions",
        bulk_slo / bulk_base
    );
    assert!(
        slo_p95 < bulk_median,
        "SLO client's p95 ({slo_p95:.1} us) must undercut the bulk median ({bulk_median:.1} us)"
    );
    assert!(
        bulk_slo >= 0.8 * bulk_base,
        "bulk throughput under SLOs must stay >= 0.8x the fairness-only baseline \
         (got {bulk_slo:.1} vs {bulk_base:.1} launches/s)"
    );
    (slo_p95, bulk_median, bulk_base, bulk_slo, misses, preemptions)
}

/// Degraded-device scenario: closed-loop sharded `scale` requests over
/// a uniform 4-device pool whose device 2 is scripted (`sim::fault`) to
/// hang 150 ms per launch from its 4th launch on. The no-watchdog
/// baseline re-reserves the wedged device for every stitch (it looks
/// idle again after each hang); with the health layer the first hang
/// quarantines it (~2x the 15 ms watchdog floor) and every later
/// request plans around it. Returns
/// `(t_noreplan_ms, t_replan_ms, quarantines)`.
fn degraded_device_scenario(requests: usize) -> (f64, f64, u64) {
    println!(
        "\n--- degraded device: {requests} sharded requests, 1 of 4 devices wedged mid-run ---"
    );
    let n = 32 * 1024;
    let data: Vec<f32> = (0..n).map(|k| (k % 1013) as f32).collect();
    let run = |watchdog: bool| -> (f64, u64) {
        let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4)
            .with_shard_min_trips(2048)
            .with_watchdog(watchdog)
            .with_watchdog_min_ms(15)
            .with_fault_spec("2=stall:150ms:30s@launch:3")
            .expect("valid fault spec");
        let pool = DevicePool::new(&cfg).unwrap();
        // Warm all four image caches before the fault window opens: the
        // closed loop hands device 2 exactly one shard per request, so
        // three warm requests leave it at launch index 3 — the trigger.
        for _ in 0..3 {
            let (req, want) = sharded_scale_request(&data, Affinity::any(), OptLevel::O2);
            let resp = pool.submit(req).unwrap().wait().unwrap();
            assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
        }
        let t0 = clock::now();
        for _ in 0..requests {
            let (req, want) = sharded_scale_request(&data, Affinity::any(), OptLevel::O2);
            let resp = pool.submit(req).unwrap().wait().unwrap();
            assert_eq!(
                bytes_to_f32(resp.buffers[0].as_ref().unwrap()),
                want,
                "degraded-pool results must stay correct"
            );
        }
        let elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;
        pool.quiesce();
        let m = pool.metrics();
        for d in &m.devices {
            assert_eq!(d.reserved, 0, "reservation leak on device {}", d.id);
        }
        (elapsed_ms, m.devices[2].quarantines)
    };
    let (t_noreplan, q0) = run(false);
    assert_eq!(q0, 0, "watchdog off must never quarantine");
    let (t_replan, q1) = run(true);
    assert!(q1 >= 1, "the wedged device must end up quarantined");
    println!(
        "no-replan {t_noreplan:>7.0} ms | replan {t_replan:>7.0} ms | speedup {:.2}x | \
         {q1} quarantine(s)",
        t_noreplan / t_replan
    );
    assert!(
        t_replan < 0.7 * t_noreplan,
        "re-planning must beat the no-re-plan baseline \
         (got {t_replan:.0} ms vs {t_noreplan:.0} ms)"
    );
    (t_noreplan, t_replan, q1)
}

/// Tracing overhead: identical async small-launch workloads on warm
/// mixed pools with the event tracer gated off vs recording. Both pools
/// are measured in interleaved best-of-3 rounds so machine noise hits
/// the two configurations alike. Returns `(off_rate, on_rate)`.
fn trace_overhead_scenario(batch: usize) -> (f64, f64) {
    println!("\n--- trace overhead: {batch} x scale({ELEMS}), gated off vs recording ---");
    let off_pool = DevicePool::new(&PoolConfig::mixed4().with_batch_max(32)).unwrap();
    let on_pool = DevicePool::new(
        &PoolConfig::mixed4().with_batch_max(32).with_trace(true).with_trace_capacity(1 << 16),
    )
    .unwrap();
    assert!(!off_pool.trace_enabled() && on_pool.trace_enabled());
    // Warm both pools' image caches before measuring.
    run_small_scales(&off_pool, batch, false);
    run_small_scales(&on_pool, batch, false);
    let (mut off, mut on) = (0.0f64, 0.0f64);
    for _ in 0..3 {
        off = off.max(run_small_scales(&off_pool, batch, false));
        on = on.max(run_small_scales(&on_pool, batch, false));
    }
    let stats = on_pool.trace_stats();
    assert!(stats.recorded > 0, "the recording pool must have captured events");
    println!(
        "gated off {off:>8.1} launches/s | recording {on:>8.1} launches/s ({:.3}x) | \
         {} events recorded ({} dropped)",
        on / off,
        stats.recorded,
        stats.dropped
    );
    // Tracing is compile-always: the gated-off pool IS the production
    // no-tracing path, paying one branch per would-be event. It must not
    // trail the fastest measured configuration by more than 2%.
    let best = off.max(on);
    assert!(
        off >= 0.98 * best,
        "gated-off tracing must stay within 2% of the fastest configuration \
         (off {off:.1} vs best {best:.1} launches/s)"
    );
    (off, on)
}

/// Hedged-execution scenario. Tail half: closed-loop small requests on
/// a uniform 4-device pool, device 2 scripted to hang 150 ms on its
/// first launch — with the watchdog alone the victim request waits out
/// the hang (quarantine protects *later* requests only), so the
/// client's p99 sojourn carries the stall; with hedging the monitor
/// duplicates the victim at max(3 x EWMA, watchdog_min/4 ≈ 4 ms) and
/// the duplicate's reply bounds the tail. Overhead half: hedge on vs
/// off on clean warm pools, interleaved best-of-3. Returns
/// `(p99_watchdog_us, p99_hedged_us, hedge_wins, idle_off, idle_on)`.
fn hedged_scenario(requests: usize, batch: usize) -> (f64, f64, u64, f64, f64) {
    println!("\n--- hedged: {requests} closed-loop requests, 1 of 4 devices wedged ---");
    let data: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
    let run = |hedge: bool| -> (f64, u64, u64) {
        let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4)
            .with_batch_max(1)
            .with_watchdog(true)
            .with_watchdog_min_ms(15)
            .with_hedge(hedge)
            .with_hedge_after_factor(3)
            .with_fault_spec("2=stall:150ms:30s@launch:0")
            .expect("valid fault spec");
        let pool = DevicePool::new(&cfg).unwrap();
        for _ in 0..requests {
            let (mut req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
            req.client = "tail".into();
            let resp = pool.submit(req).unwrap().wait().unwrap();
            assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
        }
        pool.quiesce();
        let m = pool.metrics();
        let p99 = m
            .clients
            .iter()
            .find(|c| c.client == "tail")
            .expect("tail client metrics")
            .latency_p99_us();
        (p99, m.hedge_wins, m.devices[2].quarantines)
    };
    let (p99_watchdog, _, q0) = run(false);
    assert!(q0 >= 1, "the wedged device must end up quarantined");
    let (p99_hedged, wins, _) = run(true);
    println!(
        "watchdog-only p99 {p99_watchdog:>9.1} us | hedged p99 {p99_hedged:>9.1} us \
         ({:.2}x) | {wins} hedge win(s)",
        p99_watchdog / p99_hedged.max(1e-9)
    );
    assert!(wins >= 1, "the stalled victim must have been rescued by a duplicate");
    assert!(
        p99_hedged < 0.7 * p99_watchdog,
        "hedging must beat watchdog-only re-planning on the degraded p99 \
         (got {p99_hedged:.1} us vs {p99_watchdog:.1} us)"
    );

    // Idle overhead: a healthy pool with hedging on runs the monitor and
    // registers every in-flight batch, but must never launch a duplicate
    // — and must stay within noise of hedging off.
    let off_pool = DevicePool::new(&PoolConfig::mixed4().with_batch_max(32)).unwrap();
    let on_pool =
        DevicePool::new(&PoolConfig::mixed4().with_batch_max(32).with_hedge(true)).unwrap();
    run_small_scales(&off_pool, batch, false);
    run_small_scales(&on_pool, batch, false);
    let (mut idle_off, mut idle_on) = (0.0f64, 0.0f64);
    for _ in 0..3 {
        idle_off = idle_off.max(run_small_scales(&off_pool, batch, false));
        idle_on = idle_on.max(run_small_scales(&on_pool, batch, false));
    }
    assert_eq!(on_pool.metrics().hedges, 0, "a healthy pool must never hedge");
    println!(
        "idle overhead: hedge off {idle_off:>8.1} launches/s | on {idle_on:>8.1} launches/s \
         ({:.3}x)",
        idle_on / idle_off.max(1e-9)
    );
    assert!(
        idle_on >= 0.95 * idle_off,
        "idle-pool hedge overhead must stay in noise \
         (got {idle_on:.1} vs {idle_off:.1} launches/s)"
    );
    (p99_watchdog, p99_hedged, wins, idle_off, idle_on)
}

/// Replayed-trace scenario: replay the committed steady multi-tenant
/// fixture twice, each time on a fresh uniform 4-device pool driven by
/// its own virtual clock. The recorded gaps elapse on the virtual
/// timeline, so wall time pays only execution; every replayed result is
/// verified against the host reference inside `replay_capture`; and the
/// two runs' re-captures must be **byte-identical** — the
/// reproducibility contract behind `omprt replay --virtual`. Returns
/// `(requests, wall_rate, virtual_elapsed_us, deadline_misses)`.
fn replayed_scenario() -> (usize, f64, f64, u64) {
    const TRACE: &str = include_str!("../../traces/steady_multi_tenant.capture");
    println!("\n--- replayed: traces/steady_multi_tenant.capture on a virtual-clock pool ---");
    let cap = parse_capture(TRACE).expect("committed fixture must parse");
    let run = || -> (String, f64, f64, u64) {
        let vc = Arc::new(VirtualClock::new());
        // The bench thread is the pacing driver: register it before the
        // pool spawns so virtual time only advances while it sleeps.
        let _driver = Participant::new(&*vc);
        let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4)
            .with_trace(true)
            .with_trace_capacity(1 << 14)
            .with_clock(vc.clone());
        let pool = DevicePool::new(&cfg).unwrap();
        let t0 = clock::now();
        let report = replay_capture(&pool, &cap, &ReplayOptions::new()).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(report.submitted as usize, cap.records.len(), "{report:?}");
        assert_eq!(report.rejected, 0, "{report:?}");
        assert_eq!(report.failed, 0, "{report:?}");
        assert_eq!(report.mismatched, 0, "replayed results must match the host reference");
        pool.quiesce();
        let recapture = pool.trace_capture();
        assert_eq!(pool.trace_stats().dropped, 0, "ring must hold the whole replay");
        let (_, misses) = pool.metrics().deadline_totals();
        (recapture, wall, report.elapsed.as_secs_f64() * 1e6, misses)
    };
    let (recap_a, wall_a, virtual_us, misses) = run();
    let (recap_b, _, _, _) = run();
    assert_eq!(
        recap_a, recap_b,
        "two virtual-clock replays of the same trace must re-capture identically"
    );
    let n = parse_capture(&recap_a).expect("re-capture must validate").records.len();
    assert_eq!(n, cap.records.len(), "re-capture must cover every replayed request");
    let rate = cap.records.len() as f64 / wall_a.max(1e-9);
    println!(
        "{} requests | {rate:>8.1} replayed/s wall | {:.0} us virtual | {misses} deadline \
         miss(es) | re-captures identical",
        cap.records.len(),
        virtual_us
    );
    (cap.records.len(), rate, virtual_us, misses)
}

/// Minimal hand-rolled JSON (the offline crate set has no serde).
fn write_bench_json(path: &str, json: &str) {
    match std::fs::write(path, json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncannot write {path}: {e}"),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // 128 floor: the hit-rate assert below tolerates up to 8 cold misses
    // (2 modules x 4 devices), which must stay under 10% of the batch.
    let batch = if smoke { 128 } else { 256 };
    let shard_n = if smoke { 64 * 1024 } else { 256 * 1024 };
    let per_client = if smoke { 16 } else { 64 };

    println!(
        "\n=== pool throughput: {batch} requests/batch, {ELEMS} f32 elems, mixed scale/saxpy{} ===\n",
        if smoke { " [smoke]" } else { "" }
    );
    let (cold1, warm1) = bench_pool(
        "1 device (portable)",
        &PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64),
        batch,
    );
    let (cold4, warm4) = bench_pool("4 devices (mixed)", &PoolConfig::mixed4(), batch);
    println!(
        "\n4-device vs 1-device: cold {:.2}x, warm {:.2}x",
        cold4 / cold1,
        warm4 / warm1
    );

    // The repeated-kernel workload must be cache-friendly: two modules
    // over the pool's devices.
    let pool = DevicePool::new(&PoolConfig::mixed4()).unwrap();
    run_batch(&pool, batch);
    let cache = pool.metrics().cache();
    assert!(
        cache.hit_rate() > 0.9,
        "repeated-kernel batch must exceed 90% hit rate, got {:.1}%",
        cache.hit_rate() * 100.0
    );
    println!(
        "repeated-kernel batch hit rate: {:.1}% (> 90% required)",
        cache.hit_rate() * 100.0
    );

    let (per_request, async_unbatched, batched) = batched_small_launch_scenario(batch);
    let (t_single_ms, t_quad_ms, shards) = sharded_large_launch_scenario(shard_n);
    let (static_rate, adaptive_rate) = adaptive_vs_static_scenario(per_client);
    let shares = fairness_scenario(4 * per_client);
    let (slo_p95, bulk_median, bulk_base, bulk_slo, misses, preemptions) =
        slo_scenario(per_client);
    let (t_noreplan_ms, t_replan_ms, quarantines) =
        degraded_device_scenario(if smoke { 4 } else { 8 });
    let (trace_off, trace_on) = trace_overhead_scenario(batch);
    let (p99_watchdog, p99_hedged, hedge_wins, idle_off, idle_on) =
        hedged_scenario(if smoke { 48 } else { 96 }, batch);
    let (replay_n, replay_rate, replay_virtual_us, replay_misses) = replayed_scenario();

    let min_share = shares.iter().cloned().fold(f64::INFINITY, f64::min);
    let json = format!(
        "{{\n  \"bench\": \"pool_throughput\",\n  \"smoke\": {smoke},\n  \
         \"scaling\": {{\"cold_1dev\": {cold1:.1}, \"warm_1dev\": {warm1:.1}, \
         \"cold_4dev\": {cold4:.1}, \"warm_4dev\": {warm4:.1}}},\n  \
         \"batched\": {{\"per_request\": {per_request:.1}, \
         \"async_unbatched\": {async_unbatched:.1}, \"batched32\": {batched:.1}}},\n  \
         \"sharded\": {{\"t_single_ms\": {t_single_ms:.2}, \"t_quad_ms\": {t_quad_ms:.2}, \
         \"shards\": {shards}}},\n  \
         \"adaptive\": {{\"static32\": {static_rate:.1}, \"adaptive\": {adaptive_rate:.1}, \
         \"ratio\": {:.3}}},\n  \
         \"fairness\": {{\"clients\": 8, \"fair_share\": 0.125, \"min_share\": {min_share:.4}, \
         \"shares\": [{}]}},\n  \
         \"slo\": {{\"slo_p95_us\": {slo_p95:.1}, \"bulk_median_us\": {bulk_median:.1}, \
         \"bulk_rate_baseline\": {bulk_base:.1}, \"bulk_rate_slo\": {bulk_slo:.1}, \
         \"bulk_ratio\": {:.3}, \"misses\": {misses}, \"preemptions\": {preemptions}}},\n  \
         \"degraded\": {{\"t_noreplan_ms\": {t_noreplan_ms:.1}, \"t_replan_ms\": {t_replan_ms:.1}, \
         \"speedup\": {:.3}, \"quarantines\": {quarantines}}},\n  \
         \"trace\": {{\"gated_off\": {trace_off:.1}, \"recording\": {trace_on:.1}, \
         \"recording_ratio\": {:.3}}},\n  \
         \"hedged\": {{\"p99_watchdog_us\": {p99_watchdog:.1}, \
         \"p99_hedged_us\": {p99_hedged:.1}, \"speedup\": {:.3}, \
         \"hedge_wins\": {hedge_wins}, \"idle_off\": {idle_off:.1}, \
         \"idle_on\": {idle_on:.1}, \"idle_ratio\": {:.3}}},\n  \
         \"replayed\": {{\"trace\": \"traces/steady_multi_tenant.capture\", \
         \"requests\": {replay_n}, \"wall_rate\": {replay_rate:.1}, \
         \"virtual_elapsed_us\": {replay_virtual_us:.0}, \
         \"deadline_misses\": {replay_misses}, \"identical_recapture\": true}}\n}}\n",
        adaptive_rate / static_rate,
        shares.iter().map(|s| format!("{s:.4}")).collect::<Vec<_>>().join(", "),
        bulk_slo / bulk_base,
        t_noreplan_ms / t_replan_ms.max(1e-9),
        trace_on / trace_off.max(1e-9),
        p99_watchdog / p99_hedged.max(1e-9),
        idle_on / idle_off.max(1e-9),
    );
    let path =
        std::env::var("BENCH_POOL_JSON").unwrap_or_else(|_| "BENCH_pool.json".to_string());
    write_bench_json(&path, &json);
}
