//! Device-pool scheduler integration tests: concurrent mixed-arch,
//! mixed-runtime offload traffic with results verified against ground
//! truth, affinity constraints, kernel-image cache accounting, launch
//! batching, cross-device sharding and queue backpressure.

use omprt::coordinator::PoolCoordinator;
use omprt::devrt::RuntimeKind;
use omprt::ir::passes::OptLevel;
use omprt::sched::workload::{
    saxpy_request, scale_request, scale_request_by, sharded_saxpy_request, sharded_scale_request,
};
use omprt::sched::{bytes_to_f32, Affinity, DevicePool, PoolConfig, TrySubmitError};
use omprt::sim::Arch;
use omprt::util::clock;
use std::time::Duration;

const CLIENTS: usize = 8;
const PER_CLIENT: usize = 32;

/// 8 client threads x 32 submissions across a 4-device mixed pool.
/// Every result must equal the host-computed ground truth, and the
/// repeated-kernel workload (two distinct modules over four devices)
/// must exceed a 90% image-cache hit rate.
#[test]
fn concurrent_mixed_pool_matches_ground_truth() {
    let pool = DevicePool::new(&PoolConfig::mixed4()).unwrap();
    assert_eq!(pool.device_count(), 4);

    std::thread::scope(|scope| {
        for client in 0..CLIENTS {
            let pool = &pool;
            scope.spawn(move || {
                let affinities = [
                    Affinity::any(),
                    Affinity::on_arch(Arch::Nvptx64),
                    Affinity::on_arch(Arch::Amdgcn),
                    Affinity::on_kind(RuntimeKind::Legacy),
                    Affinity::on_kind(RuntimeKind::Portable),
                ];
                let mut pending = vec![];
                for i in 0..PER_CLIENT {
                    let n = 64 + (client * PER_CLIENT + i) % 64;
                    let affinity = affinities[(client + i) % affinities.len()];
                    let (req, want) = if i % 2 == 0 {
                        let data: Vec<f32> =
                            (0..n).map(|k| (k + client * 1000 + i) as f32).collect();
                        scale_request(&data, affinity, OptLevel::O2)
                    } else {
                        let x: Vec<f32> = (0..n).map(|k| (k + i) as f32).collect();
                        let y: Vec<f32> = (0..n).map(|k| (k * 2 + client) as f32).collect();
                        saxpy_request(0.5, &x, &y, affinity, OptLevel::O2)
                    };
                    pending.push((pool.submit(req).unwrap(), want, affinity));
                }
                for (handle, want, affinity) in pending {
                    let resp = handle.wait().unwrap();
                    assert!(
                        affinity.matches(resp.arch, resp.kind),
                        "placement violated affinity {affinity:?}: ran on {}:{}",
                        resp.kind,
                        resp.arch
                    );
                    let got = bytes_to_f32(resp.buffers[0].as_ref().unwrap());
                    assert_eq!(got, want, "client result differs from ground truth");
                }
            });
        }
    });

    let m = pool.metrics();
    let total = (CLIENTS * PER_CLIENT) as u64;
    assert!(total >= 256, "workload must exercise >= 256 requests");
    assert_eq!(m.submitted, total);
    assert_eq!(m.completed, total);
    assert_eq!(m.failed, 0);
    assert_eq!(m.queue_depth, 0);
    // Two distinct modules over four per-device caches bound the misses.
    let cache = m.cache();
    assert_eq!(cache.hits + cache.misses, total);
    assert!(cache.misses <= 8, "at most 2 modules x 4 devices may miss: {cache:?}");
    assert!(
        cache.hit_rate() > 0.9,
        "repeated-kernel workload must exceed 90% hit rate: {cache:?}"
    );
    // The workload pins jobs to each arch and each runtime kind, so both
    // simulated targets and both runtime builds must have executed work.
    for arch in Arch::all() {
        let ran: u64 = m.devices.iter().filter(|d| d.arch == arch).map(|d| d.completed).sum();
        assert!(ran > 0, "no {arch} device ran anything");
    }
    for kind in RuntimeKind::all() {
        let ran: u64 = m.devices.iter().filter(|d| d.kind == kind).map(|d| d.completed).sum();
        assert!(ran > 0, "no {kind} device ran anything");
    }
    let per_device: u64 = m.devices.iter().map(|d| d.completed).sum();
    assert_eq!(per_device, total, "per-device counters must add up");
}

/// The same requests through the mixed pool and through a single-device
/// pool must produce bit-identical outputs.
#[test]
fn pool_results_match_single_device_execution() {
    let mixed = DevicePool::new(&PoolConfig::mixed4()).unwrap();
    let single =
        DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)).unwrap();
    for i in 0..16 {
        let n = 50 + i * 7;
        let data: Vec<f32> = (0..n).map(|k| (k * 3 + i) as f32 * 0.25).collect();
        let (req_a, _) = scale_request(&data, Affinity::any(), OptLevel::O2);
        let (req_b, _) = scale_request(&data, Affinity::any(), OptLevel::O2);
        let a = mixed.submit(req_a).unwrap().wait().unwrap();
        let b = single.submit(req_b).unwrap().wait().unwrap();
        assert_eq!(
            a.buffers[0], b.buffers[0],
            "mixed-pool output differs from single-device execution (iter {i})"
        );
    }
}

/// Hit/miss accounting: first prepare of a module on a device misses,
/// every subsequent launch of the same content hits; a different module
/// or opt level misses again.
#[test]
fn image_cache_counts_hits_and_misses() {
    let pool =
        DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)).unwrap();
    let data: Vec<f32> = (0..32).map(|i| i as f32).collect();
    for _ in 0..10 {
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        let resp = pool.submit(req).unwrap().wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    let c = pool.metrics().cache();
    assert_eq!((c.hits, c.misses), (9, 1), "10 identical submissions: 1 miss, 9 hits");

    // A different kernel module misses once, then hits.
    let x: Vec<f32> = (0..32).map(|i| i as f32).collect();
    for _ in 0..3 {
        let (req, want) = saxpy_request(2.0, &x, &x, Affinity::any(), OptLevel::O2);
        let resp = pool.submit(req).unwrap().wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    let c = pool.metrics().cache();
    assert_eq!((c.hits, c.misses), (11, 2));

    // Same module at a different opt level is a different image.
    let (req, _) = scale_request(&data, Affinity::any(), OptLevel::O0);
    pool.submit(req).unwrap().wait().unwrap();
    let c = pool.metrics().cache();
    assert_eq!(c.misses, 3, "opt level must be part of the cache key");
}

/// The first cached response must report a miss, later ones hits.
#[test]
fn responses_report_cache_hit_flag() {
    let pool =
        DevicePool::new(&PoolConfig::single(RuntimeKind::Legacy, Arch::Amdgcn)).unwrap();
    let data = vec![1.0f32; 16];
    let (req, _) = scale_request(&data, Affinity::any(), OptLevel::O2);
    let first = pool.submit(req).unwrap().wait().unwrap();
    assert!(!first.cache_hit, "first launch must prepare");
    let (req, _) = scale_request(&data, Affinity::any(), OptLevel::O2);
    let second = pool.submit(req).unwrap().wait().unwrap();
    assert!(second.cache_hit, "second launch must hit the image cache");
}

/// Arch- and kind-pinned requests run where they were pinned.
#[test]
fn affinity_pins_are_honored_per_request() {
    let pool = DevicePool::new(&PoolConfig::mixed4()).unwrap();
    let data = vec![3.0f32; 64];
    for arch in Arch::all() {
        let (req, want) = scale_request(&data, Affinity::on_arch(arch), OptLevel::O2);
        let resp = pool.submit(req).unwrap().wait().unwrap();
        assert_eq!(resp.arch, arch);
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    for kind in RuntimeKind::all() {
        let (req, want) = scale_request(&data, Affinity::on_kind(kind), OptLevel::O2);
        let resp = pool.submit(req).unwrap().wait().unwrap();
        assert_eq!(resp.kind, kind);
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
}

/// A request that fails on-device reports the error through its handle
/// and does not poison the pool for later requests.
#[test]
fn failed_request_reports_error_and_pool_survives() {
    let pool =
        DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)).unwrap();
    let data = vec![1.0f32; 8];
    let (mut req, _) = scale_request(&data, Affinity::any(), OptLevel::O2);
    req.kernel = "no_such_kernel".into();
    let err = pool.submit(req).unwrap().wait();
    assert!(err.is_err(), "launching a missing kernel must fail");
    let m = pool.metrics();
    assert_eq!(m.failed, 1);

    let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
    let resp = pool.submit(req).unwrap().wait().unwrap();
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    assert_eq!(pool.metrics().completed, 1);
}

/// Small same-image requests queued behind a long-running launch are
/// coalesced into multi-job batches — and every batched result still
/// matches the host reference.
#[test]
fn batching_coalesces_queued_small_launches() {
    let pool = DevicePool::new(
        &PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64).with_batch_max(8),
    )
    .unwrap();
    // A long launch occupies the single worker while the small requests
    // pile up behind it.
    let big: Vec<f32> = (0..200_000).map(|i| (i % 101) as f32).collect();
    let (req, big_want) = scale_request(&big, Affinity::any(), OptLevel::O2);
    let big_handle = pool.submit(req).unwrap();
    let small: Vec<f32> = (0..64).map(|i| i as f32).collect();
    let mut handles = vec![];
    for _ in 0..24 {
        let (req, want) = scale_request(&small, Affinity::any(), OptLevel::O2);
        handles.push((pool.submit(req).unwrap(), want));
    }
    let resp = big_handle.wait().unwrap();
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), big_want);
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    let m = pool.metrics();
    assert_eq!(m.completed, 25);
    assert_eq!(m.failed, 0);
    let d = &m.devices[0];
    assert!(
        d.max_batch >= 2,
        "queued same-image requests must coalesce (max batch {})",
        d.max_batch
    );
    assert!(d.max_batch <= 8, "batch_max must bound coalescing (max batch {})", d.max_batch);
    assert!(d.batched_jobs >= 2);
    assert!(d.batches < 25, "batching must reduce queue pops ({} pops)", d.batches);
    // Per-job cache accounting survives batching.
    let c = m.cache();
    assert_eq!(c.hits + c.misses, 25);
    assert_eq!(c.misses, 1, "one module, one device: exactly one prepare");
}

/// A large request with a ShardSpec splits across the uniform pool's
/// devices and the stitched result is bit-identical to the host
/// reference; the per-shard work is visible in the metrics.
#[test]
fn sharded_request_splits_and_stitches() {
    let pool = DevicePool::new(
        &PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4).with_shard_min_trips(1000),
    )
    .unwrap();
    let n = 64_000;
    let data: Vec<f32> = (0..n).map(|i| ((i * 7) % 997) as f32 * 0.5).collect();
    let (req, want) = sharded_scale_request(&data, Affinity::any(), OptLevel::O2);
    let resp = pool.submit(req).unwrap().wait().unwrap();
    assert_eq!(resp.shards, 4, "4 idle uniform devices must give 4 shards");
    assert_eq!(resp.arch, Arch::Nvptx64);
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    let m = pool.metrics();
    assert_eq!(m.sharded_requests, 1);
    assert_eq!(m.shard_jobs, 4);
    assert_eq!(m.submitted, 4, "shard jobs count individually");
    assert_eq!(m.completed, 4);
    // Multi-buffer sharding: saxpy partitions all three buffers.
    let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let y: Vec<f32> = (0..n).map(|i| (i % 31) as f32).collect();
    let (req, want) = sharded_saxpy_request(0.25, &x, &y, Affinity::any(), OptLevel::O2);
    let resp = pool.submit(req).unwrap().wait().unwrap();
    assert_eq!(resp.shards, 4);
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    // To-mapped inputs still return no post-state.
    assert!(resp.buffers[1].is_none());
    assert!(resp.buffers[2].is_none());
}

/// Below `shard_min_trips` per shard, a sharded request falls back to a
/// single device (shard overhead would dominate).
#[test]
fn sharding_falls_back_below_min_trips() {
    let pool = DevicePool::new(
        &PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4).with_shard_min_trips(4096),
    )
    .unwrap();
    let data: Vec<f32> = (0..1000).map(|i| i as f32).collect();
    let (req, want) = sharded_scale_request(&data, Affinity::any(), OptLevel::O2);
    let resp = pool.submit(req).unwrap().wait().unwrap();
    assert_eq!(resp.shards, 1, "small request must not shard");
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    let m = pool.metrics();
    assert_eq!(m.sharded_requests, 0);
    assert_eq!(m.submitted, 1);
}

/// Shards never cross architectures: on the mixed pool a shardable
/// request splits over one arch's devices only.
#[test]
fn sharding_stays_on_one_architecture() {
    let pool =
        DevicePool::new(&PoolConfig::mixed4().with_shard_min_trips(1000)).unwrap();
    let n = 64_000;
    let data: Vec<f32> = (0..n).map(|i| (i % 41) as f32).collect();
    let (req, want) = sharded_scale_request(&data, Affinity::any(), OptLevel::O2);
    let resp = pool.submit(req).unwrap().wait().unwrap();
    assert_eq!(resp.shards, 2, "mixed4 has 2 devices per arch");
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    // Pinning the arch still shards within it.
    let (req, want) = sharded_scale_request(&data, Affinity::on_arch(Arch::Amdgcn), OptLevel::O2);
    let resp = pool.submit(req).unwrap().wait().unwrap();
    assert_eq!(resp.shards, 2);
    assert_eq!(resp.arch, Arch::Amdgcn);
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
}

/// queue_cap bounds the queue: a blocked worker lets the queue fill to
/// exactly the cap, `try_submit` then reports Full (handing the request
/// back), and blocking `submit` waits for space instead of growing the
/// queue. Memory stays bounded: peak depth never exceeds the cap.
#[test]
fn backpressure_bounds_the_queue() {
    let pool = DevicePool::new(
        &PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)
            .with_queue_cap(4)
            .with_batch_max(1),
    )
    .unwrap();
    // Deterministically occupy the single worker with a gated task.
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let task = pool
        .run_on(Affinity::any(), move |_lease| {
            let _ = gate_rx.recv();
        })
        .unwrap();
    // Wait until the worker has actually claimed the task.
    while pool.metrics().queue_depth > 0 || pool.metrics().devices[0].inflight == 0 {
        clock::sleep(std::time::Duration::from_millis(1));
    }
    let data = vec![1.0f32; 16];
    // Fill the queue to the cap without blocking.
    let mut handles = vec![];
    for _ in 0..4 {
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        match pool.try_submit(req) {
            Ok(h) => handles.push((h, want)),
            Err(e) => panic!("queue below cap must accept: {e:?}"),
        }
    }
    assert_eq!(pool.metrics().queue_depth, 4);
    // At capacity: try_submit must hand the request back.
    let (req, _) = scale_request(&data, Affinity::any(), OptLevel::O2);
    let returned = match pool.try_submit(req) {
        Err(TrySubmitError::Full(r)) => r,
        other => panic!("expected Full, got {:?}", other.map(|_| "Ok(handle)")),
    };
    // A blocking submit parks until the gate opens and space drains.
    let all_done = std::thread::scope(|scope| {
        let pool = &pool;
        let blocker = scope.spawn(move || {
            let h = pool.submit(returned).unwrap(); // blocks until space
            h.wait().unwrap()
        });
        clock::sleep(std::time::Duration::from_millis(20));
        assert!(!blocker.is_finished(), "submit must block while the queue is full");
        gate_tx.send(()).unwrap();
        blocker.join().unwrap()
    });
    assert_eq!(bytes_to_f32(all_done.buffers[0].as_ref().unwrap()), vec![2.0f32; 16]);
    task.wait().unwrap();
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    let m = pool.metrics();
    assert!(
        m.peak_queue_depth <= 4,
        "queue depth must never exceed the cap (peak {})",
        m.peak_queue_depth
    );
    assert_eq!(m.failed, 0);
}

/// Lost-wakeup regression: a single batched pop frees several queue
/// slots at once, and *every* submitter blocked on the `space` condvar
/// must observe the space — waking only one (or none) would leave the
/// rest parked forever even though the queue has room.
#[test]
fn batched_pop_unblocks_every_waiting_submitter() {
    let pool = DevicePool::new(
        &PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)
            .with_queue_cap(4)
            .with_batch_max(8),
    )
    .unwrap();
    // Deterministically occupy the single worker with a gated task.
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let task = pool
        .run_on(Affinity::any(), move |_lease| {
            let _ = gate_rx.recv();
        })
        .unwrap();
    while pool.metrics().queue_depth > 0 || pool.metrics().devices[0].inflight == 0 {
        clock::sleep(Duration::from_millis(1));
    }
    // Fill the queue to the cap with same-image requests: the worker's
    // next visit coalesces all four into one pop, freeing 4 slots.
    let data = vec![1.0f32; 16];
    let mut handles = vec![];
    for _ in 0..4 {
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        handles.push((pool.submit(req).unwrap(), want));
    }
    assert_eq!(pool.metrics().queue_depth, 4);
    // Three submitters block on the full queue at once.
    let blocked = std::thread::scope(|scope| {
        let pool = &pool;
        let blockers: Vec<_> = (0..3)
            .map(|_| {
                let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
                scope.spawn(move || {
                    let h = pool.submit(req).unwrap(); // blocks until space
                    (h.wait().unwrap(), want)
                })
            })
            .collect();
        clock::sleep(Duration::from_millis(30));
        for b in &blockers {
            assert!(!b.is_finished(), "submit must block while the queue is full");
        }
        // One batched pop must free enough space for all three.
        gate_tx.send(()).unwrap();
        blockers.into_iter().map(|b| b.join().unwrap()).collect::<Vec<_>>()
    });
    for (resp, want) in blocked {
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    task.wait().unwrap();
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    let m = pool.metrics();
    assert_eq!(m.failed, 0);
    assert!(
        m.peak_queue_depth <= 4,
        "queue depth must never exceed the cap (peak {})",
        m.peak_queue_depth
    );
}

/// Starvation regression: one chatty client floods a 2-device pool with
/// a deep backlog, then three quiet clients submit small bursts. With
/// weighted-DRR fairness the quiet bursts must finish while the chatty
/// backlog is still draining, and their queue-wait tail must undercut
/// the chatty tail — under the old global FIFO they would have waited
/// behind all of it.
#[test]
fn quiet_clients_are_not_starved_by_a_chatty_one() {
    const CHATTY: usize = 400;
    const QUIET: usize = 8;
    let pool =
        DevicePool::new(&PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 2)).unwrap();
    // Gate both workers so the backlog builds deterministically.
    let mut gates = vec![];
    let mut tasks = vec![];
    for _ in 0..2 {
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        tasks.push(
            pool.run_on(Affinity::any(), move |_lease| {
                let _ = rx.recv();
            })
            .unwrap(),
        );
        gates.push(tx);
    }
    while pool.metrics().queue_depth > 0
        || pool.metrics().devices.iter().any(|d| d.inflight == 0)
    {
        clock::sleep(Duration::from_millis(1));
    }
    // Distinct scale factors → distinct modules per client, so quiet
    // jobs cannot ride the chatty client's fused batches.
    let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
    let mut chatty_handles = vec![];
    for _ in 0..CHATTY {
        let (mut req, want) = scale_request_by(1.5, &data, Affinity::any(), OptLevel::O2);
        req.client = "chatty".into();
        chatty_handles.push((pool.submit(req).unwrap(), want));
    }
    let mut quiet_handles: Vec<Vec<_>> = vec![];
    for (qi, factor) in [2.5f32, 3.5, 4.5].iter().enumerate() {
        let mut hs = vec![];
        for _ in 0..QUIET {
            let (mut req, want) = scale_request_by(*factor, &data, Affinity::any(), OptLevel::O2);
            req.client = format!("quiet{qi}");
            hs.push((pool.submit(req).unwrap(), want));
        }
        quiet_handles.push(hs);
    }
    for g in gates {
        g.send(()).unwrap();
    }
    // The first quiet burst must complete while the chatty backlog still
    // drains: two more quiet lanes are backlogged at that point, so DRR
    // cannot have granted chatty more than a rotation's worth of pops.
    let mut quiet_waits: Vec<Duration> = vec![];
    let mut first = true;
    for hs in quiet_handles {
        for (h, want) in hs {
            let resp = h.wait().unwrap();
            assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
            quiet_waits.push(resp.queue_wait);
        }
        if first {
            first = false;
            let chatty_done_then = pool
                .metrics()
                .clients
                .iter()
                .find(|c| c.client == "chatty")
                .map_or(0, |c| c.completed);
            assert!(
                (chatty_done_then as usize) < CHATTY,
                "all {CHATTY} chatty requests finished before the quiet clients — starved"
            );
        }
    }
    let mut chatty_waits: Vec<Duration> = vec![];
    for (h, want) in chatty_handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
        chatty_waits.push(resp.queue_wait);
    }
    for t in tasks {
        t.wait().unwrap();
    }
    // Queue waits are recorded by the workers, so these percentiles are
    // immune to test-thread scheduling: under the old global FIFO the
    // quiet tail would sit *behind* the whole chatty backlog (quiet p95
    // >> chatty p50); under DRR it undercuts the chatty median.
    quiet_waits.sort();
    chatty_waits.sort();
    let quiet_p95 = quiet_waits[(quiet_waits.len() * 95 / 100).min(quiet_waits.len() - 1)];
    let chatty_p50 = chatty_waits[chatty_waits.len() / 2];
    assert!(
        quiet_p95 < chatty_p50,
        "quiet p95 queue wait ({quiet_p95:?}) must undercut the chatty median ({chatty_p50:?})"
    );
    // Every client's throughput is visible in the fairness metrics.
    pool.quiesce();
    let m = pool.metrics();
    for qi in 0..3 {
        let name = format!("quiet{qi}");
        let row = m.clients.iter().find(|c| c.client == name).expect("quiet client row");
        assert_eq!(row.completed, QUIET as u64);
        assert!(m.client_share(&name) > 0.0);
    }
}

/// Per-client accounting counts a sharded request once (its stitcher
/// records it), while job-level pool totals count the shard jobs — and
/// reservations drain back to zero.
#[test]
fn shard_metrics_do_not_double_count() {
    let pool = DevicePool::new(
        &PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4).with_shard_min_trips(1000),
    )
    .unwrap();
    let n = 64_000;
    let data: Vec<f32> = (0..n).map(|i| ((i * 3) % 89) as f32).collect();
    let (mut req, want) = sharded_scale_request(&data, Affinity::any(), OptLevel::O2);
    req.client = "shardy".into();
    let resp = pool.submit(req).unwrap().wait().unwrap();
    assert_eq!(resp.shards, 4);
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    pool.quiesce();
    let m = pool.metrics();
    // Job-level totals: one entry per shard job, no stitched extras.
    assert_eq!(m.sharded_requests, 1);
    assert_eq!(m.shard_jobs, 4);
    assert_eq!(m.submitted, 4);
    assert_eq!(m.completed, 4);
    let per_device: u64 = m.devices.iter().map(|d| d.completed).sum();
    assert_eq!(per_device, 4, "stitching must not double-count device completions");
    // Client-level totals: the split request is one request.
    let row = m.clients.iter().find(|c| c.client == "shardy").expect("client row");
    assert_eq!((row.completed, row.failed), (1, 0));
    assert_eq!(row.latency.count(), 1);
    // Reservations were consumed when the pinned shards were claimed.
    for d in &m.devices {
        assert_eq!(d.reserved, 0, "device {} still holds a reservation", d.id);
    }
}

/// Static mode (`adaptive = false`, `fairness = false`) preserves the
/// PR-2 scheduler: fixed batch limit, global FIFO, correct results.
#[test]
fn static_mode_still_serves_correct_results() {
    let pool = DevicePool::new(
        &PoolConfig::mixed4().with_adaptive(false).with_fairness(false),
    )
    .unwrap();
    let data: Vec<f32> = (0..96).map(|i| i as f32).collect();
    let mut handles = vec![];
    for i in 0..32 {
        let (mut req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        req.client = format!("c{}", i % 4);
        handles.push((pool.submit(req).unwrap(), want));
    }
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    pool.quiesce();
    let m = pool.metrics();
    assert_eq!(m.completed, 32);
    assert!(!m.adaptive);
    assert_eq!(m.adaptive_stats.decisions, 0, "static mode must not consult the controller");
    // Client tags are still *accounted* even when fairness scheduling
    // is off (they just share one lane).
    let total: u64 = m.clients.iter().map(|c| c.completed).sum();
    assert_eq!(total, 32);
}

/// Device leases run arbitrary closures on pool workers with exclusive
/// device access, scheduled and counted like any job.
#[test]
fn device_leases_run_closures_with_affinity() {
    let pool = DevicePool::new(&PoolConfig::mixed4()).unwrap();
    let handle = pool
        .run_on(Affinity::on_arch(Arch::Amdgcn), |lease| {
            (lease.spec.arch, lease.device.arch())
        })
        .unwrap();
    let (spec_arch, dev_arch) = handle.wait().unwrap();
    assert_eq!(spec_arch, Arch::Amdgcn);
    assert_eq!(dev_arch, Arch::Amdgcn);
    // The worker counts the task completed after the closure returns;
    // quiesce before reading the counter.
    pool.quiesce();
    assert_eq!(pool.metrics().completed, 1);
    // Unroutable affinity is rejected at submit time.
    let pool = DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64))
        .unwrap();
    assert!(pool.run_on(Affinity::on_arch(Arch::Amdgcn), |_| ()).is_err());
}

/// A panicking lease closure must not kill the device's worker: the
/// task's handle errors and the device keeps serving later requests.
#[test]
fn panicking_lease_does_not_kill_the_worker() {
    let pool = DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64))
        .unwrap();
    let task = pool
        .run_on(Affinity::any(), |_lease| -> () { panic!("lease gone wrong") })
        .unwrap();
    assert!(task.wait().is_err(), "panicked task must resolve to an error");
    // The single worker survived: an ordinary request still completes.
    let data = vec![1.5f32; 8];
    let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
    let resp = pool.submit(req).unwrap().wait().unwrap();
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    pool.quiesce();
    let m = pool.metrics();
    assert_eq!(m.failed, 1, "the panicked task counts as failed");
    assert_eq!(m.completed, 1);
}

/// The PoolCoordinator merges per-device profiles into region totals that
/// account for every launch.
#[test]
fn pool_coordinator_report_accounts_for_all_launches() {
    let pc = PoolCoordinator::new(&PoolConfig::mixed4()).unwrap();
    let data: Vec<f32> = (0..48).map(|i| i as f32).collect();
    let mut handles = vec![];
    for _ in 0..24 {
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        handles.push((pc.submit(req).unwrap(), want));
    }
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    let regions = pc.region_report();
    let scale = regions.iter().find(|r| r.name == "scale").expect("scale region");
    assert_eq!(scale.summary.count(), 24, "every launch must be profiled");
    let text = pc.format_report();
    assert!(text.contains("launches/s"), "{text}");
}
