//! Occupancy-driven adaptive scheduling: pick the effective batch size
//! and shard fan-out per queue visit from live signals instead of static
//! `[pool]` knobs.
//!
//! The paper's thesis is "portable without a performance penalty"; for
//! the device pool that means the scheduler cannot run on fixed tuning
//! constants — a `batch_max` that wins under a deep queue adds latency
//! under a shallow one, and a shard fan-out equal to the device count
//! serializes behind busy devices. This module holds the *policy*:
//!
//! * [`decide_batch_max`] — how many same-image jobs a worker should try
//!   to coalesce on this visit, from queue depth, idle-device count and
//!   the recent *fused-grid efficiency* (how full past batches actually
//!   came out relative to what the controller asked for);
//! * [`decide_shard_fanout`] — how many ways to split a sharded request,
//!   preferring *idle* devices (which the pool then reserves for the
//!   split) over the static all-eligible-devices count;
//! * [`AdaptiveController`] — the tiny mutable state behind those
//!   decisions: an EWMA of observed batch efficiency plus decision
//!   counters for the `PoolCoordinator` report.
//!
//! Both `decide_*` functions are **pure** (sampled signals in, sizes
//! out) so the policy is unit-testable without threads or devices.

use std::sync::atomic::{AtomicU64, Ordering};

/// Signals sampled at one queue visit (under the queue lock, so `depth`
/// is exact; `idle_devices` is a racy-but-recent atomic sample).
#[derive(Debug, Clone, Copy)]
pub struct SchedSignals {
    /// Jobs currently queued, pool-wide.
    pub queue_depth: usize,
    /// Devices with no in-flight work right now (including the sampler).
    pub idle_devices: usize,
    /// Total devices in the pool.
    pub device_count: usize,
    /// EWMA of observed batch fill: popped jobs / decided limit, in
    /// `[0, 1]`. 1.0 = every decided slot was filled by a compatible job.
    pub batch_efficiency: f64,
    /// A queued request eligible for this worker is inside its SLO panic
    /// window (deadline minus predicted service time — see
    /// [`crate::sched::slo::ServiceEwma`]). Urgent work must not be
    /// trapped behind a long fused grid, so the decided limit collapses
    /// to 1 while this holds.
    pub urgent: bool,
}

/// Effective batch limit for one queue visit.
///
/// Policy: split the backlog evenly over the idle workers (an idle
/// worker will pop right behind us, so grabbing the whole queue starves
/// the parallelism batching is supposed to feed), then shrink by the
/// observed efficiency — if recent batches came back mostly empty the
/// queue is key-diverse and a large scan limit only buys O(depth)
/// compare work. Always within `[1, cap]`; a depth of 0 or 1 degrades
/// to unbatched pops (lowest latency).
///
/// When `urgent` is set — some eligible lane is inside its SLO panic
/// window — the limit collapses to 1 regardless of depth: every pop
/// must return its device to the queue as fast as possible so deadline
/// work is never stuck behind a fused grid of bulk launches.
pub fn decide_batch_max(s: &SchedSignals, cap: usize) -> usize {
    let cap = cap.max(1);
    if s.urgent {
        return 1;
    }
    if s.queue_depth <= 1 {
        return 1;
    }
    let share = s.queue_depth.div_ceil(s.idle_devices.max(1));
    let eff = if s.batch_efficiency.is_finite() {
        s.batch_efficiency.clamp(0.25, 1.0)
    } else {
        1.0
    };
    let scaled = ((share as f64) * eff).ceil() as usize;
    scaled.clamp(1, cap)
}

/// Shard fan-out for a splittable request.
///
/// * `idle_eligible` — idle devices of the chosen architecture (these
///   are what the pool will reserve);
/// * `eligible` — all matching devices of that architecture;
/// * `max_by_elems` — `elems / shard_min_trips`, the most shards that
///   still give every shard a worthwhile trip count;
/// * `cap` — hard bound (the queue capacity clamp).
///
/// With two or more idle devices the fan-out is the idle count — each
/// shard lands on a device that can start immediately, so the stitch
/// finishes in one wave. With fewer than two idle devices the static
/// fan-out (`eligible`) is used instead: the split still wins once the
/// busy devices drain, and a fan-out of one would just serialize.
/// A result `< 2` means "do not shard".
pub fn decide_shard_fanout(
    idle_eligible: usize,
    eligible: usize,
    max_by_elems: usize,
    cap: usize,
) -> usize {
    let base = if idle_eligible >= 2 { idle_eligible } else { eligible };
    base.min(max_by_elems).min(cap.max(1))
}

/// Snapshot of the controller's accumulated state (for reports).
#[derive(Debug, Clone, Copy, Default)]
pub struct AdaptiveStats {
    /// Queue visits that ran the decision function.
    pub decisions: u64,
    /// Sum of decided batch limits (avg = `decided_sum / decisions`).
    pub decided_sum: u64,
    /// Current fused-grid efficiency EWMA in `[0, 1]`.
    pub efficiency: f64,
}

impl AdaptiveStats {
    /// Mean decided batch limit (0 when no decisions yet).
    pub fn avg_decided(&self) -> f64 {
        if self.decisions == 0 {
            0.0
        } else {
            self.decided_sum as f64 / self.decisions as f64
        }
    }
}

/// Shared mutable state behind the adaptive policy. All fields are
/// atomics — workers consult and update it without extra locking.
pub struct AdaptiveController {
    /// EWMA of observed batch fill, stored as `f64::to_bits`.
    efficiency_bits: AtomicU64,
    decisions: AtomicU64,
    decided_sum: AtomicU64,
}

/// EWMA smoothing factor: one observation moves the estimate 20% of the
/// way — a handful of diverse pops is enough to shrink scan limits, a
/// handful of full batches restores them.
const EWMA_ALPHA: f64 = 0.2;

impl Default for AdaptiveController {
    fn default() -> Self {
        AdaptiveController::new()
    }
}

impl AdaptiveController {
    /// Fresh controller; efficiency starts optimistic (1.0).
    pub fn new() -> Self {
        AdaptiveController {
            efficiency_bits: AtomicU64::new(1.0f64.to_bits()),
            decisions: AtomicU64::new(0),
            decided_sum: AtomicU64::new(0),
        }
    }

    /// Current efficiency EWMA.
    pub fn efficiency(&self) -> f64 {
        f64::from_bits(self.efficiency_bits.load(Ordering::Relaxed))
    }

    /// Record the outcome of one decided pop: the worker asked for up to
    /// `asked` jobs ([`decide_batch_max`]'s answer) and actually popped
    /// `got`. Counts the decision and, when the pop was batchable
    /// (`asked > 1`), folds the fill ratio into the efficiency EWMA —
    /// unbatchable pops carry no signal about key diversity.
    pub fn record(&self, asked: usize, got: usize) {
        self.decisions.fetch_add(1, Ordering::Relaxed);
        self.decided_sum.fetch_add(asked as u64, Ordering::Relaxed);
        if asked <= 1 {
            return;
        }
        let obs = (got as f64 / asked as f64).clamp(0.0, 1.0);
        // Racy read-modify-write is fine: the EWMA is a heuristic, and a
        // lost update just weights a neighbor observation instead.
        let cur = self.efficiency();
        let next = cur + EWMA_ALPHA * (obs - cur);
        self.efficiency_bits.store(next.to_bits(), Ordering::Relaxed);
    }

    /// Counters + current EWMA for the pool report.
    pub fn stats(&self) -> AdaptiveStats {
        AdaptiveStats {
            decisions: self.decisions.load(Ordering::Relaxed),
            decided_sum: self.decided_sum.load(Ordering::Relaxed),
            efficiency: self.efficiency(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn signals(depth: usize, idle: usize, eff: f64) -> SchedSignals {
        SchedSignals {
            queue_depth: depth,
            idle_devices: idle,
            device_count: 4,
            batch_efficiency: eff,
            urgent: false,
        }
    }

    #[test]
    fn empty_or_single_queue_never_batches() {
        assert_eq!(decide_batch_max(&signals(0, 4, 1.0), 32), 1);
        assert_eq!(decide_batch_max(&signals(1, 0, 1.0), 32), 1);
    }

    #[test]
    fn deep_queue_splits_over_idle_devices() {
        // 64 queued over 4 idle workers: 16 each.
        assert_eq!(decide_batch_max(&signals(64, 4, 1.0), 32), 16);
        // Only this worker idle: take up to the cap.
        assert_eq!(decide_batch_max(&signals(64, 1, 1.0), 32), 32);
        // Zero sampled idle (racy sample) behaves like one.
        assert_eq!(decide_batch_max(&signals(64, 0, 1.0), 32), 32);
    }

    #[test]
    fn low_efficiency_shrinks_the_scan_limit() {
        let full = decide_batch_max(&signals(64, 1, 1.0), 32);
        let diverse = decide_batch_max(&signals(64, 1, 0.25), 32);
        assert!(diverse < full, "diverse queues must shrink the limit ({diverse} vs {full})");
        assert!(diverse >= 1);
        // Efficiency is floored: even 0.0 keeps a quarter of the share.
        assert_eq!(decide_batch_max(&signals(64, 1, 0.0), 32), 16);
    }

    #[test]
    fn urgent_forces_single_pops() {
        // A deep queue that would normally batch hard collapses to
        // singles while SLO panic work is visible.
        let mut s = signals(64, 1, 1.0);
        s.urgent = true;
        assert_eq!(decide_batch_max(&s, 32), 1);
        // ...and recovers the moment the panic clears.
        s.urgent = false;
        assert_eq!(decide_batch_max(&s, 32), 32);
    }

    #[test]
    fn decided_limit_is_always_within_bounds() {
        for depth in [0usize, 1, 2, 5, 17, 1000] {
            for idle in [0usize, 1, 2, 4] {
                for eff in [-1.0, 0.0, 0.3, 0.99, 1.0, 2.0, f64::NAN] {
                    let d = decide_batch_max(&signals(depth, idle, eff), 8);
                    assert!((1..=8).contains(&d), "decide({depth},{idle},{eff}) = {d}");
                }
            }
        }
    }

    #[test]
    fn shard_fanout_prefers_idle_devices() {
        // 3 idle of 4 eligible: split 3 ways, not 4.
        assert_eq!(decide_shard_fanout(3, 4, 100, 1024), 3);
        // All idle: the static and adaptive plans agree.
        assert_eq!(decide_shard_fanout(4, 4, 100, 1024), 4);
        // Fewer than 2 idle: fall back to the static all-eligible plan.
        assert_eq!(decide_shard_fanout(1, 4, 100, 1024), 4);
        assert_eq!(decide_shard_fanout(0, 4, 100, 1024), 4);
    }

    #[test]
    fn shard_fanout_respects_elems_and_cap() {
        // Element budget limits the split.
        assert_eq!(decide_shard_fanout(4, 4, 3, 1024), 3);
        // Queue capacity clamps it.
        assert_eq!(decide_shard_fanout(8, 8, 100, 4), 4);
        // Too small to split at all.
        assert!(decide_shard_fanout(4, 4, 1, 1024) < 2);
    }

    #[test]
    fn controller_ewma_tracks_observations() {
        let c = AdaptiveController::new();
        assert!((c.efficiency() - 1.0).abs() < 1e-12);
        // Repeated quarter-full batches pull the EWMA down.
        for _ in 0..32 {
            c.record(32, 8);
        }
        assert!(c.efficiency() < 0.4, "EWMA must approach 0.25: {}", c.efficiency());
        // Full batches pull it back up.
        for _ in 0..32 {
            c.record(32, 32);
        }
        assert!(c.efficiency() > 0.8, "EWMA must recover: {}", c.efficiency());
        // Unbatchable pops carry no efficiency signal.
        let before = c.efficiency();
        c.record(1, 1);
        assert_eq!(c.efficiency(), before);
    }

    #[test]
    fn controller_counts_decisions() {
        let c = AdaptiveController::new();
        c.record(8, 8);
        c.record(1, 1);
        let s = c.stats();
        assert_eq!(s.decisions, 2);
        assert_eq!(s.decided_sum, 9);
        assert!((s.avg_decided() - 4.5).abs() < 1e-12);
    }
}
