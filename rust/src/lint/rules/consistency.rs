//! Rule `consistency`: cross-file enumerations stay in lockstep.
//!
//! Two families of drift this repo has had to re-check by hand on every
//! PR:
//!
//! 1. **Trace schema.** `EventKind` appears four times in
//!    `rust/src/trace/event.rs`: the enum declaration (with explicit
//!    discriminants), the `from_u8` decode match, the `name()` string
//!    match, and the roundtrip test's `1u8..=19` range literal. Adding a
//!    variant and missing one of the four compiles fine (`_ => None`
//!    swallows it) but silently drops events from `trace-validate` and
//!    the exporter. The rule re-derives all four sets and diffs them.
//!
//! 2. **Config surface.** Every `[pool]` key read in
//!    `rust/src/sched/pool.rs::from_config` should be reachable from the
//!    CLI (where a flag exists) and documented in README's flag table.
//!    `lint/rules/consistency.list` declares the mapping
//!    (`key|flag,flag|readme-token,…`); the rule checks it
//!    bidirectionally against the actual `read_*`/`sec.get` calls, the
//!    string literals in `rust/src/cli/mod.rs`, and README.md.

use std::collections::BTreeMap;
use std::path::Path;

use crate::lint::lexer::{lex, Tok, TokKind};
use crate::lint::{Finding, Manifests};

const EVENT: &str = "rust/src/trace/event.rs";
const POOL: &str = "rust/src/sched/pool.rs";
const CLI: &str = "rust/src/cli/mod.rs";

/// One `key|flags|readme-tokens` row of `consistency.list`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Row {
    /// `[pool]` config key.
    pub key: String,
    /// CLI flag names (without `--`) that feed this key; empty when the
    /// key is config-file-only.
    pub flags: Vec<String>,
    /// Tokens that must appear in README.md; empty to skip.
    pub readme: Vec<String>,
}

impl Row {
    /// Parse `key|flag,flag|--tok,--tok` (both lists may be empty).
    pub fn parse(entry: &str) -> crate::Result<Row> {
        let parts: Vec<&str> = entry.split('|').collect();
        if parts.len() != 3 || parts[0].trim().is_empty() {
            return Err(crate::util::Error::Config(format!(
                "consistency.list: `{entry}` wants `key|flags|readme` (3 `|`-separated fields)"
            )));
        }
        let list = |s: &str| {
            s.split(',')
                .map(str::trim)
                .filter(|x| !x.is_empty())
                .map(str::to_string)
                .collect()
        };
        Ok(Row { key: parts[0].trim().to_string(), flags: list(parts[1]), readme: list(parts[2]) })
    }
}

fn finding(file: &str, line: u32, msg: String) -> Finding {
    Finding { file: file.to_string(), line, rule: "consistency", msg }
}

fn leading_digits(s: &str) -> Option<u32> {
    let d: String = s.chars().take_while(|c| c.is_ascii_digit()).collect();
    d.parse().ok()
}

/// Index of the first occurrence of consecutive idents `a b`, if any.
fn find_fn(toks: &[Tok], name: &str) -> Option<usize> {
    (1..toks.len()).find(|&i| toks[i - 1].is_ident("fn") && toks[i].is_ident(name))
}

/// Extract the `EventKind` enum's `(variant, discriminant, line)` rows.
fn enum_variants(toks: &[Tok]) -> Vec<(String, u32, u32)> {
    let mut out = Vec::new();
    let Some(start) = (1..toks.len())
        .find(|&i| toks[i - 1].is_ident("enum") && toks[i].is_ident("EventKind"))
    else {
        return out;
    };
    let mut depth = 0i32;
    let mut i = start + 1;
    while i < toks.len() {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
        }
        if depth == 1
            && t.kind == TokKind::Ident
            && toks.get(i + 1).is_some_and(|e| e.is_punct("="))
            && toks.get(i + 2).is_some_and(|v| v.kind == TokKind::Num)
        {
            if let Some(v) = leading_digits(&toks[i + 2].text) {
                out.push((t.text.clone(), v, t.line));
            }
            i += 3;
            continue;
        }
        i += 1;
    }
    out
}

/// Collect `N => EventKind::Variant` arms between `fn from_u8` and the
/// next `fn`.
fn from_u8_arms(toks: &[Tok]) -> Vec<(u32, String, u32)> {
    let mut out = Vec::new();
    let Some(start) = find_fn(toks, "from_u8") else { return out };
    for i in start..toks.len() {
        if toks[i].is_ident("fn") && i > start {
            break;
        }
        if toks[i].kind == TokKind::Num
            && toks.get(i + 1).is_some_and(|a| a.is_punct("="))
            && toks.get(i + 2).is_some_and(|a| a.is_punct(">"))
            && toks.get(i + 3).is_some_and(|a| a.is_ident("EventKind"))
            && toks.get(i + 4).is_some_and(|a| a.is_punct("::"))
            && toks.get(i + 5).is_some_and(|a| a.kind == TokKind::Ident)
        {
            if let Some(v) = leading_digits(&toks[i].text) {
                out.push((v, toks[i + 5].text.clone(), toks[i].line));
            }
        }
    }
    out
}

/// Collect `EventKind::Variant => "Str"` arms between `fn name` and the
/// next `fn`.
fn name_arms(toks: &[Tok]) -> Vec<(String, String, u32)> {
    let mut out = Vec::new();
    let Some(start) = find_fn(toks, "name") else { return out };
    for i in start..toks.len() {
        if toks[i].is_ident("fn") && i > start {
            break;
        }
        if toks[i].is_ident("EventKind")
            && toks.get(i + 1).is_some_and(|a| a.is_punct("::"))
            && toks.get(i + 2).is_some_and(|a| a.kind == TokKind::Ident)
            && toks.get(i + 3).is_some_and(|a| a.is_punct("="))
            && toks.get(i + 4).is_some_and(|a| a.is_punct(">"))
            && toks.get(i + 5).is_some_and(|a| a.kind == TokKind::Str)
        {
            out.push((toks[i + 2].text.clone(), toks[i + 5].text.clone(), toks[i].line));
        }
    }
    out
}

/// Does any `lo..=hi` range literal in `toks` cover exactly `min..=max`?
fn has_roundtrip_range(toks: &[Tok], min: u32, max: u32) -> bool {
    (0..toks.len().saturating_sub(4)).any(|i| {
        toks[i].kind == TokKind::Num
            && toks[i + 1].is_punct(".")
            && toks[i + 2].is_punct(".")
            && toks[i + 3].is_punct("=")
            && toks[i + 4].kind == TokKind::Num
            && leading_digits(&toks[i].text) == Some(min)
            && leading_digits(&toks[i + 4].text) == Some(max)
    })
}

fn check_trace_schema(sources: &BTreeMap<String, String>, out: &mut Vec<Finding>) {
    let Some(src) = sources.get(EVENT) else {
        out.push(finding(EVENT, 0, "file missing — trace schema checks skipped".into()));
        return;
    };
    let toks = lex(src);
    let variants = enum_variants(&toks);
    if variants.is_empty() {
        out.push(finding(EVENT, 0, "no `enum EventKind` variants found".into()));
        return;
    }
    let decode = from_u8_arms(&toks);
    let names = name_arms(&toks);
    for (var, val, line) in &variants {
        match decode.iter().find(|(_, v, _)| v == var) {
            None => out.push(finding(
                EVENT,
                *line,
                format!("`EventKind::{var}` has no `from_u8` arm — decode drops it"),
            )),
            Some((dv, _, dline)) if dv != val => out.push(finding(
                EVENT,
                *dline,
                format!("`from_u8` maps {dv} to `EventKind::{var}` but the discriminant is {val}"),
            )),
            _ => {}
        }
        match names.iter().find(|(v, _, _)| v == var) {
            None => out.push(finding(
                EVENT,
                *line,
                format!("`EventKind::{var}` has no `name()` arm"),
            )),
            Some((_, s, nline)) if s != var => out.push(finding(
                EVENT,
                *nline,
                format!("`name()` renders `EventKind::{var}` as \"{s}\""),
            )),
            _ => {}
        }
    }
    for (val, var, line) in &decode {
        if !variants.iter().any(|(v, _, _)| v == var) {
            out.push(finding(
                EVENT,
                *line,
                format!("`from_u8` arm {val} => `EventKind::{var}`: no such variant"),
            ));
        }
    }
    let min = variants.iter().map(|(_, v, _)| *v).min().unwrap_or(0);
    let max = variants.iter().map(|(_, v, _)| *v).max().unwrap_or(0);
    if !has_roundtrip_range(&toks, min, max) {
        out.push(finding(
            EVENT,
            0,
            format!(
                "no `{min}u8..={max}` roundtrip range found — the roundtrip test no longer \
                 covers every variant"
            ),
        ));
    }
}

/// `[pool]` keys actually read in `from_config`: `read_*(sec, "key", …)`
/// and `sec.get("key")` call sites.
fn pool_keys(toks: &[Tok]) -> Vec<(String, u32)> {
    let mut out: Vec<(String, u32)> = Vec::new();
    for i in 0..toks.len() {
        let key = if toks[i].kind == TokKind::Ident
            && toks[i].text.starts_with("read_")
            && toks.get(i + 1).is_some_and(|a| a.is_punct("("))
            && toks.get(i + 2).is_some_and(|a| a.is_ident("sec"))
            && toks.get(i + 3).is_some_and(|a| a.is_punct(","))
            && toks.get(i + 4).is_some_and(|a| a.kind == TokKind::Str)
        {
            Some(&toks[i + 4])
        } else if toks[i].is_ident("sec")
            && toks.get(i + 1).is_some_and(|a| a.is_punct("."))
            && toks.get(i + 2).is_some_and(|a| a.is_ident("get"))
            && toks.get(i + 3).is_some_and(|a| a.is_punct("("))
            && toks.get(i + 4).is_some_and(|a| a.kind == TokKind::Str)
        {
            Some(&toks[i + 4])
        } else {
            None
        };
        if let Some(t) = key {
            if !out.iter().any(|(k, _)| *k == t.text) {
                out.push((t.text.clone(), t.line));
            }
        }
    }
    out
}

fn check_config_surface(
    sources: &BTreeMap<String, String>,
    readme: &str,
    rows: &[Row],
    out: &mut Vec<Finding>,
) {
    let Some(pool_src) = sources.get(POOL) else {
        out.push(finding(POOL, 0, "file missing — config surface checks skipped".into()));
        return;
    };
    let keys = pool_keys(&lex(pool_src));
    let cli_strings: Vec<String> = sources
        .get(CLI)
        .map(|src| {
            lex(src)
                .into_iter()
                .filter(|t| t.kind == TokKind::Str)
                .map(|t| t.text)
                .collect()
        })
        .unwrap_or_default();
    for (key, line) in &keys {
        if !rows.iter().any(|r| r.key == *key) {
            out.push(finding(
                POOL,
                *line,
                format!(
                    "`[pool]` key \"{key}\" is read here but missing from \
                     lint/rules/consistency.list"
                ),
            ));
        }
    }
    for row in rows {
        if !keys.iter().any(|(k, _)| *k == row.key) {
            out.push(finding(
                POOL,
                0,
                format!(
                    "consistency.list declares `[pool]` key \"{}\" but from_config never \
                     reads it",
                    row.key
                ),
            ));
        }
        for flag in &row.flags {
            if !cli_strings.iter().any(|s| s == flag) {
                out.push(finding(
                    CLI,
                    0,
                    format!(
                        "flag \"{flag}\" (for `[pool]` key \"{}\") is not a string literal \
                         in the CLI parser",
                        row.key
                    ),
                ));
            }
        }
        for tok in &row.readme {
            if !readme.contains(tok.as_str()) {
                out.push(finding(
                    "README.md",
                    0,
                    format!("\"{tok}\" (for `[pool]` key \"{}\") missing from README.md", row.key),
                ));
            }
        }
    }
}

fn check_impl(
    sources: &BTreeMap<String, String>,
    readme: &str,
    m: &Manifests,
) -> Vec<Finding> {
    let mut out = Vec::new();
    check_trace_schema(sources, &mut out);
    check_config_surface(sources, readme, &m.consistency, &mut out);
    out
}

/// Run the cross-file checks over the whole source map.
pub fn check(root: &Path, sources: &BTreeMap<String, String>, m: &Manifests) -> Vec<Finding> {
    let readme = std::fs::read_to_string(root.join("README.md")).unwrap_or_default();
    let mut out = check_impl(sources, &readme, m);
    if readme.is_empty() {
        out.push(finding("README.md", 0, "README.md missing or empty".into()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD_EVENT: &str = r#"
        pub enum EventKind { Submit = 1, Done = 2 }
        impl EventKind {
            pub fn from_u8(v: u8) -> Option<EventKind> {
                Some(match v { 1 => EventKind::Submit, 2 => EventKind::Done, _ => return None })
            }
            pub fn name(&self) -> &'static str {
                match self { EventKind::Submit => "Submit", EventKind::Done => "Done" }
            }
        }
        #[test] fn roundtrip() { for k in 1u8..=2 { let _ = EventKind::from_u8(k); } }
    "#;

    const GOOD_POOL: &str = r#"
        fn from_config(sec: &Section) {
            out.batch_max = read_uint(sec, "batch_max", 1, 1)?;
            out.hedge = read_bool(sec, "hedge", true)?;
            if let Some(v) = sec.get("devices") {}
        }
    "#;

    const GOOD_CLI: &str = r#"fn parse() { uint("batch"); flag("hedge"); flag("no-hedge"); }"#;
    const GOOD_README: &str = "| `--batch N` | … | | `--hedge` / `--no-hedge` | … |";

    fn rows() -> Vec<Row> {
        vec![
            Row::parse("batch_max|batch|--batch").unwrap(),
            Row::parse("hedge|hedge,no-hedge|--hedge,--no-hedge").unwrap(),
            Row::parse("devices||").unwrap(),
        ]
    }

    fn srcs(event: &str, pool: &str, cli: &str) -> BTreeMap<String, String> {
        let mut s = BTreeMap::new();
        s.insert(EVENT.to_string(), event.to_string());
        s.insert(POOL.to_string(), pool.to_string());
        s.insert(CLI.to_string(), cli.to_string());
        s
    }

    fn run(event: &str, pool: &str, cli: &str, readme: &str) -> Vec<Finding> {
        let m = Manifests { consistency: rows(), ..Manifests::default() };
        check_impl(&srcs(event, pool, cli), readme, &m)
    }

    #[test]
    fn consistent_tree_passes() {
        let got = run(GOOD_EVENT, GOOD_POOL, GOOD_CLI, GOOD_README);
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn row_parse_rejects_malformed_entries() {
        assert!(Row::parse("only_key").is_err());
        assert!(Row::parse("|flags|readme").is_err());
        let r = Row::parse("k | a,b | --a").unwrap();
        assert_eq!((r.key.as_str(), r.flags.len(), r.readme.len()), ("k", 2, 1));
    }

    #[test]
    fn variant_missing_from_decode_or_name_is_flagged() {
        let event = r#"
            pub enum EventKind { Submit = 1, Done = 2 }
            impl EventKind {
                pub fn from_u8(v: u8) -> Option<EventKind> {
                    Some(match v { 1 => EventKind::Submit, _ => return None })
                }
                pub fn name(&self) -> &'static str {
                    match self { EventKind::Submit => "Submit", _ => "?" }
                }
            }
            #[test] fn roundtrip() { for k in 1u8..=2 {} }
        "#;
        let got = run(event, GOOD_POOL, GOOD_CLI, GOOD_README);
        assert!(got.iter().any(|f| f.msg.contains("`EventKind::Done` has no `from_u8` arm")));
        assert!(got.iter().any(|f| f.msg.contains("`EventKind::Done` has no `name()` arm")));
    }

    #[test]
    fn decode_value_drift_and_name_drift_are_flagged() {
        let event = r#"
            pub enum EventKind { Submit = 1, Done = 2 }
            impl EventKind {
                pub fn from_u8(v: u8) -> Option<EventKind> {
                    Some(match v { 1 => EventKind::Submit, 3 => EventKind::Done, _ => return None })
                }
                pub fn name(&self) -> &'static str {
                    match self { EventKind::Submit => "Submit", EventKind::Done => "Finished" }
                }
            }
            #[test] fn roundtrip() { for k in 1u8..=2 {} }
        "#;
        let got = run(event, GOOD_POOL, GOOD_CLI, GOOD_README);
        assert!(got.iter().any(|f| f.msg.contains("maps 3 to `EventKind::Done`")));
        assert!(got.iter().any(|f| f.msg.contains("as \"Finished\"")));
    }

    #[test]
    fn stale_roundtrip_range_is_flagged() {
        let event = GOOD_EVENT.replace("1u8..=2", "1u8..=1");
        let got = run(&event, GOOD_POOL, GOOD_CLI, GOOD_README);
        assert!(got.iter().any(|f| f.msg.contains("roundtrip range")), "{got:?}");
    }

    #[test]
    fn undeclared_and_stale_config_keys_are_flagged() {
        let pool = r#"
            fn from_config(sec: &Section) {
                out.batch_max = read_uint(sec, "batch_max", 1, 1)?;
                out.queue_cap = read_uint(sec, "queue_cap", 0, 0)?;
            }
        "#;
        let got = run(GOOD_EVENT, pool, GOOD_CLI, GOOD_README);
        assert!(got.iter().any(|f| f.msg.contains("\"queue_cap\" is read here but missing")));
        assert!(got.iter().any(|f| f.msg.contains("\"hedge\" but from_config never reads")));
        assert!(got.iter().any(|f| f.msg.contains("\"devices\" but from_config never reads")));
    }

    #[test]
    fn missing_cli_flag_and_readme_token_are_flagged() {
        let cli = r#"fn parse() { uint("batch"); }"#;
        let got = run(GOOD_EVENT, GOOD_POOL, cli, "| `--batch N` |");
        assert!(got.iter().any(|f| f.file == CLI && f.msg.contains("\"hedge\"")), "{got:?}");
        assert!(got.iter().any(|f| f.file == "README.md" && f.msg.contains("--no-hedge")));
    }
}
