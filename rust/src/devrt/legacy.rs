//! The **legacy** device runtime: the pre-port structure (paper §2.1).
//!
//! One specialized build per target, generated from common source through
//! a macro — the Rust analog of Listing 1's `DEVICE`/`SHARED` macro trick:
//! the `legacy_target!` expansion *is* the "compile the same source once
//! as CUDA, once as HIP" step, with the target-dependent spellings
//! (vendor fence/increment intrinsics, impl-symbol mangling) substituted
//! per expansion. Each expanded module is a self-contained per-target
//! runtime, exactly like the old `nvptx`/`amdgcn` source trees.

use super::api::{DeviceRuntime, RuntimeKind};
use super::bindings_impl as common; // the shared *source*; macro instantiates per target
use super::irlib::{self, AtomicsFlavor, TargetParts};
use crate::sim::{Arch, Bindings};
use std::sync::Arc;

/// Expand a per-target legacy runtime module.
///
/// `$mangle` plays the role of the CUDA/HIP name mangling of the macro
/// build (`__kmpc_impl_foo$nvptx`); `$fence`/`$inc` are the vendor
/// intrinsics the target-dependent sources call.
macro_rules! legacy_target {
    ($modname:ident, $arch:expr, $sfx:literal, $dialect:literal, $fence:literal, $inc:literal) => {
        /// The macro-expanded per-target runtime (see module docs).
        pub mod $modname {
            use super::*;

            /// Impl-symbol mangling of this target's macro build.
            pub fn mangle(base: &str) -> String {
                format!("{base}${}", $sfx)
            }

            /// The target-dependent sources: fence + atomicInc.
            pub fn target_parts() -> TargetParts {
                let tf = mangle("__kmpc_impl_threadfence");
                let inc = mangle("__kmpc_impl_atomic_inc");
                TargetParts {
                    threadfence: irlib::threadfence_body(&tf, $fence),
                    threadfence_name: tf,
                    atomic_inc: irlib::atomic_inc_body(&inc, $inc),
                    atomic_inc_name: inc,
                }
            }

            /// Producer string recorded in module metadata.
            pub fn producer() -> String {
                format!("devrt-legacy 0.1 ({} macro build, {})", $dialect, $arch.name())
            }

            /// Install this target's copy of the runtime bindings.
            /// (The bodies are the macro-shared source — compiled "twice",
            /// once per expansion, like the original runtime.)
            pub fn install_bindings(b: &mut Bindings) {
                b.bind("__kmpc_target_init", Arc::new(common::target_init));
                b.bind("__kmpc_target_deinit", Arc::new(common::target_deinit));
                b.bind("__kmpc_parallel_begin", Arc::new(common::parallel_begin));
                b.bind("__kmpc_parallel_end", Arc::new(common::parallel_end));
                b.bind("__kmpc_barrier", Arc::new(common::barrier));
                b.bind("__kmpc_barrier_simple_spmd", Arc::new(common::barrier));
                b.bind("__kmpc_for_static_init_4", Arc::new(common::for_static_init));
                b.bind("__kmpc_dispatch_init_4", Arc::new(common::dispatch_init));
                b.bind("__kmpc_dispatch_next_4", Arc::new(common::dispatch_next));
                b.bind("__kmpc_dispatch_fini_4", Arc::new(common::dispatch_fini));
                b.bind("__kmpc_alloc_shared", Arc::new(common::alloc_shared));
                b.bind("__kmpc_free_shared", Arc::new(common::free_shared));
            }

            /// Build the complete legacy runtime for this target.
            pub fn build() -> DeviceRuntime {
                let mut bindings = Bindings::new();
                install_bindings(&mut bindings);
                let ir_library = irlib::build_library(
                    $arch,
                    &producer(),
                    &mangle,
                    target_parts(),
                    AtomicsFlavor::Intrinsic,
                );
                DeviceRuntime {
                    kind: RuntimeKind::Legacy,
                    arch: $arch,
                    producer: producer(),
                    ir_library,
                    bindings,
                }
            }
        }
    };
}

legacy_target!(nvptx, Arch::Nvptx64, "nvptx", "cuda", "nvvm.membar.gl", "nvvm.atom.inc.u32");
legacy_target!(amdgcn, Arch::Amdgcn, "amdgcn", "hip", "amdgcn.s.waitcnt", "amdgcn.atomic.inc32");

/// Build the legacy runtime for `arch`.
pub fn build(arch: Arch) -> DeviceRuntime {
    match arch {
        Arch::Nvptx64 => nvptx::build(),
        Arch::Amdgcn => amdgcn::build(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_target_mangling_differs() {
        assert_eq!(nvptx::mangle("__kmpc_impl_x"), "__kmpc_impl_x$nvptx");
        assert_eq!(amdgcn::mangle("__kmpc_impl_x"), "__kmpc_impl_x$amdgcn");
    }

    #[test]
    fn nvptx_build_uses_cuda_intrinsics() {
        let rt = nvptx::build();
        let inc = &rt.ir_library.funcs["__kmpc_impl_atomic_inc$nvptx"];
        assert!(inc.callees().contains("nvvm.atom.inc.u32"));
        assert!(rt.producer.contains("cuda"));
    }

    #[test]
    fn amdgcn_build_uses_hip_intrinsics() {
        let rt = amdgcn::build();
        let inc = &rt.ir_library.funcs["__kmpc_impl_atomic_inc$amdgcn"];
        assert!(inc.callees().contains("amdgcn.atomic.inc32"));
        assert!(rt.producer.contains("hip"));
    }

    #[test]
    fn legacy_library_has_no_variant_mangling() {
        let rt = build(Arch::Nvptx64);
        for name in rt.ir_library.funcs.keys() {
            assert!(!name.contains(".ompvariant."), "{name}");
        }
    }
}
