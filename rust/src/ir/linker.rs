//! Module linker — the step marked "link dev.rtl.bc" in the paper's
//! Fig. 1: application kernel modules are linked against the device
//! runtime's IR library before optimization.

use super::module::{Linkage, Module};
use crate::util::Error;
use std::collections::BTreeSet;

/// Link `lib` into `app` (in place).
///
/// Rules (LLVM-linker-like, reduced to what we need):
/// * a strong definition replaces a weak one (either direction);
/// * two strong definitions of the same symbol are an error;
/// * `Internal` symbols from the library are renamed on collision;
/// * metadata keys from the library are imported under their own name
///   when absent (first writer wins — metadata is not semantic).
pub fn link(app: &mut Module, lib: &Module) -> Result<(), Error> {
    // Functions.
    for (name, f) in &lib.funcs {
        match app.funcs.get(name) {
            None => {
                app.add_func(f.clone());
            }
            Some(existing) => {
                let e_weak = existing.linkage == Linkage::Weak;
                let l_weak = f.linkage == Linkage::Weak;
                match (e_weak, l_weak) {
                    (true, false) => {
                        app.add_func(f.clone());
                    }
                    (_, true) => { /* keep existing */ }
                    (false, false) => {
                        if f.linkage == Linkage::Internal || existing.linkage == Linkage::Internal
                        {
                            // Internal collision: rename the incoming one.
                            let mut renamed = f.clone();
                            renamed.name = format!("{name}.{}", short_hash(&lib.name));
                            app.add_func(renamed);
                        } else {
                            return Err(Error::Link(format!(
                                "duplicate strong definition of @{name} \
                                 (app `{}` vs lib `{}`)",
                                app.name, lib.name
                            )));
                        }
                    }
                }
            }
        }
    }
    // Globals.
    for (name, g) in &lib.globals {
        match app.globals.get(name) {
            None => app.add_global(g.clone()),
            Some(existing) => {
                let e_weak = existing.linkage == Linkage::Weak;
                let l_weak = g.linkage == Linkage::Weak;
                match (e_weak, l_weak) {
                    (true, false) => app.add_global(g.clone()),
                    (_, true) => {}
                    (false, false) => {
                        return Err(Error::Link(format!(
                            "duplicate strong definition of global @{name}"
                        )))
                    }
                }
            }
        }
    }
    // Metadata: import absent keys.
    for (k, v) in &lib.meta {
        app.meta.entry(k.clone()).or_insert_with(|| v.clone());
    }
    // Externs: keep only still-unresolved ones.
    let mut ext: BTreeSet<String> = app.externs.union(&lib.externs).cloned().collect();
    let defined = app.defined_symbols();
    ext.retain(|s| !defined.contains(s));
    app.externs = ext;
    Ok(())
}

/// After linking, every remaining undefined symbol must be acceptable to
/// the execution environment (intrinsics, runtime bindings, payloads).
pub fn check_resolved(
    m: &Module,
    is_environment_symbol: impl Fn(&str) -> bool,
) -> Result<(), Error> {
    let undefined: Vec<String> = m
        .undefined_symbols()
        .into_iter()
        .filter(|s| !is_environment_symbol(s))
        .collect();
    if undefined.is_empty() {
        Ok(())
    } else {
        Err(Error::Link(format!(
            "unresolved symbols in module `{}`: {}",
            m.name,
            undefined.join(", ")
        )))
    }
}

/// Symbols the simulator environment always provides: target intrinsics
/// (`gpu.*`, `nvvm.*`, `amdgcn.*`), PJRT payloads (`payload.*`) and
/// runtime bindings (`__kmpc_*`, `omp_*`).
pub fn default_environment_symbol(s: &str) -> bool {
    s.starts_with("gpu.")
        || s.starts_with("nvvm.")
        || s.starts_with("amdgcn.")
        || s.starts_with("payload.")
        || s.starts_with("__kmpc_")
        || s.starts_with("omp_")
}

fn short_hash(s: &str) -> String {
    let mut h: u32 = 2166136261;
    for b in s.bytes() {
        h ^= b as u32;
        h = h.wrapping_mul(16777619);
    }
    format!("{h:08x}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::FunctionBuilder;
    use crate::ir::module::{Function, Linkage};
    use crate::ir::types::{Operand, Type};

    fn func(name: &str, linkage: Linkage, ret_const: i32) -> Function {
        let mut b = FunctionBuilder::new(name, &[], Some(Type::I32)).linkage(linkage);
        b.ret_val(Operand::i32(ret_const));
        b.build()
    }

    #[test]
    fn strong_replaces_weak() {
        let mut app = Module::new("app");
        app.add_func(func("f", Linkage::Weak, 0));
        let mut lib = Module::new("lib");
        lib.add_func(func("f", Linkage::External, 7));
        link(&mut app, &lib).unwrap();
        let text = crate::ir::printer::print_function(&app.funcs["f"]);
        assert!(text.contains("return 7"), "{text}");
    }

    #[test]
    fn weak_does_not_replace_strong() {
        let mut app = Module::new("app");
        app.add_func(func("f", Linkage::External, 1));
        let mut lib = Module::new("lib");
        lib.add_func(func("f", Linkage::Weak, 9));
        link(&mut app, &lib).unwrap();
        let text = crate::ir::printer::print_function(&app.funcs["f"]);
        assert!(text.contains("return 1"), "{text}");
    }

    #[test]
    fn duplicate_strong_is_an_error() {
        let mut app = Module::new("app");
        app.add_func(func("f", Linkage::External, 1));
        let mut lib = Module::new("lib");
        lib.add_func(func("f", Linkage::External, 2));
        assert!(link(&mut app, &lib).is_err());
    }

    #[test]
    fn internal_collision_renames() {
        let mut app = Module::new("app");
        app.add_func(func("helper", Linkage::Internal, 1));
        let mut lib = Module::new("lib");
        lib.add_func(func("helper", Linkage::Internal, 2));
        link(&mut app, &lib).unwrap();
        assert_eq!(app.funcs.len(), 2);
    }

    #[test]
    fn externs_shrink_after_link() {
        let mut app = Module::new("app");
        let mut k = FunctionBuilder::new("k", &[], None).kernel();
        k.call_void("lib_fn", &[]);
        k.ret();
        app.add_func(k.build());
        app.declare_extern("lib_fn");
        let mut lib = Module::new("lib");
        lib.add_func(func("lib_fn", Linkage::External, 0));
        link(&mut app, &lib).unwrap();
        assert!(app.externs.is_empty());
        check_resolved(&app, default_environment_symbol).unwrap();
    }

    #[test]
    fn unresolved_non_environment_symbol_fails_check() {
        let mut app = Module::new("app");
        let mut k = FunctionBuilder::new("k", &[], None).kernel();
        k.call_void("mystery", &[]);
        k.ret();
        app.add_func(k.build());
        assert!(check_resolved(&app, default_environment_symbol).is_err());
    }

    #[test]
    fn intrinsic_and_runtime_symbols_are_environment() {
        for s in ["gpu.tid.x", "nvvm.atom.inc.u32", "amdgcn.atomic.inc32", "payload.stencil", "__kmpc_barrier", "omp_get_thread_num"] {
            assert!(default_environment_symbol(s), "{s}");
        }
        assert!(!default_environment_symbol("random_fn"));
    }
}
