//! The **portable** device runtime: the paper's new structure (§3).
//!
//! One common part (written once — [`super::bindings_impl`] and the
//! common functions of [`super::irlib`]), with the target-dependent
//! surface reduced to two `declare variant` sets:
//!
//! * `__kmpc_impl_threadfence` — Listing 2's `__kmpc_flush` path;
//! * `__kmpc_impl_atomic_inc` — Listing 4, including the `match_any`
//!   extension so one definition covers `arch(nvptx, nvptx64)`.
//!
//! All other atomics are *expressed in OpenMP 5.1* (`atomic [compare]
//! capture seq_cst`, Listing 3) and lowered by [`super::omp_atomic`] to
//! the same instructions the legacy build emits directly.

use super::api::{DeviceRuntime, RuntimeKind};
use super::bindings_impl as common;
use super::irlib::{self, AtomicsFlavor, TargetParts};
use super::variant::{Selector, Variant, VariantRegistry, VariantSet};
use crate::ir::Type;
use crate::sim::{Arch, Bindings};
use std::sync::Arc;

/// Producer string recorded in module metadata.
pub fn producer(arch: Arch) -> String {
    format!("devrt-portable 0.1 (openmp 5.1 build, {})", arch.name())
}

/// Install the common bindings (single source for every target — the
/// point of the port).
pub fn install_bindings(b: &mut Bindings) {
    b.bind("__kmpc_target_init", Arc::new(common::target_init));
    b.bind("__kmpc_target_deinit", Arc::new(common::target_deinit));
    b.bind("__kmpc_parallel_begin", Arc::new(common::parallel_begin));
    b.bind("__kmpc_parallel_end", Arc::new(common::parallel_end));
    b.bind("__kmpc_barrier", Arc::new(common::barrier));
    b.bind("__kmpc_barrier_simple_spmd", Arc::new(common::barrier));
    b.bind("__kmpc_for_static_init_4", Arc::new(common::for_static_init));
    b.bind("__kmpc_dispatch_init_4", Arc::new(common::dispatch_init));
    b.bind("__kmpc_dispatch_next_4", Arc::new(common::dispatch_next));
    b.bind("__kmpc_dispatch_fini_4", Arc::new(common::dispatch_fini));
    b.bind("__kmpc_alloc_shared", Arc::new(common::alloc_shared));
    b.bind("__kmpc_free_shared", Arc::new(common::free_shared));
}

/// The portable build's `declare variant` registry (paper Listing 4
/// structure: a trapping base + per-vendor variants, Nvidia's using
/// `match_any` over `arch(nvptx, nvptx64)`).
pub fn variant_registry() -> VariantRegistry {
    let mut reg = VariantRegistry::new();

    reg.register(VariantSet {
        base_name: "__kmpc_impl_threadfence".into(),
        base: Box::new(|n| irlib::missing_impl_body(n, &[], None)),
        variants: vec![
            Variant {
                selector: Selector::arch_any(&["nvptx", "nvptx64"]),
                build: Box::new(|n| irlib::threadfence_body(n, "nvvm.membar.gl")),
            },
            Variant {
                selector: Selector::arch("amdgcn"),
                build: Box::new(|n| irlib::threadfence_body(n, "amdgcn.s.waitcnt")),
            },
        ],
    });

    reg.register(VariantSet {
        base_name: "__kmpc_impl_atomic_inc".into(),
        base: Box::new(|n| irlib::missing_impl_body(n, &[Type::I64, Type::I32], Some(Type::I32))),
        variants: vec![
            Variant {
                selector: Selector::arch_any(&["nvptx", "nvptx64"]),
                build: Box::new(|n| irlib::atomic_inc_body(n, "nvvm.atom.inc.u32")),
            },
            Variant {
                selector: Selector::arch("amdgcn"),
                build: Box::new(|n| irlib::atomic_inc_body(n, "amdgcn.atomic.inc32")),
            },
        ],
    });

    reg
}

/// Build the portable runtime for `arch`.
pub fn build(arch: Arch) -> DeviceRuntime {
    let mut bindings = Bindings::new();
    install_bindings(&mut bindings);

    // Resolve the variant sets for this target.
    let reg = variant_registry();
    let resolved = reg.resolve_all(arch);
    let find = |base: &str| {
        resolved
            .iter()
            .find(|(b, _, _)| b == base)
            .unwrap_or_else(|| panic!("variant set {base} missing"))
    };
    let (_, tf_fn, tf_name) = find("__kmpc_impl_threadfence");
    let (_, inc_fn, inc_name) = find("__kmpc_impl_atomic_inc");
    let parts = TargetParts {
        threadfence: tf_fn.clone(),
        threadfence_name: tf_name.clone(),
        atomic_inc: inc_fn.clone(),
        atomic_inc_name: inc_name.clone(),
    };

    // Common code is unmangled — there is only one source for it.
    let identity = |s: &str| s.to_string();
    let ir_library =
        irlib::build_library(arch, &producer(arch), &identity, parts, AtomicsFlavor::Omp51);

    DeviceRuntime {
        kind: RuntimeKind::Portable,
        arch,
        producer: producer(arch),
        ir_library,
        bindings,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_resolution_picks_vendor_impls() {
        let rt = build(Arch::Nvptx64);
        // The canonical inc wrapper must call a variant-mangled impl that
        // carries the match_any context.
        let wrapper = &rt.ir_library.funcs["__kmpc_atomic_inc"];
        let callee = wrapper.callees().into_iter().next().unwrap();
        assert!(callee.contains(".ompvariant."), "{callee}");
        assert!(callee.contains("match_any"), "{callee}");
        let impl_fn = &rt.ir_library.funcs[&callee];
        assert!(impl_fn.callees().contains("nvvm.atom.inc.u32"));

        let rt = build(Arch::Amdgcn);
        let wrapper = &rt.ir_library.funcs["__kmpc_atomic_inc"];
        let callee = wrapper.callees().into_iter().next().unwrap();
        assert!(callee.contains("arch_amdgcn"), "{callee}");
        let impl_fn = &rt.ir_library.funcs[&callee];
        assert!(impl_fn.callees().contains("amdgcn.atomic.inc32"));
    }

    #[test]
    fn common_symbols_are_unmangled() {
        let rt = build(Arch::Amdgcn);
        assert!(rt.ir_library.funcs.contains_key("__kmpc_impl_atomic_add"));
        assert!(!rt.ir_library.funcs.keys().any(|k| k.contains('$')));
    }

    #[test]
    fn portable_library_is_identical_across_archs_modulo_variants() {
        // The portability claim: the common part is byte-identical for
        // both targets; only variant-selected functions (and the target
        // header line) differ.
        let n = build(Arch::Nvptx64);
        let a = build(Arch::Amdgcn);
        let common_n: Vec<&String> =
            n.ir_library.funcs.keys().filter(|k| !k.contains(".ompvariant.")).collect();
        let common_a: Vec<&String> =
            a.ir_library.funcs.keys().filter(|k| !k.contains(".ompvariant.")).collect();
        assert_eq!(common_n, common_a);
        for k in common_n {
            // The atomic_inc/flush wrappers call variant-mangled names
            // which embed the arch; all other common bodies must match.
            if k == "__kmpc_atomic_inc" || k == "__kmpc_flush" {
                continue;
            }
            let fa = crate::ir::printer::print_function(&n.ir_library.funcs[k]);
            let fb = crate::ir::printer::print_function(&a.ir_library.funcs[k]);
            assert_eq!(fa, fb, "common function {k} differs between targets");
        }
    }
}
