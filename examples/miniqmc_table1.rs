//! Table 1: per-target-region profile of the miniQMC proxy app
//! (evaluate_vgh, evaluateDetRatios) under both runtime builds.
//!
//! Usage: cargo run --release --example miniqmc_table1 [paper]

use omprt::benchmarks::harness::{format_table1, run_table1};
use omprt::benchmarks::Scale;
use omprt::runtime::{artifact, ArtifactManifest};
use omprt::sim::Arch;

fn main() -> Result<(), omprt::util::Error> {
    let paper = std::env::args().any(|a| a == "paper");
    let scale = if paper { Scale::Paper } else { Scale::Small };
    let man = ArtifactManifest::load(&artifact::default_dir())
        .map_err(|e| omprt::util::Error::Config(format!("run `make artifacts` first: {e}")))?;
    let rows = run_table1(Arch::Nvptx64, scale, &man)?;
    println!("Table 1 — miniqmc_sync_move target-region profile (nvprof analog):\n");
    print!("{}", format_table1(&rows));
    Ok(())
}
