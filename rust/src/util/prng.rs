//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has `rand_core` but not `rand`, so we carry our
//! own small generator. SplitMix64 is statistically solid for workload
//! generation and property-test shrink-free sampling, and — importantly for
//! reproducibility of EXPERIMENTS.md — fully deterministic from a seed.

/// SplitMix64 generator (public-domain reference algorithm).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift reduction; the
    /// modulo bias is negligible for our n (<2^32 workload sizes).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Standard-normal sample (Box–Muller; one value per call, simple and
    /// deterministic).
    pub fn gaussian(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = (self.next_u64() >> 11) as f64 + 1.0;
        let u1 = u1 * (1.0 / (1u64 << 53) as f64);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with uniform f32 in `[lo, hi)`.
    pub fn fill_f32(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf {
            *v = self.f32_range(lo, hi);
        }
    }

    /// Random permutation of `0..n` (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            p.swap(i, j);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_is_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn gaussian_moments_are_sane() {
        let mut r = SplitMix64::new(1234);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = SplitMix64::new(5);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &x in &p {
            assert!(!seen[x as usize]);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
