//! Rule `locks`: the declared sched lock order is acquired in order.
//!
//! `lint/rules/locks.order` declares a rank per lock field
//! (`lock rust/src/sched/pool.rs:queue 1` …). Within each function body
//! the rule tracks mutex guard lifetimes syntactically:
//!
//! * `let g = self.queue.lock()…;` — named guard, held until its brace
//!   scope closes or an explicit `drop(g)`;
//! * `self.queue.lock().unwrap().push(x);` — temporary guard, released
//!   at the end of the statement (the next `;`);
//!
//! and flags any acquisition whose rank is not strictly greater than
//! every rank already held — which covers both order inversions
//! (`clients` then `queue`) and re-entrant double-locks of the same
//! mutex. `allow file:fn:lock` entries exempt a reviewed site.
//!
//! This is a syntactic over-approximation (it cannot see guards moved
//! across function boundaries), but the pool deliberately never passes
//! guards around, and the self-check test keeps it that way.

use crate::lint::lexer::{Tok, TokKind};
use crate::lint::{Finding, Manifests};

struct Guard {
    lock: String,
    rank: u32,
    /// Binding name, `None` for statement temporaries.
    name: Option<String>,
    /// Brace depth the guard lives at.
    depth: u32,
}

/// Scan backwards from the acquisition to its statement start and pick
/// out a `let … NAME =` binding name, if any.
fn binding_name(toks: &[Tok], acq: usize) -> Option<String> {
    let mut start = acq;
    while start > 0 {
        let t = &toks[start - 1];
        if t.is_punct(";") || t.is_punct("{") || t.is_punct("}") {
            break;
        }
        start -= 1;
    }
    if !toks[start..acq].iter().any(|t| t.is_ident("let")) {
        return None;
    }
    let eq = (start..acq).find(|&i| {
        toks[i].is_punct("=") && !toks.get(i + 1).is_some_and(|n| n.is_punct("="))
    })?;
    toks[start..eq]
        .iter()
        .rev()
        .find(|t| t.kind == TokKind::Ident && t.text != "mut" && t.text != "let")
        .map(|t| t.text.clone())
}

/// Check lock-order discipline over `toks`.
pub fn check(file: &str, toks: &[Tok], m: &Manifests) -> Vec<Finding> {
    let prefix = format!("{file}:");
    let ranks: Vec<(&str, u32)> = m
        .lock_ranks
        .iter()
        .filter_map(|(k, &r)| k.strip_prefix(&prefix).map(|name| (name, r)))
        .collect();
    if ranks.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut depth: u32 = 0;
    let mut held: Vec<Guard> = Vec::new();
    // Function tracking: `fn NAME … {` at paren depth 0 opens a body.
    let mut cur_fn = String::from("?");
    let mut pending_fn: Option<String> = None;
    let mut paren: i32 = 0;
    for k in 0..toks.len() {
        let t = &toks[k];
        match t.text.as_str() {
            "(" if t.kind == TokKind::Punct => paren += 1,
            ")" if t.kind == TokKind::Punct => paren -= 1,
            "{" if t.kind == TokKind::Punct => {
                depth += 1;
                if paren == 0 {
                    if let Some(name) = pending_fn.take() {
                        cur_fn = name;
                    }
                }
            }
            "}" if t.kind == TokKind::Punct => {
                depth = depth.saturating_sub(1);
                held.retain(|g| g.depth <= depth);
                if depth == 0 {
                    cur_fn = String::from("?");
                }
            }
            ";" if t.kind == TokKind::Punct && paren == 0 => {
                held.retain(|g| g.name.is_some() || g.depth != depth);
            }
            "fn" if t.kind == TokKind::Ident => {
                if let Some(n) = toks.get(k + 1) {
                    if n.kind == TokKind::Ident {
                        pending_fn = Some(n.text.clone());
                    }
                }
            }
            "drop" if t.kind == TokKind::Ident => {
                if toks.get(k + 1).is_some_and(|a| a.is_punct("("))
                    && toks.get(k + 3).is_some_and(|b| b.is_punct(")"))
                {
                    if let Some(victim) = toks.get(k + 2) {
                        held.retain(|g| g.name.as_deref() != Some(victim.text.as_str()));
                    }
                }
            }
            _ => {}
        }
        // `NAME.lock(` where NAME is a declared lock field.
        if t.kind != TokKind::Ident {
            continue;
        }
        let Some(&(lock, rank)) = ranks.iter().find(|(name, _)| *name == t.text) else {
            continue;
        };
        if !(toks.get(k + 1).is_some_and(|a| a.is_punct("."))
            && toks.get(k + 2).is_some_and(|b| b.is_ident("lock"))
            && toks.get(k + 3).is_some_and(|c| c.is_punct("(")))
        {
            continue;
        }
        for g in &held {
            if g.rank >= rank {
                let key = format!("{file}:{cur_fn}:{lock}");
                if m.lock_allow.iter().any(|a| *a == key) {
                    continue;
                }
                out.push(Finding {
                    file: file.to_string(),
                    line: t.line,
                    rule: "locks",
                    msg: format!(
                        "in `{cur_fn}`: acquiring `{lock}` (rank {rank}) while holding \
                         `{}` (rank {}) — declared order in lint/rules/locks.order",
                        g.lock, g.rank
                    ),
                });
            }
        }
        held.push(Guard {
            lock: lock.to_string(),
            rank,
            name: binding_name(toks, k),
            depth,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;
    use std::collections::BTreeMap;

    fn m(allow: &[&str]) -> Manifests {
        let mut lock_ranks = BTreeMap::new();
        lock_ranks.insert("x.rs:inflight_reg".to_string(), 0);
        lock_ranks.insert("x.rs:queue".to_string(), 1);
        lock_ranks.insert("x.rs:clients".to_string(), 2);
        Manifests {
            lock_ranks,
            lock_allow: allow.iter().map(|s| s.to_string()).collect(),
            ..Manifests::default()
        }
    }

    fn run(src: &str) -> Vec<Finding> {
        check("x.rs", &lex(src), &m(&[]))
    }

    #[test]
    fn in_order_acquisition_passes() {
        let src = "fn f(&self) {\n\
                   let q = self.queue.lock().unwrap();\n\
                   let c = self.clients.lock().unwrap();\n\
                   }";
        assert!(run(src).is_empty(), "{:?}", run(src));
    }

    #[test]
    fn inverted_order_is_flagged_with_fn_name() {
        let src = "fn sweep(&self) {\n\
                   let c = self.clients.lock().unwrap();\n\
                   let q = self.queue.lock().unwrap();\n\
                   }";
        let got = run(src);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].line, 3);
        assert!(got[0].msg.contains("`sweep`"));
        assert!(got[0].msg.contains("acquiring `queue` (rank 1) while holding `clients` (rank 2)"));
    }

    #[test]
    fn block_scope_releases_the_guard() {
        let src = "fn f(&self) {\n\
                   { let c = self.clients.lock().unwrap(); c.len(); }\n\
                   let q = self.queue.lock().unwrap();\n\
                   }";
        assert!(run(src).is_empty(), "{:?}", run(src));
    }

    #[test]
    fn explicit_drop_releases_the_guard() {
        let src = "fn f(&self) {\n\
                   let c = self.clients.lock().unwrap();\n\
                   drop(c);\n\
                   let q = self.queue.lock().unwrap();\n\
                   }";
        assert!(run(src).is_empty(), "{:?}", run(src));
    }

    #[test]
    fn statement_temporary_releases_at_semicolon() {
        let src = "fn f(&self) {\n\
                   self.clients.lock().unwrap().len();\n\
                   let q = self.queue.lock().unwrap();\n\
                   }";
        assert!(run(src).is_empty(), "{:?}", run(src));
    }

    #[test]
    fn double_lock_of_the_same_mutex_is_flagged() {
        let src = "fn f(&self) {\n\
                   let a = self.queue.lock().unwrap();\n\
                   let b = self.queue.lock().unwrap();\n\
                   }";
        let got = run(src);
        assert_eq!(got.len(), 1);
        assert!(got[0].msg.contains("acquiring `queue` (rank 1) while holding `queue` (rank 1)"));
    }

    #[test]
    fn guards_do_not_leak_across_functions() {
        let src = "fn a(&self) { let c = self.clients.lock().unwrap(); }\n\
                   fn b(&self) { let q = self.queue.lock().unwrap(); }";
        assert!(run(src).is_empty(), "{:?}", run(src));
    }

    #[test]
    fn allow_entry_exempts_a_reviewed_site() {
        let src = "fn sweep(&self) {\n\
                   let c = self.clients.lock().unwrap();\n\
                   let q = self.queue.lock().unwrap();\n\
                   }";
        let got = check("x.rs", &lex(src), &m(&["x.rs:sweep:queue"]));
        assert!(got.is_empty(), "{got:?}");
    }
}
