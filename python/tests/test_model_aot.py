"""L2/AOT tests: payload table consistency, lowering to HLO text, and
numeric equivalence of the lowered modules with the model functions."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model


def test_payload_table_shapes_are_consistent():
    for name, (fn, in_shapes, out_shape) in model.PAYLOADS.items():
        args = [jnp.zeros(s, jnp.float32) for s in in_shapes]
        out = fn(*args)
        assert isinstance(out, tuple) and len(out) == 1, name
        assert out[0].shape == tuple(out_shape), name
        assert out[0].dtype == jnp.float32, name


def test_every_payload_lowers_to_hlo_text():
    for name, (fn, in_shapes, _) in model.PAYLOADS.items():
        specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in in_shapes]
        text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        assert text.startswith("HloModule"), name
        assert "ROOT" in text, name
        # the interchange contract: tuple-wrapped single output
        assert "tuple" in text, name


def test_stencil_payload_numeric_sanity():
    slab = np.zeros((model.STENCIL_ROWS + 2, model.STENCIL_COLS), np.float32)
    slab[17, 100] = 1.0  # a point source diffuses to its neighbours
    (out,) = model.stencil_payload(jnp.asarray(slab))
    out = np.asarray(out)
    assert out[16, 100] == np.float32(0.5)  # center weight
    assert out[15, 100] == np.float32(0.125)
    assert out[17, 100] == np.float32(0.125)
    assert out[16, 99] == np.float32(0.125)
    assert out[16, 101] == np.float32(0.125)
    assert np.count_nonzero(out) == 5


def test_vgh_payload_matches_dense_matmul():
    r = np.random.default_rng(3)
    basis = r.standard_normal((model.VGH_PLANES * model.VGH_P, model.VGH_B)).astype(np.float32)
    coef = r.standard_normal((model.VGH_B, model.VGH_O)).astype(np.float32)
    (out,) = model.vgh_payload(jnp.asarray(basis), jnp.asarray(coef))
    np.testing.assert_allclose(np.asarray(out), basis @ coef, rtol=2e-5, atol=2e-5)


def test_manifest_shape_strings():
    assert aot.shape_str((34, 258)) == "34x258"
    assert aot.shape_str((16,)) == "16"
