//! The `nvprof`-analog per-region profiler.
//!
//! Accumulates, per target region, the exact columns of the paper's
//! Table 1: total Time (ms), #Calls, Avg/Min/Max (µs).

use crate::util::{clock, Summary};
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Region-keyed profiler (thread-safe).
#[derive(Default)]
pub struct Profiler {
    regions: Mutex<BTreeMap<String, Summary>>,
}

/// One row of the profiler report.
#[derive(Debug, Clone)]
pub struct RegionReport {
    /// Region name (Table 1 "Target Region").
    pub name: String,
    /// Accumulated statistics.
    pub summary: Summary,
}

impl Profiler {
    /// Empty profiler.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample for `region`.
    pub fn record(&self, region: &str, d: Duration) {
        let mut map = self.regions.lock().unwrap();
        map.entry(region.to_string()).or_default().record(d);
    }

    /// Time a closure under a region.
    pub fn time<R>(&self, region: &str, f: impl FnOnce() -> R) -> R {
        let t0 = clock::now();
        let r = f();
        self.record(region, t0.elapsed());
        r
    }

    /// Merge another profiler's accumulated regions into this one. Pool
    /// device leases use this to fold a leased coordinator's regions
    /// into the device profiler that feeds the pool report.
    pub fn absorb(&self, other: &Profiler) {
        let other = other.report();
        let mut map = self.regions.lock().unwrap();
        for r in other {
            map.entry(r.name).or_default().merge(&r.summary);
        }
    }

    /// Snapshot all regions (sorted by name).
    pub fn report(&self) -> Vec<RegionReport> {
        self.regions
            .lock()
            .unwrap()
            .iter()
            .map(|(name, summary)| RegionReport { name: name.clone(), summary: summary.clone() })
            .collect()
    }

    /// Clear all accumulated data.
    pub fn reset(&self) {
        self.regions.lock().unwrap().clear();
    }

    /// Format a report in the layout of the paper's Table 1.
    ///
    /// ```text
    /// Target Region      | Version  | Time (ms) | # Calls | Avg (us) | Min (us) | Max (us)
    /// evaluate_vgh       | Original |   1376.23 |   64512 |   21.309 |   19.744 |   32.384
    /// ```
    pub fn table1(rows: &[(String, String, Summary)]) -> String {
        let mut out = String::new();
        out.push_str(
            "Target Region      | Version  | Time (ms) | # Calls | Avg (us) | Min (us) | Max (us)\n",
        );
        out.push_str(
            "-------------------+----------+-----------+---------+----------+----------+---------\n",
        );
        for (region, version, s) in rows {
            out.push_str(&format!(
                "{:<19}| {:<9}| {:>10.2} | {:>7} | {:>8.3} | {:>8.3} | {:>8.3}\n",
                region,
                version,
                s.total_ms(),
                s.count(),
                s.avg_us(),
                s.min_us(),
                s.max_us()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let p = Profiler::new();
        p.record("a", Duration::from_micros(10));
        p.record("a", Duration::from_micros(30));
        p.record("b", Duration::from_micros(5));
        let r = p.report();
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].name, "a");
        assert_eq!(r[0].summary.count(), 2);
        assert!((r[0].summary.avg_us() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn time_closure_returns_value() {
        let p = Profiler::new();
        let v = p.time("r", || 42);
        assert_eq!(v, 42);
        assert_eq!(p.report()[0].summary.count(), 1);
    }

    #[test]
    fn table1_layout_contains_columns() {
        let mut s = Summary::new();
        s.record(Duration::from_micros(21));
        let text = Profiler::table1(&[("evaluate_vgh".into(), "Original".into(), s)]);
        assert!(text.contains("Target Region"), "{text}");
        assert!(text.contains("evaluate_vgh"), "{text}");
        assert!(text.contains("# Calls"), "{text}");
    }

    #[test]
    fn absorb_merges_regions() {
        let a = Profiler::new();
        let b = Profiler::new();
        a.record("x", Duration::from_micros(10));
        b.record("x", Duration::from_micros(30));
        b.record("y", Duration::from_micros(5));
        a.absorb(&b);
        let r = a.report();
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].name, "x");
        assert_eq!(r[0].summary.count(), 2);
        assert_eq!(r[1].summary.count(), 1);
    }

    #[test]
    fn reset_clears() {
        let p = Profiler::new();
        p.record("a", Duration::from_micros(1));
        p.reset();
        assert!(p.report().is_empty());
    }

    #[test]
    fn profiler_is_thread_safe() {
        let p = std::sync::Arc::new(Profiler::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let p = p.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        p.record("x", Duration::from_nanos(100));
                    }
                });
            }
        });
        assert_eq!(p.report()[0].summary.count(), 4000);
    }
}
