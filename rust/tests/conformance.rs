//! §4.2 functional testing: the conformance suite (SOLLVE V&V analog)
//! must pass, and its full report must be **identical** under the legacy
//! and portable runtimes on both architectures — "All ran identically
//! with the new OpenMP runtime as they had using the previous device
//! runtime."

use omprt::conformance::{run_matrix, run_suite};
use omprt::coordinator::Coordinator;
use omprt::devrt::RuntimeKind;
use omprt::sim::Arch;

#[test]
fn suite_passes_on_portable_nvptx() {
    let c = Coordinator::new(RuntimeKind::Portable, Arch::Nvptx64);
    for o in run_suite(&c) {
        assert!(o.result.is_ok(), "{}: {:?}", o.name, o.result);
    }
}

#[test]
fn suite_reports_identical_across_runtimes_and_archs() {
    let (rows, identical) = run_matrix();
    for (kind, arch, outcomes) in &rows {
        for o in outcomes {
            assert!(o.result.is_ok(), "{kind}/{arch} {}: {:?}", o.name, o.result);
        }
    }
    assert!(identical, "conformance observables differ across configurations");
}

#[test]
fn expected_observables_spotcheck() {
    let c = Coordinator::new(RuntimeKind::Legacy, Arch::Amdgcn);
    let outcomes = run_suite(&c);
    let get = |name: &str| {
        outcomes
            .iter()
            .find(|o| o.name == name)
            .unwrap()
            .result
            .clone()
            .unwrap()
    };
    // 2 teams × Σ(0..63)
    assert_eq!(get("atomic.add_sum"), "[4032]");
    // 100 increments wrapping at 6 → 100 % 7
    assert_eq!(get("atomic.inc_wraps"), "[2]");
    // Σ(0..95)
    assert_eq!(get("reduce.add_f64"), "[4560]");
    // Σ tid over one block of 128
    assert_eq!(get("reduce.warp_shuffle_u32"), "[8128]");
    assert_eq!(get("icv.num_threads"), "[40]");
    assert_eq!(get("alloc_shared.stack"), "[1]");
    assert_eq!(get("variant.wrong_arch_intrinsic_traps"), "trapped=true");
}
