//! The benchmark suite: analogs of the six SPEC ACCEL C benchmarks the
//! paper runs (Fig. 2) plus the miniQMC proxy app (Table 1).
//!
//! Each benchmark implements [`Benchmark`]: it builds its device-IR
//! kernels (the "application"), maps its data, launches its target
//! regions through a [`Coordinator`] (which profiles them), and verifies
//! device results against a host reference — the methodology of the
//! paper's §4.2/§4.3 (identical functional behaviour, timed end-to-end).
//!
//! | name      | SPEC analog    | runtime features stressed              |
//! |-----------|----------------|----------------------------------------|
//! | postencil | 503.postencil  | static worksharing, PJRT payload tiles |
//! | polbm     | 504.polbm      | static worksharing, heavy f32 IR ALU   |
//! | pomriq    | 514.pomriq     | dynamic dispatch, fsin/fcos, reduction |
//! | pep       | 552.pep        | thread-local RNG, atomics, reduction   |
//! | pcg       | 554.pcg        | barriers, tree reductions, SpMV        |
//! | pbt       | 570.pbt        | static-chunked scheduling, line solves |
//! | miniqmc   | miniQMC        | generic+SPMD regions, payload matmuls  |

pub mod common;
pub mod harness;
pub mod miniqmc;
pub mod pbt;
pub mod pcg;
pub mod pep;
pub mod polbm;
pub mod pomriq;
pub mod postencil;

pub use common::{BenchResult, Benchmark, Scale};

/// All Fig.-2 benchmarks (SPEC ACCEL analogs), in the paper's order.
pub fn spec_accel(scale: Scale) -> Vec<Box<dyn Benchmark>> {
    vec![
        Box::new(postencil::Postencil::new(scale)),
        Box::new(polbm::Polbm::new(scale)),
        Box::new(pomriq::Pomriq::new(scale)),
        Box::new(pep::Pep::new(scale)),
        Box::new(pcg::Pcg::new(scale)),
        Box::new(pbt::Pbt::new(scale)),
    ]
}

/// Look a benchmark up by name (CLI).
pub fn by_name(name: &str, scale: Scale) -> Option<Box<dyn Benchmark>> {
    let b: Box<dyn Benchmark> = match name {
        "postencil" | "503" => Box::new(postencil::Postencil::new(scale)),
        "polbm" | "504" => Box::new(polbm::Polbm::new(scale)),
        "pomriq" | "514" => Box::new(pomriq::Pomriq::new(scale)),
        "pep" | "552" => Box::new(pep::Pep::new(scale)),
        "pcg" | "554" => Box::new(pcg::Pcg::new(scale)),
        "pbt" | "570" => Box::new(pbt::Pbt::new(scale)),
        "miniqmc" => Box::new(miniqmc::MiniQmc::new(scale)),
        _ => return None,
    };
    Some(b)
}
