//! Rule `delims`: per-file `()` `[]` `{}` balance.
//!
//! This automates the manual "delimiter balance pass" verbatim: because
//! the lexer has already made strings, char literals and comments
//! opaque, any imbalance left in the token stream is a real one. The
//! rule reports the earliest witness: an unmatched closer, a mismatched
//! pair (with the opener's line), or an opener left unclosed at EOF.

use crate::lint::lexer::{Tok, TokKind};
use crate::lint::{Finding, Manifests};

fn closer(open: &str) -> &'static str {
    match open {
        "(" => ")",
        "[" => "]",
        _ => "}",
    }
}

/// Check delimiter balance over `toks`.
pub fn check(file: &str, toks: &[Tok], m: &Manifests) -> Vec<Finding> {
    if m.delims_allow.iter().any(|f| f == file) {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut stack: Vec<&Tok> = Vec::new();
    for t in toks {
        if t.kind != TokKind::Punct {
            continue; // a Str token's text may itself be `(` etc.
        }
        match t.text.as_str() {
            "(" | "[" | "{" => stack.push(t),
            ")" | "]" | "}" => match stack.last() {
                None => out.push(Finding {
                    file: file.to_string(),
                    line: t.line,
                    rule: "delims",
                    msg: format!("unmatched closing `{}`", t.text),
                }),
                Some(o) if closer(&o.text) != t.text => {
                    let o = stack.pop().unwrap();
                    out.push(Finding {
                        file: file.to_string(),
                        line: t.line,
                        rule: "delims",
                        msg: format!("`{}` from line {} closed by `{}`", o.text, o.line, t.text),
                    });
                }
                Some(_) => {
                    stack.pop();
                }
            },
            _ => {}
        }
    }
    for o in stack {
        out.push(Finding {
            file: file.to_string(),
            line: o.line,
            rule: "delims",
            msg: format!("unclosed `{}`", o.text),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn run(src: &str) -> Vec<Finding> {
        check("x.rs", &lex(src), &Manifests::default())
    }

    #[test]
    fn balanced_source_passes() {
        assert!(run("fn f(a: [u8; 4]) { g(a[0], (1 + 2)); }").is_empty());
    }

    #[test]
    fn missing_close_is_reported_at_the_opener() {
        let got = run("fn f() { g(1; }");
        assert!(!got.is_empty());
        assert!(got.iter().any(|f| f.msg.contains('(')));
    }

    #[test]
    fn mismatched_pair_names_both_lines() {
        let got = run("fn f() {\n  g(1]\n}");
        assert!(got.iter().any(|f| f.msg.contains("from line 2") && f.msg.contains(']')));
    }

    #[test]
    fn extra_closer_is_unmatched() {
        let got = run("fn f() { } }");
        assert_eq!(got.len(), 1);
        assert!(got[0].msg.contains("unmatched closing"));
    }

    #[test]
    fn braces_inside_strings_comments_and_chars_are_ignored() {
        let src = "fn f() { let s = \"}}}\"; let r = r#\"((\"#; let c = '{'; /* ]] */ }";
        assert!(run(src).is_empty());
    }

    #[test]
    fn allowlisted_file_passes() {
        let m = Manifests { delims_allow: vec!["x.rs".into()], ..Manifests::default() };
        assert!(check("x.rs", &lex("fn f() {"), &m).is_empty());
    }
}
