//! Summary statistics for benchmark timing — also the backing store of the
//! `nvprof`-analog profiler that regenerates the paper's Table 1 columns
//! (Time, #Calls, Avg, Min, Max).

use super::clock;
use std::time::Duration;

/// Online summary of a series of duration samples.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    total_ns: u128,
    min_ns: u128,
    max_ns: u128,
    /// Sum of squared ns for stddev (Welford would be fancier; samples are
    /// bounded and u128 sums cannot realistically overflow here).
    sumsq_ns: u128,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos();
        if self.n == 0 {
            self.min_ns = ns;
            self.max_ns = ns;
        } else {
            self.min_ns = self.min_ns.min(ns);
            self.max_ns = self.max_ns.max(ns);
        }
        self.n += 1;
        self.total_ns += ns;
        self.sumsq_ns += ns * ns;
    }

    /// Merge another summary into this one.
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
        self.n += other.n;
        self.total_ns += other.total_ns;
        self.sumsq_ns += other.sumsq_ns;
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Total across samples.
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.total_ns.min(u64::MAX as u128) as u64)
    }

    /// Total in milliseconds (Table 1 "Time (ms)" column).
    pub fn total_ms(&self) -> f64 {
        self.total_ns as f64 / 1e6
    }

    /// Mean in microseconds (Table 1 "Avg (µs)" column).
    pub fn avg_us(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.total_ns as f64 / self.n as f64 / 1e3
    }

    /// Min in microseconds.
    pub fn min_us(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.min_ns as f64 / 1e3
    }

    /// Max in microseconds.
    pub fn max_us(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.max_ns as f64 / 1e3
    }

    /// Population standard deviation in microseconds.
    pub fn stddev_us(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let mean = self.total_ns as f64 / self.n as f64;
        let var = self.sumsq_ns as f64 / self.n as f64 - mean * mean;
        var.max(0.0).sqrt() / 1e3
    }
}

/// Time a closure, returning (result, elapsed).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    timed_with(&clock::WallClock, f)
}

/// Time a closure against an injected clock (the pool passes its
/// configured clock so profiler rows stay on the virtual timeline
/// under `VirtualClock`), returning (result, elapsed).
pub fn timed_with<T>(clock: &dyn clock::Clock, f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = clock.now();
    let r = f();
    (r, clock.now().saturating_duration_since(t0))
}

/// Nearest-rank percentile of a sample set: `q` in `[0, 1]` (0.5 =
/// median, 0.95 = p95). Non-finite samples are ignored; an empty (or
/// all-garbage) set yields 0. Used by the pool's per-client latency
/// reporting and the SLO bench assertions, which compare tail latency
/// rather than means.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    let mut v: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(|a, b| a.partial_cmp(b).expect("filtered to finite values"));
    let idx = ((v.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    v[idx]
}

/// Relative difference |a-b| / max(a,b); the paper's Fig. 2 "variance is
/// less than 1%" criterion is `rel_diff < 0.01`.
pub fn rel_diff(a: f64, b: f64) -> f64 {
    if a == 0.0 && b == 0.0 {
        return 0.0;
    }
    (a - b).abs() / a.abs().max(b.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_records_min_max_avg() {
        let mut s = Summary::new();
        s.record(Duration::from_micros(10));
        s.record(Duration::from_micros(20));
        s.record(Duration::from_micros(30));
        assert_eq!(s.count(), 3);
        assert!((s.avg_us() - 20.0).abs() < 1e-9);
        assert!((s.min_us() - 10.0).abs() < 1e-9);
        assert!((s.max_us() - 30.0).abs() < 1e-9);
        assert!((s.total_ms() - 0.06).abs() < 1e-9);
    }

    #[test]
    fn merge_combines() {
        let mut a = Summary::new();
        a.record(Duration::from_micros(5));
        let mut b = Summary::new();
        b.record(Duration::from_micros(15));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.avg_us() - 10.0).abs() < 1e-9);
        assert!((a.min_us() - 5.0).abs() < 1e-9);
        assert!((a.max_us() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_zeroes() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.avg_us(), 0.0);
        assert_eq!(s.stddev_us(), 0.0);
    }

    #[test]
    fn stddev_of_constant_series_is_zero() {
        let mut s = Summary::new();
        for _ in 0..10 {
            s.record(Duration::from_micros(42));
        }
        assert!(s.stddev_us() < 1e-6);
    }

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.95), 7.0);
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert!((percentile(&v, 0.5) - 50.0).abs() <= 1.0);
        assert!((percentile(&v, 0.95) - 95.0).abs() <= 1.0);
        // Garbage samples are ignored, not propagated.
        assert!(percentile(&[1.0, f64::NAN, 3.0], 1.0).is_finite());
    }

    #[test]
    fn timed_with_measures_on_the_injected_clock() {
        let vc = crate::util::vclock::VirtualClock::new();
        let (v, d) = timed_with(&vc, || {
            vc.sleep(Duration::from_millis(7));
            42
        });
        assert_eq!(v, 42);
        assert_eq!(d, Duration::from_millis(7));
    }

    #[test]
    fn rel_diff_basics() {
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
        assert!((rel_diff(100.0, 99.5) - 0.005).abs() < 1e-12);
        assert!(rel_diff(1.0, 2.0) > 0.49);
    }
}
