//! The device pool: N offload devices fed by one async submission queue.
//!
//! Clients [`DevicePool::submit`] an [`OffloadRequest`] and get an
//! [`OffloadHandle`] back immediately; the launch happens on one of the
//! pool's worker threads. See the module docs of [`crate::sched`] for the
//! placement, batching, sharding and backpressure policies.

use super::adaptive::{decide_batch_max, AdaptiveController, AdaptiveStats, SchedSignals};
use super::cache::{CacheStats, ImageCache};
use super::health::{hedge_after, judge, DeviceHealth, HealthState, WatchdogVerdict};
use super::slo::{ServiceEwma, SlackSummary};
use crate::config::Config;
use crate::coordinator::profiler::{Profiler, RegionReport};
use crate::devrt::RuntimeKind;
use crate::hostrt::{KernelImage, MapType, OffloadDevice};
use crate::ir::passes::OptLevel;
use crate::ir::Module;
use crate::sim::{Arch, BatchKernelSpec, FaultSpec, FaultState, LaunchConfig, LaunchStats, MemStats};
use crate::trace::{
    capture_text, chrome_trace_json, Event, EventKind, ExportMeta, Histogram, MetricsRegistry,
    RequestId, TraceSnapshot, TraceStats, Tracer,
};
use crate::util::clock::{self, Clock, ClockHandle, IdleGuard, Participant};
use crate::util::{stats, Error, Summary};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Which devices may serve a request. `None` fields match anything.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Affinity {
    /// Restrict to one architecture.
    pub arch: Option<Arch>,
    /// Restrict to one runtime build.
    pub kind: Option<RuntimeKind>,
}

impl Affinity {
    /// Runs anywhere.
    pub fn any() -> Affinity {
        Affinity::default()
    }

    /// Pin to an architecture.
    pub fn on_arch(arch: Arch) -> Affinity {
        Affinity { arch: Some(arch), kind: None }
    }

    /// Pin to a runtime kind.
    pub fn on_kind(kind: RuntimeKind) -> Affinity {
        Affinity { arch: None, kind: Some(kind) }
    }

    /// Does a device with `(arch, kind)` satisfy this constraint?
    pub fn matches(&self, arch: Arch, kind: RuntimeKind) -> bool {
        self.arch.map_or(true, |a| a == arch) && self.kind.map_or(true, |k| k == kind)
    }
}

/// One host buffer mapped for the duration of a pooled offload.
#[derive(Debug, Clone)]
pub struct MapBuf {
    /// Host bytes (copied to the device for `To`/`Tofrom`).
    pub bytes: Vec<u8>,
    /// Mapping semantics.
    pub map_type: MapType,
}

impl MapBuf {
    /// Map an f32 slice.
    pub fn f32(data: &[f32], map_type: MapType) -> MapBuf {
        MapBuf { bytes: f32_to_bytes(data), map_type }
    }
}

/// f32 slice → little-endian bytes.
pub fn f32_to_bytes(data: &[f32]) -> Vec<u8> {
    data.iter().flat_map(|v| v.to_le_bytes()).collect()
}

/// Little-endian bytes → f32 vector.
pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// A kernel argument: the device address of a mapped buffer, or an
/// immediate scalar.
#[derive(Debug, Clone, Copy)]
pub enum KernelArg {
    /// Address of `buffers[i]` after mapping.
    Buf(usize),
    /// Immediate 64-bit value.
    Imm(u64),
}

/// How to split one large request across several devices.
///
/// Sharding needs to know the request's data decomposition: which buffers
/// are *partitioned* by element range (each shard gets its slice) versus
/// broadcast whole, and which immediate argument carries the element
/// count so each shard can be told its own. Grid-strided kernels — every
/// kernel in this repo — are shardable this way by construction: a shard
/// is just the same kernel over a smaller `n`.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    /// Indices into `buffers` that are partitioned by element range; all
    /// other buffers are passed whole to every shard.
    pub partitioned: Vec<usize>,
    /// Bytes per element of the partitioned buffers.
    pub elem_bytes: usize,
    /// Index into `args` of the `Imm` argument holding the element count.
    pub count_arg: usize,
    /// Total element count of the request.
    pub elems: usize,
}

/// What a client submits to the pool.
pub struct OffloadRequest {
    /// The application module (kernels + globals).
    pub module: Module,
    /// Kernel entry point to launch.
    pub kernel: String,
    /// Profiler region name (aggregated in the pool report).
    pub region: String,
    /// Launch geometry.
    pub cfg: LaunchConfig,
    /// Optimization level for `prepare` (part of the cache key).
    pub opt: OptLevel,
    /// Host buffers to map.
    pub buffers: Vec<MapBuf>,
    /// Kernel arguments in order.
    pub args: Vec<KernelArg>,
    /// Placement constraint.
    pub affinity: Affinity,
    /// Optional decomposition for cross-device sharding. `None` (the
    /// default for all small launches) always runs on one device; with a
    /// spec, the pool may split the request across idle devices of one
    /// architecture when it is large enough to amortize the overhead
    /// (see `[pool] shard_min_trips`).
    pub shard: Option<ShardSpec>,
    /// Multi-tenant fairness tag: requests with the same tag share one
    /// weighted deficit-round-robin lane (see `[pool] fairness` and
    /// `client_weights`). Empty = the default client.
    pub client: String,
    /// Per-request latency budget: submit stamps an absolute deadline
    /// (`now + deadline`) on the queued job, the worker pull may move the
    /// request ahead of the DRR rotation once it enters its *panic
    /// window* (deadline minus predicted service time), and completion
    /// records a deadline-miss / slack sample for the client. `None`
    /// falls back to the client's `[pool] client_slos` target; with
    /// neither, the request is best-effort and never preempts.
    pub deadline: Option<Duration>,
}

/// What the pool hands back when a request completes.
#[derive(Debug)]
pub struct OffloadResponse {
    /// Pool-local id of the device that ran the launch (first shard's
    /// device for a sharded request).
    pub device_id: usize,
    /// Its architecture.
    pub arch: Arch,
    /// Its runtime build.
    pub kind: RuntimeKind,
    /// Launch counters (summed over shards; `wall` is the max).
    pub stats: LaunchStats,
    /// Whether the kernel image came out of the cache (for shards: all of
    /// them).
    pub cache_hit: bool,
    /// Time the request sat in the queue before a worker picked it up
    /// (max over shards).
    pub queue_wait: Duration,
    /// How many device shards executed this request (1 = unsharded).
    pub shards: usize,
    /// Post-launch contents of each `From`/`Tofrom` buffer (`None` for
    /// `To`/`Alloc` buffers). Sharded partitioned outputs are stitched
    /// back into the full-size buffer.
    pub buffers: Vec<Option<Vec<u8>>>,
}

/// Future side of a submission; resolves when a worker finishes the
/// request (or the pool shuts down first).
pub struct OffloadHandle {
    rx: mpsc::Receiver<Result<OffloadResponse, Error>>,
    /// The pool's clock: `wait` parks inside an [`IdleGuard`] so a
    /// driver registered with a virtual clock releases the timeline
    /// while it blocks.
    clock: Arc<dyn Clock>,
}

impl OffloadHandle {
    /// Block until the request completes.
    pub fn wait(self) -> Result<OffloadResponse, Error> {
        let clock = Arc::clone(&self.clock);
        let _idle = IdleGuard::new(&*clock);
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::Sched("pool dropped before the request completed".into())),
        }
    }

    /// Non-blocking poll; `None` while the request is still in flight.
    pub fn try_wait(&self) -> Option<Result<OffloadResponse, Error>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(Error::Sched("pool dropped before the request completed".into())))
            }
        }
    }
}

/// Why [`DevicePool::try_submit`] did not accept a request.
pub enum TrySubmitError {
    /// The submission queue is at capacity (`[pool] queue_cap`); the
    /// request is handed back untouched so the caller can retry or shed
    /// load — the non-blocking `WouldBlock` counterpart of the blocking
    /// [`DevicePool::submit`].
    Full(OffloadRequest),
    /// The request is malformed or unroutable (same checks as `submit`).
    Rejected(Error),
}

impl std::fmt::Debug for TrySubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrySubmitError::Full(_) => write!(f, "Full(<request>)"),
            TrySubmitError::Rejected(e) => write!(f, "Rejected({e})"),
        }
    }
}

/// Handle for a device task submitted with [`DevicePool::run_on`].
pub struct TaskHandle<R> {
    rx: mpsc::Receiver<R>,
    /// See [`OffloadHandle`]: `wait` is an idle window on this clock.
    clock: Arc<dyn Clock>,
}

impl<R> TaskHandle<R> {
    /// Block until the task ran on a pool device.
    pub fn wait(self) -> Result<R, Error> {
        let clock = Arc::clone(&self.clock);
        let _idle = IdleGuard::new(&*clock);
        self.rx
            .recv()
            .map_err(|_| Error::Sched("pool dropped before the task ran".into()))
    }
}

/// What a [`DevicePool::run_on`] closure gets: exclusive use of one pool
/// device (its worker thread is running the closure) plus the device's
/// profiler, so arbitrary multi-launch workloads — e.g. the SPEC-analog
/// benchmarks behind `omprt bench --pool` — can execute through the
/// pool's scheduler without being reshaped into single-launch requests.
pub struct DeviceLease<'a> {
    /// Pool-local device id.
    pub id: usize,
    /// Device spec.
    pub spec: DeviceSpec,
    /// The leased device.
    pub device: &'a Arc<OffloadDevice>,
    /// The device's region profiler (feeds the pool report).
    pub profiler: &'a Profiler,
}

// ---------------------------------------------------------------------------
// Pool configuration
// ---------------------------------------------------------------------------

/// One device of the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceSpec {
    /// Runtime build.
    pub kind: RuntimeKind,
    /// Architecture.
    pub arch: Arch,
}

impl DeviceSpec {
    /// Parse `"<kind>:<arch>"`, e.g. `"portable:nvptx64"`.
    pub fn parse(s: &str) -> Option<DeviceSpec> {
        let (k, a) = s.split_once(':')?;
        Some(DeviceSpec { kind: RuntimeKind::parse(k.trim())?, arch: Arch::parse(a.trim())? })
    }
}

impl std::fmt::Display for DeviceSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.kind, self.arch)
    }
}

/// Pool construction parameters (the `[pool]` config table).
#[derive(Debug, Clone, PartialEq)]
pub struct PoolConfig {
    /// Devices, in pool-id order.
    pub devices: Vec<DeviceSpec>,
    /// Default optimization level for requests (callers still set their
    /// own per-request `opt`; the demo and bench use this).
    pub default_opt: OptLevel,
    /// Most queued same-image requests a worker coalesces into one batch
    /// (1 disables batching).
    pub batch_max: usize,
    /// Submission-queue bound; `submit` blocks (and `try_submit` returns
    /// [`TrySubmitError::Full`]) while the queue is at capacity. 0 =
    /// unbounded.
    pub queue_cap: usize,
    /// Minimum elements each shard must keep; a sharded request that
    /// cannot give at least 2 shards this many elements runs on a single
    /// device instead (shard overhead would dominate).
    pub shard_min_trips: usize,
    /// Per-device kernel-image cache budget in bytes (LRU eviction past
    /// it). 0 = unlimited.
    pub cache_budget_bytes: u64,
    /// Occupancy-driven adaptive scheduling: workers pick the effective
    /// batch limit per queue visit (and the shard planner prefers — and
    /// reserves — idle devices) from live signals instead of the static
    /// knobs above, which then act as hard caps. See [`crate::sched::adaptive`].
    pub adaptive: bool,
    /// Honor per-request client tags with weighted deficit-round-robin
    /// pull, so one chatty client cannot starve others. `false` collapses
    /// every request into one FIFO lane (the pre-fairness behavior).
    pub fairness: bool,
    /// Per-client scheduling weights (default 1.0). A client with weight
    /// 4 receives 4x the pull share of a weight-1 client while both are
    /// backlogged.
    pub client_weights: Vec<(String, f64)>,
    /// Per-client latency targets (SLOs) in milliseconds. Every request
    /// from a listed client is stamped with an absolute deadline at
    /// submit (unless the request carries its own
    /// [`OffloadRequest::deadline`], which wins), making it eligible for
    /// panic-window preemption and deadline-miss accounting. Clients not
    /// listed are best-effort.
    pub client_slos: Vec<(String, f64)>,
    /// Scripted device faults (`[pool] faults = ["<dev>=<spec>"]`, see
    /// [`crate::sim::fault`] for the grammar): per-device injectable
    /// stall, slowdown, transient launch failure or permanent death,
    /// armed at pool construction. Empty = no injection.
    pub faults: Vec<FaultSpec>,
    /// Run the health monitor: a progress watchdog that marks stalled
    /// devices Suspect → Quarantined, re-plans their queued pinned shard
    /// jobs, and re-admits them via cheap probe launches.
    pub watchdog: bool,
    /// Watchdog floor in milliseconds: in-flight work is never judged
    /// suspect before this age, however small the service prediction
    /// (protects cold-start `prepare` time). Quarantine needs at least
    /// twice this age.
    pub watchdog_min_ms: u64,
    /// Bounded retry for device-fault failures: a job that failed with
    /// an injected device fault is retried on a *different* healthy
    /// device up to this many times before the original error is
    /// surfaced to the client. 0 disables retry.
    pub retry_max: u32,
    /// Tail-latency hedging: the health monitor watches in-flight work
    /// and, when a job's age exceeds [`PoolConfig::hedge_after_factor`]
    /// times its EWMA-predicted service time (or its deadline is at
    /// risk), speculatively enqueues a duplicate pinned to an idle
    /// healthy device. First completion wins; the loser is ignored on
    /// arrival, so replies, per-client accounting, deadline judgments
    /// and the trace `Done` still fire exactly once per request.
    pub hedge: bool,
    /// Hedge trigger multiple: a job becomes hedge-worthy once its
    /// in-flight age exceeds this many times the predicted service time
    /// of its executing batch (floored at a quarter of the watchdog
    /// floor, so cold predictions cannot trigger instantly). Min 1.
    pub hedge_after_factor: u32,
    /// Most speculative duplicates allowed in flight at once (bounds the
    /// extra device time hedging may burn). Min 1.
    pub hedge_max: usize,
    /// Record structured trace events (see [`crate::trace`]): every
    /// request's span through the queue, workers, stitchers and the
    /// health layer, drained on demand for the Chrome/Perfetto and
    /// replay-capture exports. Tracing is compile-always but
    /// runtime-gated: with `false` (the default) the emission sites cost
    /// one branch each.
    pub trace: bool,
    /// Per-ring trace capacity in records (one ring per device worker
    /// plus a few shared stripes). 0 selects
    /// [`crate::trace::DEFAULT_TRACE_CAPACITY`]; rings overwrite their
    /// oldest records past capacity and report the drop count.
    pub trace_capacity: usize,
    /// Time source for the whole pool: worker waits, the monitor tick,
    /// EWMA/watchdog/SLO/hedge timestamps, fault triggers and trace
    /// stamps all read this clock. Defaults to the wall clock; inject a
    /// [`crate::util::VirtualClock`] via [`PoolConfig::with_clock`] for
    /// discrete-event time. Not a `[pool]` config key — a clock is
    /// environment, not policy (it compares equal on all configs).
    pub clock: ClockHandle,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig::mixed4()
    }
}

impl PoolConfig {
    /// The canonical 4-device mixed pool: both architectures under both
    /// runtime builds.
    pub fn mixed4() -> PoolConfig {
        PoolConfig {
            devices: vec![
                DeviceSpec { kind: RuntimeKind::Portable, arch: Arch::Nvptx64 },
                DeviceSpec { kind: RuntimeKind::Portable, arch: Arch::Amdgcn },
                DeviceSpec { kind: RuntimeKind::Legacy, arch: Arch::Nvptx64 },
                DeviceSpec { kind: RuntimeKind::Legacy, arch: Arch::Amdgcn },
            ],
            default_opt: OptLevel::O2,
            batch_max: 16,
            queue_cap: 1024,
            shard_min_trips: 4096,
            cache_budget_bytes: 0,
            adaptive: true,
            fairness: true,
            client_weights: vec![],
            client_slos: vec![],
            faults: vec![],
            watchdog: true,
            watchdog_min_ms: 5000,
            retry_max: 2,
            hedge: false,
            hedge_after_factor: 3,
            hedge_max: 2,
            trace: false,
            trace_capacity: 0,
            clock: ClockHandle::default(),
        }
    }

    /// A single-device pool (baseline for the throughput bench).
    pub fn single(kind: RuntimeKind, arch: Arch) -> PoolConfig {
        PoolConfig { devices: vec![DeviceSpec { kind, arch }], ..PoolConfig::mixed4() }
    }

    /// `n` identical devices (the sharding bench/test shape).
    pub fn uniform(kind: RuntimeKind, arch: Arch, n: usize) -> PoolConfig {
        PoolConfig {
            devices: vec![DeviceSpec { kind, arch }; n.max(1)],
            ..PoolConfig::mixed4()
        }
    }

    /// Override the batch limit (1 disables batching).
    pub fn with_batch_max(mut self, batch_max: usize) -> PoolConfig {
        self.batch_max = batch_max.max(1);
        self
    }

    /// Override the queue bound (0 = unbounded).
    pub fn with_queue_cap(mut self, queue_cap: usize) -> PoolConfig {
        self.queue_cap = queue_cap;
        self
    }

    /// Override the minimum per-shard element count.
    pub fn with_shard_min_trips(mut self, trips: usize) -> PoolConfig {
        self.shard_min_trips = trips.max(1);
        self
    }

    /// Override the per-device image-cache budget (0 = unlimited).
    pub fn with_cache_budget(mut self, bytes: u64) -> PoolConfig {
        self.cache_budget_bytes = bytes;
        self
    }

    /// Enable/disable the adaptive scheduling layer (disabled = static
    /// `batch_max` / all-eligible shard fan-out, the PR-2 behavior).
    pub fn with_adaptive(mut self, adaptive: bool) -> PoolConfig {
        self.adaptive = adaptive;
        self
    }

    /// Enable/disable per-client fairness (disabled = one FIFO lane).
    pub fn with_fairness(mut self, fairness: bool) -> PoolConfig {
        self.fairness = fairness;
        self
    }

    /// Set (or overwrite) one client's scheduling weight.
    pub fn with_client_weight(mut self, client: &str, weight: f64) -> PoolConfig {
        match self.client_weights.iter_mut().find(|(c, _)| c == client) {
            Some((_, w)) => *w = weight,
            None => self.client_weights.push((client.to_string(), weight)),
        }
        self
    }

    /// Set (or overwrite) one client's latency target (SLO) in
    /// milliseconds. See [`PoolConfig::client_slos`].
    pub fn with_client_slo(mut self, client: &str, target_ms: f64) -> PoolConfig {
        match self.client_slos.iter_mut().find(|(c, _)| c == client) {
            Some((_, t)) => *t = target_ms,
            None => self.client_slos.push((client.to_string(), target_ms)),
        }
        self
    }

    /// Arm one scripted device fault (builder hook; the config-file
    /// equivalent is `[pool] faults`). Faults referencing a device index
    /// outside the pool are rejected at [`DevicePool::new`].
    pub fn with_fault(mut self, fault: FaultSpec) -> PoolConfig {
        self.faults.push(fault);
        self
    }

    /// [`PoolConfig::with_fault`] from a spec string (see
    /// [`crate::sim::fault`] for the grammar), e.g.
    /// `"2=stall:120ms:10s@launch:40"`.
    pub fn with_fault_spec(self, spec: &str) -> Result<PoolConfig, Error> {
        Ok(self.with_fault(FaultSpec::parse(spec)?))
    }

    /// Enable/disable the health monitor (progress watchdog + quarantine
    /// + probe re-admission). Disabled = the pre-fault-injection
    /// behavior: stalled devices are simply waited on.
    pub fn with_watchdog(mut self, watchdog: bool) -> PoolConfig {
        self.watchdog = watchdog;
        self
    }

    /// Override the watchdog floor (minimum in-flight age before any
    /// suspect/quarantine judgment; clamped to ≥ 1 ms).
    pub fn with_watchdog_min_ms(mut self, ms: u64) -> PoolConfig {
        self.watchdog_min_ms = ms.max(1);
        self
    }

    /// Override the device-fault retry cap (0 disables retry).
    pub fn with_retry_max(mut self, retries: u32) -> PoolConfig {
        self.retry_max = retries;
        self
    }

    /// Enable/disable tail-latency hedging (speculative re-execution of
    /// at-risk in-flight work; see [`PoolConfig::hedge`]).
    pub fn with_hedge(mut self, hedge: bool) -> PoolConfig {
        self.hedge = hedge;
        self
    }

    /// Override the hedge trigger multiple (clamped to ≥ 1).
    pub fn with_hedge_after_factor(mut self, factor: u32) -> PoolConfig {
        self.hedge_after_factor = factor.max(1);
        self
    }

    /// Override the in-flight hedge-duplicate cap (clamped to ≥ 1).
    pub fn with_hedge_max(mut self, max: usize) -> PoolConfig {
        self.hedge_max = max.max(1);
        self
    }

    /// Enable/disable structured event tracing (see [`PoolConfig::trace`]).
    pub fn with_trace(mut self, trace: bool) -> PoolConfig {
        self.trace = trace;
        self
    }

    /// Override the per-ring trace capacity in records (0 = default).
    /// Implies nothing about enablement; combine with
    /// [`PoolConfig::with_trace`].
    pub fn with_trace_capacity(mut self, records: usize) -> PoolConfig {
        self.trace_capacity = records;
        self
    }

    /// Inject the pool's time source (see [`PoolConfig::clock`]): every
    /// scheduler, fault, hedge and trace timing site reads this clock.
    /// Pass an `Arc<`[`crate::util::VirtualClock`]`>` to run the pool on
    /// deterministic discrete-event time.
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> PoolConfig {
        self.clock = ClockHandle::new(clock);
        self
    }

    /// Read the `[pool]` section of a config document:
    ///
    /// ```text
    /// [pool]
    /// devices = ["portable:nvptx64", "legacy:amdgcn"]
    /// opt = "O2"
    /// batch_max = 16          # same-image launches coalesced per pop
    /// queue_cap = 1024        # submission-queue bound (0 = unbounded)
    /// shard_min_trips = 4096  # min elements per shard
    /// cache_budget_bytes = 0  # per-device image-cache LRU budget
    /// adaptive = true         # occupancy-driven batch/shard sizing
    /// fairness = true         # per-client weighted DRR pull
    /// client_weights = ["miniqmc=4", "batch=1"]  # default weight 1.0
    /// client_slos = ["miniqmc=25"]  # latency targets in ms (SLO clients)
    /// faults = ["2=stall:120ms:10s@launch:40"]  # scripted device faults
    /// watchdog = true         # stall watchdog + quarantine + probes
    /// watchdog_min_ms = 5000  # floor below which nothing is suspect
    /// retry_max = 2           # device-fault retries on another device
    /// hedge = false           # tail-latency hedging of at-risk in-flight work
    /// hedge_after_factor = 3  # hedge when age > factor x predicted service
    /// hedge_max = 2           # most hedge duplicates in flight at once
    /// trace = false           # structured event tracing (see crate::trace)
    /// trace_capacity = 0      # per-ring trace records (0 = default)
    /// ```
    ///
    /// Missing section or keys fall back to [`PoolConfig::mixed4`].
    pub fn from_config(cfg: &Config) -> Result<PoolConfig, Error> {
        let mut out = PoolConfig::mixed4();
        let Some(sec) = cfg.section("pool") else {
            return Ok(out);
        };
        if let Some(list) = sec.get("devices").and_then(|v| v.as_str_list()) {
            let mut devices = vec![];
            for s in list {
                let spec = DeviceSpec::parse(s).ok_or_else(|| {
                    Error::Config(format!(
                        "[pool] bad device `{s}` (want \"<legacy|portable>:<nvptx64|amdgcn>\")"
                    ))
                })?;
                devices.push(spec);
            }
            if devices.is_empty() {
                return Err(Error::Config("[pool] devices list is empty".into()));
            }
            out.devices = devices;
        }
        if let Some(s) = sec.get("opt").and_then(|v| v.as_str()) {
            out.default_opt = OptLevel::parse(s)
                .ok_or_else(|| Error::Config(format!("[pool] bad opt `{s}` (want O0|O2)")))?;
        }
        out.batch_max = read_uint(sec, "batch_max", out.batch_max as i64, 1)? as usize;
        out.queue_cap = read_uint(sec, "queue_cap", out.queue_cap as i64, 0)? as usize;
        out.shard_min_trips =
            read_uint(sec, "shard_min_trips", out.shard_min_trips as i64, 1)? as usize;
        out.cache_budget_bytes =
            read_uint(sec, "cache_budget_bytes", out.cache_budget_bytes as i64, 0)? as u64;
        out.adaptive = read_bool(sec, "adaptive", out.adaptive)?;
        out.fairness = read_bool(sec, "fairness", out.fairness)?;
        if let Some(list) = sec.get("client_weights").and_then(|v| v.as_str_list()) {
            let mut weights = vec![];
            for s in list {
                let parsed = s.split_once('=').and_then(|(name, w)| {
                    let w: f64 = w.trim().parse().ok()?;
                    (w > 0.0 && w.is_finite()).then(|| (name.trim().to_string(), w))
                });
                match parsed {
                    Some(pair) => weights.push(pair),
                    None => {
                        return Err(Error::Config(format!(
                            "[pool] bad client weight `{s}` (want \"<client>=<positive weight>\")"
                        )))
                    }
                }
            }
            out.client_weights = weights;
        }
        if let Some(list) = sec.get("client_slos").and_then(|v| v.as_str_list()) {
            let mut slos = vec![];
            for s in list {
                let parsed = s.split_once('=').and_then(|(name, ms)| {
                    let ms: f64 = ms.trim().parse().ok()?;
                    (ms > 0.0 && ms.is_finite()).then(|| (name.trim().to_string(), ms))
                });
                match parsed {
                    Some(pair) => slos.push(pair),
                    None => {
                        return Err(Error::Config(format!(
                            "[pool] bad client SLO `{s}` (want \"<client>=<positive ms>\")"
                        )))
                    }
                }
            }
            out.client_slos = slos;
        }
        if let Some(list) = sec.get("faults").and_then(|v| v.as_str_list()) {
            let mut faults = vec![];
            for s in list {
                faults.push(FaultSpec::parse(s)?);
            }
            out.faults = faults;
        }
        out.watchdog = read_bool(sec, "watchdog", out.watchdog)?;
        out.watchdog_min_ms =
            read_uint(sec, "watchdog_min_ms", out.watchdog_min_ms as i64, 1)? as u64;
        let retry_max = read_uint(sec, "retry_max", out.retry_max as i64, 0)?;
        out.retry_max = u32::try_from(retry_max).map_err(|_| {
            Error::Config(format!("[pool] retry_max too large (max {})", u32::MAX))
        })?;
        out.hedge = read_bool(sec, "hedge", out.hedge)?;
        let hedge_after = read_uint(sec, "hedge_after_factor", out.hedge_after_factor as i64, 1)?;
        out.hedge_after_factor = u32::try_from(hedge_after).map_err(|_| {
            Error::Config(format!("[pool] hedge_after_factor too large (max {})", u32::MAX))
        })?;
        out.hedge_max = read_uint(sec, "hedge_max", out.hedge_max as i64, 1)? as usize;
        out.trace = read_bool(sec, "trace", out.trace)?;
        out.trace_capacity =
            read_uint(sec, "trace_capacity", out.trace_capacity as i64, 0)? as usize;
        Ok(out)
    }
}

/// Read a boolean `[pool]` key.
fn read_bool(sec: &crate::config::Section, key: &str, default: bool) -> Result<bool, Error> {
    match sec.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| Error::Config(format!("[pool] bad {key} `{v:?}` (want true|false)"))),
    }
}

/// Read a non-negative integer `[pool]` key with a minimum-value check.
fn read_uint(
    sec: &crate::config::Section,
    key: &str,
    default: i64,
    min: i64,
) -> Result<i64, Error> {
    match sec.get(key) {
        None => Ok(default),
        Some(v) => match v.as_uint() {
            Some(u) if u as i64 >= min => Ok(u as i64),
            _ => Err(Error::Config(format!("[pool] bad {key} `{v:?}` (want integer >= {min})"))),
        },
    }
}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

/// The batch-compatibility key: two queued requests can be coalesced on a
/// device when their image-cache keys agree (arch/kind are implied by the
/// device doing the popping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct BatchKey {
    content: u64,
    opt: OptLevel,
}

struct OffloadJob {
    /// Shared with the hedging registry: a speculative duplicate reuses
    /// the original's request without copying argument buffers.
    req: Arc<OffloadRequest>,
    key: BatchKey,
    /// Shard jobs are never coalesced: a batch runs on one device, which
    /// would defeat the point of splitting the request. They are also
    /// excluded from per-client accounting — the stitcher records the
    /// whole request once instead.
    is_shard: bool,
    /// Reserved placement: only the worker with this pool id may claim
    /// the job (shard-aware placement pins each shard to an idle device
    /// picked by the planner). `None` = any matching worker.
    target_device: Option<usize>,
    /// Absolute deadline stamped at submit from the request's own budget
    /// or the client's SLO; shard jobs inherit their parent's. `None` =
    /// best-effort.
    deadline: Option<Instant>,
    /// Devices this job already failed on with an injected device fault
    /// (bounded retry excludes them; `len()` is the attempt count).
    tried: Vec<usize>,
    /// The *first* device-fault message, surfaced to the client when the
    /// retry cap is exhausted — later failures on other devices must not
    /// mask the original incident.
    first_fault: Option<String>,
    reply: mpsc::Sender<Result<OffloadResponse, Error>>,
    /// When the job entered the queue for its *current* stint (reset on
    /// retry requeue) — the basis of the queue-wait metric.
    enqueued: Instant,
    /// When the job was first enqueued — the basis of submit-to-
    /// completion sojourn, which spans failed attempts.
    first_enqueued: Instant,
    /// Trace identity: the accepted request this job belongs to. Shard
    /// jobs carry the *parent* request's id; a retried job keeps its id
    /// (the `Retry` event carries the attempt count instead).
    req_id: RequestId,
    /// Hedging winner latch, shared between a request's original job and
    /// any speculative duplicate: the first terminal outcome to swap it
    /// owns the reply, the per-client record, the deadline judgment and
    /// the trace `Done`; the loser is ignored on arrival. Unhedged jobs
    /// carry (and trivially win) their own private latch, so the check
    /// is one uncontended atomic swap on the normal path.
    settled: Arc<AtomicBool>,
    /// Is this job a speculative hedge duplicate launched by the health
    /// monitor? Duplicates resolve into `hedge_wins`/`hedge_wasted`,
    /// are never retried, are never themselves hedged, and a losing
    /// duplicate's service observation never feeds the EWMA.
    is_hedge: bool,
}

type TaskFn = Box<dyn FnOnce(&DeviceLease<'_>) + Send>;

struct TaskJob {
    affinity: Affinity,
    client: String,
    run: TaskFn,
    /// Stamped from the client's SLO at submit (tasks carry no explicit
    /// per-request budget).
    deadline: Option<Instant>,
    enqueued: Instant,
    /// Trace identity (leased tasks are requests too).
    req_id: RequestId,
}

enum Job {
    Offload(OffloadJob),
    Task(TaskJob),
}

impl Job {
    fn affinity(&self) -> Affinity {
        match self {
            Job::Offload(j) => j.req.affinity,
            Job::Task(t) => t.affinity,
        }
    }

    fn client(&self) -> &str {
        match self {
            Job::Offload(j) => &j.req.client,
            Job::Task(t) => &t.client,
        }
    }

    fn target_device(&self) -> Option<usize> {
        match self {
            Job::Offload(j) => j.target_device,
            Job::Task(_) => None,
        }
    }

    /// Has this job already failed on `device_id` with a device fault?
    /// (Retried jobs must land on a *different* device.)
    fn tried_on(&self, device_id: usize) -> bool {
        self.tried().contains(&device_id)
    }

    /// Devices this job already failed on (empty for tasks).
    fn tried(&self) -> &[usize] {
        match self {
            Job::Offload(j) => &j.tried,
            Job::Task(_) => &[],
        }
    }

    fn deadline(&self) -> Option<Instant> {
        match self {
            Job::Offload(j) => j.deadline,
            Job::Task(t) => t.deadline,
        }
    }

    /// Image-cache content key for service-time prediction (`None` for
    /// leased tasks, which have no image).
    fn image_key(&self) -> Option<u64> {
        match self {
            Job::Offload(j) => Some(j.key.content),
            Job::Task(_) => None,
        }
    }

    /// Trace identity: the request this job belongs to.
    fn req_id(&self) -> RequestId {
        match self {
            Job::Offload(j) => j.req_id,
            Job::Task(t) => t.req_id,
        }
    }

    /// Is this one shard of a split request?
    fn is_shard(&self) -> bool {
        match self {
            Job::Offload(j) => j.is_shard,
            Job::Task(_) => false,
        }
    }
}

// ---------------------------------------------------------------------------
// The submission queue: per-client lanes + weighted deficit round robin
// ---------------------------------------------------------------------------

/// A lane's deficit never drops below this: followers coalesced into
/// another lane's batch "borrow" share (their lane is charged without
/// being the leader), and the floor bounds how long the repayment can
/// suppress the lane. Panic-window preemptions charge against the same
/// floor, so an SLO lane repays borrowed share through suppressed
/// rotation turns.
const DEFICIT_FLOOR: f64 = -8.0;

/// Starvation bound for deadline preemption: at most this many
/// *consecutive* panic-window pops before a worker must take one normal
/// DRR pop (which resets the streak). A pathological SLO client whose
/// every request is past deadline therefore drains at most
/// `PANIC_STREAK_MAX` jobs per best-effort job, and best-effort lanes
/// always make progress.
const PANIC_STREAK_MAX: usize = 8;

/// One client's FIFO lane plus its deficit-round-robin accounting.
struct Lane {
    client: String,
    weight: f64,
    /// Pop budget: a lane is eligible to lead a pop while `deficit >= 1`;
    /// every job taken from the lane (leader or coalesced follower)
    /// costs 1. Replenished by `weight` per round while backlogged,
    /// reset to 0 when the lane drains.
    deficit: f64,
    jobs: VecDeque<Job>,
}

impl Lane {
    /// Cap accumulated budget so a lane whose jobs were ineligible for
    /// the sampling workers (affinity pins) cannot hoard an unbounded
    /// burst. Always >= 1 so every lane can eventually lead.
    fn deficit_cap(&self) -> f64 {
        (8.0 * self.weight).max(1.0)
    }
}

/// The pool's submission queue. Jobs live in per-client FIFO lanes;
/// workers pop via weighted deficit round robin (one client cannot
/// starve the rest), coalescing same-image followers across lanes.
/// With `fairness` off every job lands in one shared lane, which
/// degenerates to the original global FIFO.
///
/// `len`/`peak` are maintained inside the same critical section as the
/// mutations that change them, so `peak` can never under-report a
/// transient depth (the PR-2 code sampled `len()` after dropping the
/// lock).
struct SchedQueue {
    lanes: Vec<Lane>,
    by_client: HashMap<String, usize>,
    /// Lane index the next DRR scan starts from.
    cursor: usize,
    len: usize,
    peak: usize,
    fairness: bool,
    weights: HashMap<String, f64>,
    /// Consecutive panic-window preemptions since the last normal DRR
    /// pop (any worker). Capped at [`PANIC_STREAK_MAX`] — the starvation
    /// bound that keeps best-effort lanes draining under deadline
    /// pressure.
    panic_streak: usize,
}

impl SchedQueue {
    fn new(fairness: bool, client_weights: &[(String, f64)]) -> SchedQueue {
        SchedQueue {
            lanes: vec![],
            by_client: HashMap::new(),
            cursor: 0,
            len: 0,
            peak: 0,
            fairness,
            weights: client_weights.iter().cloned().collect(),
            panic_streak: 0,
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn peak(&self) -> usize {
        self.peak
    }

    /// Lane index for `client`, creating the lane on first use.
    fn lane_idx(&mut self, client: &str) -> usize {
        let key = if self.fairness { client } else { "" };
        if let Some(&i) = self.by_client.get(key) {
            return i;
        }
        // Only lane creation can grow the table, so this is the one spot
        // that needs to consider reclaiming drained lanes.
        self.maybe_compact();
        let weight = self.weights.get(key).copied().unwrap_or(1.0).max(0.01);
        self.lanes.push(Lane {
            client: key.to_string(),
            weight,
            deficit: 0.0,
            jobs: VecDeque::new(),
        });
        self.by_client.insert(key.to_string(), self.lanes.len() - 1);
        self.lanes.len() - 1
    }

    fn push(&mut self, job: Job) {
        let client = job.client().to_string();
        let i = self.lane_idx(&client);
        self.lanes[i].jobs.push_back(job);
        self.len += 1;
        self.peak = self.peak.max(self.len);
    }

    /// Lanes persist per client tag (drained lanes hold no budget, so
    /// keeping them is semantically free) — but a workload minting
    /// endless one-off tags would grow the lane table, and every DRR
    /// scan, without bound. Once the table is large and mostly empty,
    /// drop the drained lanes and rebuild the index.
    fn maybe_compact(&mut self) {
        const COMPACT_LANES: usize = 64;
        if self.lanes.len() <= COMPACT_LANES {
            return;
        }
        let empties = self.lanes.iter().filter(|l| l.jobs.is_empty()).count();
        if empties * 2 < self.lanes.len() {
            return;
        }
        self.lanes.retain(|l| !l.jobs.is_empty());
        self.by_client.clear();
        for (i, lane) in self.lanes.iter().enumerate() {
            self.by_client.insert(lane.client.clone(), i);
        }
        self.cursor = 0;
    }

    /// Can the DRR scan claim `job` for the worker of `spec`? Pinned
    /// jobs are deliberately excluded — they are claimable only through
    /// [`SchedQueue::pop_pinned`], which is what keeps the pool's
    /// `reserved` counters balanced. Jobs that already failed on this
    /// device with an injected fault are excluded too: the retry
    /// contract is "a different device".
    fn eligible(job: &Job, spec: DeviceSpec, device_id: usize) -> bool {
        job.affinity().matches(spec.arch, spec.kind)
            && job.target_device().is_none()
            && !job.tried_on(device_id)
    }

    /// Remove the oldest job pinned to `device_id` (reserved shard
    /// placement). Pinned jobs outrank the DRR scan: the planner chose
    /// this device because it was idle, and the stitch serializes on its
    /// slowest shard.
    fn pop_pinned(&mut self, device_id: usize) -> Option<OffloadJob> {
        for i in 0..self.lanes.len() {
            let lane = &mut self.lanes[i];
            if let Some(pos) =
                lane.jobs.iter().position(|j| j.target_device() == Some(device_id))
            {
                let job = lane.jobs.remove(pos).expect("position is in range");
                lane.deficit = (lane.deficit - 1.0).max(DEFICIT_FLOOR);
                if lane.jobs.is_empty() {
                    lane.deficit = 0.0;
                }
                self.len -= 1;
                match job {
                    Job::Offload(j) => return Some(j),
                    Job::Task(_) => unreachable!("tasks are never pinned"),
                }
            }
        }
        None
    }

    /// The first job of `lane` this worker could claim, if it is inside
    /// its *panic window* at `now`: the remaining time to its deadline
    /// is at most the predicted service time for its image
    /// ([`ServiceEwma`]), i.e. it must start now (or should already have
    /// started) to meet the deadline. Head-of-lane semantics: lanes are
    /// FIFO per client, so only the first eligible job is considered — a
    /// deadline further down a lane cannot jump its own client's earlier
    /// work.
    fn head_panic(
        lane: &Lane,
        spec: DeviceSpec,
        device_id: usize,
        now: Instant,
        svc: &ServiceEwma,
    ) -> Option<(usize, Instant)> {
        let pos = lane.jobs.iter().position(|j| Self::eligible(j, spec, device_id))?;
        let job = &lane.jobs[pos];
        let deadline = job.deadline()?;
        let panicking = deadline
            .checked_duration_since(now)
            .map_or(true, |slack| slack <= svc.predict(job.image_key()));
        panicking.then_some((pos, deadline))
    }

    /// Is any job this worker could claim inside its panic window right
    /// now? Consulted before picking the batch limit: urgent work must
    /// not end up trapped behind a long fused grid, so the adaptive
    /// controller collapses the limit to 1 while this holds (see
    /// [`SchedSignals::urgent`]).
    fn any_panic(
        &self,
        spec: DeviceSpec,
        device_id: usize,
        now: Instant,
        svc: &ServiceEwma,
    ) -> bool {
        self.lanes
            .iter()
            .any(|l| Self::head_panic(l, spec, device_id, now, svc).is_some())
    }

    /// Earliest-deadline-first preemption *within the fairness
    /// envelope*: among the lanes whose head job is inside its panic
    /// window, serve the one with the earliest deadline — ignoring the
    /// DRR rotation and the lane's pop budget. The lane is still charged
    /// one deficit per job taken (floored at [`DEFICIT_FLOOR`]), so the
    /// preempted share is repaid through suppressed rotation turns, and
    /// the whole path is gated on the [`PANIC_STREAK_MAX`] starvation
    /// bound: after that many consecutive preemptions, workers fall
    /// through to a normal DRR pop (which resets the streak) before any
    /// further deadline work may jump the line.
    fn pop_panic(
        &mut self,
        spec: DeviceSpec,
        device_id: usize,
        limit: usize,
        now: Instant,
        svc: &ServiceEwma,
    ) -> Option<Work> {
        if self.panic_streak >= PANIC_STREAK_MAX {
            return None;
        }
        let mut best: Option<(usize, usize, Instant)> = None;
        for (i, lane) in self.lanes.iter().enumerate() {
            if let Some((pos, deadline)) = Self::head_panic(lane, spec, device_id, now, svc) {
                if best.map_or(true, |(_, _, b)| deadline < b) {
                    best = Some((i, pos, deadline));
                }
            }
        }
        let (i, pos, _) = best?;
        self.panic_streak += 1;
        let lane = &mut self.lanes[i];
        lane.deficit = (lane.deficit - 1.0).max(DEFICIT_FLOOR);
        let job = lane.jobs.remove(pos).expect("position is in range");
        if lane.jobs.is_empty() {
            lane.deficit = 0.0;
        }
        self.len -= 1;
        match job {
            Job::Task(t) => Some(Work::Task(t)),
            Job::Offload(leader) => {
                let mut batch = vec![leader];
                if limit > 1 && !batch[0].is_shard {
                    self.coalesce(&mut batch, i, spec, device_id, limit);
                }
                Some(Work::Batch(batch))
            }
        }
    }

    /// Pop one unit of work for the worker of `(spec, device_id)`.
    /// Deadline work inside its panic window goes first (EDF, see
    /// [`SchedQueue::pop_panic`]); otherwise this is the weighted-DRR
    /// pop: serve the first lane — in round-robin order from the cursor
    /// — holding both pop budget and an eligible job; coalesce up to
    /// `limit - 1` same-key offload followers from all lanes (each
    /// follower charged to its own lane). The returned flag reports
    /// whether the pop was a deadline preemption. Returns `None` only
    /// when no queued job is eligible for this worker.
    fn pop(
        &mut self,
        spec: DeviceSpec,
        device_id: usize,
        limit: usize,
        now: Instant,
        svc: &ServiceEwma,
    ) -> Option<(Work, bool)> {
        if let Some(work) = self.pop_panic(spec, device_id, limit, now, svc) {
            return Some((work, true));
        }
        for pass in 0..2 {
            let n = self.lanes.len();
            for k in 0..n {
                let i = (self.cursor + k) % n;
                if self.lanes[i].deficit < 1.0 {
                    continue;
                }
                let Some(pos) = self.lanes[i]
                    .jobs
                    .iter()
                    .position(|j| Self::eligible(j, spec, device_id))
                else {
                    continue;
                };
                self.cursor = (i + 1) % n;
                self.panic_streak = 0;
                let lane = &mut self.lanes[i];
                lane.deficit -= 1.0;
                let job = lane.jobs.remove(pos).expect("position is in range");
                if lane.jobs.is_empty() {
                    lane.deficit = 0.0;
                }
                self.len -= 1;
                match job {
                    Job::Task(t) => return Some((Work::Task(t), false)),
                    Job::Offload(leader) => {
                        let mut batch = vec![leader];
                        if limit > 1 && !batch[0].is_shard {
                            self.coalesce(&mut batch, i, spec, device_id, limit);
                        }
                        return Some((Work::Batch(batch), false));
                    }
                }
            }
            if pass == 0 && !self.replenish_for(spec, device_id) {
                return None;
            }
        }
        None
    }

    /// Refill pop budgets ahead of a second DRR pass. Returns `false`
    /// when no queued job is eligible for this worker (nothing to wait
    /// for from this pop). Weights may be fractional and deficits
    /// negative (batch borrowing), so the number of `+weight` rounds the
    /// fastest eligible lane needs to afford a pop is computed in closed
    /// form, then every backlogged lane advances that many rounds in one
    /// pass (capping once is equivalent to capping per round — the
    /// increase is monotone).
    fn replenish_for(&mut self, spec: DeviceSpec, device_id: usize) -> bool {
        let mut rounds: f64 = f64::INFINITY;
        let mut any_eligible = false;
        for lane in &self.lanes {
            if !lane.jobs.iter().any(|j| Self::eligible(j, spec, device_id)) {
                continue;
            }
            any_eligible = true;
            // Rounds this lane needs to reach a deficit of 1.0. Callers
            // replenish only when no eligible lane can already afford a
            // pop, so `need` is positive; max(1.0) guards the boundary.
            let need = 1.0 - lane.deficit;
            rounds = rounds.min((need / lane.weight).ceil().max(1.0));
        }
        if !any_eligible {
            return false;
        }
        for lane in &mut self.lanes {
            if !lane.jobs.is_empty() {
                lane.deficit = (lane.deficit + rounds * lane.weight).min(lane.deficit_cap());
            }
        }
        true
    }

    /// Pull same-key, unpinned, non-shard offload jobs into `batch`,
    /// starting with the leader's own lane (preserving that client's
    /// FIFO order) and then the other lanes in cursor order. Followers
    /// are charged to their own lane's deficit — riding a foreign batch
    /// still spends that client's share (floored, so the debt is
    /// bounded).
    fn coalesce(
        &mut self,
        batch: &mut Vec<OffloadJob>,
        leader_lane: usize,
        spec: DeviceSpec,
        device_id: usize,
        limit: usize,
    ) {
        let key = batch[0].key;
        let n = self.lanes.len();
        for k in 0..n {
            if batch.len() >= limit {
                break;
            }
            let li = (leader_lane + k) % n;
            let lane = &mut self.lanes[li];
            let mut i = 0;
            while batch.len() < limit && i < lane.jobs.len() {
                let compatible = matches!(
                    &lane.jobs[i],
                    Job::Offload(o) if o.key == key
                        && !o.is_shard
                        && o.target_device.is_none()
                        && !o.tried.contains(&device_id)
                        && o.req.affinity.matches(spec.arch, spec.kind)
                );
                if compatible {
                    match lane.jobs.remove(i) {
                        Some(Job::Offload(o)) => batch.push(o),
                        _ => unreachable!("index i held an offload job"),
                    }
                    lane.deficit = (lane.deficit - 1.0).max(DEFICIT_FLOOR);
                    self.len -= 1;
                } else {
                    i += 1;
                }
            }
            if lane.jobs.is_empty() {
                lane.deficit = 0.0;
            }
        }
    }

    /// Preemptive shard re-planning: retarget every still-queued job
    /// pinned to `device` (just quarantined). `choose` picks a
    /// replacement device for one job — typically a currently idle
    /// healthy device, claimed by the caller as it chooses — or `None`
    /// to unpin the job, which makes it visible to the normal DRR scan
    /// (any matching worker may then claim it). Returns how many jobs
    /// were re-planned; the caller owns the `reserved`-counter
    /// rebalancing and must run under the queue lock it already holds.
    fn replan_pinned(
        &mut self,
        device: usize,
        mut choose: impl FnMut(&OffloadJob) -> Option<usize>,
    ) -> usize {
        let mut moved = 0;
        for lane in &mut self.lanes {
            for job in &mut lane.jobs {
                if let Job::Offload(o) = job {
                    if o.target_device == Some(device) {
                        o.target_device = choose(o);
                        moved += 1;
                    }
                }
            }
        }
        moved
    }

    /// Remove every queued *unpinned* job for which `stranded` holds
    /// (its affinity matches no live device — see the quarantine sweep
    /// in `quarantine_and_replan`), so its client fails fast instead of
    /// waiting on a dead device. Pinned jobs are skipped: re-planning
    /// has already routed them, and their reservation accounting is
    /// owned elsewhere.
    fn remove_stranded(&mut self, stranded: impl Fn(&Job) -> bool) -> Vec<Job> {
        let mut out = vec![];
        for lane in &mut self.lanes {
            let mut i = 0;
            while i < lane.jobs.len() {
                if lane.jobs[i].target_device().is_none() && stranded(&lane.jobs[i]) {
                    out.push(lane.jobs.remove(i).expect("index is in range"));
                    self.len -= 1;
                } else {
                    i += 1;
                }
            }
            if lane.jobs.is_empty() {
                lane.deficit = 0.0;
            }
        }
        out
    }

    /// Remove every queued job (shutdown path).
    fn drain(&mut self) -> Vec<Job> {
        let mut out = Vec::with_capacity(self.len);
        for lane in &mut self.lanes {
            out.extend(lane.jobs.drain(..));
            lane.deficit = 0.0;
        }
        self.len = 0;
        out
    }
}

/// Per-device state shared with the device's worker thread.
struct DeviceSlot {
    id: usize,
    spec: DeviceSpec,
    device: Arc<OffloadDevice>,
    cache: ImageCache,
    profiler: Profiler,
    inflight: AtomicUsize,
    completed: AtomicU64,
    batches: AtomicU64,
    batched_jobs: AtomicU64,
    max_batch: AtomicUsize,
    /// Nanoseconds this device's worker spent executing work (occupancy
    /// = busy / uptime).
    busy_ns: AtomicU64,
    /// Health lifecycle state + progress timestamps (see
    /// [`crate::sched::health`]).
    health: DeviceHealth,
    /// Scripted fault, armed at pool construction (`[pool] faults`).
    fault: Option<FaultState>,
}

/// Per-client completion accounting (behind `Shared::clients`).
#[derive(Default)]
struct ClientAccum {
    completed: u64,
    failed: u64,
    /// Time requests sat queued before a worker claimed them.
    queue_wait: Summary,
    /// Submit-to-completion sojourn time.
    latency: Summary,
    /// Log-bucketed sojourn distribution (µs). Unlike the capped sample
    /// ring it replaced, this covers *every* completion with bounded
    /// memory and merges exactly across clients, so p50/p95/p99 are
    /// lifetime quantiles (exact within a ~1.5× bucket), not a window.
    latency_hist: Histogram,
    /// Log-bucketed queue-wait distribution (µs).
    queue_wait_hist: Histogram,
    /// Log-bucketed signed deadline-slack distribution (µs; negative =
    /// missed).
    slack_hist: Histogram,
    /// Requests that carried a deadline (explicit budget or client SLO).
    deadlines: u64,
    /// Deadlined requests that completed after their deadline. A sharded
    /// request counts once (its stitcher records it), not per shard.
    deadline_miss: u64,
    /// Signed slack (deadline − completion) over deadlined requests.
    slack: SlackSummary,
}

/// One executing job as seen by the hedging monitor (the value side of
/// `Shared::inflight_reg`). Everything a speculative duplicate needs is
/// captured here — shared request `Arc`, reply sender clone, settle
/// latch — so the monitor can mint the duplicate without touching the
/// worker that owns the original.
struct InflightEntry {
    req: Arc<OffloadRequest>,
    key: BatchKey,
    is_shard: bool,
    deadline: Option<Instant>,
    /// Devices the original already failed on — the duplicate must not
    /// land there (nor on the device the original is running on now).
    tried: Vec<usize>,
    /// Device the original is executing on.
    device: usize,
    /// When the enclosing batch began executing.
    started: Instant,
    /// Jobs in the executing batch: the service prediction scales with
    /// it, since the EWMA tracks per-job time.
    batch_jobs: u64,
    req_id: RequestId,
    reply: mpsc::Sender<Result<OffloadResponse, Error>>,
    settled: Arc<AtomicBool>,
    first_enqueued: Instant,
    /// A duplicate was already launched for this entry (one hedge per
    /// in-flight stint).
    hedged: bool,
}

struct Shared {
    queue: Mutex<SchedQueue>,
    /// Workers wait here for jobs.
    cv: Condvar,
    /// Submitters wait here for queue space (when `queue_cap > 0`).
    space: Condvar,
    shutdown: AtomicBool,
    slots: Vec<DeviceSlot>,
    /// Static batch limit; the adaptive controller's hard cap.
    batch_max: usize,
    queue_cap: usize,
    shard_min_trips: usize,
    /// Occupancy-driven batch/shard sizing on/off.
    adaptive: bool,
    controller: AdaptiveController,
    /// Pinned shard jobs queued per device (the reservation table): a
    /// device with a nonzero count is spoken for and not "idle" to the
    /// shard planner.
    reserved: Vec<AtomicUsize>,
    /// Per-client request accounting, keyed by client tag ("" = the
    /// default client). Sharded requests are recorded once by their
    /// stitcher, not per shard job.
    clients: Mutex<BTreeMap<String, ClientAccum>>,
    /// Configured weights, for reports (scheduling reads the copy inside
    /// [`SchedQueue`]).
    client_weights: Vec<(String, f64)>,
    /// Per-client latency targets: submit stamps `now + target` as the
    /// absolute deadline on requests from these clients (unless the
    /// request carries its own budget).
    slos: HashMap<String, Duration>,
    /// Per-image service-time EWMAs feeding panic-window prediction.
    service: ServiceEwma,
    /// Queue pops that went through the EDF panic path instead of the
    /// DRR rotation.
    preemptions: AtomicU64,
    /// Health monitor on/off (`[pool] watchdog`).
    watchdog: bool,
    /// Watchdog floor: minimum in-flight age before suspicion.
    watchdog_min: Duration,
    /// Device-fault retry cap per job.
    retry_max: u32,
    /// Tail-latency hedging on/off (`[pool] hedge`).
    hedge: bool,
    /// Hedge trigger multiple: duplicate once in-flight age exceeds
    /// `hedge_after_factor x` the predicted batch service time.
    hedge_after_factor: u32,
    /// Most hedge duplicates in flight at once.
    hedge_max: usize,
    /// Hedge duplicates launched by the monitor.
    hedges: AtomicU64,
    /// Duplicates that completed first and owned their request's reply.
    hedge_wins: AtomicU64,
    /// Duplicates that lost the settle race, failed, or drained
    /// unresolved at shutdown/stranding.
    hedge_wasted: AtomicU64,
    /// Duplicates launched but not yet resolved (capped at `hedge_max`).
    hedges_inflight: AtomicUsize,
    /// Token allocator for the in-flight registry.
    hedge_seq: AtomicU64,
    /// The hedging monitor's view of executing work: one entry per
    /// hedge-eligible job currently inside `run_offload_batch`, keyed by
    /// a per-job token. Workers register on launch start and deregister
    /// on launch end; the monitor scans for at-risk entries. The lock is
    /// never held together with the queue lock (registration happens
    /// after the pop, hedge enqueues take the queue lock only after
    /// releasing this one), so no lock-order cycle exists.
    inflight_reg: Mutex<HashMap<u64, InflightEntry>>,
    /// Quarantine incidents that triggered a pinned-job re-plan sweep.
    replans: AtomicU64,
    /// Still-queued pinned jobs retargeted/unpinned by those sweeps.
    replanned_jobs: AtomicU64,
    /// Jobs re-queued onto a different device after a device fault.
    retries: AtomicU64,
    /// Jobs whose retry budget ran out (original fault surfaced).
    retries_exhausted: AtomicU64,
    /// Quarantine re-admission probes attempted.
    probes: AtomicU64,
    /// Probes that passed and returned a device to service.
    readmissions: AtomicU64,
    /// Bumped on every queue push — submissions *and* retry requeues.
    /// Probe-failure sweeps compare it against `last_sweep_gen` so a
    /// long-dead device doesn't re-scan an unchanged queue, while any
    /// job that entered since the last sweep (including a retry that
    /// raced a quarantine) is guaranteed a rescue sweep.
    queue_gen: AtomicU64,
    /// `queue_gen` as of the last stranded sweep.
    last_sweep_gen: AtomicU64,
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    sharded_requests: AtomicU64,
    shard_jobs: AtomicU64,
    started: Instant,
    /// Event tracing: request-id allocation always, ring emission only
    /// when `[pool] trace = true`.
    tracer: Tracer,
    /// The pool's time source ([`PoolConfig::clock`]): every timing
    /// site below reads this handle, never the free-function facade, so
    /// an injected [`crate::util::VirtualClock`] governs the whole
    /// scheduler.
    clock: Arc<dyn Clock>,
}

impl Shared {
    /// Nanoseconds since the pool started (the watchdog's clock).
    fn now_ns(&self) -> u64 {
        let since = self.clock.now().saturating_duration_since(self.started);
        since.as_nanos().min(u64::MAX as u128) as u64
    }

    /// Is there a non-quarantined device matching `affinity` outside
    /// `tried`? The shared core of the submit/lease fail-fast, retry
    /// eligibility and stranded-sweep policies — one rule, one place.
    fn any_live_candidate(&self, affinity: Affinity, tried: &[usize]) -> bool {
        self.slots.iter().any(|s| {
            s.health.state() != HealthState::Quarantined
                && !tried.contains(&s.id)
                && affinity.matches(s.spec.arch, s.spec.kind)
        })
    }

    /// Is there a live (non-quarantined) device matching `affinity`?
    fn any_live_match(&self, affinity: Affinity) -> bool {
        self.any_live_candidate(affinity, &[])
    }
}

/// Append one completed/failed request to `map` (the `Shared::clients`
/// table, locked by the caller). `get_mut` first so the common
/// already-seen-client path allocates nothing. When the request carried
/// a `deadline`, its outcome is compared against completion time *here*
/// — exactly once per request, which is what keeps miss counts correct
/// for sharded requests (recorded by their stitcher, never per shard).
/// This is also the one place every request terminates, so it closes the
/// request's trace span: a `DeadlineJudged` event when a deadline was
/// judged, then the terminal `Done` event.
#[allow(clippy::too_many_arguments)]
fn record_into(
    map: &mut BTreeMap<String, ClientAccum>,
    tracer: &Tracer,
    req: RequestId,
    client: &str,
    queue_wait: Duration,
    latency: Duration,
    ok: bool,
    deadline: Option<Instant>,
    completed: Instant,
) {
    let acc = match map.get_mut(client) {
        Some(acc) => acc,
        None => map.entry(client.to_string()).or_default(),
    };
    if ok {
        acc.completed += 1;
    } else {
        acc.failed += 1;
    }
    acc.queue_wait.record(queue_wait);
    acc.latency.record(latency);
    acc.latency_hist.record(latency);
    acc.queue_wait_hist.record(queue_wait);
    if let Some(dl) = deadline {
        acc.deadlines += 1;
        // Judged against when the work actually finished (`completed`,
        // captured by the worker/stitcher before taking this lock), not
        // the accounting instant — lock contention on the clients table
        // must not turn met deadlines into recorded misses.
        let (miss, slack_us) = match dl.checked_duration_since(completed) {
            Some(slack) => {
                acc.slack.record_secs(slack.as_secs_f64());
                acc.slack_hist.record_us(slack.as_secs_f64() * 1e6);
                (false, slack.as_secs_f64() * 1e6)
            }
            None => {
                acc.deadline_miss += 1;
                let over = completed.saturating_duration_since(dl).as_secs_f64();
                acc.slack.record_secs(-over);
                acc.slack_hist.record_us(-over * 1e6);
                (true, -over * 1e6)
            }
        };
        tracer.emit(
            None,
            Event::new(EventKind::DeadlineJudged)
                .req(req)
                .a(miss as u64)
                .b((slack_us as i64) as u64)
                .c(tracer.client_id(client)),
        );
    }
    tracer.emit(
        None,
        Event::new(EventKind::Done)
            .req(req)
            .a(ok as u64)
            .b(latency.as_nanos().min(u64::MAX as u128) as u64)
            .c(tracer.client_id(client)),
    );
}

/// Single-record convenience (task and stitcher paths; the batched reply
/// loop locks once for the whole batch instead).
#[allow(clippy::too_many_arguments)]
fn record_client(
    shared: &Shared,
    req: RequestId,
    client: &str,
    queue_wait: Duration,
    latency: Duration,
    ok: bool,
    deadline: Option<Instant>,
    completed: Instant,
) {
    let mut map = shared.clients.lock().unwrap();
    record_into(
        &mut map,
        &shared.tracer,
        req,
        client,
        queue_wait,
        latency,
        ok,
        deadline,
        completed,
    );
}

/// A pool of offload devices with per-device worker threads.
pub struct DevicePool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// The health monitor ("pool-health"), when the watchdog is on.
    monitor: Option<std::thread::JoinHandle<()>>,
}

impl DevicePool {
    /// Build the devices and start one worker thread per device.
    pub fn new(config: &PoolConfig) -> Result<DevicePool, Error> {
        if config.devices.is_empty() {
            return Err(Error::Sched("pool needs at least one device".into()));
        }
        for f in &config.faults {
            if f.device >= config.devices.len() {
                return Err(Error::Config(format!(
                    "fault `{f}` references device {} but the pool has {}",
                    f.device,
                    config.devices.len()
                )));
            }
            if config.faults.iter().filter(|o| o.device == f.device).count() > 1 {
                return Err(Error::Config(format!(
                    "device {} has more than one fault spec",
                    f.device
                )));
            }
        }
        let clock: Arc<dyn Clock> = Arc::clone(&config.clock.0);
        let slots: Vec<DeviceSlot> = config
            .devices
            .iter()
            .enumerate()
            .map(|(id, spec)| DeviceSlot {
                id,
                spec: *spec,
                device: Arc::new(
                    OffloadDevice::new(spec.kind, spec.arch).with_clock(Arc::clone(&clock)),
                ),
                cache: ImageCache::with_budget(config.cache_budget_bytes),
                profiler: Profiler::new(),
                inflight: AtomicUsize::new(0),
                completed: AtomicU64::new(0),
                batches: AtomicU64::new(0),
                batched_jobs: AtomicU64::new(0),
                max_batch: AtomicUsize::new(0),
                busy_ns: AtomicU64::new(0),
                health: DeviceHealth::new(),
                fault: config
                    .faults
                    .iter()
                    .find(|f| f.device == id)
                    .map(|f| FaultState::arm_with_clock(f.clone(), Arc::clone(&clock))),
            })
            .collect();
        let reserved = (0..config.devices.len()).map(|_| AtomicUsize::new(0)).collect();
        let shared = Arc::new(Shared {
            queue: Mutex::new(SchedQueue::new(config.fairness, &config.client_weights)),
            cv: Condvar::new(),
            space: Condvar::new(),
            shutdown: AtomicBool::new(false),
            slots,
            batch_max: config.batch_max.max(1),
            queue_cap: config.queue_cap,
            shard_min_trips: config.shard_min_trips.max(1),
            adaptive: config.adaptive,
            controller: AdaptiveController::new(),
            reserved,
            clients: Mutex::new(BTreeMap::new()),
            client_weights: config.client_weights.clone(),
            slos: config
                .client_slos
                .iter()
                .filter(|(_, ms)| *ms > 0.0 && ms.is_finite())
                .map(|(c, ms)| (c.clone(), Duration::from_secs_f64(ms / 1e3)))
                .collect(),
            service: ServiceEwma::new(),
            preemptions: AtomicU64::new(0),
            watchdog: config.watchdog,
            watchdog_min: Duration::from_millis(config.watchdog_min_ms.max(1)),
            retry_max: config.retry_max,
            hedge: config.hedge,
            hedge_after_factor: config.hedge_after_factor.max(1),
            hedge_max: config.hedge_max.max(1),
            hedges: AtomicU64::new(0),
            hedge_wins: AtomicU64::new(0),
            hedge_wasted: AtomicU64::new(0),
            hedges_inflight: AtomicUsize::new(0),
            hedge_seq: AtomicU64::new(0),
            inflight_reg: Mutex::new(HashMap::new()),
            replans: AtomicU64::new(0),
            replanned_jobs: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            retries_exhausted: AtomicU64::new(0),
            probes: AtomicU64::new(0),
            readmissions: AtomicU64::new(0),
            queue_gen: AtomicU64::new(0),
            last_sweep_gen: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            sharded_requests: AtomicU64::new(0),
            shard_jobs: AtomicU64::new(0),
            started: clock.now(),
            tracer: Tracer::with_clock(
                config.trace,
                config.trace_capacity,
                config.devices.len(),
                Arc::clone(&clock),
            ),
            clock,
        });
        let mut workers = vec![];
        for id in 0..config.devices.len() {
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("pool-dev{id}"))
                .spawn(move || worker_loop(&shared, id))
                .map_err(|e| Error::Sched(format!("cannot spawn pool worker: {e}")))?;
            workers.push(handle);
        }
        // The monitor thread hosts both the watchdog and the hedging
        // scan; either feature needs it running.
        let monitor = if config.watchdog || config.hedge {
            let shared = shared.clone();
            Some(
                std::thread::Builder::new()
                    .name("pool-health".into())
                    .spawn(move || monitor_loop(&shared))
                    .map_err(|e| Error::Sched(format!("cannot spawn health monitor: {e}")))?,
            )
        } else {
            None
        };
        Ok(DevicePool { shared, workers, monitor })
    }

    /// Number of devices.
    pub fn device_count(&self) -> usize {
        self.shared.slots.len()
    }

    /// Device specs in pool-id order.
    pub fn specs(&self) -> Vec<DeviceSpec> {
        self.shared.slots.iter().map(|s| s.spec).collect()
    }

    /// The pool's time source. External drivers that pace submissions
    /// against recorded timelines (the trace replay engine) must sleep
    /// on *this* clock, so pacing is wall time on a wall pool and
    /// discrete-event time under a [`crate::util::VirtualClock`].
    pub fn clock(&self) -> Arc<dyn Clock> {
        Arc::clone(&self.shared.clock)
    }

    /// The configured shard granularity (`[pool] shard_min_trips`):
    /// the planner never fans out below this many elements per shard.
    /// Replay uses it to size payloads so a recorded fan-out is
    /// reproduced exactly.
    pub fn shard_min_trips(&self) -> usize {
        self.shared.shard_min_trips
    }

    /// Fail fast when the request is malformed, its affinity matches no
    /// pool device, or its shard spec is inconsistent.
    fn validate(&self, req: &OffloadRequest) -> Result<(), Error> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Sched("pool is shut down".into()));
        }
        if req.kernel.is_empty() {
            return Err(Error::Sched("request has no kernel name".into()));
        }
        validate_client_name(&req.client)?;
        for a in &req.args {
            if let KernelArg::Buf(i) = a {
                if *i >= req.buffers.len() {
                    return Err(Error::Sched(format!(
                        "arg references buffer {i} but only {} buffers are mapped",
                        req.buffers.len()
                    )));
                }
            }
        }
        if !self
            .shared
            .slots
            .iter()
            .any(|s| req.affinity.matches(s.spec.arch, s.spec.kind))
        {
            return Err(Error::Sched(format!(
                "affinity {:?} matches no device in the pool ({:?})",
                req.affinity,
                self.specs().iter().map(|s| s.to_string()).collect::<Vec<_>>()
            )));
        }
        // Deadline work must never wait on a dead device: when every
        // matching device sits in quarantine, fail fast — the client can
        // shed or retry; re-admission lifts this the moment a probe
        // passes.
        if !self.shared.any_live_match(req.affinity) {
            return Err(Error::Fault(format!(
                "every device matching affinity {:?} is quarantined",
                req.affinity
            )));
        }
        if let Some(spec) = &req.shard {
            if spec.elem_bytes == 0 || spec.elems == 0 {
                return Err(Error::Sched("shard spec with zero elems or elem_bytes".into()));
            }
            match req.args.get(spec.count_arg) {
                Some(KernelArg::Imm(_)) => {}
                _ => {
                    return Err(Error::Sched(format!(
                        "shard count_arg {} must index an Imm argument",
                        spec.count_arg
                    )))
                }
            }
            let want = spec
                .elems
                .checked_mul(spec.elem_bytes)
                .ok_or_else(|| Error::Sched("shard spec size overflow".into()))?;
            for &bi in &spec.partitioned {
                let len = req
                    .buffers
                    .get(bi)
                    .ok_or_else(|| {
                        Error::Sched(format!("shard partitions missing buffer {bi}"))
                    })?
                    .bytes
                    .len();
                if len != want {
                    return Err(Error::Sched(format!(
                        "partitioned buffer {bi} is {len} bytes, expected {want} \
                         (elems * elem_bytes)"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Submit a request; returns a handle resolving to the response.
    ///
    /// Fails fast (without enqueueing) when the request is malformed or
    /// its affinity matches no device in the pool. When the pool has a
    /// `queue_cap`, a full queue makes `submit` **block** until workers
    /// drain space (backpressure); use [`DevicePool::try_submit`] to shed
    /// load instead.
    ///
    /// A request carrying a [`ShardSpec`] that is large enough (see
    /// `[pool] shard_min_trips`) is split into per-device shards across
    /// the matching architecture with the most eligible devices; the
    /// handle resolves to the stitched response.
    ///
    /// Requests with a latency budget — their own
    /// [`OffloadRequest::deadline`] or a `[pool] client_slos` target for
    /// their client — are stamped with an absolute deadline here; shard
    /// jobs inherit the parent's deadline, so a panicking sharded
    /// request pulls **all** its shards ahead.
    pub fn submit(&self, req: OffloadRequest) -> Result<OffloadHandle, Error> {
        // Span anchor: captured on entry so the request's trace span
        // covers validation, shard planning and any backpressure wait.
        // The `Submit` event itself is only emitted after the request is
        // *accepted* (enqueued), so every `Submit` in a trace is a real
        // admission — the replay capture needs no filtering.
        let t0 = self.shared.tracer.now_ns();
        self.validate(&req)?;
        let rid = self.shared.tracer.next_request_id();
        let deadline = self.stamp_deadline(&req);
        if let Some(plan) = self.shard_plan(&req) {
            let fanout = plan.ranges.len();
            let arch = plan.arch;
            let (jobs, parts) = self.build_shards(&req, &plan, deadline, rid);
            let n = jobs.len();
            // Spawn first (so a spawn failure queues nothing), then
            // enqueue all shard jobs in one critical section — the
            // reserved devices see their pinned work the moment any of
            // it is visible — and only then arm the stitcher. A failed
            // enqueue drops `arm` and the stitcher exits without a
            // trace.
            let (frx, arm) = spawn_stitcher(&req, parts, self.shared.clone(), deadline, rid)?;
            self.enqueue_bulk(jobs.into_iter().map(Job::Offload).collect())?;
            let _ = arm.send(());
            self.shared.sharded_requests.fetch_add(1, Ordering::Relaxed);
            self.shared.shard_jobs.fetch_add(n as u64, Ordering::Relaxed);
            self.emit_submit(t0, rid, &req.client, req.module.content_hash(), deadline);
            self.shared.tracer.emit(
                None,
                Event::new(EventKind::ShardPlanned)
                    .req(rid)
                    .a(fanout as u64)
                    .b(arch_code(arch)),
            );
            return Ok(OffloadHandle { rx: frx, clock: Arc::clone(&self.shared.clock) });
        }
        let (reply, rx) = mpsc::channel();
        let job = make_offload_job(req, reply, false, None, deadline, rid, self.shared.clock.now());
        let key = job.key.content;
        // The job (and its request) moves into the queue; clone the
        // client tag for the post-acceptance Submit event only when it
        // will actually be emitted.
        let client = if self.shared.tracer.enabled() {
            job.req.client.clone()
        } else {
            String::new()
        };
        self.enqueue_bulk(vec![Job::Offload(job)])?;
        self.emit_submit(t0, rid, &client, key, deadline);
        Ok(OffloadHandle { rx, clock: Arc::clone(&self.shared.clock) })
    }

    /// Absolute deadline for `req`, if it has a latency budget: the
    /// request's own [`OffloadRequest::deadline`] wins over the client's
    /// configured SLO; neither means best-effort (`None`).
    fn stamp_deadline(&self, req: &OffloadRequest) -> Option<Instant> {
        let budget = req
            .deadline
            .or_else(|| self.shared.slos.get(&req.client).copied())?;
        self.shared.clock.now().checked_add(budget)
    }

    /// Non-blocking [`DevicePool::submit`]: when the queue is at capacity
    /// the request is returned in [`TrySubmitError::Full`] instead of
    /// blocking. A sharded request is accepted only if **all** its shard
    /// jobs fit at once.
    pub fn try_submit(&self, req: OffloadRequest) -> Result<OffloadHandle, TrySubmitError> {
        let t0 = self.shared.tracer.now_ns();
        if let Err(e) = self.validate(&req) {
            return Err(TrySubmitError::Rejected(e));
        }
        let rid = self.shared.tracer.next_request_id();
        let deadline = self.stamp_deadline(&req);
        if let Some(plan) = self.shard_plan(&req) {
            // Cheap capacity check before materializing shard buffers and
            // spawning the stitcher: under sustained backpressure every
            // rejected retry would otherwise pay O(data) copies. The
            // all-or-nothing bulk enqueue below remains authoritative.
            if self.shared.queue_cap > 0 {
                let depth = self.shared.queue.lock().unwrap().len();
                if depth + plan.ranges.len() > self.shared.queue_cap {
                    return Err(TrySubmitError::Full(req));
                }
            }
            let fanout = plan.ranges.len();
            let arch = plan.arch;
            let (jobs, parts) = self.build_shards(&req, &plan, deadline, rid);
            let n = jobs.len();
            // Spawn-then-enqueue-then-arm, exactly as in `submit`.
            let (frx, arm) = match spawn_stitcher(&req, parts, self.shared.clone(), deadline, rid)
            {
                Ok(pair) => pair,
                Err(e) => return Err(TrySubmitError::Rejected(e)),
            };
            if self
                .try_enqueue_bulk(jobs.into_iter().map(Job::Offload).collect())
                .is_err()
            {
                // Dropping `arm` makes the disarmed stitcher exit without
                // recording anything; the untouched original goes back to
                // the caller and no metrics show a trace. (The allocated
                // request id goes unused — ids are not required to be
                // dense, only unique.)
                return Err(TrySubmitError::Full(req));
            }
            let _ = arm.send(());
            self.shared.sharded_requests.fetch_add(1, Ordering::Relaxed);
            self.shared.shard_jobs.fetch_add(n as u64, Ordering::Relaxed);
            self.emit_submit(t0, rid, &req.client, req.module.content_hash(), deadline);
            self.shared.tracer.emit(
                None,
                Event::new(EventKind::ShardPlanned)
                    .req(rid)
                    .a(fanout as u64)
                    .b(arch_code(arch)),
            );
            return Ok(OffloadHandle { rx: frx, clock: Arc::clone(&self.shared.clock) });
        }
        let (reply, rx) = mpsc::channel();
        let job = make_offload_job(req, reply, false, None, deadline, rid, self.shared.clock.now());
        let key = job.key.content;
        let client = if self.shared.tracer.enabled() {
            job.req.client.clone()
        } else {
            String::new()
        };
        match self.try_enqueue_bulk(vec![Job::Offload(job)]) {
            Ok(()) => {
                self.emit_submit(t0, rid, &client, key, deadline);
                Ok(OffloadHandle { rx, clock: Arc::clone(&self.shared.clock) })
            }
            Err(mut jobs) => match jobs.pop() {
                // No clones of the request `Arc` exist until a job is
                // registered in flight, so a rejected job always hands
                // the untouched original back to the caller.
                Some(Job::Offload(j)) => match Arc::try_unwrap(j.req) {
                    Ok(req) => Err(TrySubmitError::Full(req)),
                    Err(_) => unreachable!("queued request has no clones"),
                },
                _ => unreachable!("bulk enqueue returns the jobs it was given"),
            },
        }
    }

    /// Run an arbitrary closure with exclusive use of one matching pool
    /// device (a *device lease*). The closure runs on the device's worker
    /// thread, scheduled like any queued job — this is how whole
    /// benchmarks route through the pool (`omprt bench --pool`).
    pub fn run_on<R, F>(&self, affinity: Affinity, f: F) -> Result<TaskHandle<R>, Error>
    where
        R: Send + 'static,
        F: FnOnce(&DeviceLease<'_>) -> R + Send + 'static,
    {
        self.run_on_as(affinity, "", f)
    }

    /// [`DevicePool::run_on`] with a client tag: the task is scheduled
    /// and accounted under `client`'s fairness lane.
    pub fn run_on_as<R, F>(
        &self,
        affinity: Affinity,
        client: &str,
        f: F,
    ) -> Result<TaskHandle<R>, Error>
    where
        R: Send + 'static,
        F: FnOnce(&DeviceLease<'_>) -> R + Send + 'static,
    {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Sched("pool is shut down".into()));
        }
        validate_client_name(client)?;
        if !self
            .shared
            .slots
            .iter()
            .any(|s| affinity.matches(s.spec.arch, s.spec.kind))
        {
            return Err(Error::Sched(format!(
                "affinity {:?} matches no device in the pool ({:?})",
                affinity,
                self.specs().iter().map(|s| s.to_string()).collect::<Vec<_>>()
            )));
        }
        // Same fail-fast as `submit`: a lease must never sit waiting on
        // a pool corner that is entirely quarantined.
        if !self.shared.any_live_match(affinity) {
            return Err(Error::Fault(format!(
                "every device matching affinity {affinity:?} is quarantined"
            )));
        }
        let (tx, rx) = mpsc::channel();
        let run: TaskFn = Box::new(move |lease: &DeviceLease<'_>| {
            let _ = tx.send(f(lease));
        });
        // Tasks carry no per-request budget; the client's SLO (if any)
        // still stamps a deadline so leased benchmarks participate in
        // panic-window scheduling and miss accounting.
        let deadline = self
            .shared
            .slos
            .get(client)
            .and_then(|t| self.shared.clock.now().checked_add(*t));
        let t0 = self.shared.tracer.now_ns();
        let rid = self.shared.tracer.next_request_id();
        self.enqueue_bulk(vec![Job::Task(TaskJob {
            affinity,
            client: client.to_string(),
            run,
            deadline,
            enqueued: self.shared.clock.now(),
            req_id: rid,
        })])?;
        // Tasks have no kernel image; key word = 0.
        self.emit_submit(t0, rid, client, 0, deadline);
        Ok(TaskHandle { rx, clock: Arc::clone(&self.shared.clock) })
    }

    /// Emit the `Submit` trace event for an *accepted* request, anchored
    /// at `t0` (captured on entry to the submitting call, so the span
    /// includes validation, planning and backpressure). Payload: `a` =
    /// interned client id, `b` = image content key (0 for tasks), `c` =
    /// remaining deadline budget in ns (0 = best-effort).
    fn emit_submit(
        &self,
        t0: u64,
        rid: RequestId,
        client: &str,
        key: u64,
        deadline: Option<Instant>,
    ) {
        let tracer = &self.shared.tracer;
        if !tracer.enabled() {
            return;
        }
        tracer.emit_at(
            None,
            t0,
            Event::new(EventKind::Submit)
                .req(rid)
                .a(tracer.client_id(client))
                .b(key)
                .c(deadline_budget_ns(deadline, self.shared.clock.now())),
        );
    }

    /// Make `job` visible in the queue. Must run with the queue lock
    /// held: the counters below have to change in the same critical
    /// section as the push — `submitted` so it never lags `completed` in
    /// a metrics snapshot, `reserved` so a worker that sees space freed
    /// can never observe a pinned job without its reservation, and the
    /// queue's own `peak` so no transient depth escapes it.
    fn push_locked(&self, q: &mut SchedQueue, job: Job) {
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        self.shared.queue_gen.fetch_add(1, Ordering::Relaxed);
        if let Some(d) = job.target_device() {
            self.shared.reserved[d].fetch_add(1, Ordering::Relaxed);
        }
        let (rid, is_shard, target) = (job.req_id(), job.is_shard(), job.target_device());
        q.push(job);
        // Payload: a = queue depth after the push, b = shard-job flag,
        // c = pinned device + 1 (0 = unpinned).
        self.shared.tracer.emit(
            None,
            Event::new(EventKind::Enqueue)
                .req(rid)
                .a(q.len() as u64)
                .b(is_shard as u64)
                .c(target.map_or(0, |d| d as u64 + 1)),
        );
    }

    /// Blocking all-or-nothing enqueue honoring `queue_cap`
    /// backpressure: waits until every job fits (sharded submissions
    /// enter the queue atomically), then pushes all of them in one
    /// critical section.
    fn enqueue_bulk(&self, mut jobs: Vec<Job>) -> Result<(), Error> {
        let shared = &self.shared;
        if shared.queue_cap > 0 && jobs.len() > shared.queue_cap {
            // Cannot ever fit (the shard planner clamps fan-out to the
            // cap, so this is a programming-error backstop, not a path).
            return Err(Error::Sched(format!(
                "{} jobs cannot fit a queue capped at {}",
                jobs.len(),
                shared.queue_cap
            )));
        }
        let mut q = shared.queue.lock().unwrap();
        let mut waited = false;
        if shared.queue_cap > 0 {
            let t_wait = if shared.tracer.enabled() { shared.tracer.now_ns() } else { 0 };
            while q.len() + jobs.len() > shared.queue_cap {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return Err(Error::Sched("pool is shut down".into()));
                }
                waited = true;
                // The submitter is parked, not working: tell the clock so
                // virtual time can advance past the backpressure window.
                let _idle = IdleGuard::new(&*shared.clock);
                q = shared.space.wait(q).unwrap();
            }
            if waited {
                // Payload: a = how long the submitter blocked on a full
                // queue (ns). Tagged with the first job's request id (for
                // a sharded submission, every job carries the parent id).
                shared.tracer.emit(
                    None,
                    Event::new(EventKind::BackpressureWait)
                        .req(jobs.first().map_or(0, |j| j.req_id()))
                        .a(shared.tracer.now_ns().saturating_sub(t_wait)),
                );
            }
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Sched("pool is shut down".into()));
        }
        if waited {
            // The shard planner's idle sample predates the backpressure
            // wait: the devices it reserved have almost certainly taken
            // other work since, and a stale pin would serialize the
            // stitch behind them while genuinely idle devices sit
            // blinded (pinned jobs are invisible to the DRR scan). Drop
            // the pins — placement falls back to pull order, which is
            // exactly the no-reservation policy. Reservation counters
            // only ever increment at push time, so stripping here is
            // consistent.
            for job in &mut jobs {
                if let Job::Offload(j) = job {
                    j.target_device = None;
                }
            }
        }
        for job in jobs {
            self.push_locked(&mut q, job);
        }
        drop(q);
        // notify_all: the jobs may be eligible only for a subset of the
        // sleeping workers, and notify_one could wake the wrong one.
        shared.cv.notify_all();
        Ok(())
    }

    /// All-or-nothing non-blocking enqueue; hands the jobs back when they
    /// do not fit under `queue_cap`.
    fn try_enqueue_bulk(&self, jobs: Vec<Job>) -> Result<(), Vec<Job>> {
        let shared = &self.shared;
        let mut q = shared.queue.lock().unwrap();
        if shared.queue_cap > 0 && q.len() + jobs.len() > shared.queue_cap {
            return Err(jobs);
        }
        for job in jobs {
            self.push_locked(&mut q, job);
        }
        drop(q);
        shared.cv.notify_all();
        Ok(())
    }

    /// Decide whether (and how) to shard `req`: pick the matching
    /// architecture, split the element range evenly, and fall back to
    /// single-device execution when any shard would drop under
    /// `shard_min_trips` elements.
    ///
    /// In adaptive mode the planner prefers the architecture with the
    /// most *idle* devices (no in-flight work, no pending reservation),
    /// sizes the fan-out to that idle count, and — when enough idle
    /// devices exist — *reserves* them by pinning one shard job to each,
    /// so shards cannot interleave with unrelated pulls and serialize
    /// the stitch. The idle sample is racy by design (a device may claim
    /// other work between the sample and the enqueue); reservations
    /// only shorten the window, correctness never depends on them. In
    /// static mode (`adaptive = false`) this is the PR-2 policy: count
    /// all eligible devices, placement by pull order.
    fn shard_plan(&self, req: &OffloadRequest) -> Option<ShardPlan> {
        let spec = req.shard.as_ref()?;
        // Matching devices grouped by arch, with the subset that is idle.
        // Quarantined devices are invisible here — neither counted nor
        // reserved — and Suspect devices count as eligible but never as
        // idle (a possibly-stalling device must not be handed a shard
        // the stitch will serialize on).
        let mut archs: Vec<(Arch, Vec<usize>, Vec<usize>)> = vec![];
        for s in &self.shared.slots {
            if !req.affinity.matches(s.spec.arch, s.spec.kind) {
                continue;
            }
            let health = s.health.state();
            if health == HealthState::Quarantined {
                continue;
            }
            let idle = health == HealthState::Healthy
                && s.inflight.load(Ordering::Relaxed) == 0
                && self.shared.reserved[s.id].load(Ordering::Relaxed) == 0;
            let entry = match archs.iter_mut().find(|(a, _, _)| *a == s.spec.arch) {
                Some(e) => e,
                None => {
                    archs.push((s.spec.arch, vec![], vec![]));
                    archs.last_mut().expect("just pushed")
                }
            };
            entry.1.push(s.id);
            if idle {
                entry.2.push(s.id);
            }
        }
        // First-seen order breaks ties, so the plan is deterministic.
        let adaptive = self.shared.adaptive;
        let score = |all: &[usize], idle: &[usize]| {
            if adaptive {
                (idle.len(), all.len())
            } else {
                (all.len(), 0)
            }
        };
        let mut best: Option<&(Arch, Vec<usize>, Vec<usize>)> = None;
        for entry in &archs {
            if best.map_or(true, |b| score(&entry.1, &entry.2) > score(&b.1, &b.2)) {
                best = Some(entry);
            }
        }
        let (arch, all, idle) = best?;
        // Clamp to the queue bound so a sharded request can always be
        // enqueued whole — otherwise `try_submit` on a pool with
        // queue_cap < device count would report Full forever, even idle.
        let cap = if self.shared.queue_cap > 0 { self.shared.queue_cap } else { usize::MAX };
        let max_by_elems = spec.elems / self.shared.shard_min_trips;
        let n = if adaptive {
            super::adaptive::decide_shard_fanout(idle.len(), all.len(), max_by_elems, cap)
        } else {
            all.len().min(max_by_elems).min(cap)
        };
        if n < 2 {
            return None;
        }
        // Reserve concrete idle devices when the fan-out fits in them.
        let targets =
            (adaptive && idle.len() >= n).then(|| idle[..n].to_vec());
        let base = spec.elems / n;
        let rem = spec.elems % n;
        let mut ranges = Vec::with_capacity(n);
        let mut lo = 0usize;
        for i in 0..n {
            let len = base + usize::from(i < rem);
            ranges.push((lo, lo + len));
            lo += len;
        }
        Some(ShardPlan { arch: *arch, ranges, targets })
    }

    /// Materialize the shard jobs for `req` under `plan`. The original
    /// request is only borrowed, so a failed enqueue can hand it back.
    fn build_shards(
        &self,
        req: &OffloadRequest,
        plan: &ShardPlan,
        deadline: Option<Instant>,
        req_id: RequestId,
    ) -> (Vec<OffloadJob>, Vec<ShardPart>) {
        let spec = req.shard.as_ref().expect("a plan implies a spec");
        let n = plan.ranges.len();
        let mut jobs = Vec::with_capacity(n);
        let mut parts = Vec::with_capacity(n);
        for (si, &(lo, hi)) in plan.ranges.iter().enumerate() {
            let buffers: Vec<MapBuf> = req
                .buffers
                .iter()
                .enumerate()
                .map(|(bi, b)| {
                    if spec.partitioned.contains(&bi) {
                        MapBuf {
                            bytes: b.bytes[lo * spec.elem_bytes..hi * spec.elem_bytes].to_vec(),
                            map_type: b.map_type,
                        }
                    } else {
                        b.clone()
                    }
                })
                .collect();
            let mut args = req.args.clone();
            args[spec.count_arg] = KernelArg::Imm((hi - lo) as u64);
            let sreq = OffloadRequest {
                module: req.module.clone(),
                kernel: req.kernel.clone(),
                region: req.region.clone(),
                cfg: LaunchConfig::new(
                    req.cfg.grid_dim.div_ceil(n as u32).max(1),
                    req.cfg.block_dim,
                ),
                opt: req.opt,
                buffers,
                args,
                affinity: Affinity { arch: Some(plan.arch), kind: req.affinity.kind },
                shard: None,
                client: req.client.clone(),
                deadline: req.deadline,
            };
            let (tx, rx) = mpsc::channel();
            let target = plan.targets.as_ref().map(|t| t[si]);
            // Shard jobs carry the *parent* request's id: every event
            // they emit joins the parent's span.
            jobs.push(make_offload_job(sreq, tx, true, target, deadline, req_id, self.shared.clock.now()));
            parts.push(ShardPart { rx, lo, hi });
        }
        (jobs, parts)
    }

    /// Snapshot of queue/throughput/cache/allocator/fairness metrics.
    pub fn metrics(&self) -> PoolMetrics {
        let (queue_depth, peak_queue_depth) = {
            let q = self.shared.queue.lock().unwrap();
            (q.len(), q.peak())
        };
        let uptime = self.shared.clock.now().saturating_duration_since(self.shared.started);
        let uptime_ns = uptime.as_nanos().max(1);
        let now_ns = self.shared.now_ns();
        let devices: Vec<DeviceMetrics> = self
            .shared
            .slots
            .iter()
            .map(|s| {
                // In-flight age of the executing batch vs. its service
                // prediction — what the watchdog and hedging triggers
                // judge. None = idle (or leased, which is exempt).
                let busy = s.health.watchable_busy().map(|(since_ns, jobs, key)| {
                    (
                        Duration::from_nanos(now_ns.saturating_sub(since_ns)),
                        self.shared.service.predict_batch(key, jobs),
                    )
                });
                DeviceMetrics {
                    id: s.id,
                    kind: s.spec.kind,
                    arch: s.spec.arch,
                    inflight: s.inflight.load(Ordering::Relaxed),
                    inflight_age: busy.map(|(age, _)| age),
                    inflight_predicted: busy.map(|(_, p)| p),
                    reserved: self.shared.reserved[s.id].load(Ordering::Relaxed),
                    completed: s.completed.load(Ordering::Relaxed),
                    batches: s.batches.load(Ordering::Relaxed),
                    batched_jobs: s.batched_jobs.load(Ordering::Relaxed),
                    max_batch: s.max_batch.load(Ordering::Relaxed),
                    occupancy: (s.busy_ns.load(Ordering::Relaxed) as f64
                        / uptime_ns as f64)
                        .min(1.0),
                    health: s.health.state(),
                    quarantines: s.health.quarantine_count(),
                    fault: s.fault.as_ref().map(|f| f.spec().to_string()),
                    fault_injected: s.fault.as_ref().map_or(0, |f| f.injected()),
                    cache: s.cache.stats(),
                    cached_images: s.cache.len(),
                    cache_bytes: s.cache.bytes(),
                    mem: s.device.gmem.stats(),
                }
            })
            .collect();
        let clients: Vec<ClientMetrics> = {
            let map = self.shared.clients.lock().unwrap();
            map.iter()
                .map(|(client, acc)| ClientMetrics {
                    client: client.clone(),
                    weight: self
                        .shared
                        .client_weights
                        .iter()
                        .find(|(c, _)| c == client)
                        .map_or(1.0, |(_, w)| *w),
                    slo: self.shared.slos.get(client).copied(),
                    completed: acc.completed,
                    failed: acc.failed,
                    queue_wait: acc.queue_wait.clone(),
                    latency: acc.latency.clone(),
                    latency_us: acc.latency_hist.clone(),
                    queue_wait_us: acc.queue_wait_hist.clone(),
                    slack_us: acc.slack_hist.clone(),
                    deadlines: acc.deadlines,
                    deadline_miss: acc.deadline_miss,
                    slack: acc.slack.clone(),
                })
                .collect()
        };
        PoolMetrics {
            queue_depth,
            peak_queue_depth,
            queue_cap: self.shared.queue_cap,
            submitted: self.shared.submitted.load(Ordering::Relaxed),
            completed: self.shared.completed.load(Ordering::Relaxed),
            failed: self.shared.failed.load(Ordering::Relaxed),
            sharded_requests: self.shared.sharded_requests.load(Ordering::Relaxed),
            shard_jobs: self.shared.shard_jobs.load(Ordering::Relaxed),
            adaptive: self.shared.adaptive,
            adaptive_stats: self.shared.controller.stats(),
            preemptions: self.shared.preemptions.load(Ordering::Relaxed),
            watchdog: self.shared.watchdog,
            replans: self.shared.replans.load(Ordering::Relaxed),
            replanned_jobs: self.shared.replanned_jobs.load(Ordering::Relaxed),
            retries: self.shared.retries.load(Ordering::Relaxed),
            retries_exhausted: self.shared.retries_exhausted.load(Ordering::Relaxed),
            probes: self.shared.probes.load(Ordering::Relaxed),
            readmissions: self.shared.readmissions.load(Ordering::Relaxed),
            hedge: self.shared.hedge,
            hedges: self.shared.hedges.load(Ordering::Relaxed),
            hedge_wins: self.shared.hedge_wins.load(Ordering::Relaxed),
            hedge_wasted: self.shared.hedge_wasted.load(Ordering::Relaxed),
            uptime,
            devices,
            clients,
        }
    }

    /// Per-device profiler reports, in pool-id order.
    pub fn profiler_reports(&self) -> Vec<(DeviceSpec, Vec<RegionReport>)> {
        self.shared
            .slots
            .iter()
            .map(|s| (s.spec, s.profiler.report()))
            .collect()
    }

    /// Block until every submitted request has completed or failed.
    /// Intended for tests/benches that stop submitting first; new
    /// submissions during the wait extend it.
    pub fn quiesce(&self) {
        loop {
            let m = self.metrics();
            if m.queue_depth == 0 && m.completed + m.failed >= m.submitted {
                return;
            }
            self.shared.clock.sleep(Duration::from_millis(1));
        }
    }

    /// Whether event tracing is recording (`[pool] trace = true` /
    /// `--trace-out`).
    pub fn trace_enabled(&self) -> bool {
        self.shared.tracer.enabled()
    }

    /// Trace-ring accounting (recorded/dropped event counts).
    pub fn trace_stats(&self) -> TraceStats {
        self.shared.tracer.stats()
    }

    /// Drain the trace rings into a time-sorted snapshot. Non-destructive;
    /// quiesce first for a complete capture.
    pub fn trace_snapshot(&self) -> TraceSnapshot {
        self.shared.tracer.snapshot()
    }

    /// Export labels for this pool's traces: device tracks named by
    /// spec, clients from the tracer's interner, arch names in
    /// [`ARCH_LABELS`] order.
    fn export_meta(&self, snap: &TraceSnapshot) -> ExportMeta {
        ExportMeta {
            process: "omprt pool".to_string(),
            device_labels: self
                .shared
                .slots
                .iter()
                .map(|s| format!("dev{} {}", s.id, s.spec))
                .collect(),
            clients: snap.clients.clone(),
            arch_labels: ARCH_LABELS.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Render the current trace as Chrome trace-event JSON
    /// (Perfetto-loadable; the `--trace-out` payload).
    pub fn trace_chrome_json(&self) -> String {
        let snap = self.trace_snapshot();
        let meta = self.export_meta(&snap);
        chrome_trace_json(&snap.records, &meta)
    }

    /// Render the current trace as the line-oriented replay capture
    /// (the `--capture-out` payload). When the trace ring overwrote
    /// records, the capture carries a `# dropped=N` trailer so replay
    /// consumers can tell a complete capture from a truncated one.
    pub fn trace_capture(&self) -> String {
        let snap = self.trace_snapshot();
        let meta = self.export_meta(&snap);
        capture_text(&snap.records, &meta, self.trace_stats().dropped)
    }

    /// Snapshot the pool's named metrics: scheduler counters, per-device
    /// gauges and the per-client latency/queue-wait/slack histograms —
    /// the `--metrics-json` payload.
    pub fn metrics_registry(&self) -> MetricsRegistry {
        let m = self.metrics();
        let mut reg = MetricsRegistry::new();
        reg.set_counter("pool.submitted", m.submitted);
        reg.set_counter("pool.completed", m.completed);
        reg.set_counter("pool.failed", m.failed);
        reg.set_counter("pool.sharded_requests", m.sharded_requests);
        reg.set_counter("pool.shard_jobs", m.shard_jobs);
        reg.set_counter("pool.preemptions", m.preemptions);
        reg.set_counter("pool.retries", m.retries);
        reg.set_counter("pool.retries_exhausted", m.retries_exhausted);
        reg.set_counter("pool.replans", m.replans);
        reg.set_counter("pool.replanned_jobs", m.replanned_jobs);
        reg.set_counter("pool.probes", m.probes);
        reg.set_counter("pool.readmissions", m.readmissions);
        reg.set_counter("pool.hedges", m.hedges);
        reg.set_counter("pool.hedge_wins", m.hedge_wins);
        reg.set_counter("pool.hedge_wasted", m.hedge_wasted);
        reg.set_counter("pool.queue_depth", m.queue_depth as u64);
        reg.set_counter("pool.peak_queue_depth", m.peak_queue_depth as u64);
        reg.set_gauge("pool.uptime_s", m.uptime.as_secs_f64());
        let t = self.trace_stats();
        reg.set_counter("trace.recorded", t.recorded);
        reg.set_counter("trace.dropped", t.dropped);
        for d in &m.devices {
            let p = format!("device.{}", d.id);
            reg.set_counter(&format!("{p}.completed"), d.completed);
            reg.set_counter(&format!("{p}.batches"), d.batches);
            reg.set_counter(&format!("{p}.quarantines"), d.quarantines);
            reg.set_gauge(&format!("{p}.occupancy"), d.occupancy);
        }
        for c in &m.clients {
            let name = if c.client.is_empty() { "default" } else { &c.client };
            let p = format!("client.{name}");
            reg.set_counter(&format!("{p}.completed"), c.completed);
            reg.set_counter(&format!("{p}.failed"), c.failed);
            reg.set_counter(&format!("{p}.deadlines"), c.deadlines);
            reg.set_counter(&format!("{p}.deadline_miss"), c.deadline_miss);
            reg.set_histogram(&format!("{p}.latency_us"), c.latency_us.clone());
            reg.set_histogram(&format!("{p}.queue_wait_us"), c.queue_wait_us.clone());
            if c.slack_us.count() > 0 {
                reg.set_histogram(&format!("{p}.slack_us"), c.slack_us.clone());
            }
        }
        reg
    }
}

struct ShardPlan {
    arch: Arch,
    ranges: Vec<(usize, usize)>,
    /// Device ids reserved for the shards (one per range) when the
    /// adaptive planner found enough idle devices; `None` = placement by
    /// pull order (static mode, or a busy pool).
    targets: Option<Vec<usize>>,
}

struct ShardPart {
    rx: mpsc::Receiver<Result<OffloadResponse, Error>>,
    lo: usize,
    hi: usize,
}

#[allow(clippy::too_many_arguments)]
fn make_offload_job(
    req: OffloadRequest,
    reply: mpsc::Sender<Result<OffloadResponse, Error>>,
    is_shard: bool,
    target_device: Option<usize>,
    deadline: Option<Instant>,
    req_id: RequestId,
    now: Instant,
) -> OffloadJob {
    let key = BatchKey { content: req.module.content_hash(), opt: req.opt };
    OffloadJob {
        req: Arc::new(req),
        key,
        is_shard,
        target_device,
        deadline,
        tried: vec![],
        first_fault: None,
        reply,
        enqueued: now,
        first_enqueued: now,
        req_id,
        settled: Arc::new(AtomicBool::new(false)),
        is_hedge: false,
    }
}

/// Numeric architecture code used in `ShardPlanned` trace payloads;
/// [`ARCH_LABELS`] maps it back to a name for exports.
fn arch_code(arch: Arch) -> u64 {
    match arch {
        Arch::Nvptx64 => 0,
        Arch::Amdgcn => 1,
    }
}

/// Labels for [`arch_code`] values, in code order (feeds
/// [`crate::trace::ExportMeta::arch_labels`]).
pub const ARCH_LABELS: [&str; 2] = ["nvptx64", "amdgcn"];

/// Reject client names that cannot be carried through reports and
/// trace captures. The capture exporter percent-escapes whitespace,
/// `=`, `%` and control characters (see [`crate::trace::escape_client`]),
/// so almost anything survives a capture round-trip — but a control
/// character (NUL, BEL, a newline or tab…) in a client tag is never
/// intentional and would corrupt every plain-text report line it is
/// printed into, so it is refused at the door instead of being carried
/// through fairness lanes, metrics and captures.
fn validate_client_name(client: &str) -> Result<(), Error> {
    if client.chars().any(|c| c.is_control()) {
        return Err(Error::Sched(format!(
            "client name {client:?} contains control characters and cannot be \
             represented in reports or trace captures"
        )));
    }
    Ok(())
}

/// Remaining deadline budget in ns at submit time — the `Submit` event's
/// `c` word. 0 = best-effort; an already-expired deadline clamps to 1 so
/// "has a deadline" stays distinguishable.
fn deadline_budget_ns(deadline: Option<Instant>, now: Instant) -> u64 {
    match deadline {
        None => 0,
        Some(d) => d
            .saturating_duration_since(now)
            .as_nanos()
            .clamp(1, u64::MAX as u128) as u64,
    }
}

/// Spawn the result-stitcher for a sharded request; resolves the returned
/// receiver with the assembled response once every shard reported. The
/// stitcher also records the request (once, not per shard) in the
/// per-client accounting on `shared`.
///
/// The stitcher starts **disarmed**: it does nothing until the caller
/// sends on the returned arm channel (after the shard jobs were actually
/// enqueued) and exits silently — no metrics, no response — if the arm
/// sender is dropped instead. This keeps both failure orders clean: a
/// spawn failure happens before anything is enqueued, and an enqueue
/// failure (`try_submit` Full, shutdown) leaves no phantom per-client
/// record from a stitcher watching jobs that never ran.
fn spawn_stitcher(
    req: &OffloadRequest,
    parts: Vec<ShardPart>,
    shared: Arc<Shared>,
    deadline: Option<Instant>,
    req_id: RequestId,
) -> Result<(mpsc::Receiver<Result<OffloadResponse, Error>>, mpsc::Sender<()>), Error> {
    let spec = req.shard.as_ref().expect("sharded request has a spec");
    let buf_meta: Vec<(MapType, usize)> =
        req.buffers.iter().map(|b| (b.map_type, b.bytes.len())).collect();
    let partitioned = spec.partitioned.clone();
    let elem_bytes = spec.elem_bytes;
    let client = req.client.clone();
    let enqueued = shared.clock.now();
    let (ftx, frx) = mpsc::channel();
    let (arm_tx, arm_rx) = mpsc::channel::<()>();
    std::thread::Builder::new()
        .name("pool-stitch".into())
        .spawn(move || {
            if arm_rx.recv().is_err() {
                return; // never armed: the shard jobs were not enqueued
            }
            stitch(parts, buf_meta, partitioned, elem_bytes, ftx, StitchAccount {
                shared,
                client,
                enqueued,
                deadline,
                req_id,
            })
        })
        .map_err(|e| Error::Sched(format!("cannot spawn shard stitcher: {e}")))?;
    Ok((frx, arm_tx))
}

/// What the stitcher needs to account the whole request to its client.
struct StitchAccount {
    shared: Arc<Shared>,
    client: String,
    enqueued: Instant,
    /// The parent request's deadline: the stitcher judges miss/slack for
    /// the request as a whole — shard jobs are skipped at reply time, so
    /// a missed sharded request increments `deadline_miss` exactly once.
    deadline: Option<Instant>,
    /// The parent request's trace id: the stitcher emits the `Stitch`
    /// event and (via `record_client`) the request's single `Done`.
    req_id: RequestId,
}

/// Wait for all shard responses and assemble the full-request response:
/// partitioned outputs are copied into their element ranges, broadcast
/// outputs come from the first shard, counters are summed (`wall` and
/// `queue_wait` take the max).
fn stitch(
    parts: Vec<ShardPart>,
    buf_meta: Vec<(MapType, usize)>,
    partitioned: Vec<usize>,
    elem_bytes: usize,
    ftx: mpsc::Sender<Result<OffloadResponse, Error>>,
    account: StitchAccount,
) {
    let mut got: Vec<(OffloadResponse, usize, usize)> = Vec::with_capacity(parts.len());
    let mut first_err: Option<Error> = None;
    for part in parts {
        match part.rx.recv() {
            Ok(Ok(resp)) => got.push((resp, part.lo, part.hi)),
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err =
                        Some(Error::Sched("shard dropped before the request completed".into()));
                }
            }
        }
    }
    // Per-client accounting sees the *request* exactly once — its shard
    // jobs are deliberately skipped at reply time, so fairness metrics
    // cannot double-count a split request.
    // Completion = the moment the last shard reported, captured before
    // the clients-table lock so contention cannot skew miss judgments.
    let done = account.shared.clock.now();
    let max_wait = got.iter().map(|(r, _, _)| r.queue_wait).max().unwrap_or(Duration::ZERO);
    // Payload: a = shards that reported a result, b = whether the whole
    // request stitched cleanly.
    account.shared.tracer.emit(
        None,
        Event::new(EventKind::Stitch)
            .req(account.req_id)
            .a(got.len() as u64)
            .b(first_err.is_none() as u64),
    );
    record_client(
        &account.shared,
        account.req_id,
        &account.client,
        max_wait,
        done.saturating_duration_since(account.enqueued),
        first_err.is_none(),
        account.deadline,
        done,
    );
    if let Some(e) = first_err {
        let _ = ftx.send(Err(e));
        return;
    }
    let mut buffers: Vec<Option<Vec<u8>>> = Vec::with_capacity(buf_meta.len());
    for (bi, (map_type, full_len)) in buf_meta.iter().enumerate() {
        if !matches!(map_type, MapType::From | MapType::Tofrom) {
            buffers.push(None);
            continue;
        }
        if partitioned.contains(&bi) {
            let mut out = vec![0u8; *full_len];
            for (resp, lo, hi) in &got {
                if let Some(src) = &resp.buffers[bi] {
                    out[lo * elem_bytes..hi * elem_bytes].copy_from_slice(src);
                }
            }
            buffers.push(Some(out));
        } else {
            buffers.push(got[0].0.buffers[bi].clone());
        }
    }
    let mut stats = LaunchStats::default();
    let mut queue_wait = Duration::ZERO;
    let mut cache_hit = true;
    for (resp, _, _) in &got {
        stats.lane_ops += resp.stats.lane_ops;
        stats.warp_steps += resp.stats.warp_steps;
        stats.blocks += resp.stats.blocks;
        if resp.stats.wall > stats.wall {
            stats.wall = resp.stats.wall;
        }
        if resp.queue_wait > queue_wait {
            queue_wait = resp.queue_wait;
        }
        cache_hit &= resp.cache_hit;
    }
    let shards = got.len();
    let first = &got[0].0;
    let _ = ftx.send(Ok(OffloadResponse {
        device_id: first.device_id,
        arch: first.arch,
        kind: first.kind,
        stats,
        cache_hit,
        queue_wait,
        shards,
        buffers,
    }));
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        // Flip the shutdown predicate while holding the queue mutex: a
        // worker that already checked `shutdown` and is between that check
        // and `cv.wait` would otherwise miss this notify forever. Blocked
        // submitters (backpressure) are woken the same way.
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
            self.shared.cv.notify_all();
            self.shared.space.notify_all();
        }
        // With shutdown visible, drain the clock: a virtual clock parks
        // sleepers on its timeline, and a worker mid-stall (or the
        // monitor mid-tick) must wake *now*, not at its virtual
        // deadline. Sleeps re-checked after this return immediately
        // because chunked sleeps test `shutdown` per chunk. No-op on the
        // wall clock.
        self.shared.clock.wake_sleepers();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(m) = self.monitor.take() {
            let _ = m.join();
        }
        // Fail any requests still queued so waiting clients unblock with
        // an error instead of a channel disconnect. (Dropped task jobs
        // disconnect their handles, which also unblocks their waiters.)
        // Each drained non-shard request gets a terminal `Done {ok: 0}`
        // so shutdown leaves no open trace spans; drained shard jobs
        // resolve through their stitcher, which emits the parent's Done.
        let mut q = self.shared.queue.lock().unwrap();
        for job in q.drain() {
            match job {
                Job::Offload(j) => {
                    // A drained hedge duplicate resolves as wasted with
                    // no reply and no Done — the original (drained in
                    // this same loop, or already settled) owns the
                    // request's termination.
                    if j.is_hedge {
                        self.shared.hedges_inflight.fetch_sub(1, Ordering::Relaxed);
                        self.shared.hedge_wasted.fetch_add(1, Ordering::Relaxed);
                        self.shared.tracer.emit(
                            None,
                            Event::new(EventKind::HedgeWasted).req(j.req_id).a(2),
                        );
                        continue;
                    }
                    // An original whose duplicate already won needs no
                    // shutdown error: its reply and Done already fired.
                    if j.settled.swap(true, Ordering::SeqCst) {
                        continue;
                    }
                    if !j.is_shard {
                        self.shared
                            .tracer
                            .emit(None, Event::new(EventKind::Done).req(j.req_id));
                    }
                    let _ = j
                        .reply
                        .send(Err(Error::Sched("pool shut down before the request ran".into())));
                }
                Job::Task(t) => {
                    self.shared
                        .tracer
                        .emit(None, Event::new(EventKind::Done).req(t.req_id));
                }
            }
        }
    }
}

/// What a worker popped in one queue visit.
enum Work {
    Batch(Vec<OffloadJob>),
    Task(TaskJob),
}

/// Worker body, one queue visit per iteration:
///
/// 1. claim any shard job *pinned* to this device (reserved placement
///    outranks everything — the stitch serializes on its slowest shard);
/// 2. otherwise pick the effective batch limit — the static `batch_max`,
///    or in adaptive mode [`decide_batch_max`] over the live signals —
///    and take one weighted-DRR pop (leader + same-image followers);
/// 3. run it, reply to every job, account per-client completion.
fn worker_loop(shared: &Shared, id: usize) {
    // Workers participate in virtual time for the thread's whole life:
    // while any worker is runnable the clock is frozen, and the idle
    // guards around the two condvar waits below are what let it move.
    let _clock = Participant::new(&*shared.clock);
    let slot = &shared.slots[id];
    loop {
        let (work, decided, preempted, pinned) = {
            let mut q = shared.queue.lock().unwrap();
            'wait: loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // A quarantined device claims nothing — not even work
                // pinned to it (re-planning re-routes that). A pinned
                // job can still *race* in behind the quarantine sweep
                // (the shard planner's idle sample is lock-free), so
                // drain any such pins here instead of letting them — and
                // their reservations — strand forever. Both wake paths
                // notify the cv (pushes and the monitor's readmit), so
                // the timeout is only a backstop — sized to the watchdog
                // floor rather than a busy poll.
                if slot.health.is_quarantined() {
                    if shared.reserved[id].load(Ordering::Relaxed) > 0 {
                        if replan_pinned_locked(shared, id, &mut q) > 0 {
                            shared.cv.notify_all();
                        }
                    }
                    let backstop = shared
                        .watchdog_min
                        .clamp(Duration::from_millis(2), Duration::from_millis(250));
                    let _idle = IdleGuard::new(&*shared.clock);
                    let (qq, _) = shared.cv.wait_timeout(q, backstop).unwrap();
                    q = qq;
                    continue 'wait;
                }
                // `reserved` is incremented in the same critical section
                // as the pinned push and we hold the queue lock here, so
                // this guard is exact: the O(queue) pinned scan runs only
                // when a pinned job for this device actually exists.
                if shared.reserved[id].load(Ordering::Relaxed) > 0 {
                    if let Some(job) = q.pop_pinned(id) {
                        shared.reserved[id].fetch_sub(1, Ordering::Relaxed);
                        break 'wait (Work::Batch(vec![job]), 1, false, true);
                    }
                }
                let now = shared.clock.now();
                let limit = if shared.adaptive {
                    // Quarantined devices are not idle capacity: counting
                    // them would both oversize shard fan-outs and shrink
                    // batch limits for the healthy rest.
                    let idle = shared
                        .slots
                        .iter()
                        .filter(|s| {
                            s.inflight.load(Ordering::Relaxed) == 0
                                && s.health.state() != HealthState::Quarantined
                        })
                        .count();
                    let signals = SchedSignals {
                        queue_depth: q.len(),
                        idle_devices: idle,
                        device_count: shared.slots.len(),
                        batch_efficiency: shared.controller.efficiency(),
                        urgent: q.any_panic(slot.spec, id, now, &shared.service),
                    };
                    decide_batch_max(&signals, shared.batch_max)
                } else {
                    shared.batch_max
                };
                if let Some((work, preempted)) =
                    q.pop(slot.spec, id, limit, now, &shared.service)
                {
                    if preempted {
                        shared.preemptions.fetch_add(1, Ordering::Relaxed);
                    }
                    break 'wait (work, limit, preempted, false);
                }
                // Parked with an empty (eligible) queue: mark the worker
                // idle so a virtual clock can advance to the next event.
                let _idle = IdleGuard::new(&*shared.clock);
                q = shared.cv.wait(q).unwrap();
            }
        };
        // Jobs left the queue: wake submitters blocked on a full queue.
        // notify_all, not notify_one — a batched (or bulk-shard) pop can
        // free several slots at once, and waking a single submitter
        // would leave the rest blocked until the *next* pop even though
        // space exists (the lost-wakeup shape this queue is tested for).
        shared.space.notify_all();
        // Pop + batch-formation events go to this worker's private ring,
        // emitted after the queue lock is released. Payload: a = jobs
        // claimed, c = pinned-claim flag; a pop through the EDF panic
        // path is `PopPanic`, the DRR rotation is `PopNormal`.
        if shared.tracer.enabled() {
            let (rid, count) = match &work {
                Work::Batch(batch) => (batch[0].req_id, batch.len()),
                Work::Task(t) => (t.req_id, 1),
            };
            let kind = if preempted { EventKind::PopPanic } else { EventKind::PopNormal };
            shared.tracer.emit(
                Some(id),
                Event::new(kind).device(id).req(rid).a(count as u64).c(pinned as u64),
            );
            if let Work::Batch(batch) = &work {
                if batch.len() > 1 {
                    // Payload: a = batch size, b = shared image key.
                    shared.tracer.emit(
                        Some(id),
                        Event::new(EventKind::BatchFormed)
                            .device(id)
                            .req(batch[0].req_id)
                            .a(batch.len() as u64)
                            .b(batch[0].key.content),
                    );
                }
            }
        }
        match work {
            Work::Task(task) => {
                let queue_wait =
                    shared.clock.now().saturating_duration_since(task.enqueued);
                slot.inflight.fetch_add(1, Ordering::Relaxed);
                // Leased closures run for as long as they like (whole
                // benchmarks); flag the lease so the stall watchdog
                // skips this device instead of quarantining a legitimate
                // multi-second run.
                slot.health.set_leased(true);
                slot.health.begin_work(shared.now_ns(), 1, None);
                let lease = DeviceLease {
                    id: slot.id,
                    spec: slot.spec,
                    device: &slot.device,
                    profiler: &slot.profiler,
                };
                // Leased closures are arbitrary user code; a panic must
                // not kill this device's worker thread (every job pinned
                // to the device would starve forever). The panicked
                // task's handle resolves to an error via its dropped
                // sender.
                let (outcome, elapsed) = stats::timed_with(&*shared.clock, || {
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        (task.run)(&lease)
                    }))
                });
                // end_lease, not end_work: a completing lease says
                // nothing about device faults and must not reset the
                // quarantine streak a failing offload mix is building.
                slot.health.end_lease();
                slot.health.set_leased(false);
                slot.inflight.fetch_sub(1, Ordering::Relaxed);
                slot.busy_ns
                    .fetch_add(elapsed.as_nanos().min(u64::MAX as u128) as u64, Ordering::Relaxed);
                // Deliberately NOT recorded into the service EWMA: a
                // multi-second leased benchmark would poison the global
                // fallback and make every unseen image key look
                // permanently panicked.
                let done = shared.clock.now();
                let ok = outcome.is_ok();
                match outcome {
                    Ok(()) => {
                        slot.completed.fetch_add(1, Ordering::Relaxed);
                        shared.completed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        shared.failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
                record_client(
                    shared,
                    task.req_id,
                    &task.client,
                    queue_wait,
                    done.saturating_duration_since(task.enqueued),
                    ok,
                    task.deadline,
                    done,
                );
            }
            Work::Batch(batch) => {
                if shared.adaptive && !batch[0].is_shard {
                    shared.controller.record(decided, batch.len());
                }
                run_offload_batch(shared, slot, batch);
            }
        }
    }
}

/// Health-monitor body (the "pool-health" thread), one tick per
/// iteration:
///
/// * judge every watchable in-flight device against the stall watchdog
///   ([`judge`]): in-flight age vs. the service EWMA's prediction for
///   the executing batch, floored by `[pool] watchdog_min_ms` —
///   Suspect devices receive no *new* shard reservations (existing pins
///   stay until quarantine), Quarantined devices are taken out of
///   service and their queued pinned jobs re-planned;
/// * probe quarantined devices (at most once per `watchdog_min` each)
///   and re-admit the ones that pass.
///
/// Leased tasks are exempt from judgment ([`DeviceHealth::watchable_busy`])
/// — a benchmark legitimately holds a device for seconds.
///
/// The same tick drives the hedging scan ([`maybe_hedge`]) when
/// `[pool] hedge` is on: hedging triggers *earlier* than suspicion
/// (quarter-floor vs. full floor), which is the point — rescue the
/// in-flight request before the device is even formally suspect.
fn monitor_loop(shared: &Shared) {
    // The monitor participates in virtual time too — its tick sleeps use
    // the low-priority `sleep_tick` class, so an otherwise idle pool does
    // not see virtual time gallop forward at watchdog cadence, yet the
    // tick still interleaves correctly with real (normal-class) events.
    let _clock = Participant::new(&*shared.clock);
    // Tick scales with the watchdog floor: detection latency only needs
    // to be small *relative to the thresholds* (suspect at ≥ floor,
    // quarantine at ≥ 2x floor), so a conservative floor — the
    // fault-free default — does not buy a kilohertz wakeup loop.
    let tick = (shared.watchdog_min / 8)
        .clamp(Duration::from_millis(1), Duration::from_millis(50));
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if shared.hedge {
            maybe_hedge(shared);
        }
        if !shared.watchdog {
            // Hedge-only mode: no judgments, no probes.
            shared.clock.sleep_tick(tick);
            continue;
        }
        let now_ns = shared.now_ns();
        for slot in &shared.slots {
            match slot.health.state() {
                HealthState::Quarantined => {
                    let last = slot.health.last_probe_ns();
                    if now_ns.saturating_sub(last)
                        >= shared.watchdog_min.as_nanos().min(u64::MAX as u128) as u64
                    {
                        slot.health.set_last_probe_ns(now_ns);
                        shared.probes.fetch_add(1, Ordering::Relaxed);
                        let probe_ok = probe_device(slot).is_ok();
                        // Payload: a = probe outcome.
                        shared.tracer.emit(
                            None,
                            Event::new(EventKind::Probe)
                                .device(slot.id)
                                .a(probe_ok as u64),
                        );
                        if probe_ok {
                            slot.health.readmit();
                            shared.readmissions.fetch_add(1, Ordering::Relaxed);
                            shared
                                .tracer
                                .emit(None, Event::new(EventKind::Readmit).device(slot.id));
                            // The readmitted worker polls its state, but
                            // waiting peers may hold claimable work too.
                            shared.cv.notify_all();
                        } else {
                            // Still dark: fail anything that slipped into
                            // the queue for this (or any) dead corner of
                            // the pool since the quarantine sweep — but
                            // only when jobs actually entered the queue
                            // since (submissions or retry requeues), so a
                            // long-dead device doesn't re-scan an
                            // unchanged queue on every probe.
                            let seen = shared.queue_gen.load(Ordering::Relaxed);
                            if shared.last_sweep_gen.swap(seen, Ordering::Relaxed) != seen {
                                sweep_stranded(shared);
                            }
                        }
                    }
                }
                state => {
                    if let Some((since_ns, jobs, key)) = slot.health.watchable_busy() {
                        let age = Duration::from_nanos(now_ns.saturating_sub(since_ns));
                        // Per-key prediction when the batch has an image
                        // key (falls back to the global EWMA inside
                        // `predict`): a legitimately heavy image with
                        // established history must not read as a stall.
                        let predicted = shared.service.predict_batch(key, jobs);
                        match judge(age, predicted, shared.watchdog_min) {
                            WatchdogVerdict::Quarantine => {
                                quarantine_and_replan(shared, slot.id)
                            }
                            WatchdogVerdict::Suspect => slot.health.mark_suspect(),
                            WatchdogVerdict::Ok => {}
                        }
                    } else if state == HealthState::Suspect {
                        // Whatever looked stuck finished while we slept
                        // (`end_work` clears Suspect too; this covers a
                        // worker that raced the transition). CAS so a
                        // concurrent fault-streak quarantine survives.
                        slot.health.clear_suspect();
                    }
                }
            }
        }
        shared.clock.sleep_tick(tick);
    }
}

/// One hedging pass over the in-flight registry: find jobs whose age
/// says the device is wedged — or whose deadline the prediction says is
/// about to be blown — and enqueue a speculative duplicate for each,
/// pinned to an idle healthy device the original's `tried` set (plus
/// the device it is wedged on) excludes.
///
/// Trigger math, per entry:
/// * **stall**: `age ≥ hedge_after(predicted, factor, floor)` where
///   `predicted` is the EWMA prediction scaled to the executing batch
///   and `floor = max(watchdog_min / 4, 1ms)` — a quarter of the
///   watchdog's suspicion floor, so rescue starts before quarantine
///   machinery does, but cold keys (prediction 0) still cannot trigger
///   instantly;
/// * **deadline risk**: the job carries a deadline, has already run
///   past its prediction, and `now + predicted` lands past the
///   deadline — waiting the prediction out again cannot make it.
///
/// One duplicate per in-flight stint (`hedged` latches the entry), at
/// most `hedge_max` unresolved duplicates pool-wide, one per target
/// device per pass. Duplicates re-enter the queue exactly like retries:
/// direct push under the queue lock — no `submitted` bump, no
/// backpressure (the request was admitted once) — with a generation
/// bump and a pin reservation so the planner sees the target as taken.
fn maybe_hedge(shared: &Shared) {
    let now = shared.clock.now();
    let floor = (shared.watchdog_min / 4).max(Duration::from_millis(1));
    let mut dups: Vec<OffloadJob> = vec![];
    // Devices already claimed by a duplicate minted this pass.
    let mut taken: Vec<usize> = vec![];
    {
        let mut reg = shared.inflight_reg.lock().unwrap();
        for entry in reg.values_mut() {
            if entry.hedged || entry.settled.load(Ordering::SeqCst) {
                continue;
            }
            if shared.hedges_inflight.load(Ordering::Relaxed) + dups.len() >= shared.hedge_max {
                break;
            }
            let age = now.saturating_duration_since(entry.started);
            let predicted = shared
                .service
                .predict_batch(Some(entry.key.content), entry.batch_jobs);
            let stalled = age >= hedge_after(predicted, shared.hedge_after_factor, floor);
            let deadline_risk = match entry.deadline {
                Some(dl) => age >= floor && age > predicted && now + predicted > dl,
                None => false,
            };
            if !stalled && !deadline_risk {
                continue;
            }
            let Some(target) = shared.slots.iter().find(|s| {
                s.id != entry.device
                    && !taken.contains(&s.id)
                    && s.health.state() == HealthState::Healthy
                    && s.inflight.load(Ordering::Relaxed) == 0
                    && shared.reserved[s.id].load(Ordering::Relaxed) == 0
                    && !entry.tried.contains(&s.id)
                    && entry.req.affinity.matches(s.spec.arch, s.spec.kind)
            }) else {
                // No idle healthy device to speculate on; the entry
                // stays unhedged and the next pass reconsiders it.
                continue;
            };
            taken.push(target.id);
            entry.hedged = true;
            let mut tried = entry.tried.clone();
            if !tried.contains(&entry.device) {
                tried.push(entry.device);
            }
            dups.push(OffloadJob {
                req: entry.req.clone(),
                key: entry.key,
                is_shard: entry.is_shard,
                target_device: Some(target.id),
                deadline: entry.deadline,
                tried,
                first_fault: None,
                reply: entry.reply.clone(),
                enqueued: now,
                first_enqueued: entry.first_enqueued,
                req_id: entry.req_id,
                settled: entry.settled.clone(),
                is_hedge: true,
            });
            shared.hedges.fetch_add(1, Ordering::Relaxed);
            shared.hedges_inflight.fetch_add(1, Ordering::Relaxed);
            // Payload: a = the device the original is wedged on, b =
            // in-flight age (ns), c = predicted batch service (ns);
            // `device` is the duplicate's target.
            shared.tracer.emit(
                None,
                Event::new(EventKind::HedgeLaunched)
                    .device(target.id)
                    .req(entry.req_id)
                    .a(entry.device as u64)
                    .b(age.as_nanos().min(u64::MAX as u128) as u64)
                    .c(predicted.as_nanos().min(u64::MAX as u128) as u64),
            );
        }
    }
    if dups.is_empty() {
        return;
    }
    // Registry lock released before the queue lock — the documented
    // ordering that keeps the two from ever deadlocking.
    let mut q = shared.queue.lock().unwrap();
    for job in dups {
        shared.queue_gen.fetch_add(1, Ordering::Relaxed);
        let target = job.target_device.expect("hedge duplicates are pinned");
        shared.reserved[target].fetch_add(1, Ordering::Relaxed);
        let (rid, is_shard) = (job.req_id, job.is_shard);
        q.push(Job::Offload(job));
        shared.tracer.emit(
            None,
            Event::new(EventKind::Enqueue)
                .req(rid)
                .a(q.len() as u64)
                .b(is_shard as u64),
        );
    }
    drop(q);
    shared.cv.notify_all();
}

/// A cheap probe launch for quarantine re-admission: consult the
/// scripted fault layer (the only failure source in the simulator),
/// then do a tiny global-memory write/read roundtrip so the probe
/// actually exercises the device.
fn probe_device(slot: &DeviceSlot) -> Result<(), Error> {
    if let Some(f) = slot.fault.as_ref() {
        f.probe_ok()?;
    }
    let addr = slot.device.gmem.alloc(8, 8)?;
    let result = (|| {
        let pattern = 0xA5A5_5A5A_A5A5_5A5Au64.to_le_bytes();
        slot.device.gmem.write_bytes(addr, &pattern)?;
        let mut back = [0u8; 8];
        slot.device.gmem.read_bytes(addr, &mut back)?;
        if back != pattern {
            return Err(Error::Fault("probe readback mismatch".into()));
        }
        Ok(())
    })();
    let _ = slot.device.gmem.free(addr);
    result
}

/// Quarantine `device` (idempotent — only the first caller sweeps) and
/// **preemptively re-plan** its still-queued pinned shard jobs: each is
/// retargeted to a currently idle healthy device matching its affinity
/// (whose reservation is bumped as it is chosen, in the same queue
/// critical section that rebalances the quarantined device's counter),
/// or unpinned into normal DRR visibility when no idle device exists —
/// the reservation-free fallback placement. Queued jobs whose affinity
/// no longer matches any live device are failed immediately: deadline
/// work must never sit waiting on a dead device.
fn quarantine_and_replan(shared: &Shared, device: usize) {
    let slot = &shared.slots[device];
    if !slot.health.quarantine() {
        return;
    }
    shared.tracer.emit(None, Event::new(EventKind::Quarantine).device(device));
    {
        let mut q = shared.queue.lock().unwrap();
        replan_pinned_locked(shared, device, &mut q);
        shared.replans.fetch_add(1, Ordering::Relaxed);
    }
    // Re-planned pins are claimable immediately.
    shared.cv.notify_all();
    sweep_stranded(shared);
}

/// The re-plan body shared by [`quarantine_and_replan`] and the gated
/// worker (which drains pins that *raced* onto the device after the
/// quarantine sweep — the shard planner's idle sample is lock-free, so
/// a pinned push can land just behind the sweep). Must run under the
/// queue lock `q` was taken from.
fn replan_pinned_locked(shared: &Shared, device: usize, q: &mut SchedQueue) -> usize {
    let moved = q.replan_pinned(device, |job| {
        let target = shared.slots.iter().find(|s| {
            s.id != device
                && s.health.state() == HealthState::Healthy
                && s.inflight.load(Ordering::Relaxed) == 0
                && shared.reserved[s.id].load(Ordering::Relaxed) == 0
                && !job.tried.contains(&s.id)
                && job.req.affinity.matches(s.spec.arch, s.spec.kind)
        })?;
        shared.reserved[target.id].fetch_add(1, Ordering::Relaxed);
        Some(target.id)
    });
    if moved > 0 {
        shared.reserved[device].fetch_sub(moved, Ordering::Relaxed);
        shared.replanned_jobs.fetch_add(moved as u64, Ordering::Relaxed);
        // Unpinning makes jobs visible to the stranded sweep for the
        // first time (it skips pinned jobs), so arm the next
        // probe-failure sweep even if no new push ever arrives.
        shared.queue_gen.fetch_add(1, Ordering::Relaxed);
    }
    moved
}

/// Fail every queued job that no live device can ever claim — each
/// remaining device is quarantined, fails the job's affinity, or
/// already failed the job (retry excludes it via `tried`). Deadline
/// work must never sit waiting on a dead device. Runs at every
/// quarantine and again whenever a re-admission probe fails, which also
/// closes the submit/quarantine race: a request validated just before
/// its only device went dark is caught by the next probe's sweep.
fn sweep_stranded(shared: &Shared) {
    shared
        .last_sweep_gen
        .store(shared.queue_gen.load(Ordering::Relaxed), Ordering::Relaxed);
    let stranded = {
        let mut q = shared.queue.lock().unwrap();
        // Stranded = no live device can ever claim it: every device is
        // quarantined, fails the affinity, or already failed this very
        // job (retry excludes it via `tried`).
        q.remove_stranded(|job| !shared.any_live_candidate(job.affinity(), job.tried()))
    };
    if stranded.is_empty() {
        return;
    }
    // Removals freed queue slots for blocked submitters.
    shared.space.notify_all();
    let done = shared.clock.now();
    // One clients-table lock for the whole sweep, matching the batched
    // reply loop's discipline.
    let mut accounts = shared.clients.lock().unwrap();
    for job in stranded {
        match job {
            Job::Offload(j) => {
                // A stranded hedge duplicate resolves as wasted, full
                // stop: the original (running, queued, or already
                // settled) owns the request's termination, so nothing
                // fails, records, or replies here.
                if j.is_hedge {
                    shared.hedges_inflight.fetch_sub(1, Ordering::Relaxed);
                    shared.hedge_wasted.fetch_add(1, Ordering::Relaxed);
                    shared.tracer.emit(
                        None,
                        Event::new(EventKind::HedgeWasted).req(j.req_id).a(2),
                    );
                    continue;
                }
                // A stranded original whose hedge duplicate already won
                // is equally silent — its reply, record and `Done`
                // happened when the duplicate settled.
                if j.settled.swap(true, Ordering::SeqCst) {
                    continue;
                }
                shared.failed.fetch_add(1, Ordering::Relaxed);
                // Shard jobs are accounted by their stitcher (which sees
                // the error reply); everything else records here.
                // Queue-wait covers the current stint only (reset on
                // retry requeue); sojourn spans the whole journey.
                if !j.is_shard {
                    record_into(
                        &mut accounts,
                        &shared.tracer,
                        j.req_id,
                        &j.req.client,
                        done.saturating_duration_since(j.enqueued),
                        done.saturating_duration_since(j.first_enqueued),
                        false,
                        j.deadline,
                        done,
                    );
                }
                let err = match j.first_fault.clone() {
                    // A retry orphan keeps its original incident.
                    Some(first) => first,
                    None => format!(
                        "no live device matches affinity {:?} (quarantine)",
                        j.req.affinity
                    ),
                };
                let _ = j.reply.send(Err(Error::Fault(err)));
            }
            // Dropping a task drops its reply sender (the TaskHandle
            // resolves to a pool error), but the client's books must
            // still balance: completed + failed == submitted per client.
            Job::Task(t) => {
                shared.failed.fetch_add(1, Ordering::Relaxed);
                let sojourn = done.saturating_duration_since(t.enqueued);
                record_into(
                    &mut accounts,
                    &shared.tracer,
                    t.req_id,
                    &t.client,
                    sojourn,
                    sojourn,
                    false,
                    t.deadline,
                    done,
                );
            }
        }
    }
}

/// Execute a popped batch (size ≥ 1) on `slot` and reply to every job.
///
/// The image lookup/prepare is paid once per batch; follower jobs are
/// recorded as cache hits (they share the leader's image by
/// construction). Batches of independent jobs — images without
/// global-space globals, so no cross-launch device state — execute as one
/// fused grid via [`OffloadDevice::offload_batch`]; anything else falls
/// back to per-job sequential launches.
fn run_offload_batch(shared: &Shared, slot: &DeviceSlot, batch: Vec<OffloadJob>) {
    let n = batch.len();
    let t_busy = shared.clock.now();
    slot.inflight.fetch_add(n, Ordering::Relaxed);
    slot.health.begin_work(shared.now_ns(), n, Some(batch[0].key.content));
    // Payload: a = jobs in the launch, b = image key. Tagged with the
    // leader's request id (followers share the span via BatchFormed).
    shared.tracer.emit(
        Some(slot.id),
        Event::new(EventKind::LaunchStart)
            .device(slot.id)
            .req(batch[0].req_id)
            .a(n as u64)
            .b(batch[0].key.content),
    );
    slot.batches.fetch_add(1, Ordering::Relaxed);
    if n > 1 {
        slot.batched_jobs.fetch_add(n as u64, Ordering::Relaxed);
    }
    slot.max_batch.fetch_max(n, Ordering::Relaxed);
    let now = shared.clock.now();
    let waits: Vec<Duration> =
        batch.iter().map(|j| now.saturating_duration_since(j.enqueued)).collect();

    // Register the batch with the hedging monitor before anything that
    // can block (the scripted-fault stall sleeps below, exactly like a
    // real wedged launch). Hedge duplicates are themselves never
    // registered — one speculative copy per request is the ceiling —
    // and with hedging off the registry stays empty and untouched.
    let reg_tokens: Vec<u64> = if shared.hedge {
        let started = shared.clock.now();
        let mut reg = shared.inflight_reg.lock().unwrap();
        batch
            .iter()
            .filter(|j| !j.is_hedge)
            .map(|j| {
                let tok = shared.hedge_seq.fetch_add(1, Ordering::Relaxed);
                reg.insert(
                    tok,
                    InflightEntry {
                        req: j.req.clone(),
                        key: j.key,
                        is_shard: j.is_shard,
                        deadline: j.deadline,
                        tried: j.tried.clone(),
                        device: slot.id,
                        started,
                        batch_jobs: n as u64,
                        req_id: j.req_id,
                        reply: j.reply.clone(),
                        settled: j.settled.clone(),
                        first_enqueued: j.first_enqueued,
                        hedged: false,
                    },
                );
                tok
            })
            .collect()
    } else {
        vec![]
    };

    // Scripted-fault gate. An injected stall sleeps *here* — in flight,
    // so the watchdog sees the age grow exactly as it would for a real
    // wedged launch; fail/die turn the whole batch into device-fault
    // errors (eligible for retry below); slow hands back a factor
    // applied after execution. `fault_touched` covers *any* injection,
    // including a stall that then returns Ok (detected via the injected
    // counter) — the EWMA guard below needs to know.
    let (gate, slow_factor, fault_touched) = match slot.fault.as_ref() {
        Some(f) => {
            let injected_before = f.injected();
            match f.on_batch_start(n, &shared.shutdown) {
                Ok(factor) => {
                    (None, factor, factor > 1.0 || f.injected() > injected_before)
                }
                // Keep the bare message: it is re-wrapped as
                // `Error::Fault` per job below, and stringifying the
                // whole error here would double the Display prefix.
                Err(Error::Fault(m)) => (Some(m), 1.0, true),
                Err(e) => (Some(e.to_string()), 1.0, true),
            }
        }
        None => (None, 1.0, false),
    };
    let fault_failed = gate.is_some();

    let results: Vec<Result<OffloadResponse, Error>> = match gate {
        Some(msg) => batch.iter().map(|_| Err(Error::Fault(msg.clone()))).collect(),
        None => match slot.cache.get_or_prepare(&slot.device, &batch[0].req.module, batch[0].req.opt)
        {
            Err(e) => {
                let msg = format!("prepare failed: {e}");
                batch.iter().map(|_| Err(Error::Sched(msg.clone()))).collect()
            }
            Ok((image, first_hit)) => {
                if n > 1 {
                    slot.cache.note_batched_hits(n as u64 - 1);
                }
                if n > 1 && image.module.global_addrs.is_empty() {
                    run_fused(&*shared.clock, slot, &image, &batch, &waits, first_hit)
                } else {
                    batch
                        .iter()
                        .enumerate()
                        .map(|(i, job)| {
                            let hit = if i == 0 { first_hit } else { true };
                            run_one(&*shared.clock, slot, &image, &job.req, waits[i], hit)
                        })
                        .collect()
                }
            }
        },
    };
    if slow_factor > 1.0 {
        if let Some(f) = slot.fault.as_ref() {
            let elapsed = shared.clock.now().saturating_duration_since(t_busy);
            f.apply_slowdown(slow_factor, elapsed, &shared.shutdown);
        }
    }

    slot.inflight.fetch_sub(n, Ordering::Relaxed);
    let done = shared.clock.now();
    let busy = done.saturating_duration_since(t_busy);
    slot.busy_ns
        .fetch_add(busy.as_nanos().min(u64::MAX as u128) as u64, Ordering::Relaxed);
    // Payload: a = jobs, b = whether every job in the launch succeeded,
    // c = device wall time for the launch (ns).
    shared.tracer.emit(
        Some(slot.id),
        Event::new(EventKind::LaunchEnd)
            .device(slot.id)
            .req(batch[0].req_id)
            .a(n as u64)
            .b(results.iter().all(|r| r.is_ok()) as u64)
            .c(busy.as_nanos().min(u64::MAX as u128) as u64),
    );
    // The batch is no longer hedge-worthy: results are in hand.
    if !reg_tokens.is_empty() {
        let mut reg = shared.inflight_reg.lock().unwrap();
        for tok in &reg_tokens {
            reg.remove(tok);
        }
    }
    // The EWMA observation for this batch is recorded *after* the reply
    // loop below: a batch containing a hedge loser (either side of the
    // race) measured a stalled or redundant run, and folding that into
    // the service prediction would poison the very trigger that hedged
    // it. `suppressed_any` is only known once the loop has settled.
    let (key0, shard0) = (batch[0].key.content, batch[0].is_shard);
    // Fault-streak quarantine: a fast-failing (dead) device never trips
    // the stall watchdog, so consecutive injected-fault batches trip it
    // here instead.
    if slot.health.end_work(fault_failed) && shared.watchdog {
        quarantine_and_replan(shared, slot.id);
    }

    // Reply / retry split. Device-fault failures are re-queued onto a
    // different healthy device while the bounded budget lasts; whatever
    // ends here is accounted and replied exactly once.
    let mut requeue: Vec<OffloadJob> = vec![];
    let mut suppressed_any = false;
    {
        // One clients-table lock for the whole batch, not one per job.
        let mut accounts = shared.clients.lock().unwrap();
        for ((i, mut job), result) in batch.into_iter().enumerate().zip(results) {
            // Hedge duplicates resolve right here, whatever happened: a
            // duplicate is never retried, and only a *successful* one
            // that wins the settle race owns the request's reply. The
            // short-circuit matters — a failed duplicate must not latch
            // the race, because the original may still succeed.
            if job.is_hedge {
                let won = result.is_ok() && !job.settled.swap(true, Ordering::SeqCst);
                shared.hedges_inflight.fetch_sub(1, Ordering::Relaxed);
                if !won {
                    shared.hedge_wasted.fetch_add(1, Ordering::Relaxed);
                    suppressed_any = true;
                    // Payload: a = why it was wasted (0 = lost the race,
                    // 1 = the duplicate itself failed).
                    shared.tracer.emit(
                        Some(slot.id),
                        Event::new(EventKind::HedgeWasted)
                            .device(slot.id)
                            .req(job.req_id)
                            .a(u64::from(result.is_err())),
                    );
                    continue;
                }
                shared.hedge_wins.fetch_add(1, Ordering::Relaxed);
                shared.tracer.emit(
                    Some(slot.id),
                    Event::new(EventKind::HedgeWon).device(slot.id).req(job.req_id),
                );
                // Fall through: the winning duplicate takes the normal
                // accounting + reply path as if it were the original.
            }
            let result = match result {
                Err(Error::Fault(msg)) => {
                    if job.is_hedge {
                        unreachable!("failed hedge duplicates resolve above");
                    }
                    // A hedge duplicate already owns this request: the
                    // original's fault is moot — no retry, no reply, no
                    // accounting. (Unsettled originals retry normally
                    // even while a duplicate races them.)
                    if job.settled.load(Ordering::SeqCst) {
                        suppressed_any = true;
                        continue;
                    }
                    if job.first_fault.is_none() {
                        job.first_fault = Some(msg.clone());
                    }
                    if !job.tried.contains(&slot.id) {
                        job.tried.push(slot.id);
                    }
                    // `tried` already contains this device, so the
                    // candidate scan naturally demands a different one.
                    let can_retry = (job.tried.len() as u64) <= shared.retry_max as u64
                        && shared.any_live_candidate(job.req.affinity, &job.tried);
                    if can_retry {
                        // The pin (if any) pointed at this misbehaving
                        // device; the retry goes wherever the DRR scan
                        // sends it. Queue-wait restarts for the new
                        // stint (sojourn keeps the original clock).
                        job.target_device = None;
                        job.enqueued = shared.clock.now();
                        shared.retries.fetch_add(1, Ordering::Relaxed);
                        // Same request id, incremented attempt: a =
                        // attempt number (1-based = devices tried so
                        // far), device = the device that faulted.
                        shared.tracer.emit(
                            Some(slot.id),
                            Event::new(EventKind::Retry)
                                .device(slot.id)
                                .req(job.req_id)
                                .a(job.tried.len() as u64),
                        );
                        requeue.push(job);
                        continue;
                    }
                    shared.retries_exhausted.fetch_add(1, Ordering::Relaxed);
                    // Past the cap the *original* fault is surfaced, not
                    // whichever device failed last.
                    Err(Error::Fault(job.first_fault.clone().expect("set above")))
                }
                other => other,
            };
            // Exactly-once settle: the first terminal outcome for a
            // request — original or hedge duplicate — owns the pool
            // counters, the per-client record, the deadline judgment,
            // the reply and the trace `Done`. A loser is ignored on
            // arrival. (A winning duplicate already swapped the latch
            // above; unhedged jobs win their private latch trivially.)
            if !job.is_hedge && job.settled.swap(true, Ordering::SeqCst) {
                suppressed_any = true;
                continue;
            }
            match &result {
                Ok(_) => {
                    slot.completed.fetch_add(1, Ordering::Relaxed);
                    shared.completed.fetch_add(1, Ordering::Relaxed);
                }
                Err(_) => {
                    shared.failed.fetch_add(1, Ordering::Relaxed);
                }
            }
            // Shard jobs are accounted by their request's stitcher, so the
            // per-client metrics count split requests once.
            if !job.is_shard {
                record_into(
                    &mut accounts,
                    &shared.tracer,
                    job.req_id,
                    &job.req.client,
                    waits[i],
                    done.saturating_duration_since(job.first_enqueued),
                    result.is_ok(),
                    job.deadline,
                    done,
                );
            }
            // A dropped handle is fine: the work still ran.
            let _ = job.reply.send(result);
        }
    }
    // One per-job service observation per batch, feeding the panic-window
    // prediction for this image key. Shard batches are skipped: a shard
    // runs a fraction of the full request under the same content key,
    // and folding its time in would teach the predictor that unsharded
    // runs of the image are several times faster than they are. Batches
    // the fault layer touched are skipped too — an injected stall or
    // slowdown is the *device* misbehaving, not the image's service
    // time, and folding it in would both poison the panic predictor and
    // teach the watchdog to tolerate the very stall it should catch.
    // Batches with a suppressed hedge loser are skipped for the same
    // reason: the loser's time measures the race, not the image.
    if !shard0 && !fault_touched && !suppressed_any {
        shared.service.record(Some(key0), busy.as_secs_f64() / n as f64);
    }
    if !requeue.is_empty() {
        // Retries re-enter the queue directly: they were already counted
        // in `submitted` at their original enqueue, and backpressure
        // must not apply (the job was admitted once; blocking a worker
        // thread on `queue_cap` here could deadlock the pool). The
        // generation bump keeps the probe-failure sweep armed: a retry
        // whose target quarantined in this window must still be swept.
        let mut q = shared.queue.lock().unwrap();
        for job in requeue {
            shared.queue_gen.fetch_add(1, Ordering::Relaxed);
            let rid = job.req_id;
            let is_shard = job.is_shard;
            q.push(Job::Offload(job));
            // Re-entry into the queue under the same request id.
            shared.tracer.emit(
                Some(slot.id),
                Event::new(EventKind::Enqueue)
                    .req(rid)
                    .a(q.len() as u64)
                    .b(is_shard as u64),
            );
        }
        drop(q);
        shared.cv.notify_all();
    }
}

/// Map each request buffer into device memory (copying `To`/`Tofrom`
/// data); on failure everything already mapped is freed.
fn map_buffers(device: &OffloadDevice, req: &OffloadRequest) -> Result<Vec<u64>, Error> {
    let mut addrs = Vec::with_capacity(req.buffers.len());
    for b in &req.buffers {
        match device.gmem.alloc((b.bytes.len() as u64).max(1), 8) {
            Ok(addr) => {
                addrs.push(addr);
                if matches!(b.map_type, MapType::To | MapType::Tofrom) {
                    if let Err(e) = device.gmem.write_bytes(addr, &b.bytes) {
                        free_buffers(device, &addrs);
                        return Err(e);
                    }
                }
            }
            Err(e) => {
                free_buffers(device, &addrs);
                return Err(e);
            }
        }
    }
    Ok(addrs)
}

/// Return mapped buffers to the device's free-list allocator.
fn free_buffers(device: &OffloadDevice, addrs: &[u64]) {
    for &addr in addrs {
        let _ = device.gmem.free(addr);
    }
}

/// Resolve `KernelArg`s against the mapped device addresses.
fn resolve_args(req: &OffloadRequest, dev_addrs: &[u64]) -> Vec<u64> {
    req.args
        .iter()
        .map(|a| match a {
            KernelArg::Buf(i) => dev_addrs[*i], // index validated at submit
            KernelArg::Imm(v) => *v,
        })
        .collect()
}

/// Read back `From`/`Tofrom` buffers after a launch.
fn read_back(
    device: &OffloadDevice,
    req: &OffloadRequest,
    dev_addrs: &[u64],
) -> Result<Vec<Option<Vec<u8>>>, Error> {
    let mut out = Vec::with_capacity(req.buffers.len());
    for (b, addr) in req.buffers.iter().zip(dev_addrs) {
        if matches!(b.map_type, MapType::From | MapType::Tofrom) {
            let mut buf = vec![0u8; b.bytes.len()];
            device.gmem.read_bytes(*addr, &mut buf)?;
            out.push(Some(buf));
        } else {
            out.push(None);
        }
    }
    Ok(out)
}

/// Execute one request on `slot`: map, launch, read back, free.
fn run_one(
    clock: &dyn Clock,
    slot: &DeviceSlot,
    image: &Arc<KernelImage>,
    req: &OffloadRequest,
    queue_wait: Duration,
    cache_hit: bool,
) -> Result<OffloadResponse, Error> {
    let dev_addrs = map_buffers(&slot.device, req)?;
    let args = resolve_args(req, &dev_addrs);
    let (launch, elapsed) =
        stats::timed_with(clock, || slot.device.offload(image, &req.kernel, &args, req.cfg));
    slot.profiler.record(&req.region, elapsed);
    let result = (|| {
        let stats = launch?;
        let buffers = read_back(&slot.device, req, &dev_addrs)?;
        Ok(OffloadResponse {
            device_id: slot.id,
            arch: slot.spec.arch,
            kind: slot.spec.kind,
            stats,
            cache_hit,
            queue_wait,
            shards: 1,
            buffers,
        })
    })();
    free_buffers(&slot.device, &dev_addrs);
    result
}

/// Execute a batch of independent jobs as one fused grid. Per-job wall
/// attribution inside a fused grid is not measurable; each job's region
/// is charged an equal share of the batch.
fn run_fused(
    clock: &dyn Clock,
    slot: &DeviceSlot,
    image: &Arc<KernelImage>,
    batch: &[OffloadJob],
    waits: &[Duration],
    first_hit: bool,
) -> Vec<Result<OffloadResponse, Error>> {
    let n = batch.len();
    let mut mapped: Vec<Result<Vec<u64>, Error>> =
        batch.iter().map(|j| map_buffers(&slot.device, &j.req)).collect();

    // Fused items cover only the successfully mapped jobs.
    let mut arg_store: Vec<Vec<u64>> = Vec::with_capacity(n);
    let mut item_of_job: Vec<Option<usize>> = Vec::with_capacity(n);
    for (i, job) in batch.iter().enumerate() {
        match &mapped[i] {
            Ok(addrs) => {
                item_of_job.push(Some(arg_store.len()));
                arg_store.push(resolve_args(&job.req, addrs));
            }
            Err(_) => item_of_job.push(None),
        }
    }
    let mut items: Vec<BatchKernelSpec<'_>> = Vec::with_capacity(arg_store.len());
    for (i, job) in batch.iter().enumerate() {
        if let Some(k) = item_of_job[i] {
            items.push(BatchKernelSpec {
                kernel: &job.req.kernel,
                args: &arg_store[k],
                cfg: job.req.cfg,
            });
        }
    }

    let (launch_results, elapsed) =
        stats::timed_with(clock, || slot.device.offload_batch(image, &items));
    // Equal-share attribution over the jobs that actually launched;
    // map-failed jobs ran nothing and are not charged.
    let share = elapsed / items.len().max(1) as u32;

    let mut launch_iter = launch_results.into_iter();
    let mut results = Vec::with_capacity(n);
    for (i, job) in batch.iter().enumerate() {
        let res = match item_of_job[i] {
            None => {
                let e = std::mem::replace(&mut mapped[i], Ok(Vec::new()));
                Err(e.expect_err("unmapped job carries its map error"))
            }
            Some(_) => {
                slot.profiler.record(&job.req.region, share);
                match launch_iter.next().expect("one result per fused item") {
                    Err(e) => Err(e),
                    Ok(stats) => {
                        let addrs = mapped[i].as_ref().expect("mapped job has addresses");
                        read_back(&slot.device, &job.req, addrs).map(|buffers| OffloadResponse {
                            device_id: slot.id,
                            arch: slot.spec.arch,
                            kind: slot.spec.kind,
                            stats,
                            cache_hit: if i == 0 { first_hit } else { true },
                            queue_wait: waits[i],
                            shards: 1,
                            buffers,
                        })
                    }
                }
            }
        };
        results.push(res);
    }
    for m in &mapped {
        if let Ok(addrs) = m {
            free_buffers(&slot.device, addrs);
        }
    }
    results
}

// ---------------------------------------------------------------------------
// Metrics
// ---------------------------------------------------------------------------

/// Per-device metrics snapshot.
#[derive(Debug, Clone)]
pub struct DeviceMetrics {
    /// Pool-local device id.
    pub id: usize,
    /// Runtime build.
    pub kind: RuntimeKind,
    /// Architecture.
    pub arch: Arch,
    /// Requests currently executing on this device (a whole batch counts
    /// each of its jobs).
    pub inflight: usize,
    /// Age of the batch currently executing on this device (`None` =
    /// idle, or held by a lease, which the watchdog exempts). This is
    /// the left side of the watchdog/hedging trigger comparison.
    pub inflight_age: Option<Duration>,
    /// EWMA service prediction for that executing batch (the right side
    /// of the comparison; zero = cold key, `None` = idle/leased).
    pub inflight_predicted: Option<Duration>,
    /// Shard jobs queued with this device reserved for them.
    pub reserved: usize,
    /// Requests completed on this device.
    pub completed: u64,
    /// Queue pops (each pop executes a batch of ≥ 1 jobs).
    pub batches: u64,
    /// Jobs that ran inside a multi-job batch.
    pub batched_jobs: u64,
    /// Largest batch popped so far.
    pub max_batch: usize,
    /// Fraction of pool uptime this device's worker spent executing
    /// work, in `[0, 1]`.
    pub occupancy: f64,
    /// Health lifecycle state (see [`crate::sched::health`]).
    pub health: HealthState,
    /// Times this device entered quarantine.
    pub quarantines: u64,
    /// The armed fault spec, when the device is scripted to misbehave
    /// (`[pool] faults` echo).
    pub fault: Option<String>,
    /// Times the fault layer actually injected misbehavior here.
    pub fault_injected: u64,
    /// Image-cache counters.
    pub cache: CacheStats,
    /// Images currently cached.
    pub cached_images: usize,
    /// Estimated bytes of cached images.
    pub cache_bytes: u64,
    /// Device global-memory allocator counters.
    pub mem: MemStats,
}

/// Pool-wide metrics snapshot.
#[derive(Debug, Clone)]
pub struct PoolMetrics {
    /// Jobs waiting in the submission queue.
    pub queue_depth: usize,
    /// Deepest the queue has ever been.
    pub peak_queue_depth: usize,
    /// Configured queue bound (0 = unbounded).
    pub queue_cap: usize,
    /// Total jobs accepted (shard jobs and device tasks count
    /// individually).
    pub submitted: u64,
    /// Total jobs completed successfully.
    pub completed: u64,
    /// Total jobs that failed.
    pub failed: u64,
    /// Client requests that were split across devices.
    pub sharded_requests: u64,
    /// Shard jobs those requests produced.
    pub shard_jobs: u64,
    /// Whether the adaptive scheduling layer is on.
    pub adaptive: bool,
    /// Adaptive-controller counters (all zero when `adaptive` is off).
    pub adaptive_stats: AdaptiveStats,
    /// Queue pops taken through the EDF panic path (deadline work
    /// jumping the DRR rotation inside its panic window).
    pub preemptions: u64,
    /// Whether the health monitor (watchdog/quarantine/probes) is on.
    pub watchdog: bool,
    /// Quarantine incidents that swept the queue for pinned re-planning.
    pub replans: u64,
    /// Still-queued pinned shard jobs retargeted or unpinned by those
    /// sweeps.
    pub replanned_jobs: u64,
    /// Device-fault jobs re-queued onto a different healthy device.
    pub retries: u64,
    /// Device-fault jobs whose retry budget ran out (original error
    /// surfaced to the client).
    pub retries_exhausted: u64,
    /// Quarantine re-admission probes attempted.
    pub probes: u64,
    /// Probes that passed and returned a device to service.
    pub readmissions: u64,
    /// Whether tail-latency hedging is on.
    pub hedge: bool,
    /// Speculative duplicates launched for at-risk in-flight work.
    pub hedges: u64,
    /// Duplicates that completed first and owned their request's reply.
    pub hedge_wins: u64,
    /// Duplicates that lost the race, failed, or drained unresolved —
    /// after the pool settles, `hedges == hedge_wins + hedge_wasted`.
    pub hedge_wasted: u64,
    /// Time since the pool started.
    pub uptime: Duration,
    /// Per-device breakdown.
    pub devices: Vec<DeviceMetrics>,
    /// Per-client breakdown, sorted by client tag. Counts *requests*
    /// (a sharded request is one entry) plus device tasks, so totals
    /// can differ from the job-level `completed`.
    pub clients: Vec<ClientMetrics>,
}

/// Per-client fairness + SLO metrics snapshot.
#[derive(Debug, Clone)]
pub struct ClientMetrics {
    /// Client tag ("" = the default client).
    pub client: String,
    /// Configured scheduling weight (1.0 unless overridden).
    pub weight: f64,
    /// Configured latency target (`[pool] client_slos`), if any.
    pub slo: Option<Duration>,
    /// Requests completed for this client.
    pub completed: u64,
    /// Requests failed for this client.
    pub failed: u64,
    /// Time the client's requests sat queued before a worker claimed
    /// them.
    pub queue_wait: Summary,
    /// Submit-to-completion sojourn times.
    pub latency: Summary,
    /// Log-bucketed sojourn distribution in µs, covering every
    /// completion (see [`Histogram`]; backs
    /// [`ClientMetrics::latency_p95_us`] and merges exactly across
    /// clients).
    pub latency_us: Histogram,
    /// Log-bucketed queue-wait distribution in µs.
    pub queue_wait_us: Histogram,
    /// Log-bucketed signed deadline-slack distribution in µs (negative
    /// = missed); empty when the client never carried a deadline.
    pub slack_us: Histogram,
    /// Requests that carried a deadline (explicit budget or client SLO).
    pub deadlines: u64,
    /// Deadlined requests that completed past their deadline. Sharded
    /// requests count once (stitcher-side), never per shard.
    pub deadline_miss: u64,
    /// Signed slack (deadline − completion time) over deadlined
    /// requests: positive = met with room, negative = missed by that
    /// much. Finite for any finite clock readings.
    pub slack: SlackSummary,
}

impl ClientMetrics {
    /// Median submit-to-completion sojourn in µs (0 with no samples).
    pub fn latency_p50_us(&self) -> f64 {
        self.latency_us.percentile_us(0.50)
    }

    /// 95th-percentile sojourn in µs (0 with no samples). Tail latency
    /// is what SLOs are judged on — the SLO bench compares this against
    /// bulk clients' medians.
    pub fn latency_p95_us(&self) -> f64 {
        self.latency_us.percentile_us(0.95)
    }

    /// 99th-percentile sojourn in µs (0 with no samples).
    pub fn latency_p99_us(&self) -> f64 {
        self.latency_us.percentile_us(0.99)
    }
}

impl PoolMetrics {
    /// `client`'s fraction of all client-recorded completions (0 when
    /// nothing completed). Fair-share comparisons in the fairness tests
    /// and bench are phrased over this.
    pub fn client_share(&self, client: &str) -> f64 {
        let total: u64 = self.clients.iter().map(|c| c.completed).sum();
        if total == 0 {
            return 0.0;
        }
        self.clients
            .iter()
            .find(|c| c.client == client)
            .map_or(0.0, |c| c.completed as f64 / total as f64)
    }
    /// `(deadlined requests, deadline misses)` summed across clients.
    pub fn deadline_totals(&self) -> (u64, u64) {
        self.clients
            .iter()
            .fold((0, 0), |(d, m), c| (d + c.deadlines, m + c.deadline_miss))
    }

    /// Aggregated image-cache counters.
    pub fn cache(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for d in &self.devices {
            s.hits += d.cache.hits;
            s.misses += d.cache.misses;
            s.evictions += d.cache.evictions;
        }
        s
    }

    /// Jobs coalesced into multi-job batches, pool-wide.
    pub fn batched_jobs(&self) -> u64 {
        self.devices.iter().map(|d| d.batched_jobs).sum()
    }

    /// Bytes live across every device allocator.
    pub fn device_live_bytes(&self) -> u64 {
        self.devices.iter().map(|d| d.mem.live_bytes).sum()
    }

    /// Completed launches per second of pool uptime.
    pub fn throughput_per_sec(&self) -> f64 {
        let secs = self.uptime.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.completed as f64 / secs
        }
    }
}

// ---------------------------------------------------------------------------
// Test harness over the internal queue
// ---------------------------------------------------------------------------

/// Deterministic, single-threaded harness over the pool's internal
/// scheduling queue, exposed (hidden) for the crate's property-based
/// tests in `tests/proptests.rs`: random op sequences drive `push`/
/// `pop`/`pop_pinned` directly and check the queue's invariants —
/// deficit floor, pinned-job invisibility, the panic-streak bound and
/// exact job accounting across lane compaction — without threads or
/// devices. Not part of the public API.
#[doc(hidden)]
pub struct QueueTestHarness {
    q: SchedQueue,
    svc: ServiceEwma,
    /// Settle latches minted by `push_hedge`, in creation order, so the
    /// proptests can race `settle` against pops the way an original
    /// racing its duplicate would.
    latches: Vec<Arc<AtomicBool>>,
}

#[doc(hidden)]
impl QueueTestHarness {
    /// Fresh queue with the given fairness flag and client weights.
    pub fn new(fairness: bool, client_weights: &[(String, f64)]) -> QueueTestHarness {
        QueueTestHarness {
            q: SchedQueue::new(fairness, client_weights),
            svc: ServiceEwma::new(),
            latches: vec![],
        }
    }

    fn spec() -> DeviceSpec {
        DeviceSpec { kind: RuntimeKind::Portable, arch: Arch::Nvptx64 }
    }

    /// Queue one any-affinity job for `client`, optionally pinned to a
    /// device and optionally carrying an already-expired deadline (so it
    /// is inside its panic window from the first pop).
    pub fn push(&mut self, client: &str, pinned: Option<usize>, past_deadline: bool) {
        let req = OffloadRequest {
            module: Module::new("harness"),
            kernel: "k".into(),
            region: "r".into(),
            cfg: LaunchConfig::new(1, 32),
            opt: OptLevel::O2,
            buffers: vec![],
            args: vec![],
            affinity: Affinity::any(),
            shard: None,
            client: client.to_string(),
            deadline: None,
        };
        let deadline = past_deadline.then(clock::now);
        let (tx, _rx) = mpsc::channel();
        self.q
            .push(Job::Offload(make_offload_job(req, tx, pinned.is_some(), pinned, deadline, 0, clock::now())));
    }

    /// One DRR/EDF pop for the worker of `device_id`; returns
    /// `(leader client, was a panic preemption, batch size)`. Asserts
    /// the invariant that no pinned job ever leaves through this path.
    pub fn pop(&mut self, device_id: usize, limit: usize) -> Option<(String, bool, usize)> {
        let (work, preempted) =
            self.q.pop(Self::spec(), device_id, limit.max(1), clock::now(), &self.svc)?;
        match work {
            Work::Task(_) => unreachable!("harness only queues offload jobs"),
            Work::Batch(batch) => {
                for job in &batch {
                    assert!(
                        job.target_device.is_none(),
                        "pinned job leaked through the DRR/EDF pop"
                    );
                }
                Some((batch[0].req.client.clone(), preempted, batch.len()))
            }
        }
    }

    /// Claim the oldest job pinned to `device_id`; asserts the pin
    /// matches. Returns whether a job was claimed.
    pub fn pop_pinned(&mut self, device_id: usize) -> bool {
        match self.q.pop_pinned(device_id) {
            Some(job) => {
                assert_eq!(job.target_device, Some(device_id), "pop_pinned crossed devices");
                true
            }
            None => false,
        }
    }

    /// Queue a hedge-duplicate-shaped job for `client`: pinned to
    /// `device` and flagged `is_hedge`, exactly as [`maybe_hedge`] mints
    /// them. Returns the index of the duplicate's settle latch (see
    /// [`QueueTestHarness::settle`]). From the queue's point of view a
    /// duplicate is just another pinned job — which is precisely the
    /// invariant the proptests pound on: accounting, reservations and
    /// pinned-invisibility must hold with duplicates in flight.
    pub fn push_hedge(&mut self, client: &str, device: usize) -> usize {
        let req = OffloadRequest {
            module: Module::new("harness"),
            kernel: "k".into(),
            region: "r".into(),
            cfg: LaunchConfig::new(1, 32),
            opt: OptLevel::O2,
            buffers: vec![],
            args: vec![],
            affinity: Affinity::any(),
            shard: None,
            client: client.to_string(),
            deadline: None,
        };
        let (tx, _rx) = mpsc::channel();
        let mut job = make_offload_job(req, tx, false, Some(device), None, 0, clock::now());
        job.is_hedge = true;
        let latch = job.settled.clone();
        self.q.push(Job::Offload(job));
        self.latches.push(latch);
        self.latches.len() - 1
    }

    /// Settle latch `idx` the way a completing original (or duplicate)
    /// would; returns whether this call won the race — false means the
    /// other side already settled and this outcome would be suppressed.
    pub fn settle(&mut self, idx: usize) -> bool {
        !self.latches[idx].swap(true, Ordering::SeqCst)
    }

    /// Settle latches minted so far (`push_hedge` count).
    pub fn latch_count(&self) -> usize {
        self.latches.len()
    }

    /// Jobs currently queued.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.q.len() == 0
    }

    /// Lanes currently allocated (compaction bound checks).
    pub fn lane_count(&self) -> usize {
        self.q.lanes.len()
    }

    /// Smallest lane deficit right now.
    pub fn min_deficit(&self) -> f64 {
        self.q.lanes.iter().map(|l| l.deficit).fold(0.0, f64::min)
    }

    /// Consecutive panic preemptions since the last normal pop.
    pub fn panic_streak(&self) -> usize {
        self.q.panic_streak
    }

    /// The queue's deficit floor (most negative legal deficit).
    pub fn deficit_floor() -> f64 {
        DEFICIT_FLOOR
    }

    /// The starvation bound on consecutive panic preemptions.
    pub fn panic_streak_max() -> usize {
        PANIC_STREAK_MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affinity_matching() {
        let any = Affinity::any();
        assert!(any.matches(Arch::Nvptx64, RuntimeKind::Legacy));
        let a = Affinity::on_arch(Arch::Amdgcn);
        assert!(a.matches(Arch::Amdgcn, RuntimeKind::Portable));
        assert!(!a.matches(Arch::Nvptx64, RuntimeKind::Portable));
        let k = Affinity::on_kind(RuntimeKind::Legacy);
        assert!(k.matches(Arch::Nvptx64, RuntimeKind::Legacy));
        assert!(!k.matches(Arch::Nvptx64, RuntimeKind::Portable));
    }

    #[test]
    fn device_spec_parses() {
        let s = DeviceSpec::parse("portable:nvptx64").unwrap();
        assert_eq!(s.kind, RuntimeKind::Portable);
        assert_eq!(s.arch, Arch::Nvptx64);
        assert_eq!(DeviceSpec::parse("legacy:amdgcn").unwrap().arch, Arch::Amdgcn);
        assert!(DeviceSpec::parse("nvptx64").is_none());
        assert!(DeviceSpec::parse("bad:nvptx64").is_none());
        assert!(DeviceSpec::parse("legacy:gfx9").is_none());
    }

    #[test]
    fn pool_config_from_config_document() {
        let cfg = Config::parse(
            "[pool]\ndevices = [\"portable:nvptx64\", \"legacy:amdgcn\"]\nopt = \"O0\"\n\
             batch_max = 4\nqueue_cap = 32\nshard_min_trips = 100\ncache_budget_bytes = 65536\n\
             adaptive = false\nfairness = false\nclient_weights = [\"qmc=4\", \"batch=0.5\"]\n\
             client_slos = [\"qmc=25\", \"ui=2.5\"]",
        )
        .unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(pc.devices.len(), 2);
        assert_eq!(pc.devices[1], DeviceSpec { kind: RuntimeKind::Legacy, arch: Arch::Amdgcn });
        assert_eq!(pc.default_opt, OptLevel::O0);
        assert_eq!(pc.batch_max, 4);
        assert_eq!(pc.queue_cap, 32);
        assert_eq!(pc.shard_min_trips, 100);
        assert_eq!(pc.cache_budget_bytes, 65536);
        assert!(!pc.adaptive);
        assert!(!pc.fairness);
        assert_eq!(
            pc.client_weights,
            vec![("qmc".to_string(), 4.0), ("batch".to_string(), 0.5)]
        );
        assert_eq!(
            pc.client_slos,
            vec![("qmc".to_string(), 25.0), ("ui".to_string(), 2.5)]
        );
        // Missing section → default mixed pool (adaptive + fairness on).
        let pc = PoolConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(pc, PoolConfig::mixed4());
        assert!(pc.adaptive);
        assert!(pc.fairness);
        // Bad spec errors.
        let cfg = Config::parse("[pool]\ndevices = [\"warp9:nvptx64\"]").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        // Out-of-range knobs error.
        let cfg = Config::parse("[pool]\nbatch_max = 0").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[pool]\nqueue_cap = -1").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        // Malformed adaptive/fairness/weights error.
        let cfg = Config::parse("[pool]\nadaptive = 3").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[pool]\nclient_weights = [\"qmc\"]").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[pool]\nclient_weights = [\"qmc=-1\"]").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[pool]\nclient_slos = [\"qmc\"]").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[pool]\nclient_slos = [\"qmc=0\"]").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn pool_config_parses_faults_and_health_knobs() {
        let cfg = Config::parse(
            "[pool]\ndevices = [\"portable:nvptx64\", \"legacy:amdgcn\"]\n\
             faults = [\"1=stall:120ms:10s@launch:40\", \"0=die@t:200ms\"]\n\
             watchdog = false\nwatchdog_min_ms = 50\nretry_max = 5",
        )
        .unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(pc.faults.len(), 2);
        assert_eq!(pc.faults[0].device, 1);
        assert_eq!(pc.faults[1].device, 0);
        assert!(!pc.watchdog);
        assert_eq!(pc.watchdog_min_ms, 50);
        assert_eq!(pc.retry_max, 5);
        // Defaults: watchdog on, conservative floor, bounded retry, no faults.
        let d = PoolConfig::mixed4();
        assert!(d.faults.is_empty());
        assert!(d.watchdog);
        assert_eq!(d.retry_max, 2);
        // Bad specs and out-of-range knobs error.
        let cfg = Config::parse("[pool]\nfaults = [\"0=melt@launch:1\"]").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[pool]\nwatchdog_min_ms = 0").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        // A fault referencing a device outside the pool is rejected at
        // construction, as is a device with two fault scripts.
        let bad = PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)
            .with_fault_spec("3=die@launch:0")
            .unwrap();
        assert!(DevicePool::new(&bad).is_err());
        let twice = PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)
            .with_fault_spec("0=die@launch:9999999")
            .unwrap()
            .with_fault_spec("0=fail:1@launch:9999999")
            .unwrap();
        assert!(DevicePool::new(&twice).is_err());
    }

    #[test]
    fn pool_config_parses_hedge_knobs() {
        let cfg = Config::parse("[pool]\nhedge = true\nhedge_after_factor = 5\nhedge_max = 4")
            .unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert!(pc.hedge);
        assert_eq!(pc.hedge_after_factor, 5);
        assert_eq!(pc.hedge_max, 4);
        // Defaults: hedging off, trigger at 3x predicted, 2 duplicates.
        let d = PoolConfig::mixed4();
        assert!(!d.hedge);
        assert_eq!(d.hedge_after_factor, 3);
        assert_eq!(d.hedge_max, 2);
        // Builders clamp to the sane minimum of 1.
        let b = PoolConfig::mixed4()
            .with_hedge(true)
            .with_hedge_after_factor(0)
            .with_hedge_max(0);
        assert!(b.hedge);
        assert_eq!(b.hedge_after_factor, 1);
        assert_eq!(b.hedge_max, 1);
        // Zero (or non-boolean) knobs in a config file are rejected.
        let cfg = Config::parse("[pool]\nhedge_after_factor = 0").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[pool]\nhedge_max = 0").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[pool]\nhedge = 7").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn hedge_settle_latch_is_exactly_once() {
        let (tx, _rx) = mpsc::channel();
        let job = make_offload_job(base_request(Affinity::any()), tx, false, None, None, 0, clock::now());
        // The duplicate shares the original's latch (as `maybe_hedge`
        // arranges); whichever side swaps first owns the termination.
        let dup_latch = job.settled.clone();
        assert!(!job.settled.swap(true, Ordering::SeqCst), "first settle wins");
        assert!(dup_latch.swap(true, Ordering::SeqCst), "second settle is suppressed");
    }

    #[test]
    fn harness_hedge_push_is_pinned_and_settles_once() {
        let mut h = QueueTestHarness::new(true, &[]);
        h.push("a", None, false);
        let latch = h.push_hedge("a", 1);
        assert_eq!(h.len(), 2);
        // The duplicate is pinned: invisible to the DRR pop path.
        let (client, _, n) = h.pop(0, 8).expect("original is claimable");
        assert_eq!((client.as_str(), n), ("a", 1));
        assert!(!h.pop_pinned(0), "duplicate is pinned to device 1, not 0");
        assert!(h.pop_pinned(1), "duplicate claimable only by its target");
        assert!(h.is_empty());
        // Original settles first; the duplicate's outcome is suppressed.
        assert!(h.settle(latch));
        assert!(!h.settle(latch));
        assert_eq!(h.latch_count(), 1);
    }

    #[test]
    fn f32_byte_roundtrip() {
        let v = vec![0.0f32, 1.5, -2.25, f32::MAX];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&v)), v);
    }

    fn base_request(affinity: Affinity) -> OffloadRequest {
        OffloadRequest {
            module: Module::new("m"),
            kernel: "k".into(),
            region: "r".into(),
            cfg: LaunchConfig::new(1, 32),
            opt: OptLevel::O2,
            buffers: vec![],
            args: vec![],
            affinity,
            shard: None,
            client: String::new(),
            deadline: None,
        }
    }

    #[test]
    fn submit_validates_before_enqueue() {
        let pool = DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64))
            .unwrap();
        // Bad buffer index.
        let mut r = base_request(Affinity::any());
        r.args = vec![KernelArg::Buf(3)];
        assert!(pool.submit(r).is_err());
        // Affinity matching no pool device.
        let r = base_request(Affinity::on_arch(Arch::Amdgcn));
        assert!(pool.submit(r).is_err());
        assert_eq!(pool.metrics().submitted, 0);
    }

    fn queued_job(client: &str, target: Option<usize>) -> Job {
        queued_job_dl(client, target, None)
    }

    fn queued_job_dl(client: &str, target: Option<usize>, deadline: Option<Instant>) -> Job {
        let mut req = base_request(Affinity::any());
        req.client = client.to_string();
        let (tx, _rx) = mpsc::channel();
        Job::Offload(make_offload_job(req, tx, target.is_some(), target, deadline, 0, clock::now()))
    }

    fn pop_client(q: &mut SchedQueue, spec: DeviceSpec, limit: usize) -> Option<String> {
        let svc = ServiceEwma::new();
        match q.pop(spec, 0, limit, clock::now(), &svc)?.0 {
            Work::Batch(batch) => Some(batch[0].req.client.clone()),
            Work::Task(_) => None,
        }
    }

    const SPEC: DeviceSpec = DeviceSpec { kind: RuntimeKind::Portable, arch: Arch::Nvptx64 };

    #[test]
    fn drr_alternates_between_backlogged_clients() {
        let mut q = SchedQueue::new(true, &[]);
        for _ in 0..4 {
            q.push(queued_job("a", None));
        }
        for _ in 0..2 {
            q.push(queued_job("b", None));
        }
        let order: Vec<String> = (0..6).map(|_| pop_client(&mut q, SPEC, 1).unwrap()).collect();
        assert_eq!(order, ["a", "b", "a", "b", "a", "a"], "chatty a must not starve b");
        assert!(q.pop(SPEC, 0, 1, clock::now(), &ServiceEwma::new()).is_none());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn drr_weights_skew_the_pull_share() {
        let mut q = SchedQueue::new(true, &[("a".to_string(), 3.0)]);
        for _ in 0..6 {
            q.push(queued_job("a", None));
            q.push(queued_job("b", None));
        }
        let first8: Vec<String> = (0..8).map(|_| pop_client(&mut q, SPEC, 1).unwrap()).collect();
        let a = first8.iter().filter(|c| *c == "a").count();
        let b = first8.len() - a;
        assert!(a >= 2 * b, "weight-3 client must dominate the early pops: {first8:?}");
    }

    #[test]
    fn coalescing_crosses_lanes_for_same_image_jobs() {
        let mut q = SchedQueue::new(true, &[]);
        q.push(queued_job("a", None));
        for _ in 0..3 {
            q.push(queued_job("b", None));
        }
        // All four jobs share one module, so a limit-4 pop takes them all.
        match q.pop(SPEC, 0, 4, clock::now(), &ServiceEwma::new()).unwrap().0 {
            Work::Batch(batch) => {
                assert_eq!(batch.len(), 4);
                assert_eq!(batch[0].req.client, "a", "leader comes from the served lane");
            }
            Work::Task(_) => panic!("expected a batch"),
        }
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn fairness_off_collapses_to_fifo() {
        let mut q = SchedQueue::new(false, &[]);
        q.push(queued_job("a", None));
        q.push(queued_job("a", None));
        q.push(queued_job("b", None));
        let order: Vec<String> = (0..3).map(|_| pop_client(&mut q, SPEC, 1).unwrap()).collect();
        assert_eq!(order, ["a", "a", "b"], "without fairness the queue is a global FIFO");
    }

    #[test]
    fn pinned_jobs_are_invisible_to_other_workers() {
        let mut q = SchedQueue::new(true, &[]);
        q.push(queued_job("a", Some(1)));
        // Worker 0 sees nothing poppable.
        assert!(q.pop(SPEC, 0, 4, clock::now(), &ServiceEwma::new()).is_none());
        assert!(q.pop_pinned(0).is_none());
        // Worker 1 claims it via the pinned path.
        let job = q.pop_pinned(1).expect("pinned job for device 1");
        assert_eq!(job.target_device, Some(1));
        assert_eq!(q.len(), 0);
    }

    /// Pop and return `(client, was_preemption)` for assertions on the
    /// EDF panic path.
    fn pop_flag(
        q: &mut SchedQueue,
        now: Instant,
        svc: &ServiceEwma,
    ) -> Option<(String, bool)> {
        let (work, preempted) = q.pop(SPEC, 0, 1, now, svc)?;
        match work {
            Work::Batch(batch) => Some((batch[0].req.client.clone(), preempted)),
            Work::Task(_) => None,
        }
    }

    #[test]
    fn panic_lane_preempts_the_drr_rotation() {
        let mut q = SchedQueue::new(true, &[]);
        let svc = ServiceEwma::new();
        // A backlogged best-effort lane that would normally lead the
        // rotation...
        for _ in 0..4 {
            q.push(queued_job("bulk", None));
        }
        // ...and one deadlined job already past its deadline.
        q.push(queued_job_dl("slo", None, Some(clock::now())));
        let (client, preempted) = pop_flag(&mut q, clock::now(), &svc).unwrap();
        assert_eq!(client, "slo", "panic work must jump the DRR rotation");
        assert!(preempted, "the pop must be flagged as a preemption");
        // With the panic drained, normal DRR resumes.
        let (client, preempted) = pop_flag(&mut q, clock::now(), &svc).unwrap();
        assert_eq!((client.as_str(), preempted), ("bulk", false));
    }

    #[test]
    fn panic_window_opens_at_predicted_service_time() {
        let mut q = SchedQueue::new(true, &[]);
        q.push(queued_job("bulk", None));
        let job = queued_job_dl("slo", None, Some(clock::now() + Duration::from_secs(5)));
        let key = job.image_key().unwrap();
        q.push(job);
        // With no service history (predicted service 0) five seconds of
        // slack looks comfortable: no preemption.
        let fresh = ServiceEwma::new();
        assert!(!q.any_panic(SPEC, 0, clock::now(), &fresh));
        let (client, preempted) = pop_flag(&mut q, clock::now(), &fresh).unwrap();
        assert_eq!((client.as_str(), preempted), ("bulk", false));
        // A service EWMA slower than the remaining slack opens the panic
        // window before the deadline itself arrives.
        let slow = ServiceEwma::new();
        for _ in 0..8 {
            slow.record(Some(key), 10.0);
        }
        assert!(q.any_panic(SPEC, 0, clock::now(), &slow));
        let (client, preempted) = pop_flag(&mut q, clock::now(), &slow).unwrap();
        assert_eq!((client.as_str(), preempted), ("slo", true));
    }

    #[test]
    fn edf_serves_the_earliest_deadline_first() {
        let mut q = SchedQueue::new(true, &[]);
        let svc = ServiceEwma::new();
        let base = clock::now();
        q.push(queued_job_dl("later", None, Some(base + Duration::from_millis(2))));
        q.push(queued_job_dl("sooner", None, Some(base + Duration::from_millis(1))));
        // Both are past deadline at pop time: earliest must win even
        // though "later" was pushed (and would rotate) first.
        let now = base + Duration::from_millis(10);
        let (client, preempted) = pop_flag(&mut q, now, &svc).unwrap();
        assert_eq!((client.as_str(), preempted), ("sooner", true));
        let (client, _) = pop_flag(&mut q, now, &svc).unwrap();
        assert_eq!(client, "later");
    }

    #[test]
    fn panic_streak_is_bounded_so_best_effort_lanes_drain() {
        let mut q = SchedQueue::new(true, &[]);
        let svc = ServiceEwma::new();
        // A pathological SLO client: every job is already past deadline.
        for _ in 0..32 {
            q.push(queued_job_dl("slo", None, Some(clock::now())));
        }
        for _ in 0..4 {
            q.push(queued_job("bulk", None));
        }
        let order: Vec<(String, bool)> =
            (0..(2 * (PANIC_STREAK_MAX + 1))).map(|_| pop_flag(&mut q, clock::now(), &svc).unwrap()).collect();
        // The first PANIC_STREAK_MAX pops may all be preemptions, but the
        // streak cap forces a normal DRR pop — which must reach the
        // best-effort lane — before preemption resumes.
        let bulk_served = order.iter().filter(|(c, _)| c == "bulk").count();
        assert!(
            bulk_served >= 2,
            "best-effort lane must drain under deadline pressure: {order:?}"
        );
        for window in order.windows(PANIC_STREAK_MAX + 1) {
            assert!(
                window.iter().any(|(_, preempted)| !preempted),
                "more than {PANIC_STREAK_MAX} consecutive preemptions: {order:?}"
            );
        }
    }

    #[test]
    fn deadlineless_queues_never_report_panic() {
        let mut q = SchedQueue::new(true, &[]);
        let svc = ServiceEwma::new();
        svc.record(Some(1), 100.0);
        for _ in 0..4 {
            q.push(queued_job("a", None));
        }
        assert!(!q.any_panic(SPEC, 0, clock::now(), &svc));
        let (_, preempted) = pop_flag(&mut q, clock::now(), &svc).unwrap();
        assert!(!preempted);
    }

    #[test]
    fn drained_one_off_lanes_are_compacted() {
        let mut q = SchedQueue::new(true, &[]);
        for i in 0..200 {
            q.push(queued_job(&format!("oneoff{i}"), None));
            let _ = q.pop(SPEC, 0, 1, clock::now(), &ServiceEwma::new());
        }
        assert!(
            q.lanes.len() <= 130,
            "drained one-off lanes must be reclaimed ({} lanes)",
            q.lanes.len()
        );
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn queue_peak_tracks_depth_under_the_lock() {
        let mut q = SchedQueue::new(true, &[]);
        for _ in 0..3 {
            q.push(queued_job("a", None));
        }
        assert_eq!((q.len(), q.peak()), (3, 3));
        let _ = q.pop(SPEC, 0, 1, clock::now(), &ServiceEwma::new());
        q.push(queued_job("b", None));
        assert_eq!((q.len(), q.peak()), (3, 3));
        q.push(queued_job("b", None));
        assert_eq!(q.peak(), 4);
    }

    #[test]
    fn submit_validates_shard_specs() {
        let pool = DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64))
            .unwrap();
        // count_arg must point at an Imm argument.
        let mut r = base_request(Affinity::any());
        r.buffers = vec![MapBuf { bytes: vec![0u8; 32], map_type: MapType::Tofrom }];
        r.args = vec![KernelArg::Buf(0)];
        r.shard = Some(ShardSpec { partitioned: vec![0], elem_bytes: 4, count_arg: 0, elems: 8 });
        assert!(pool.submit(r).is_err());
        // Partitioned buffer length must equal elems * elem_bytes.
        let mut r = base_request(Affinity::any());
        r.buffers = vec![MapBuf { bytes: vec![0u8; 30], map_type: MapType::Tofrom }];
        r.args = vec![KernelArg::Buf(0), KernelArg::Imm(8)];
        r.shard = Some(ShardSpec { partitioned: vec![0], elem_bytes: 4, count_arg: 1, elems: 8 });
        assert!(pool.submit(r).is_err());
        assert_eq!(pool.metrics().submitted, 0);
    }

    /// Occupy every pool worker with a lease that blocks until released;
    /// returns one release sender per device id (index = device id).
    fn block_all_workers(pool: &DevicePool) -> Vec<mpsc::Sender<()>> {
        let n = pool.device_count();
        let (started_tx, started_rx) = mpsc::channel::<(usize, mpsc::Sender<()>)>();
        for _ in 0..n {
            let started = started_tx.clone();
            pool.run_on(Affinity::any(), move |lease| {
                let (release_tx, release_rx) = mpsc::channel::<()>();
                started.send((lease.id, release_tx)).unwrap();
                let _ = release_rx.recv();
            })
            .unwrap();
        }
        let mut releases: Vec<Option<mpsc::Sender<()>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (id, tx) = started_rx
                .recv_timeout(Duration::from_secs(10))
                .expect("every worker must claim one blocking lease");
            releases[id] = Some(tx);
        }
        releases.into_iter().map(|r| r.expect("one lease per device")).collect()
    }

    /// Tentpole regression: quarantining a device re-plans its
    /// still-queued pinned shard jobs and rebalances the reservation
    /// counters in the same sweep.
    #[test]
    fn quarantine_replans_queued_pinned_jobs() {
        use crate::sched::workload::scale_request;
        let pool = DevicePool::new(
            &PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 3).with_watchdog(false),
        )
        .unwrap();
        let releases = block_all_workers(&pool);
        // A shard-style job pinned to device 0, queued while its worker
        // is busy — exactly the "reserved device stalls with the shard
        // still queued" shape.
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        let (tx, rx) = mpsc::channel();
        pool.try_enqueue_bulk(vec![Job::Offload(make_offload_job(req, tx, true, Some(0), None, 0, clock::now()))])
            .unwrap_or_else(|_| panic!("queue has room"));
        assert_eq!(pool.shared.reserved[0].load(Ordering::Relaxed), 1);

        quarantine_and_replan(&pool.shared, 0);
        // Devices 1/2 are busy (blocked leases), so the job cannot be
        // re-pinned — it must drop into DRR visibility with device 0's
        // reservation released.
        assert_eq!(pool.shared.reserved[0].load(Ordering::Relaxed), 0);
        let m = pool.metrics();
        assert_eq!(m.replans, 1);
        assert_eq!(m.replanned_jobs, 1);
        assert_eq!(m.devices[0].health, HealthState::Quarantined);

        // Release the healthy workers: one of them claims the unpinned
        // job; the quarantined device 0 must not (its worker stays
        // gated).
        for r in &releases[1..] {
            let _ = r.send(());
        }
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("re-planned job must complete")
            .expect("scale kernel runs");
        assert_ne!(resp.device_id, 0, "quarantined device must claim nothing");
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
        for d in pool.metrics().devices {
            assert_eq!(d.reserved, 0, "no reservation may leak (device {})", d.id);
        }
        let _ = releases[0].send(());
    }

    /// PR-4 hardening regression (previously untested): `enqueue_bulk`
    /// strips stale shard device pins after a backpressure wait — the
    /// idle sample that chose the pins predates the wait. The job must
    /// come out DRR-visible (claimable by a different device) with no
    /// reservation recorded for the stale target.
    #[test]
    fn enqueue_bulk_strips_stale_pins_after_backpressure_wait() {
        use crate::sched::workload::scale_request;
        let pool = DevicePool::new(
            &PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 2)
                .with_queue_cap(1)
                .with_watchdog(false),
        )
        .unwrap();
        let releases = block_all_workers(&pool);
        // Fill the 1-slot queue with an unpinned filler only device 0
        // will get to claim (we release only device 0 below).
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let (filler, _) = scale_request(&data, Affinity::any(), OptLevel::O2);
        let (ftx, frx) = mpsc::channel();
        pool.try_enqueue_bulk(vec![Job::Offload(make_offload_job(filler, ftx, false, None, None, 0, clock::now()))])
            .unwrap_or_else(|_| panic!("queue has room for the filler"));

        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        let (tx, rx) = mpsc::channel();
        std::thread::scope(|scope| {
            scope.spawn(|| {
                // Pinned to device 1 — whose worker stays blocked for the
                // whole test. Stripping the stale pin is the only way
                // this job can ever run.
                pool.enqueue_bulk(vec![Job::Offload(make_offload_job(
                    req,
                    tx,
                    true,
                    Some(1),
                    None,
                    0,
                    clock::now(),
                ))])
                .expect("bulk enqueue succeeds after the wait");
            });
            // Let the spawned enqueue reach the backpressure wait, then
            // free device 0 so it drains the filler and opens a slot.
            clock::sleep(Duration::from_millis(100));
            assert_eq!(pool.metrics().queue_depth, 1, "enqueue must be blocked on the cap");
            releases[0].send(()).unwrap();
            let resp = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("job with a stripped pin must be claimable by device 0")
                .expect("scale kernel runs");
            assert_eq!(
                resp.device_id, 0,
                "device 1 never ran: only a stripped pin lets device 0 serve the job"
            );
            assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
            assert_eq!(
                pool.metrics().devices[1].reserved,
                0,
                "a stripped pin must leave no reservation behind"
            );
            let _ = frx.recv_timeout(Duration::from_secs(10));
            releases[1].send(()).unwrap();
        });
    }
}
