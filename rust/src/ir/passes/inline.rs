//! Inliner.
//!
//! Inlines calls to module-local functions that are marked
//! `alwaysinline`, or that are small (≤ [`SMALL_THRESHOLD`] instructions)
//! and not marked `noinline`. Only *single-exit* bodies are inlined — the
//! runtime library is authored to satisfy this (a trailing `return` and no
//! early returns), which mirrors how the real device runtime's hot leaves
//! are structured for inlining.

use crate::ir::inst::{Inst, Stmt};
use crate::ir::module::{Function, InlineHint, Module};
use crate::ir::types::{Operand, Reg};
use std::collections::BTreeMap;

/// Functions at or below this instruction count inline by default.
pub const SMALL_THRESHOLD: usize = 24;

/// Maximum inlining rounds (bounds growth on call chains).
const MAX_ROUNDS: usize = 8;

/// Run the pass; returns the number of call sites inlined.
pub fn run(m: &mut Module) -> usize {
    let mut total = 0;
    for _ in 0..MAX_ROUNDS {
        let inlined = run_round(m);
        total += inlined;
        if inlined == 0 {
            break;
        }
    }
    total
}

fn run_round(m: &mut Module) -> usize {
    // Snapshot inlinable callees.
    let candidates: BTreeMap<String, Function> = m
        .funcs
        .iter()
        .filter(|(_, f)| is_inlinable(f))
        .map(|(n, f)| (n.clone(), f.clone()))
        .collect();
    if candidates.is_empty() {
        return 0;
    }
    let mut inlined = 0;
    let names: Vec<String> = m.funcs.keys().cloned().collect();
    for name in names {
        let mut f = m.funcs.remove(&name).unwrap();
        // Never inline a function into itself.
        let body = std::mem::take(&mut f.body);
        f.body = splice_block(body, &mut f, &candidates, &name, &mut inlined);
        m.funcs.insert(name, f);
    }
    inlined
}

/// A function is inlinable when single-exit and hinted/small.
pub fn is_inlinable(f: &Function) -> bool {
    if f.is_kernel || f.inline == InlineHint::Never {
        return false;
    }
    let wanted = f.inline == InlineHint::Always || f.inst_count() <= SMALL_THRESHOLD;
    wanted && single_exit(f)
}

/// Single exit: exactly one `Return`, and it is the last top-level stmt.
fn single_exit(f: &Function) -> bool {
    let mut returns = 0usize;
    for s in &f.body {
        count_returns(s, &mut returns);
    }
    returns == 1 && matches!(f.body.last(), Some(Stmt::Return(_)))
}

fn count_returns(s: &Stmt, n: &mut usize) {
    match s {
        Stmt::Return(_) => *n += 1,
        Stmt::If { then_, else_, .. } => {
            for t in then_ {
                count_returns(t, n);
            }
            for e in else_ {
                count_returns(e, n);
            }
        }
        Stmt::Loop { body } => {
            for b in body {
                count_returns(b, n);
            }
        }
        _ => {}
    }
}

fn splice_block(
    body: Vec<Stmt>,
    caller: &mut Function,
    candidates: &BTreeMap<String, Function>,
    caller_name: &str,
    inlined: &mut usize,
) -> Vec<Stmt> {
    let mut out = Vec::with_capacity(body.len());
    for s in body {
        match s {
            Stmt::Inst(Inst::Call { dst, callee, args })
                if callee != caller_name && candidates.contains_key(&callee) =>
            {
                let callee_fn = &candidates[&callee];
                inline_call(&mut out, caller, callee_fn, dst, &args);
                *inlined += 1;
            }
            Stmt::Inst(i) => out.push(Stmt::Inst(i)),
            Stmt::If { cond, then_, else_ } => {
                let t = splice_block(then_, caller, candidates, caller_name, inlined);
                let e = splice_block(else_, caller, candidates, caller_name, inlined);
                out.push(Stmt::If { cond, then_: t, else_: e });
            }
            Stmt::Loop { body } => {
                let b = splice_block(body, caller, candidates, caller_name, inlined);
                out.push(Stmt::Loop { body: b });
            }
            other => out.push(other),
        }
    }
    out
}

/// Splice one call site: bind params with copies, remap callee registers
/// above the caller's register space, rewrite the trailing return into an
/// assignment of the call's destination.
fn inline_call(
    out: &mut Vec<Stmt>,
    caller: &mut Function,
    callee: &Function,
    dst: Option<Reg>,
    args: &[Operand],
) {
    let offset = caller.regs.len() as u32;
    caller.regs.extend_from_slice(&callee.regs);
    let remap = |r: Reg| Reg(r.0 + offset);

    for (i, a) in args.iter().enumerate() {
        out.push(Stmt::Inst(Inst::Copy { dst: Reg(offset + i as u32), src: *a }));
    }

    let mut body = callee.body.clone();
    let trailing = body.pop(); // the single Return
    remap_block(&mut body, offset);
    out.extend(body);

    match trailing {
        Some(Stmt::Return(Some(mut v))) => {
            remap_operand(&mut v, offset);
            if let Some(d) = dst {
                out.push(Stmt::Inst(Inst::Copy { dst: d, src: v }));
            }
        }
        Some(Stmt::Return(None)) => {}
        other => unreachable!("single-exit invariant violated: {other:?}"),
    }
    let _ = remap; // silence if optimized differently
}

fn remap_block(body: &mut [Stmt], offset: u32) {
    for s in body {
        remap_stmt(s, offset);
    }
}

fn remap_stmt(s: &mut Stmt, offset: u32) {
    match s {
        Stmt::Inst(i) => {
            i.map_dst(|r| Reg(r.0 + offset));
            i.map_operands(|o| remap_operand(o, offset));
        }
        Stmt::If { cond, then_, else_ } => {
            remap_operand(cond, offset);
            remap_block(then_, offset);
            remap_block(else_, offset);
        }
        Stmt::Loop { body } => remap_block(body, offset),
        Stmt::Return(Some(v)) => remap_operand(v, offset),
        _ => {}
    }
}

fn remap_operand(o: &mut Operand, offset: u32) {
    if let Operand::Reg(r) = o {
        *r = Reg(r.0 + offset);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::FunctionBuilder;
    use crate::ir::types::Type;
    use crate::ir::verify::verify_module;

    fn add_one_lib(hint: InlineHint) -> Function {
        let mut f = FunctionBuilder::new("add_one", &[Type::I32], Some(Type::I32));
        let p = f.param(0);
        let v = f.add(p, Operand::i32(1));
        f.ret_val(v);
        f.inline_hint(hint).build()
    }

    fn caller_of(callee: &str) -> Function {
        let mut k = FunctionBuilder::new("main", &[], Some(Type::I32));
        let r = k.call(callee, &[Operand::i32(1)], Type::I32);
        let r2 = k.call(callee, &[Operand::Reg(r)], Type::I32);
        k.ret_val(r2);
        k.build()
    }

    #[test]
    fn inlines_both_call_sites() {
        let mut m = Module::new("t");
        m.add_func(add_one_lib(InlineHint::Always));
        m.add_func(caller_of("add_one"));
        let n = run(&mut m);
        assert_eq!(n, 2);
        verify_module(&m).unwrap();
        assert!(!m.funcs["main"].callees().contains("add_one"));
    }

    #[test]
    fn noinline_is_respected() {
        let mut m = Module::new("t");
        m.add_func(add_one_lib(InlineHint::Never));
        m.add_func(caller_of("add_one"));
        assert_eq!(run(&mut m), 0);
    }

    #[test]
    fn multi_exit_function_is_not_inlined() {
        let mut m = Module::new("t");
        let mut f = FunctionBuilder::new("maybe", &[Type::I1], Some(Type::I32));
        let p = f.param(0);
        f.if_(p, |b| b.ret_val(Operand::i32(1)));
        f.ret_val(Operand::i32(0));
        m.add_func(f.inline_hint(InlineHint::Always).build());
        m.add_func(caller_of("maybe"));
        assert_eq!(run(&mut m), 0);
    }

    #[test]
    fn recursion_is_not_inlined_into_itself() {
        let mut m = Module::new("t");
        let mut f = FunctionBuilder::new("rec", &[Type::I32], Some(Type::I32));
        let p = f.param(0);
        let r = f.call("rec", &[Operand::Reg(p)], Type::I32);
        f.ret_val(r);
        m.add_func(f.inline_hint(InlineHint::Always).build());
        // One round may try; it must not loop forever or self-splice.
        let n = run(&mut m);
        assert_eq!(n, 0);
        verify_module(&m).unwrap();
    }

    #[test]
    fn chained_inlining_reaches_fixpoint() {
        // a calls b calls c; all alwaysinline.
        let mut m = Module::new("t");
        let mut c = FunctionBuilder::new("c", &[Type::I32], Some(Type::I32));
        let p = c.param(0);
        let v = c.mul(p, Operand::i32(3));
        c.ret_val(v);
        m.add_func(c.inline_hint(InlineHint::Always).build());

        let mut b = FunctionBuilder::new("b", &[Type::I32], Some(Type::I32));
        let p = b.param(0);
        let v = b.call("c", &[Operand::Reg(p)], Type::I32);
        b.ret_val(v);
        m.add_func(b.inline_hint(InlineHint::Always).build());

        let mut a = FunctionBuilder::new("a", &[Type::I32], Some(Type::I32));
        let p = a.param(0);
        let v = a.call("b", &[Operand::Reg(p)], Type::I32);
        a.ret_val(v);
        m.add_func(a.build());

        run(&mut m);
        verify_module(&m).unwrap();
        assert!(!m.funcs["a"].callees().contains("b"));
        assert!(!m.funcs["a"].callees().contains("c"));
    }

    #[test]
    fn kernel_entry_is_never_inlined_away() {
        let mut m = Module::new("t");
        let mut k = FunctionBuilder::new("kern", &[], None).kernel();
        k.ret();
        m.add_func(k.inline_hint(InlineHint::Always).build());
        let mut main = FunctionBuilder::new("main", &[], None);
        main.call_void("kern", &[]);
        main.ret();
        m.add_func(main.build());
        assert_eq!(run(&mut m), 0);
    }
}
