"""AOT lowering: JAX payloads → HLO **text** artifacts + manifest.

Run once by `make artifacts`; the Rust binary is self-contained after.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
published `xla` crate's backend) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_str(shape) -> str:
    return "x".join(str(d) for d in shape)


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower payloads to HLO text")
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest_lines = []
    for name, (fn, in_shapes, out_shape) in sorted(model.PAYLOADS.items()):
        specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in in_shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out_dir / fname).write_text(text)
        ins = ", ".join(f'"{shape_str(s)}"' for s in in_shapes)
        manifest_lines += [
            f"[{name}]",
            f'file = "{fname}"',
            f"inputs = [{ins}]",
            f'output = "{shape_str(out_shape)}"',
            "",
        ]
        print(f"wrote {fname} ({len(text)} chars)")

    (out_dir / "manifest.toml").write_text("\n".join(manifest_lines))
    print(f"wrote manifest.toml with {len(model.PAYLOADS)} payloads")


if __name__ == "__main__":
    main()
