//! The PJRT service thread.
//!
//! Owns the CPU `PjRtClient` and all compiled executables. HLO **text**
//! is the interchange format: jax ≥ 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use super::artifact::ArtifactSpec;
use crate::util::Error;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;

/// A request to the service thread.
enum Request {
    /// Compile an artifact (idempotent per name).
    Load { spec: ArtifactSpec, reply: mpsc::Sender<Result<(), String>> },
    /// Execute a loaded artifact on f32 inputs.
    Exec { name: String, inputs: Vec<Vec<f32>>, reply: mpsc::Sender<Result<Vec<f32>, String>> },
    /// Stop the thread.
    Shutdown,
}

/// Cloneable, thread-safe handle to the PJRT service.
#[derive(Clone)]
pub struct PjrtService {
    tx: mpsc::Sender<Request>,
    /// Keep the join handle alive for the process lifetime.
    _thread: Arc<ServiceThread>,
}

struct ServiceThread {
    tx: mpsc::Sender<Request>,
    handle: std::sync::Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for ServiceThread {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl PjrtService {
    /// Start the service (one PJRT CPU client).
    pub fn start() -> Result<Self, Error> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<String, String>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || service_main(rx, ready_tx))
            .map_err(|e| Error::Pjrt(format!("cannot spawn pjrt thread: {e}")))?;
        match ready_rx.recv() {
            Ok(Ok(platform)) => {
                log::info!("pjrt service ready on {platform}");
            }
            Ok(Err(e)) => return Err(Error::Pjrt(e)),
            Err(_) => return Err(Error::Pjrt("pjrt service died during startup".into())),
        }
        Ok(PjrtService {
            tx: tx.clone(),
            _thread: Arc::new(ServiceThread { tx, handle: std::sync::Mutex::new(Some(handle)) }),
        })
    }

    /// Compile an artifact (no-op if already loaded under that name).
    pub fn load(&self, spec: &ArtifactSpec) -> Result<(), Error> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Load { spec: spec.clone(), reply })
            .map_err(|_| Error::Pjrt("pjrt service gone".into()))?;
        rx.recv().map_err(|_| Error::Pjrt("pjrt service gone".into()))?.map_err(Error::Pjrt)
    }

    /// Execute a loaded artifact.
    pub fn execute(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<f32>, Error> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Exec { name: name.to_string(), inputs, reply })
            .map_err(|_| Error::Pjrt("pjrt service gone".into()))?;
        rx.recv().map_err(|_| Error::Pjrt("pjrt service gone".into()))?.map_err(Error::Pjrt)
    }
}

struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

fn service_main(rx: mpsc::Receiver<Request>, ready: mpsc::Sender<Result<String, String>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(c.platform_name()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(format!("PjRtClient::cpu failed: {e}")));
            return;
        }
    };
    let mut loaded: HashMap<String, Loaded> = HashMap::new();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Load { spec, reply } => {
                let r = if loaded.contains_key(&spec.name) {
                    Ok(())
                } else {
                    compile(&client, &spec).map(|exe| {
                        loaded.insert(spec.name.clone(), Loaded { exe, spec });
                    })
                };
                let _ = reply.send(r);
            }
            Request::Exec { name, inputs, reply } => {
                let r = match loaded.get(&name) {
                    None => Err(format!("payload `{name}` not loaded")),
                    Some(l) => execute(l, inputs),
                };
                let _ = reply.send(r);
            }
        }
    }
}

fn compile(client: &xla::PjRtClient, spec: &ArtifactSpec) -> Result<xla::PjRtLoadedExecutable, String> {
    let path = spec
        .file
        .to_str()
        .ok_or_else(|| format!("non-utf8 artifact path {:?}", spec.file))?;
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| format!("parse {path}: {e}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| format!("compile {}: {e}", spec.name))
}

fn execute(l: &Loaded, inputs: Vec<Vec<f32>>) -> Result<Vec<f32>, String> {
    if inputs.len() != l.spec.inputs.len() {
        return Err(format!(
            "payload `{}`: expected {} inputs, got {}",
            l.spec.name,
            l.spec.inputs.len(),
            inputs.len()
        ));
    }
    let mut literals = Vec::with_capacity(inputs.len());
    for (i, data) in inputs.iter().enumerate() {
        let want = l.spec.input_elems(i);
        if data.len() != want {
            return Err(format!(
                "payload `{}` input {i}: expected {want} elems, got {}",
                l.spec.name,
                data.len()
            ));
        }
        let shape: Vec<i64> = l.spec.inputs[i].iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(data)
            .reshape(&shape)
            .map_err(|e| format!("reshape input {i}: {e}"))?;
        literals.push(lit);
    }
    let result = l
        .exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| format!("execute {}: {e}", l.spec.name))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| format!("fetch result: {e}"))?;
    // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
    let out = lit.to_tuple1().map_err(|e| format!("untuple: {e}"))?;
    let v = out.to_vec::<f32>().map_err(|e| format!("to_vec: {e}"))?;
    if v.len() != l.spec.output_elems() {
        return Err(format!(
            "payload `{}`: output has {} elems, manifest says {}",
            l.spec.name,
            v.len(),
            l.spec.output_elems()
        ));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// HLO text for fn(x, y) = (x·y + 2,) over f32[2,2] — captured from
    /// the reference round-trip (gen_hlo.py). Lets the PJRT path be
    /// tested without Python in the loop.
    const MATMUL_HLO: &str = r#"HloModule xla_computation_fn, entry_computation_layout={(f32[2,2]{1,0}, f32[2,2]{1,0})->(f32[2,2]{1,0})}

ENTRY main.8 {
  Arg_0.1 = f32[2,2]{1,0} parameter(0)
  Arg_1.2 = f32[2,2]{1,0} parameter(1)
  dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  constant.4 = f32[] constant(2)
  broadcast.5 = f32[2,2]{1,0} broadcast(constant.4), dimensions={}
  add.6 = f32[2,2]{1,0} add(dot.3, broadcast.5)
  ROOT tuple.7 = (f32[2,2]{1,0}) tuple(add.6)
}
"#;

    fn write_artifact() -> (tempdir::TempDirGuard, ArtifactSpec) {
        let dir = tempdir::guard("pjrt_test");
        let path = dir.path.join("matmul.hlo.txt");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(MATMUL_HLO.as_bytes()).unwrap();
        let spec = ArtifactSpec {
            name: "matmul".into(),
            file: path,
            inputs: vec![vec![2, 2], vec![2, 2]],
            output: vec![2, 2],
        };
        (dir, spec)
    }

    /// Minimal tempdir helper (no tempfile crate offline).
    mod tempdir {
        pub struct TempDirGuard {
            pub path: std::path::PathBuf,
        }
        impl Drop for TempDirGuard {
            fn drop(&mut self) {
                let _ = std::fs::remove_dir_all(&self.path);
            }
        }
        pub fn guard(tag: &str) -> TempDirGuard {
            let path = std::env::temp_dir().join(format!(
                "omprt_{tag}_{}_{:?}",
                std::process::id(),
                std::thread::current().id()
            ));
            std::fs::create_dir_all(&path).unwrap();
            TempDirGuard { path }
        }
    }

    #[test]
    fn service_loads_and_executes_hlo_text() {
        let (_dir, spec) = write_artifact();
        let svc = PjrtService::start().unwrap();
        svc.load(&spec).unwrap();
        // loading twice is fine
        svc.load(&spec).unwrap();
        let out = svc
            .execute("matmul", vec![vec![1., 2., 3., 4.], vec![1., 1., 1., 1.]])
            .unwrap();
        assert_eq!(out, vec![5., 5., 9., 9.]);
    }

    #[test]
    fn execute_checks_input_arity_and_shape() {
        let (_dir, spec) = write_artifact();
        let svc = PjrtService::start().unwrap();
        svc.load(&spec).unwrap();
        assert!(svc.execute("matmul", vec![vec![1., 2., 3., 4.]]).is_err());
        assert!(svc.execute("matmul", vec![vec![1.], vec![1.]]).is_err());
        assert!(svc.execute("unknown", vec![]).is_err());
    }

    #[test]
    fn service_is_usable_from_many_threads() {
        let (_dir, spec) = write_artifact();
        let svc = PjrtService::start().unwrap();
        svc.load(&spec).unwrap();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let svc = svc.clone();
                s.spawn(move || {
                    for _ in 0..10 {
                        let out = svc
                            .execute("matmul", vec![vec![1., 0., 0., 1.], vec![1., 2., 3., 4.]])
                            .unwrap();
                        assert_eq!(out, vec![3., 4., 5., 6.]);
                    }
                });
            }
        });
    }
}
