//! The device pool: N offload devices fed by one async submission queue.
//!
//! Clients [`DevicePool::submit`] an [`OffloadRequest`] and get an
//! [`OffloadHandle`] back immediately; the launch happens on one of the
//! pool's worker threads. See the module docs of [`crate::sched`] for the
//! placement policy.

use super::cache::{CacheStats, ImageCache};
use crate::config::Config;
use crate::coordinator::profiler::{Profiler, RegionReport};
use crate::devrt::RuntimeKind;
use crate::hostrt::{MapType, OffloadDevice};
use crate::ir::passes::OptLevel;
use crate::ir::Module;
use crate::sim::{Arch, LaunchConfig, LaunchStats};
use crate::util::Error;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Which devices may serve a request. `None` fields match anything.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Affinity {
    /// Restrict to one architecture.
    pub arch: Option<Arch>,
    /// Restrict to one runtime build.
    pub kind: Option<RuntimeKind>,
}

impl Affinity {
    /// Runs anywhere.
    pub fn any() -> Affinity {
        Affinity::default()
    }

    /// Pin to an architecture.
    pub fn on_arch(arch: Arch) -> Affinity {
        Affinity { arch: Some(arch), kind: None }
    }

    /// Pin to a runtime kind.
    pub fn on_kind(kind: RuntimeKind) -> Affinity {
        Affinity { arch: None, kind: Some(kind) }
    }

    /// Does a device with `(arch, kind)` satisfy this constraint?
    pub fn matches(&self, arch: Arch, kind: RuntimeKind) -> bool {
        self.arch.map_or(true, |a| a == arch) && self.kind.map_or(true, |k| k == kind)
    }
}

/// One host buffer mapped for the duration of a pooled offload.
#[derive(Debug, Clone)]
pub struct MapBuf {
    /// Host bytes (copied to the device for `To`/`Tofrom`).
    pub bytes: Vec<u8>,
    /// Mapping semantics.
    pub map_type: MapType,
}

impl MapBuf {
    /// Map an f32 slice.
    pub fn f32(data: &[f32], map_type: MapType) -> MapBuf {
        MapBuf { bytes: f32_to_bytes(data), map_type }
    }
}

/// f32 slice → little-endian bytes.
pub fn f32_to_bytes(data: &[f32]) -> Vec<u8> {
    data.iter().flat_map(|v| v.to_le_bytes()).collect()
}

/// Little-endian bytes → f32 vector.
pub fn bytes_to_f32(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// A kernel argument: the device address of a mapped buffer, or an
/// immediate scalar.
#[derive(Debug, Clone, Copy)]
pub enum KernelArg {
    /// Address of `buffers[i]` after mapping.
    Buf(usize),
    /// Immediate 64-bit value.
    Imm(u64),
}

/// What a client submits to the pool.
pub struct OffloadRequest {
    /// The application module (kernels + globals).
    pub module: Module,
    /// Kernel entry point to launch.
    pub kernel: String,
    /// Profiler region name (aggregated in the pool report).
    pub region: String,
    /// Launch geometry.
    pub cfg: LaunchConfig,
    /// Optimization level for `prepare` (part of the cache key).
    pub opt: OptLevel,
    /// Host buffers to map.
    pub buffers: Vec<MapBuf>,
    /// Kernel arguments in order.
    pub args: Vec<KernelArg>,
    /// Placement constraint.
    pub affinity: Affinity,
}

/// What the pool hands back when a request completes.
#[derive(Debug)]
pub struct OffloadResponse {
    /// Pool-local id of the device that ran the launch.
    pub device_id: usize,
    /// Its architecture.
    pub arch: Arch,
    /// Its runtime build.
    pub kind: RuntimeKind,
    /// Launch counters.
    pub stats: LaunchStats,
    /// Whether the kernel image came out of the cache.
    pub cache_hit: bool,
    /// Time the request sat in the queue before a worker picked it up.
    pub queue_wait: Duration,
    /// Post-launch contents of each `From`/`Tofrom` buffer (`None` for
    /// `To`/`Alloc` buffers).
    pub buffers: Vec<Option<Vec<u8>>>,
}

/// Future side of a submission; resolves when a worker finishes the
/// request (or the pool shuts down first).
pub struct OffloadHandle {
    rx: mpsc::Receiver<Result<OffloadResponse, Error>>,
}

impl OffloadHandle {
    /// Block until the request completes.
    pub fn wait(self) -> Result<OffloadResponse, Error> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::Sched("pool dropped before the request completed".into())),
        }
    }

    /// Non-blocking poll; `None` while the request is still in flight.
    pub fn try_wait(&self) -> Option<Result<OffloadResponse, Error>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(Error::Sched("pool dropped before the request completed".into())))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Pool configuration
// ---------------------------------------------------------------------------

/// One device of the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceSpec {
    /// Runtime build.
    pub kind: RuntimeKind,
    /// Architecture.
    pub arch: Arch,
}

impl DeviceSpec {
    /// Parse `"<kind>:<arch>"`, e.g. `"portable:nvptx64"`.
    pub fn parse(s: &str) -> Option<DeviceSpec> {
        let (k, a) = s.split_once(':')?;
        Some(DeviceSpec { kind: RuntimeKind::parse(k.trim())?, arch: Arch::parse(a.trim())? })
    }
}

impl std::fmt::Display for DeviceSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.kind, self.arch)
    }
}

/// Pool construction parameters (the `[pool]` config table).
#[derive(Debug, Clone, PartialEq)]
pub struct PoolConfig {
    /// Devices, in pool-id order.
    pub devices: Vec<DeviceSpec>,
    /// Default optimization level for requests (callers still set their
    /// own per-request `opt`; the demo and bench use this).
    pub default_opt: OptLevel,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig::mixed4()
    }
}

impl PoolConfig {
    /// The canonical 4-device mixed pool: both architectures under both
    /// runtime builds.
    pub fn mixed4() -> PoolConfig {
        PoolConfig {
            devices: vec![
                DeviceSpec { kind: RuntimeKind::Portable, arch: Arch::Nvptx64 },
                DeviceSpec { kind: RuntimeKind::Portable, arch: Arch::Amdgcn },
                DeviceSpec { kind: RuntimeKind::Legacy, arch: Arch::Nvptx64 },
                DeviceSpec { kind: RuntimeKind::Legacy, arch: Arch::Amdgcn },
            ],
            default_opt: OptLevel::O2,
        }
    }

    /// A single-device pool (baseline for the throughput bench).
    pub fn single(kind: RuntimeKind, arch: Arch) -> PoolConfig {
        PoolConfig { devices: vec![DeviceSpec { kind, arch }], default_opt: OptLevel::O2 }
    }

    /// Read the `[pool]` section of a config document:
    ///
    /// ```text
    /// [pool]
    /// devices = ["portable:nvptx64", "legacy:amdgcn"]
    /// opt = "O2"
    /// ```
    ///
    /// Missing section or keys fall back to [`PoolConfig::mixed4`].
    pub fn from_config(cfg: &Config) -> Result<PoolConfig, Error> {
        let mut out = PoolConfig::mixed4();
        let Some(sec) = cfg.section("pool") else {
            return Ok(out);
        };
        if let Some(list) = sec.get("devices").and_then(|v| v.as_str_list()) {
            let mut devices = vec![];
            for s in list {
                let spec = DeviceSpec::parse(s).ok_or_else(|| {
                    Error::Config(format!(
                        "[pool] bad device `{s}` (want \"<legacy|portable>:<nvptx64|amdgcn>\")"
                    ))
                })?;
                devices.push(spec);
            }
            if devices.is_empty() {
                return Err(Error::Config("[pool] devices list is empty".into()));
            }
            out.devices = devices;
        }
        if let Some(s) = sec.get("opt").and_then(|v| v.as_str()) {
            out.default_opt = OptLevel::parse(s)
                .ok_or_else(|| Error::Config(format!("[pool] bad opt `{s}` (want O0|O2)")))?;
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

struct Job {
    req: OffloadRequest,
    reply: mpsc::Sender<Result<OffloadResponse, Error>>,
    enqueued: Instant,
}

/// Per-device state shared with the device's worker thread.
struct DeviceSlot {
    id: usize,
    spec: DeviceSpec,
    device: Arc<OffloadDevice>,
    cache: ImageCache,
    profiler: Profiler,
    inflight: AtomicUsize,
    completed: AtomicU64,
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
    slots: Vec<DeviceSlot>,
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    started: Instant,
}

/// A pool of offload devices with per-device worker threads.
pub struct DevicePool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl DevicePool {
    /// Build the devices and start one worker thread per device.
    pub fn new(config: &PoolConfig) -> Result<DevicePool, Error> {
        if config.devices.is_empty() {
            return Err(Error::Sched("pool needs at least one device".into()));
        }
        let slots: Vec<DeviceSlot> = config
            .devices
            .iter()
            .enumerate()
            .map(|(id, spec)| DeviceSlot {
                id,
                spec: *spec,
                device: Arc::new(OffloadDevice::new(spec.kind, spec.arch)),
                cache: ImageCache::new(),
                profiler: Profiler::new(),
                inflight: AtomicUsize::new(0),
                completed: AtomicU64::new(0),
            })
            .collect();
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            slots,
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            started: Instant::now(),
        });
        let mut workers = vec![];
        for id in 0..config.devices.len() {
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("pool-dev{id}"))
                .spawn(move || worker_loop(&shared, id))
                .map_err(|e| Error::Sched(format!("cannot spawn pool worker: {e}")))?;
            workers.push(handle);
        }
        Ok(DevicePool { shared, workers })
    }

    /// Number of devices.
    pub fn device_count(&self) -> usize {
        self.shared.slots.len()
    }

    /// Device specs in pool-id order.
    pub fn specs(&self) -> Vec<DeviceSpec> {
        self.shared.slots.iter().map(|s| s.spec).collect()
    }

    /// Submit a request; returns a handle resolving to the response.
    ///
    /// Fails fast (without enqueueing) when the request is malformed or
    /// its affinity matches no device in the pool.
    pub fn submit(&self, req: OffloadRequest) -> Result<OffloadHandle, Error> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Sched("pool is shut down".into()));
        }
        if req.kernel.is_empty() {
            return Err(Error::Sched("request has no kernel name".into()));
        }
        for a in &req.args {
            if let KernelArg::Buf(i) = a {
                if *i >= req.buffers.len() {
                    return Err(Error::Sched(format!(
                        "arg references buffer {i} but only {} buffers are mapped",
                        req.buffers.len()
                    )));
                }
            }
        }
        if !self
            .shared
            .slots
            .iter()
            .any(|s| req.affinity.matches(s.spec.arch, s.spec.kind))
        {
            return Err(Error::Sched(format!(
                "affinity {:?} matches no device in the pool ({:?})",
                req.affinity,
                self.specs().iter().map(|s| s.to_string()).collect::<Vec<_>>()
            )));
        }
        let (reply, rx) = mpsc::channel();
        // Count before the job becomes visible so `submitted` never lags
        // behind `completed` in a metrics snapshot.
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Job { req, reply, enqueued: Instant::now() });
        }
        // notify_all: the job may be eligible only for a subset of the
        // sleeping workers, and notify_one could wake the wrong one.
        self.shared.cv.notify_all();
        Ok(OffloadHandle { rx })
    }

    /// Snapshot of queue/throughput/cache metrics.
    pub fn metrics(&self) -> PoolMetrics {
        let queue_depth = self.shared.queue.lock().unwrap().len();
        let devices: Vec<DeviceMetrics> = self
            .shared
            .slots
            .iter()
            .map(|s| DeviceMetrics {
                id: s.id,
                kind: s.spec.kind,
                arch: s.spec.arch,
                inflight: s.inflight.load(Ordering::Relaxed),
                completed: s.completed.load(Ordering::Relaxed),
                cache: s.cache.stats(),
                cached_images: s.cache.len(),
            })
            .collect();
        PoolMetrics {
            queue_depth,
            submitted: self.shared.submitted.load(Ordering::Relaxed),
            completed: self.shared.completed.load(Ordering::Relaxed),
            failed: self.shared.failed.load(Ordering::Relaxed),
            uptime: self.shared.started.elapsed(),
            devices,
        }
    }

    /// Per-device profiler reports, in pool-id order.
    pub fn profiler_reports(&self) -> Vec<(DeviceSpec, Vec<RegionReport>)> {
        self.shared
            .slots
            .iter()
            .map(|s| (s.spec, s.profiler.report()))
            .collect()
    }

    /// Block until every submitted request has completed or failed.
    /// Intended for tests/benches that stop submitting first; new
    /// submissions during the wait extend it.
    pub fn quiesce(&self) {
        loop {
            let m = self.metrics();
            if m.queue_depth == 0 && m.completed + m.failed >= m.submitted {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        // Flip the shutdown predicate while holding the queue mutex: a
        // worker that already checked `shutdown` and is between that check
        // and `cv.wait` would otherwise miss this notify forever.
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
            self.shared.cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // Fail any requests still queued so waiting clients unblock with
        // an error instead of a channel disconnect.
        let mut q = self.shared.queue.lock().unwrap();
        while let Some(job) = q.pop_front() {
            let _ = job
                .reply
                .send(Err(Error::Sched("pool shut down before the request ran".into())));
        }
    }
}

/// Worker body: pull the oldest affinity-compatible job, run it, reply.
fn worker_loop(shared: &Shared, id: usize) {
    let slot = &shared.slots[id];
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(pos) = q
                    .iter()
                    .position(|j| j.req.affinity.matches(slot.spec.arch, slot.spec.kind))
                {
                    break q.remove(pos).expect("position is in range");
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        let queue_wait = job.enqueued.elapsed();
        slot.inflight.fetch_add(1, Ordering::Relaxed);
        let result = run_job(slot, &job.req, queue_wait);
        slot.inflight.fetch_sub(1, Ordering::Relaxed);
        match &result {
            Ok(_) => {
                slot.completed.fetch_add(1, Ordering::Relaxed);
                shared.completed.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                shared.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        // A dropped handle is fine: the work still ran.
        let _ = job.reply.send(result);
    }
}

/// Execute one request on `slot`: image from cache, map, launch, unmap.
fn run_job(
    slot: &DeviceSlot,
    req: &OffloadRequest,
    queue_wait: Duration,
) -> Result<OffloadResponse, Error> {
    let (image, cache_hit) = slot.cache.get_or_prepare(&slot.device, &req.module, req.opt)?;

    let mut dev_addrs = Vec::with_capacity(req.buffers.len());
    for b in &req.buffers {
        let addr = slot.device.gmem.alloc((b.bytes.len() as u64).max(1), 8)?;
        if matches!(b.map_type, MapType::To | MapType::Tofrom) {
            slot.device.gmem.write_bytes(addr, &b.bytes)?;
        }
        dev_addrs.push(addr);
    }

    let args: Vec<u64> = req
        .args
        .iter()
        .map(|a| match a {
            KernelArg::Buf(i) => dev_addrs[*i], // index validated at submit
            KernelArg::Imm(v) => *v,
        })
        .collect();

    let (launch, elapsed) =
        crate::util::stats::timed(|| slot.device.offload(&image, &req.kernel, &args, req.cfg));
    slot.profiler.record(&req.region, elapsed);
    let stats = launch?;

    let mut out = Vec::with_capacity(req.buffers.len());
    for (b, addr) in req.buffers.iter().zip(&dev_addrs) {
        if matches!(b.map_type, MapType::From | MapType::Tofrom) {
            let mut buf = vec![0u8; b.bytes.len()];
            slot.device.gmem.read_bytes(*addr, &mut buf)?;
            out.push(Some(buf));
        } else {
            out.push(None);
        }
    }

    Ok(OffloadResponse {
        device_id: slot.id,
        arch: slot.spec.arch,
        kind: slot.spec.kind,
        stats,
        cache_hit,
        queue_wait,
        buffers: out,
    })
}

// ---------------------------------------------------------------------------
// Metrics
// ---------------------------------------------------------------------------

/// Per-device metrics snapshot.
#[derive(Debug, Clone)]
pub struct DeviceMetrics {
    /// Pool-local device id.
    pub id: usize,
    /// Runtime build.
    pub kind: RuntimeKind,
    /// Architecture.
    pub arch: Arch,
    /// Requests currently executing (0 or 1 with one worker per device).
    pub inflight: usize,
    /// Requests completed on this device.
    pub completed: u64,
    /// Image-cache counters.
    pub cache: CacheStats,
    /// Images currently cached.
    pub cached_images: usize,
}

/// Pool-wide metrics snapshot.
#[derive(Debug, Clone)]
pub struct PoolMetrics {
    /// Jobs waiting in the submission queue.
    pub queue_depth: usize,
    /// Total requests accepted.
    pub submitted: u64,
    /// Total requests completed successfully.
    pub completed: u64,
    /// Total requests that failed.
    pub failed: u64,
    /// Time since the pool started.
    pub uptime: Duration,
    /// Per-device breakdown.
    pub devices: Vec<DeviceMetrics>,
}

impl PoolMetrics {
    /// Aggregated image-cache counters.
    pub fn cache(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for d in &self.devices {
            s.hits += d.cache.hits;
            s.misses += d.cache.misses;
        }
        s
    }

    /// Completed launches per second of pool uptime.
    pub fn throughput_per_sec(&self) -> f64 {
        let secs = self.uptime.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.completed as f64 / secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affinity_matching() {
        let any = Affinity::any();
        assert!(any.matches(Arch::Nvptx64, RuntimeKind::Legacy));
        let a = Affinity::on_arch(Arch::Amdgcn);
        assert!(a.matches(Arch::Amdgcn, RuntimeKind::Portable));
        assert!(!a.matches(Arch::Nvptx64, RuntimeKind::Portable));
        let k = Affinity::on_kind(RuntimeKind::Legacy);
        assert!(k.matches(Arch::Nvptx64, RuntimeKind::Legacy));
        assert!(!k.matches(Arch::Nvptx64, RuntimeKind::Portable));
    }

    #[test]
    fn device_spec_parses() {
        let s = DeviceSpec::parse("portable:nvptx64").unwrap();
        assert_eq!(s.kind, RuntimeKind::Portable);
        assert_eq!(s.arch, Arch::Nvptx64);
        assert_eq!(DeviceSpec::parse("legacy:amdgcn").unwrap().arch, Arch::Amdgcn);
        assert!(DeviceSpec::parse("nvptx64").is_none());
        assert!(DeviceSpec::parse("bad:nvptx64").is_none());
        assert!(DeviceSpec::parse("legacy:gfx9").is_none());
    }

    #[test]
    fn pool_config_from_config_document() {
        let cfg = Config::parse(
            "[pool]\ndevices = [\"portable:nvptx64\", \"legacy:amdgcn\"]\nopt = \"O0\"",
        )
        .unwrap();
        let pc = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(pc.devices.len(), 2);
        assert_eq!(pc.devices[1], DeviceSpec { kind: RuntimeKind::Legacy, arch: Arch::Amdgcn });
        assert_eq!(pc.default_opt, OptLevel::O0);
        // Missing section → default mixed pool.
        let pc = PoolConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(pc, PoolConfig::mixed4());
        // Bad spec errors.
        let cfg = Config::parse("[pool]\ndevices = [\"warp9:nvptx64\"]").unwrap();
        assert!(PoolConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn f32_byte_roundtrip() {
        let v = vec![0.0f32, 1.5, -2.25, f32::MAX];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&v)), v);
    }

    #[test]
    fn submit_validates_before_enqueue() {
        let pool = DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64))
            .unwrap();
        let req = |affinity| OffloadRequest {
            module: Module::new("m"),
            kernel: "k".into(),
            region: "r".into(),
            cfg: LaunchConfig::new(1, 32),
            opt: OptLevel::O2,
            buffers: vec![],
            args: vec![KernelArg::Buf(3)],
            affinity,
        };
        // Bad buffer index.
        assert!(pool.submit(req(Affinity::any())).is_err());
        // Affinity matching no pool device.
        let mut r = req(Affinity::on_arch(Arch::Amdgcn));
        r.args = vec![];
        assert!(pool.submit(r).is_err());
        assert_eq!(pool.metrics().submitted, 0);
    }
}
