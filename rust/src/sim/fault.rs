//! Deterministic fault injection for simulated devices.
//!
//! The pool's failure half — the progress watchdog, quarantine,
//! preemptive shard re-planning and bounded retry in [`crate::sched`] —
//! is only testable if a device can be made to misbehave *on demand and
//! reproducibly*. Real accelerators stall, slow down, drop launches and
//! die; the simulator never does. This module scripts those behaviors
//! per device:
//!
//! * **stall** — launches hang for a fixed duration before executing
//!   (a wedged DMA engine / driver timeout);
//! * **slow** — launches take a multiple of their real time (thermal
//!   throttling, a degraded link);
//! * **fail** — a bounded run of launches returns a transient error
//!   (ECC hiccup, spurious launch failure);
//! * **die** — every launch from the trigger on fails permanently
//!   (the device fell off the bus).
//!
//! Faults are *scripted*, not random: each is armed by a trigger — a
//! device-local launch index or elapsed time since the pool started —
//! so a test or bench provokes exactly the same failure at exactly the
//! same point every run.
//!
//! ## Spec grammar
//!
//! One fault per device, written `"<dev>=<kind>@<trigger>"`:
//!
//! ```text
//! kind    := stall:<dur>[:<window>]   # each launch in the window hangs <dur> first
//!          | slow:<factor>x[:<window>]# launches take <factor> x their real time
//!          | fail:<count>             # <count> launches fail transiently
//!          | die                      # permanent failure from the trigger on
//! trigger := launch:<n>               # n-th launch on this device (0-based)
//!          | t:<dur>                  # elapsed time since the pool started
//! dur     := <float>ms | <float>s
//! ```
//!
//! `stall`'s window defaults to one stall's worth (a single hang);
//! `slow`'s window defaults to forever. Examples:
//!
//! ```text
//! [pool]
//! faults = ["2=stall:120ms:10s@launch:40", "1=slow:8x@t:50ms",
//!           "0=fail:25@launch:40", "3=die@t:200ms"]
//! ```
//!
//! The same strings are accepted by `--fault` on `omprt pool` /
//! `omprt bench --pool` (comma-separated) and by
//! [`crate::sched::PoolConfig::with_fault_spec`].

use crate::util::clock::{Clock, WallClock};
use crate::util::Error;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// What kind of misbehavior to inject.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// Each launch inside the active window sleeps `dur` before
    /// executing. `window` bounds how long (from the first triggered
    /// launch) the degradation lasts; `None` = a single stall.
    Stall {
        /// Per-launch hang.
        dur: Duration,
        /// Degradation window measured from the first stalled launch.
        window: Option<Duration>,
    },
    /// Launches inside the window take `factor` times their real time
    /// (the extra time is slept after execution). `None` window =
    /// degraded forever.
    Slow {
        /// Slowdown multiple (> 1.0).
        factor: f64,
        /// Degradation window measured from the first slowed launch.
        window: Option<Duration>,
    },
    /// The first `count` launches at/after the trigger fail with a
    /// transient [`Error::Fault`]; later launches succeed again.
    Fail {
        /// How many consecutive launches fail.
        count: u64,
    },
    /// Every launch from the trigger on fails permanently, and probes
    /// never succeed — the device is gone.
    Die,
}

/// When the fault activates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultTrigger {
    /// The `n`-th launch on the device (0-based, counted per device).
    Launch(u64),
    /// Elapsed time since the fault was armed (pool construction).
    Elapsed(Duration),
}

/// One scripted fault: which device, what happens, when.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Pool-local device id the fault applies to.
    pub device: usize,
    /// The misbehavior.
    pub kind: FaultKind,
    /// Activation point.
    pub trigger: FaultTrigger,
}

/// Parse `"<float>ms"` / `"<float>s"` into a duration.
fn parse_dur(s: &str) -> Option<Duration> {
    let (num, scale) = if let Some(n) = s.strip_suffix("ms") {
        (n, 1e-3)
    } else if let Some(n) = s.strip_suffix('s') {
        (n, 1.0)
    } else {
        return None;
    };
    let v: f64 = num.parse().ok()?;
    (v >= 0.0 && v.is_finite()).then(|| Duration::from_secs_f64(v * scale))
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s}s")
    } else {
        // Sub-second durations echo in ms without rounding away
        // fractions: the Display string is what reports surface and
        // users copy back into `[pool] faults`, so it must roundtrip.
        let ms = s * 1e3;
        if (ms - ms.round()).abs() < 1e-9 {
            format!("{}ms", ms.round() as u64)
        } else {
            format!("{ms}ms")
        }
    }
}

impl FaultSpec {
    /// Parse one spec string (see the module docs for the grammar).
    pub fn parse(s: &str) -> Result<FaultSpec, Error> {
        let bad = |why: &str| Error::Config(format!("bad fault spec `{s}`: {why}"));
        let (dev, rest) = s.split_once('=').ok_or_else(|| bad("want `<dev>=<kind>@<trigger>`"))?;
        let device: usize =
            dev.trim().parse().map_err(|_| bad("device must be a pool-local index"))?;
        let (kind_s, trig_s) =
            rest.split_once('@').ok_or_else(|| bad("missing `@<trigger>`"))?;
        let mut kp = kind_s.trim().split(':');
        let kind = match kp.next().unwrap_or("") {
            "stall" => {
                let dur = kp.next().and_then(parse_dur).ok_or_else(|| {
                    bad("stall wants `stall:<dur>[:<window>]` with ms/s durations")
                })?;
                let window = match kp.next() {
                    Some(w) => Some(parse_dur(w).ok_or_else(|| bad("bad stall window"))?),
                    None => None,
                };
                FaultKind::Stall { dur, window }
            }
            "slow" => {
                let f = kp
                    .next()
                    .and_then(|f| f.strip_suffix('x'))
                    .and_then(|f| f.parse::<f64>().ok())
                    .filter(|f| *f > 1.0 && f.is_finite())
                    .ok_or_else(|| bad("slow wants `slow:<factor>x` with factor > 1"))?;
                let window = match kp.next() {
                    Some(w) => Some(parse_dur(w).ok_or_else(|| bad("bad slow window"))?),
                    None => None,
                };
                FaultKind::Slow { factor: f, window }
            }
            "fail" => {
                let count = kp
                    .next()
                    .and_then(|c| c.parse::<u64>().ok())
                    .filter(|c| *c > 0)
                    .ok_or_else(|| bad("fail wants `fail:<count>` with count > 0"))?;
                FaultKind::Fail { count }
            }
            "die" => FaultKind::Die,
            other => return Err(bad(&format!("unknown fault kind `{other}`"))),
        };
        if kp.next().is_some() {
            return Err(bad("trailing fields after the fault kind"));
        }
        let trigger = {
            let t = trig_s.trim();
            if let Some(n) = t.strip_prefix("launch:") {
                FaultTrigger::Launch(
                    n.parse().map_err(|_| bad("launch trigger wants an index"))?,
                )
            } else if let Some(d) = t.strip_prefix("t:") {
                FaultTrigger::Elapsed(parse_dur(d).ok_or_else(|| bad("bad time trigger"))?)
            } else {
                return Err(bad("trigger must be `launch:<n>` or `t:<dur>`"));
            }
        };
        Ok(FaultSpec { device, kind, trigger })
    }

    /// Parse a comma-separated list of specs (the `--fault` CLI shape).
    pub fn parse_list(s: &str) -> Result<Vec<FaultSpec>, Error> {
        s.split(',')
            .map(|item| FaultSpec::parse(item.trim()))
            .collect()
    }
}

impl std::fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}=", self.device)?;
        match &self.kind {
            FaultKind::Stall { dur, window } => {
                write!(f, "stall:{}", fmt_dur(*dur))?;
                if let Some(w) = window {
                    write!(f, ":{}", fmt_dur(*w))?;
                }
            }
            FaultKind::Slow { factor, window } => {
                write!(f, "slow:{factor}x")?;
                if let Some(w) = window {
                    write!(f, ":{}", fmt_dur(*w))?;
                }
            }
            FaultKind::Fail { count } => write!(f, "fail:{count}")?,
            FaultKind::Die => write!(f, "die")?,
        }
        match self.trigger {
            FaultTrigger::Launch(n) => write!(f, "@launch:{n}"),
            FaultTrigger::Elapsed(d) => write!(f, "@t:{}", fmt_dur(d)),
        }
    }
}

/// Granularity of the shutdown-aware sleep used by stall/slow injection:
/// a long hang must not pin a worker thread past pool shutdown.
const SLEEP_CHUNK: Duration = Duration::from_millis(5);

/// Sleep `total` on `clock` in [`SLEEP_CHUNK`] steps, returning early
/// (false) when `shutdown` flips. On a virtual clock each chunk is a
/// discrete event, so the pool's watchdog ticks interleave with a long
/// stall exactly as they do in wall time.
fn chunked_sleep(clock: &dyn Clock, total: Duration, shutdown: &AtomicBool) -> bool {
    let t0 = clock.now();
    loop {
        let left = total.saturating_sub(clock.now().saturating_duration_since(t0));
        if left.is_zero() {
            return true;
        }
        if shutdown.load(Ordering::SeqCst) {
            return false;
        }
        clock.sleep(SLEEP_CHUNK.min(left));
    }
}

/// Armed runtime state of one device's scripted fault. The pool holds
/// one per faulted device and consults it around every launch batch;
/// the health monitor consults [`FaultState::probe_ok`] to decide
/// quarantine re-admission.
pub struct FaultState {
    spec: FaultSpec,
    /// Timing source: stall sleeps, windows and `t:` triggers all read
    /// this clock, so a pool on a virtual clock injects faults on the
    /// virtual timeline.
    clock: Arc<dyn Clock>,
    /// When the fault was armed (pool construction) — the zero point of
    /// `t:` triggers.
    armed: Instant,
    /// Device-local launch counter (each job of a batch counts once).
    launches: AtomicU64,
    /// Launches that failed after an elapsed-time `fail` trigger.
    fail_seq: AtomicU64,
    /// Times the fault actually injected something (stalls slept,
    /// launches failed/slowed).
    injected: AtomicU64,
    /// First instant the (stall/slow) window activated.
    window_start: Mutex<Option<Instant>>,
    /// A stall sleep is in progress right now (probes fail during it).
    stalling: AtomicBool,
    /// `Die` has issued its first failure.
    died: AtomicBool,
}

impl FaultState {
    /// Arm `spec` now, on the wall clock.
    pub fn arm(spec: FaultSpec) -> FaultState {
        FaultState::arm_with_clock(spec, Arc::new(WallClock))
    }

    /// Arm `spec` now, reading all times from `clock` (the pool passes
    /// its configured clock).
    pub fn arm_with_clock(spec: FaultSpec, clock: Arc<dyn Clock>) -> FaultState {
        let armed = clock.now();
        FaultState {
            spec,
            clock,
            armed,
            launches: AtomicU64::new(0),
            fail_seq: AtomicU64::new(0),
            injected: AtomicU64::new(0),
            window_start: Mutex::new(None),
            stalling: AtomicBool::new(false),
            died: AtomicBool::new(false),
        }
    }

    /// The armed spec.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// How many times the fault has injected misbehavior.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Has the trigger point been reached for a batch whose first job is
    /// launch `first` and last is `last`?
    fn triggered(&self, first: u64, last: u64) -> bool {
        match self.spec.trigger {
            FaultTrigger::Launch(n) => last >= n,
            FaultTrigger::Elapsed(d) => {
                let _ = first;
                self.clock.now().saturating_duration_since(self.armed) >= d
            }
        }
    }

    /// Is the degradation window (started at the first triggered launch)
    /// still active at `now`? Opens the window if unset.
    fn window_active(&self, window: Option<Duration>, now: Instant) -> bool {
        let mut ws = self.window_start.lock().unwrap();
        let start = *ws.get_or_insert(now);
        match window {
            None => true,
            Some(w) => now.saturating_duration_since(start) <= w,
        }
    }

    /// Gate one launch batch of `jobs` jobs about to execute on the
    /// device. Consumes `jobs` launch indices. Returns the slowdown
    /// factor to apply after execution (1.0 = none), sleeps through an
    /// injected stall (abandoning it early on `shutdown`), or returns
    /// the injected failure every job of the batch must report.
    pub fn on_batch_start(&self, jobs: usize, shutdown: &AtomicBool) -> Result<f64, Error> {
        let n = (jobs as u64).max(1);
        let first = self.launches.fetch_add(n, Ordering::Relaxed);
        let last = first + n - 1;
        if !self.triggered(first, last) {
            return Ok(1.0);
        }
        match &self.spec.kind {
            FaultKind::Die => {
                self.died.store(true, Ordering::SeqCst);
                self.injected.fetch_add(1, Ordering::Relaxed);
                Err(Error::Fault(format!(
                    "injected permanent death of device {} ({})",
                    self.spec.device, self.spec
                )))
            }
            FaultKind::Fail { count } => {
                let in_window = match self.spec.trigger {
                    FaultTrigger::Launch(t) => first < t + count,
                    // Time trigger: the first `count` *launches* after
                    // the trigger fail — a batch consumes its job count,
                    // matching the launch-indexed variant's accounting.
                    FaultTrigger::Elapsed(_) => {
                        self.fail_seq.fetch_add(n, Ordering::Relaxed) < *count
                    }
                };
                if in_window {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    Err(Error::Fault(format!(
                        "injected transient launch failure on device {} ({})",
                        self.spec.device, self.spec
                    )))
                } else {
                    Ok(1.0)
                }
            }
            FaultKind::Stall { dur, window } => {
                let now = self.clock.now();
                let w = window.unwrap_or(*dur);
                if self.window_active(Some(w), now) {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    self.stalling.store(true, Ordering::SeqCst);
                    chunked_sleep(&*self.clock, *dur, shutdown);
                    self.stalling.store(false, Ordering::SeqCst);
                }
                Ok(1.0)
            }
            FaultKind::Slow { factor, window } => {
                if self.window_active(*window, self.clock.now()) {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    Ok(*factor)
                } else {
                    Ok(1.0)
                }
            }
        }
    }

    /// Apply a slowdown factor returned by
    /// [`FaultState::on_batch_start`]: sleep the extra `(factor - 1)`
    /// share of the observed execution time on this fault's clock
    /// (shutdown-aware).
    pub fn apply_slowdown(&self, factor: f64, elapsed: Duration, shutdown: &AtomicBool) {
        if factor > 1.0 {
            let extra = elapsed.mul_f64(factor - 1.0);
            let _ = chunked_sleep(&*self.clock, extra, shutdown);
        }
    }

    /// Would a health probe of the device succeed right now? Dead
    /// devices and devices inside an active stall window fail the probe
    /// (still wedged); slowed and transiently-failing devices pass — they
    /// respond, just badly, and the watchdog re-judges them on the next
    /// incident.
    pub fn probe_ok(&self) -> Result<(), Error> {
        match &self.spec.kind {
            FaultKind::Die => {
                let dead = self.died.load(Ordering::SeqCst)
                    || match self.spec.trigger {
                        FaultTrigger::Elapsed(d) => {
                            self.clock.now().saturating_duration_since(self.armed) >= d
                        }
                        FaultTrigger::Launch(_) => false,
                    };
                if dead {
                    Err(Error::Fault(format!(
                        "probe failed: device {} is dead ({})",
                        self.spec.device, self.spec
                    )))
                } else {
                    Ok(())
                }
            }
            FaultKind::Stall { dur, window } => {
                if self.stalling.load(Ordering::SeqCst) {
                    return Err(Error::Fault(format!(
                        "probe failed: device {} is mid-stall",
                        self.spec.device
                    )));
                }
                let ws = self.window_start.lock().unwrap();
                match *ws {
                    Some(start)
                        if self.clock.now().saturating_duration_since(start)
                            <= window.unwrap_or(*dur) =>
                    {
                        Err(Error::Fault(format!(
                            "probe failed: device {} still inside its stall window",
                            self.spec.device
                        )))
                    }
                    _ => Ok(()),
                }
            }
            FaultKind::Slow { .. } | FaultKind::Fail { .. } => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock;
    use crate::util::vclock::VirtualClock;

    #[test]
    fn spec_grammar_roundtrips() {
        for s in [
            "2=stall:120ms:10s@launch:40",
            "1=slow:8x@t:50ms",
            "0=fail:25@launch:40",
            "3=die@t:200ms",
            "0=stall:5ms@launch:0",
            "1=slow:2.5x:1s@launch:3",
            "0=stall:0.4ms@launch:0",
            "1=fail:1@t:1.5s",
        ] {
            let spec = FaultSpec::parse(s).unwrap_or_else(|e| panic!("`{s}`: {e}"));
            let again = FaultSpec::parse(&spec.to_string()).unwrap();
            assert_eq!(spec, again, "`{s}` must roundtrip through Display");
        }
    }

    #[test]
    fn spec_grammar_rejects_garbage() {
        for s in [
            "",
            "0",
            "0=die",               // missing trigger
            "0=die@soon",          // bad trigger
            "0=stall@launch:1",    // stall needs a duration
            "0=stall:xyz@launch:1",
            "0=slow:1x@launch:1",  // factor must exceed 1
            "0=slow:4@launch:1",   // missing the `x`
            "0=fail:0@launch:1",   // zero count
            "0=melt@launch:1",     // unknown kind
            "x=die@launch:1",      // bad device
            "0=die:1:2:3@launch:1",
        ] {
            assert!(FaultSpec::parse(s).is_err(), "`{s}` must be rejected");
        }
    }

    #[test]
    fn parse_list_splits_on_commas() {
        let specs = FaultSpec::parse_list("0=die@launch:5, 1=fail:2@t:10ms").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].device, 0);
        assert_eq!(specs[1].device, 1);
        assert!(FaultSpec::parse_list("0=die@launch:5,bogus").is_err());
    }

    fn no_shutdown() -> AtomicBool {
        AtomicBool::new(false)
    }

    #[test]
    fn launch_triggered_fail_covers_exactly_its_window() {
        let f = FaultState::arm(FaultSpec::parse("0=fail:3@launch:2").unwrap());
        let sd = no_shutdown();
        // Launches 0-1 fine, 2-4 fail, 5+ fine again.
        assert!(f.on_batch_start(1, &sd).is_ok()); // 0
        assert!(f.on_batch_start(1, &sd).is_ok()); // 1
        for _ in 0..3 {
            assert!(matches!(f.on_batch_start(1, &sd), Err(Error::Fault(_))));
        }
        assert!(f.on_batch_start(1, &sd).is_ok()); // 5
        assert_eq!(f.injected(), 3);
        // Transient faults never fail a probe.
        assert!(f.probe_ok().is_ok());
    }

    #[test]
    fn batch_spanning_the_trigger_fails_whole() {
        let f = FaultState::arm(FaultSpec::parse("0=fail:4@launch:2").unwrap());
        let sd = no_shutdown();
        // A 4-job batch covering launches 0-3 reaches index 2: it fails.
        assert!(f.on_batch_start(4, &sd).is_err());
    }

    #[test]
    fn die_is_permanent_and_fails_probes() {
        let f = FaultState::arm(FaultSpec::parse("1=die@launch:1").unwrap());
        let sd = no_shutdown();
        assert!(f.probe_ok().is_ok(), "not dead before the trigger");
        assert!(f.on_batch_start(1, &sd).is_ok()); // launch 0
        for _ in 0..4 {
            assert!(f.on_batch_start(1, &sd).is_err());
        }
        assert!(f.probe_ok().is_err(), "dead devices never pass probes");
    }

    #[test]
    fn stall_sleeps_then_recovers() {
        let f = FaultState::arm(FaultSpec::parse("0=stall:20ms@launch:1").unwrap());
        let sd = no_shutdown();
        let t0 = clock::now();
        assert!(f.on_batch_start(1, &sd).is_ok()); // launch 0: clean
        assert!(t0.elapsed() < Duration::from_millis(15), "no stall before trigger");
        let t1 = clock::now();
        assert!(f.on_batch_start(1, &sd).is_ok()); // launch 1: stalls 20ms
        assert!(
            t1.elapsed() >= Duration::from_millis(18),
            "triggered launch must stall: {:?}",
            t1.elapsed()
        );
        assert_eq!(f.injected(), 1);
        // Default window = one stall's worth: once it has passed, later
        // launches run clean and probes succeed.
        clock::sleep(Duration::from_millis(25));
        let t2 = clock::now();
        assert!(f.on_batch_start(1, &sd).is_ok());
        assert!(t2.elapsed() < Duration::from_millis(15), "window over: no more stalls");
        assert!(f.probe_ok().is_ok());
    }

    #[test]
    fn stall_window_fails_probes_while_active() {
        let f = FaultState::arm(FaultSpec::parse("0=stall:10ms:300ms@launch:0").unwrap());
        let sd = no_shutdown();
        assert!(f.on_batch_start(1, &sd).is_ok()); // stalls 10ms, opens the window
        assert!(f.probe_ok().is_err(), "window still active");
    }

    #[test]
    fn stall_abandons_on_shutdown() {
        let f = FaultState::arm(FaultSpec::parse("0=stall:10s@launch:0").unwrap());
        let sd = AtomicBool::new(true);
        let t0 = clock::now();
        assert!(f.on_batch_start(1, &sd).is_ok());
        assert!(t0.elapsed() < Duration::from_secs(1), "shutdown must cut the stall short");
    }

    #[test]
    fn slow_returns_its_factor_and_probes_pass() {
        let f = FaultState::arm(FaultSpec::parse("0=slow:4x@launch:0").unwrap());
        let sd = no_shutdown();
        let factor = f.on_batch_start(1, &sd).unwrap();
        assert!((factor - 4.0).abs() < 1e-12);
        assert!(f.probe_ok().is_ok(), "slow devices respond to probes");
        // The slowdown sleep scales with observed time.
        let t0 = clock::now();
        f.apply_slowdown(3.0, Duration::from_millis(10), &sd);
        assert!(clock::now() - t0 >= Duration::from_millis(18));
    }

    #[test]
    fn elapsed_trigger_uses_armed_clock() {
        let f = FaultState::arm(FaultSpec::parse("0=die@t:30ms").unwrap());
        let sd = no_shutdown();
        assert!(f.on_batch_start(1, &sd).is_ok(), "alive before the trigger time");
        clock::sleep(Duration::from_millis(35));
        assert!(f.on_batch_start(1, &sd).is_err());
        assert!(f.probe_ok().is_err());
    }

    #[test]
    fn virtual_clock_drives_triggers_and_stalls() {
        let vc = Arc::new(VirtualClock::new());
        let sd = no_shutdown();
        let f = FaultState::arm_with_clock(FaultSpec::parse("0=die@t:30ms").unwrap(), vc.clone());
        assert!(f.on_batch_start(1, &sd).is_ok(), "alive before the virtual trigger");
        vc.sleep(Duration::from_millis(35)); // no wall time passes
        assert!(f.on_batch_start(1, &sd).is_err());
        assert!(f.probe_ok().is_err());

        // A virtual stall advances virtual time by exactly its duration.
        let s =
            FaultState::arm_with_clock(FaultSpec::parse("0=stall:600ms@launch:0").unwrap(), vc.clone());
        let t0 = vc.elapsed();
        assert!(s.on_batch_start(1, &sd).is_ok());
        assert_eq!(vc.elapsed() - t0, Duration::from_millis(600));
        assert!(s.injected() >= 1);
    }
}
