//! Kernel launching: block scheduling over a worker pool, warp threads,
//! block barriers, and the runtime-binding registry.

use super::device::DeviceDesc;
use super::interp::{CallEnv, Interp};
use super::loader::LoadedModule;
use super::memory::{GlobalMemory, SharedMemory};
use crate::util::clock::Clock;
use crate::util::{clock, Error};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Signature of a host-side runtime binding (`__kmpc_*` entry points
/// implemented in Rust, and `payload.*` PJRT executions). Called once per
/// *warp* reaching the call, with per-lane arguments and the active mask.
/// Returns per-lane results when the callee produces a value.
pub type RtFn =
    Arc<dyn Fn(&CallEnv<'_>, &[Vec<u64>], u64) -> Result<Option<Vec<u64>>, Error> + Send + Sync>;

/// Registry of runtime bindings, looked up by symbol name after module
/// functions and before intrinsics.
#[derive(Clone, Default)]
pub struct Bindings {
    map: HashMap<String, RtFn>,
}

impl Bindings {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Install a binding.
    pub fn bind(&mut self, name: impl Into<String>, f: RtFn) {
        self.map.insert(name.into(), f);
    }

    /// Look up a binding.
    pub fn get(&self, name: &str) -> Option<&RtFn> {
        self.map.get(name)
    }

    /// Number of installed bindings.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Names of all bindings (sorted, for diagnostics).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.map.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }
}

/// A reusable block-wide barrier with dynamic membership: warps that
/// finish the kernel `leave()` and stop counting toward the barrier
/// (CUDA's `__syncthreads` UB-for-exited-threads becomes well-defined
/// "exited warps don't participate"). Poisoning wakes all waiters with an
/// error so one trapped warp cannot deadlock the block.
pub struct BlockBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
}

struct BarrierState {
    participants: u32,
    arrived: u32,
    epoch: u64,
    poisoned: bool,
}

/// How long a warp may wait at a block barrier before the simulator calls
/// it a deadlock (divergent barriers are UB on hardware; we trap instead).
const BARRIER_TIMEOUT: Duration = Duration::from_secs(20);

impl BlockBarrier {
    /// Barrier over `participants` warps.
    pub fn new(participants: u32) -> Self {
        BlockBarrier {
            state: Mutex::new(BarrierState { participants, arrived: 0, epoch: 0, poisoned: false }),
            cv: Condvar::new(),
        }
    }

    /// Arrive and wait for the rest of the block.
    pub fn wait(&self) -> Result<(), Error> {
        let mut st = self.state.lock().unwrap();
        if st.poisoned {
            return Err(Error::trap("barrier", "block poisoned by a trapped warp"));
        }
        st.arrived += 1;
        if st.arrived >= st.participants {
            st.arrived = 0;
            st.epoch += 1;
            self.cv.notify_all();
            return Ok(());
        }
        let epoch = st.epoch;
        loop {
            let (guard, timeout) = self.cv.wait_timeout(st, BARRIER_TIMEOUT).unwrap();
            st = guard;
            if st.poisoned {
                return Err(Error::trap("barrier", "block poisoned by a trapped warp"));
            }
            if st.epoch != epoch {
                return Ok(());
            }
            if timeout.timed_out() {
                st.poisoned = true;
                self.cv.notify_all();
                return Err(Error::trap(
                    "barrier",
                    "barrier timeout — divergent __syncthreads (some warps never arrived)",
                ));
            }
        }
    }

    /// A warp finished the kernel: stop counting it.
    pub fn leave(&self) {
        let mut st = self.state.lock().unwrap();
        st.participants = st.participants.saturating_sub(1);
        if st.participants > 0 && st.arrived >= st.participants {
            st.arrived = 0;
            st.epoch += 1;
            self.cv.notify_all();
        }
    }

    /// Wake all waiters with an error (a warp trapped).
    pub fn poison(&self) {
        let mut st = self.state.lock().unwrap();
        st.poisoned = true;
        self.cv.notify_all();
    }
}

/// Launch geometry (1-D grid and block — sufficient for the benchmark
/// suite; multi-dim indexing is linearized by kernels).
#[derive(Debug, Clone, Copy)]
pub struct LaunchConfig {
    /// Number of blocks (OpenMP teams).
    pub grid_dim: u32,
    /// Threads per block.
    pub block_dim: u32,
}

impl LaunchConfig {
    /// Convenience constructor.
    pub fn new(grid_dim: u32, block_dim: u32) -> Self {
        LaunchConfig { grid_dim, block_dim }
    }
}

/// Counters collected during a launch.
#[derive(Debug, Default)]
pub struct LaunchStats {
    /// Lane-instructions executed (sum of active lanes over all insts).
    pub lane_ops: u64,
    /// Warp-level interpreter steps.
    pub warp_steps: u64,
    /// Blocks executed.
    pub blocks: u32,
    /// Wall-clock duration of the launch.
    pub wall: Duration,
}

/// Shared mutable counters (updated by warp threads at coarse granularity).
#[derive(Default)]
pub struct StatsCollector {
    pub lane_ops: AtomicU64,
    pub warp_steps: AtomicU64,
}

/// Resolve `kernel` in `module` and validate launch parameters — the
/// shared front half of [`launch_kernel`] and [`launch_kernel_batch`].
fn resolve_kernel(
    desc: &DeviceDesc,
    module: &LoadedModule,
    kernel: &str,
    args: &[u64],
    cfg: LaunchConfig,
) -> Result<Arc<crate::ir::Function>, Error> {
    let f = module
        .func(kernel)
        .ok_or_else(|| Error::DevRt(format!("kernel `{kernel}` not found in module `{}`", module.module.name)))?
        .clone();
    if !f.is_kernel {
        return Err(Error::DevRt(format!("function `{kernel}` is not a kernel entry")));
    }
    if f.num_params as usize != args.len() {
        return Err(Error::DevRt(format!(
            "kernel `{kernel}` expects {} args, got {}",
            f.num_params,
            args.len()
        )));
    }
    if cfg.block_dim == 0 || cfg.grid_dim == 0 {
        return Err(Error::DevRt("launch with empty grid or block".into()));
    }
    if cfg.block_dim > desc.max_threads_per_block {
        return Err(Error::DevRt(format!(
            "block_dim {} exceeds device limit {}",
            cfg.block_dim, desc.max_threads_per_block
        )));
    }
    Ok(f)
}

/// Execute `kernel` from `module` over the launch grid.
///
/// Each block runs on a pool worker ("SM"); each warp of a block is a host
/// thread so that block barriers can suspend it (single-warp blocks run
/// inline on the SM worker — no barrier partner means no thread is
/// needed). Kernel arguments are broadcast to all lanes.
pub fn launch_kernel(
    desc: &DeviceDesc,
    module: &LoadedModule,
    kernel: &str,
    args: &[u64],
    gmem: &GlobalMemory,
    bindings: &Bindings,
    cfg: LaunchConfig,
) -> Result<LaunchStats, Error> {
    launch_kernel_with_clock(&clock::WallClock, desc, module, kernel, args, gmem, bindings, cfg)
}

/// [`launch_kernel`] with an injected wall-time source for the returned
/// [`LaunchStats::wall`] stamp (the pool passes its configured clock so
/// profiler rows stay on the virtual timeline). The SM worker threads
/// themselves are compute-bound and never sleep, so they need no clock.
#[allow(clippy::too_many_arguments)]
pub fn launch_kernel_with_clock(
    timer: &dyn Clock,
    desc: &DeviceDesc,
    module: &LoadedModule,
    kernel: &str,
    args: &[u64],
    gmem: &GlobalMemory,
    bindings: &Bindings,
    cfg: LaunchConfig,
) -> Result<LaunchStats, Error> {
    let f = resolve_kernel(desc, module, kernel, args, cfg)?;
    let width = desc.arch.warp_width();
    let warps_per_block = cfg.block_dim.div_ceil(width);
    let stats = StatsCollector::default();
    let first_error: Mutex<Option<Error>> = Mutex::new(None);
    let next_block = AtomicUsize::new(0);
    let t0 = timer.now();

    let workers = desc.sm_count.min(cfg.grid_dim).max(1);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let b = next_block.fetch_add(1, Ordering::Relaxed);
                if b >= cfg.grid_dim as usize || first_error.lock().unwrap().is_some() {
                    return;
                }
                if let Err(e) = run_block(
                    desc, module, &f, args, gmem, bindings, cfg, b as u32, warps_per_block, &stats,
                ) {
                    let mut slot = first_error.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                }
            });
        }
    });

    if let Some(e) = first_error.into_inner().unwrap() {
        return Err(e);
    }
    Ok(LaunchStats {
        lane_ops: stats.lane_ops.load(Ordering::Relaxed),
        warp_steps: stats.warp_steps.load(Ordering::Relaxed),
        blocks: cfg.grid_dim,
        wall: timer.now().saturating_duration_since(t0),
    })
}

/// One launch of a fused batch: the kernel entry, its broadcast args, and
/// its own geometry. All items must come from the same loaded module.
#[derive(Debug, Clone, Copy)]
pub struct BatchKernelSpec<'a> {
    /// Kernel entry point.
    pub kernel: &'a str,
    /// Kernel arguments (broadcast to all lanes).
    pub args: &'a [u64],
    /// Launch geometry of this item.
    pub cfg: LaunchConfig,
}

/// Execute several launches of **one loaded module** as a single fused
/// grid — the device-side half of the pool's launch batching.
///
/// Every block of every item observes exactly the `(ctaid, nctaid, args)`
/// it would see in a solo launch, so fusion is invisible to kernels;
/// blocks of different items interleave over the device's SM workers,
/// which is where the throughput win comes from: a small launch whose
/// grid covers only a couple of SMs no longer leaves the rest idle, and
/// the per-launch thread-scope setup is paid once per batch instead of
/// once per launch.
///
/// **Caller contract:** items must be independent — the pool only fuses
/// requests whose image has no global-space globals, so items cannot
/// observe each other through device memory. Results are per-item; a
/// failing item does not abort its siblings (their blocks keep running).
/// `wall` in each item's stats is the whole batch's wall time (per-item
/// isolation is not measurable inside a fused grid).
pub fn launch_kernel_batch(
    desc: &DeviceDesc,
    module: &LoadedModule,
    items: &[BatchKernelSpec<'_>],
    gmem: &GlobalMemory,
    bindings: &Bindings,
) -> Vec<Result<LaunchStats, Error>> {
    launch_kernel_batch_with_clock(&clock::WallClock, desc, module, items, gmem, bindings)
}

/// [`launch_kernel_batch`] with an injected wall-time source (see
/// [`launch_kernel_with_clock`]).
pub fn launch_kernel_batch_with_clock(
    timer: &dyn Clock,
    desc: &DeviceDesc,
    module: &LoadedModule,
    items: &[BatchKernelSpec<'_>],
    gmem: &GlobalMemory,
    bindings: &Bindings,
) -> Vec<Result<LaunchStats, Error>> {
    // Validate every item up front; invalid ones fail without running and
    // are excluded from the fused grid.
    let mut preps: Vec<Option<(Arc<crate::ir::Function>, u32)>> = Vec::with_capacity(items.len());
    let mut errors: Vec<Mutex<Option<Error>>> = Vec::with_capacity(items.len());
    let width = desc.arch.warp_width();
    for it in items {
        match resolve_kernel(desc, module, it.kernel, it.args, it.cfg) {
            Ok(f) => {
                let warps = it.cfg.block_dim.div_ceil(width);
                preps.push(Some((f, warps)));
                errors.push(Mutex::new(None));
            }
            Err(e) => {
                preps.push(None);
                errors.push(Mutex::new(Some(e)));
            }
        }
    }

    // Flat schedule: (item index, block id) for every block of every
    // valid item, in item order.
    let mut flat: Vec<(usize, u32)> = Vec::new();
    for (i, p) in preps.iter().enumerate() {
        if p.is_some() {
            for b in 0..items[i].cfg.grid_dim {
                flat.push((i, b));
            }
        }
    }
    let stats: Vec<StatsCollector> =
        (0..items.len()).map(|_| StatsCollector::default()).collect();
    let cursor = AtomicUsize::new(0);
    let t0 = timer.now();

    if !flat.is_empty() {
        let workers = desc.sm_count.min(flat.len() as u32).max(1);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let idx = cursor.fetch_add(1, Ordering::Relaxed);
                    if idx >= flat.len() {
                        return;
                    }
                    let (item, block) = flat[idx];
                    // A failed item stops scheduling its remaining blocks;
                    // other items keep going.
                    if errors[item].lock().unwrap().is_some() {
                        continue;
                    }
                    let (f, warps) = preps[item].as_ref().expect("scheduled item is valid");
                    if let Err(e) = run_block(
                        desc,
                        module,
                        f,
                        items[item].args,
                        gmem,
                        bindings,
                        items[item].cfg,
                        block,
                        *warps,
                        &stats[item],
                    ) {
                        let mut slot = errors[item].lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                    }
                });
            }
        });
    }

    let wall = timer.now().saturating_duration_since(t0);
    errors
        .into_iter()
        .enumerate()
        .map(|(i, e)| match e.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(LaunchStats {
                lane_ops: stats[i].lane_ops.load(Ordering::Relaxed),
                warp_steps: stats[i].warp_steps.load(Ordering::Relaxed),
                blocks: items[i].cfg.grid_dim,
                wall,
            }),
        })
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn run_block(
    desc: &DeviceDesc,
    module: &LoadedModule,
    f: &Arc<crate::ir::Function>,
    args: &[u64],
    gmem: &GlobalMemory,
    bindings: &Bindings,
    cfg: LaunchConfig,
    block_id: u32,
    warps_per_block: u32,
    stats: &StatsCollector,
) -> Result<(), Error> {
    let smem = SharedMemory::new(desc.shared_mem_per_block);
    let barrier = BlockBarrier::new(warps_per_block);
    let width = desc.arch.warp_width();

    // Fast path: a single-warp block has no barrier partner to suspend
    // for, so the warp runs inline on the SM worker instead of paying a
    // thread spawn + join — the dominant fixed cost of small launches.
    if warps_per_block == 1 {
        let env = CallEnv {
            desc,
            module,
            gmem,
            smem: &smem,
            barrier: &barrier,
            bindings,
            block_id,
            grid_dim: cfg.grid_dim,
            block_dim: cfg.block_dim,
            warp_id: 0,
            num_warps: 1,
        };
        let mut mask: u64 = 0;
        for lane in 0..width {
            if lane < cfg.block_dim {
                mask |= 1 << lane;
            }
        }
        let interp = Interp::new(&env, stats);
        let arg_lanes: Vec<Vec<u64>> = args.iter().map(|&a| vec![a; width as usize]).collect();
        let r = interp.run_function(f, &arg_lanes, mask);
        barrier.leave();
        return r.map(|_| ());
    }

    let block_error: Mutex<Option<Error>> = Mutex::new(None);

    std::thread::scope(|scope| {
        for warp_id in 0..warps_per_block {
            let smem = &smem;
            let barrier = &barrier;
            let block_error = &block_error;
            scope.spawn(move || {
                let env = CallEnv {
                    desc,
                    module,
                    gmem,
                    smem,
                    barrier,
                    bindings,
                    block_id,
                    grid_dim: cfg.grid_dim,
                    block_dim: cfg.block_dim,
                    warp_id,
                    num_warps: warps_per_block,
                };
                // Active lanes: those whose linear tid is inside block_dim.
                let base = warp_id * width;
                let mut mask: u64 = 0;
                for lane in 0..width {
                    if base + lane < cfg.block_dim {
                        mask |= 1 << lane;
                    }
                }
                let interp = Interp::new(&env, stats);
                let arg_lanes: Vec<Vec<u64>> =
                    args.iter().map(|&a| vec![a; width as usize]).collect();
                let r = interp.run_function(f, &arg_lanes, mask);
                barrier.leave();
                if let Err(e) = r {
                    barrier.poison();
                    let mut slot = block_error.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                }
            });
        }
    });

    match block_error.into_inner().unwrap() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_releases_all_participants() {
        let b = Arc::new(BlockBarrier::new(4));
        let counter = Arc::new(AtomicU64::new(0));
        let mut hs = vec![];
        for _ in 0..4 {
            let b = b.clone();
            let c = counter.clone();
            hs.push(std::thread::spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
                b.wait().unwrap();
                // after the barrier everyone must see all arrivals
                assert_eq!(c.load(Ordering::SeqCst), 4);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn leaving_warp_unblocks_barrier() {
        let b = Arc::new(BlockBarrier::new(2));
        let b2 = b.clone();
        let waiter = std::thread::spawn(move || b2.wait());
        clock::sleep(Duration::from_millis(50));
        b.leave(); // the other warp exits the kernel instead of arriving
        waiter.join().unwrap().unwrap();
    }

    #[test]
    fn poison_wakes_waiters_with_error() {
        let b = Arc::new(BlockBarrier::new(2));
        let b2 = b.clone();
        let waiter = std::thread::spawn(move || b2.wait());
        clock::sleep(Duration::from_millis(50));
        b.poison();
        assert!(waiter.join().unwrap().is_err());
    }

    use super::super::device::DeviceDesc;
    use super::super::loader::LoadedModule;
    use super::super::memory::GlobalMemory;
    use crate::ir::{AddrSpace, CmpPred, FunctionBuilder, Module, Operand, Type};

    /// kernel saxpy(out, x, y, a_bits, n): out[i] = a*x[i] + y[i] for each
    /// thread's strided range — exercises ids, loops, loads, stores, casts.
    fn saxpy_module() -> Module {
        let mut m = Module::new("saxpy");
        let mut b = FunctionBuilder::new(
            "saxpy",
            &[Type::I64, Type::I64, Type::I64, Type::I64, Type::I64],
            None,
        )
        .kernel();
        let (out, x, y, a_bits, n) = (b.param(0), b.param(1), b.param(2), b.param(3), b.param(4));
        let a32 = b.cast(crate::ir::CastOp::Trunc, a_bits, Type::I32);
        let a = b.cast(crate::ir::CastOp::Bitcast, a32, Type::F32);
        let tid = b.call("gpu.tid.x", &[], Type::I32);
        let ntid = b.call("gpu.ntid.x", &[], Type::I32);
        let ctaid = b.call("gpu.ctaid.x", &[], Type::I32);
        let nctaid = b.call("gpu.nctaid.x", &[], Type::I32);
        let block_base = b.mul(ctaid, ntid);
        let gid = b.add(block_base, tid);
        let stride = b.mul(ntid, nctaid);
        let gid64 = b.sext64(gid);
        let stride64 = b.sext64(stride);
        let i = b.copy(gid64);
        b.loop_(|b| {
            let done = b.cmp(CmpPred::Ge, i, n);
            b.if_(done, |b| b.break_());
            let xi_addr = b.index(x, i, 4);
            let yi_addr = b.index(y, i, 4);
            let oi_addr = b.index(out, i, 4);
            let xv = b.load(Type::F32, AddrSpace::Global, xi_addr);
            let yv = b.load(Type::F32, AddrSpace::Global, yi_addr);
            let ax = b.mul(a, xv);
            let s = b.add(ax, yv);
            b.store(Type::F32, AddrSpace::Global, oi_addr, s);
            let next = b.add(i, stride64);
            b.assign(i, next);
        });
        b.ret();
        m.add_func(b.build());
        m
    }

    fn run_saxpy(desc: &DeviceDesc, n: usize, grid: u32, block: u32) {
        let gmem = GlobalMemory::new(16 << 20);
        let lm = LoadedModule::load(saxpy_module(), &gmem).unwrap();
        let bytes = (n * 4) as u64;
        let out = gmem.alloc(bytes, 8).unwrap();
        let x = gmem.alloc(bytes, 8).unwrap();
        let y = gmem.alloc(bytes, 8).unwrap();
        let xs: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let ys: Vec<f32> = (0..n).map(|i| (i * 2) as f32).collect();
        let as_bytes = |v: &[f32]| -> Vec<u8> { v.iter().flat_map(|f| f.to_le_bytes()).collect() };
        gmem.write_bytes(x, &as_bytes(&xs)).unwrap();
        gmem.write_bytes(y, &as_bytes(&ys)).unwrap();
        let a = 0.5f32;
        let stats = launch_kernel(
            desc,
            &lm,
            "saxpy",
            &[out, x, y, a.to_bits() as u64, n as u64],
            &gmem,
            &Bindings::new(),
            LaunchConfig::new(grid, block),
        )
        .unwrap();
        assert!(stats.lane_ops > 0);
        let mut buf = vec![0u8; n * 4];
        gmem.read_bytes(out, &mut buf).unwrap();
        for i in 0..n {
            let got = f32::from_le_bytes(buf[i * 4..i * 4 + 4].try_into().unwrap());
            let want = a * xs[i] + ys[i];
            assert_eq!(got, want, "lane {i}");
        }
    }

    #[test]
    fn saxpy_runs_on_nvptx_sim() {
        run_saxpy(&DeviceDesc::nvptx64(), 1000, 4, 64);
    }

    #[test]
    fn saxpy_runs_on_amdgcn_sim() {
        run_saxpy(&DeviceDesc::amdgcn(), 777, 3, 128);
    }

    #[test]
    fn saxpy_handles_partial_warps_and_single_thread() {
        run_saxpy(&DeviceDesc::nvptx64(), 65, 2, 33);
        run_saxpy(&DeviceDesc::nvptx64(), 10, 1, 1);
    }

    #[test]
    fn launch_rejects_bad_configs() {
        let gmem = GlobalMemory::new(1 << 20);
        let desc = DeviceDesc::nvptx64();
        let lm = LoadedModule::load(saxpy_module(), &gmem).unwrap();
        let b = Bindings::new();
        let err = launch_kernel(&desc, &lm, "nope", &[], &gmem, &b, LaunchConfig::new(1, 1));
        assert!(err.is_err());
        let err = launch_kernel(&desc, &lm, "saxpy", &[], &gmem, &b, LaunchConfig::new(1, 1));
        assert!(err.is_err(), "wrong arg count must fail");
        let err = launch_kernel(
            &desc,
            &lm,
            "saxpy",
            &[0, 0, 0, 0, 0],
            &gmem,
            &b,
            LaunchConfig::new(1, 4096),
        );
        assert!(err.is_err(), "oversized block must fail");
    }

    #[test]
    fn trap_in_one_warp_fails_launch_without_deadlock() {
        let mut m = Module::new("trap");
        let mut b = FunctionBuilder::new("t", &[], None).kernel();
        let tid = b.call("gpu.tid.x", &[], Type::I32);
        let is_w1 = b.cmp(CmpPred::Ge, tid, Operand::i32(32));
        // warp 1 traps, warp 0 waits at a barrier → poison must wake it.
        b.if_else(
            is_w1,
            |b| b.trap("boom"),
            |b| b.call_void("gpu.barrier0", &[]),
        );
        b.ret();
        m.add_func(b.build());
        let gmem = GlobalMemory::new(1 << 20);
        let desc = DeviceDesc::nvptx64();
        let lm = LoadedModule::load(m, &gmem).unwrap();
        let r = launch_kernel(
            &desc,
            &lm,
            "t",
            &[],
            &gmem,
            &Bindings::new(),
            LaunchConfig::new(1, 64),
        );
        match r {
            Err(Error::Trap { msg, .. }) => assert!(msg.contains("boom") || msg.contains("poisoned"), "{msg}"),
            other => panic!("expected trap, got {other:?}"),
        }
    }

    #[test]
    fn bindings_register_and_resolve() {
        let mut b = Bindings::new();
        assert!(b.is_empty());
        b.bind("__kmpc_test", Arc::new(|_, _, _| Ok(None)));
        assert_eq!(b.len(), 1);
        assert!(b.get("__kmpc_test").is_some());
        assert!(b.get("other").is_none());
        assert_eq!(b.names(), vec!["__kmpc_test"]);
    }
}
