//! Instructions and structured statements of the device IR.

use super::types::{AddrSpace, Operand, Reg, Type};
use std::fmt;

/// Binary operations. Integer semantics are wrapping; division by zero is
/// a device trap. Signed/unsigned variants are explicit (the register file
/// stores raw bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    SDiv,
    UDiv,
    SRem,
    URem,
    And,
    Or,
    Xor,
    Shl,
    LShr,
    AShr,
    SMin,
    SMax,
    UMin,
    UMax,
    /// Float-only.
    FDiv,
    FMin,
    FMax,
}

impl BinOp {
    /// Mnemonic used by the printer.
    pub fn mnemonic(self) -> &'static str {
        match self {
            BinOp::Add => "add",
            BinOp::Sub => "sub",
            BinOp::Mul => "mul",
            BinOp::SDiv => "sdiv",
            BinOp::UDiv => "udiv",
            BinOp::SRem => "srem",
            BinOp::URem => "urem",
            BinOp::And => "and",
            BinOp::Or => "or",
            BinOp::Xor => "xor",
            BinOp::Shl => "shl",
            BinOp::LShr => "lshr",
            BinOp::AShr => "ashr",
            BinOp::SMin => "smin",
            BinOp::SMax => "smax",
            BinOp::UMin => "umin",
            BinOp::UMax => "umax",
            BinOp::FDiv => "fdiv",
            BinOp::FMin => "fmin",
            BinOp::FMax => "fmax",
        }
    }
}

/// Unary operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnOp {
    /// Integer two's-complement negate / float negate (by dst type).
    Neg,
    /// Bitwise not (ints).
    Not,
    /// |x| (floats).
    FAbs,
    FSqrt,
    FExp,
    FLog,
    FSin,
    FCos,
    FFloor,
    /// 1/x (floats) — distinct op so the interpreter can model the GPU
    /// fast-reciprocal path.
    FRcp,
}

impl UnOp {
    /// Mnemonic used by the printer.
    pub fn mnemonic(self) -> &'static str {
        match self {
            UnOp::Neg => "neg",
            UnOp::Not => "not",
            UnOp::FAbs => "fabs",
            UnOp::FSqrt => "fsqrt",
            UnOp::FExp => "fexp",
            UnOp::FLog => "flog",
            UnOp::FSin => "fsin",
            UnOp::FCos => "fcos",
            UnOp::FFloor => "ffloor",
            UnOp::FRcp => "frcp",
        }
    }
}

/// Comparison predicates. `U*` are unsigned integer orders; `Lt`..`Ge` are
/// signed for ints and ordered for floats.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpPred {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    ULt,
    ULe,
    UGt,
    UGe,
}

impl CmpPred {
    /// Mnemonic used by the printer.
    pub fn mnemonic(self) -> &'static str {
        match self {
            CmpPred::Eq => "eq",
            CmpPred::Ne => "ne",
            CmpPred::Lt => "lt",
            CmpPred::Le => "le",
            CmpPred::Gt => "gt",
            CmpPred::Ge => "ge",
            CmpPred::ULt => "ult",
            CmpPred::ULe => "ule",
            CmpPred::UGt => "ugt",
            CmpPred::UGe => "uge",
        }
    }
}

/// Conversions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CastOp {
    SExt,
    ZExt,
    Trunc,
    SIToFP,
    FPToSI,
    FPExt,
    FPTrunc,
    /// Same-width reinterpret.
    Bitcast,
}

impl CastOp {
    /// Mnemonic used by the printer.
    pub fn mnemonic(self) -> &'static str {
        match self {
            CastOp::SExt => "sext",
            CastOp::ZExt => "zext",
            CastOp::Trunc => "trunc",
            CastOp::SIToFP => "sitofp",
            CastOp::FPToSI => "fptosi",
            CastOp::FPExt => "fpext",
            CastOp::FPTrunc => "fptrunc",
            CastOp::Bitcast => "bitcast",
        }
    }
}

/// A non-control instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum Inst {
    /// `dst = op a, b`
    Bin { op: BinOp, dst: Reg, a: Operand, b: Operand },
    /// `dst = op a`
    Un { op: UnOp, dst: Reg, a: Operand },
    /// `dst = cmp.pred a, b` (dst is i1)
    Cmp { pred: CmpPred, dst: Reg, a: Operand, b: Operand },
    /// `dst = select cond, a, b`
    Select { dst: Reg, cond: Operand, a: Operand, b: Operand },
    /// `dst = cast.op src` (dst type is the target type)
    Cast { op: CastOp, dst: Reg, src: Operand },
    /// `dst = src` — used by the inliner for argument binding.
    Copy { dst: Reg, src: Operand },
    /// `dst = load.<ty> space[addr]`
    Load { dst: Reg, ty: Type, space: AddrSpace, addr: Operand },
    /// `store.<ty> space[addr], val`
    Store { ty: Type, space: AddrSpace, addr: Operand, val: Operand },
    /// `dst = &@global` — address of a module global in its space.
    GlobalAddr { dst: Reg, name: String },
    /// `dst = call @callee(args...)`
    ///
    /// Resolution at execution time: module function → device-runtime
    /// binding → target intrinsic → trap. Intrinsics are calls with
    /// reserved names (`gpu.*`, `nvvm.*`, `amdgcn.*`, `payload.*`).
    Call { dst: Option<Reg>, callee: String, args: Vec<Operand> },
    /// `dst = call_indirect fn_id(args...)` — indirect call through a
    /// function id produced by the `gpu.funcref.<name>` pseudo-intrinsic.
    /// This is how outlined parallel regions are dispatched by the
    /// generic-mode state machine (warp specialization, paper ref. [8]).
    /// `fn_id` must be warp-uniform at execution time.
    CallIndirect { dst: Option<Reg>, fn_id: Operand, args: Vec<Operand> },
    /// Device-side trap with a message (the fallback `declare variant`
    /// body of the paper's Listing 4 compiles to this).
    Trap { msg: String },
}

impl Inst {
    /// Destination register, if any.
    pub fn dst(&self) -> Option<Reg> {
        match self {
            Inst::Bin { dst, .. }
            | Inst::Un { dst, .. }
            | Inst::Cmp { dst, .. }
            | Inst::Select { dst, .. }
            | Inst::Cast { dst, .. }
            | Inst::Copy { dst, .. }
            | Inst::Load { dst, .. }
            | Inst::GlobalAddr { dst, .. } => Some(*dst),
            Inst::Call { dst, .. } | Inst::CallIndirect { dst, .. } => *dst,
            Inst::Store { .. } | Inst::Trap { .. } => None,
        }
    }

    /// True if removing the instruction (when its result is unused) would
    /// change program behaviour.
    pub fn has_side_effect(&self) -> bool {
        matches!(
            self,
            Inst::Store { .. } | Inst::Call { .. } | Inst::CallIndirect { .. } | Inst::Trap { .. }
        )
    }

    /// Operands read by this instruction.
    pub fn operands(&self) -> Vec<Operand> {
        match self {
            Inst::Bin { a, b, .. } | Inst::Cmp { a, b, .. } => vec![*a, *b],
            Inst::Un { a, .. } => vec![*a],
            Inst::Select { cond, a, b, .. } => vec![*cond, *a, *b],
            Inst::Cast { src, .. } | Inst::Copy { src, .. } => vec![*src],
            Inst::Load { addr, .. } => vec![*addr],
            Inst::Store { addr, val, .. } => vec![*addr, *val],
            Inst::GlobalAddr { .. } | Inst::Trap { .. } => vec![],
            Inst::Call { args, .. } => args.clone(),
            Inst::CallIndirect { fn_id, args, .. } => {
                let mut v = vec![*fn_id];
                v.extend_from_slice(args);
                v
            }
        }
    }

    /// Apply `f` to every operand in place.
    pub fn map_operands(&mut self, mut f: impl FnMut(&mut Operand)) {
        match self {
            Inst::Bin { a, b, .. } | Inst::Cmp { a, b, .. } => {
                f(a);
                f(b);
            }
            Inst::Un { a, .. } => f(a),
            Inst::Select { cond, a, b, .. } => {
                f(cond);
                f(a);
                f(b);
            }
            Inst::Cast { src, .. } | Inst::Copy { src, .. } => f(src),
            Inst::Load { addr, .. } => f(addr),
            Inst::Store { addr, val, .. } => {
                f(addr);
                f(val);
            }
            Inst::GlobalAddr { .. } | Inst::Trap { .. } => {}
            Inst::Call { args, .. } => {
                for a in args {
                    f(a);
                }
            }
            Inst::CallIndirect { fn_id, args, .. } => {
                f(fn_id);
                for a in args {
                    f(a);
                }
            }
        }
    }

    /// Rewrite the destination register through `f`.
    pub fn map_dst(&mut self, f: impl Fn(Reg) -> Reg) {
        match self {
            Inst::Bin { dst, .. }
            | Inst::Un { dst, .. }
            | Inst::Cmp { dst, .. }
            | Inst::Select { dst, .. }
            | Inst::Cast { dst, .. }
            | Inst::Copy { dst, .. }
            | Inst::Load { dst, .. }
            | Inst::GlobalAddr { dst, .. } => *dst = f(*dst),
            Inst::Call { dst, .. } | Inst::CallIndirect { dst, .. } => {
                if let Some(d) = dst {
                    *d = f(*d);
                }
            }
            Inst::Store { .. } | Inst::Trap { .. } => {}
        }
    }
}

/// A structured statement. Function bodies are trees of these; the SIMT
/// interpreter executes them lockstep per warp with divergence masks.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    Inst(Inst),
    /// Two-armed conditional; lanes partition by `cond`.
    If { cond: Operand, then_: Vec<Stmt>, else_: Vec<Stmt> },
    /// Infinite loop; exits via `Break` (or `Return`).
    Loop { body: Vec<Stmt> },
    /// Exit the innermost enclosing loop.
    Break,
    /// Jump to the next iteration of the innermost enclosing loop.
    Continue,
    /// Return from the function.
    Return(Option<Operand>),
}

impl Stmt {
    /// Visit every instruction in the subtree.
    pub fn visit_insts<'a>(&'a self, f: &mut impl FnMut(&'a Inst)) {
        match self {
            Stmt::Inst(i) => f(i),
            Stmt::If { then_, else_, .. } => {
                for s in then_ {
                    s.visit_insts(f);
                }
                for s in else_ {
                    s.visit_insts(f);
                }
            }
            Stmt::Loop { body } => {
                for s in body {
                    s.visit_insts(f);
                }
            }
            Stmt::Break | Stmt::Continue | Stmt::Return(_) => {}
        }
    }

    /// Visit every instruction mutably.
    pub fn visit_insts_mut(&mut self, f: &mut impl FnMut(&mut Inst)) {
        match self {
            Stmt::Inst(i) => f(i),
            Stmt::If { then_, else_, .. } => {
                for s in then_ {
                    s.visit_insts_mut(f);
                }
                for s in else_ {
                    s.visit_insts_mut(f);
                }
            }
            Stmt::Loop { body } => {
                for s in body {
                    s.visit_insts_mut(f);
                }
            }
            Stmt::Break | Stmt::Continue | Stmt::Return(_) => {}
        }
    }

    /// Operands read directly by this statement's head (not the subtree).
    pub fn head_operands(&self) -> Vec<Operand> {
        match self {
            Stmt::Inst(i) => i.operands(),
            Stmt::If { cond, .. } => vec![*cond],
            Stmt::Return(Some(v)) => vec![*v],
            _ => vec![],
        }
    }
}

impl fmt::Display for Inst {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Inst::Bin { op, dst, a, b } => write!(f, "{dst} = {} {a}, {b}", op.mnemonic()),
            Inst::Un { op, dst, a } => write!(f, "{dst} = {} {a}", op.mnemonic()),
            Inst::Cmp { pred, dst, a, b } => {
                write!(f, "{dst} = cmp.{} {a}, {b}", pred.mnemonic())
            }
            Inst::Select { dst, cond, a, b } => write!(f, "{dst} = select {cond}, {a}, {b}"),
            Inst::Cast { op, dst, src } => write!(f, "{dst} = {} {src}", op.mnemonic()),
            Inst::Copy { dst, src } => write!(f, "{dst} = copy {src}"),
            Inst::Load { dst, ty, space, addr } => {
                write!(f, "{dst} = load.{ty} {space}[{addr}]")
            }
            Inst::Store { ty, space, addr, val } => {
                write!(f, "store.{ty} {space}[{addr}], {val}")
            }
            Inst::GlobalAddr { dst, name } => write!(f, "{dst} = addr_of @{name}"),
            Inst::Call { dst, callee, args } => {
                if let Some(d) = dst {
                    write!(f, "{d} = call @{callee}(")?;
                } else {
                    write!(f, "call @{callee}(")?;
                }
                for (i, a) in args.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{a}")?;
                }
                write!(f, ")")
            }
            Inst::CallIndirect { dst, fn_id, args } => {
                if let Some(d) = dst {
                    write!(f, "{d} = call_indirect {fn_id}(")?;
                } else {
                    write!(f, "call_indirect {fn_id}(")?;
                }
                for (i, a) in args.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{a}")?;
                }
                write!(f, ")")
            }
            Inst::Trap { msg } => write!(f, "trap \"{msg}\""),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::types::{Const, Operand, Reg};

    #[test]
    fn dst_and_side_effects() {
        let add = Inst::Bin {
            op: BinOp::Add,
            dst: Reg(3),
            a: Operand::i32(1),
            b: Operand::Reg(Reg(2)),
        };
        assert_eq!(add.dst(), Some(Reg(3)));
        assert!(!add.has_side_effect());

        let st = Inst::Store {
            ty: Type::F32,
            space: AddrSpace::Global,
            addr: Operand::i64(0),
            val: Operand::f32(1.0),
        };
        assert_eq!(st.dst(), None);
        assert!(st.has_side_effect());
    }

    #[test]
    fn display_forms() {
        let i = Inst::Bin {
            op: BinOp::Mul,
            dst: Reg(1),
            a: Operand::Reg(Reg(0)),
            b: Operand::Const(Const::I32(4)),
        };
        assert_eq!(i.to_string(), "%r1 = mul %r0, 4");

        let c = Inst::Call {
            dst: Some(Reg(2)),
            callee: "gpu.tid.x".into(),
            args: vec![],
        };
        assert_eq!(c.to_string(), "%r2 = call @gpu.tid.x()");
    }

    #[test]
    fn visit_insts_walks_nested_structure() {
        let body = Stmt::Loop {
            body: vec![
                Stmt::If {
                    cond: Operand::bool(true),
                    then_: vec![Stmt::Inst(Inst::Copy {
                        dst: Reg(0),
                        src: Operand::i32(1),
                    })],
                    else_: vec![Stmt::Break],
                },
                Stmt::Inst(Inst::Copy { dst: Reg(1), src: Operand::i32(2) }),
            ],
        };
        let mut n = 0;
        body.visit_insts(&mut |_| n += 1);
        assert_eq!(n, 2);
    }

    #[test]
    fn map_operands_rewrites_all() {
        let mut i = Inst::Select {
            dst: Reg(5),
            cond: Operand::Reg(Reg(1)),
            a: Operand::Reg(Reg(2)),
            b: Operand::Reg(Reg(3)),
        };
        i.map_operands(|o| {
            if let Operand::Reg(r) = o {
                *o = Operand::Reg(Reg(r.0 + 10));
            }
        });
        assert_eq!(
            i.operands(),
            vec![
                Operand::Reg(Reg(11)),
                Operand::Reg(Reg(12)),
                Operand::Reg(Reg(13))
            ]
        );
    }
}
