//! `PoolCoordinator` — the multi-device analog of [`super::Coordinator`]:
//! owns a [`DevicePool`], aggregates the per-device `nvprof`-style region
//! profiles into one report, and renders queue/throughput/cache metrics.

use crate::sched::{DevicePool, OffloadHandle, OffloadRequest, PoolConfig, PoolMetrics};
use crate::util::{Error, Summary};
use std::collections::BTreeMap;

/// One aggregated region row: per-device summaries merged.
#[derive(Debug, Clone)]
pub struct PoolRegionReport {
    /// Region name.
    pub name: String,
    /// Summary merged across every device that ran the region.
    pub summary: Summary,
    /// How many devices contributed samples.
    pub devices: usize,
}

/// A pool plus report plumbing.
pub struct PoolCoordinator {
    /// The device pool.
    pub pool: DevicePool,
}

impl PoolCoordinator {
    /// Build the pool from a config.
    pub fn new(config: &PoolConfig) -> Result<PoolCoordinator, Error> {
        Ok(PoolCoordinator { pool: DevicePool::new(config)? })
    }

    /// Submit through to the pool.
    pub fn submit(&self, req: OffloadRequest) -> Result<OffloadHandle, Error> {
        self.pool.submit(req)
    }

    /// Run a closure with a device lease (see [`DevicePool::run_on`]).
    pub fn run_on<R, F>(
        &self,
        affinity: crate::sched::Affinity,
        f: F,
    ) -> Result<crate::sched::TaskHandle<R>, Error>
    where
        R: Send + 'static,
        F: FnOnce(&crate::sched::DeviceLease<'_>) -> R + Send + 'static,
    {
        self.pool.run_on(affinity, f)
    }

    /// Current queue/throughput/cache metrics.
    pub fn metrics(&self) -> PoolMetrics {
        self.pool.metrics()
    }

    /// Named counters/gauges/histograms as a JSON document (the
    /// `--metrics-json` export; see [`DevicePool::metrics_registry`]).
    pub fn metrics_json(&self) -> String {
        self.pool.metrics_registry().to_json()
    }

    /// Drained trace as Chrome trace-event JSON (empty-event document
    /// when tracing is off; see [`DevicePool::trace_chrome_json`]).
    pub fn trace_chrome_json(&self) -> String {
        self.pool.trace_chrome_json()
    }

    /// Drained trace as the compact line-oriented replay capture (see
    /// [`DevicePool::trace_capture`]).
    pub fn trace_capture(&self) -> String {
        self.pool.trace_capture()
    }

    /// Merge every device's profiler report into per-region totals.
    pub fn region_report(&self) -> Vec<PoolRegionReport> {
        let mut merged: BTreeMap<String, (Summary, usize)> = BTreeMap::new();
        for (_, reports) in self.pool.profiler_reports() {
            for r in reports {
                let e = merged.entry(r.name.clone()).or_default();
                e.0.merge(&r.summary);
                e.1 += 1;
            }
        }
        merged
            .into_iter()
            .map(|(name, (summary, devices))| PoolRegionReport { name, summary, devices })
            .collect()
    }

    /// Render the full status report (device table with occupancy,
    /// cache, batching, adaptive-controller state, sharding, allocator,
    /// per-client fairness table, regions).
    pub fn format_report(&self) -> String {
        let m = self.metrics();
        let cache = m.cache();
        let mut out = String::new();
        let cap = if m.queue_cap == 0 { "∞".to_string() } else { m.queue_cap.to_string() };
        out.push_str(&format!(
            "pool: {} devices | queue depth {} (peak {}, cap {}) | submitted {} | completed {} | failed {}\n",
            m.devices.len(),
            m.queue_depth,
            m.peak_queue_depth,
            cap,
            m.submitted,
            m.completed,
            m.failed
        ));
        out.push_str(&format!(
            "throughput: {:.1} launches/s over {:.2}s | image cache: {} hits / {} misses ({:.1}% hit rate), {} evictions\n",
            m.throughput_per_sec(),
            m.uptime.as_secs_f64(),
            cache.hits,
            cache.misses,
            cache.hit_rate() * 100.0,
            cache.evictions
        ));
        out.push_str(&format!(
            "batching: {} jobs coalesced into multi-job batches | sharding: {} requests split into {} shard jobs | device mem live: {} B\n",
            m.batched_jobs(),
            m.sharded_requests,
            m.shard_jobs,
            m.device_live_bytes()
        ));
        if m.adaptive {
            let a = &m.adaptive_stats;
            out.push_str(&format!(
                "adaptive: on | {} decisions, avg decided batch {:.1} | fused-fill efficiency {:.2}\n",
                a.decisions,
                a.avg_decided(),
                a.efficiency
            ));
        } else {
            out.push_str("adaptive: off (static batch_max / shard fan-out)\n");
        }
        let (deadlined, missed) = m.deadline_totals();
        out.push_str(&format!(
            "slo: {} deadlined requests, {} missed | {} EDF preemptions\n",
            deadlined, missed, m.preemptions
        ));
        if m.watchdog {
            let quarantined = m
                .devices
                .iter()
                .filter(|d| d.health == crate::sched::HealthState::Quarantined)
                .count();
            out.push_str(&format!(
                "health: watchdog on | {} quarantined now | {} replans ({} pinned jobs moved) | \
                 {} retries ({} exhausted) | {} probes, {} readmissions\n",
                quarantined,
                m.replans,
                m.replanned_jobs,
                m.retries,
                m.retries_exhausted,
                m.probes,
                m.readmissions
            ));
        } else {
            out.push_str("health: watchdog off (stalled devices are waited on)\n");
        }
        if m.hedge {
            out.push_str(&format!(
                "hedge: on | {} launched, {} won, {} wasted\n",
                m.hedges, m.hedge_wins, m.hedge_wasted
            ));
        } else {
            out.push_str("hedge: off (at-risk in-flight work is not duplicated)\n");
        }
        let ts = self.pool.trace_stats();
        if ts.enabled {
            out.push_str(&format!(
                "trace: on | {} events recorded ({} dropped) across {} rings x {} slots\n",
                ts.recorded, ts.dropped, ts.rings, ts.capacity
            ));
        }
        out.push_str(
            "dev | runtime  | arch    | hlth | done  | maxbat | occ%  | images | hits/miss/evict | mem live/peak | inflight age/pred\n",
        );
        out.push_str(
            "----+----------+---------+------+-------+--------+-------+--------+-----------------+---------------+------------------\n",
        );
        for d in &m.devices {
            // Age of the batch executing *right now* vs the EWMA's
            // prediction for it — a wedged-in-flight device shows age
            // racing past pred long before the watchdog verdict flips.
            let inflight = match (d.inflight_age, d.inflight_predicted) {
                (Some(age), Some(pred)) => format!(
                    "{:.1}/{:.1} ms",
                    age.as_secs_f64() * 1e3,
                    pred.as_secs_f64() * 1e3
                ),
                _ => "-".to_string(),
            };
            out.push_str(&format!(
                "{:>3} | {:<8} | {:<7} | {:<4} | {:>5} | {:>6} | {:>5.1} | {:>6} | {}/{}/{} | {}/{} | {}\n",
                d.id,
                d.kind.to_string(),
                d.arch.to_string(),
                d.health.label(),
                d.completed,
                d.max_batch,
                d.occupancy * 100.0,
                d.cached_images,
                d.cache.hits,
                d.cache.misses,
                d.cache.evictions,
                d.mem.live_bytes,
                d.mem.peak_bytes,
                inflight
            ));
        }
        for d in &m.devices {
            if let Some(fault) = &d.fault {
                out.push_str(&format!(
                    "fault: dev {} scripted `{fault}` | injected {} time(s) | {} quarantine(s)\n",
                    d.id, d.fault_injected, d.quarantines
                ));
            }
        }
        if !m.clients.is_empty() {
            let uptime = m.uptime.as_secs_f64().max(1e-9);
            out.push_str(
                "client           | weight | slo(ms) | done  | fail | share% | req/s   | avg wait (us) | avg sojourn (us) | p50 (us)  | p95 (us)  | p99 (us)  | miss | slack avg (ms)\n",
            );
            out.push_str(
                "-----------------+--------+---------+-------+------+--------+---------+---------------+------------------+-----------+-----------+-----------+------+---------------\n",
            );
            for c in &m.clients {
                let name = if c.client.is_empty() { "(default)" } else { &c.client };
                let slo = match c.slo {
                    Some(t) => format!("{:.1}", t.as_secs_f64() * 1e3),
                    None => "-".to_string(),
                };
                out.push_str(&format!(
                    "{:<17}| {:>6.2} | {:>7} | {:>5} | {:>4} | {:>5.1} | {:>7.1} | {:>13.3} | {:>16.3} | {:>9.1} | {:>9.1} | {:>9.1} | {:>4} | {:>13.3}\n",
                    name,
                    c.weight,
                    slo,
                    c.completed,
                    c.failed,
                    m.client_share(&c.client) * 100.0,
                    c.completed as f64 / uptime,
                    c.queue_wait.avg_us(),
                    c.latency.avg_us(),
                    c.latency_p50_us(),
                    c.latency_p95_us(),
                    c.latency_p99_us(),
                    c.deadline_miss,
                    c.slack.avg_us() / 1e3
                ));
            }
        }
        let regions = self.region_report();
        if !regions.is_empty() {
            out.push_str("region            | calls  | avg (us) | total (ms) | devices\n");
            out.push_str("------------------+--------+----------+------------+--------\n");
            for r in &regions {
                out.push_str(&format!(
                    "{:<18}| {:>6} | {:>8.3} | {:>10.2} | {}\n",
                    r.name,
                    r.summary.count(),
                    r.summary.avg_us(),
                    r.summary.total_ms(),
                    r.devices
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::passes::OptLevel;
    use crate::sched::workload::scale_request;
    use crate::sched::{bytes_to_f32, Affinity};

    #[test]
    fn pool_coordinator_aggregates_regions_and_metrics() {
        let pc = PoolCoordinator::new(&PoolConfig::mixed4()).unwrap();
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut handles = vec![];
        for _ in 0..8 {
            let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
            handles.push((pc.submit(req).unwrap(), want));
        }
        for (h, want) in handles {
            let resp = h.wait().unwrap();
            assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
        }
        let m = pc.metrics();
        assert_eq!(m.submitted, 8);
        assert_eq!(m.completed, 8);
        assert_eq!(m.failed, 0);
        assert_eq!(m.cache().hits + m.cache().misses, 8);
        let regions = pc.region_report();
        let scale = regions.iter().find(|r| r.name == "scale").unwrap();
        assert_eq!(scale.summary.count(), 8);
        assert!(scale.devices >= 1);
        let text = pc.format_report();
        assert!(text.contains("hit rate"), "{text}");
        assert!(text.contains("scale"), "{text}");
        // The fairness table lists the default client with every request.
        assert!(text.contains("(default)"), "{text}");
        let def = m.clients.iter().find(|c| c.client.is_empty()).expect("default client row");
        assert_eq!(def.completed, 8);
        assert!((m.client_share("") - 1.0).abs() < 1e-12);
        // Occupancy, adaptive-controller, SLO and health state surface
        // in the report (miss + slack columns, deadline/preemption and
        // watchdog lines, per-device health column).
        assert!(text.contains("occ%"), "{text}");
        assert!(text.contains("adaptive:"), "{text}");
        assert!(text.contains("slo:"), "{text}");
        assert!(text.contains("miss"), "{text}");
        assert!(text.contains("slack avg"), "{text}");
        assert!(text.contains("p50 (us)") && text.contains("p99 (us)"), "{text}");
        // mixed4 leaves tracing off: no trace line, but the metrics
        // export still works.
        assert!(!text.contains("trace: on"), "{text}");
        let mj = pc.metrics_json();
        assert!(mj.contains("\"pool.completed\""), "{mj}");
        assert!(mj.contains("latency_us"), "{mj}");
        assert!(text.contains("health: watchdog on"), "{text}");
        assert!(text.contains("hlth"), "{text}");
        // mixed4 leaves hedging off; the report says so, and the
        // in-flight age column reads `-` once the pool has drained.
        assert!(text.contains("hedge: off"), "{text}");
        assert!(text.contains("inflight age/pred"), "{text}");
        assert_eq!((m.hedges, m.hedge_wins, m.hedge_wasted), (0, 0, 0));
        // A fault-free healthy pool: every device reads `ok`, nothing
        // quarantined, no retries.
        assert!(text.contains("| ok "), "{text}");
        assert_eq!(m.replans, 0);
        assert_eq!(m.retries, 0);
        assert!(m
            .devices
            .iter()
            .all(|d| d.health == crate::sched::HealthState::Healthy));
        // A best-effort workload has no deadlines and no misses.
        let (deadlined, missed) = m.deadline_totals();
        assert_eq!((deadlined, missed), (0, 0));
    }
}
