//! `sched` — the device-pool offload scheduler.
//!
//! The paper's runtime makes one device target cheap to bring up; this
//! layer makes *many* devices cheap to drive at once. A [`DevicePool`]
//! owns N [`crate::hostrt::OffloadDevice`]s — mixed architectures
//! (`nvptx64-sim`, `amdgcn-sim`) and mixed runtime builds (legacy,
//! portable) — behind one asynchronous submission queue. Clients
//! [`DevicePool::submit`] an [`OffloadRequest`] (module + kernel + launch
//! config + buffer mappings) and immediately get an [`OffloadHandle`]
//! future; per-device worker threads execute the requests and resolve the
//! handles.
//!
//! ## Placement policy
//!
//! Placement is **pull-based least-loaded with affinity filtering**:
//!
//! * one worker thread per device pulls from the shared FIFO queue the
//!   moment its device is free, so work naturally flows to the
//!   least-loaded device — an idle device never waits behind a busy one;
//! * each request carries an [`Affinity`] constraint (`arch` and/or
//!   runtime `kind`, both optional); a worker only claims the oldest job
//!   its device satisfies, skipping over incompatible ones so a pinned
//!   job cannot head-of-line-block the rest of the pool;
//! * a request whose affinity matches no pool device is rejected at
//!   submit time rather than queued forever.
//!
//! ## Batch lifecycle
//!
//! When a worker claims the oldest eligible job it also coalesces up to
//! `[pool] batch_max − 1` *compatible* followers — queued requests with
//! the same image-cache key (module content hash + opt level; arch and
//! runtime kind are implied by the device doing the popping). The batch
//! pays queue synchronization, image lookup (one cache access; follower
//! jobs are recorded as hits) and profiler bookkeeping once. Batches of
//! **independent** jobs — images with no global-space globals, so no
//! launch can observe another through device state — execute as one
//! *fused grid* ([`crate::sim::launch_kernel_batch`]): every block still
//! sees exactly the `(ctaid, nctaid, args)` of its own solo launch, but
//! blocks of different jobs interleave across the device's SMs, so small
//! grids stop leaving most of the device idle and the per-launch
//! thread-scope setup is paid once per batch. Images with device globals
//! fall back to sequential per-job launches inside the batch. Shard jobs
//! never batch (a batch runs on one device, which would undo the split).
//!
//! ## Shard lifecycle
//!
//! A request carrying a [`ShardSpec`] (which buffers are partitioned by
//! element range, which `Imm` argument is the element count) may be split
//! at submit time: the pool picks the matching architecture with the most
//! eligible devices, divides the element range evenly, and enqueues one
//! pinned sub-request per shard — pull-based placement then spreads them
//! across whichever of those devices are idle. A detached *stitcher*
//! collects the shard responses, copies each partitioned output into its
//! element range of the full-size buffer, sums the launch counters (max
//! for `wall`/`queue_wait`) and resolves the client handle with
//! `shards = n`. When splitting would drop any shard under
//! `[pool] shard_min_trips` elements — shard overhead would dominate —
//! the request runs unsplit on a single device (`shards = 1`).
//!
//! ## Backpressure
//!
//! The submission queue is bounded by `[pool] queue_cap` (0 = unbounded):
//! at capacity, [`DevicePool::submit`] blocks until workers drain space,
//! and [`DevicePool::try_submit`] returns [`TrySubmitError::Full`] with
//! the request handed back — the `WouldBlock` variant for callers that
//! shed load instead of waiting. `PoolMetrics::peak_queue_depth` records
//! the deepest the queue has ever been, so tests can assert boundedness.
//!
//! ## Kernel-image cache and eviction
//!
//! `prepare` (link the runtime IR library, optimize, verify, load) is the
//! expensive half of an offload. Each device worker consults an
//! [`ImageCache`] keyed by `(module content hash, arch, runtime kind, opt
//! level)` — see [`cache`] for the key-design rationale — so a kernel
//! module pays the prepare cost once per device configuration and every
//! subsequent launch of it is queue-pop + map + launch. The cache evicts
//! least-recently-used images past a `[pool] cache_budget_bytes` budget
//! (0 = unlimited); evicting the last reference to an image returns its
//! global-space allocations to the device's free-list allocator, so
//! long-lived pools hold both host and device footprint steady.
//! Hit/miss/eviction counters aggregate into [`PoolMetrics`] and the
//! [`crate::coordinator::PoolCoordinator`] report.
//!
//! ## Device leases
//!
//! [`DevicePool::run_on`] queues an arbitrary closure as a job; the
//! worker hands it a [`DeviceLease`] (exclusive use of the device plus
//! its profiler). This is how multi-launch workloads that do not fit the
//! single-launch request shape — the SPEC-analog benchmark suite behind
//! `omprt bench --pool` — run through the pool's scheduler and metrics.

pub mod cache;
pub mod pool;
pub mod workload;

pub use cache::{CacheKey, CacheStats, ImageCache};
pub use pool::{
    bytes_to_f32, f32_to_bytes, Affinity, DeviceLease, DeviceMetrics, DevicePool, DeviceSpec,
    KernelArg, MapBuf, OffloadHandle, OffloadRequest, OffloadResponse, PoolConfig, PoolMetrics,
    ShardSpec, TaskHandle, TrySubmitError,
};
