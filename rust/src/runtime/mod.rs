//! The PJRT bridge — loads AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` (JAX / Pallas, build time only) and executes
//! them from the Rust request path.
//!
//! The `xla` crate's client types are `Rc`-based (`!Send`), so a single
//! dedicated **service thread** owns the `PjRtClient` and every compiled
//! executable; the rest of the system (including warp threads hitting
//! `payload.*` call sites) talks to it through channels. This serializes
//! payload launches, which is also the honest model of one device stream.

pub mod artifact;
pub mod payload;
pub mod pjrt;

pub use artifact::{ArtifactManifest, ArtifactSpec};
pub use payload::install_payloads;
pub use pjrt::PjrtService;
