//! BENCH (paper Table 1): miniqmc_sync_move per-region profile under both
//! runtime builds.

use omprt::benchmarks::harness::{format_table1, run_table1};
use omprt::benchmarks::Scale;
use omprt::runtime::{artifact, ArtifactManifest};
use omprt::sim::Arch;

fn main() {
    let Ok(man) = ArtifactManifest::load(&artifact::default_dir()) else {
        eprintln!("table1 needs artifacts: run `make artifacts`");
        return;
    };
    let rows = run_table1(Arch::Nvptx64, Scale::Paper, &man).unwrap();
    println!("\n=== Table 1: miniqmc_sync_move target-region profile ===\n");
    print!("{}", format_table1(&rows));
}
