"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has a reference implementation here
written with plain `jax.numpy` ops. pytest (python/tests/) asserts
`assert_allclose(pallas(x), ref(x))` over hypothesis-generated inputs —
this is the core correctness signal for the L1 layer.
"""

import jax.numpy as jnp

# Five-point stencil coefficients (heat diffusion step).
STENCIL_C = 0.5
STENCIL_N = 0.125


def stencil_tile(inp):
    """One Jacobi step on a (R+2, C) slab.

    Input rows 0 and R+1 are the halo; output has R rows and the same C
    columns, with the edge columns (0 and C-1) passed through unchanged so
    that the result can be written back contiguously.
    """
    r = inp.shape[0] - 2
    center = inp[1 : r + 1, :]
    up = inp[0:r, :]
    down = inp[2 : r + 2, :]
    out = center
    interior = (
        STENCIL_C * center[:, 1:-1]
        + STENCIL_N * (up[:, 1:-1] + down[:, 1:-1] + center[:, :-2] + center[:, 2:])
    )
    out = out.at[:, 1:-1].set(interior)
    return out


def vgh_matmul(basis, coef):
    """miniQMC `evaluate_vgh` core: (10·P, B) basis-derivative planes times
    (B, O) orbital coefficients → (10·P, O) value/gradient/hessian planes.

    The B-spline gather+weights are evaluated on the device (IR side);
    the heavy contraction is this matmul — the MXU-shaped part.
    """
    return jnp.matmul(basis, coef, preferred_element_type=jnp.float32)


def detratio_tile(u, inv_row):
    """miniQMC `evaluateDetRatios`: ratio_k = dot(u_k, psiM_inv_row)."""
    return jnp.matmul(u, inv_row)
