//! Chaos battery for the pool's failure half: scripted device faults
//! (stall / transient failure / permanent death — `sim::fault`) driving
//! the health machinery (watchdog, quarantine, preemptive shard
//! re-planning, bounded retry, probe re-admission — `sched::health` +
//! `sched::pool`).
//!
//! Since the `util::vclock` PR the battery runs on **virtual time**: the
//! `virtual_*` tests inject a discrete-event [`VirtualClock`] via
//! `PoolConfig::with_clock`, so every multi-second scripted stall, hedge
//! window and probe cadence costs zero wall time — CI runs them as the
//! named "Pool virtual-time chaos" step (`cargo test --test pool_chaos
//! virtual`). One wall-clock smoke per lifecycle stays behind
//! (`wall_*`, plus the dead-device and retry-cap tests) so the default
//! clock path keeps end-to-end coverage.
//!
//! The virtual soak is the headline: 100,000 launches across a
//! simulated hour of mixed fault/SLO/hedge traffic, finishing in
//! seconds of wall time, with the exactly-once ledger invariants
//! asserted at the end:
//!
//! * every accepted request **completes or fails deterministically** —
//!   `completed + failed` equals what the clients submitted;
//! * reservation counters all drain to 0 (re-planning rebalances, never
//!   leaks);
//! * the hedge ledger balances (`hedges == hedge_wins + hedge_wasted`);
//! * no deadline is judged twice (per-client slack sample count equals
//!   the deadline count).
//!
//! The determinism test is the other new capability: two identical
//! seeded chaos runs on fresh virtual clocks must produce byte-identical
//! `# omprt-capture v1` exports (same request ids, same `t_us`, same
//! shard fan-outs) and identical outcome counters — the capture-level
//! determinism contract documented in ARCHITECTURE.md "Virtual time".
//!
//! The trace battery re-runs the soak shape with event tracing on and
//! judges *span completeness*: every accepted request must show exactly
//! one `Submit` and exactly one terminal `Done` on the drained timeline
//! — through retries, re-plans, stranded sweeps and stitchers — with
//! retry attempts 1-based and increasing, and zero ring drops. The
//! hedge battery (`*hedge*` — CI runs these by name) proves the
//! exactly-once ledger with speculative re-execution on.

use omprt::coordinator::PoolCoordinator;
use omprt::devrt::RuntimeKind;
use omprt::ir::passes::OptLevel;
use omprt::sched::workload::{saxpy_request, scale_request, sharded_scale_request};
use omprt::sched::{
    bytes_to_f32, replay_capture, Affinity, HealthState, OffloadHandle, PoolConfig, ReplayOptions,
};
use omprt::sim::Arch;
use omprt::trace::{parse_capture, validate_chrome_trace, EventKind};
use omprt::util::clock::{self, Clock, Participant, WallClock};
use omprt::util::VirtualClock;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Duration;

/// Poll `metrics()` until `pred` holds or `timeout` passes *on the
/// given clock*; returns whether it held. On a [`VirtualClock`] the
/// 5 ms poll sleeps are what pace virtual time while the driver waits,
/// so the predicate is re-checked every time the timeline moves.
fn wait_for(
    clock: &dyn Clock,
    pc: &PoolCoordinator,
    timeout: Duration,
    pred: impl Fn(&omprt::sched::PoolMetrics) -> bool,
) -> bool {
    let t0 = clock.now();
    loop {
        if pred(&pc.metrics()) {
            return true;
        }
        if clock.now().saturating_duration_since(t0) > timeout {
            return false;
        }
        clock.sleep(Duration::from_millis(5));
    }
}

#[test]
fn virtual_thousand_launch_chaos_soak() {
    const TOTAL: usize = 1000;
    const ELEMS: usize = 192;
    // The driver registers with the virtual clock: while it is runnable
    // time is frozen, and its blocking waits (backpressure, handle
    // replies) are the idle windows that let the timeline advance
    // through the scripted 600 ms stalls at zero wall cost.
    let vc = Arc::new(VirtualClock::new());
    let _driver = Participant::new(&*vc);
    // Mixed pool: dev0 portable:nvptx64, dev1 portable:amdgcn,
    // dev2 legacy:nvptx64 (never faulted — the always-healthy fallback),
    // dev3 legacy:amdgcn.
    let cfg = PoolConfig::mixed4()
        .with_queue_cap(64)
        .with_batch_max(4)
        .with_watchdog_min_ms(100)
        .with_retry_max(2)
        .with_client_slo("slo", 250.0)
        .with_clock(vc.clone())
        .with_fault_spec("0=fail:25@launch:40")
        .unwrap()
        .with_fault_spec("1=stall:600ms:1500ms@launch:30")
        .unwrap()
        .with_fault_spec("3=die@launch:60")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let clients = ["c0", "c1", "c2", "slo"];
    let mut handles: Vec<(String, OffloadHandle, Vec<f32>)> = vec![];
    let mut accepted: HashMap<String, u64> = HashMap::new();
    let mut rejected = 0u64;
    for i in 0..TOTAL {
        let client = clients[i % clients.len()].to_string();
        let (mut req, want) = if i % 50 == 17 {
            // Cross-device sharded request (16K elems, partitioned).
            let data: Vec<f32> = (0..16 * 1024).map(|k| ((k + i) % 83) as f32).collect();
            sharded_scale_request(&data, Affinity::any(), OptLevel::O2)
        } else if i % 37 == 5 {
            // Pinned to the arch+runtime only the dying device serves:
            // before its death these run there; afterwards they fail
            // deterministically (at submit or via the stranded sweep)
            // instead of waiting on a dead device forever.
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 89) as f32).collect();
            scale_request(
                &data,
                Affinity { arch: Some(Arch::Amdgcn), kind: Some(RuntimeKind::Legacy) },
                OptLevel::O2,
            )
        } else if i % 2 == 0 {
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 83) as f32).collect();
            scale_request(&data, Affinity::any(), OptLevel::O2)
        } else {
            let x: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
            let y: Vec<f32> = (0..ELEMS).map(|k| ((k * 3 + i) % 59) as f32).collect();
            saxpy_request(0.5, &x, &y, Affinity::any(), OptLevel::O2)
        };
        req.client = client.clone();
        match pc.submit(req) {
            Ok(h) => {
                *accepted.entry(client.clone()).or_default() += 1;
                handles.push((client, h, want));
            }
            Err(e) => {
                // Only the dead-device-only affinity may be turned away,
                // and only with the fail-fast quarantine error.
                assert!(
                    e.to_string().contains("quarantined"),
                    "unexpected submit rejection: {e}"
                );
                rejected += 1;
            }
        }
    }

    // Every accepted request resolves: success with the right data, or
    // a deterministic error.
    let mut ok: HashMap<String, u64> = HashMap::new();
    let mut failed: HashMap<String, u64> = HashMap::new();
    for (client, h, want) in handles {
        match h.wait() {
            Ok(resp) => {
                assert_eq!(
                    bytes_to_f32(resp.buffers[0].as_ref().unwrap()),
                    want,
                    "chaos survivor must still compute the right answer"
                );
                *ok.entry(client).or_default() += 1;
            }
            Err(_) => {
                *failed.entry(client).or_default() += 1;
            }
        }
    }
    pc.pool.quiesce();

    let m = pc.metrics();
    // Per-client accounting is exact: completed + failed == accepted.
    for client in clients {
        let a = accepted.get(client).copied().unwrap_or(0);
        let cm = m.clients.iter().find(|c| c.client == client);
        let (done, fail) = cm.map_or((0, 0), |c| (c.completed, c.failed));
        assert_eq!(
            done + fail,
            a,
            "client {client}: completed {done} + failed {fail} != accepted {a}"
        );
        assert_eq!(done, ok.get(client).copied().unwrap_or(0), "client {client} completions");
        assert_eq!(
            fail,
            failed.get(client).copied().unwrap_or(0),
            "client {client} failures"
        );
        // No deadline judged twice: exactly one signed-slack sample per
        // deadlined request.
        let cm = cm.expect("every client saw traffic");
        assert_eq!(
            cm.slack.count(),
            cm.deadlines,
            "client {client}: slack samples must equal deadlined requests"
        );
        if client == "slo" {
            assert_eq!(cm.deadlines, a, "every accepted slo request carries a deadline");
        } else {
            assert_eq!(cm.deadlines, 0, "best-effort client {client} has no deadlines");
        }
    }

    // Queue fully drained, reservations rebalanced to zero everywhere.
    assert_eq!(m.queue_depth, 0);
    for d in &m.devices {
        assert_eq!(d.reserved, 0, "device {} leaks a reservation", d.id);
    }

    // The dead device ends the run Quarantined (its probes can never
    // pass) and the incidents are visible.
    assert_eq!(m.devices[3].health, HealthState::Quarantined, "dead device stays out");
    assert!(m.devices[3].quarantines >= 1);
    assert!(m.devices[1].quarantines >= 1, "stalled device must have been quarantined");
    assert!(m.devices[0].fault_injected >= 1, "transient-failure script must have fired");
    assert!(m.retries >= 1, "transient failures must have been retried elsewhere");
    // dev2 never carries a fault script.
    assert!(m.devices[2].fault.is_none());

    let report = pc.format_report();
    assert!(report.contains("quar"), "quarantine must surface in the report:\n{report}");
    assert!(report.contains("health: watchdog on"), "{report}");
    assert!(report.contains("fault: dev 3"), "fault echo must surface:\n{report}");

    // The always-healthy fallback plus retry kept the pool useful: the
    // only hard failures permitted are (a) requests pinned to the dead
    // device's unique (kind, arch) and (b) sharded requests whose
    // shards were stranded on quarantined amdgcn devices. Anything
    // with a healthy-device escape hatch must have succeeded.
    let any_failed: u64 = ["c0", "c1", "c2", "slo"]
        .iter()
        .map(|c| failed.get(*c).copied().unwrap_or(0))
        .sum();
    let pinned_accepted: u64 = (0..TOTAL)
        .filter(|i| i % 50 != 17 && i % 37 == 5)
        .count() as u64;
    let sharded: u64 = (0..TOTAL).filter(|i| i % 50 == 17).count() as u64;
    assert!(
        any_failed <= pinned_accepted + sharded + rejected,
        "failures ({any_failed}) exceed the deterministic fault budget \
         ({pinned_accepted} dead-pinned + {sharded} sharded + {rejected} rejected)"
    );
}

#[test]
fn virtual_trace_spans_complete_after_chaos_soak() {
    const TOTAL: usize = 1000;
    const ELEMS: usize = 192;
    // The headline soak's fault script, with tracing on and rings sized
    // so nothing can be dropped (asserted below). Virtual time: the
    // trace timestamps come from the injected clock too, so the drained
    // timeline is stamped in virtual nanoseconds.
    let vc = Arc::new(VirtualClock::new());
    let _driver = Participant::new(&*vc);
    let cfg = PoolConfig::mixed4()
        .with_queue_cap(64)
        .with_batch_max(4)
        .with_watchdog_min_ms(100)
        .with_retry_max(2)
        .with_client_slo("slo", 250.0)
        .with_trace(true)
        .with_trace_capacity(1 << 15)
        .with_clock(vc.clone())
        .with_fault_spec("0=fail:25@launch:40")
        .unwrap()
        .with_fault_spec("1=stall:600ms:1500ms@launch:30")
        .unwrap()
        .with_fault_spec("3=die@launch:60")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();
    assert!(pc.pool.trace_enabled());

    let clients = ["c0", "c1", "c2", "slo"];
    let mut accepted = 0u64;
    let mut handles = vec![];
    for i in 0..TOTAL {
        let (mut req, _) = if i % 50 == 17 {
            let data: Vec<f32> = (0..16 * 1024).map(|k| ((k + i) % 83) as f32).collect();
            sharded_scale_request(&data, Affinity::any(), OptLevel::O2)
        } else if i % 37 == 5 {
            // Pinned to the dying device's unique (kind, arch).
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 89) as f32).collect();
            scale_request(
                &data,
                Affinity { arch: Some(Arch::Amdgcn), kind: Some(RuntimeKind::Legacy) },
                OptLevel::O2,
            )
        } else {
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 83) as f32).collect();
            scale_request(&data, Affinity::any(), OptLevel::O2)
        };
        req.client = clients[i % clients.len()].to_string();
        if let Ok(h) = pc.submit(req) {
            accepted += 1;
            handles.push(h);
        }
    }
    // Resolve everything; success vs deterministic failure is judged by
    // the headline soak — here only the spans matter.
    for h in handles {
        let _ = h.wait();
    }
    pc.pool.quiesce();

    let snap = pc.pool.trace_snapshot();
    assert_eq!(snap.stats.dropped, 0, "rings sized for the soak must drop nothing");

    let mut submits: HashMap<u64, usize> = HashMap::new();
    let mut dones: HashMap<u64, usize> = HashMap::new();
    let mut sharded: HashSet<u64> = HashSet::new();
    let mut retries: HashMap<u64, Vec<u64>> = HashMap::new();
    for r in &snap.records {
        match r.kind {
            EventKind::Submit => *submits.entry(r.req).or_default() += 1,
            EventKind::Done => *dones.entry(r.req).or_default() += 1,
            EventKind::ShardPlanned => {
                sharded.insert(r.req);
            }
            EventKind::Retry => retries.entry(r.req).or_default().push(r.a),
            _ => {}
        }
    }
    // One Submit per accepted request (Submit is emitted only after
    // acceptance, so rejected dead-pinned requests leave no span)...
    assert_eq!(submits.len() as u64, accepted, "one span root per accepted request");
    // ...and exactly one terminal Done per span, no matter how the
    // request ended: batch completion, retry rescue, stranded sweep or
    // stitcher. Sharded requests terminate once, at their stitcher.
    for (rid, n) in &submits {
        assert_eq!(*n, 1, "request {rid} submitted more than once");
        assert_eq!(
            dones.get(rid).copied().unwrap_or(0),
            1,
            "request {rid} must terminate exactly once"
        );
    }
    assert_eq!(dones.len(), submits.len(), "no Done without a matching Submit");

    // Retries reuse the parent's id with a 1-based attempt bounded by
    // retry_max. Shard fan-outs share one id across shard jobs, so only
    // unsharded requests promise strict attempt monotonicity.
    let m = pc.metrics();
    assert!(m.retries >= 1, "the fault script must provoke retries");
    for (rid, attempts) in &retries {
        assert!(submits.contains_key(rid), "Retry for unknown request {rid}");
        assert!(
            attempts.iter().all(|&a| a >= 1 && a <= 2),
            "request {rid}: attempts {attempts:?} outside 1..=retry_max"
        );
        if !sharded.contains(rid) {
            assert_eq!(attempts[0], 1, "request {rid}: first retry is attempt 1");
            for w in attempts.windows(2) {
                assert!(
                    w[1] > w[0],
                    "request {rid}: attempts {attempts:?} must increase"
                );
            }
        }
    }

    // Deadline judgments mirror the metrics: one per deadlined request,
    // and only the SLO client carries deadlines.
    let slo = m.clients.iter().find(|c| c.client == "slo").expect("slo client traffic");
    assert_eq!(snap.count(EventKind::DeadlineJudged) as u64, slo.deadlines);
}

#[test]
fn trace_shard_and_capture_exports() {
    // Fault-free uniform pool on the default wall clock: sharding spans
    // all four devices and the exports can be checked deterministically.
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4)
        .with_shard_min_trips(2048)
        .with_client_slo("slo", 250.0)
        .with_trace(true);
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let data: Vec<f32> = (0..256).map(|k| k as f32).collect();
    let mut handles = vec![];
    for i in 0..8 {
        let (mut req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        req.client = if i % 2 == 0 { "slo".to_string() } else { "bulk".to_string() };
        handles.push((pc.submit(req).unwrap(), want));
    }
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    let big: Vec<f32> = (0..16 * 1024).map(|k| (k % 97) as f32).collect();
    let (req, want) = sharded_scale_request(&big, Affinity::any(), OptLevel::O2);
    let resp = pc.submit(req).unwrap().wait().unwrap();
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    assert!(resp.shards >= 2, "a 4-device uniform pool must shard, got {}", resp.shards);
    pc.pool.quiesce();

    let snap = pc.pool.trace_snapshot();
    assert_eq!(snap.stats.dropped, 0);
    // One ShardPlanned, fan-out matching the response; every shard
    // launch carries the *parent's* request id (shards never batch, so
    // launches and shards correspond one to one).
    let planned: Vec<_> =
        snap.records.iter().filter(|r| r.kind == EventKind::ShardPlanned).collect();
    assert_eq!(planned.len(), 1);
    let parent = planned[0].req;
    assert_eq!(planned[0].a, resp.shards as u64);
    let shard_launches = snap
        .records
        .iter()
        .filter(|r| r.kind == EventKind::LaunchStart && r.req == parent)
        .count();
    assert_eq!(shard_launches, resp.shards, "one launch per shard, all under the parent id");
    let stitches: Vec<_> = snap.records.iter().filter(|r| r.kind == EventKind::Stitch).collect();
    assert_eq!(stitches.len(), 1);
    assert_eq!(stitches[0].req, parent);
    assert_eq!(stitches[0].a, resp.shards as u64);
    assert_eq!(stitches[0].b, 1, "a fault-free stitch succeeds");
    assert_eq!(
        snap.records.iter().filter(|r| r.kind == EventKind::Done && r.req == parent).count(),
        1,
        "a sharded request terminates once, at its stitcher"
    );
    // Half the plain requests ran under the SLO tag: each judged once.
    assert_eq!(snap.count(EventKind::DeadlineJudged), 4);

    // The Chrome export is structurally valid: parseable JSON, a
    // traceEvents array, matched B/E pairs per (pid, tid) track.
    let chrome = pc.trace_chrome_json();
    let n = validate_chrome_trace(&chrome).expect("chrome export must validate");
    assert!(n > 0, "the export must carry events");

    // The replay capture holds one line per accepted request; the
    // sharded parent carries its fan-out and arch, and only SLO-tagged
    // requests carry a deadline budget.
    let capture = pc.trace_capture();
    let lines: Vec<&str> = capture.lines().filter(|l| !l.starts_with('#')).collect();
    assert_eq!(lines.len(), 9, "8 plain + 1 sharded accepted requests:\n{capture}");
    for l in &lines {
        assert!(l.starts_with("req="), "malformed capture line: {l}");
        for field in ["t_us=", "client=", "key=0x", "deadline_us=", "shards=", "arch="] {
            assert!(l.contains(field), "capture line missing {field}: {l}");
        }
    }
    let parent_line = lines
        .iter()
        .find(|l| l.starts_with(&format!("req={parent} ")))
        .expect("sharded parent must appear in the capture");
    assert!(parent_line.contains(&format!("shards={}", resp.shards)), "{parent_line}");
    assert!(parent_line.contains("arch=nvptx64"), "{parent_line}");
    assert!(parent_line.contains("deadline_us=-"), "{parent_line}");
    assert!(
        lines.iter().any(|l| l.contains("client=slo") && !l.contains("deadline_us=-")),
        "SLO requests must carry a deadline budget:\n{capture}"
    );
}

/// The capture-level determinism contract, end to end: two chaos runs
/// with identical configs and fresh virtual clocks must export
/// byte-identical `# omprt-capture v1` documents and identical outcome
/// counters. While the registered driver is runnable virtual time is
/// frozen, so every `Submit` is stamped `t_us=0` in driver order with
/// sequential request ids; the shard fan-out is pinned to 2 by sizing
/// the sharded payload at exactly `2 x shard_min_trips` elements (the
/// element bound dominates the idle-device sample, which is the only
/// schedule-dependent input). Hedge/retry racing may place work
/// differently between runs — the capture and the completed/failed
/// ledger must not notice.
fn deterministic_chaos_run() -> (String, u64, u64, u64) {
    const TOTAL: usize = 300;
    let vc = Arc::new(VirtualClock::new());
    let _driver = Participant::new(&*vc);
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4)
        .with_queue_cap(0)
        .with_batch_max(4)
        .with_watchdog_min_ms(100)
        .with_retry_max(2)
        .with_client_slo("slo", 250.0)
        .with_hedge(true)
        .with_hedge_after_factor(3)
        .with_hedge_max(2)
        .with_trace(true)
        .with_trace_capacity(1 << 14)
        .with_clock(vc.clone())
        .with_fault_spec("0=fail:10@launch:5")
        .unwrap()
        // 50 ms stalls stay below the 200 ms quarantine threshold: the
        // stalled device remains eligible, so the shard planner's
        // eligible set — and with it the fan-out — never changes.
        .with_fault_spec("1=stall:50ms:400ms@launch:10")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let clients = ["alpha", "bulk", "slo"];
    let mut handles = vec![];
    for i in 0..TOTAL {
        let (mut req, _want) = if i % 40 == 7 {
            // Exactly 2 x shard_min_trips (4096) elements: max_by_elems
            // == 2 pins the fan-out whatever the idle sample says.
            let data: Vec<f32> = (0..8192).map(|k| ((k + i) % 83) as f32).collect();
            sharded_scale_request(&data, Affinity::any(), OptLevel::O2)
        } else {
            let data: Vec<f32> = (0..96).map(|k| ((k + i) % 89) as f32).collect();
            scale_request(&data, Affinity::any(), OptLevel::O2)
        };
        req.client = clients[i % clients.len()].to_string();
        handles.push(pc.submit(req).expect("an unbounded queue accepts everything"));
    }
    for h in handles {
        h.wait().expect("a uniform pool with retries loses nothing to these faults");
    }
    pc.pool.quiesce();
    let m = pc.metrics();
    (pc.trace_capture(), m.submitted, m.completed, m.failed)
}

#[test]
fn virtual_identical_runs_produce_identical_captures() {
    let (cap1, sub1, done1, fail1) = deterministic_chaos_run();
    let (cap2, sub2, done2, fail2) = deterministic_chaos_run();

    // Structure first, so a mismatch fails with a readable cause.
    let lines: Vec<&str> = cap1.lines().filter(|l| !l.starts_with('#')).collect();
    assert_eq!(lines.len(), 300, "one capture line per accepted request");
    for l in &lines {
        assert!(
            l.contains("t_us=0.000 "),
            "submission happens under frozen virtual time: {l}"
        );
    }
    assert!(
        lines.iter().any(|l| l.contains("shards=2")),
        "the sharded parents must record the pinned fan-out:\n{cap1}"
    );

    assert_eq!(cap1, cap2, "two identical virtual-time runs must capture identically");
    assert_eq!((sub1, done1, fail1), (sub2, done2, fail2), "outcome counters must agree");
    assert_eq!(sub1, 300);
    assert_eq!(fail1, 0, "fail faults are always rescued by retry on a uniform pool");
}

/// The long-horizon soak the virtual clock unlocks: 100,000 launches of
/// mixed fault/SLO/hedge traffic spread across a simulated hour — 100
/// bursts of 1,000 requests with a 37 s virtual gap between bursts — in
/// seconds of wall time. The scripted stalls, hedge windows, watchdog
/// cadence and inter-burst idle gaps all elapse on the virtual
/// timeline; the only wall time spent is the actual kernel execution.
#[test]
fn virtual_hour_soak_hundred_thousand_launches() {
    const BURSTS: usize = 100;
    const PER_BURST: usize = 1000;
    // Tiny payloads: the wall cost of this test is pure launch overhead
    // x 100k, so keep per-launch data movement minimal.
    const ELEMS: usize = 32;
    let vc = Arc::new(VirtualClock::new());
    let _driver = Participant::new(&*vc);
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4)
        .with_queue_cap(256)
        .with_batch_max(8)
        // A conservative watchdog floor keeps the monitor tick at its
        // 50 ms clamp: the hour-long timeline is then ~72k monitor
        // wakeups, not millions.
        .with_watchdog_min_ms(400)
        .with_retry_max(2)
        .with_client_slo("slo", 250.0)
        .with_hedge(true)
        .with_hedge_after_factor(3)
        .with_hedge_max(3)
        .with_clock(vc.clone())
        .with_fault_spec("0=fail:50@launch:200")
        .unwrap()
        .with_fault_spec("1=stall:300ms:2s@launch:500")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let clients = ["c0", "c1", "slo"];
    let mut accepted = 0u64;
    let mut ok = 0u64;
    let mut err = 0u64;
    let x: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
    for burst in 0..BURSTS {
        let mut handles = Vec::with_capacity(PER_BURST);
        for i in 0..PER_BURST {
            let (mut req, _want) = if (burst + i) % 2 == 0 {
                let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 83) as f32).collect();
                scale_request(&data, Affinity::any(), OptLevel::O2)
            } else {
                let y: Vec<f32> = (0..ELEMS).map(|k| ((k * 3 + burst) % 59) as f32).collect();
                saxpy_request(0.5, &x, &y, Affinity::any(), OptLevel::O2)
            };
            req.client = clients[i % clients.len()].to_string();
            // Backpressure (cap 256) parks the driver in an idle window;
            // virtual time advances through any concurrent stall.
            handles.push(pc.submit(req).expect("uniform pool accepts Affinity::any"));
            accepted += 1;
        }
        for h in handles {
            match h.wait() {
                Ok(_) => ok += 1,
                Err(_) => err += 1,
            }
        }
        // The idle gap between bursts: pure virtual time. 100 of these
        // alone push the timeline past the one-hour mark.
        vc.sleep(Duration::from_secs(37));
    }
    pc.pool.quiesce();
    // A losing speculative copy may still be draining when quiesce
    // returns (quiesce waits for *requests*, not copies).
    assert!(wait_hedges_resolved(&*vc, &pc), "hedge ledger never resolved");

    let m = pc.metrics();
    assert_eq!(accepted, (BURSTS * PER_BURST) as u64);
    assert_eq!(m.submitted, accepted, "every request admitted exactly once");
    // The exactly-once ledger, after 100k launches and a simulated hour:
    // completed + failed == accepted, nothing double-resolved, nothing
    // lost.
    assert_eq!(
        m.completed + m.failed,
        accepted,
        "ledger must balance: {} completed + {} failed != {accepted}",
        m.completed,
        m.failed
    );
    assert_eq!(m.completed, ok, "pool and client views of success agree");
    assert_eq!(m.failed, err, "pool and client views of failure agree");
    assert_eq!(m.queue_depth, 0, "a drained soak leaves nothing queued");
    for d in &m.devices {
        assert_eq!(d.reserved, 0, "device {} leaks a reservation", d.id);
    }
    assert_eq!(
        m.hedges,
        m.hedge_wins + m.hedge_wasted,
        "every speculative duplicate is judged exactly once"
    );
    for c in &m.clients {
        assert_eq!(
            c.slack.count(),
            c.deadlines,
            "client {}: one deadline judgment per deadlined request",
            c.client
        );
    }
    assert!(
        vc.elapsed() >= Duration::from_secs(3600),
        "the soak must span a simulated hour, got {:?}",
        vc.elapsed()
    );
}

#[test]
fn virtual_stalled_device_quarantines_shards_replan_and_probe_readmits() {
    // Uniform pool so sharding spans all four devices; device 2 wedges
    // hard (600ms hangs for 1.5s) after a handful of launches. On the
    // virtual clock the stall, the watchdog judgment and the probe
    // cadence all elapse in virtual time — the 20 s predicates below
    // are virtual seconds, paced by the driver's poll sleeps.
    let vc = Arc::new(VirtualClock::new());
    let _driver = Participant::new(&*vc);
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4)
        .with_batch_max(4)
        .with_watchdog_min_ms(100)
        .with_shard_min_trips(2048)
        .with_clock(vc.clone())
        .with_fault_spec("2=stall:600ms:1500ms@launch:6")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    // Enough traffic to walk device 2 past launch 6 mid-run.
    let data: Vec<f32> = (0..256).map(|k| k as f32).collect();
    let mut handles = vec![];
    for i in 0..64 {
        let (mut req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        req.client = format!("burst{}", i % 2);
        handles.push((pc.submit(req).unwrap(), want));
    }

    // The watchdog must catch the wedged device while the stall is
    // still in progress.
    assert!(
        wait_for(&*vc, &pc, Duration::from_secs(20), |m| {
            m.devices[2].health == HealthState::Quarantined
        }),
        "watchdog never quarantined the stalled device: {:?}",
        pc.metrics().devices.iter().map(|d| d.health).collect::<Vec<_>>()
    );

    // A sharded request planned *now* must route around the quarantined
    // device and still finish correctly.
    let big: Vec<f32> = (0..16 * 1024).map(|k| (k % 97) as f32).collect();
    let (req, want) = sharded_scale_request(&big, Affinity::any(), OptLevel::O2);
    let resp = pc.submit(req).unwrap().wait().unwrap();
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    assert_ne!(resp.device_id, 2, "a quarantined device must serve no shard");

    // Every pre-stall request still completes (the wedged batch finishes
    // once its injected hang ends; nothing is lost).
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    pc.pool.quiesce();

    // Once the scripted window closes, the probe readmits the device.
    assert!(
        wait_for(&*vc, &pc, Duration::from_secs(20), |m| {
            m.devices[2].health == HealthState::Healthy
        }),
        "probe must readmit the device after its stall window"
    );
    let m = pc.metrics();
    assert!(m.probes >= 1, "re-admission requires probes");
    assert!(m.readmissions >= 1);
    assert!(m.devices[2].quarantines >= 1);
    assert!(m.devices[2].fault_injected >= 1);
    for d in &m.devices {
        assert_eq!(d.reserved, 0, "device {} leaks a reservation", d.id);
    }
    assert_eq!(m.failed, 0, "a stall must delay work, never lose it");
}

/// Wall-clock smoke for the stall -> quarantine -> probe -> readmit
/// lifecycle: the virtual battery carries the heavy variants, this keeps
/// the default-clock path covered end to end with a sub-second script.
#[test]
fn wall_stall_smoke_quarantine_and_readmit() {
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 2)
        .with_batch_max(1)
        .with_watchdog_min_ms(50)
        .with_fault_spec("0=stall:250ms:600ms@launch:3")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let data: Vec<f32> = (0..128).map(|k| k as f32).collect();
    let mut handles = vec![];
    for _ in 0..16 {
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        handles.push((pc.submit(req).unwrap(), want));
    }
    assert!(
        wait_for(&WallClock, &pc, Duration::from_secs(10), |m| {
            m.devices[0].health == HealthState::Quarantined
        }),
        "watchdog must quarantine the wedged device on the wall clock too"
    );
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    pc.pool.quiesce();
    assert!(
        wait_for(&WallClock, &pc, Duration::from_secs(10), |m| {
            m.devices[0].health == HealthState::Healthy
        }),
        "probe must readmit once the wall-clock window closes"
    );
    let m = pc.metrics();
    assert_eq!(m.failed, 0, "a stall must delay work, never lose it");
    assert!(m.devices[0].quarantines >= 1);
    assert!(m.readmissions >= 1);
}

#[test]
fn dead_device_work_retries_onto_healthy_devices() {
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 2)
        .with_batch_max(4)
        .with_watchdog_min_ms(100)
        .with_retry_max(2)
        .with_fault_spec("0=die@launch:2")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let data: Vec<f32> = (0..128).map(|k| k as f32).collect();
    let mut handles = vec![];
    for _ in 0..40 {
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        handles.push((pc.submit(req).unwrap(), want));
    }
    for (h, want) in handles {
        let resp = h.wait().expect("every request must be rescued by retry");
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    pc.pool.quiesce();

    let m = pc.metrics();
    assert_eq!(m.failed, 0, "with a healthy sibling, death must cost nothing");
    assert!(m.retries >= 1, "jobs claimed by the dead device must have been retried");
    assert_eq!(m.retries_exhausted, 0);
    // The dead device is quarantined by its fault streak and stays out
    // (its probes never pass).
    assert!(
        wait_for(&WallClock, &pc, Duration::from_secs(20), |m| {
            m.devices[0].health == HealthState::Quarantined
        }),
        "fault streak must quarantine the dead device"
    );
    clock::sleep(Duration::from_millis(250));
    assert_eq!(
        pc.metrics().devices[0].health,
        HealthState::Quarantined,
        "probes must never readmit a dead device"
    );
    let report = pc.format_report();
    assert!(report.contains("die"), "the fault echo names the script:\n{report}");
}

/// Poll until every device is idle (no in-flight batch) and the hedge
/// ledger has resolved (`hedges == hedge_wins + hedge_wasted`). Quiesce
/// returns when every *request* has terminated, but a losing copy may
/// still be executing — trace and counter assertions must wait it out.
fn wait_hedges_resolved(clock: &dyn Clock, pc: &PoolCoordinator) -> bool {
    wait_for(clock, pc, Duration::from_secs(30), |m| {
        m.devices.iter().all(|d| d.inflight_age.is_none())
            && m.hedges == m.hedge_wins + m.hedge_wasted
    })
}

#[test]
fn virtual_stalled_inflight_job_is_hedged_and_wins() {
    // Two uniform devices; dev0 wedges for 2.5s on its second launch.
    // The watchdog is off, so only hedging can rescue the stuck request:
    // the monitor sees its in-flight age pass max(3 x EWMA, min/4 =
    // 500ms), duplicates it onto idle dev1, and the duplicate's reply
    // resolves the handle roughly 2s before the original unwedges — all
    // of it in virtual time, so the test costs no wall-clock waiting.
    let vc = Arc::new(VirtualClock::new());
    let _driver = Participant::new(&*vc);
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 2)
        .with_batch_max(1)
        .with_watchdog(false)
        .with_watchdog_min_ms(2000)
        .with_hedge(true)
        .with_hedge_after_factor(3)
        .with_hedge_max(2)
        .with_trace(true)
        .with_clock(vc.clone())
        .with_fault_spec("0=stall:2500ms:10s@launch:1")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let data: Vec<f32> = (0..128).map(|k| k as f32).collect();
    let mut handles = vec![];
    for _ in 0..8 {
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        handles.push((pc.submit(req).unwrap(), want));
    }
    let t0 = vc.now();
    for (h, want) in handles {
        let resp = h.wait().expect("every request resolves, hedged or not");
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    // The duplicate, not the 2.5s stall, bounded the (virtual) tail.
    let waited = vc.now().saturating_duration_since(t0);
    assert!(
        waited < Duration::from_millis(2300),
        "replies must not wait out the stall: {waited:?}"
    );
    pc.pool.quiesce();
    assert!(wait_hedges_resolved(&*vc, &pc), "hedge ledger never resolved");

    let m = pc.metrics();
    assert!(m.hedge);
    assert!(m.hedges >= 1, "the stalled launch must have been hedged");
    assert!(m.hedge_wins >= 1, "the duplicate beats a 2.5s stall");
    assert_eq!(m.hedges, m.hedge_wins + m.hedge_wasted);
    assert_eq!(m.failed, 0, "hedging must lose nothing");
    for d in &m.devices {
        assert_eq!(d.reserved, 0, "device {} leaks a reservation", d.id);
    }
    let report = pc.format_report();
    assert!(report.contains("hedge: on"), "{report}");

    // Exactly-once on the timeline: one Done per accepted request even
    // though two copies of the stalled one executed to completion, and
    // the hedge events mirror the counters.
    let snap = pc.pool.trace_snapshot();
    let mut dones: HashMap<u64, usize> = HashMap::new();
    for r in &snap.records {
        if r.kind == EventKind::Done {
            *dones.entry(r.req).or_default() += 1;
        }
    }
    assert_eq!(dones.len(), 8, "every accepted request terminates");
    assert!(dones.values().all(|&n| n == 1), "a hedged request must Done once: {dones:?}");
    assert_eq!(snap.count(EventKind::HedgeLaunched) as u64, m.hedges);
    assert_eq!(snap.count(EventKind::HedgeWon) as u64, m.hedge_wins);
    assert_eq!(snap.count(EventKind::HedgeWasted) as u64, m.hedge_wasted);
}

/// Wall-clock smoke for the hedge lifecycle: a sub-second stall rescued
/// by a duplicate on the default clock. The heavy hedge soaks run on
/// virtual time.
#[test]
fn wall_hedge_rescue_smoke() {
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 2)
        .with_batch_max(1)
        .with_watchdog(false)
        .with_watchdog_min_ms(400)
        .with_hedge(true)
        .with_hedge_after_factor(3)
        .with_hedge_max(2)
        .with_fault_spec("0=stall:800ms:5s@launch:1")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let data: Vec<f32> = (0..128).map(|k| k as f32).collect();
    let mut handles = vec![];
    for _ in 0..4 {
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        handles.push((pc.submit(req).unwrap(), want));
    }
    let t0 = clock::now();
    for (h, want) in handles {
        let resp = h.wait().expect("every request resolves, hedged or not");
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    assert!(
        t0.elapsed() < Duration::from_millis(700),
        "the duplicate must bound the tail below the 800ms stall: {:?}",
        t0.elapsed()
    );
    pc.pool.quiesce();
    assert!(wait_hedges_resolved(&WallClock, &pc), "hedge ledger never resolved");
    let m = pc.metrics();
    assert!(m.hedge_wins >= 1, "the duplicate beats the stall on the wall clock too");
    assert_eq!(m.hedges, m.hedge_wins + m.hedge_wasted);
    assert_eq!(m.failed, 0);
}

#[test]
fn virtual_hedged_chaos_soak_keeps_exactly_once_accounting() {
    const TOTAL: usize = 600;
    const ELEMS: usize = 192;
    // The headline soak's shape — shards, retries, SLO deadlines, a
    // stalling device, a degraded device and a dying device — with
    // hedging on top, all on virtual time. The point: however the
    // copies race the faults, every accepted request terminates exactly
    // once and the hedge ledger balances.
    let vc = Arc::new(VirtualClock::new());
    let _driver = Participant::new(&*vc);
    let cfg = PoolConfig::mixed4()
        .with_queue_cap(64)
        .with_batch_max(4)
        .with_watchdog_min_ms(100)
        .with_retry_max(2)
        .with_client_slo("slo", 250.0)
        .with_hedge(true)
        .with_hedge_after_factor(3)
        .with_hedge_max(3)
        .with_trace(true)
        .with_trace_capacity(1 << 15)
        .with_clock(vc.clone())
        .with_fault_spec("0=slow:8x:2s@launch:40")
        .unwrap()
        .with_fault_spec("1=stall:600ms:1500ms@launch:30")
        .unwrap()
        .with_fault_spec("3=die@launch:60")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let clients = ["c0", "c1", "slo"];
    let mut accepted: HashMap<String, u64> = HashMap::new();
    let mut handles: Vec<(String, OffloadHandle, Vec<f32>)> = vec![];
    for i in 0..TOTAL {
        let client = clients[i % clients.len()].to_string();
        let (mut req, want) = if i % 50 == 17 {
            let data: Vec<f32> = (0..16 * 1024).map(|k| ((k + i) % 83) as f32).collect();
            sharded_scale_request(&data, Affinity::any(), OptLevel::O2)
        } else if i % 37 == 5 {
            // Pinned to the dying device's unique (kind, arch): fails
            // deterministically after the death — terminating exactly
            // once either way is precisely what's under test.
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 89) as f32).collect();
            scale_request(
                &data,
                Affinity { arch: Some(Arch::Amdgcn), kind: Some(RuntimeKind::Legacy) },
                OptLevel::O2,
            )
        } else if i % 2 == 0 {
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 83) as f32).collect();
            scale_request(&data, Affinity::any(), OptLevel::O2)
        } else {
            let x: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
            let y: Vec<f32> = (0..ELEMS).map(|k| ((k * 3 + i) % 59) as f32).collect();
            saxpy_request(0.5, &x, &y, Affinity::any(), OptLevel::O2)
        };
        req.client = client.clone();
        if let Ok(h) = pc.submit(req) {
            *accepted.entry(client.clone()).or_default() += 1;
            handles.push((client, h, want));
        }
    }
    let mut ok: HashMap<String, u64> = HashMap::new();
    let mut failed: HashMap<String, u64> = HashMap::new();
    for (client, h, want) in handles {
        match h.wait() {
            Ok(resp) => {
                assert_eq!(
                    bytes_to_f32(resp.buffers[0].as_ref().unwrap()),
                    want,
                    "a hedged winner must still compute the right answer"
                );
                *ok.entry(client).or_default() += 1;
            }
            Err(_) => {
                *failed.entry(client).or_default() += 1;
            }
        }
    }
    pc.pool.quiesce();
    assert!(wait_hedges_resolved(&*vc, &pc), "hedge ledger never resolved");

    let m = pc.metrics();
    assert!(m.hedges >= 1, "600ms stalls against a 25ms hedge floor must hedge");
    assert_eq!(
        m.hedges,
        m.hedge_wins + m.hedge_wasted,
        "every launched duplicate is judged exactly once"
    );
    // Exactly-once per client: completed + failed == accepted, one
    // slack sample per deadlined request, through every copy in flight.
    for client in clients {
        let a = accepted.get(client).copied().unwrap_or(0);
        let cm = m.clients.iter().find(|c| c.client == client).expect("client traffic");
        assert_eq!(
            cm.completed + cm.failed,
            a,
            "client {client}: completed {} + failed {} != accepted {a}",
            cm.completed,
            cm.failed
        );
        assert_eq!(cm.completed, ok.get(client).copied().unwrap_or(0));
        assert_eq!(cm.failed, failed.get(client).copied().unwrap_or(0));
        assert_eq!(
            cm.slack.count(),
            cm.deadlines,
            "client {client}: one deadline judgment per deadlined request"
        );
    }
    assert_eq!(m.queue_depth, 0);
    for d in &m.devices {
        assert_eq!(d.reserved, 0, "device {} leaks a reservation", d.id);
    }

    // The drained timeline agrees: one Submit and one terminal Done per
    // accepted request, hedge events matching the counters exactly.
    let snap = pc.pool.trace_snapshot();
    assert_eq!(snap.stats.dropped, 0, "rings sized for the soak must drop nothing");
    let mut submits: HashMap<u64, usize> = HashMap::new();
    let mut dones: HashMap<u64, usize> = HashMap::new();
    for r in &snap.records {
        match r.kind {
            EventKind::Submit => *submits.entry(r.req).or_default() += 1,
            EventKind::Done => *dones.entry(r.req).or_default() += 1,
            _ => {}
        }
    }
    let total_accepted: u64 = accepted.values().sum();
    assert_eq!(submits.len() as u64, total_accepted);
    for (rid, n) in &submits {
        assert_eq!(*n, 1, "request {rid} submitted more than once");
        assert_eq!(
            dones.get(rid).copied().unwrap_or(0),
            1,
            "request {rid} must terminate exactly once, hedged or not"
        );
    }
    assert_eq!(dones.len(), submits.len(), "no Done without a matching Submit");
    assert_eq!(snap.count(EventKind::HedgeLaunched) as u64, m.hedges);
    assert_eq!(snap.count(EventKind::HedgeWon) as u64, m.hedge_wins);
    assert_eq!(snap.count(EventKind::HedgeWasted) as u64, m.hedge_wasted);
    let slo = m.clients.iter().find(|c| c.client == "slo").unwrap();
    assert_eq!(snap.count(EventKind::DeadlineJudged) as u64, slo.deadlines);
}

#[test]
fn retry_cap_surfaces_the_original_fault() {
    // Single device: there is never a *different* device to retry on,
    // so the first injected fault must come straight back to the
    // client — and it must be the original error text.
    let cfg = PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)
        .with_watchdog(false)
        .with_retry_max(2)
        .with_fault_spec("0=fail:4@launch:0")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let data: Vec<f32> = (0..64).map(|k| k as f32).collect();
    for i in 0..4 {
        let (req, _) = scale_request(&data, Affinity::any(), OptLevel::O2);
        let err = pc.submit(req).unwrap().wait().expect_err("launches 0-3 are scripted to fail");
        let msg = err.to_string();
        assert!(msg.contains("device fault"), "launch {i}: {msg}");
        assert!(msg.contains("injected transient launch failure"), "launch {i}: {msg}");
    }
    // The window is spent: the device works again.
    let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
    let resp = pc.submit(req).unwrap().wait().unwrap();
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);

    let m = pc.metrics();
    assert_eq!(m.retries, 0, "no sibling device: nothing can be retried");
    assert_eq!(m.retries_exhausted, 4);
    assert_eq!(m.failed, 4);
    assert_eq!(m.completed, 1);
}

#[test]
fn virtual_replay_of_the_adversarial_fixture_under_scripted_faults() {
    // Replay the committed adversarial fixture — 70% hot-key traffic,
    // hostile client names, deadline_us=1 lines — against a degraded
    // virtual-clock pool: device 0 fails transiently, device 1 stalls
    // 50 ms per launch for a window. The replay driver paces by the
    // recorded timestamps on the virtual timeline, so the whole storm
    // costs ~zero wall time, and the exactly-once contract must hold
    // through retries and quarantines: every re-issued request
    // completes or fails, nothing is lost, nothing double-counted.
    let cap = parse_capture(include_str!("../../traces/adversarial_hot_key.capture"))
        .expect("committed fixture must parse");
    let vc = Arc::new(VirtualClock::new());
    let _driver = Participant::new(&*vc);
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4)
        .with_queue_cap(64)
        .with_watchdog_min_ms(100)
        .with_retry_max(2)
        .with_clock(vc.clone())
        .with_fault_spec("0=fail:10@launch:5")
        .unwrap()
        .with_fault_spec("1=stall:50ms:400ms@launch:10")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let report = replay_capture(&pc.pool, &cap, &ReplayOptions::new()).unwrap();
    assert_eq!(report.submitted, cap.records.len() as u64, "{report:?}");
    assert_eq!(report.rejected, 0, "{report:?}");
    assert_eq!(
        report.completed + report.failed,
        report.submitted,
        "every re-issued request must terminate exactly once: {report:?}"
    );
    assert_eq!(report.mismatched, 0, "completed results must match the host reference");
    assert_eq!(report.clients, 4, "the four hostile client names");

    pc.pool.quiesce();
    let m = pc.metrics();
    assert_eq!(m.submitted, report.submitted);
    assert_eq!(m.completed, report.completed);
    assert_eq!(m.failed, report.failed);
    for d in &m.devices {
        assert_eq!(d.reserved, 0, "reservation leak on device {}", d.id);
    }
    // The hostile names survive the capture round-trip into the pool's
    // own per-client accounting (including the literal-`-` client).
    let lanes: HashSet<&str> = m.clients.iter().map(|c| c.client.as_str()).collect();
    for hostile in ["tenant a", "a=b", "-", "100%"] {
        assert!(lanes.contains(hostile), "missing client lane {hostile:?} in {lanes:?}");
    }
}
