//! SLO / deadline-accounting integration tests: deadline stamping from
//! request budgets and client SLO config, miss counting (exactly one
//! client, sharded requests counted once by their stitcher), signed
//! slack finiteness, and preemption accounting under mixed traffic.

use omprt::coordinator::PoolCoordinator;
use omprt::devrt::RuntimeKind;
use omprt::ir::passes::OptLevel;
use omprt::sched::workload::{scale_request_by, sharded_scale_request};
use omprt::sched::{bytes_to_f32, Affinity, ClientMetrics, DevicePool, PoolConfig, PoolMetrics};
use omprt::sim::Arch;
use std::time::Duration;

fn client<'m>(m: &'m PoolMetrics, name: &str) -> &'m ClientMetrics {
    m.clients
        .iter()
        .find(|c| c.client == name)
        .unwrap_or_else(|| panic!("no metrics row for client `{name}`"))
}

/// An already-expired explicit deadline must count a miss for exactly
/// the submitting client — and only for its own requests.
#[test]
fn missed_deadline_increments_exactly_one_client() {
    let pool =
        DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)).unwrap();
    let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
    let mut handles = vec![];
    for i in 0..4 {
        // Zero budget: the absolute deadline equals the submit instant,
        // so completion is necessarily late (a deterministic miss).
        let (mut req, want) = scale_request_by(2.0, &data, Affinity::any(), OptLevel::O2);
        req.client = "late".into();
        req.deadline = Some(Duration::ZERO);
        handles.push((pool.submit(req).unwrap(), want, true));
        // Interleaved best-effort traffic from another client.
        let (mut req, want) =
            scale_request_by(3.0 + i as f32, &data, Affinity::any(), OptLevel::O2);
        req.client = "calm".into();
        handles.push((pool.submit(req).unwrap(), want, false));
    }
    for (h, want, _) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    let m = pool.metrics();
    let late = client(&m, "late");
    assert_eq!(late.completed, 4);
    assert_eq!(late.deadlines, 4, "every zero-budget request carries a deadline");
    assert_eq!(late.deadline_miss, 4, "every zero-budget request must miss");
    let calm = client(&m, "calm");
    assert_eq!(calm.completed, 4);
    assert_eq!((calm.deadlines, calm.deadline_miss), (0, 0), "no deadline leaks to calm");
    assert_eq!(m.deadline_totals(), (4, 4));
}

/// A met deadline records positive slack; slack aggregates are finite
/// either way (the clock-skew-free simulation invariant).
#[test]
fn slack_summaries_are_signed_and_finite() {
    let pool =
        DevicePool::new(&PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)).unwrap();
    let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
    // Generous budget: must be met, slack positive.
    let (mut req, want) = scale_request_by(2.0, &data, Affinity::any(), OptLevel::O2);
    req.client = "met".into();
    req.deadline = Some(Duration::from_secs(600));
    let resp = pool.submit(req).unwrap().wait().unwrap();
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    // Zero budget: missed, slack negative.
    let (mut req, _) = scale_request_by(2.0, &data, Affinity::any(), OptLevel::O2);
    req.client = "missed".into();
    req.deadline = Some(Duration::ZERO);
    pool.submit(req).unwrap().wait().unwrap();
    let m = pool.metrics();
    let met = client(&m, "met");
    assert_eq!((met.deadlines, met.deadline_miss), (1, 0));
    assert!(met.slack.min_us() > 0.0, "met deadline must record positive slack");
    let missed = client(&m, "missed");
    assert_eq!((missed.deadlines, missed.deadline_miss), (1, 1));
    assert!(missed.slack.max_us() <= 0.0, "missed deadline must record negative slack");
    for c in [met, missed] {
        for v in [c.slack.avg_us(), c.slack.min_us(), c.slack.max_us()] {
            assert!(v.is_finite(), "slack aggregates must be finite: {v}");
        }
    }
}

/// A sharded request that misses its deadline counts ONE miss — the
/// stitcher judges the request as a whole; shard jobs are skipped.
#[test]
fn sharded_miss_counts_once() {
    let pool = DevicePool::new(
        &PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4).with_shard_min_trips(1024),
    )
    .unwrap();
    let data: Vec<f32> = (0..32 * 1024).map(|i| (i % 101) as f32).collect();
    let (mut req, want) = sharded_scale_request(&data, Affinity::any(), OptLevel::O2);
    req.client = "split".into();
    req.deadline = Some(Duration::ZERO);
    let resp = pool.submit(req).unwrap().wait().unwrap();
    assert!(resp.shards >= 2, "request must actually shard, got {}", resp.shards);
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    let m = pool.metrics();
    let split = client(&m, "split");
    assert_eq!(split.completed, 1, "one request, despite {} shards", resp.shards);
    assert_eq!(
        (split.deadlines, split.deadline_miss),
        (1, 1),
        "the miss must count once, not per shard"
    );
    assert!(m.shard_jobs >= 2);
}

/// `[pool] client_slos` stamps deadlines without the request asking, and
/// the per-request explicit budget overrides the client target.
#[test]
fn client_slo_config_stamps_deadlines() {
    let pool = DevicePool::new(
        &PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)
            .with_client_slo("svc", 600_000.0),
    )
    .unwrap();
    let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
    // No explicit budget: the client SLO applies (and is easily met).
    let (mut req, _) = scale_request_by(2.0, &data, Affinity::any(), OptLevel::O2);
    req.client = "svc".into();
    pool.submit(req).unwrap().wait().unwrap();
    // Explicit zero budget overrides the generous SLO: a miss.
    let (mut req, _) = scale_request_by(2.0, &data, Affinity::any(), OptLevel::O2);
    req.client = "svc".into();
    req.deadline = Some(Duration::ZERO);
    pool.submit(req).unwrap().wait().unwrap();
    // Untagged traffic stays best-effort.
    let (req, _) = scale_request_by(2.0, &data, Affinity::any(), OptLevel::O2);
    pool.submit(req).unwrap().wait().unwrap();
    let m = pool.metrics();
    let svc = client(&m, "svc");
    assert_eq!(svc.deadlines, 2, "SLO-stamped + explicit-budget requests");
    assert_eq!(svc.deadline_miss, 1, "only the zero-budget request misses");
    assert_eq!(svc.slo, Some(Duration::from_secs(600)));
    let default = client(&m, "");
    assert_eq!((default.deadlines, default.deadline_miss), (0, 0));
}

/// Mixed deadline + bulk traffic completes correctly with preemption
/// enabled, preemptions surface in the metrics, and per-client p95/p50
/// percentiles are available for every client.
#[test]
fn preemption_under_load_keeps_results_correct() {
    let pc = PoolCoordinator::new(
        &PoolConfig::mixed4().with_client_slo("rt", 0.001), // 1µs: panics constantly
    )
    .unwrap();
    let data: Vec<f32> = (0..128).map(|i| i as f32).collect();
    let mut handles = vec![];
    for i in 0..60 {
        let client = if i % 4 == 0 { "rt" } else { "bulk" };
        let factor = if i % 4 == 0 { 2.5 } else { 2.0 };
        let (mut req, want) = scale_request_by(factor, &data, Affinity::any(), OptLevel::O2);
        req.client = client.into();
        handles.push((pc.submit(req).unwrap(), want));
    }
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    let m = pc.metrics();
    assert_eq!(m.completed, 60);
    assert_eq!(m.failed, 0);
    let rt = client(&m, "rt");
    assert_eq!(rt.deadlines, 15);
    assert!(rt.latency_p95_us() >= rt.latency_p50_us());
    assert!(client(&m, "bulk").latency_p95_us() > 0.0);
    // The starvation bound guarantees bulk progress even though "rt" was
    // permanently panicking; everything drained, so both held.
    let text = pc.format_report();
    assert!(text.contains("slo:"), "{text}");
    assert!(text.contains("rt"), "{text}");
}
