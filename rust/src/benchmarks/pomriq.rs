//! 514.pomriq analog: MRI-Q — non-uniform Fourier reconstruction.
//!
//! `Q(x_i) = Σ_k |m_k|² · (cos φ, sin φ)` with `φ = 2π(kx·x + ky·y + kz·z)`.
//! Points are claimed through **dynamic dispatch** (`__kmpc_dispatch_*`);
//! the inner k-loop is device-IR `fsin`/`fcos` — the transcendental-heavy
//! SPEC member.

use super::common::{checksum_f32, compare_f32, unpack_range, BenchResult, Benchmark, Scale};
use crate::coordinator::Coordinator;
use crate::devrt::{irlib, state};
use crate::hostrt::{DataEnv, MapType};
use crate::ir::passes::OptLevel;
use crate::ir::{AddrSpace, CmpPred, FunctionBuilder, Module, Operand, Type, UnOp};
use crate::sim::LaunchConfig;
use crate::util::{Error, SplitMix64};

/// The benchmark.
pub struct Pomriq {
    points: usize,
    samples: usize,
    teams: u32,
}

impl Pomriq {
    /// Configure for a scale.
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Small => Pomriq { points: 128, samples: 64, teams: 2 },
            Scale::Paper => Pomriq { points: 1024, samples: 256, teams: 6 },
        }
    }

    /// Kernel args: qr, qi, x, y, z, kx, ky, kz, mag (device addrs).
    fn module(&self) -> Module {
        let k_n = self.samples as i32;
        let n = self.points as i32;
        let mut m = Module::new("pomriq");
        let params = vec![Type::I64; 9];
        let mut b = FunctionBuilder::new("computeq", &params, None).kernel();
        let (qr, qi) = (b.param(0), b.param(1));
        let (px, py, pz) = (b.param(2), b.param(3), b.param(4));
        let (kx, ky, kz, mag) = (b.param(5), b.param(6), b.param(7), b.param(8));
        irlib::emit_spmd_prologue(&mut b);
        // `distribute` across teams: team t owns [t·per, (t+1)·per), then
        // dynamic dispatch within the team.
        let team = b.call("gpu.ctaid.x", &[], Type::I32);
        let nteams = b.call("gpu.nctaid.x", &[], Type::I32);
        let nm1 = b.add(nteams, Operand::i32(-1));
        let npad = b.add(nm1, Operand::i32(n));
        let per = b.sdiv(npad, nteams);
        let lo = b.mul(team, per);
        let hi0 = b.add(lo, per);
        let hi = b.bin(crate::ir::BinOp::SMin, hi0, Operand::i32(n));
        let lo64 = b.sext64(lo);
        let hi64 = b.sext64(hi);
        b.call_void(
            "__kmpc_dispatch_init_4",
            &[
                lo64.into(),
                hi64.into(),
                Operand::i64(4),
                Operand::i64(state::SCHED_DYNAMIC as i64),
            ],
        );
        b.loop_(|b| {
            let packed = b.call("__kmpc_dispatch_next_4", &[], Type::I64);
            let done = b.cmp(CmpPred::Eq, packed, Operand::i64(state::DISPATCH_DONE as i64));
            b.if_(done, |b| b.break_());
            let (lb, ub) = unpack_range(b, packed);
            b.for_range(lb, ub, Operand::i32(1), |b, i| {
                let xa = b.index(px, i, 4);
                let x = b.load(Type::F32, AddrSpace::Global, xa);
                let ya = b.index(py, i, 4);
                let y = b.load(Type::F32, AddrSpace::Global, ya);
                let za = b.index(pz, i, 4);
                let z = b.load(Type::F32, AddrSpace::Global, za);
                let sr = b.copy(Operand::f32(0.0));
                let si = b.copy(Operand::f32(0.0));
                b.for_range(Operand::i32(0), Operand::i32(k_n), Operand::i32(1), |b, k| {
                    let kxa = b.index(kx, k, 4);
                    let kxv = b.load(Type::F32, AddrSpace::Global, kxa);
                    let kya = b.index(ky, k, 4);
                    let kyv = b.load(Type::F32, AddrSpace::Global, kya);
                    let kza = b.index(kz, k, 4);
                    let kzv = b.load(Type::F32, AddrSpace::Global, kza);
                    let ma = b.index(mag, k, 4);
                    let mv = b.load(Type::F32, AddrSpace::Global, ma);
                    let t0 = b.mul(kxv, x);
                    let t1 = b.mul(kyv, y);
                    let t2 = b.mul(kzv, z);
                    let s01 = b.add(t0, t1);
                    let s = b.add(s01, t2);
                    let phi = b.mul(s, Operand::f32(2.0 * std::f32::consts::PI));
                    let c = b.un(UnOp::FCos, phi);
                    let sn = b.un(UnOp::FSin, phi);
                    let mc = b.mul(mv, c);
                    let ms = b.mul(mv, sn);
                    let nr = b.add(sr, mc);
                    b.assign(sr, nr);
                    let ni = b.add(si, ms);
                    b.assign(si, ni);
                });
                let qra = b.index(qr, i, 4);
                b.store(Type::F32, AddrSpace::Global, qra, sr);
                let qia = b.index(qi, i, 4);
                b.store(Type::F32, AddrSpace::Global, qia, si);
            });
        });
        b.call_void("__kmpc_dispatch_fini_4", &[]);
        irlib::emit_spmd_epilogue(&mut b);
        b.ret();
        m.add_func(b.build());
        m
    }

    fn inputs(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = SplitMix64::new(514);
        let mut mk = |n: usize, lo: f32, hi: f32| {
            let mut v = vec![0f32; n];
            rng.fill_f32(&mut v, lo, hi);
            v
        };
        let x = mk(self.points, -0.5, 0.5);
        let y = mk(self.points, -0.5, 0.5);
        let z = mk(self.points, -0.5, 0.5);
        let kx = mk(self.samples, -1.0, 1.0);
        let ky = mk(self.samples, -1.0, 1.0);
        let kz = mk(self.samples, -1.0, 1.0);
        let mag = mk(self.samples, 0.0, 1.0);
        (x, y, z, kx, ky, kz, mag)
    }

    fn host_ref(&self) -> (Vec<f32>, Vec<f32>) {
        let (x, y, z, kx, ky, kz, mag) = self.inputs();
        let mut qr = vec![0f32; self.points];
        let mut qi = vec![0f32; self.points];
        for i in 0..self.points {
            let (mut sr, mut si) = (0f32, 0f32);
            for k in 0..self.samples {
                let phi =
                    2.0 * std::f32::consts::PI * (kx[k] * x[i] + ky[k] * y[i] + kz[k] * z[i]);
                sr += mag[k] * phi.cos();
                si += mag[k] * phi.sin();
            }
            qr[i] = sr;
            qi[i] = si;
        }
        (qr, qi)
    }
}

impl Benchmark for Pomriq {
    fn name(&self) -> &'static str {
        "514.pomriq"
    }

    fn run(&self, c: &Coordinator) -> Result<BenchResult, Error> {
        let image = c.prepare(self.module(), OptLevel::O2)?;
        let mut env = DataEnv::new(&c.device);
        let (x, y, z, kx, ky, kz, mag) = self.inputs();
        let mut qr = vec![0f32; self.points];
        let mut qi = vec![0f32; self.points];
        let args = [
            env.map(&qr, MapType::From)?,
            env.map(&qi, MapType::From)?,
            env.map(&x, MapType::To)?,
            env.map(&y, MapType::To)?,
            env.map(&z, MapType::To)?,
            env.map(&kx, MapType::To)?,
            env.map(&ky, MapType::To)?,
            env.map(&kz, MapType::To)?,
            env.map(&mag, MapType::To)?,
        ];
        let stats = c.run_region(
            &image,
            "computeq",
            "pomriq.computeQ",
            &args,
            LaunchConfig::new(self.teams, 64),
        )?;
        env.unmap(&mut qr)?;
        env.unmap(&mut qi)?;

        let (hr, hi) = self.host_ref();
        let verified = compare_f32(&qr, &hr, 2e-3).is_none() && compare_f32(&qi, &hi, 2e-3).is_none();
        if !verified {
            log::error!("pomriq verify failed");
        }
        let mut all = qr.clone();
        all.extend_from_slice(&qi);
        Ok(BenchResult { kernel_wall: stats.wall, verified, checksum: checksum_f32(&all) })
    }
}
