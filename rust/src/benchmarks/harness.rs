//! The evaluation harnesses that regenerate the paper's Fig. 2 and
//! Table 1 (used by `examples/` and `rust/benches/`).

use super::{spec_accel, Scale};
use crate::coordinator::{Coordinator, Profiler};
use crate::devrt::RuntimeKind;
use crate::runtime::{ArtifactManifest, PjrtService};
use crate::sim::Arch;
use crate::util::stats::rel_diff;
use crate::util::{Error, Summary};

/// One row of the Fig.-2 comparison.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    /// Benchmark name.
    pub name: String,
    /// Mean wall time (s) of the timed section under the original
    /// (legacy CUDA/HIP-style) runtime.
    pub original_s: f64,
    /// Mean wall time (s) under the new (portable OpenMP 5.1) runtime.
    pub new_s: f64,
    /// Relative difference |a−b|/max — the paper calls <1 % noise.
    pub rel: f64,
    /// Both versions verified against the host reference.
    pub verified: bool,
}

/// Run the Fig.-2 experiment: every SPEC-analog benchmark under both
/// runtime builds, `reps` repetitions each (the paper uses 5), mean
/// execution time per version.
pub fn run_fig2(
    arch: Arch,
    scale: Scale,
    reps: u32,
    manifest: Option<&ArtifactManifest>,
) -> Result<Vec<Fig2Row>, Error> {
    let svc = match manifest {
        Some(_) => Some(PjrtService::start()?),
        None => None,
    };
    let mut rows = vec![];
    for bench in spec_accel(scale) {
        if bench.needs_artifacts() && manifest.is_none() {
            log::warn!("skipping {} (no artifacts)", bench.name());
            continue;
        }
        let mut means = [0f64; 2];
        let mut verified = true;
        for (vi, kind) in RuntimeKind::all().into_iter().enumerate() {
            let mut c = Coordinator::new(kind, arch);
            if bench.needs_artifacts() {
                c.attach_artifacts_with(svc.as_ref().unwrap(), manifest.unwrap())?;
            }
            // One unmeasured warmup (PJRT compile/JIT, allocator warm-up)
            // before the timed repetitions, as the paper's methodology
            // measures steady-state execution. The paper averages 5 runs
            // on a dedicated Summit node; this testbed is a time-shared
            // host where OS scheduling noise dominates sub-second runs,
            // so we report the *median* of the repetitions instead.
            let w = bench.run(&c)?;
            verified &= w.verified;
            let mut samples = Vec::with_capacity(reps as usize);
            for _ in 0..reps {
                let r = bench.run(&c)?;
                verified &= r.verified;
                samples.push(r.kernel_wall.as_secs_f64());
            }
            samples.sort_by(f64::total_cmp);
            means[vi] = samples[samples.len() / 2];
        }
        rows.push(Fig2Row {
            name: bench.name().to_string(),
            original_s: means[0],
            new_s: means[1],
            rel: rel_diff(means[0], means[1]),
            verified,
        });
    }
    Ok(rows)
}

/// Render the Fig.-2 rows as a table.
pub fn format_fig2(rows: &[Fig2Row]) -> String {
    let mut out = String::new();
    out.push_str("Benchmark      | Original (s) | New (s) | rel.diff | verified\n");
    out.push_str("---------------+--------------+---------+----------+---------\n");
    for r in rows {
        out.push_str(&format!(
            "{:<15}| {:>12.4} | {:>7.4} | {:>7.2}% | {}\n",
            r.name,
            r.original_s,
            r.new_s,
            r.rel * 100.0,
            if r.verified { "yes" } else { "NO" }
        ));
    }
    out
}

/// Run the Table-1 experiment: the miniQMC proxy app under both runtimes,
/// per-region profiles. Returns rows `(region, version, summary)` in the
/// paper's layout order.
pub fn run_table1(
    arch: Arch,
    scale: Scale,
    manifest: &ArtifactManifest,
) -> Result<Vec<(String, String, Summary)>, Error> {
    let svc = PjrtService::start()?;
    let mut rows: Vec<(String, String, Summary)> = vec![];
    let mut per_kind: Vec<(RuntimeKind, Summary, Summary)> = vec![];
    for kind in RuntimeKind::all() {
        let mut c = Coordinator::new(kind, arch);
        c.attach_artifacts_with(&svc, manifest)?;
        let app = super::miniqmc::MiniQmc::new(scale);
        let p = app.run_profiled(&c)?;
        if !p.result.verified {
            return Err(Error::Verify(format!("miniqmc failed under {kind}")));
        }
        per_kind.push((kind, p.vgh, p.det));
    }
    for region_idx in 0..2 {
        for (kind, vgh, det) in &per_kind {
            let (region, s) = if region_idx == 0 {
                ("evaluate_vgh", vgh.clone())
            } else {
                ("evaluateDetRatios", det.clone())
            };
            rows.push((region.to_string(), kind.paper_name().to_string(), s));
        }
    }
    Ok(rows)
}

/// Render Table 1.
pub fn format_table1(rows: &[(String, String, Summary)]) -> String {
    Profiler::table1(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_runs_without_artifacts_on_non_payload_benchmarks() {
        // Only the IR-only benchmarks run (postencil is skipped).
        let rows = run_fig2(Arch::Nvptx64, Scale::Small, 1, None).unwrap();
        assert!(rows.len() >= 5);
        for r in &rows {
            assert!(r.verified, "{}", r.name);
            assert!(r.original_s > 0.0 && r.new_s > 0.0);
        }
        let text = format_fig2(&rows);
        assert!(text.contains("504.polbm"), "{text}");
    }
}
