//! Minimal offline shim for the `xla` PJRT bindings.
//!
//! The real crate wraps `xla_extension`; that shared library is not
//! available in the offline build environment, so this crate implements
//! the small API surface `omprt::runtime::pjrt` uses on top of a tiny
//! **HLO-text interpreter**. It parses the `ENTRY` computation of an HLO
//! module in textual form and evaluates it over f32 literals.
//!
//! Supported opcodes: `parameter`, `constant` (scalar or flat `{..}`
//! list), `broadcast`, `reshape`, `transpose`, `dot` (1-D/2-D),
//! elementwise `add`/`subtract`/`multiply`/`divide`/`maximum`/`minimum`/
//! `negate`/`exponential`, and `tuple`. Anything else reports a clean
//! error at compile time rather than producing wrong numbers.

use std::collections::HashMap;
use std::fmt;

/// Error type mirroring `xla::Error` (Display is all callers use).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn err<T>(msg: impl Into<String>) -> Result<T, Error> {
    Err(Error(msg.into()))
}

// ---------------------------------------------------------------------------
// Literals
// ---------------------------------------------------------------------------

/// An f32 tensor (or a tuple of tensors, as produced by a ROOT `tuple`).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<usize>,
    data: Vec<f32>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// A rank-1 literal over `data`.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len()], data: data.to_vec(), tuple: None }
    }

    /// A scalar literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { dims: vec![], data: vec![v], tuple: None }
    }

    fn tensor(dims: Vec<usize>, data: Vec<f32>) -> Literal {
        Literal { dims, data, tuple: None }
    }

    fn tuple_of(elems: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: vec![], tuple: Some(elems) }
    }

    /// Element count.
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// Reinterpret with a new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        if self.tuple.is_some() {
            return err("reshape of a tuple literal");
        }
        let d: Vec<usize> = dims.iter().map(|&x| x as usize).collect();
        let n: usize = d.iter().product();
        if n != self.data.len() {
            return err(format!(
                "reshape: element count mismatch ({} data vs {:?})",
                self.data.len(),
                d
            ));
        }
        Ok(Literal::tensor(d, self.data.clone()))
    }

    /// Unwrap a 1-tuple (the `return_tuple=True` convention).
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        match self.tuple {
            Some(mut elems) if elems.len() == 1 => Ok(elems.remove(0)),
            Some(elems) => err(format!("to_tuple1: tuple has {} elements", elems.len())),
            None => err("to_tuple1: not a tuple literal"),
        }
    }

    /// Copy out the element data.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, Error> {
        if self.tuple.is_some() {
            return err("to_vec of a tuple literal");
        }
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Element types extractable from a [`Literal`] (the shim stores f32).
pub trait Element {
    fn from_f32(v: f32) -> Self;
}

impl Element for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

// ---------------------------------------------------------------------------
// HLO module handling
// ---------------------------------------------------------------------------

/// Parsed-enough representation of an HLO module in text form.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Load HLO text from a file.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("cannot read {path}: {e}")))?;
        if !text.contains("ENTRY") {
            return err(format!("{path}: no ENTRY computation in HLO text"));
        }
        Ok(HloModuleProto { text })
    }

    /// Build directly from HLO text (test convenience).
    pub fn from_text(text: &str) -> Result<HloModuleProto, Error> {
        if !text.contains("ENTRY") {
            return err("no ENTRY computation in HLO text");
        }
        Ok(HloModuleProto { text: text.to_string() })
    }
}

/// A computation awaiting compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    /// Wrap a proto (the text is compiled by [`PjRtClient::compile`]).
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: proto.text.clone() }
    }
}

/// The CPU "client".
pub struct PjRtClient;

impl PjRtClient {
    /// Always available: the interpreter *is* the CPU backend.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    /// Platform name, as the real client reports it.
    pub fn platform_name(&self) -> String {
        "cpu-hlo-interp".to_string()
    }

    /// "Compile": parse and validate the ENTRY computation.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        let program = parse_entry(&comp.text)?;
        // Validate opcodes up front so unsupported modules fail at
        // compile time, like a real backend would.
        for inst in &program.insts {
            if !is_supported(&inst.opcode) {
                return err(format!("unsupported HLO opcode `{}`", inst.opcode));
            }
        }
        Ok(PjRtLoadedExecutable { program })
    }
}

/// A compiled (parsed) executable.
pub struct PjRtLoadedExecutable {
    program: Program,
}

impl PjRtLoadedExecutable {
    /// Execute over the given argument literals. The result mirrors the
    /// real API's `Vec<replica, Vec<output, buffer>>` nesting.
    pub fn execute<T: AsRef<Literal>>(&self, args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        let args: Vec<&Literal> = args.iter().map(|a| a.as_ref()).collect();
        let out = eval(&self.program, &args)?;
        Ok(vec![vec![PjRtBuffer { literal: out }]])
    }
}

/// A device buffer holding one result.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Fetch the buffer contents as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Ok(self.literal.clone())
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct HloInst {
    name: String,
    dims: Vec<usize>,
    opcode: String,
    operands: Vec<String>,
    attrs: HashMap<String, String>,
    is_root: bool,
}

#[derive(Debug, Clone)]
struct Program {
    insts: Vec<HloInst>,
}

fn is_supported(op: &str) -> bool {
    matches!(
        op,
        "parameter"
            | "constant"
            | "broadcast"
            | "reshape"
            | "transpose"
            | "dot"
            | "add"
            | "subtract"
            | "multiply"
            | "divide"
            | "maximum"
            | "minimum"
            | "negate"
            | "exponential"
            | "tuple"
    )
}

/// Extract the lines of the `ENTRY ... { ... }` block.
fn entry_lines(text: &str) -> Result<Vec<String>, Error> {
    let start = match text.find("ENTRY") {
        Some(i) => i,
        None => return err("no ENTRY computation"),
    };
    let open = match text[start..].find('{') {
        Some(i) => start + i,
        None => return err("ENTRY has no opening brace"),
    };
    // The body ends at the matching close brace; instruction attrs use
    // braces too ({1,0}, dimensions={..}), so track nesting depth.
    let mut depth = 0usize;
    let mut end = None;
    for (i, c) in text[open..].char_indices() {
        match c {
            '{' => depth += 1,
            '}' => {
                depth -= 1;
                if depth == 0 {
                    end = Some(open + i);
                    break;
                }
            }
            _ => {}
        }
    }
    let end = match end {
        Some(e) => e,
        None => return err("ENTRY has no closing brace"),
    };
    Ok(text[open + 1..end]
        .lines()
        .map(|l| l.trim().to_string())
        .filter(|l| !l.is_empty())
        .collect())
}

/// Parse `f32[2,2]{1,0}` (or `f32[]`, or a tuple shape) → dims. Tuple
/// shapes return the dims of the first element (only used for display).
fn parse_shape_dims(s: &str) -> Result<Vec<usize>, Error> {
    let s = s.trim().trim_start_matches('(');
    let lb = match s.find('[') {
        Some(i) => i,
        None => return Ok(vec![]), // scalar like `f32` (defensive)
    };
    let rb = match s[lb..].find(']') {
        Some(i) => lb + i,
        None => return err(format!("bad shape `{s}`")),
    };
    let inner = s[lb + 1..rb].trim();
    if inner.is_empty() {
        return Ok(vec![]);
    }
    inner
        .split(',')
        .map(|d| {
            d.trim()
                .parse::<usize>()
                .map_err(|e| Error(format!("bad dim `{d}` in `{s}`: {e}")))
        })
        .collect()
}

/// Split `s` on top-level commas (ignoring commas inside (), {}, []).
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = vec![];
    let mut depth = 0i32;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '(' | '{' | '[' => {
                depth += 1;
                cur.push(c);
            }
            ')' | '}' | ']' => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 => {
                out.push(cur.trim().to_string());
                cur = String::new();
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

/// Parse one instruction line.
fn parse_inst(line: &str) -> Result<HloInst, Error> {
    let (is_root, rest) = match line.strip_prefix("ROOT ") {
        Some(r) => (true, r),
        None => (false, line),
    };
    let (name, rhs) = match rest.split_once('=') {
        Some((n, r)) => (n.trim().to_string(), r.trim()),
        None => return err(format!("bad HLO line `{line}`")),
    };
    // rhs = <shape> <opcode>(<operands>)[, attr=..]*
    // The shape ends at the whitespace before the opcode; shapes contain
    // no spaces in the HLO text JAX emits.
    let (shape_str, after_shape) = match rhs.split_once(' ') {
        Some((s, r)) => (s, r.trim()),
        None => return err(format!("bad HLO rhs `{rhs}`")),
    };
    let dims = parse_shape_dims(shape_str)?;
    let op_paren = match after_shape.find('(') {
        Some(i) => i,
        None => return err(format!("no operand list in `{rhs}`")),
    };
    let opcode = after_shape[..op_paren].trim().to_string();
    // Find the matching close paren of the operand list.
    let mut depth = 0i32;
    let mut close = None;
    for (i, c) in after_shape.char_indices() {
        if i < op_paren {
            continue;
        }
        match c {
            '(' | '{' | '[' => depth += 1,
            ')' | '}' | ']' => {
                depth -= 1;
                if depth == 0 {
                    close = Some(i);
                    break;
                }
            }
            _ => {}
        }
    }
    let close = match close {
        Some(c) => c,
        None => return err(format!("unterminated operand list in `{rhs}`")),
    };
    let operand_str = &after_shape[op_paren + 1..close];
    let operands = split_top_level(operand_str);
    // Attrs after the close paren: `, key={..}` or `, key=value`.
    let mut attrs = HashMap::new();
    let attr_str = after_shape[close + 1..].trim_start_matches(',').trim();
    for part in split_top_level(attr_str) {
        if let Some((k, v)) = part.split_once('=') {
            attrs.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    Ok(HloInst { name, dims, opcode, operands, attrs, is_root })
}

fn parse_entry(text: &str) -> Result<Program, Error> {
    let mut insts = vec![];
    for line in entry_lines(text)? {
        insts.push(parse_inst(&line)?);
    }
    if insts.is_empty() {
        return err("empty ENTRY computation");
    }
    Ok(Program { insts })
}

/// Parse `{1,0}` / `{}` into a usize list.
fn parse_int_set(s: &str) -> Result<Vec<usize>, Error> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}').trim();
    if inner.is_empty() {
        return Ok(vec![]);
    }
    inner
        .split(',')
        .map(|d| {
            d.trim()
                .parse::<usize>()
                .map_err(|e| Error(format!("bad int set `{s}`: {e}")))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Evaluation
// ---------------------------------------------------------------------------

fn eval(program: &Program, args: &[&Literal]) -> Result<Literal, Error> {
    let mut env: HashMap<&str, Literal> = HashMap::new();
    let mut root: Option<Literal> = None;
    for inst in &program.insts {
        let value = eval_inst(inst, &env, args)?;
        if inst.is_root {
            root = Some(value.clone());
        }
        env.insert(inst.name.as_str(), value);
    }
    match root {
        Some(v) => Ok(v),
        // No ROOT marker: the last instruction is the root.
        None => Ok(env[program.insts.last().unwrap().name.as_str()].clone()),
    }
}

fn operand<'a>(
    env: &'a HashMap<&str, Literal>,
    inst: &HloInst,
    i: usize,
) -> Result<&'a Literal, Error> {
    let name = inst
        .operands
        .get(i)
        .ok_or_else(|| Error(format!("`{}`: missing operand {i}", inst.name)))?;
    env.get(name.as_str())
        .ok_or_else(|| Error(format!("`{}`: unknown operand `{name}`", inst.name)))
}

fn elementwise2(
    inst: &HloInst,
    env: &HashMap<&str, Literal>,
    f: impl Fn(f32, f32) -> f32,
) -> Result<Literal, Error> {
    let a = operand(env, inst, 0)?;
    let b = operand(env, inst, 1)?;
    if a.data.len() != b.data.len() {
        return err(format!(
            "`{}`: elementwise size mismatch ({} vs {})",
            inst.name,
            a.data.len(),
            b.data.len()
        ));
    }
    let data = a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect();
    Ok(Literal::tensor(inst.dims.clone(), data))
}

fn elementwise1(
    inst: &HloInst,
    env: &HashMap<&str, Literal>,
    f: impl Fn(f32) -> f32,
) -> Result<Literal, Error> {
    let a = operand(env, inst, 0)?;
    let data = a.data.iter().map(|&x| f(x)).collect();
    Ok(Literal::tensor(inst.dims.clone(), data))
}

/// Row-major strides for `dims`.
fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

fn eval_inst(
    inst: &HloInst,
    env: &HashMap<&str, Literal>,
    args: &[&Literal],
) -> Result<Literal, Error> {
    match inst.opcode.as_str() {
        "parameter" => {
            let idx: usize = inst
                .operands
                .first()
                .and_then(|s| s.trim().parse().ok())
                .ok_or_else(|| Error(format!("`{}`: bad parameter index", inst.name)))?;
            let a = args
                .get(idx)
                .ok_or_else(|| Error(format!("parameter({idx}) but only {} args", args.len())))?;
            let want: usize = inst.dims.iter().product();
            if a.data.len() != want {
                return err(format!(
                    "parameter({idx}): expected {want} elements, got {}",
                    a.data.len()
                ));
            }
            Ok(Literal::tensor(inst.dims.clone(), a.data.clone()))
        }
        "constant" => {
            let raw = inst
                .operands
                .first()
                .ok_or_else(|| Error(format!("`{}`: constant without value", inst.name)))?;
            let vals = parse_constant(raw)?;
            let want: usize = inst.dims.iter().product();
            if vals.len() != want {
                return err(format!(
                    "`{}`: constant has {} values for shape {:?}",
                    inst.name,
                    vals.len(),
                    inst.dims
                ));
            }
            Ok(Literal::tensor(inst.dims.clone(), vals))
        }
        "broadcast" => {
            let a = operand(env, inst, 0)?;
            let out_dims = &inst.dims;
            let map = parse_int_set(inst.attrs.get("dimensions").map(String::as_str).unwrap_or("{}"))?;
            if map.len() != a.dims.len() {
                return err(format!(
                    "`{}`: broadcast dimensions {:?} vs input rank {}",
                    inst.name,
                    map,
                    a.dims.len()
                ));
            }
            let out_n: usize = out_dims.iter().product();
            let out_strides = strides(out_dims);
            let in_strides = strides(&a.dims);
            let mut data = vec![0f32; out_n];
            for (lin, slot) in data.iter_mut().enumerate() {
                let mut in_lin = 0usize;
                for (k, &od) in map.iter().enumerate() {
                    let coord = (lin / out_strides[od]) % out_dims[od];
                    in_lin += coord * in_strides[k];
                }
                *slot = a.data[in_lin];
            }
            Ok(Literal::tensor(out_dims.clone(), data))
        }
        "reshape" => {
            let a = operand(env, inst, 0)?;
            let want: usize = inst.dims.iter().product();
            if a.data.len() != want {
                return err(format!("`{}`: reshape element count mismatch", inst.name));
            }
            Ok(Literal::tensor(inst.dims.clone(), a.data.clone()))
        }
        "transpose" => {
            let a = operand(env, inst, 0)?;
            let perm = parse_int_set(
                inst.attrs.get("dimensions").map(String::as_str).unwrap_or(""),
            )?;
            if perm.len() != a.dims.len() {
                return err(format!("`{}`: transpose rank mismatch", inst.name));
            }
            let out_dims = &inst.dims;
            let out_strides = strides(out_dims);
            let in_strides = strides(&a.dims);
            let mut data = vec![0f32; a.data.len()];
            for (lin, slot) in data.iter_mut().enumerate() {
                let mut in_lin = 0usize;
                for (o, &src_axis) in perm.iter().enumerate() {
                    let coord = (lin / out_strides[o]) % out_dims[o];
                    in_lin += coord * in_strides[src_axis];
                }
                *slot = a.data[in_lin];
            }
            Ok(Literal::tensor(out_dims.clone(), data))
        }
        "dot" => {
            let a = operand(env, inst, 0)?;
            let b = operand(env, inst, 1)?;
            let lc = parse_int_set(
                inst.attrs.get("lhs_contracting_dims").map(String::as_str).unwrap_or("{1}"),
            )?;
            let rc = parse_int_set(
                inst.attrs.get("rhs_contracting_dims").map(String::as_str).unwrap_or("{0}"),
            )?;
            dot(inst, a, b, &lc, &rc)
        }
        "add" => elementwise2(inst, env, |x, y| x + y),
        "subtract" => elementwise2(inst, env, |x, y| x - y),
        "multiply" => elementwise2(inst, env, |x, y| x * y),
        "divide" => elementwise2(inst, env, |x, y| x / y),
        "maximum" => elementwise2(inst, env, f32::max),
        "minimum" => elementwise2(inst, env, f32::min),
        "negate" => elementwise1(inst, env, |x| -x),
        "exponential" => elementwise1(inst, env, f32::exp),
        "tuple" => {
            let mut elems = vec![];
            for i in 0..inst.operands.len() {
                elems.push(operand(env, inst, i)?.clone());
            }
            Ok(Literal::tuple_of(elems))
        }
        other => err(format!("unsupported HLO opcode `{other}`")),
    }
}

/// Parse a constant payload: `2`, `2.5`, `-1e-3`, or `{1, 2, 3}`.
fn parse_constant(raw: &str) -> Result<Vec<f32>, Error> {
    let raw = raw.trim();
    if let Some(inner) = raw.strip_prefix('{') {
        let inner = inner.trim_end_matches('}');
        if inner.contains('{') {
            return err("nested constant arrays are not supported");
        }
        if inner.trim().is_empty() {
            return Ok(vec![]);
        }
        return inner
            .split(',')
            .map(|v| {
                v.trim()
                    .parse::<f32>()
                    .map_err(|e| Error(format!("bad constant element `{v}`: {e}")))
            })
            .collect();
    }
    raw.parse::<f32>()
        .map(|v| vec![v])
        .map_err(|e| Error(format!("bad constant `{raw}`: {e}")))
}

/// General 1-D/2-D dot product with single contracting dims.
fn dot(
    inst: &HloInst,
    a: &Literal,
    b: &Literal,
    lc: &[usize],
    rc: &[usize],
) -> Result<Literal, Error> {
    if lc.len() != 1 || rc.len() != 1 {
        return err(format!("`{}`: only single contracting dims supported", inst.name));
    }
    let (lc, rc) = (lc[0], rc[0]);
    match (a.dims.len(), b.dims.len()) {
        (2, 2) => {
            if lc != 1 || rc != 0 {
                return err(format!("`{}`: unsupported dot layout", inst.name));
            }
            let (m, k) = (a.dims[0], a.dims[1]);
            let n = b.dims[1];
            if b.dims[0] != k {
                return err(format!("`{}`: dot inner dims differ", inst.name));
            }
            let mut out = vec![0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0f32;
                    for p in 0..k {
                        acc += a.data[i * k + p] * b.data[p * n + j];
                    }
                    out[i * n + j] = acc;
                }
            }
            Ok(Literal::tensor(vec![m, n], out))
        }
        (2, 1) => {
            if lc != 1 || rc != 0 {
                return err(format!("`{}`: unsupported dot layout", inst.name));
            }
            let (m, k) = (a.dims[0], a.dims[1]);
            if b.dims[0] != k {
                return err(format!("`{}`: dot inner dims differ", inst.name));
            }
            let mut out = vec![0f32; m];
            for (i, slot) in out.iter_mut().enumerate() {
                let mut acc = 0f32;
                for p in 0..k {
                    acc += a.data[i * k + p] * b.data[p];
                }
                *slot = acc;
            }
            Ok(Literal::tensor(vec![m], out))
        }
        (1, 2) => {
            if lc != 0 || rc != 0 {
                return err(format!("`{}`: unsupported dot layout", inst.name));
            }
            let k = a.dims[0];
            let n = b.dims[1];
            if b.dims[0] != k {
                return err(format!("`{}`: dot inner dims differ", inst.name));
            }
            let mut out = vec![0f32; n];
            for (j, slot) in out.iter_mut().enumerate() {
                let mut acc = 0f32;
                for p in 0..k {
                    acc += a.data[p] * b.data[p * n + j];
                }
                *slot = acc;
            }
            Ok(Literal::tensor(vec![n], out))
        }
        (1, 1) => {
            if a.dims[0] != b.dims[0] {
                return err(format!("`{}`: dot vector lengths differ", inst.name));
            }
            let acc = a.data.iter().zip(&b.data).map(|(x, y)| x * y).sum();
            Ok(Literal::tensor(vec![], vec![acc]))
        }
        _ => err(format!("`{}`: dot rank {:?}x{:?} unsupported", inst.name, a.dims, b.dims)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MATMUL: &str = r#"HloModule xla_computation_fn, entry_computation_layout={(f32[2,2]{1,0}, f32[2,2]{1,0})->(f32[2,2]{1,0})}

ENTRY main.8 {
  Arg_0.1 = f32[2,2]{1,0} parameter(0)
  Arg_1.2 = f32[2,2]{1,0} parameter(1)
  dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  constant.4 = f32[] constant(2)
  broadcast.5 = f32[2,2]{1,0} broadcast(constant.4), dimensions={}
  add.6 = f32[2,2]{1,0} add(dot.3, broadcast.5)
  ROOT tuple.7 = (f32[2,2]{1,0}) tuple(add.6)
}
"#;

    fn run(text: &str, args: &[Literal]) -> Literal {
        let proto = HloModuleProto::from_text(text).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let exe = PjRtClient::cpu().unwrap().compile(&comp).unwrap();
        let out = exe.execute::<Literal>(args).unwrap();
        out[0][0].to_literal_sync().unwrap()
    }

    #[test]
    fn matmul_plus_two_evaluates() {
        let a = Literal::vec1(&[1., 2., 3., 4.]).reshape(&[2, 2]).unwrap();
        let b = Literal::vec1(&[1., 1., 1., 1.]).reshape(&[2, 2]).unwrap();
        let out = run(MATMUL, &[a, b]).to_tuple1().unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![5., 5., 9., 9.]);
    }

    #[test]
    fn scalar_broadcast_fills_shape() {
        let text = r#"HloModule m
ENTRY e {
  c = f32[] constant(3)
  ROOT b = f32[2,3]{1,0} broadcast(c), dimensions={}
}
"#;
        let out = run(text, &[]);
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![3.0; 6]);
    }

    #[test]
    fn vector_broadcast_along_dim() {
        let text = r#"HloModule m
ENTRY e {
  p = f32[3]{0} parameter(0)
  ROOT b = f32[2,3]{1,0} broadcast(p), dimensions={1}
}
"#;
        let out = run(text, &[Literal::vec1(&[1., 2., 3.])]);
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn matvec_dot() {
        let text = r#"HloModule m
ENTRY e {
  a = f32[2,3]{1,0} parameter(0)
  v = f32[3]{0} parameter(1)
  ROOT d = f32[2]{0} dot(a, v), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"#;
        let a = Literal::vec1(&[1., 2., 3., 4., 5., 6.]).reshape(&[2, 3]).unwrap();
        let v = Literal::vec1(&[1., 0., 1.]);
        let out = run(text, &[a, v]);
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![4., 10.]);
    }

    #[test]
    fn transpose_permutes() {
        let text = r#"HloModule m
ENTRY e {
  p = f32[2,3]{1,0} parameter(0)
  ROOT t = f32[3,2]{1,0} transpose(p), dimensions={1,0}
}
"#;
        let p = Literal::vec1(&[1., 2., 3., 4., 5., 6.]).reshape(&[2, 3]).unwrap();
        let out = run(text, &[p]);
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn unsupported_opcode_fails_at_compile() {
        let text = r#"HloModule m
ENTRY e {
  p = f32[4]{0} parameter(0)
  ROOT s = f32[4]{0} sort(p), dimensions={0}
}
"#;
        let proto = HloModuleProto::from_text(text).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        assert!(PjRtClient::cpu().unwrap().compile(&comp).is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        assert!(Literal::vec1(&[1., 2., 3.]).reshape(&[2, 2]).is_err());
        assert!(Literal::vec1(&[1., 2., 3., 4.]).reshape(&[2, 2]).is_ok());
    }

    #[test]
    fn missing_file_errors() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
