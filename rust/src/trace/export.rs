//! Trace exporters: Chrome trace-event JSON (Perfetto-loadable), the
//! line-oriented replay capture, and a dependency-free JSON checker.
//!
//! The Chrome export lays the pool out as one process with three kinds
//! of tracks:
//!
//! * one track per device (`B`/`E` duration pairs around every executed
//!   batch, plus quarantine/probe/readmit instants);
//! * one `scheduler` track carrying the queue-side instants (`Submit`,
//!   `Enqueue`, `BackpressureWait`, pops, `ShardPlanned`, `Retry`,
//!   `Stitch`, `DeadlineJudged`);
//! * one track per client holding each request's complete span as an
//!   `X` event from `Submit` to `Done`, tied to the device batches that
//!   executed it by `s`/`f` flow events.
//!
//! Open the file in <https://ui.perfetto.dev> (or `chrome://tracing`)
//! directly — it is the standard `{"traceEvents": [...]}` envelope.
//!
//! [`validate_chrome_trace`] re-parses an export with the hand-rolled
//! [`parse_json`] (the offline crate set has no serde) and checks the
//! structural invariants Perfetto needs: well-formed JSON, a
//! `traceEvents` array, `ph`/`pid`/`tid` on every event, timestamps on
//! every non-metadata event, and strictly matched `B`/`E` pairs per
//! track. CI runs it over the smoke-mode bench trace via
//! `omprt trace-validate`.
//!
//! [`validate_capture`] does the same job for the line-oriented
//! `# omprt-capture v1` replay format: header magic, the fixed
//! seven-token line grammar, monotone submit timestamps, unique request
//! ids, decodable escaped client names, shard/arch consistency
//! (`shards > 1` iff a real arch label) and a well-formed `# dropped=N`
//! lossy trailer. It is a thin wrapper over the typed parser in
//! [`super::capture`], which replay consumers use directly.

use super::event::{EventKind, TraceRecord};
use super::metrics::json_escape;
use std::collections::BTreeMap;

/// Labels needed to render a trace for humans: where devices, clients
/// and shard-plan arch codes get their names.
#[derive(Debug, Clone, Default)]
pub struct ExportMeta {
    /// Process name shown in the trace viewer (e.g. `omprt pool`).
    pub process: String,
    /// Per-device track labels, indexed by device id.
    pub device_labels: Vec<String>,
    /// Client interner table (from [`super::TraceSnapshot::clients`]).
    pub clients: Vec<String>,
    /// Arch names indexed by the `ShardPlanned` arch code.
    pub arch_labels: Vec<String>,
}

impl ExportMeta {
    pub(crate) fn client(&self, id: u64) -> &str {
        self.clients.get(id as usize).map_or("?", |s| s.as_str())
    }

    pub(crate) fn arch(&self, code: u64) -> &str {
        self.arch_labels.get(code as usize).map_or("?", |s| s.as_str())
    }
}

const PID: u64 = 1;
const SCHED_TID: u64 = 100;
const CLIENT_TID_BASE: u64 = 200;

fn ts_us(t_ns: u64) -> String {
    format!("{:.3}", t_ns as f64 / 1e3)
}

fn device_tid(dev: usize) -> u64 {
    1 + dev as u64
}

fn meta_event(out: &mut Vec<String>, name: &str, tid: u64, label: &str) {
    out.push(format!(
        "{{\"name\": \"{}\", \"ph\": \"M\", \"pid\": {PID}, \"tid\": {tid}, \
         \"args\": {{\"name\": \"{}\"}}}}",
        json_escape(name),
        json_escape(label)
    ));
}

/// Render a drained record set as Chrome trace-event JSON. Records must
/// be time-sorted (as [`super::Tracer::snapshot`] returns them).
pub fn chrome_trace_json(records: &[TraceRecord], meta: &ExportMeta) -> String {
    let mut ev: Vec<String> = Vec::new();
    let process = if meta.process.is_empty() { "omprt pool" } else { &meta.process };
    meta_event(&mut ev, "process_name", 0, process);
    for (d, label) in meta.device_labels.iter().enumerate() {
        meta_event(&mut ev, "thread_name", device_tid(d), label);
    }
    meta_event(&mut ev, "thread_name", SCHED_TID, "scheduler");
    for (c, name) in meta.clients.iter().enumerate() {
        let label = if name.is_empty() { "requests:(default)".to_string() } else { format!("requests:{name}") };
        meta_event(&mut ev, "thread_name", CLIENT_TID_BASE + c as u64, &label);
    }

    // Pass 1: request spans (Submit → Done) as X events per client
    // track, with an `s` flow origin at submit time.
    let mut submits: BTreeMap<u64, &TraceRecord> = BTreeMap::new();
    let mut dones: BTreeMap<u64, &TraceRecord> = BTreeMap::new();
    for r in records {
        match r.kind {
            EventKind::Submit => {
                submits.entry(r.req).or_insert(r);
            }
            EventKind::Done => {
                dones.insert(r.req, r);
            }
            _ => {}
        }
    }
    for (req, sub) in &submits {
        let tid = CLIENT_TID_BASE + sub.a;
        match dones.get(req) {
            Some(done) => {
                let dur_ns = done.t_ns.saturating_sub(sub.t_ns);
                let ok = done.a == 1;
                ev.push(format!(
                    "{{\"name\": \"req {req}\", \"cat\": \"request\", \"ph\": \"X\", \
                     \"pid\": {PID}, \"tid\": {tid}, \"ts\": {}, \"dur\": {}, \
                     \"args\": {{\"req\": {req}, \"client\": \"{}\", \"ok\": {ok}, \
                     \"key\": \"{:#x}\"}}}}",
                    ts_us(sub.t_ns),
                    ts_us(dur_ns),
                    json_escape(meta.client(sub.a)),
                    sub.b
                ));
            }
            None => {
                // Incomplete span (snapshot taken mid-flight): an
                // instant, so the B/E discipline stays intact.
                ev.push(format!(
                    "{{\"name\": \"req {req} (in flight)\", \"cat\": \"request\", \
                     \"ph\": \"i\", \"s\": \"t\", \"pid\": {PID}, \"tid\": {tid}, \
                     \"ts\": {}, \"args\": {{\"req\": {req}}}}}",
                    ts_us(sub.t_ns)
                ));
            }
        }
        ev.push(format!(
            "{{\"name\": \"req\", \"cat\": \"flow\", \"ph\": \"s\", \"id\": {req}, \
             \"pid\": {PID}, \"tid\": {tid}, \"ts\": {}}}",
            ts_us(sub.t_ns)
        ));
    }

    // Pass 2: per-device batch spans. One worker per device executes
    // sequentially, so Start/End pair up in order; an unpaired Start
    // (snapshot mid-batch, or End lost to ring overwrite) degrades to an
    // instant so B/E always match.
    let ndev = records
        .iter()
        .filter_map(|r| r.device)
        .max()
        .map_or(meta.device_labels.len(), |m| (m + 1).max(meta.device_labels.len()));
    for dev in 0..ndev {
        let tid = device_tid(dev);
        let mut open: Option<&TraceRecord> = None;
        for r in records.iter().filter(|r| r.device == Some(dev)) {
            match r.kind {
                EventKind::LaunchStart => {
                    if let Some(stale) = open.take() {
                        launch_instant(&mut ev, stale, tid, "launch (no end)");
                    }
                    open = Some(r);
                }
                EventKind::LaunchEnd => {
                    if let Some(start) = open.take() {
                        ev.push(format!(
                            "{{\"name\": \"batch x{}\", \"cat\": \"launch\", \"ph\": \"B\", \
                             \"pid\": {PID}, \"tid\": {tid}, \"ts\": {}, \
                             \"args\": {{\"req\": {}, \"jobs\": {}, \"key\": \"{:#x}\"}}}}",
                            start.a,
                            ts_us(start.t_ns),
                            start.req,
                            start.a,
                            start.b
                        ));
                        ev.push(format!(
                            "{{\"name\": \"batch x{}\", \"cat\": \"launch\", \"ph\": \"E\", \
                             \"pid\": {PID}, \"tid\": {tid}, \"ts\": {}, \
                             \"args\": {{\"ok\": {}, \"wall_ns\": {}}}}}",
                            start.a,
                            ts_us(r.t_ns.max(start.t_ns)),
                            r.b == 1,
                            r.c
                        ));
                        // Flow target: tie the request span to the batch
                        // that executed its lead job.
                        if start.req != 0 {
                            ev.push(format!(
                                "{{\"name\": \"req\", \"cat\": \"flow\", \"ph\": \"f\", \
                                 \"bp\": \"e\", \"id\": {}, \"pid\": {PID}, \"tid\": {tid}, \
                                 \"ts\": {}}}",
                                start.req,
                                ts_us(start.t_ns)
                            ));
                        }
                    }
                }
                EventKind::Quarantine | EventKind::Probe | EventKind::Readmit => {
                    launch_instant(&mut ev, r, tid, r.kind.name());
                }
                _ => {}
            }
        }
        if let Some(stale) = open {
            launch_instant(&mut ev, stale, tid, "launch (in flight)");
        }
    }

    // Pass 3: queue-side instants on the scheduler track.
    for r in records {
        let name = match r.kind {
            EventKind::Submit
            | EventKind::Done
            | EventKind::LaunchStart
            | EventKind::LaunchEnd
            | EventKind::Quarantine
            | EventKind::Probe
            | EventKind::Readmit => continue,
            EventKind::ShardPlanned => {
                format!("ShardPlanned x{} ({})", r.a, meta.arch(r.b))
            }
            k => k.name().to_string(),
        };
        ev.push(format!(
            "{{\"name\": \"{}\", \"cat\": \"queue\", \"ph\": \"i\", \"s\": \"t\", \
             \"pid\": {PID}, \"tid\": {SCHED_TID}, \"ts\": {}, \
             \"args\": {{\"req\": {}, \"a\": {}, \"b\": {}, \"c\": {}}}}}",
            json_escape(&name),
            ts_us(r.t_ns),
            r.req,
            r.a,
            r.b,
            r.c
        ));
    }

    format!(
        "{{\"displayTimeUnit\": \"ms\",\n\"traceEvents\": [\n{}\n]}}\n",
        ev.join(",\n")
    )
}

fn launch_instant(ev: &mut Vec<String>, r: &TraceRecord, tid: u64, name: &str) {
    ev.push(format!(
        "{{\"name\": \"{}\", \"cat\": \"device\", \"ph\": \"i\", \"s\": \"t\", \
         \"pid\": {PID}, \"tid\": {tid}, \"ts\": {}, \"args\": {{\"req\": {}, \"a\": {}}}}}",
        json_escape(name),
        ts_us(r.t_ns),
        r.req,
        r.a
    ));
}

/// Render the replay capture: one line per accepted request with
/// everything a replay driver needs to re-issue the same workload shape
/// — client, image key, shard fan-out + arch, deadline budget and the
/// original submit timestamp (µs since pool start, for paced replay).
///
/// Client names are percent-escaped injectively (see
/// [`super::capture::escape_client`]) so hostile names — whitespace,
/// `=`, a literal `-` — survive the round trip; deadline budgets round
/// **up** to whole microseconds so a sub-µs budget never collapses to
/// the absent sentinel; and a non-zero `dropped` (the trace ring's
/// overwrite count) appends a `# dropped=N` trailer marking the capture
/// as lossy.
pub fn capture_text(records: &[TraceRecord], meta: &ExportMeta, dropped: u64) -> String {
    super::capture::Capture::from_records(records, meta, dropped).to_text()
}

/// A parsed JSON value — the minimal tree the validator (and tests)
/// need; the offline crate set has no serde.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (parsed as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<JsonValue>),
    /// An object, field order preserved.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Field lookup on an object (`None` for other variants).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("JSON error at byte {}: {msg}", self.pos)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|_| self.err("bad utf8"))?;
        text.parse::<f64>()
            .map(JsonValue::Num)
            .map_err(|_| self.err(&format!("bad number `{text}`")))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates (paired or lone) degrade to the
                            // replacement char — the validator only needs
                            // structure, not full fidelity.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(fields));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

/// Parse a JSON document (trailing whitespace allowed, trailing garbage
/// rejected).
pub fn parse_json(input: &str) -> Result<JsonValue, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

/// Validate a Chrome trace-event export: well-formed JSON, a
/// `traceEvents` array, `ph`/`pid`/`tid` on every event, a `ts` on every
/// non-metadata event, and strictly matched `B`/`E` pairs per
/// `(pid, tid)` track (checked in timestamp order). Returns the event
/// count.
pub fn validate_chrome_trace(json: &str) -> Result<usize, String> {
    let root = parse_json(json)?;
    let events = match root.get("traceEvents") {
        Some(JsonValue::Arr(events)) => events,
        _ => return Err("missing `traceEvents` array".to_string()),
    };
    // (pid, tid) -> [(ts, is_begin, file order)]
    let mut tracks: BTreeMap<(i64, i64), Vec<(f64, bool, usize)>> = BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| format!("event {i}: missing `ph`"))?;
        let pid = e
            .get("pid")
            .and_then(JsonValue::as_num)
            .ok_or_else(|| format!("event {i}: missing `pid`"))? as i64;
        let tid = e
            .get("tid")
            .and_then(JsonValue::as_num)
            .ok_or_else(|| format!("event {i}: missing `tid`"))? as i64;
        let ts = e.get("ts").and_then(JsonValue::as_num);
        if ph != "M" && ts.is_none() {
            return Err(format!("event {i}: `{ph}` event without `ts`"));
        }
        match ph {
            "B" => tracks.entry((pid, tid)).or_default().push((ts.unwrap(), true, i)),
            "E" => tracks.entry((pid, tid)).or_default().push((ts.unwrap(), false, i)),
            _ => {}
        }
    }
    for ((pid, tid), mut evs) in tracks {
        evs.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap().then(x.2.cmp(&y.2)));
        let mut depth: i64 = 0;
        for (ts, is_b, _) in evs {
            if is_b {
                depth += 1;
            } else {
                depth -= 1;
                if depth < 0 {
                    return Err(format!(
                        "track pid={pid} tid={tid}: `E` at ts={ts} without a matching `B`"
                    ));
                }
            }
        }
        if depth != 0 {
            return Err(format!("track pid={pid} tid={tid}: {depth} unclosed `B` event(s)"));
        }
    }
    Ok(events.len())
}

/// Validate a `# omprt-capture v1` replay capture (the [`capture_text`]
/// output): the version header on line 1, then per non-comment line the
/// fixed grammar `req= t_us= client= key= deadline_us= shards= arch=`
/// with parseable values — unique `u64` request ids, finite
/// non-decreasing `t_us`, a decodable escaped client, a `0x`-hex image
/// key, `deadline_us` either `-` or a `u64`, `shards >= 1`, and
/// `shards > 1` exactly when `arch` is a real label (not `-`). A
/// `# dropped=N` trailer must be well-formed and final. Returns the
/// request-line count; a thin wrapper over
/// [`super::capture::parse_capture`], which this shares its grammar
/// with.
pub fn validate_capture(text: &str) -> Result<usize, String> {
    super::capture::parse_capture(text).map(|c| c.records.len())
}

#[cfg(test)]
mod tests {
    use super::super::event::{Event, EventKind};
    use super::super::sink::Tracer;
    use super::*;

    fn sample_meta() -> ExportMeta {
        ExportMeta {
            process: "omprt pool".to_string(),
            device_labels: vec!["dev0 portable:nvptx64".to_string(), "dev1 legacy:amdgcn".to_string()],
            clients: vec!["".to_string(), "bulk".to_string()],
            arch_labels: vec!["nvptx64".to_string(), "amdgcn".to_string()],
        }
    }

    /// A plausible two-request trace: one plain request batch-executed
    /// on dev0, one sharded request split over both devices.
    fn sample_records() -> Vec<TraceRecord> {
        let t = Tracer::new(true, 1024, 2);
        let r1 = t.next_request_id();
        let r2 = t.next_request_id();
        t.emit_at(None, 100, Event::new(EventKind::Submit).req(r1).a(1).b(0xabc).c(250_000_000));
        t.emit_at(None, 150, Event::new(EventKind::Enqueue).req(r1).a(1));
        t.emit_at(None, 200, Event::new(EventKind::Submit).req(r2).a(0).b(0xdef));
        t.emit_at(None, 210, Event::new(EventKind::ShardPlanned).req(r2).a(2).b(0));
        t.emit_at(None, 220, Event::new(EventKind::Enqueue).req(r2).a(2).b(1).c(1));
        t.emit_at(None, 225, Event::new(EventKind::Enqueue).req(r2).a(3).b(1).c(2));
        t.emit_at(Some(0), 300, Event::new(EventKind::PopNormal).device(0).req(r1).a(1));
        t.emit_at(Some(0), 310, Event::new(EventKind::LaunchStart).device(0).req(r1).a(1).b(0xabc));
        t.emit_at(Some(0), 400, Event::new(EventKind::LaunchEnd).device(0).req(r1).a(1).b(1).c(90));
        t.emit_at(None, 420, Event::new(EventKind::DeadlineJudged).req(r1).a(0).b(1000).c(1));
        t.emit_at(None, 430, Event::new(EventKind::Done).req(r1).a(1).b(330).c(1));
        t.emit_at(Some(0), 500, Event::new(EventKind::LaunchStart).device(0).req(r2).a(1).b(0xdef));
        t.emit_at(Some(0), 560, Event::new(EventKind::LaunchEnd).device(0).req(r2).a(1).b(1).c(60));
        t.emit_at(Some(1), 505, Event::new(EventKind::LaunchStart).device(1).req(r2).a(1).b(0xdef));
        t.emit_at(Some(1), 590, Event::new(EventKind::LaunchEnd).device(1).req(r2).a(1).b(1).c(85));
        t.emit_at(None, 600, Event::new(EventKind::Stitch).req(r2).a(2).b(1));
        t.emit_at(None, 610, Event::new(EventKind::Done).req(r2).a(1).b(410));
        t.snapshot().records
    }

    #[test]
    fn chrome_export_is_valid_json_with_matched_pairs() {
        let records = sample_records();
        let json = chrome_trace_json(&records, &sample_meta());
        let n = validate_chrome_trace(&json).expect("export must validate");
        assert!(n > records.len() / 2, "export carries a useful event count: {n}");
        // Both complete request spans render as X events.
        assert_eq!(json.matches("\"ph\": \"X\"").count(), 2);
        // Three executed batches → three B/E pairs.
        assert_eq!(json.matches("\"ph\": \"B\"").count(), 3);
        assert_eq!(json.matches("\"ph\": \"E\"").count(), 3);
        // Flow events tie submits to launches.
        assert_eq!(json.matches("\"ph\": \"s\"").count(), 2);
        assert_eq!(json.matches("\"ph\": \"f\"").count(), 3);
        assert!(json.contains("ShardPlanned x2 (nvptx64)"), "{json}");
    }

    #[test]
    fn incomplete_span_degrades_to_instants_and_still_validates() {
        let t = Tracer::new(true, 64, 1);
        let r = t.next_request_id();
        t.emit_at(None, 10, Event::new(EventKind::Submit).req(r).a(0).b(1));
        t.emit_at(Some(0), 20, Event::new(EventKind::LaunchStart).device(0).req(r).a(1).b(1));
        // No LaunchEnd, no Done: mid-flight snapshot.
        let json = chrome_trace_json(&t.snapshot().records, &sample_meta());
        validate_chrome_trace(&json).expect("mid-flight snapshot still validates");
        assert!(json.contains("in flight"), "{json}");
        assert_eq!(json.matches("\"ph\": \"B\"").count(), 0);
    }

    #[test]
    fn capture_lists_accepted_requests_with_shard_and_deadline() {
        let records = sample_records();
        let text = capture_text(&records, &sample_meta(), 0);
        assert!(text.starts_with("# omprt-capture v1\n"), "{text}");
        let lines: Vec<&str> = text.lines().filter(|l| !l.starts_with('#')).collect();
        assert_eq!(lines.len(), 2, "one line per accepted request:\n{text}");
        assert!(
            lines[0].contains("client=bulk")
                && lines[0].contains("deadline_us=250000")
                && lines[0].contains("shards=1")
                && lines[0].contains("key=0xabc"),
            "{}",
            lines[0]
        );
        assert!(
            lines[1].contains("client=-")
                && lines[1].contains("deadline_us=-")
                && lines[1].contains("shards=2")
                && lines[1].contains("arch=nvptx64"),
            "{}",
            lines[1]
        );
    }

    #[test]
    fn capture_validator_accepts_real_exports() {
        let text = capture_text(&sample_records(), &sample_meta(), 0);
        assert_eq!(validate_capture(&text).unwrap(), 2, "{text}");
        // An empty capture (header only) is valid with zero requests.
        assert_eq!(validate_capture("# omprt-capture v1\n").unwrap(), 0);
    }

    /// A lossy ring must not produce a capture that claims full
    /// coverage: the overwrite count surfaces as a `# dropped=N`
    /// trailer that still validates but is visible to consumers.
    #[test]
    fn capture_marks_lossy_rings_with_a_dropped_trailer() {
        let text = capture_text(&sample_records(), &sample_meta(), 3);
        assert!(text.ends_with("# dropped=3\n"), "{text}");
        assert_eq!(validate_capture(&text).unwrap(), 2, "{text}");
        assert_eq!(super::super::capture::parse_capture(&text).unwrap().dropped, 3);
        // Lossless captures carry no trailer at all.
        assert!(!capture_text(&sample_records(), &sample_meta(), 0).contains("dropped"));
    }

    /// Regression (capture grammar): the exporter used to write client
    /// names after only whitespace→`_` mangling, so a client literally
    /// named `-` collided with the no-client sentinel and a name
    /// containing `=` corrupted the `key=value` grammar. Names now
    /// escape injectively and round-trip.
    #[test]
    fn capture_escapes_hostile_client_names_injectively() {
        let meta = ExportMeta {
            clients: vec![
                "-".to_string(),
                "a=b".to_string(),
                "under_score".to_string(),
                "under score".to_string(),
            ],
            ..sample_meta()
        };
        let t = Tracer::new(true, 64, 1);
        for c in 0..4u64 {
            let r = t.next_request_id();
            t.emit_at(None, 100 * (c + 1), Event::new(EventKind::Submit).req(r).a(c).b(0xa));
        }
        let text = capture_text(&t.snapshot().records, &meta, 0);
        // The sentinel collision and the grammar corruption are gone...
        assert!(text.contains("client=%2D"), "{text}");
        assert!(text.contains("client=a%3Db"), "{text}");
        // ...and the two names the old `_` mangling merged stay distinct.
        assert!(text.contains("client=under_score"), "{text}");
        assert!(text.contains("client=under%20score"), "{text}");
        assert_eq!(validate_capture(&text).unwrap(), 4, "{text}");
        let cap = super::super::capture::parse_capture(&text).unwrap();
        let names: Vec<&str> = cap.records.iter().map(|r| r.client.as_str()).collect();
        assert_eq!(names, ["-", "a=b", "under_score", "under score"], "{text}");
    }

    /// Regression (deadline truncation): a sub-microsecond budget
    /// (1..999 ns) used to floor-divide to `deadline_us=0`, telling a
    /// replay the budget was already missed. Budgets now round up, with
    /// `-` reserved for the genuinely-absent case.
    #[test]
    fn capture_rounds_sub_microsecond_deadlines_up() {
        let t = Tracer::new(true, 64, 1);
        for (i, ns) in [1u64, 999, 1_000, 1_001].into_iter().enumerate() {
            let r = t.next_request_id();
            t.emit_at(
                None,
                100 * (i as u64 + 1),
                Event::new(EventKind::Submit).req(r).a(0).b(0xa).c(ns),
            );
        }
        let text = capture_text(&t.snapshot().records, &sample_meta(), 0);
        let deadlines: Vec<&str> = text
            .lines()
            .filter(|l| !l.starts_with('#'))
            .map(|l| {
                l.split_whitespace()
                    .find_map(|tok| tok.strip_prefix("deadline_us="))
                    .unwrap()
            })
            .collect();
        assert_eq!(deadlines, ["1", "1", "1", "2"], "{text}");
        assert!(!text.contains("deadline_us=0"), "{text}");
    }

    #[test]
    fn capture_validator_rejects_malformed_lines() {
        let hdr = "# omprt-capture v1\n";
        let ok = "req=1 t_us=0.100 client=bulk key=0xabc deadline_us=250 shards=1 arch=-\n";
        assert_eq!(validate_capture(&format!("{hdr}{ok}")).unwrap(), 1);
        // Wrong or missing header.
        assert!(validate_capture("").unwrap_err().contains("header"));
        assert!(validate_capture(&format!("# omprt-capture v2\n{ok}"))
            .unwrap_err()
            .contains("header"));
        // Token-level grammar failures, each with the line number.
        for (bad, why) in [
            ("req=1 t_us=0.1 client=c key=0xa deadline_us=- shards=1\n", "tokens"),
            ("req=1 t_us=0.1 key=0xa client=c deadline_us=- shards=1 arch=-\n", "client="),
            ("req=x t_us=0.1 client=c key=0xa deadline_us=- shards=1 arch=-\n", "bad req"),
            ("req=1 t_us=zz client=c key=0xa deadline_us=- shards=1 arch=-\n", "bad t_us"),
            ("req=1 t_us=0.1 client=c key=abc deadline_us=- shards=1 arch=-\n", "0x-hex"),
            ("req=1 t_us=0.1 client=c key=0xzz deadline_us=- shards=1 arch=-\n", "bad hex"),
            // Hostile client names the pre-escaping exporter emitted
            // verbatim: a raw `=` inside the value and escape sequences
            // no encoder produces must both be rejected, not silently
            // re-tokenized.
            ("req=1 t_us=0.1 client=a=b key=0xa deadline_us=- shards=1 arch=-\n", "client"),
            ("req=1 t_us=0.1 client=%zz key=0xa deadline_us=- shards=1 arch=-\n", "client"),
            ("req=1 t_us=0.1 client=c key=0xa deadline_us=soon shards=1 arch=-\n", "deadline"),
            ("req=1 t_us=0.1 client=c key=0xa deadline_us=- shards=0 arch=-\n", ">= 1"),
        ] {
            let err = validate_capture(&format!("{hdr}{bad}")).unwrap_err();
            assert!(err.contains("line 2") && err.contains(why), "{bad:?} -> {err}");
        }
        // Duplicate request ids and backwards timestamps span lines.
        let dup = format!("{hdr}{ok}req=1 t_us=0.200 client=c key=0xb deadline_us=- shards=1 arch=-\n");
        assert!(validate_capture(&dup).unwrap_err().contains("duplicate req"));
        let back = format!("{hdr}{ok}req=2 t_us=0.050 client=c key=0xb deadline_us=- shards=1 arch=-\n");
        assert!(validate_capture(&back).unwrap_err().contains("backwards"));
        // Shard/arch consistency, both directions.
        let sharded_no_arch =
            format!("{hdr}req=1 t_us=0.1 client=c key=0xa deadline_us=- shards=2 arch=-\n");
        assert!(validate_capture(&sharded_no_arch).unwrap_err().contains("inconsistent"));
        let plain_with_arch =
            format!("{hdr}req=1 t_us=0.1 client=c key=0xa deadline_us=- shards=1 arch=nvptx64\n");
        assert!(validate_capture(&plain_with_arch).unwrap_err().contains("inconsistent"));
    }

    #[test]
    fn parser_accepts_valid_documents() {
        let v = parse_json(r#"{"a": [1, -2.5e3, "x\n\"yA"], "b": {"c": true, "d": null}}"#)
            .unwrap();
        assert_eq!(
            v.get("a"),
            Some(&JsonValue::Arr(vec![
                JsonValue::Num(1.0),
                JsonValue::Num(-2500.0),
                JsonValue::Str("x\n\"yA".to_string()),
            ]))
        );
        assert_eq!(v.get("b").and_then(|b| b.get("c")), Some(&JsonValue::Bool(true)));
        assert_eq!(v.get("b").and_then(|b| b.get("d")), Some(&JsonValue::Null));
        assert_eq!(parse_json("[]").unwrap(), JsonValue::Arr(vec![]));
        assert_eq!(parse_json(" {} ").unwrap(), JsonValue::Obj(vec![]));
    }

    #[test]
    fn parser_rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"unterminated", "{]}"] {
            assert!(parse_json(bad).is_err(), "must reject {bad:?}");
        }
    }

    #[test]
    fn validator_rejects_unbalanced_pairs() {
        let unbalanced = r#"{"traceEvents": [
            {"name": "x", "ph": "B", "pid": 1, "tid": 1, "ts": 1.0},
            {"name": "x", "ph": "B", "pid": 1, "tid": 1, "ts": 2.0},
            {"name": "x", "ph": "E", "pid": 1, "tid": 1, "ts": 3.0}
        ]}"#;
        let err = validate_chrome_trace(unbalanced).unwrap_err();
        assert!(err.contains("unclosed"), "{err}");
        let orphan = r#"{"traceEvents": [
            {"name": "x", "ph": "E", "pid": 1, "tid": 1, "ts": 1.0}
        ]}"#;
        let err = validate_chrome_trace(orphan).unwrap_err();
        assert!(err.contains("without a matching"), "{err}");
        // Per-track isolation: pairs on different tids don't cancel.
        let cross = r#"{"traceEvents": [
            {"name": "x", "ph": "B", "pid": 1, "tid": 1, "ts": 1.0},
            {"name": "x", "ph": "E", "pid": 1, "tid": 2, "ts": 2.0}
        ]}"#;
        assert!(validate_chrome_trace(cross).is_err());
        // Missing required keys.
        assert!(validate_chrome_trace(r#"{"traceEvents": [{"pid": 1, "tid": 1}]}"#).is_err());
        assert!(
            validate_chrome_trace(r#"{"traceEvents": [{"ph": "i", "pid": 1, "tid": 1}]}"#)
                .is_err(),
            "non-metadata event without ts must fail"
        );
        assert!(validate_chrome_trace(r#"{"notTraceEvents": []}"#).is_err());
    }

    #[test]
    fn validator_accepts_metadata_without_ts() {
        let ok = r#"{"traceEvents": [
            {"name": "process_name", "ph": "M", "pid": 1, "tid": 0, "args": {"name": "p"}}
        ]}"#;
        assert_eq!(validate_chrome_trace(ok).unwrap(), 1);
    }
}
