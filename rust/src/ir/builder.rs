//! Ergonomic construction of IR functions.
//!
//! The device runtime library and every benchmark kernel are written
//! against this builder; it plays the role of Clang's codegen in the
//! paper's pipeline (OpenMP / CUDA source → bitcode).

use super::inst::{BinOp, CastOp, CmpPred, Inst, Stmt, UnOp};
use super::module::{Function, InlineHint, Linkage};
use super::types::{AddrSpace, Operand, Reg, Type};

/// Builder for a single [`Function`].
pub struct FunctionBuilder {
    name: String,
    num_params: u32,
    regs: Vec<Type>,
    ret: Option<Type>,
    is_kernel: bool,
    inline: InlineHint,
    linkage: Linkage,
    /// Stack of statement frames; `frames[0]` is the function body, deeper
    /// entries are open `if`/`loop` regions.
    frames: Vec<Vec<Stmt>>,
}

impl FunctionBuilder {
    /// Start a function with the given parameter types.
    pub fn new(name: impl Into<String>, params: &[Type], ret: Option<Type>) -> Self {
        FunctionBuilder {
            name: name.into(),
            num_params: params.len() as u32,
            regs: params.to_vec(),
            ret,
            is_kernel: false,
            inline: InlineHint::Default,
            linkage: Linkage::External,
            frames: vec![vec![]],
        }
    }

    /// Mark as a kernel entry point.
    pub fn kernel(mut self) -> Self {
        self.is_kernel = true;
        self
    }

    /// Set the inline hint.
    pub fn inline_hint(mut self, h: InlineHint) -> Self {
        self.inline = h;
        self
    }

    /// Set linkage.
    pub fn linkage(mut self, l: Linkage) -> Self {
        self.linkage = l;
        self
    }

    /// The i-th parameter register.
    pub fn param(&self, i: u32) -> Reg {
        assert!(i < self.num_params, "param {i} out of range");
        Reg(i)
    }

    /// Allocate a fresh register of type `ty`.
    pub fn new_reg(&mut self, ty: Type) -> Reg {
        let r = Reg(self.regs.len() as u32);
        self.regs.push(ty);
        r
    }

    /// Type of a register.
    pub fn reg_ty(&self, r: Reg) -> Type {
        self.regs[r.0 as usize]
    }

    fn ty_of(&self, o: Operand) -> Type {
        match o {
            Operand::Reg(r) => self.reg_ty(r),
            Operand::Const(c) => c.ty(),
        }
    }

    /// Push a raw statement.
    pub fn push(&mut self, s: Stmt) {
        self.frames.last_mut().expect("open frame").push(s);
    }

    /// Push an instruction.
    pub fn inst(&mut self, i: Inst) {
        self.push(Stmt::Inst(i));
    }

    // ---- arithmetic helpers -------------------------------------------

    /// `dst = op a, b` with the result type of `a`.
    pub fn bin(&mut self, op: BinOp, a: impl Into<Operand>, b: impl Into<Operand>) -> Reg {
        let a = a.into();
        let b = b.into();
        let dst = self.new_reg(self.ty_of(a));
        self.inst(Inst::Bin { op, dst, a, b });
        dst
    }

    /// Integer/float add.
    pub fn add(&mut self, a: impl Into<Operand>, b: impl Into<Operand>) -> Reg {
        self.bin(BinOp::Add, a, b)
    }
    /// Subtract.
    pub fn sub(&mut self, a: impl Into<Operand>, b: impl Into<Operand>) -> Reg {
        self.bin(BinOp::Sub, a, b)
    }
    /// Multiply.
    pub fn mul(&mut self, a: impl Into<Operand>, b: impl Into<Operand>) -> Reg {
        self.bin(BinOp::Mul, a, b)
    }
    /// Signed divide.
    pub fn sdiv(&mut self, a: impl Into<Operand>, b: impl Into<Operand>) -> Reg {
        self.bin(BinOp::SDiv, a, b)
    }
    /// Unsigned divide.
    pub fn udiv(&mut self, a: impl Into<Operand>, b: impl Into<Operand>) -> Reg {
        self.bin(BinOp::UDiv, a, b)
    }
    /// Signed remainder.
    pub fn srem(&mut self, a: impl Into<Operand>, b: impl Into<Operand>) -> Reg {
        self.bin(BinOp::SRem, a, b)
    }
    /// Float divide.
    pub fn fdiv(&mut self, a: impl Into<Operand>, b: impl Into<Operand>) -> Reg {
        self.bin(BinOp::FDiv, a, b)
    }

    /// `dst = op a`.
    pub fn un(&mut self, op: UnOp, a: impl Into<Operand>) -> Reg {
        let a = a.into();
        let dst = self.new_reg(self.ty_of(a));
        self.inst(Inst::Un { op, dst, a });
        dst
    }

    /// Comparison producing an i1.
    pub fn cmp(&mut self, pred: CmpPred, a: impl Into<Operand>, b: impl Into<Operand>) -> Reg {
        let a = a.into();
        let b = b.into();
        let dst = self.new_reg(Type::I1);
        self.inst(Inst::Cmp { pred, dst, a, b });
        dst
    }

    /// `dst = select cond, a, b`.
    pub fn select(
        &mut self,
        cond: impl Into<Operand>,
        a: impl Into<Operand>,
        b: impl Into<Operand>,
    ) -> Reg {
        let cond = cond.into();
        let a = a.into();
        let b = b.into();
        let dst = self.new_reg(self.ty_of(a));
        self.inst(Inst::Select { dst, cond, a, b });
        dst
    }

    /// Conversion into `to`.
    pub fn cast(&mut self, op: CastOp, src: impl Into<Operand>, to: Type) -> Reg {
        let src = src.into();
        let dst = self.new_reg(to);
        self.inst(Inst::Cast { op, dst, src });
        dst
    }

    /// i32 → i64 sign extension (the most common cast in kernels).
    pub fn sext64(&mut self, src: impl Into<Operand>) -> Reg {
        self.cast(CastOp::SExt, src, Type::I64)
    }

    /// Copy into a fresh register.
    pub fn copy(&mut self, src: impl Into<Operand>) -> Reg {
        let src = src.into();
        let dst = self.new_reg(self.ty_of(src));
        self.inst(Inst::Copy { dst, src });
        dst
    }

    /// Copy into an existing register (mutable-variable style).
    pub fn assign(&mut self, dst: Reg, src: impl Into<Operand>) {
        self.inst(Inst::Copy { dst, src: src.into() });
    }

    // ---- memory -------------------------------------------------------

    /// Typed load.
    pub fn load(&mut self, ty: Type, space: AddrSpace, addr: impl Into<Operand>) -> Reg {
        let addr = addr.into();
        let dst = self.new_reg(ty);
        self.inst(Inst::Load { dst, ty, space, addr });
        dst
    }

    /// Typed store.
    pub fn store(
        &mut self,
        ty: Type,
        space: AddrSpace,
        addr: impl Into<Operand>,
        val: impl Into<Operand>,
    ) {
        self.inst(Inst::Store { ty, space, addr: addr.into(), val: val.into() });
    }

    /// Address of a module global.
    pub fn global_addr(&mut self, name: impl Into<String>) -> Reg {
        let dst = self.new_reg(Type::I64);
        self.inst(Inst::GlobalAddr { dst, name: name.into() });
        dst
    }

    /// `base + index * scale` in i64 — the array-indexing idiom.
    pub fn index(
        &mut self,
        base: impl Into<Operand>,
        idx: impl Into<Operand>,
        scale: u64,
    ) -> Reg {
        let idx = idx.into();
        let idx64 = if self.ty_of(idx) == Type::I64 {
            idx
        } else {
            Operand::Reg(self.sext64(idx))
        };
        let scaled = self.bin(BinOp::Mul, idx64, Operand::i64(scale as i64));
        self.bin(BinOp::Add, base.into(), scaled)
    }

    // ---- calls --------------------------------------------------------

    /// Call with a result.
    pub fn call(&mut self, callee: impl Into<String>, args: &[Operand], ret: Type) -> Reg {
        let dst = self.new_reg(ret);
        self.inst(Inst::Call { dst: Some(dst), callee: callee.into(), args: args.to_vec() });
        dst
    }

    /// Call without a result.
    pub fn call_void(&mut self, callee: impl Into<String>, args: &[Operand]) {
        self.inst(Inst::Call { dst: None, callee: callee.into(), args: args.to_vec() });
    }

    /// Device trap.
    pub fn trap(&mut self, msg: impl Into<String>) {
        self.inst(Inst::Trap { msg: msg.into() });
    }

    // ---- structured control -------------------------------------------

    /// `if cond { then } else { else_ }`.
    pub fn if_else(
        &mut self,
        cond: impl Into<Operand>,
        then_: impl FnOnce(&mut Self),
        else_: impl FnOnce(&mut Self),
    ) {
        let cond = cond.into();
        self.frames.push(vec![]);
        then_(self);
        let t = self.frames.pop().unwrap();
        self.frames.push(vec![]);
        else_(self);
        let e = self.frames.pop().unwrap();
        self.push(Stmt::If { cond, then_: t, else_: e });
    }

    /// `if cond { then }`.
    pub fn if_(&mut self, cond: impl Into<Operand>, then_: impl FnOnce(&mut Self)) {
        self.if_else(cond, then_, |_| {});
    }

    /// `loop { body }` — exit with [`Self::break_`].
    pub fn loop_(&mut self, body: impl FnOnce(&mut Self)) {
        self.frames.push(vec![]);
        body(self);
        let b = self.frames.pop().unwrap();
        self.push(Stmt::Loop { body: b });
    }

    /// Break out of the innermost loop.
    pub fn break_(&mut self) {
        self.push(Stmt::Break);
    }

    /// Continue the innermost loop.
    pub fn continue_(&mut self) {
        self.push(Stmt::Continue);
    }

    /// `while cond(b) { body }` — the condition closure re-evaluates every
    /// iteration (lowered to `loop { c = cond; if !c break; body }`).
    pub fn while_(
        &mut self,
        cond: impl Fn(&mut Self) -> Operand,
        body: impl FnOnce(&mut Self),
    ) {
        self.loop_(|b| {
            let c = cond(b);
            let not_c = b.cmp(CmpPred::Eq, c, Operand::bool(false));
            b.if_(not_c, |b| b.break_());
            body(b);
        });
    }

    /// Counted i32 loop `for (iv = start; iv < end; iv += step)`.
    /// `start`/`end`/`step` may be registers or constants; `step` must be
    /// positive.
    pub fn for_range(
        &mut self,
        start: impl Into<Operand>,
        end: impl Into<Operand>,
        step: impl Into<Operand>,
        body: impl FnOnce(&mut Self, Reg),
    ) {
        let start = start.into();
        let end = end.into();
        let step = step.into();
        let iv = self.copy(start);
        self.loop_(|b| {
            let in_range = b.cmp(CmpPred::Lt, iv, end);
            let done = b.cmp(CmpPred::Eq, in_range, Operand::bool(false));
            b.if_(done, |b| b.break_());
            body(b, iv);
            let next = b.add(iv, step);
            b.assign(iv, next);
        });
    }

    /// Return void.
    pub fn ret(&mut self) {
        self.push(Stmt::Return(None));
    }

    /// Return a value.
    pub fn ret_val(&mut self, v: impl Into<Operand>) {
        self.push(Stmt::Return(Some(v.into())));
    }

    /// Finish the function. Appends a trailing `return` for void functions
    /// that did not end with one.
    pub fn build(mut self) -> Function {
        assert_eq!(self.frames.len(), 1, "unclosed control region in `{}`", self.name);
        let mut body = self.frames.pop().unwrap();
        if self.ret.is_none() && !matches!(body.last(), Some(Stmt::Return(_))) {
            body.push(Stmt::Return(None));
        }
        Function {
            name: self.name,
            num_params: self.num_params,
            regs: self.regs,
            ret: self.ret,
            body,
            is_kernel: self.is_kernel,
            inline: self.inline,
            linkage: self.linkage,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_are_leading_regs() {
        let b = FunctionBuilder::new("f", &[Type::I32, Type::I64], Some(Type::I32));
        assert_eq!(b.param(0), Reg(0));
        assert_eq!(b.param(1), Reg(1));
        assert_eq!(b.reg_ty(Reg(1)), Type::I64);
    }

    #[test]
    fn build_appends_void_return() {
        let mut b = FunctionBuilder::new("f", &[], None);
        b.copy(Operand::i32(1));
        let f = b.build();
        assert!(matches!(f.body.last(), Some(Stmt::Return(None))));
    }

    #[test]
    fn if_else_nests_frames() {
        let mut b = FunctionBuilder::new("f", &[Type::I1], None);
        let p = b.param(0);
        b.if_else(
            p,
            |b| {
                b.copy(Operand::i32(1));
            },
            |b| {
                b.copy(Operand::i32(2));
            },
        );
        let f = b.build();
        match &f.body[0] {
            Stmt::If { then_, else_, .. } => {
                assert_eq!(then_.len(), 1);
                assert_eq!(else_.len(), 1);
            }
            other => panic!("expected If, got {other:?}"),
        }
    }

    #[test]
    fn for_range_produces_loop_with_break() {
        let mut b = FunctionBuilder::new("f", &[], None);
        b.for_range(Operand::i32(0), Operand::i32(10), Operand::i32(1), |b, iv| {
            b.add(iv, Operand::i32(0));
        });
        let f = b.build();
        let has_loop = f.body.iter().any(|s| matches!(s, Stmt::Loop { .. }));
        assert!(has_loop, "{:?}", f.body);
    }

    #[test]
    #[should_panic(expected = "param 2 out of range")]
    fn param_out_of_range_panics() {
        let b = FunctionBuilder::new("f", &[Type::I32], None);
        let _ = b.param(2);
    }

    #[test]
    fn index_scales_and_extends() {
        let mut b = FunctionBuilder::new("f", &[Type::I64, Type::I32], None);
        let base = b.param(0);
        let i = b.param(1);
        let addr = b.index(base, i, 4);
        assert_eq!(b.reg_ty(addr), Type::I64);
    }
}
