//! Chaos battery for the pool's failure half: scripted device faults
//! (stall / transient failure / permanent death — `sim::fault`) driving
//! the health machinery (watchdog, quarantine, preemptive shard
//! re-planning, bounded retry, probe re-admission — `sched::health` +
//! `sched::pool`).
//!
//! The soak is the headline: 1,000 launches over the mixed 4-device
//! pool with a stalling device, a transiently failing device and a
//! dying device, all scripted by launch index so every run provokes the
//! same incidents. The invariants:
//!
//! * every accepted request **completes or fails deterministically** —
//!   per-client `completed + failed` equals what the client submitted;
//! * reservation counters all drain to 0 (re-planning rebalances, never
//!   leaks);
//! * the dead device ends the run Quarantined and visibly so in the
//!   `PoolCoordinator` report;
//! * no deadline is judged twice (per-client slack sample count equals
//!   the deadline count).
//!
//! The trace battery re-runs the soak with event tracing on and judges
//! *span completeness*: every accepted request must show exactly one
//! `Submit` and exactly one terminal `Done` on the drained timeline —
//! through retries, re-plans, stranded sweeps and stitchers — with
//! retry attempts 1-based and increasing, and zero ring drops. A
//! fault-free shard test pins down the parent-id convention and checks
//! the Chrome/capture exports structurally.
//!
//! The hedge battery (`*hedge*` — CI runs these by name) re-runs the
//! soak shape with speculative re-execution on: a deterministic
//! stall-rescue test proving the duplicate's reply bounds the tail, and
//! a mixed-fault soak proving the exactly-once ledger — one `Done` and
//! one deadline judgment per accepted request, `hedges == hedge_wins +
//! hedge_wasted`, reservations drained — however copies race faults,
//! retries and shards.

use omprt::coordinator::PoolCoordinator;
use omprt::devrt::RuntimeKind;
use omprt::ir::passes::OptLevel;
use omprt::sched::workload::{saxpy_request, scale_request, sharded_scale_request};
use omprt::sched::{bytes_to_f32, Affinity, HealthState, OffloadHandle, PoolConfig};
use omprt::sim::Arch;
use omprt::trace::{validate_chrome_trace, EventKind};
use omprt::util::clock;
use std::collections::{HashMap, HashSet};
use std::time::Duration;

/// Poll `metrics()` until `pred` holds or `timeout` passes; returns
/// whether it held.
fn wait_for(
    pc: &PoolCoordinator,
    timeout: Duration,
    pred: impl Fn(&omprt::sched::PoolMetrics) -> bool,
) -> bool {
    let t0 = clock::now();
    loop {
        if pred(&pc.metrics()) {
            return true;
        }
        if t0.elapsed() > timeout {
            return false;
        }
        clock::sleep(Duration::from_millis(5));
    }
}

#[test]
fn thousand_launch_chaos_soak() {
    const TOTAL: usize = 1000;
    const ELEMS: usize = 192;
    // Mixed pool: dev0 portable:nvptx64, dev1 portable:amdgcn,
    // dev2 legacy:nvptx64 (never faulted — the always-healthy fallback),
    // dev3 legacy:amdgcn.
    let cfg = PoolConfig::mixed4()
        .with_queue_cap(64)
        .with_batch_max(4)
        .with_watchdog_min_ms(100)
        .with_retry_max(2)
        .with_client_slo("slo", 250.0)
        .with_fault_spec("0=fail:25@launch:40")
        .unwrap()
        .with_fault_spec("1=stall:600ms:1500ms@launch:30")
        .unwrap()
        .with_fault_spec("3=die@launch:60")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let clients = ["c0", "c1", "c2", "slo"];
    let mut handles: Vec<(String, OffloadHandle, Vec<f32>)> = vec![];
    let mut accepted: HashMap<String, u64> = HashMap::new();
    let mut rejected = 0u64;
    for i in 0..TOTAL {
        let client = clients[i % clients.len()].to_string();
        let (mut req, want) = if i % 50 == 17 {
            // Cross-device sharded request (16K elems, partitioned).
            let data: Vec<f32> = (0..16 * 1024).map(|k| ((k + i) % 83) as f32).collect();
            sharded_scale_request(&data, Affinity::any(), OptLevel::O2)
        } else if i % 37 == 5 {
            // Pinned to the arch+runtime only the dying device serves:
            // before its death these run there; afterwards they fail
            // deterministically (at submit or via the stranded sweep)
            // instead of waiting on a dead device forever.
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 89) as f32).collect();
            scale_request(
                &data,
                Affinity { arch: Some(Arch::Amdgcn), kind: Some(RuntimeKind::Legacy) },
                OptLevel::O2,
            )
        } else if i % 2 == 0 {
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 83) as f32).collect();
            scale_request(&data, Affinity::any(), OptLevel::O2)
        } else {
            let x: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
            let y: Vec<f32> = (0..ELEMS).map(|k| ((k * 3 + i) % 59) as f32).collect();
            saxpy_request(0.5, &x, &y, Affinity::any(), OptLevel::O2)
        };
        req.client = client.clone();
        match pc.submit(req) {
            Ok(h) => {
                *accepted.entry(client.clone()).or_default() += 1;
                handles.push((client, h, want));
            }
            Err(e) => {
                // Only the dead-device-only affinity may be turned away,
                // and only with the fail-fast quarantine error.
                assert!(
                    e.to_string().contains("quarantined"),
                    "unexpected submit rejection: {e}"
                );
                rejected += 1;
            }
        }
    }

    // Every accepted request resolves: success with the right data, or
    // a deterministic error.
    let mut ok: HashMap<String, u64> = HashMap::new();
    let mut failed: HashMap<String, u64> = HashMap::new();
    for (client, h, want) in handles {
        match h.wait() {
            Ok(resp) => {
                assert_eq!(
                    bytes_to_f32(resp.buffers[0].as_ref().unwrap()),
                    want,
                    "chaos survivor must still compute the right answer"
                );
                *ok.entry(client).or_default() += 1;
            }
            Err(_) => {
                *failed.entry(client).or_default() += 1;
            }
        }
    }
    pc.pool.quiesce();

    let m = pc.metrics();
    // Per-client accounting is exact: completed + failed == accepted.
    for client in clients {
        let a = accepted.get(client).copied().unwrap_or(0);
        let cm = m.clients.iter().find(|c| c.client == client);
        let (done, fail) = cm.map_or((0, 0), |c| (c.completed, c.failed));
        assert_eq!(
            done + fail,
            a,
            "client {client}: completed {done} + failed {fail} != accepted {a}"
        );
        assert_eq!(done, ok.get(client).copied().unwrap_or(0), "client {client} completions");
        assert_eq!(
            fail,
            failed.get(client).copied().unwrap_or(0),
            "client {client} failures"
        );
        // No deadline judged twice: exactly one signed-slack sample per
        // deadlined request.
        let cm = cm.expect("every client saw traffic");
        assert_eq!(
            cm.slack.count(),
            cm.deadlines,
            "client {client}: slack samples must equal deadlined requests"
        );
        if client == "slo" {
            assert_eq!(cm.deadlines, a, "every accepted slo request carries a deadline");
        } else {
            assert_eq!(cm.deadlines, 0, "best-effort client {client} has no deadlines");
        }
    }

    // Queue fully drained, reservations rebalanced to zero everywhere.
    assert_eq!(m.queue_depth, 0);
    for d in &m.devices {
        assert_eq!(d.reserved, 0, "device {} leaks a reservation", d.id);
    }

    // The dead device ends the run Quarantined (its probes can never
    // pass) and the incidents are visible.
    assert_eq!(m.devices[3].health, HealthState::Quarantined, "dead device stays out");
    assert!(m.devices[3].quarantines >= 1);
    assert!(m.devices[1].quarantines >= 1, "stalled device must have been quarantined");
    assert!(m.devices[0].fault_injected >= 1, "transient-failure script must have fired");
    assert!(m.retries >= 1, "transient failures must have been retried elsewhere");
    // dev2 never carries a fault script.
    assert!(m.devices[2].fault.is_none());

    let report = pc.format_report();
    assert!(report.contains("quar"), "quarantine must surface in the report:\n{report}");
    assert!(report.contains("health: watchdog on"), "{report}");
    assert!(report.contains("fault: dev 3"), "fault echo must surface:\n{report}");

    // The always-healthy fallback plus retry kept the pool useful: the
    // only hard failures permitted are (a) requests pinned to the dead
    // device's unique (kind, arch) and (b) sharded requests whose
    // shards were stranded on quarantined amdgcn devices. Anything
    // with a healthy-device escape hatch must have succeeded.
    let any_failed: u64 = ["c0", "c1", "c2", "slo"]
        .iter()
        .map(|c| failed.get(*c).copied().unwrap_or(0))
        .sum();
    let pinned_accepted: u64 = (0..TOTAL)
        .filter(|i| i % 50 != 17 && i % 37 == 5)
        .count() as u64;
    let sharded: u64 = (0..TOTAL).filter(|i| i % 50 == 17).count() as u64;
    assert!(
        any_failed <= pinned_accepted + sharded + rejected,
        "failures ({any_failed}) exceed the deterministic fault budget \
         ({pinned_accepted} dead-pinned + {sharded} sharded + {rejected} rejected)"
    );
}

#[test]
fn trace_spans_complete_after_chaos_soak() {
    const TOTAL: usize = 1000;
    const ELEMS: usize = 192;
    // The headline soak's fault script, with tracing on and rings sized
    // so nothing can be dropped (asserted below).
    let cfg = PoolConfig::mixed4()
        .with_queue_cap(64)
        .with_batch_max(4)
        .with_watchdog_min_ms(100)
        .with_retry_max(2)
        .with_client_slo("slo", 250.0)
        .with_trace(true)
        .with_trace_capacity(1 << 15)
        .with_fault_spec("0=fail:25@launch:40")
        .unwrap()
        .with_fault_spec("1=stall:600ms:1500ms@launch:30")
        .unwrap()
        .with_fault_spec("3=die@launch:60")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();
    assert!(pc.pool.trace_enabled());

    let clients = ["c0", "c1", "c2", "slo"];
    let mut accepted = 0u64;
    let mut handles = vec![];
    for i in 0..TOTAL {
        let (mut req, _) = if i % 50 == 17 {
            let data: Vec<f32> = (0..16 * 1024).map(|k| ((k + i) % 83) as f32).collect();
            sharded_scale_request(&data, Affinity::any(), OptLevel::O2)
        } else if i % 37 == 5 {
            // Pinned to the dying device's unique (kind, arch).
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 89) as f32).collect();
            scale_request(
                &data,
                Affinity { arch: Some(Arch::Amdgcn), kind: Some(RuntimeKind::Legacy) },
                OptLevel::O2,
            )
        } else {
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 83) as f32).collect();
            scale_request(&data, Affinity::any(), OptLevel::O2)
        };
        req.client = clients[i % clients.len()].to_string();
        if let Ok(h) = pc.submit(req) {
            accepted += 1;
            handles.push(h);
        }
    }
    // Resolve everything; success vs deterministic failure is judged by
    // the headline soak — here only the spans matter.
    for h in handles {
        let _ = h.wait();
    }
    pc.pool.quiesce();

    let snap = pc.pool.trace_snapshot();
    assert_eq!(snap.stats.dropped, 0, "rings sized for the soak must drop nothing");

    let mut submits: HashMap<u64, usize> = HashMap::new();
    let mut dones: HashMap<u64, usize> = HashMap::new();
    let mut sharded: HashSet<u64> = HashSet::new();
    let mut retries: HashMap<u64, Vec<u64>> = HashMap::new();
    for r in &snap.records {
        match r.kind {
            EventKind::Submit => *submits.entry(r.req).or_default() += 1,
            EventKind::Done => *dones.entry(r.req).or_default() += 1,
            EventKind::ShardPlanned => {
                sharded.insert(r.req);
            }
            EventKind::Retry => retries.entry(r.req).or_default().push(r.a),
            _ => {}
        }
    }
    // One Submit per accepted request (Submit is emitted only after
    // acceptance, so rejected dead-pinned requests leave no span)...
    assert_eq!(submits.len() as u64, accepted, "one span root per accepted request");
    // ...and exactly one terminal Done per span, no matter how the
    // request ended: batch completion, retry rescue, stranded sweep or
    // stitcher. Sharded requests terminate once, at their stitcher.
    for (rid, n) in &submits {
        assert_eq!(*n, 1, "request {rid} submitted more than once");
        assert_eq!(
            dones.get(rid).copied().unwrap_or(0),
            1,
            "request {rid} must terminate exactly once"
        );
    }
    assert_eq!(dones.len(), submits.len(), "no Done without a matching Submit");

    // Retries reuse the parent's id with a 1-based attempt bounded by
    // retry_max. Shard fan-outs share one id across shard jobs, so only
    // unsharded requests promise strict attempt monotonicity.
    let m = pc.metrics();
    assert!(m.retries >= 1, "the fault script must provoke retries");
    for (rid, attempts) in &retries {
        assert!(submits.contains_key(rid), "Retry for unknown request {rid}");
        assert!(
            attempts.iter().all(|&a| a >= 1 && a <= 2),
            "request {rid}: attempts {attempts:?} outside 1..=retry_max"
        );
        if !sharded.contains(rid) {
            assert_eq!(attempts[0], 1, "request {rid}: first retry is attempt 1");
            for w in attempts.windows(2) {
                assert!(
                    w[1] > w[0],
                    "request {rid}: attempts {attempts:?} must increase"
                );
            }
        }
    }

    // Deadline judgments mirror the metrics: one per deadlined request,
    // and only the SLO client carries deadlines.
    let slo = m.clients.iter().find(|c| c.client == "slo").expect("slo client traffic");
    assert_eq!(snap.count(EventKind::DeadlineJudged) as u64, slo.deadlines);
}

#[test]
fn trace_shard_and_capture_exports() {
    // Fault-free uniform pool: sharding spans all four devices and the
    // exports can be checked deterministically.
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4)
        .with_shard_min_trips(2048)
        .with_client_slo("slo", 250.0)
        .with_trace(true);
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let data: Vec<f32> = (0..256).map(|k| k as f32).collect();
    let mut handles = vec![];
    for i in 0..8 {
        let (mut req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        req.client = if i % 2 == 0 { "slo".to_string() } else { "bulk".to_string() };
        handles.push((pc.submit(req).unwrap(), want));
    }
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    let big: Vec<f32> = (0..16 * 1024).map(|k| (k % 97) as f32).collect();
    let (req, want) = sharded_scale_request(&big, Affinity::any(), OptLevel::O2);
    let resp = pc.submit(req).unwrap().wait().unwrap();
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    assert!(resp.shards >= 2, "a 4-device uniform pool must shard, got {}", resp.shards);
    pc.pool.quiesce();

    let snap = pc.pool.trace_snapshot();
    assert_eq!(snap.stats.dropped, 0);
    // One ShardPlanned, fan-out matching the response; every shard
    // launch carries the *parent's* request id (shards never batch, so
    // launches and shards correspond one to one).
    let planned: Vec<_> =
        snap.records.iter().filter(|r| r.kind == EventKind::ShardPlanned).collect();
    assert_eq!(planned.len(), 1);
    let parent = planned[0].req;
    assert_eq!(planned[0].a, resp.shards as u64);
    let shard_launches = snap
        .records
        .iter()
        .filter(|r| r.kind == EventKind::LaunchStart && r.req == parent)
        .count();
    assert_eq!(shard_launches, resp.shards, "one launch per shard, all under the parent id");
    let stitches: Vec<_> = snap.records.iter().filter(|r| r.kind == EventKind::Stitch).collect();
    assert_eq!(stitches.len(), 1);
    assert_eq!(stitches[0].req, parent);
    assert_eq!(stitches[0].a, resp.shards as u64);
    assert_eq!(stitches[0].b, 1, "a fault-free stitch succeeds");
    assert_eq!(
        snap.records.iter().filter(|r| r.kind == EventKind::Done && r.req == parent).count(),
        1,
        "a sharded request terminates once, at its stitcher"
    );
    // Half the plain requests ran under the SLO tag: each judged once.
    assert_eq!(snap.count(EventKind::DeadlineJudged), 4);

    // The Chrome export is structurally valid: parseable JSON, a
    // traceEvents array, matched B/E pairs per (pid, tid) track.
    let chrome = pc.trace_chrome_json();
    let n = validate_chrome_trace(&chrome).expect("chrome export must validate");
    assert!(n > 0, "the export must carry events");

    // The replay capture holds one line per accepted request; the
    // sharded parent carries its fan-out and arch, and only SLO-tagged
    // requests carry a deadline budget.
    let capture = pc.trace_capture();
    let lines: Vec<&str> = capture.lines().filter(|l| !l.starts_with('#')).collect();
    assert_eq!(lines.len(), 9, "8 plain + 1 sharded accepted requests:\n{capture}");
    for l in &lines {
        assert!(l.starts_with("req="), "malformed capture line: {l}");
        for field in ["t_us=", "client=", "key=0x", "deadline_us=", "shards=", "arch="] {
            assert!(l.contains(field), "capture line missing {field}: {l}");
        }
    }
    let parent_line = lines
        .iter()
        .find(|l| l.starts_with(&format!("req={parent} ")))
        .expect("sharded parent must appear in the capture");
    assert!(parent_line.contains(&format!("shards={}", resp.shards)), "{parent_line}");
    assert!(parent_line.contains("arch=nvptx64"), "{parent_line}");
    assert!(parent_line.contains("deadline_us=-"), "{parent_line}");
    assert!(
        lines.iter().any(|l| l.contains("client=slo") && !l.contains("deadline_us=-")),
        "SLO requests must carry a deadline budget:\n{capture}"
    );
}

#[test]
fn stalled_device_quarantines_shards_replan_and_probe_readmits() {
    // Uniform pool so sharding spans all four devices; device 2 wedges
    // hard (600ms hangs for 1.5s) after a handful of launches.
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 4)
        .with_batch_max(4)
        .with_watchdog_min_ms(100)
        .with_shard_min_trips(2048)
        .with_fault_spec("2=stall:600ms:1500ms@launch:6")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    // Enough traffic to walk device 2 past launch 6 mid-run.
    let data: Vec<f32> = (0..256).map(|k| k as f32).collect();
    let mut handles = vec![];
    for i in 0..64 {
        let (mut req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        req.client = format!("burst{}", i % 2);
        handles.push((pc.submit(req).unwrap(), want));
    }

    // The watchdog must catch the wedged device while the stall is
    // still in progress.
    assert!(
        wait_for(&pc, Duration::from_secs(20), |m| {
            m.devices[2].health == HealthState::Quarantined
        }),
        "watchdog never quarantined the stalled device: {:?}",
        pc.metrics().devices.iter().map(|d| d.health).collect::<Vec<_>>()
    );

    // A sharded request planned *now* must route around the quarantined
    // device and still finish correctly.
    let big: Vec<f32> = (0..16 * 1024).map(|k| (k % 97) as f32).collect();
    let (req, want) = sharded_scale_request(&big, Affinity::any(), OptLevel::O2);
    let resp = pc.submit(req).unwrap().wait().unwrap();
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    assert_ne!(resp.device_id, 2, "a quarantined device must serve no shard");

    // Every pre-stall request still completes (the wedged batch finishes
    // once its injected hang ends; nothing is lost).
    for (h, want) in handles {
        let resp = h.wait().unwrap();
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    pc.pool.quiesce();

    // Once the scripted window closes, the probe readmits the device.
    assert!(
        wait_for(&pc, Duration::from_secs(20), |m| {
            m.devices[2].health == HealthState::Healthy
        }),
        "probe must readmit the device after its stall window"
    );
    let m = pc.metrics();
    assert!(m.probes >= 1, "re-admission requires probes");
    assert!(m.readmissions >= 1);
    assert!(m.devices[2].quarantines >= 1);
    assert!(m.devices[2].fault_injected >= 1);
    for d in &m.devices {
        assert_eq!(d.reserved, 0, "device {} leaks a reservation", d.id);
    }
    assert_eq!(m.failed, 0, "a stall must delay work, never lose it");
}

#[test]
fn dead_device_work_retries_onto_healthy_devices() {
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 2)
        .with_batch_max(4)
        .with_watchdog_min_ms(100)
        .with_retry_max(2)
        .with_fault_spec("0=die@launch:2")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let data: Vec<f32> = (0..128).map(|k| k as f32).collect();
    let mut handles = vec![];
    for _ in 0..40 {
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        handles.push((pc.submit(req).unwrap(), want));
    }
    for (h, want) in handles {
        let resp = h.wait().expect("every request must be rescued by retry");
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    pc.pool.quiesce();

    let m = pc.metrics();
    assert_eq!(m.failed, 0, "with a healthy sibling, death must cost nothing");
    assert!(m.retries >= 1, "jobs claimed by the dead device must have been retried");
    assert_eq!(m.retries_exhausted, 0);
    // The dead device is quarantined by its fault streak and stays out
    // (its probes never pass).
    assert!(
        wait_for(&pc, Duration::from_secs(20), |m| {
            m.devices[0].health == HealthState::Quarantined
        }),
        "fault streak must quarantine the dead device"
    );
    clock::sleep(Duration::from_millis(250));
    assert_eq!(
        pc.metrics().devices[0].health,
        HealthState::Quarantined,
        "probes must never readmit a dead device"
    );
    let report = pc.format_report();
    assert!(report.contains("die"), "the fault echo names the script:\n{report}");
}

/// Poll until every device is idle (no in-flight batch) and the hedge
/// ledger has resolved (`hedges == hedge_wins + hedge_wasted`). Quiesce
/// returns when every *request* has terminated, but a losing copy may
/// still be executing — trace and counter assertions must wait it out.
fn wait_hedges_resolved(pc: &PoolCoordinator) -> bool {
    wait_for(pc, Duration::from_secs(30), |m| {
        m.devices.iter().all(|d| d.inflight_age.is_none())
            && m.hedges == m.hedge_wins + m.hedge_wasted
    })
}

#[test]
fn stalled_inflight_job_is_hedged_and_wins() {
    // Two uniform devices; dev0 wedges for 2.5s on its second launch.
    // The watchdog is off, so only hedging can rescue the stuck request:
    // the monitor sees its in-flight age pass max(3 x EWMA, min/4 =
    // 500ms), duplicates it onto idle dev1, and the duplicate's reply
    // resolves the handle roughly 2s before the original unwedges.
    let cfg = PoolConfig::uniform(RuntimeKind::Portable, Arch::Nvptx64, 2)
        .with_batch_max(1)
        .with_watchdog(false)
        .with_watchdog_min_ms(2000)
        .with_hedge(true)
        .with_hedge_after_factor(3)
        .with_hedge_max(2)
        .with_trace(true)
        .with_fault_spec("0=stall:2500ms:10s@launch:1")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let data: Vec<f32> = (0..128).map(|k| k as f32).collect();
    let mut handles = vec![];
    for _ in 0..8 {
        let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
        handles.push((pc.submit(req).unwrap(), want));
    }
    let t0 = clock::now();
    for (h, want) in handles {
        let resp = h.wait().expect("every request resolves, hedged or not");
        assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);
    }
    // The duplicate, not the 2.5s stall, bounded the tail.
    assert!(
        t0.elapsed() < Duration::from_millis(2300),
        "replies must not wait out the stall: {:?}",
        t0.elapsed()
    );
    pc.pool.quiesce();
    assert!(wait_hedges_resolved(&pc), "hedge ledger never resolved");

    let m = pc.metrics();
    assert!(m.hedge);
    assert!(m.hedges >= 1, "the stalled launch must have been hedged");
    assert!(m.hedge_wins >= 1, "the duplicate beats a 2.5s stall");
    assert_eq!(m.hedges, m.hedge_wins + m.hedge_wasted);
    assert_eq!(m.failed, 0, "hedging must lose nothing");
    for d in &m.devices {
        assert_eq!(d.reserved, 0, "device {} leaks a reservation", d.id);
    }
    let report = pc.format_report();
    assert!(report.contains("hedge: on"), "{report}");

    // Exactly-once on the timeline: one Done per accepted request even
    // though two copies of the stalled one executed to completion, and
    // the hedge events mirror the counters.
    let snap = pc.pool.trace_snapshot();
    let mut dones: HashMap<u64, usize> = HashMap::new();
    for r in &snap.records {
        if r.kind == EventKind::Done {
            *dones.entry(r.req).or_default() += 1;
        }
    }
    assert_eq!(dones.len(), 8, "every accepted request terminates");
    assert!(dones.values().all(|&n| n == 1), "a hedged request must Done once: {dones:?}");
    assert_eq!(snap.count(EventKind::HedgeLaunched) as u64, m.hedges);
    assert_eq!(snap.count(EventKind::HedgeWon) as u64, m.hedge_wins);
    assert_eq!(snap.count(EventKind::HedgeWasted) as u64, m.hedge_wasted);
}

#[test]
fn hedged_chaos_soak_keeps_exactly_once_accounting() {
    const TOTAL: usize = 600;
    const ELEMS: usize = 192;
    // The headline soak's shape — shards, retries, SLO deadlines, a
    // stalling device, a degraded device and a dying device — with
    // hedging on top. The point: however the copies race the faults,
    // every accepted request terminates exactly once and the hedge
    // ledger balances.
    let cfg = PoolConfig::mixed4()
        .with_queue_cap(64)
        .with_batch_max(4)
        .with_watchdog_min_ms(100)
        .with_retry_max(2)
        .with_client_slo("slo", 250.0)
        .with_hedge(true)
        .with_hedge_after_factor(3)
        .with_hedge_max(3)
        .with_trace(true)
        .with_trace_capacity(1 << 15)
        .with_fault_spec("0=slow:8x:2s@launch:40")
        .unwrap()
        .with_fault_spec("1=stall:600ms:1500ms@launch:30")
        .unwrap()
        .with_fault_spec("3=die@launch:60")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let clients = ["c0", "c1", "slo"];
    let mut accepted: HashMap<String, u64> = HashMap::new();
    let mut handles: Vec<(String, OffloadHandle, Vec<f32>)> = vec![];
    for i in 0..TOTAL {
        let client = clients[i % clients.len()].to_string();
        let (mut req, want) = if i % 50 == 17 {
            let data: Vec<f32> = (0..16 * 1024).map(|k| ((k + i) % 83) as f32).collect();
            sharded_scale_request(&data, Affinity::any(), OptLevel::O2)
        } else if i % 37 == 5 {
            // Pinned to the dying device's unique (kind, arch): fails
            // deterministically after the death — terminating exactly
            // once either way is precisely what's under test.
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 89) as f32).collect();
            scale_request(
                &data,
                Affinity { arch: Some(Arch::Amdgcn), kind: Some(RuntimeKind::Legacy) },
                OptLevel::O2,
            )
        } else if i % 2 == 0 {
            let data: Vec<f32> = (0..ELEMS).map(|k| ((k + i) % 83) as f32).collect();
            scale_request(&data, Affinity::any(), OptLevel::O2)
        } else {
            let x: Vec<f32> = (0..ELEMS).map(|k| k as f32).collect();
            let y: Vec<f32> = (0..ELEMS).map(|k| ((k * 3 + i) % 59) as f32).collect();
            saxpy_request(0.5, &x, &y, Affinity::any(), OptLevel::O2)
        };
        req.client = client.clone();
        if let Ok(h) = pc.submit(req) {
            *accepted.entry(client.clone()).or_default() += 1;
            handles.push((client, h, want));
        }
    }
    let mut ok: HashMap<String, u64> = HashMap::new();
    let mut failed: HashMap<String, u64> = HashMap::new();
    for (client, h, want) in handles {
        match h.wait() {
            Ok(resp) => {
                assert_eq!(
                    bytes_to_f32(resp.buffers[0].as_ref().unwrap()),
                    want,
                    "a hedged winner must still compute the right answer"
                );
                *ok.entry(client).or_default() += 1;
            }
            Err(_) => {
                *failed.entry(client).or_default() += 1;
            }
        }
    }
    pc.pool.quiesce();
    assert!(wait_hedges_resolved(&pc), "hedge ledger never resolved");

    let m = pc.metrics();
    assert!(m.hedges >= 1, "600ms stalls against a 25ms hedge floor must hedge");
    assert_eq!(
        m.hedges,
        m.hedge_wins + m.hedge_wasted,
        "every launched duplicate is judged exactly once"
    );
    // Exactly-once per client: completed + failed == accepted, one
    // slack sample per deadlined request, through every copy in flight.
    for client in clients {
        let a = accepted.get(client).copied().unwrap_or(0);
        let cm = m.clients.iter().find(|c| c.client == client).expect("client traffic");
        assert_eq!(
            cm.completed + cm.failed,
            a,
            "client {client}: completed {} + failed {} != accepted {a}",
            cm.completed,
            cm.failed
        );
        assert_eq!(cm.completed, ok.get(client).copied().unwrap_or(0));
        assert_eq!(cm.failed, failed.get(client).copied().unwrap_or(0));
        assert_eq!(
            cm.slack.count(),
            cm.deadlines,
            "client {client}: one deadline judgment per deadlined request"
        );
    }
    assert_eq!(m.queue_depth, 0);
    for d in &m.devices {
        assert_eq!(d.reserved, 0, "device {} leaks a reservation", d.id);
    }

    // The drained timeline agrees: one Submit and one terminal Done per
    // accepted request, hedge events matching the counters exactly.
    let snap = pc.pool.trace_snapshot();
    assert_eq!(snap.stats.dropped, 0, "rings sized for the soak must drop nothing");
    let mut submits: HashMap<u64, usize> = HashMap::new();
    let mut dones: HashMap<u64, usize> = HashMap::new();
    for r in &snap.records {
        match r.kind {
            EventKind::Submit => *submits.entry(r.req).or_default() += 1,
            EventKind::Done => *dones.entry(r.req).or_default() += 1,
            _ => {}
        }
    }
    let total_accepted: u64 = accepted.values().sum();
    assert_eq!(submits.len() as u64, total_accepted);
    for (rid, n) in &submits {
        assert_eq!(*n, 1, "request {rid} submitted more than once");
        assert_eq!(
            dones.get(rid).copied().unwrap_or(0),
            1,
            "request {rid} must terminate exactly once, hedged or not"
        );
    }
    assert_eq!(dones.len(), submits.len(), "no Done without a matching Submit");
    assert_eq!(snap.count(EventKind::HedgeLaunched) as u64, m.hedges);
    assert_eq!(snap.count(EventKind::HedgeWon) as u64, m.hedge_wins);
    assert_eq!(snap.count(EventKind::HedgeWasted) as u64, m.hedge_wasted);
    let slo = m.clients.iter().find(|c| c.client == "slo").unwrap();
    assert_eq!(snap.count(EventKind::DeadlineJudged) as u64, slo.deadlines);
}

#[test]
fn retry_cap_surfaces_the_original_fault() {
    // Single device: there is never a *different* device to retry on,
    // so the first injected fault must come straight back to the
    // client — and it must be the original error text.
    let cfg = PoolConfig::single(RuntimeKind::Portable, Arch::Nvptx64)
        .with_watchdog(false)
        .with_retry_max(2)
        .with_fault_spec("0=fail:4@launch:0")
        .unwrap();
    let pc = PoolCoordinator::new(&cfg).unwrap();

    let data: Vec<f32> = (0..64).map(|k| k as f32).collect();
    for i in 0..4 {
        let (req, _) = scale_request(&data, Affinity::any(), OptLevel::O2);
        let err = pc.submit(req).unwrap().wait().expect_err("launches 0-3 are scripted to fail");
        let msg = err.to_string();
        assert!(msg.contains("device fault"), "launch {i}: {msg}");
        assert!(msg.contains("injected transient launch failure"), "launch {i}: {msg}");
    }
    // The window is spent: the device works again.
    let (req, want) = scale_request(&data, Affinity::any(), OptLevel::O2);
    let resp = pc.submit(req).unwrap().wait().unwrap();
    assert_eq!(bytes_to_f32(resp.buffers[0].as_ref().unwrap()), want);

    let m = pc.metrics();
    assert_eq!(m.retries, 0, "no sibling device: nothing can be retried");
    assert_eq!(m.retries_exhausted, 4);
    assert_eq!(m.failed, 4);
    assert_eq!(m.completed, 1);
}
