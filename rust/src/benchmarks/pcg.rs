//! 554.pcg analog: conjugate gradient on a SPD tridiagonal system.
//!
//! Single-team kernel (grid = 1) so that the dot products can use the
//! block-wide tree reduction; the whole CG iteration loop runs *inside*
//! one target region (barrier/reduction heavy — the most runtime-bound
//! member of the suite). A[i][i] = 4, off-diagonals −1.

use super::common::{checksum_f32, emit_static_range, BenchResult, Benchmark, Scale};
use crate::coordinator::Coordinator;
use crate::devrt::irlib;
use crate::hostrt::{DataEnv, MapType};
use crate::ir::passes::OptLevel;
use crate::ir::{AddrSpace, CastOp, CmpPred, FunctionBuilder, Module, Operand, Reg, Type};
use crate::sim::LaunchConfig;
use crate::util::{Error, SplitMix64};

/// The benchmark.
pub struct Pcg {
    n: usize,
    iters: usize,
    block: u32,
}

impl Pcg {
    /// Configure for a scale.
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Small => Pcg { n: 256, iters: 8, block: 64 },
            Scale::Paper => Pcg { n: 2048, iters: 25, block: 128 },
        }
    }

    /// Emit `y = A·p` over the thread's static range (tridiag SPD).
    fn emit_spmv(b: &mut FunctionBuilder, p: Reg, y: Reg, lb: Reg, ub: Reg, n: i32) {
        b.for_range(lb, ub, Operand::i32(1), |b, i| {
            let pa = b.index(p, i, 4);
            let pi = b.load(Type::F32, AddrSpace::Global, pa);
            let acc = b.mul(pi, Operand::f32(4.0));
            let has_left = b.cmp(CmpPred::Gt, i, Operand::i32(0));
            b.if_(has_left, |b| {
                let im1 = b.add(i, Operand::i32(-1));
                let a = b.index(p, im1, 4);
                let v = b.load(Type::F32, AddrSpace::Global, a);
                let nv = b.sub(acc, v);
                b.assign(acc, nv);
            });
            let has_right = b.cmp(CmpPred::Lt, i, Operand::i32(n - 1));
            b.if_(has_right, |b| {
                let ip1 = b.add(i, Operand::i32(1));
                let a = b.index(p, ip1, 4);
                let v = b.load(Type::F32, AddrSpace::Global, a);
                let nv = b.sub(acc, v);
                b.assign(acc, nv);
            });
            let ya = b.index(y, i, 4);
            b.store(Type::F32, AddrSpace::Global, ya, acc);
        });
    }

    /// Emit a block-wide dot product over the thread's range; returns an
    /// f64 register holding the full sum (all threads).
    fn emit_dot(b: &mut FunctionBuilder, x: Reg, y: Reg, lb: Reg, ub: Reg, tid: Reg) -> Reg {
        let acc = b.copy(Operand::f64(0.0));
        b.for_range(lb, ub, Operand::i32(1), |b, i| {
            let xa = b.index(x, i, 4);
            let xv = b.load(Type::F32, AddrSpace::Global, xa);
            let ya = b.index(y, i, 4);
            let yv = b.load(Type::F32, AddrSpace::Global, ya);
            let prod = b.mul(xv, yv);
            let p64 = b.cast(CastOp::FPExt, prod, Type::F64);
            let na = b.add(acc, p64);
            b.assign(acc, na);
        });
        b.call("__kmpc_reduce_add_f64", &[tid.into(), acc.into()], Type::F64)
    }

    /// One kernel runs the whole CG loop. Args: x, r, p, ap, resid_out.
    fn module(&self) -> Module {
        let n = self.n as i32;
        let iters = self.iters as i32;
        let mut m = Module::new("pcg");
        let mut b = FunctionBuilder::new("cg", &[Type::I64; 5], None).kernel();
        let (x, r, p, ap, resid) =
            (b.param(0), b.param(1), b.param(2), b.param(3), b.param(4));
        irlib::emit_spmd_prologue(&mut b);
        let tid = b.call("omp_get_thread_num", &[], Type::I32);
        let (lb, ub) = emit_static_range(&mut b, Operand::i32(0), Operand::i32(n));
        // rs_old = r·r
        let rs_old = Self::emit_dot(&mut b, r, r, lb, ub, tid);
        let rs = b.copy(rs_old);
        b.for_range(Operand::i32(0), Operand::i32(iters), Operand::i32(1), |b, _| {
            Self::emit_spmv(b, p, ap, lb, ub, n);
            b.call_void("__kmpc_barrier", &[]);
            let p_ap = Self::emit_dot(b, p, ap, lb, ub, tid);
            let alpha = b.fdiv(rs, p_ap);
            let alpha32 = b.cast(CastOp::FPTrunc, alpha, Type::F32);
            // x += α p ; r -= α Ap (own range)
            b.for_range(lb, ub, Operand::i32(1), |b, i| {
                let pa = b.index(p, i, 4);
                let pv = b.load(Type::F32, AddrSpace::Global, pa);
                let xa = b.index(x, i, 4);
                let xv = b.load(Type::F32, AddrSpace::Global, xa);
                let dx = b.mul(alpha32, pv);
                let nx = b.add(xv, dx);
                b.store(Type::F32, AddrSpace::Global, xa, nx);
                let apa = b.index(ap, i, 4);
                let apv = b.load(Type::F32, AddrSpace::Global, apa);
                let ra = b.index(r, i, 4);
                let rv = b.load(Type::F32, AddrSpace::Global, ra);
                let dr = b.mul(alpha32, apv);
                let nr = b.sub(rv, dr);
                b.store(Type::F32, AddrSpace::Global, ra, nr);
            });
            b.call_void("__kmpc_barrier", &[]);
            let rs_new = Self::emit_dot(b, r, r, lb, ub, tid);
            let beta = b.fdiv(rs_new, rs);
            let beta32 = b.cast(CastOp::FPTrunc, beta, Type::F32);
            // p = r + β p
            b.for_range(lb, ub, Operand::i32(1), |b, i| {
                let ra = b.index(r, i, 4);
                let rv = b.load(Type::F32, AddrSpace::Global, ra);
                let pa = b.index(p, i, 4);
                let pv = b.load(Type::F32, AddrSpace::Global, pa);
                let bp = b.mul(beta32, pv);
                let np = b.add(rv, bp);
                b.store(Type::F32, AddrSpace::Global, pa, np);
            });
            b.call_void("__kmpc_barrier", &[]);
            b.assign(rs, rs_new);
        });
        // thread 0 writes the final residual norm²
        let is0 = b.cmp(CmpPred::Eq, tid, Operand::i32(0));
        b.if_(is0, |b| {
            let r32 = b.cast(CastOp::FPTrunc, rs, Type::F32);
            b.store(Type::F32, AddrSpace::Global, resid, r32);
        });
        irlib::emit_spmd_epilogue(&mut b);
        b.ret();
        m.add_func(b.build());
        m
    }

    fn rhs(&self) -> Vec<f32> {
        let mut rng = SplitMix64::new(554);
        let mut v = vec![0f32; self.n];
        rng.fill_f32(&mut v, -1.0, 1.0);
        v
    }

    /// Host CG (f64 accumulation like the device).
    fn host_ref(&self) -> (Vec<f32>, f32) {
        let n = self.n;
        let bvec = self.rhs();
        let mut x = vec![0f32; n];
        let mut r = bvec.clone();
        let mut p = bvec.clone();
        let spmv = |p: &[f32], y: &mut [f32]| {
            for i in 0..n {
                let mut acc = 4.0 * p[i];
                if i > 0 {
                    acc -= p[i - 1];
                }
                if i < n - 1 {
                    acc -= p[i + 1];
                }
                y[i] = acc;
            }
        };
        let dot = |a: &[f32], bb: &[f32]| -> f64 {
            a.iter().zip(bb).map(|(x, y)| (*x * *y) as f64).sum()
        };
        let mut ap = vec![0f32; n];
        let mut rs = dot(&r, &r);
        for _ in 0..self.iters {
            spmv(&p, &mut ap);
            let alpha = (rs / dot(&p, &ap)) as f32;
            for i in 0..n {
                x[i] += alpha * p[i];
                r[i] -= alpha * ap[i];
            }
            let rs_new = dot(&r, &r);
            let beta = (rs_new / rs) as f32;
            for i in 0..n {
                p[i] = r[i] + beta * p[i];
            }
            rs = rs_new;
        }
        (x, rs as f32)
    }
}

impl Benchmark for Pcg {
    fn name(&self) -> &'static str {
        "554.pcg"
    }

    fn run(&self, c: &Coordinator) -> Result<BenchResult, Error> {
        let image = c.prepare(self.module(), OptLevel::O2)?;
        let mut env = DataEnv::new(&c.device);
        let bvec = self.rhs();
        let mut x = vec![0f32; self.n];
        let r = bvec.clone();
        let p = bvec.clone();
        let ap = vec![0f32; self.n];
        let mut resid = vec![0f32; 1];
        let args = [
            env.map(&x, MapType::Tofrom)?,
            env.map(&r, MapType::To)?,
            env.map(&p, MapType::To)?,
            env.map(&ap, MapType::Alloc)?,
            env.map(&resid, MapType::From)?,
        ];
        let stats =
            c.run_region(&image, "cg", "pcg.cg", &args, LaunchConfig::new(1, self.block))?;
        env.unmap(&mut x)?;
        env.unmap(&mut resid)?;

        let (hx, h_rs) = self.host_ref();
        // Device and host differ only in f32 rounding order within the
        // per-thread partials; CG is mildly sensitive, so compare with a
        // modest tolerance and check the residual dropped as expected.
        let rs0: f64 = bvec.iter().map(|v| (*v * *v) as f64).sum();
        let converged = (resid[0] as f64) < rs0 * 0.51 && resid[0].is_finite();
        let matches = super::common::compare_f32(&x, &hx, 5e-2).is_none()
            && (resid[0] - h_rs).abs() <= 0.05 * h_rs.abs().max(1e-6);
        let verified = converged && matches;
        if !verified {
            log::error!(
                "pcg verify failed: resid={} host_rs={h_rs} rs0={rs0} converged={converged}",
                resid[0]
            );
        }
        Ok(BenchResult { kernel_wall: stats.wall, verified, checksum: checksum_f32(&x) })
    }
}
