"""L1 Pallas kernel: one Jacobi (5-point) step on a row slab.

The paper's 503.postencil analog offloads row stripes per team; each team
invokes this kernel on a (R+2, C) input slab (one halo row above and
below) and receives the R updated rows back (edge columns pass through).

HARDWARE ADAPTATION (DESIGN.md §3): the CUDA version would stage the tile
in `__shared__` memory per thread block. On TPU-shaped hardware the tile
*is* the VMEM block: BlockSpec brings the whole slab into VMEM and the
VPU executes the shifted adds as vector ops — no per-thread indexing.
`interpret=True` (CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(inp_ref, out_ref):
    x = inp_ref[...]
    r = x.shape[0] - 2
    center = x[1 : r + 1, :]
    up = x[0:r, :]
    down = x[2 : r + 2, :]
    interior = ref.STENCIL_C * center[:, 1:-1] + ref.STENCIL_N * (
        up[:, 1:-1] + down[:, 1:-1] + center[:, :-2] + center[:, 2:]
    )
    out = center.at[:, 1:-1].set(interior)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=())
def stencil_tile(inp):
    """Pallas entry point; shape (R+2, C) -> (R, C)."""
    r = inp.shape[0] - 2
    c = inp.shape[1]
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(inp)


# VMEM footprint estimate for DESIGN.md §8 (f32 slab in + tile out).
def vmem_bytes(r: int, c: int) -> int:
    return 4 * ((r + 2) * c + r * c)
