//! `sched` — the device-pool offload scheduler.
//!
//! The paper's runtime makes one device target cheap to bring up; this
//! layer makes *many* devices cheap to drive at once. A [`DevicePool`]
//! owns N [`crate::hostrt::OffloadDevice`]s — mixed architectures
//! (`nvptx64-sim`, `amdgcn-sim`) and mixed runtime builds (legacy,
//! portable) — behind one asynchronous submission queue. Clients
//! [`DevicePool::submit`] an [`OffloadRequest`] (module + kernel + launch
//! config + buffer mappings) and immediately get an [`OffloadHandle`]
//! future; per-device worker threads execute the requests and resolve the
//! handles.
//!
//! ## Placement policy
//!
//! Placement is **pull-based least-loaded with affinity filtering**:
//!
//! * one worker thread per device pulls from the shared FIFO queue the
//!   moment its device is free, so work naturally flows to the
//!   least-loaded device — an idle device never waits behind a busy one;
//! * each request carries an [`Affinity`] constraint (`arch` and/or
//!   runtime `kind`, both optional); a worker only claims the oldest job
//!   its device satisfies, skipping over incompatible ones so a pinned
//!   job cannot head-of-line-block the rest of the pool;
//! * a request whose affinity matches no pool device is rejected at
//!   submit time rather than queued forever.
//!
//! ## Kernel-image cache
//!
//! `prepare` (link the runtime IR library, optimize, verify, load) is the
//! expensive half of an offload. Each device worker consults an
//! [`ImageCache`] keyed by `(module content hash, arch, runtime kind, opt
//! level)` — see [`cache`] for the key-design rationale — so a kernel
//! module pays the prepare cost once per device configuration and every
//! subsequent launch of it is queue-pop + map + launch. Hit/miss counters
//! aggregate into [`PoolMetrics`] and the
//! [`crate::coordinator::PoolCoordinator`] report.

pub mod cache;
pub mod pool;
pub mod workload;

pub use cache::{CacheKey, CacheStats, ImageCache};
pub use pool::{
    bytes_to_f32, f32_to_bytes, Affinity, DeviceMetrics, DevicePool, DeviceSpec, KernelArg,
    MapBuf, OffloadHandle, OffloadRequest, OffloadResponse, PoolConfig, PoolMetrics,
};
