//! BENCH (E7): variant-dispatch ablation — resolution cost of the
//! `declare variant` engine (match_any vs exact selectors) measured at
//! "compile" (build+link) time, plus proof that the dispatched atomicInc
//! has the same runtime cost as the direct vendor intrinsic.

use omprt::devrt::variant::{Selector, Variant, VariantRegistry, VariantSet};
use omprt::devrt::{self, irlib, RuntimeKind};
use omprt::sim::Arch;
use omprt::util::clock;

fn build_registry(n: usize) -> VariantRegistry {
    let mut reg = VariantRegistry::new();
    for i in 0..n {
        reg.register(VariantSet {
            base_name: format!("f{i}"),
            base: Box::new(|name| irlib::missing_impl_body(name, &[], None)),
            variants: vec![
                Variant {
                    selector: Selector::arch_any(&["nvptx", "nvptx64"]),
                    build: Box::new(|name| irlib::threadfence_body(name, "nvvm.membar.gl")),
                },
                Variant {
                    selector: Selector::arch("amdgcn"),
                    build: Box::new(|name| irlib::threadfence_body(name, "amdgcn.s.waitcnt")),
                },
            ],
        });
    }
    reg
}

fn main() {
    println!("\n=== E7: variant-dispatch ablation ===\n");
    // resolution throughput
    for n in [10usize, 100, 1000] {
        let reg = build_registry(n);
        let t0 = clock::now();
        let mut total = 0;
        for _ in 0..100 {
            total += reg.resolve_all(Arch::Nvptx64).len();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "resolve_all over {n:4} variant sets: {:8.1} sets/ms (resolved {total} total)",
            (total as f64 / dt) / 1e3
        );
    }
    // full runtime build cost, both kinds (the packaging-time cost).
    for kind in RuntimeKind::all() {
        let t0 = clock::now();
        for _ in 0..50 {
            let rt = devrt::build(kind, Arch::Amdgcn);
            std::hint::black_box(rt.ir_library.funcs.len());
        }
        println!("devrt::build({kind}) x50: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    }
}
