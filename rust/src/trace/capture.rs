//! The `# omprt-capture v1` replay-capture format: a typed parser and
//! renderer for the line-oriented export written by `--capture-out`.
//!
//! One line per *accepted* request, in submit order:
//!
//! ```text
//! # omprt-capture v1
//! # req t_us client key deadline_us shards arch
//! req=1 t_us=0.000 client=bulk key=0xabc deadline_us=250000 shards=1 arch=-
//! req=2 t_us=503.000 client=- key=0xdef deadline_us=- shards=2 arch=nvptx64
//! # dropped=0-or-more, only present when the trace ring overwrote records
//! ```
//!
//! Grammar contract (shared with [`super::export::validate_capture`],
//! which is a thin wrapper over [`parse_capture`]):
//!
//! * line 1 is exactly `# omprt-capture v1`;
//! * every other non-empty line is either a comment (`#`) or exactly
//!   seven `key=value` tokens in the fixed order
//!   `req t_us client key deadline_us shards arch`;
//! * `req` ids are unique `u64`s, `t_us` is finite and non-decreasing,
//!   `key` is `0x`-hex, `deadline_us` is `-` (best-effort) or a `u64`,
//!   `shards >= 1`, and `shards > 1` exactly when `arch` is a real
//!   label;
//! * `client` is `-` for the default client or an escaped name (see
//!   below);
//! * a `# dropped=N` trailer, when present, must be well-formed, appear
//!   once, and not be followed by further request lines. It marks a
//!   **lossy** capture: the ring overwrote `N` records, so the request
//!   lines under-represent the recorded workload.
//!
//! ## Client-name escaping
//!
//! Client names are arbitrary strings, but the capture grammar reserves
//! whitespace (token separator), `=` (key/value separator), `-` (the
//! whole-token no-client sentinel) and `%` (the escape introducer).
//! [`escape_client`] percent-encodes each reserved or control character
//! as `%XX` per UTF-8 byte, and renders the one name whose escaped form
//! would collide with the sentinel (`-`) as `%2D`. Because `%` always
//! escapes itself the encoding is injective, and [`unescape_client`]
//! inverts it exactly — two distinct clients can never merge in a
//! capture, and a replay reconstructs the original names byte-for-byte.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

use super::event::{EventKind, TraceRecord};
use super::export::ExportMeta;

/// The line-1 magic every capture starts with.
pub const CAPTURE_HEADER: &str = "# omprt-capture v1";

const COLUMNS: &str = "# req t_us client key deadline_us shards arch";

/// Whether `c` must be percent-encoded in a `client=` value: the
/// grammar's reserved characters plus anything a terminal or diff tool
/// would mangle.
fn reserved(c: char) -> bool {
    c.is_whitespace() || c.is_control() || c == '%' || c == '='
}

/// Encode a client name for a `client=` token. Empty names render as
/// the `-` sentinel; reserved characters (see [`reserved`]) become
/// `%XX` per UTF-8 byte; a name whose encoding would otherwise read as
/// the bare sentinel renders as `%2D`. Injective over all names.
pub fn escape_client(name: &str) -> String {
    if name.is_empty() {
        return "-".to_string();
    }
    let mut out = String::with_capacity(name.len());
    for c in name.chars() {
        if reserved(c) {
            let mut buf = [0u8; 4];
            for b in c.encode_utf8(&mut buf).bytes() {
                out.push_str(&format!("%{b:02X}"));
            }
        } else {
            out.push(c);
        }
    }
    // `-` is never escaped, so `out == "-"` iff the name itself is `-`:
    // encode it so the token cannot collide with the no-client sentinel.
    if out == "-" {
        "%2D".to_string()
    } else {
        out
    }
}

/// Decode a `client=` token back to the original client name. `-` is
/// the default (empty) client. Rejects tokens [`escape_client`] cannot
/// produce: a raw `=`, a truncated or non-hex `%` escape, or bytes that
/// do not decode to UTF-8.
pub fn unescape_client(tok: &str) -> Result<String, String> {
    if tok == "-" {
        return Ok(String::new());
    }
    let bytes = tok.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' => {
                let hex = tok
                    .get(i + 1..i + 3)
                    .ok_or_else(|| format!("truncated `%` escape in client `{tok}`"))?;
                let v = u8::from_str_radix(hex, 16)
                    .map_err(|_| format!("bad `%` escape `%{hex}` in client `{tok}`"))?;
                out.push(v);
                i += 3;
            }
            b'=' => return Err(format!("unescaped `=` in client `{tok}`")),
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8(out).map_err(|_| format!("client `{tok}` does not decode to UTF-8"))
}

/// One parsed capture line: everything a replay driver needs to
/// re-issue the request.
#[derive(Debug, Clone, PartialEq)]
pub struct CaptureRecord {
    /// Original request id (unique within the capture).
    pub req: u64,
    /// Submit time in microseconds since pool start.
    pub t_us: f64,
    /// Decoded client name; empty = the default client.
    pub client: String,
    /// Kernel-image content key (`0x0` for non-image requests).
    pub key: u64,
    /// Remaining deadline budget at submit, rounded **up** to whole
    /// microseconds; `None` = best-effort.
    pub deadline_us: Option<u64>,
    /// Shard fan-out the planner chose (1 = unsharded).
    pub shards: u64,
    /// Shard target architecture label; `Some` exactly when `shards > 1`.
    pub arch: Option<String>,
}

impl CaptureRecord {
    /// Submit offset from pool start, exact to the nanosecond (the
    /// 3-decimal `t_us` rendering is a lossless ns encoding).
    pub fn offset(&self) -> Duration {
        Duration::from_nanos((self.t_us * 1e3).round() as u64)
    }

    /// Deadline budget to re-issue with. A recorded budget is never
    /// zero (zero means absent), so clamp defensively to 1 µs.
    pub fn deadline(&self) -> Option<Duration> {
        self.deadline_us.map(|us| Duration::from_micros(us.max(1)))
    }

    /// Render this record as one capture line (no trailing newline).
    pub fn line(&self) -> String {
        let deadline = match self.deadline_us {
            Some(d) => d.to_string(),
            None => "-".to_string(),
        };
        format!(
            "req={} t_us={:.3} client={} key={:#x} deadline_us={} shards={} arch={}",
            self.req,
            self.t_us,
            escape_client(&self.client),
            self.key,
            deadline,
            self.shards,
            self.arch.as_deref().unwrap_or("-"),
        )
    }
}

/// A parsed (or synthesized) capture: the request lines plus the lossy
/// marker from the `# dropped=N` trailer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Capture {
    /// Request lines in submit order.
    pub records: Vec<CaptureRecord>,
    /// Trace records overwritten at capture time; `> 0` means the
    /// request lines under-represent the recorded workload.
    pub dropped: u64,
}

impl Capture {
    /// Build a capture from a drained trace snapshot: one record per
    /// `Submit`, joined with its `ShardPlanned` fan-out/arch when one
    /// was recorded. `dropped` is the ring's overwrite count — when
    /// non-zero the rendering carries a `# dropped=N` trailer so
    /// consumers can tell a complete capture from a truncated one.
    pub fn from_records(records: &[TraceRecord], meta: &ExportMeta, dropped: u64) -> Capture {
        let mut shard: BTreeMap<u64, (u64, u64)> = BTreeMap::new();
        for r in records {
            if r.kind == EventKind::ShardPlanned {
                shard.insert(r.req, (r.a, r.b));
            }
        }
        let mut out = Vec::new();
        for r in records {
            if r.kind != EventKind::Submit {
                continue;
            }
            let (shards, arch) = match shard.get(&r.req) {
                Some(&(fanout, code)) => (fanout, Some(meta.arch(code).to_string())),
                None => (1, None),
            };
            out.push(CaptureRecord {
                req: r.req,
                t_us: r.t_ns as f64 / 1e3,
                client: meta.client(r.a).to_string(),
                key: r.b,
                // Round *up*: a sub-microsecond budget (1..999 ns) must
                // not collapse to 0, which replay could not distinguish
                // from "already missed"; 0 is reserved for absent.
                deadline_us: if r.c == 0 { None } else { Some(r.c.div_ceil(1_000)) },
                shards,
                arch,
            });
        }
        Capture { records: out, dropped }
    }

    /// Render the capture in the `# omprt-capture v1` wire format.
    pub fn to_text(&self) -> String {
        let mut out = String::with_capacity(64 + self.records.len() * 80);
        out.push_str(CAPTURE_HEADER);
        out.push('\n');
        out.push_str(COLUMNS);
        out.push('\n');
        for r in &self.records {
            out.push_str(&r.line());
            out.push('\n');
        }
        if self.dropped > 0 {
            out.push_str(&format!("# dropped={}\n", self.dropped));
        }
        out
    }
}

/// Parse a `# omprt-capture v1` document into a [`Capture`], enforcing
/// the full grammar contract (see the module docs). This is the strict
/// counterpart of [`super::export::validate_capture`] — same grammar,
/// but it returns the typed records instead of just counting them.
pub fn parse_capture(text: &str) -> Result<Capture, String> {
    let mut lines = text.lines();
    match lines.next() {
        Some(CAPTURE_HEADER) => {}
        other => {
            return Err(format!(
                "line 1: expected `{CAPTURE_HEADER}` header, got {other:?}"
            ))
        }
    }
    const KEYS: [&str; 7] = ["req", "t_us", "client", "key", "deadline_us", "shards", "arch"];
    let mut seen_req = BTreeSet::new();
    let mut last_t = f64::NEG_INFINITY;
    let mut records = Vec::new();
    let mut dropped: Option<u64> = None;
    for (i, line) in lines.enumerate() {
        let lineno = i + 2; // 1-based, after the header
        if let Some(rest) = line.strip_prefix("# dropped=") {
            if dropped.is_some() {
                return Err(format!("line {lineno}: duplicate `# dropped=` trailer"));
            }
            let n: u64 = rest
                .trim()
                .parse()
                .map_err(|_| format!("line {lineno}: bad `# dropped=` count `{rest}`"))?;
            dropped = Some(n);
            continue;
        }
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        if dropped.is_some() {
            return Err(format!(
                "line {lineno}: request line after the `# dropped=` trailer"
            ));
        }
        let tokens: Vec<&str> = line.split_whitespace().collect();
        if tokens.len() != KEYS.len() {
            return Err(format!(
                "line {lineno}: expected {} `key=value` tokens, got {}",
                KEYS.len(),
                tokens.len()
            ));
        }
        let mut vals = [""; 7];
        for (slot, (tok, key)) in tokens.iter().zip(KEYS).enumerate() {
            vals[slot] = match tok.strip_prefix(key).and_then(|r| r.strip_prefix('=')) {
                Some(v) if !v.is_empty() => v,
                _ => {
                    return Err(format!(
                        "line {lineno}: token {} must be `{key}=<value>`, got `{tok}`",
                        slot + 1
                    ))
                }
            };
        }
        let [req, t_us, client, key, deadline, shards, arch] = vals;
        let req: u64 = req
            .parse()
            .map_err(|_| format!("line {lineno}: bad req id `{req}`"))?;
        if !seen_req.insert(req) {
            return Err(format!("line {lineno}: duplicate req id {req}"));
        }
        let t: f64 = t_us
            .parse()
            .map_err(|_| format!("line {lineno}: bad t_us `{t_us}`"))?;
        if !t.is_finite() {
            return Err(format!("line {lineno}: non-finite t_us `{t_us}`"));
        }
        if t < last_t {
            return Err(format!(
                "line {lineno}: t_us {t} goes backwards (previous {last_t})"
            ));
        }
        last_t = t;
        let client = unescape_client(client).map_err(|e| format!("line {lineno}: {e}"))?;
        let hex = key
            .strip_prefix("0x")
            .ok_or_else(|| format!("line {lineno}: key must be 0x-hex, got `{key}`"))?;
        let key = u64::from_str_radix(hex, 16)
            .map_err(|_| format!("line {lineno}: bad hex key `0x{hex}`"))?;
        let deadline_us = if deadline == "-" {
            None
        } else {
            Some(
                deadline
                    .parse::<u64>()
                    .map_err(|_| format!("line {lineno}: bad deadline_us `{deadline}`"))?,
            )
        };
        let fanout: u64 = shards
            .parse()
            .map_err(|_| format!("line {lineno}: bad shards `{shards}`"))?;
        if fanout == 0 {
            return Err(format!("line {lineno}: shards must be >= 1"));
        }
        if (fanout > 1) != (arch != "-") {
            return Err(format!(
                "line {lineno}: shards={fanout} inconsistent with arch={arch} \
                 (fan-out > 1 exactly when a shard arch is recorded)"
            ));
        }
        records.push(CaptureRecord {
            req,
            t_us: t,
            client,
            key,
            deadline_us,
            shards: fanout,
            arch: (arch != "-").then(|| arch.to_string()),
        });
    }
    Ok(Capture {
        records,
        dropped: dropped.unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(req: u64, t_us: f64, client: &str) -> CaptureRecord {
        CaptureRecord {
            req,
            t_us,
            client: client.to_string(),
            key: 0xabc,
            deadline_us: None,
            shards: 1,
            arch: None,
        }
    }

    #[test]
    fn escape_is_injective_over_hostile_names() {
        let hostile = [
            "", "-", "%2D", "a b", "a\tb", "a=b", "a%b", "=", "%", "a-b", "a_b",
            "tenant a", "100%", "x\ny", "héllo wörld",
        ];
        let mut seen = std::collections::BTreeMap::new();
        for name in hostile {
            let esc = escape_client(name);
            // No reserved characters survive, and the token never reads
            // as the bare sentinel unless the name is empty.
            assert!(!esc.contains(char::is_whitespace), "{name:?} -> {esc}");
            assert!(!esc.contains('='), "{name:?} -> {esc}");
            assert_eq!(esc == "-", name.is_empty(), "{name:?} -> {esc}");
            if let Some(prev) = seen.insert(esc.clone(), name) {
                panic!("{prev:?} and {name:?} both escape to `{esc}`");
            }
            assert_eq!(unescape_client(&esc).unwrap(), name, "via `{esc}`");
        }
    }

    #[test]
    fn sentinel_and_collision_cases() {
        assert_eq!(escape_client(""), "-");
        assert_eq!(escape_client("-"), "%2D");
        assert_eq!(escape_client("a=b"), "a%3Db");
        assert_eq!(escape_client("a b"), "a%20b");
        assert_eq!(unescape_client("-").unwrap(), "");
        assert_eq!(unescape_client("%2D").unwrap(), "-");
    }

    #[test]
    fn unescape_rejects_tokens_escape_never_produces() {
        for bad in ["a=b", "%", "%2", "%zz", "a%fz"] {
            assert!(unescape_client(bad).is_err(), "must reject `{bad}`");
        }
        // Escapes that decode to invalid UTF-8 are refused too.
        assert!(unescape_client("%FF%FE").is_err());
    }

    #[test]
    fn render_parse_round_trip_preserves_records() {
        let cap = Capture {
            records: vec![
                CaptureRecord { deadline_us: Some(1), ..rec(1, 0.0, "tenant a") },
                CaptureRecord { key: 0x1f, ..rec(2, 12.345, "a=b") },
                CaptureRecord {
                    shards: 2,
                    arch: Some("nvptx64".to_string()),
                    deadline_us: Some(250_000),
                    ..rec(3, 500.0, "-")
                },
                rec(4, 500.0, ""),
            ],
            dropped: 0,
        };
        let text = cap.to_text();
        assert!(text.starts_with("# omprt-capture v1\n"), "{text}");
        let back = parse_capture(&text).unwrap();
        assert_eq!(back, cap, "{text}");
    }

    #[test]
    fn offset_is_exact_to_the_nanosecond() {
        let r = rec(1, 12.345, "c");
        assert_eq!(r.offset(), Duration::from_nanos(12_345));
        assert_eq!(rec(2, 0.0, "c").offset(), Duration::ZERO);
    }

    #[test]
    fn dropped_trailer_round_trips_and_is_strict() {
        let cap = Capture { records: vec![rec(1, 0.0, "c")], dropped: 7 };
        let text = cap.to_text();
        assert!(text.ends_with("# dropped=7\n"), "{text}");
        assert_eq!(parse_capture(&text).unwrap().dropped, 7);
        // Absent trailer means lossless.
        assert_eq!(parse_capture("# omprt-capture v1\n").unwrap().dropped, 0);
        // Malformed, duplicated or non-trailing forms are errors.
        for (bad, why) in [
            ("# omprt-capture v1\n# dropped=x\n", "dropped"),
            ("# omprt-capture v1\n# dropped=1\n# dropped=2\n", "duplicate"),
            (
                "# omprt-capture v1\n# dropped=1\nreq=1 t_us=0.1 client=c key=0xa deadline_us=- shards=1 arch=-\n",
                "after",
            ),
        ] {
            let err = parse_capture(bad).unwrap_err();
            assert!(err.contains(why), "{bad:?} -> {err}");
        }
    }

    #[test]
    fn parse_rejects_undecodable_client_tokens() {
        let hdr = "# omprt-capture v1\n";
        for bad in ["client=a=b", "client=%zz", "client=%2"] {
            let line = format!("req=1 t_us=0.1 {bad} key=0xa deadline_us=- shards=1 arch=-\n");
            let err = parse_capture(&format!("{hdr}{line}")).unwrap_err();
            assert!(err.contains("line 2") && err.contains("client"), "{bad} -> {err}");
        }
    }
}
