//! §4.1 code comparison: print the legacy- and portable-built runtime
//! libraries, diff them, and classify the differences.

use omprt::devrt::{self, RuntimeKind};
use omprt::ir::printer::{diff_text, print_module};
use omprt::sim::Arch;

fn main() {
    for arch in Arch::all() {
        let legacy = devrt::build(RuntimeKind::Legacy, arch);
        let portable = devrt::build(RuntimeKind::Portable, arch);
        let a = print_module(&legacy.ir_library);
        let b = print_module(&portable.ir_library);
        let d = diff_text(&a, &b);
        println!("== {arch} ==");
        println!("  library text: legacy {} lines, portable {} lines", a.lines().count(), b.lines().count());
        println!("  differing lines: {} legacy-only, {} portable-only", d.only_a.len(), d.only_b.len());
        println!("  diff is metadata + symbol mangling only: {}", d.only_metadata_and_mangling());
        println!("  sample legacy-only lines:");
        for l in d.only_a.iter().take(4) {
            println!("    {l}");
        }
        println!("  sample portable-only lines:");
        for l in d.only_b.iter().take(4) {
            println!("    {l}");
        }
    }
}
