//! BENCH (E6): ablation of the paper's §2.3 co-optimization claim — the
//! runtime linked as an IR library and inlined (O2) vs kept out-of-line
//! (O0). Measures the atomics-heavy pep benchmark per opt level.

use omprt::benchmarks::{by_name, Scale};
use omprt::coordinator::Coordinator;
use omprt::devrt::{irlib, RuntimeKind};
use omprt::hostrt::{DataEnv, MapType};
use omprt::ir::passes::OptLevel;
use omprt::ir::{FunctionBuilder, Module, Operand, Type};
use omprt::sim::{Arch, LaunchConfig};
use omprt::util::clock;

fn atomic_loop_module(iters: i32) -> Module {
    let mut m = Module::new("abl");
    let mut b = FunctionBuilder::new("k", &[Type::I64], None).kernel();
    let out = b.param(0);
    irlib::emit_spmd_prologue(&mut b);
    b.for_range(Operand::i32(0), Operand::i32(iters), Operand::i32(1), |b, _| {
        b.call("__kmpc_atomic_add", &[out.into(), Operand::i32(1)], Type::I32);
    });
    irlib::emit_spmd_epilogue(&mut b);
    b.ret();
    m.add_func(b.build());
    m
}

fn main() {
    println!("\n=== E6 ablation: runtime inlined (O2) vs out-of-line (O0) ===\n");
    let c = Coordinator::new(RuntimeKind::Portable, Arch::Nvptx64);
    for (level, label) in [(OptLevel::O0, "O0 (out-of-line)"), (OptLevel::O2, "O2 (inlined)  ")] {
        let image = c.prepare(atomic_loop_module(4000), level).unwrap();
        let mut env = DataEnv::new(&c.device);
        let out = vec![0u32; 1];
        let d = env.map(&out, MapType::Tofrom).unwrap();
        c.device.offload(&image, "k", &[d], LaunchConfig::new(2, 64)).unwrap(); // warmup
        let mut best = f64::MAX;
        for _ in 0..5 {
            let t0 = clock::now();
            c.device.offload(&image, "k", &[d], LaunchConfig::new(2, 64)).unwrap();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        println!(
            "{label}: {:.3} ms   (inlined {} call sites, folded {}, removed {})",
            best * 1e3,
            image.opt_stats.inlined,
            image.opt_stats.folded,
            image.opt_stats.removed
        );
    }
    // Also show a full benchmark under O2 for context.
    let bench = by_name("pep", Scale::Small).unwrap();
    let r = bench.run(&c).unwrap();
    println!("\npep (O2 path, small): {:.3} ms, verified={}", r.kernel_wall.as_secs_f64() * 1e3, r.verified);
}
